from repro.data.pipeline import MemmapSource, Prefetcher, SyntheticSource
__all__ = ["MemmapSource", "Prefetcher", "SyntheticSource"]
