"""Deterministic, seekable, host-sharded data pipeline.

Fault-tolerance contract (DESIGN.md §6): ``batch_at(step)`` is a pure
function of (seed, step, host shard), so a restarted/rescaled job resumes
from the checkpointed step with byte-identical data — no sample loss, no
duplicate visits, and straggler re-assignment is just re-indexing.

Two sources:
  * SyntheticSource — counter-based tokens (splitmix-style hash); used by
    examples/tests and the dry-run.
  * MemmapSource — token stream from a binary .npy/.bin file, windowed.

A background prefetch thread keeps ``depth`` batches ready (host-side
overlap of data and compute — the paper's H2D stage at the training level).
"""

from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


def _splitmix64(x: np.ndarray) -> np.ndarray:
    x = (x + np.uint64(0x9E3779B97F4A7C15)).astype(np.uint64)
    z = x
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


class SyntheticSource:
    """Deterministic token batches: token[b, s] = hash(seed, step, b, s)."""

    def __init__(self, vocab_size: int, seed: int = 0):
        self.vocab = vocab_size
        self.seed = seed

    def batch_at(self, step: int, batch: int, seq: int,
                 host_index: int = 0, host_count: int = 1) -> Dict:
        assert batch % host_count == 0
        local = batch // host_count
        b0 = host_index * local
        idx = (np.uint64(self.seed) << np.uint64(40)) \
            + (np.uint64(step) << np.uint64(20))
        rows = np.arange(b0, b0 + local, dtype=np.uint64)[:, None]
        cols = np.arange(seq + 1, dtype=np.uint64)[None, :]
        h = _splitmix64(idx + rows * np.uint64(100003) + cols)
        toks = (h % np.uint64(self.vocab)).astype(np.int32)
        return {"inputs": toks[:, :-1], "labels": toks[:, 1:]}


class MemmapSource:
    """Token stream in a flat int32 file; step/host -> deterministic window."""

    def __init__(self, path: str, vocab_size: int):
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.vocab = vocab_size

    def batch_at(self, step: int, batch: int, seq: int,
                 host_index: int = 0, host_count: int = 1) -> Dict:
        assert batch % host_count == 0
        local = batch // host_count
        n = len(self.tokens)
        span = seq + 1
        stride = max(1, (n - span) // max(1, batch))
        b0 = host_index * local
        rows = []
        for b in range(b0, b0 + local):
            start = ((step * batch + b) * stride) % (n - span)
            rows.append(np.asarray(self.tokens[start:start + span]))
        toks = np.stack(rows) % self.vocab
        return {"inputs": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


class Prefetcher:
    """Background thread keeping ``depth`` upcoming batches materialized."""

    def __init__(self, source, batch: int, seq: int, start_step: int = 0,
                 depth: int = 2, host_index: int = 0, host_count: int = 1):
        self.source = source
        self.args = (batch, seq, host_index, host_count)
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self.step = start_step
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._fill, daemon=True)
        self.thread.start()

    def _fill(self):
        step = self.step
        batch, seq, hi, hc = self.args
        while not self._stop.is_set():
            b = self.source.batch_at(step, batch, seq, hi, hc)
            while not self._stop.is_set():
                try:
                    self.q.put((step, b), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        self.thread.join(timeout=2.0)
