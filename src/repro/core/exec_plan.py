"""ExecutablePlan — a :class:`~repro.core.streams.Schedule` compiled for
dispatch (DESIGN.md §13).

``ScheduleExecutor.run`` used to pay per-op string/dict work on every run:
handler-registry lookups keyed by kernel name, payload ``isinstance``
dispatch, and (in concurrent mode) it would additionally need the event
graph rebuilt from scratch.  ``compile_executable`` hoists all of that into
a one-time compile step:

  * **handler resolution** — the registered callable per COMPUTE / finalize
    op, pre-fetched from the global registry (per-executor overrides are
    still consulted at run time; they are instance state, not schedule
    state);
  * **engine assignment** — every op is mapped to the engine that would run
    it on real hardware: one H2D copy engine, one D2H copy engine, one
    kernel engine per stream (the same engine split
    :func:`~repro.core.simulator.gpu_like` models), with per-engine FIFO
    queues in issue order;
  * **dependency edges** — the direct happens-before edges of the event
    program (:func:`~repro.core.streams.dependency_edges`, the same edges
    ``validate_schedule``'s vector clocks close over), plus *host-coherence
    edges* ordering an H2D re-read of an output operand after every earlier
    D2H that lands an overlapping host slice (the serial interpreter gets
    this from its pending-flush sweep; the concurrent runner gets it from
    these compile-time edges).  Edges within one engine are pruned — the
    engine's FIFO order implies them.

The compiled plan is cached **on the schedule object itself** (schedules
are mutable, unhashable dataclasses, so identity is the right cache key and
the plan dies with the schedule).  A cached plan is revalidated with an
O(n) identity scan over ``sched.ops`` — appending, replacing, or reordering
ops invalidates it — and against the handler-registry version, so
registering a new kernel handler recompiles affected plans instead of
serving stale resolutions.  Repeated runs of one schedule (tuner replays,
hybrid bands, fault replays, benchmark reps) therefore skip every per-op
string/dict step: ``plan_cache_stats()`` exposes the hit/miss counters the
``exec_plan_cache_hit`` benchmark row guards.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.streams import (BlockRef, Op, OpKind, Schedule, SliceRef,
                                dependency_edges)

# integer op-kind codes (faster to branch on than enum identity in the
# per-op hot path)
KIND_H2D = 0
KIND_COMPUTE = 1
KIND_D2H = 2

# fixed engine slots; compute engines follow at index 2 + stream
ENGINE_H2D = 0
ENGINE_D2H = 1

_CACHE_ATTR = "_exec_plan"

_stats_lock = threading.Lock()
_stats = {"hits": 0, "misses": 0}


def plan_cache_stats() -> Dict[str, int]:
    """Process-wide plan-cache counters: ``{"hits": n, "misses": n}``."""
    with _stats_lock:
        return dict(_stats)


def reset_plan_cache_stats() -> None:
    with _stats_lock:
        _stats["hits"] = 0
        _stats["misses"] = 0


def _slices_may_overlap(a: SliceRef, b: SliceRef) -> bool:
    """Conservative host-span overlap test at compile time (no host shapes:
    a ``None`` extent means "the full axis" and overlaps everything)."""
    if a.operand != b.operand:
        return False

    def hit(sa: Optional[Tuple[int, int]], sb: Optional[Tuple[int, int]]
            ) -> bool:
        if sa is None or sb is None:
            return True
        return sa[0] < sb[0] + sb[1] and sb[0] < sa[0] + sa[1]

    return hit(a.rows, b.rows) and hit(a.cols, b.cols)


@dataclasses.dataclass
class ExecutablePlan:
    """Compiled, integer-indexed form of one schedule (see module doc).

    ``ops`` is an identity snapshot of ``sched.ops`` at compile time — the
    cache-validity witness *and* the object handlers receive (handlers take
    the full :class:`Op`, so the plan carries references, not copies).
    """

    sched: Schedule
    ops: List[Op]                       # snapshot, same objects as sched.ops
    n_ops: int
    kinds: List[int]                    # KIND_H2D / KIND_COMPUTE / KIND_D2H
    engines: List[str]                  # engine names, index = engine id
    engine_of: List[int]                # per-op engine id
    queues: List[List[int]]             # per-engine op indices, issue order
    preds: List[List[int]]              # cross-engine direct dependencies
    resolved: List[Optional[Callable]]  # registry handler per op (or None)
    kernels: List[Optional[str]]        # kernel name per handler op
    handlers_version: int               # registry version at compile time

    def is_valid_for(self, sched: Schedule, handlers_version: int) -> bool:
        ops = sched.ops
        if len(ops) != self.n_ops or handlers_version != self.handlers_version:
            return False
        mine = self.ops
        return all(mine[i] is ops[i] for i in range(self.n_ops))


def _compile(sched: Schedule, op_handlers: Dict[str, Callable],
             handlers_version: int) -> ExecutablePlan:
    ops = list(sched.ops)
    n = len(ops)
    n_streams = len(sched.streams)
    if ops:
        n_streams = max(n_streams, max(o.stream for o in ops) + 1)
    engines = ["h2d", "d2h"] + [f"compute:{s}" for s in range(n_streams)]

    kinds: List[int] = [0] * n
    engine_of: List[int] = [0] * n
    queues: List[List[int]] = [[] for _ in engines]
    resolved: List[Optional[Callable]] = [None] * n
    kernels: List[Optional[str]] = [None] * n

    _, preds = dependency_edges(sched)

    # D2H landing sites per output operand, for host-coherence edges
    d2h_slices: Dict[str, List[Tuple[int, SliceRef]]] = {}

    for i, op in enumerate(ops):
        ref = op.payload
        if op.kind == OpKind.H2D:
            kinds[i] = KIND_H2D
            engine_of[i] = ENGINE_H2D
        elif op.kind == OpKind.COMPUTE:
            kinds[i] = KIND_COMPUTE
            engine_of[i] = 2 + op.stream
            if isinstance(ref, BlockRef):
                kernels[i] = ref.kernel
                resolved[i] = op_handlers.get(ref.kernel)
        else:
            kinds[i] = KIND_D2H
            engine_of[i] = ENGINE_D2H
            if isinstance(ref, BlockRef):   # finalize handler
                kernels[i] = ref.kernel
                resolved[i] = op_handlers.get(ref.kernel)
            elif isinstance(ref, SliceRef):
                d2h_slices.setdefault(ref.operand, []).append((i, ref))
        queues[engine_of[i]].append(i)

    # host-coherence edges: an H2D whose source operand is also a D2H
    # target may read host bytes an earlier D2H writes (inout operands —
    # GEMM's C with beta != 0), so it must start after that D2H *lands*.
    # The event program orders the device buffers but not the host copy;
    # the serial interpreter flushes overlapping pending write-backs before
    # the read, the concurrent runner honors these explicit edges instead.
    for i, op in enumerate(ops):
        if kinds[i] != KIND_H2D or not isinstance(op.payload, SliceRef):
            continue
        sites = d2h_slices.get(op.payload.operand)
        if not sites:
            continue
        for j, jref in sites:
            if j < i and _slices_may_overlap(op.payload, jref):
                preds[i].append(j)

    # prune same-engine edges (the engine's FIFO walk implies them) and
    # duplicates; what remains is exactly the cross-engine wait set each
    # worker blocks on.
    pruned: List[List[int]] = []
    for i in range(n):
        e = engine_of[i]
        keep = sorted({j for j in preds[i] if engine_of[j] != e})
        pruned.append(keep)

    return ExecutablePlan(
        sched=sched, ops=ops, n_ops=n, kinds=kinds, engines=engines,
        engine_of=engine_of, queues=queues, preds=pruned,
        resolved=resolved, kernels=kernels,
        handlers_version=handlers_version)


def compile_executable(sched: Schedule) -> ExecutablePlan:
    """Compile ``sched`` into an :class:`ExecutablePlan`, served from the
    per-schedule cache when the op list and handler registry are unchanged
    since the last compile (see module doc for the invalidation rules)."""
    # late import: runtime owns the handler registry and imports this
    # module at load time, so the reference here must resolve lazily
    from repro.core import runtime as _rt

    version = _rt.handlers_version()
    cached = getattr(sched, _CACHE_ATTR, None)
    if cached is not None and cached.is_valid_for(sched, version):
        with _stats_lock:
            _stats["hits"] += 1
        return cached
    with _stats_lock:
        _stats["misses"] += 1
    plan = _compile(sched, _rt._OP_HANDLERS, version)
    setattr(sched, _CACHE_ATTR, plan)
    return plan
