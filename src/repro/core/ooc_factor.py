"""Out-of-core blocked Cholesky — the paper's stated future work (§VII:
"we plan to provide out-of-core factorizations (LU, QR, Cholesky) that use
the out-of-core matrix-matrix multiplication (DGEMM) as a fundamental
building block").

Right-looking blocked Cholesky on an SPD matrix held in host memory:

  for each panel k:
      A[k,k]  = chol(A[k,k])                     (in-core, panel-sized)
      A[i,k]  = A[i,k] @ inv(L[k,k])^T           (panel solve, in-core)
      A[i,j] -= A[i,k] @ A[j,k]^T                (trailing update — >90% of
                                                  FLOPs — executed by the
                                                  OOC GEMM engine)

Only O(panel x N) is resident during the panel steps; the trailing update is
the first-class SYRK pipeline spec streamed through the same
schedule/executor machinery as MMOOC.
"""

from __future__ import annotations

import numpy as np

from repro.core.oocgemm import ooc_syrk


def ooc_cholesky(A, panel: int = 256, *, budget_bytes: int,
                 backend: str = "host", tune=None,
                 tuner=None) -> np.ndarray:
    """Lower-triangular Cholesky factor of SPD ``A`` (host-resident).

    ``tune="auto"`` forwards to :func:`~repro.core.oocgemm.ooc_syrk`: each
    trailing-update shape gets its own cached plan (the shapes shrink as
    the factorization advances, so a handful of plans cover the run)."""
    A = np.array(A, copy=True)
    n = A.shape[0]
    assert A.shape == (n, n), "square SPD input required"

    for k0 in range(0, n, panel):
        k1 = min(n, k0 + panel)
        # 1. factor the diagonal block in-core
        A[k0:k1, k0:k1] = np.linalg.cholesky(A[k0:k1, k0:k1])
        Lkk = A[k0:k1, k0:k1]
        if k1 == n:
            break
        # 2. panel solve: A[i,k] <- A[i,k] @ inv(Lkk)^T
        #    (solve Lkk @ X^T = A[i,k]^T; the panel is the resident set)
        A[k1:, k0:k1] = np.linalg.solve(Lkk, A[k1:, k0:k1].T).T
        # 3. trailing symmetric update A[k1:, k1:] -= P @ P^T, streamed by
        #    the OOC SYRK spec (no host-side P.T materialization)
        P = np.ascontiguousarray(A[k1:, k0:k1])
        A[k1:, k1:] = np.asarray(ooc_syrk(
            P, A[k1:, k1:], alpha=-1.0, beta=1.0,
            budget_bytes=budget_bytes, backend=backend,
            tune=tune, tuner=tuner))
    return np.tril(A)
