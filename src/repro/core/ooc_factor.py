"""Out-of-core factorizations — the paper's §VII future work, first-class.

The paper closes by promising "out-of-core factorizations (LU, QR, Cholesky)
that use the out-of-core matrix-matrix multiplication (DGEMM) as a
fundamental building block".  Earlier revisions of this module were a host
loop calling :func:`~repro.core.oocgemm.ooc_syrk` once per panel — no
panel/update overlap, no LU.  Now the whole factorization is ONE compiled
:class:`~repro.core.streams.Schedule`
(:func:`~repro.core.pipeline.compile_factor_pipeline`) that interleaves
in-core panel ops (POTRF / partial-pivot GETRF, TRSM solves — registered op
handlers in ``core/runtime.py``) with the streamed SYRK/GEMM trailing
update, with a *lookahead* parameter: panel ``k+1`` factors while trailing
update ``k`` is still streaming, which is where blocked factorizations hide
their critical path (DESIGN.md §8).

Entry points:

  * :func:`ooc_cholesky` — lower-triangular factor of a host-resident SPD
    matrix.
  * :func:`ooc_lu` — right-looking LU with partial pivoting inside the
    resident panel and row-swap replay on write-back; returns ``(LU, perm)``
    with ``A[perm] = tril(LU, -1) + I  @  triu(LU)``.

Both accept ``tune="auto"`` (the autotuner plans panel width, trailing block
dims, stream/buffer counts and lookahead depth under one shrinking-dims
cache key) and ``devices=[...]`` (the trailing updates co-execute across a
heterogeneous device set via the hybrid subsystem; panel ops stay host-side,
as they are panel-sized).
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core import pipeline as plib
from repro.core.oocgemm import ooc_gemm, ooc_syrk
from repro.core.pipeline import FactorPipelineSpec, factor_pipeline_spec
from repro.core.runtime import (ScheduleExecutor, apply_panel_pivots,
                                getrf_panel)
from repro.core.streams import OpKind, validate_schedule
from repro.obs import get_observability


def _plan_factor_spec(kind: str, n: int, panel: int, budget_bytes: int,
                      bytes_per_el: int, lookahead: int,
                      nbuf: int) -> FactorPipelineSpec:
    """Feasible spec for the budget, degrading gracefully: try the requested
    (lookahead, panel) first, then drop the lookahead buffers, then halve
    the panel — the panel width is a performance hint, not a contract."""
    err: Optional[ValueError] = None
    pw = min(panel, n)
    while pw >= 1:
        for la in sorted({lookahead, 0}, reverse=True):
            try:
                return factor_pipeline_spec(
                    n, pw, budget_bytes, bytes_per_el,
                    kind=kind, lookahead=la, nbuf=nbuf)
            except ValueError as e:
                err = e
        pw //= 2
    raise err if err is not None else ValueError(
        f"no feasible {kind} pipeline for n={n} within {budget_bytes}B")


def _tuned_factor_spec(tuner, kind: str, n: int, panel: int,
                       budget_bytes: int, bytes_per_el: int,
                       dtype):
    """(spec, nstreams, nbuf, evict, plan) from the autotuner's factor plan
    — one cached search covers every shrinking per-panel trailing shape;
    the plan rides along so the caller can record prediction drift."""
    if tuner is None:
        from repro.tune import get_default_tuner
        tuner = get_default_tuner()
    plan = tuner.factor_plan(kind, n, panel, budget_bytes,
                             dtype=np.dtype(dtype).name)
    spec = factor_pipeline_spec(
        n, plan.param("panel"), budget_bytes, bytes_per_el, kind=kind,
        lookahead=plan.param("lookahead"), nbuf=plan.nbuf,
        bm=plan.param("bm"), bn=plan.param("bn"))
    return spec, plan.nstreams, plan.nbuf, plan.evict, plan


def _run_factor(A: np.ndarray, spec: FactorPipelineSpec, nstreams: int,
                nbuf: int, validate: bool, evict: str = "lru", plan=None,
                faults=None, policy=None):
    """Compile + execute the factor schedule over a copy of ``A``; returns
    (factored matrix, executor state) — LU's permutation rides in scratch.

    When a trace is active the executor records its pipeline as the
    ``factor:<kind>`` lane group; a tuned ``plan`` additionally yields a
    drift record (whole-factorization predicted vs measured) and the
    ``repro_factor_*`` gauges expose the searched lookahead/panel shape.
    """
    obs = get_observability()
    sched = plib.compile_factor_pipeline(spec, nstreams=nstreams, nbuf=nbuf,
                                         evict=evict)
    if validate:
        validate_schedule(sched)
    out = np.array(A, copy=True)
    ex = ScheduleExecutor(record_spans=obs.tracer is not None,
                          trace_group=f"factor:{spec.kind}")
    state = ex.run(
        sched, operands={}, outputs={"A": out},
        ctx={"alpha": -1.0, "beta": 1.0, "panel": spec.panel, "n": spec.n},
        faults=faults, policy=policy)
    if obs.metrics.enabled:
        kernel = f"{spec.kind}-factor"
        obs.metrics.gauge(
            "repro_factor_lookahead_depth",
            "panels factored ahead of the streaming trailing update").set(
                spec.lookahead, kernel=kernel)
        obs.metrics.gauge(
            "repro_factor_panel_width",
            "resident panel width of the last factorization").set(
                spec.panel, kernel=kernel)
    if plan is not None:
        obs.record_drift(
            plan.kernel, plan.tier, plan.fingerprint,
            predicted_makespan=plan.makespan,
            measured_seconds=ex.last_wall_seconds,
            predicted_h2d_bytes=sched.total_bytes(OpKind.H2D),
            measured_h2d_bytes=ex.last_h2d_bytes,
            predicted_d2h_bytes=sched.total_bytes(OpKind.D2H),
            measured_d2h_bytes=ex.last_d2h_bytes)
    return out, state


def _run_factor_resilient(A, kind, spec, nstreams, nbuf, validate, evict,
                          plan, *, faults, policy, panel, budget_bytes, bpe,
                          dtype, tune, tuner):
    """:func:`_run_factor` with the oom degradation ladder (DESIGN.md §12)
    wrapped around it: an injected (or real) device oom aborts the run,
    after which successive ladder rungs — halve nbuf, drop lookahead,
    halve the budget (tuned plans: budget halvings only, each re-searched)
    — recompile through the existing planning paths until one executes.
    The degraded re-run is fault-free: the oom occurrence was consumed by
    the failed attempt.  Every attempted rung is recorded in
    ``policy.degrades``."""
    if faults is None:
        return _run_factor(A, spec, nstreams, nbuf, validate, evict=evict,
                           plan=plan)
    from repro.fault.errors import OomError
    from repro.fault.policy import FaultPolicy
    policy = policy or FaultPolicy()
    try:
        return _run_factor(A, spec, nstreams, nbuf, validate, evict=evict,
                           plan=plan, faults=faults, policy=policy)
    except OomError:
        obs = get_observability()
        n = A.shape[0]
        kernel = f"{kind}-factor"
        for step in policy.degrade_ladder(nbuf=nbuf,
                                          lookahead=spec.lookahead,
                                          budget_bytes=budget_bytes,
                                          tuned=tune == "auto"):
            policy.degrades.append(step)
            obs.instant(f"fault:degrade:{step.action}", kernel=kernel)
            try:
                if tune == "auto":
                    spec2, ns2, nb2, ev2, plan2 = _tuned_factor_spec(
                        tuner, kind, n, panel, step.budget_bytes, bpe,
                        dtype)
                else:
                    spec2 = _plan_factor_spec(
                        kind, n, panel, step.budget_bytes, bpe,
                        step.lookahead, step.nbuf)
                    ns2, nb2, ev2, plan2 = nstreams, step.nbuf, evict, None
                result = _run_factor(A, spec2, ns2, nb2, validate,
                                     evict=ev2, plan=plan2)
            except ValueError:
                continue
            obs.record_fault_recovery(kernel, "degrade")
            return result
        raise


def _check_square(A) -> int:
    n = A.shape[0]
    if A.ndim != 2 or A.shape != (n, n):
        raise ValueError(f"square matrix required, got {A.shape}")
    return n


def ooc_cholesky(A, panel: int = 256, *, budget_bytes: int,
                 backend: str = "host", tune=None, tuner=None,
                 lookahead: int = 1, nstreams: int = 2, nbuf: int = 2,
                 evict: str = "lru", validate: bool = False,
                 devices: Optional[Sequence] = None,
                 tolerance: Optional[float] = None,
                 faults=None, fault_policy=None) -> np.ndarray:
    """Lower-triangular Cholesky factor of SPD ``A`` (host-resident).

    Host backend (default): the factorization is one lookahead pipeline
    schedule — panel POTRF/TRSM ops interleaved with the streamed SYRK
    trailing update; ``lookahead=0`` degenerates to the sequential
    per-panel loop.  ``tune="auto"`` resolves panel width, trailing block
    dims, stream count, buffer depth and lookahead from the autotuner.
    ``evict`` picks the factored-row block cache's eviction policy
    (``"lru"``/``"belady"``) — it changes only H2D traffic, never the
    factor; tuned plans carry their own.

    ``devices=[...]`` (or a non-host ``backend``) falls back to the
    per-panel loop with the trailing update executed by
    :func:`~repro.core.oocgemm.ooc_syrk` on that backend / hybrid device
    set — panels are panel-sized and stay on the host.

    Precision: the streaming engine computes in float32 (JAX x64 is off in
    this stack), so a float64 input returns a float64 array with
    f32-accurate residuals (~1e-6 relative, not LAPACK's ~1e-15) — pair
    with iterative refinement if full f64 accuracy matters.
    """
    if tune not in (None, "auto"):
        raise ValueError(f"unknown tune mode {tune!r}; expected None/'auto'")
    A = np.asarray(A)
    n = _check_square(A)
    if devices is not None or backend != "host":
        if faults is not None:
            raise ValueError("fault injection is supported on the host "
                             "pipeline backend only (hybrid paths take "
                             "fault_plans on run_hybrid_*)")
        return _loop_cholesky(A, panel, budget_bytes, backend, tune, tuner,
                              devices, tolerance)
    bpe = np.dtype(A.dtype).itemsize
    plan = None
    if tune == "auto":
        spec, nstreams, nbuf, evict, plan = _tuned_factor_spec(
            tuner, "cholesky", n, panel, budget_bytes, bpe, A.dtype)
    else:
        spec = _plan_factor_spec("cholesky", n, panel, budget_bytes, bpe,
                                 lookahead, nbuf)
    out, _ = _run_factor_resilient(
        A, "cholesky", spec, nstreams, nbuf, validate, evict, plan,
        faults=faults, policy=fault_policy, panel=panel,
        budget_bytes=budget_bytes, bpe=bpe, dtype=A.dtype, tune=tune,
        tuner=tuner)
    return np.tril(out)


def ooc_lu(A, panel: int = 256, *, budget_bytes: int,
           backend: str = "host", tune=None, tuner=None,
           lookahead: int = 1, nstreams: int = 2, nbuf: int = 2,
           evict: str = "lru", validate: bool = False,
           devices: Optional[Sequence] = None,
           tolerance: Optional[float] = None,
           faults=None, fault_policy=None
           ) -> Tuple[np.ndarray, np.ndarray]:
    """Right-looking LU with partial pivoting: ``A[perm] = L @ U``.

    Returns ``(LU, perm)``: ``LU`` packs the unit-lower ``L`` below the
    diagonal and ``U`` on/above it; ``perm`` is the row permutation such
    that ``A[perm]`` equals ``(tril(LU, -1) + I) @ triu(LU)``.

    Pivot search runs over the full resident panel (true partial pivoting:
    the panel holds every remaining row of its columns); row swaps replay on
    the host columns outside the panel at panel write-back
    (``lu_writeback`` handler), so the trailing stream always reads
    consistently permuted rows.  ``lookahead`` overlaps the next panel's
    transfer+GETRF with the current trailing update; ``tune="auto"`` and
    ``devices=[...]`` behave as in :func:`ooc_cholesky` (the hybrid path
    co-executes the GEMM trailing update across the device set).  As there,
    the engine computes in float32 regardless of input dtype — float64
    results carry f32-level residuals.
    """
    if tune not in (None, "auto"):
        raise ValueError(f"unknown tune mode {tune!r}; expected None/'auto'")
    A = np.asarray(A)
    n = _check_square(A)
    if devices is not None or backend != "host":
        if faults is not None:
            raise ValueError("fault injection is supported on the host "
                             "pipeline backend only (hybrid paths take "
                             "fault_plans on run_hybrid_*)")
        return _loop_lu(A, panel, budget_bytes, backend, tune, tuner,
                        devices, tolerance)
    bpe = np.dtype(A.dtype).itemsize
    plan = None
    if tune == "auto":
        spec, nstreams, nbuf, evict, plan = _tuned_factor_spec(
            tuner, "lu", n, panel, budget_bytes, bpe, A.dtype)
    else:
        spec = _plan_factor_spec("lu", n, panel, budget_bytes, bpe,
                                 lookahead, nbuf)
    out, state = _run_factor_resilient(
        A, "lu", spec, nstreams, nbuf, validate, evict, plan,
        faults=faults, policy=fault_policy, panel=panel,
        budget_bytes=budget_bytes, bpe=bpe, dtype=A.dtype, tune=tune,
        tuner=tuner)
    return out, state.scratch.get("perm", np.arange(n))


# ---------------------------------------------------------------------------
# Per-panel loop: the non-host backends and the hybrid device path (panel
# math host-side, trailing update through the OOC kernels)
# ---------------------------------------------------------------------------
def _trailing_kwargs(budget_bytes, backend, tune, tuner, devices, tolerance):
    kw = dict(budget_bytes=budget_bytes, backend=backend, tune=tune,
              tuner=tuner)
    if devices is not None:
        kw.update(devices=devices, tolerance=tolerance)
    return kw


def _loop_cholesky(A, panel, budget_bytes, backend, tune, tuner, devices,
                   tolerance) -> np.ndarray:
    A = np.array(A, copy=True)
    n = A.shape[0]
    kw = _trailing_kwargs(budget_bytes, backend, tune, tuner, devices,
                          tolerance)
    for k0 in range(0, n, panel):
        k1 = min(n, k0 + panel)
        A[k0:k1, k0:k1] = np.linalg.cholesky(A[k0:k1, k0:k1])
        if k1 == n:
            break
        A[k1:, k0:k1] = np.linalg.solve(A[k0:k1, k0:k1],
                                        A[k1:, k0:k1].T).T
        P = np.ascontiguousarray(A[k1:, k0:k1])
        A[k1:, k1:] = np.asarray(ooc_syrk(
            P, A[k1:, k1:], alpha=-1.0, beta=1.0, **kw))
    return np.tril(A)


def _loop_lu(A, panel, budget_bytes, backend, tune, tuner, devices,
             tolerance) -> Tuple[np.ndarray, np.ndarray]:
    A = np.array(A, copy=True)
    n = A.shape[0]
    perm = np.arange(n)
    kw = _trailing_kwargs(budget_bytes, backend, tune, tuner, devices,
                          tolerance)
    for k0 in range(0, n, panel):
        k1 = min(n, k0 + panel)
        pnl = np.ascontiguousarray(A[k0:, k0:k1])
        piv = getrf_panel(pnl)
        apply_panel_pivots(A, piv, k0, k1, perm)
        A[k0:, k0:k1] = pnl
        if k1 == n:
            break
        lkk = np.tril(A[k0:k1, k0:k1], -1) + np.eye(k1 - k0, dtype=A.dtype)
        A[k0:k1, k1:] = np.linalg.solve(lkk, A[k0:k1, k1:])
        L = np.ascontiguousarray(A[k1:, k0:k1])
        U = np.ascontiguousarray(A[k0:k1, k1:])
        A[k1:, k1:] = np.asarray(ooc_gemm(
            L, U, A[k1:, k1:], alpha=-1.0, beta=1.0, **kw))
    return A, perm
