"""repro.core — libhclooc's contribution, TPU-native.

Public surface:
  * plan_gemm_partition / plan_attention_partition  (hclMatrixPartitioner)
  * PipelineSpec / compile_pipeline + the kernel specs (gemm / attention /
    syrk / vendor) and their build_*_schedule wrappers
  * validate_schedule, simulate, hardware models
  * ooc_gemm / ooc_syrk / ooc_attention              (MMOOC and friends)
  * ScheduleExecutor / register_op_handler           (the one interpreter)
  * HostOocRuntime / VmemOocRuntime / MeshOocRuntime (hclRuntime hierarchy)
  * api: hcl-prefixed facade for paper-parity code
"""

from repro.core.oocgemm import is_in_core, ooc_gemm, ooc_syrk, plan_for_device
from repro.core.ooc_attention import ooc_attention
from repro.core.ooc_factor import ooc_cholesky, ooc_lu
from repro.core.partitioner import (
    TRAVERSALS,
    AttentionPartition,
    GemmPartition,
    plan_attention_partition,
    plan_gemm_partition,
    traversal_order,
)
from repro.core.pipeline import (
    EVICT_POLICIES,
    BlockCache,
    ComputeStage,
    FactorPipelineSpec,
    PipelineSpec,
    StreamedOperand,
    WriteBack,
    attention_pipeline_spec,
    build_attention_schedule,
    build_gemm_schedule,
    build_syrk_schedule,
    build_vendor_schedule,
    compile_factor_pipeline,
    compile_pipeline,
    factor_pipeline_spec,
    gemm_pipeline_spec,
    schedule_stats,
    syrk_pipeline_spec,
    vendor_pipeline_spec,
)
from repro.core.exec_plan import (
    ExecutablePlan,
    compile_executable,
    plan_cache_stats,
)
from repro.core.runtime import (
    ExecState,
    HostOocRuntime,
    MeshOocRuntime,
    OocRuntime,
    RuntimeFactory,
    ScheduleExecutor,
    VmemOocRuntime,
    register_op_handler,
    register_runtime,
)
from repro.core.simulator import (
    HardwareModel,
    SimResult,
    gpu_like,
    phi_like,
    simulate,
    simulate_reference,
    tpu_v5e_ici,
    tpu_v5e_vmem,
)
from repro.core.trace import (
    chrome_trace,
    chrome_trace_groups,
    write_chrome_trace,
    write_chrome_trace_groups,
)
from repro.core.streams import (
    BlockRef,
    Device,
    Event,
    Op,
    OpKind,
    Schedule,
    ScheduleError,
    SliceRef,
    Stream,
    StreamFactory,
    validate_schedule,
)

__all__ = [
    "AttentionPartition", "BlockCache", "BlockRef", "ComputeStage",
    "Device", "EVICT_POLICIES", "Event",
    "ExecState", "ExecutablePlan", "FactorPipelineSpec", "GemmPartition",
    "HardwareModel",
    "HostOocRuntime", "MeshOocRuntime", "Op", "OpKind", "OocRuntime",
    "PipelineSpec", "RuntimeFactory", "Schedule", "ScheduleError",
    "ScheduleExecutor", "SimResult", "SliceRef", "Stream", "StreamFactory",
    "StreamedOperand", "TRAVERSALS", "VmemOocRuntime", "WriteBack",
    "attention_pipeline_spec", "build_attention_schedule",
    "build_gemm_schedule", "build_syrk_schedule", "build_vendor_schedule",
    "chrome_trace", "chrome_trace_groups", "compile_executable",
    "compile_factor_pipeline", "compile_pipeline", "factor_pipeline_spec",
    "gemm_pipeline_spec", "gpu_like", "is_in_core", "ooc_attention",
    "ooc_cholesky", "ooc_gemm", "ooc_lu", "ooc_syrk", "phi_like",
    "plan_cache_stats", "plan_attention_partition",
    "plan_for_device", "plan_gemm_partition", "register_op_handler",
    "register_runtime", "schedule_stats", "simulate", "simulate_reference",
    "syrk_pipeline_spec", "tpu_v5e_ici", "tpu_v5e_vmem", "traversal_order",
    "validate_schedule", "vendor_pipeline_spec", "write_chrome_trace",
    "write_chrome_trace_groups",
]
