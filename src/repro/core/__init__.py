"""repro.core — libhclooc's contribution, TPU-native.

Public surface:
  * plan_gemm_partition / plan_attention_partition  (hclMatrixPartitioner)
  * build_gemm_schedule / build_attention_schedule / build_vendor_schedule
  * validate_schedule, simulate, hardware models
  * ooc_gemm / ooc_attention                        (MMOOC and friends)
  * HostOocRuntime / VmemOocRuntime / MeshOocRuntime (hclRuntime hierarchy)
  * api: hcl-prefixed facade for paper-parity code
"""

from repro.core.oocgemm import is_in_core, ooc_gemm, plan_for_device
from repro.core.ooc_attention import ooc_attention
from repro.core.partitioner import (
    AttentionPartition,
    GemmPartition,
    plan_attention_partition,
    plan_gemm_partition,
)
from repro.core.pipeline import (
    build_attention_schedule,
    build_gemm_schedule,
    build_vendor_schedule,
    schedule_stats,
)
from repro.core.runtime import (
    HostOocRuntime,
    MeshOocRuntime,
    OocRuntime,
    RuntimeFactory,
    VmemOocRuntime,
)
from repro.core.simulator import (
    HardwareModel,
    SimResult,
    gpu_like,
    phi_like,
    simulate,
    tpu_v5e_ici,
    tpu_v5e_vmem,
)
from repro.core.streams import (
    Device,
    Event,
    Op,
    OpKind,
    Schedule,
    ScheduleError,
    Stream,
    StreamFactory,
    validate_schedule,
)

__all__ = [
    "AttentionPartition", "Device", "Event", "GemmPartition",
    "HardwareModel", "HostOocRuntime", "MeshOocRuntime", "Op", "OpKind",
    "OocRuntime", "RuntimeFactory", "Schedule", "ScheduleError", "SimResult",
    "Stream", "StreamFactory", "VmemOocRuntime",
    "build_attention_schedule", "build_gemm_schedule",
    "build_vendor_schedule", "gpu_like", "is_in_core", "ooc_attention",
    "ooc_gemm", "phi_like", "plan_attention_partition", "plan_for_device",
    "plan_gemm_partition", "schedule_stats", "simulate", "tpu_v5e_ici",
    "tpu_v5e_vmem", "validate_schedule",
]
