"""Out-of-core attention — the engine's second data-parallel kernel.

Reuses the MMOOC pipeline machinery (claim: the synchronization pattern is
kernel-agnostic).  The KV cache plays the role of the out-of-core operand;
queries stay resident; each streamed (K, V) block updates an online-softmax
carry (m, l, acc) — a different merge operator in the same schedule.

This is the host-driven variant, executing the Schedule op-by-op like
``HostOocRuntime``.  The jit-compatible in-model variant (lax.scan over KV
blocks) lives in ``models/layers.py``; the Pallas in-VMEM variant in
``kernels/flash_attention.py``.  All three agree with ``kernels/ref.py``.
"""

from __future__ import annotations

from typing import Dict, Hashable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partitioner import plan_attention_partition
from repro.core.pipeline import build_attention_schedule
from repro.core.streams import OpKind, validate_schedule


@jax.jit
def _attn_block_update(q, k_blk, v_blk, m, l, acc):
    """One online-softmax step over a KV block.

    q: (H, d)    k_blk/v_blk: (S_b, Hkv, d)    m,l: (H,)    acc: (H, d)
    GQA: query head h reads kv head h // (H // Hkv).
    """
    H, d = q.shape
    hkv = k_blk.shape[1]
    group = H // hkv
    kb = jnp.repeat(k_blk, group, axis=1)          # (S_b, H, d)
    vb = jnp.repeat(v_blk, group, axis=1)
    s = jnp.einsum("hd,shd->hs", q, kb) / np.sqrt(d)   # (H, S_b)
    m_new = jnp.maximum(m, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])                    # (H, S_b)
    scale = jnp.exp(m - m_new)
    l_new = l * scale + p.sum(axis=1)
    acc_new = acc * scale[:, None] + jnp.einsum("hs,shd->hd", p, vb)
    return m_new, l_new, acc_new


def ooc_attention(
    q,
    k_cache,
    v_cache,
    *,
    budget_bytes: int,
    nstreams: int = 2,
    nbuf: int = 2,
    validate: bool = False,
):
    """Single-query (decode-shaped) attention over an out-of-core KV cache.

    q: (H, d); k_cache/v_cache: (S, Hkv, d) living in host memory.
    Returns (H, d).
    """
    q = jnp.asarray(q)
    k_cache = np.asarray(k_cache)
    v_cache = np.asarray(v_cache)
    S, hkv, d = k_cache.shape
    H = q.shape[0]

    part = plan_attention_partition(
        S, hkv, d, budget_bytes,
        bytes_per_el=np.dtype(k_cache.dtype).itemsize,
    )
    sched = build_attention_schedule(part, hkv, d, H,
                                     nstreams=nstreams, nbuf=nbuf)
    if validate:
        validate_schedule(sched)

    bufs: Dict[Tuple[str, Hashable], jax.Array] = {}
    m = jnp.full((H,), -jnp.inf, dtype=jnp.float32)
    l = jnp.zeros((H,), dtype=jnp.float32)
    acc = jnp.zeros((H, d), dtype=jnp.float32)

    for op in sched.ops:
        pl = op.payload or {}
        if op.kind == OpKind.H2D:
            idx = pl["idx"]
            lo, hi = idx * part.bs, min(S, (idx + 1) * part.bs)
            src = k_cache if pl["operand"] == "K" else v_cache
            bufs[(pl["operand"], op.buffers_written[0][1])] = jnp.asarray(
                src[lo:hi]
            )
        elif op.kind == OpKind.COMPUTE:
            kb = bufs[("K", op.buffers_read[0][1])]
            vb = bufs[("V", op.buffers_read[1][1])]
            m, l, acc = _attn_block_update(
                q.astype(jnp.float32), kb.astype(jnp.float32),
                vb.astype(jnp.float32), m, l, acc)
        # D2H R(out): final normalization below
    return (acc / l[:, None]).astype(q.dtype)
