"""Out-of-core attention — the engine's second data-parallel kernel.

Reuses the MMOOC pipeline machinery (claim: the synchronization pattern is
kernel-agnostic).  The KV cache plays the role of the out-of-core operand;
queries stay resident; each streamed (K, V) block updates an online-softmax
carry (m, l, acc) — a different merge operator in the same schedule.

This is the host-driven variant: the :func:`attention_pipeline_spec` schedule
runs on the shared :class:`~repro.core.runtime.ScheduleExecutor`, with the
``attn`` / ``attn_out`` op handlers below supplying the kernel semantics.
The jit-compatible in-model variant (lax.scan over KV blocks) lives in
``models/layers.py``; the Pallas in-VMEM variant in
``kernels/flash_attention.py``.  All three agree with ``kernels/ref.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partitioner import plan_attention_partition
from repro.core.pipeline import build_attention_schedule
from repro.core.runtime import (
    ExecState,
    ScheduleExecutor,
    register_op_handler,
)
from repro.core.streams import BlockRef, Op, OpKind, validate_schedule
from repro.obs import get_observability


@jax.jit
def _attn_block_update(q, k_blk, v_blk, m, l, acc):
    """One online-softmax step over a KV block.

    q: (H, d)    k_blk/v_blk: (S_b, Hkv, d)    m,l: (H,)    acc: (H, d)
    GQA: query head h reads kv head h // (H // Hkv).
    """
    H, d = q.shape
    hkv = k_blk.shape[1]
    group = H // hkv
    kb = jnp.repeat(k_blk, group, axis=1)          # (S_b, H, d)
    vb = jnp.repeat(v_blk, group, axis=1)
    s = jnp.einsum("hd,shd->hs", q, kb) / np.sqrt(d)   # (H, S_b)
    m_new = jnp.maximum(m, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])                    # (H, S_b)
    scale = jnp.exp(m - m_new)
    l_new = l * scale + p.sum(axis=1)
    acc_new = acc * scale[:, None] + jnp.einsum("hs,shd->hd", p, vb)
    return m_new, l_new, acc_new


@register_op_handler("attn")
def _attn_handler(st: ExecState, op: Op, ref: BlockRef) -> None:
    """Online-softmax merge of one KV block into the (m, l, acc) carry."""
    q = st.ctx["q"]
    if "carry" not in st.scratch:
        H, d = q.shape
        st.scratch["carry"] = (
            jnp.full((H,), -jnp.inf, dtype=jnp.float32),
            jnp.zeros((H,), dtype=jnp.float32),
            jnp.zeros((H, d), dtype=jnp.float32),
        )
    m, l, acc = st.scratch["carry"]
    kb = st.bufs[op.buffers_read[0]]
    vb = st.bufs[op.buffers_read[1]]
    st.scratch["carry"] = _attn_block_update(
        q.astype(jnp.float32), kb.astype(jnp.float32),
        vb.astype(jnp.float32), m, l, acc)


@register_op_handler("attn_out")
def _attn_out_handler(st: ExecState, op: Op, ref: BlockRef) -> None:
    """Finalize: normalize the carry and land it in the host output."""
    m, l, acc = st.scratch["carry"]
    out = st.outputs["out"]
    out[...] = np.asarray((acc / l[:, None]).astype(out.dtype))


def ooc_attention(
    q,
    k_cache,
    v_cache,
    *,
    budget_bytes: int,
    nstreams: int = 2,
    nbuf: int = 2,
    validate: bool = False,
    tune=None,
    tuner=None,
    devices=None,
    tolerance=None,
):
    """Single-query (decode-shaped) attention over an out-of-core KV cache.

    q: (H, d); k_cache/v_cache: (S, Hkv, d) living in host memory.
    Returns (H, d).

    tune: ``None`` uses the defaults above; ``"auto"`` plans the KV block
    length, stream count and buffer depth through an
    :class:`~repro.tune.tuner.AutoTuner` (``tuner`` or the process default),
    served from the plan cache on repeat calls.

    devices: a set of :class:`~repro.hybrid.DeviceSpec` co-executes the
    query across all of them — the KV cache is split into contiguous
    position chunks sized so calibrated profiles predict equal finish
    times, each device folds its chunk into an online-softmax partial, and
    the partials merge exactly.  Budgets come from the specs, so
    ``budget_bytes`` is ignored on this path.
    """
    if tune not in (None, "auto"):
        raise ValueError(f"unknown tune mode {tune!r}; expected None/'auto'")
    q = jnp.asarray(q)
    k_cache = np.asarray(k_cache)
    v_cache = np.asarray(v_cache)
    S, hkv, d = k_cache.shape
    H = q.shape[0]

    if devices is not None:
        from repro.hybrid import plan_hybrid_attention, run_hybrid_attention

        kw = {} if tolerance is None else {"tolerance": tolerance}
        hplan = plan_hybrid_attention(
            S, hkv, d, H, devices,
            dtype=np.dtype(k_cache.dtype).name, **kw)
        out, _ = run_hybrid_attention(q, k_cache, v_cache, hplan,
                                      validate=validate)
        return jnp.asarray(out).astype(q.dtype)

    plan = None
    if tune == "auto":
        if tuner is None:
            from repro.tune import get_default_tuner
            tuner = get_default_tuner()
        plan = tuner.attention_plan(
            S, hkv, d, H, budget_bytes,
            dtype=np.dtype(k_cache.dtype).name)
        part = plan.attention_partition()
        nstreams, nbuf = plan.nstreams, plan.nbuf
    else:
        part = plan_attention_partition(
            S, hkv, d, budget_bytes,
            bytes_per_el=np.dtype(k_cache.dtype).itemsize,
        )
    sched = build_attention_schedule(part, hkv, d, H,
                                     nstreams=nstreams, nbuf=nbuf)
    if validate:
        validate_schedule(sched)

    # f32 carry lands in an f32 host buffer; the one cast to q.dtype happens
    # at the end (a narrower KV dtype must not quantize the result).
    out = np.zeros((H, d), dtype=np.float32)
    obs = get_observability()
    ex = ScheduleExecutor(record_spans=obs.tracer is not None)
    ex.run(
        sched,
        operands={"K": k_cache, "V": v_cache},
        outputs={"out": out},
        ctx={"q": q},
    )
    if plan is not None:
        obs.record_drift(
            plan.kernel, plan.tier, plan.fingerprint,
            predicted_makespan=plan.makespan,
            measured_seconds=ex.last_wall_seconds,
            predicted_h2d_bytes=sched.total_bytes(OpKind.H2D),
            measured_h2d_bytes=ex.last_h2d_bytes,
            predicted_d2h_bytes=sched.total_bytes(OpKind.D2H),
            measured_d2h_bytes=ex.last_d2h_bytes)
    return jnp.asarray(out).astype(q.dtype)
