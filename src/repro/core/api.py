"""hcl-prefixed facade — the paper's exact API surface, for LOC-parity demos.

The productivity claim (C4) is measured against code written in the paper's
own vocabulary; this module provides that vocabulary verbatim
(``hclDeviceFactory``, ``hclRuntimeFactory``, ``hclStreamFactory``,
``hclMatrixPartitioner``, ...), mapping onto the TPU-native engine.
``examples/mmooc_via_api.py`` is written against this facade and is the LOC
numerator; the three direct backend implementations in
``benchmarks/direct_impls.py`` are the denominator.
"""

from __future__ import annotations

from typing import List, Optional

from jax.sharding import Mesh

from repro.core.exec_plan import ExecutablePlan, compile_executable
from repro.core.partitioner import GemmPartition, plan_gemm_partition
from repro.core.pipeline import PipelineSpec, compile_pipeline
from repro.core.runtime import (
    OocRuntime,
    RuntimeFactory,
    ScheduleExecutor,
    register_op_handler,
)
from repro.core.streams import Device, Schedule, Stream, StreamFactory

# Device-type names map to memory tiers (DESIGN.md §2): the analogues of the
# paper's {"GPU", "PHI", "FPGA"} triple.
_TIER_BYTES = {
    "VMEM": 128 * 2**20,   # v5e VMEM
    "HBM": 16 * 2**30,     # v5e HBM
    "MESH": 16 * 2**30,    # per-shard HBM (aggregate = pod)
    "HYBRID": 0,           # composite: memory is the member devices' sum
}


class hclDeviceFactory:
    @staticmethod
    def create(name: str, dev_id: int = 0,
               mem_bytes: Optional[int] = None) -> Device:
        name = name.upper()
        if name not in _TIER_BYTES:
            raise ValueError(f"unknown device type {name!r}")
        return Device(name, dev_id, mem_bytes or _TIER_BYTES[name])


class hclRuntimeFactory:
    @staticmethod
    def create(device: Device, mesh: Optional[Mesh] = None,
               **kw) -> OocRuntime:
        return RuntimeFactory.create(device, mesh, **kw)


class hclStreamFactory:
    @staticmethod
    def create(device: Device, n: int) -> List[Stream]:
        return StreamFactory.create(device, n)


def hclGetMemSize(device: Device) -> int:
    return device.mem_size()


def hclMatrixPartitioner(M: int, N: int, K: int, dMemSize: int,
                         bytes_per_el: int = 4,
                         nbuf: Optional[int] = None,
                         nstreams: Optional[int] = None) -> GemmPartition:
    """Partition against the device memory — optionally aware of the actual
    pipeline depth (``nbuf``/``nstreams``) so deeper pipelines get blocks
    their larger buffer allocation still fits; default is the paper's fixed
    2-deep model."""
    return plan_gemm_partition(M, N, K, dMemSize, bytes_per_el,
                               nbuf=nbuf, nstreams=nstreams)


def hclCompilePipeline(spec: PipelineSpec, nstreams: int = 2,
                       nbuf: int = 2) -> Schedule:
    """DSL entry point (the paper's §V "synchronization pattern can be
    reused" future work): PipelineSpec -> event-correct Schedule."""
    return compile_pipeline(spec, nstreams=nstreams, nbuf=nbuf)


class hclScheduleExecutor(ScheduleExecutor):
    """Facade alias: the single schedule interpreter (DESIGN.md §4), with
    ``register_op_handler`` as the kernel extension point and
    ``mode="concurrent"`` selecting the per-engine worker-thread runner
    (DESIGN.md §13)."""


hclRegisterOpHandler = register_op_handler


def hclCompileExecutable(sched: Schedule) -> ExecutablePlan:
    """Compile (or fetch the cached) :class:`ExecutablePlan` for a schedule
    — pre-resolved handlers, per-engine queues, dependency edges
    (DESIGN.md §13)."""
    return compile_executable(sched)


def hclHybridRuntime(devices, **kw):
    """Facade over :class:`repro.hybrid.HybridOocRuntime` (DESIGN.md §7):
    one kernel call co-scheduled across a heterogeneous device set, load
    balanced by calibrated profiles.

        gpu = DeviceSpec("gpu0", gpu_profile(), 2 * 2**30)
        phi = DeviceSpec("phi0", phi_profile(), 2 * 2**30)
        rt = hclHybridRuntime([gpu, phi])
        C = rt.gemm(A, B, C, alpha, beta)

    ``devices`` is a sequence of :class:`~repro.hybrid.DeviceSpec` (or bare
    ``(name, profile, budget_bytes)`` tuples).  Resolved lazily —
    ``repro.hybrid`` imports ``repro.tune``, which imports this package."""
    from repro.hybrid import HybridOocRuntime

    return HybridOocRuntime(devices, **kw)


def hclOocFactor(A, kind: str = "cholesky", **kw):
    """Facade over the out-of-core factorizations (DESIGN.md §8): one
    lookahead pipeline schedule interleaving panel POTRF/GETRF/TRSM ops with
    the streamed SYRK/GEMM trailing update.

        L = hclOocFactor(A, "cholesky", budget_bytes=..., lookahead=1)
        LU, perm = hclOocFactor(A, "lu", budget_bytes=..., tune="auto")

    Keyword arguments forward to :func:`repro.core.ooc_factor.ooc_cholesky`
    / :func:`~repro.core.ooc_factor.ooc_lu` (``panel``, ``budget_bytes``,
    ``lookahead``, ``tune``, ``devices``, ...).  The engine computes in
    float32 whatever the input dtype: float64 results carry f32-level
    residuals (see the entry-point docstrings)."""
    from repro.core.ooc_factor import ooc_cholesky, ooc_lu

    if kind == "cholesky":
        return ooc_cholesky(A, **kw)
    if kind == "lu":
        return ooc_lu(A, **kw)
    raise ValueError(f"unknown factor kind {kind!r}; expected "
                     f"'cholesky' or 'lu'")


def hclObservability(enable: bool = False, trace: bool = False, **kw):
    """Facade over the process :class:`repro.obs.Observability` bundle
    (DESIGN.md §10): metrics registry, hierarchical tracer and drift
    monitor in one switch.

        obs = hclObservability(enable=True, trace=True)
        C = ooc_gemm(A, B, budget_bytes=..., tune="auto", devices=[...])
        obs.tracer.write("trace.json")           # one coherent timeline
        print(obs.metrics.to_prometheus_text())  # exact byte accounting
        print(obs.drift.snapshot()["rolling"])   # predicted vs measured

    With no arguments this just returns the singleton (everything starts
    disabled); ``enable=True`` turns on metrics, ``trace=True`` also starts
    a tracer.  Extra keywords forward to
    :meth:`~repro.obs.Observability.enable`."""
    from repro.obs import get_observability

    obs = get_observability()
    if enable or trace:
        obs.enable(metrics=True, trace=trace, **kw)
    return obs


def hclTraceAnalysis(sched: Schedule, hw=None, res=None, spans=None, **kw):
    """Facade over :class:`repro.obs.analyze.TraceAnalysis` (DESIGN.md §11):
    bottleneck attribution over one schedule's span timeline.

        ana, res = hclTraceAnalysis(sched, hw=profile.model_for(2))
        print(ana.digest())        # verdict + critical-path shares
        ana.verify_reconciliation(res)   # exact accounting, or AssertionError

    Three input shapes: simulate here (``hw`` an engine model or a
    :class:`~repro.tune.calibrate.HardwareProfile`, returns
    ``(analysis, SimResult)``), attribute an existing simulation (``res``),
    or attribute recorded wall-clock spans (``spans``, tolerance-matched).
    Resolved lazily: the analyzer imports the simulator."""
    from repro.obs.analyze import TraceAnalysis

    if res is not None:
        return TraceAnalysis.from_sim(sched, res, hw=hw)
    if spans is not None:
        return TraceAnalysis.from_spans(sched, spans, hw=hw, **kw)
    if hw is None:
        raise ValueError("hclTraceAnalysis needs hw=, res= or spans=")
    if hasattr(hw, "model_for"):       # a HardwareProfile: default 2 streams
        hw = hw.model_for(kw.pop("nstreams", 2))
    return TraceAnalysis.analyze(sched, hw)


def hclAutoTuner(device: Optional[Device] = None, **kw):
    """Facade over :class:`repro.tune.AutoTuner` (DESIGN.md §6): calibrate
    the device once, then dispense cached ``TunedPlan``s — partition
    geometry, stream count, buffer depth — per problem shape and tier.

        tuner = hclAutoTuner(device)                # calibrates lazily
        plan = tuner.gemm_plan(M, N, K, hclGetMemSize(device))
        C = ooc_gemm(A, B, budget_bytes=..., tune="auto", tuner=tuner)

    Resolved lazily: ``repro.tune`` imports ``repro.core`` submodules, so
    the facade must not import the tuner package at module load."""
    from repro.tune import AutoTuner

    if device is not None:
        kw.setdefault("tier", device.name.upper())
    return AutoTuner(**kw)


def hclFaultPolicy(**kw):
    """Facade over :class:`repro.fault.FaultPolicy` (DESIGN.md §12): the
    recovery knobs every resilient entry point shares — transfer retry
    count and exponential backoff, and the oom degradation ladder's depth.

        pol = hclFaultPolicy(max_retries=5, backoff_base=0.02)
        C = ooc_gemm(A, B, budget_bytes=..., faults=plan, fault_policy=pol)

    Pair with a :class:`~repro.fault.FaultPlan` (deterministic, seeded,
    schedule-addressable) passed as ``faults=`` to ``ooc_gemm`` /
    ``ooc_syrk`` / ``ooc_cholesky`` / ``ooc_lu``, or as per-device
    ``fault_plans=`` to ``run_hybrid_gemm`` / ``run_hybrid_syrk``.
    Resolved lazily to keep the facade import-light."""
    from repro.fault import FaultPolicy

    return FaultPolicy(**kw)
