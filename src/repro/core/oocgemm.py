"""MMOOC — out-of-core matrix multiplication, the paper's reference kernel.

``ooc_gemm`` is the public entry point: plan a partition for the device's
memory budget, build the event-correct pipeline schedule, and execute it on
the selected backend.  The in-core/out-of-core switch (paper §VI: libhclooc
switches when N exceeds what fits) lives here: if the whole problem fits the
budget, a single in-core DGEMM is issued — the transition that claim C2 says
must cost 0 %.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pipeline as plib
from repro.core.partitioner import GemmPartition, plan_gemm_partition
from repro.core.runtime import (
    HostOocRuntime,
    MeshOocRuntime,
    OocRuntime,
    RuntimeFactory,
    VmemOocRuntime,
    _block_dgemm,
)
from repro.core.streams import Device, OpKind, validate_schedule
from repro.obs import get_observability


def is_in_core(M: int, N: int, K: int, budget_bytes: int,
               bytes_per_el: int = 4) -> bool:
    """True if A, B and C are simultaneously resident within the budget."""
    return (M * K + K * N + M * N) * bytes_per_el <= budget_bytes


def _tuned_gemm_plan(tuner, kernel: str, M: int, N: int, K: int,
                     budget_bytes: int, dtype):
    """Resolve the full :class:`~repro.tune.search.TunedPlan` from the
    (default) autotuner's plan cache — searched once per (shape, dtype,
    tier, hardware).  Returning the plan (not just its pipeline knobs)
    keeps the predicted makespan available for drift recording."""
    if tuner is None:
        from repro.tune import get_default_tuner
        tuner = get_default_tuner()
    plan = tuner.gemm_plan(M, N, K, budget_bytes,
                           dtype=np.dtype(dtype).name, kernel=kernel)
    if not plan.write_back:
        # "keep"-mode plans describe resident-C (SUMMA-style) pipelines;
        # this entry point must land C in host memory
        raise ValueError(
            f"tuned plan for {kernel} {(M, N, K)} was searched with "
            f"write_back=False; ooc_{kernel} requires write-back plans")
    return plan


def _record_host_drift(plan, rt, sched) -> None:
    """After a tuned host-backend run: log measured wall/bytes against the
    plan's simulated makespan and the schedule's modeled byte totals."""
    ex = getattr(rt, "executor", None)
    if plan is None or ex is None:
        return
    get_observability().record_drift(
        plan.kernel, plan.tier, plan.fingerprint,
        predicted_makespan=plan.makespan,
        measured_seconds=ex.last_wall_seconds,
        predicted_h2d_bytes=sched.total_bytes(OpKind.H2D),
        measured_h2d_bytes=ex.last_h2d_bytes,
        predicted_d2h_bytes=sched.total_bytes(OpKind.D2H),
        measured_d2h_bytes=ex.last_d2h_bytes)


def _hybrid_kwargs(tolerance: Optional[float]) -> dict:
    return {} if tolerance is None else {"tolerance": tolerance}


def _host_gemm_resilient(rt, A, B, C, alpha, beta, part, sched, *, faults,
                         policy, tuned, tune, tuner, nstreams, nbuf,
                         traversal, evict, budget_bytes, bpe):
    """Host-backend GEMM under fault injection with the oom degradation
    ladder (DESIGN.md §12): an injected oom aborts the run, then halve
    nbuf / halve budget rungs replan + rebuild the schedule (tuned runs
    re-search at the reduced budget) and re-execute clean.  The attempted
    rungs are recorded in ``policy.degrades``."""
    from repro.fault.errors import OomError
    from repro.fault.policy import FaultPolicy

    M, K = A.shape
    N = B.shape[1]
    policy = policy or FaultPolicy()
    try:
        out = rt.gemm(A, B, C, alpha, beta, part, schedule=sched,
                      faults=faults, policy=policy)
        _record_host_drift(tuned, rt, sched)
        return out
    except OomError:
        obs = get_observability()
        for step in policy.degrade_ladder(nbuf=nbuf, lookahead=0,
                                          budget_bytes=budget_bytes,
                                          tuned=tune == "auto"):
            policy.degrades.append(step)
            obs.instant(f"fault:degrade:{step.action}", kernel="gemm")
            try:
                if tune == "auto":
                    t2 = _tuned_gemm_plan(tuner, "gemm", M, N, K,
                                          step.budget_bytes, A.dtype)
                    part2, ns2, nb2 = (t2.gemm_partition(), t2.nstreams,
                                       t2.nbuf)
                    tr2, ev2 = t2.traversal, t2.evict
                else:
                    part2 = plan_gemm_partition(M, N, K, step.budget_bytes,
                                                bpe)
                    ns2, nb2, tr2, ev2 = (nstreams, step.nbuf, traversal,
                                          evict)
                sched2 = plib.build_gemm_schedule(
                    part2, nstreams=ns2, nbuf=nb2, traversal=tr2, evict=ev2)
                # clean re-run: the oom occurrence was consumed above
                out = rt.gemm(A, B, C, alpha, beta, part2, schedule=sched2)
            except ValueError:
                continue
            obs.record_fault_recovery("gemm", "degrade")
            return out
        raise


def ooc_gemm(
    A,
    B,
    C=None,
    alpha: float = 1.0,
    beta: float = 0.0,
    *,
    budget_bytes: int,
    backend: str = "host",
    nstreams: int = 2,
    nbuf: int = 2,
    traversal: str = "col",
    evict: str = "lru",
    mesh=None,
    validate: bool = False,
    runtime: Optional[OocRuntime] = None,
    tune: Optional[str] = None,
    tuner=None,
    devices: Optional[Sequence] = None,
    tolerance: Optional[float] = None,
    faults=None,
    fault_policy=None,
):
    """Compute ``alpha * A @ B + beta * C`` streaming blocks through a memory
    tier of size ``budget_bytes``.

    backend: "host" (schedule-driven block streaming), "vmem" (Pallas kernel),
    "mesh" (SUMMA ring over a mesh axis).

    tune: ``None`` uses the hardcoded defaults above; ``"auto"`` asks an
    :class:`~repro.tune.tuner.AutoTuner` (``tuner`` or the process default)
    for a calibrated plan — partition geometry, stream count and buffer
    depth — served from the plan cache on repeat calls (host backend; other
    backends plan their own pipelines).

    devices: a set of :class:`~repro.hybrid.DeviceSpec` (or ``(name,
    profile, budget_bytes)`` tuples) co-executes the one GEMM across all of
    them: C's rows are split so the calibrated profiles predict equal
    per-device finish times (``tolerance`` overrides the balancer default),
    each band runs its own tuned schedule concurrently, and the disjoint
    bands merge into one result.  Per-device budgets come from the specs,
    so ``budget_bytes`` and ``backend`` are ignored on this path.

    traversal / evict (host backend): block-grid step order (see
    :data:`~repro.core.partitioner.TRAVERSALS`) and residency-cache
    eviction policy (``"lru"``/``"belady"``) — they change which H2D
    transfers the compiler's block cache elides, never the result.  Tuned
    plans carry their own searched traversal/evict and override these.

    faults / fault_policy (host backend, DESIGN.md §12): a
    :class:`~repro.fault.FaultPlan` (or ``sched -> plan`` callable) armed
    on the executor.  Transfer faults retry, compute faults replay; an
    injected oom walks the degradation ladder (halve nbuf, then halve the
    budget — tuned runs re-search at the reduced budget) and re-executes
    clean.
    """
    if tune not in (None, "auto"):
        raise ValueError(f"unknown tune mode {tune!r}; expected None/'auto'")
    if faults is not None and (devices is not None or backend != "host"):
        raise ValueError("fault injection is supported on the host "
                         "pipeline backend only (hybrid paths take "
                         "fault_plans on run_hybrid_*)")
    if devices is not None:
        from repro.hybrid import plan_hybrid_gemm, run_hybrid_gemm

        A = np.asarray(A)
        B = np.asarray(B)
        hplan = plan_hybrid_gemm(
            A.shape[0], B.shape[1], A.shape[1], devices,
            dtype=np.dtype(A.dtype).name, **_hybrid_kwargs(tolerance))
        out, _ = run_hybrid_gemm(A, B, C, alpha, beta, hplan,
                                 validate=validate)
        return out
    A = np.asarray(A) if backend == "host" else jnp.asarray(A)
    B = np.asarray(B) if backend == "host" else jnp.asarray(B)
    M, K = A.shape
    K2, N = B.shape
    if K != K2:
        raise ValueError(f"inner dims mismatch: {A.shape} @ {B.shape}")
    if C is None:
        C = np.zeros((M, N), dtype=A.dtype) if backend == "host" \
            else jnp.zeros((M, N), dtype=A.dtype)
        beta = 0.0
    bpe = np.dtype(A.dtype).itemsize

    if backend == "mesh":
        rt = runtime or MeshOocRuntime(mesh)
        return rt.gemm(A, B, C, alpha, beta, None)

    if is_in_core(M, N, K, budget_bytes, bpe):
        # In-core fast path: one resident DGEMM (claim C2 transition point).
        out = _block_dgemm(jnp.asarray(A), jnp.asarray(B), jnp.asarray(C),
                           jnp.float32(alpha), jnp.float32(beta))
        return np.asarray(out) if backend == "host" else out

    tuned = None
    if tune == "auto" and backend == "host":
        tuned = _tuned_gemm_plan(tuner, "gemm", M, N, K, budget_bytes,
                                 A.dtype)
        part, nstreams, nbuf = (tuned.gemm_partition(), tuned.nstreams,
                                tuned.nbuf)
        traversal, evict = tuned.traversal, tuned.evict
    else:
        part = plan_gemm_partition(M, N, K, budget_bytes, bpe)
    if backend == "host":
        sched = plib.build_gemm_schedule(part, nstreams=nstreams, nbuf=nbuf,
                                         traversal=traversal, evict=evict)
        if validate:
            validate_schedule(sched)
        rt = runtime or HostOocRuntime()
        if faults is None:
            out = rt.gemm(A, B, C, alpha, beta, part, schedule=sched)
            _record_host_drift(tuned, rt, sched)
            return out
        return _host_gemm_resilient(
            rt, A, B, C, alpha, beta, part, sched, faults=faults,
            policy=fault_policy, tuned=tuned, tune=tune, tuner=tuner,
            nstreams=nstreams, nbuf=nbuf, traversal=traversal, evict=evict,
            budget_bytes=budget_bytes, bpe=bpe)
    if backend == "vmem":
        rt = runtime or VmemOocRuntime()
        return rt.gemm(A, B, C, alpha, beta, part)
    raise ValueError(f"unknown backend {backend!r}")


def ooc_syrk(
    P,
    C=None,
    alpha: float = 1.0,
    beta: float = 0.0,
    *,
    budget_bytes: int,
    backend: str = "host",
    nstreams: int = 2,
    nbuf: int = 2,
    traversal: str = "col",
    evict: str = "lru",
    validate: bool = False,
    runtime: Optional[OocRuntime] = None,
    tune: Optional[str] = None,
    tuner=None,
    devices: Optional[Sequence] = None,
    tolerance: Optional[float] = None,
    faults=None,
    fault_policy=None,
):
    """Compute ``alpha * P @ P^T + beta * C`` out-of-core (blocked SYRK).

    The Cholesky trailing update as a first-class pipeline kernel: on the
    host backend the :func:`~repro.core.pipeline.syrk_pipeline_spec` streams
    the panel twice (row slices and transposed row slices) through the same
    schedule shape and ``dgemm`` handler as MMOOC, with no host-side ``P.T``
    copy — only individual blocks are transposed in flight.  The vmem and
    in-core paths delegate to the dense GEMM kernel and do materialize the
    transpose on-device.

    tune: as in :func:`ooc_gemm` — ``"auto"`` plans partition/streams/buffers
    through the autotuner (keyed as the ``syrk`` kernel, since the panel is
    streamed twice).

    devices: as in :func:`ooc_gemm` — co-execute across a heterogeneous
    device set, splitting C's rows by calibrated profile (each band's
    transposed panel still streams the full P, block by block).

    traversal / evict: as in :func:`ooc_gemm` — step order and block-cache
    eviction policy for the host pipeline; tuned plans override both.
    """
    if tune not in (None, "auto"):
        raise ValueError(f"unknown tune mode {tune!r}; expected None/'auto'")
    if faults is not None and (devices is not None or backend != "host"):
        raise ValueError("fault injection is supported on the host "
                         "pipeline backend only (hybrid paths take "
                         "fault_plans on run_hybrid_*)")
    if devices is not None:
        from repro.hybrid import plan_hybrid_syrk, run_hybrid_syrk

        P = np.asarray(P)
        hplan = plan_hybrid_syrk(
            P.shape[0], P.shape[1], devices,
            dtype=np.dtype(P.dtype).name, **_hybrid_kwargs(tolerance))
        out, _ = run_hybrid_syrk(P, C, alpha, beta, hplan,
                                 validate=validate)
        return out
    if backend not in ("host", "vmem"):
        raise ValueError(f"unknown backend {backend!r}")
    P = np.asarray(P) if backend == "host" else jnp.asarray(P)
    n, K = P.shape
    if C is None:
        C = np.zeros((n, n), dtype=P.dtype) if backend == "host" \
            else jnp.zeros((n, n), dtype=P.dtype)
        beta = 0.0
    bpe = np.dtype(P.dtype).itemsize

    if is_in_core(n, n, K, budget_bytes, bpe):
        out = _block_dgemm(jnp.asarray(P), jnp.asarray(P).T, jnp.asarray(C),
                           jnp.float32(alpha), jnp.float32(beta))
        return np.asarray(out) if backend == "host" else out

    tuned = None
    if tune == "auto" and backend == "host":
        tuned = _tuned_gemm_plan(tuner, "syrk", n, n, K, budget_bytes,
                                 P.dtype)
        part, nstreams, nbuf = (tuned.gemm_partition(), tuned.nstreams,
                                tuned.nbuf)
        traversal, evict = tuned.traversal, tuned.evict
    else:
        part = plan_gemm_partition(n, n, K, budget_bytes, bpe)
    if backend == "host":
        sched = plib.build_syrk_schedule(part, nstreams=nstreams, nbuf=nbuf,
                                         traversal=traversal, evict=evict)
        if validate:
            validate_schedule(sched)
        rt = runtime or HostOocRuntime()
        out = rt.syrk(P, C, alpha, beta, part, schedule=sched,
                      faults=faults, policy=fault_policy)
        _record_host_drift(tuned, rt, sched)
        return out
    # "vmem": the only other backend the top-of-function guard admits
    rt = runtime or VmemOocRuntime()
    return rt.gemm(P, jnp.asarray(P).T, C, alpha, beta, part)


def plan_for_device(M: int, N: int, K: int, device: Device,
                    bytes_per_el: int = 4) -> GemmPartition:
    """Partition using the device's reported memory (hclGetMemSize path)."""
    return plan_gemm_partition(M, N, K, device.mem_bytes, bytes_per_el)
