"""OOC runtimes — the ``hclRuntime`` class hierarchy, TPU-native.

The paper's ``hclRuntimeFactory`` dispenses one of three device-type-specific
runtimes (CUDA / Phi offload / OpenCL) behind a pure-virtual interface.  Here
the three "device types" are the three TPU memory tiers a blocked workload can
stream through (DESIGN.md §2):

  * :class:`HostOocRuntime`  — host-driven block streaming through a chip's
    HBM: executes a :class:`~repro.core.streams.Schedule` op-by-op with real
    JAX dispatch (async on real hardware), buffers keyed by parity exactly as
    the schedule's event program dictates.  This is the most literal port of
    the paper's MMOOC loop.
  * :class:`VmemOocRuntime`  — HBM->VMEM streaming *inside* the chip via the
    Pallas kernel (``kernels/block_matmul.py``); the schedule is declarative
    (grid + BlockSpec index maps) and Mosaic emits the double-buffered DMAs.
  * :class:`MeshOocRuntime`  — the pod's aggregate HBM as backing store:
    SUMMA ring over ICI with ``shard_map`` + ``ppermute`` ping-pong buffers
    (the paper's §V ``nsteps``/SUMMA integration point).

All runtimes compute the same DGEMM contract ``C = alpha*A@B + beta*C`` and
are cross-checked against ``kernels/ref.py`` in tests.
"""

from __future__ import annotations

import functools
from typing import Dict, Hashable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import pipeline as plib
from repro.core.partitioner import GemmPartition, plan_gemm_partition
from repro.core.streams import Device, OpKind, Schedule


class OocRuntime:
    """Pure-virtual base (the paper's ``hclRuntime``)."""

    device: Device

    def gemm(self, A, B, C, alpha: float, beta: float,
             part: GemmPartition, **kw):
        raise NotImplementedError

    # hcl-style helpers shared by backends ------------------------------------
    def mem_size(self) -> int:  # hclGetMemSize
        return self.device.mem_bytes

    def device_synchronize(self, *arrays) -> None:  # hclDeviceSynchronize
        for a in arrays:
            jax.block_until_ready(a)


@functools.partial(jax.jit, static_argnames=("transpose",))
def _block_dgemm(a, b, c, alpha, beta, transpose: bool = False):
    """In-core DGEMM on resident blocks (the vendor-kernel slot)."""
    acc = jnp.dot(a, b, preferred_element_type=jnp.float32)
    return (alpha * acc + beta * c).astype(c.dtype)


class HostOocRuntime(OocRuntime):
    """Executes a block schedule with eager JAX ops.

    Faithful mechanics: ``nbuf`` device buffers per operand class, transfers
    keyed by the schedule's payload, DGEMM on the parity buffers, write-back
    into the host result.  On real hardware JAX's async dispatch overlaps the
    transfer of block ``idx+1`` with the DGEMM of block ``idx`` exactly as the
    event program orders them; on CPU the schedule is executed with identical
    semantics (ordering + results), which is what tests assert.
    """

    def __init__(self, device: Optional[Device] = None):
        self.device = device or Device("HBM", 0, 16 * 2**30)

    def gemm(self, A, B, C, alpha, beta, part: GemmPartition,
             nstreams: int = 2, nbuf: int = 2,
             schedule: Optional[Schedule] = None):
        sched = schedule or plib.build_gemm_schedule(
            part, nstreams=nstreams, nbuf=nbuf
        )
        out = np.array(C, copy=True)
        bufs: Dict[Tuple[str, Hashable], jax.Array] = {}

        # Execute in global issue order: on a single-stream-per-device backend
        # (XLA CPU/TPU enqueue), issue order + data deps realize the event
        # program; cross-stream reordering freedom only adds overlap on HW
        # with parallel engines.
        for op in sched.ops:
            pl = op.payload or {}
            if op.kind == OpKind.H2D:
                if pl["operand"] == "A":
                    blk = A[pl["rs"]:pl["rs"] + pl["rn"], :]
                    bufs[("A", op.buffers_written[0][1])] = jnp.asarray(blk)
                elif pl["operand"] == "B":
                    blk = B[:, pl["cs"]:pl["cs"] + pl["cn"]]
                    bufs[("B", op.buffers_written[0][1])] = jnp.asarray(blk)
                elif pl["operand"] == "C":
                    blk = out[pl["rs"]:pl["rs"] + pl["rn"],
                              pl["cs"]:pl["cs"] + pl["cn"]]
                    bufs[("C", op.buffers_written[0][1])] = jnp.asarray(blk)
            elif op.kind == OpKind.COMPUTE:
                if pl.get("noop"):
                    continue
                pa = ("A", op.buffers_read[0][1])
                pb = ("B", op.buffers_read[1][1])
                pc = ("C", op.buffers_written[0][1])
                bufs[pc] = _block_dgemm(
                    bufs[pa], bufs[pb], bufs[pc],
                    jnp.asarray(alpha, dtype=jnp.float32),
                    jnp.asarray(beta, dtype=jnp.float32),
                )
            elif op.kind == OpKind.D2H:
                if pl.get("operand") == "C":
                    pc = ("C", op.buffers_read[0][1])
                    out[pl["rs"]:pl["rs"] + pl["rn"],
                        pl["cs"]:pl["cs"] + pl["cn"]] = np.asarray(bufs[pc])
        return out


class VmemOocRuntime(OocRuntime):
    """HBM->VMEM tier: delegates to the Pallas block-matmul kernel, which IS
    the paper's pipeline compiled into the chip (Mosaic double-buffers the
    A/B/C tile DMAs across grid steps)."""

    def __init__(self, device: Optional[Device] = None,
                 interpret: Optional[bool] = None):
        self.device = device or Device("VMEM", 0, 128 * 2**20)
        # CPU container: interpret mode (kernel body runs in Python).
        self.interpret = (
            interpret if interpret is not None
            else jax.devices()[0].platform != "tpu"
        )

    def gemm(self, A, B, C, alpha, beta, part: GemmPartition,
             block: Optional[Tuple[int, int, int]] = None, **kw):
        from repro.kernels import ops as kops

        bm = min(part.bm, 512)
        bn = min(part.bn, 512)
        bk = min(part.K, 512)
        if block is not None:
            bm, bn, bk = block
        return kops.block_matmul(
            jnp.asarray(A), jnp.asarray(B), jnp.asarray(C),
            alpha=alpha, beta=beta, block=(bm, bn, bk),
            interpret=self.interpret,
        )


class MeshOocRuntime(OocRuntime):
    """Mesh tier: SUMMA ring over ICI.

    The operands are sharded across a 1-D submesh (A by row blocks, B by
    column blocks, C by row blocks); each device streams the remote B blocks
    through a ping-pong buffer with ``ppermute`` while the MXU consumes the
    current block — the paper's 2-stream overlap where the "PCIe link" is ICI
    and the "host memory" is the neighbours' HBM.
    """

    def __init__(self, mesh: Mesh, axis: str = "model",
                 device: Optional[Device] = None):
        self.mesh = mesh
        self.axis = axis
        self.device = device or Device("MESH", 0, 16 * 2**30)

    def gemm(self, A, B, C, alpha, beta, part=None, overlap: bool = True, **kw):
        mesh, axis = self.mesh, self.axis
        Pn = mesh.shape[axis]
        M, K = A.shape
        _, N = B.shape
        if M % Pn or N % Pn:
            raise ValueError(f"SUMMA needs M,N divisible by mesh axis {Pn}")
        n_blk = N // Pn
        alpha = jnp.float32(alpha)
        beta = jnp.float32(beta)

        def ring_body(a_blk, b_blk, c_blk):
            # a_blk: (M/P, K)  b_blk: (K, N/P)  c_blk: (M/P, N)
            me = jax.lax.axis_index(axis)
            perm = [(i, (i - 1) % Pn) for i in range(Pn)]

            def step(t, carry):
                b_cur, acc = carry
                # issue the permute FIRST so Mosaic/XLA can overlap the ICI
                # transfer of the next block with this block's matmul
                # (ping-pong buffer: b_nxt is a fresh buffer).
                b_nxt = jax.lax.ppermute(b_cur, axis, perm) if overlap else b_cur
                col = ((me + t) % Pn) * n_blk
                prod = jnp.dot(a_blk, b_cur,
                               preferred_element_type=jnp.float32)
                old = jax.lax.dynamic_slice(
                    acc, (0, col), (acc.shape[0], n_blk))
                upd = (alpha * prod + beta * old).astype(acc.dtype)
                acc = jax.lax.dynamic_update_slice(acc, upd, (0, col))
                if not overlap:
                    b_nxt = jax.lax.ppermute(b_cur, axis, perm)
                return b_nxt, acc

            _, acc = jax.lax.fori_loop(0, Pn, step, (b_blk, c_blk))
            return acc

        spec_a = P(axis, None)
        spec_b = P(None, axis)
        spec_c = P(axis, None)
        fn = jax.shard_map(
            ring_body, mesh=mesh,
            in_specs=(spec_a, spec_b, spec_c),
            out_specs=spec_c,
        )
        sA = jax.device_put(A, NamedSharding(mesh, spec_a))
        sB = jax.device_put(B, NamedSharding(mesh, spec_b))
        sC = jax.device_put(C, NamedSharding(mesh, spec_c))
        return jax.jit(fn)(sA, sB, sC)


class RuntimeFactory:
    """``hclRuntimeFactory``: device tuple -> runtime."""

    _BACKENDS = {"HBM": HostOocRuntime, "VMEM": VmemOocRuntime}

    @staticmethod
    def create(device: Device, mesh: Optional[Mesh] = None) -> OocRuntime:
        if device.name.upper() == "MESH":
            if mesh is None:
                raise ValueError("MESH runtime needs a jax Mesh")
            return MeshOocRuntime(mesh, device=device)
        try:
            cls = RuntimeFactory._BACKENDS[device.name.upper()]
        except KeyError:
            raise ValueError(
                f"unknown device type {device.name!r}; expected one of "
                f"{sorted(RuntimeFactory._BACKENDS)} or MESH"
            ) from None
        return cls(device)
