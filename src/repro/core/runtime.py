"""OOC runtimes — the ``hclRuntime`` class hierarchy, TPU-native.

The paper's ``hclRuntimeFactory`` dispenses one of three device-type-specific
runtimes (CUDA / Phi offload / OpenCL) behind a pure-virtual interface.  Here
the three "device types" are the three TPU memory tiers a blocked workload can
stream through (DESIGN.md §2):

  * :class:`HostOocRuntime`  — host-driven block streaming through a chip's
    HBM: executes a :class:`~repro.core.streams.Schedule` op-by-op with real
    JAX dispatch (async on real hardware), buffers keyed by parity exactly as
    the schedule's event program dictates.  This is the most literal port of
    the paper's MMOOC loop.
  * :class:`VmemOocRuntime`  — HBM->VMEM streaming *inside* the chip via the
    Pallas kernel (``kernels/block_matmul.py``); the schedule is declarative
    (grid + BlockSpec index maps) and Mosaic emits the double-buffered DMAs.
  * :class:`MeshOocRuntime`  — the pod's aggregate HBM as backing store:
    SUMMA ring over ICI with ``shard_map`` + ``ppermute`` ping-pong buffers
    (the paper's §V ``nsteps``/SUMMA integration point).

All runtimes compute the same DGEMM contract ``C = alpha*A@B + beta*C`` and
are cross-checked against ``kernels/ref.py`` in tests.
"""

from __future__ import annotations

import dataclasses
import functools
import importlib
import threading
import time
from typing import Any, Callable, Dict, Hashable, List, Optional, Tuple, Type

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import exec_plan as _xplan
from repro.core import pipeline as plib
from repro.core.exec_plan import ExecutablePlan, compile_executable
from repro.core.partitioner import GemmPartition, plan_gemm_partition
from repro.core.streams import (BlockRef, Device, Op, OpKind, Schedule,
                                ScheduleError, SliceRef)
from repro.obs import get_observability


class OocRuntime:
    """Pure-virtual base (the paper's ``hclRuntime``)."""

    device: Device

    def gemm(self, A, B, C, alpha: float, beta: float,
             part: GemmPartition, **kw):
        raise NotImplementedError

    @classmethod
    def from_device(cls, device: Device, *, mesh: Optional[Mesh] = None,
                    **kw) -> "OocRuntime":
        """Factory hook :class:`RuntimeFactory` calls for the registered
        tier; override when construction needs more than the device tuple
        (the mesh runtime needs a jax Mesh, the hybrid composite a device
        set)."""
        return cls(device=device, **kw)

    # hcl-style helpers shared by backends ------------------------------------
    def mem_size(self) -> int:  # hclGetMemSize
        return self.device.mem_bytes

    def device_synchronize(self, *arrays) -> None:  # hclDeviceSynchronize
        for a in arrays:
            jax.block_until_ready(a)


# ===========================================================================
# Runtime registry — tiers self-register instead of being if/elif'd
# ===========================================================================
_RUNTIME_REGISTRY: Dict[str, Type[OocRuntime]] = {}

# Tiers whose runtime lives outside core (imported on first use so core
# stays cycle-free: the hybrid composite pulls in repro.tune which in turn
# imports repro.core).
_LAZY_RUNTIME_MODULES: Dict[str, str] = {"HYBRID": "repro.hybrid.executor"}


def register_runtime(name: str) -> Callable[[Type[OocRuntime]],
                                            Type[OocRuntime]]:
    """Class decorator registering an :class:`OocRuntime` under tier ``name``.

    ``RuntimeFactory.create`` dispatches ``Device.name`` through this
    registry via the class's :meth:`OocRuntime.from_device` hook, so new
    tiers (and composites like the hybrid runtime) plug in without editing
    the factory.
    """

    def deco(cls: Type[OocRuntime]) -> Type[OocRuntime]:
        _RUNTIME_REGISTRY[name.upper()] = cls
        return cls

    return deco


@functools.partial(jax.jit, static_argnames=("transpose",))
def _block_dgemm(a, b, c, alpha, beta, transpose: bool = False):
    """In-core DGEMM on resident blocks (the vendor-kernel slot)."""
    acc = jnp.dot(a, b, preferred_element_type=jnp.float32)
    return (alpha * acc + beta * c).astype(c.dtype)


# ===========================================================================
# ScheduleExecutor — the single schedule interpreter for every host path
# ===========================================================================
HandlerFn = Callable[["ExecState", Op, BlockRef], None]
_OP_HANDLERS: Dict[str, HandlerFn] = {}
# bumped on every registration: compiled ExecutablePlans pin the version
# they resolved handlers against, so late registrations invalidate cached
# plans instead of serving stale (or missing) resolutions
_HANDLERS_VERSION = 0


def handlers_version() -> int:
    """Monotonic handler-registry version (plan-cache invalidation key)."""
    return _HANDLERS_VERSION


def register_op_handler(kernel: str) -> Callable[[HandlerFn], HandlerFn]:
    """Register ``fn(state, op, ref)`` for ops whose :class:`BlockRef` payload
    names ``kernel`` — COMPUTE dispatch and "final"-mode D2H finalizers.

    Handlers receive parity buffers positionally via ``op.buffers_read`` /
    ``op.buffers_written`` in the order the :class:`PipelineSpec` declared
    them, kernel parameters via ``state.ctx``, and may keep carry state in
    ``state.scratch``.
    """

    def deco(fn: HandlerFn) -> HandlerFn:
        global _HANDLERS_VERSION
        _OP_HANDLERS[kernel] = fn
        _HANDLERS_VERSION += 1
        return fn

    return deco


@dataclasses.dataclass
class ExecState:
    """Mutable execution state threaded through op handlers."""

    bufs: Dict[Tuple[str, Hashable], jax.Array]  # device parity buffers
    operands: Dict[str, Any]                     # host-resident inputs
    outputs: Dict[str, np.ndarray]               # host results (in-place)
    ctx: Dict[str, Any]                          # kernel parameters
    scratch: Dict[str, Any]                      # handler carry state

    def host(self, name: str):
        """Host array an H2D slices from: inout operands read the live
        output so a kernel can accumulate into what it already wrote."""
        return self.outputs[name] if name in self.outputs \
            else self.operands[name]


def _take(arr, ref: SliceRef):
    if ref.rows is not None:
        arr = arr[ref.rows[0]:ref.rows[0] + ref.rows[1]]
    if ref.cols is not None:
        arr = arr[:, ref.cols[0]:ref.cols[0] + ref.cols[1]]
    return arr.T if ref.transpose else arr


def _spans_overlap(a: SliceRef, b: SliceRef, shape) -> bool:
    def hit(sa, sb, extent):
        lo_a, n_a = sa if sa is not None else (0, extent)
        lo_b, n_b = sb if sb is not None else (0, extent)
        return lo_a < lo_b + n_b and lo_b < lo_a + n_a

    return (a.operand == b.operand
            and hit(a.rows, b.rows, shape[0])
            and hit(a.cols, b.cols, shape[1] if len(shape) > 1 else 1))


class ScheduleExecutor:
    """Executes a :class:`Schedule` against host arrays with real JAX ops.

    One interpreter for every host-driven kernel (GEMM, attention, SYRK, the
    hand-rolled benchmark baselines): H2D slices the typed
    :class:`SliceRef` payload into a parity buffer, COMPUTE dispatches the
    :class:`BlockRef` payload through the handler registry, D2H writes a
    parity buffer back into the destination slice (or dispatches a finalize
    handler).  Every run first compiles (or fetches from the per-schedule
    cache) an :class:`~repro.core.exec_plan.ExecutablePlan` — pre-resolved
    handlers, engine queues, dependency edges — so repeated runs skip all
    per-op string/dict work.

    ``mode`` selects the run loop (DESIGN.md §13):

      * ``"issue_order"`` (default) — the serial interpreter: ops run in
        global issue order on the calling thread.  Issue order + data deps
        realize the event program (it is a proven linear extension of the
        dependency order); real overlap is whatever XLA's async dispatch
        gives us.  This path is the differential oracle the concurrent
        mode is asserted bitwise-identical against, and the fallback
        whenever ``faults=`` is armed (fault injection is not ported yet).
      * ``"concurrent"`` — the event-driven runner: one worker thread per
        engine (H2D copy, D2H copy, one kernel engine per stream — the
        same engine split the simulator models) consumes its per-engine
        FIFO queue and blocks on ``threading.Event``s mirroring the
        schedule's event program, so host wall-clock genuinely overlaps
        transfers and compute.  Deadlock-free by construction: issue order
        is a linear extension of the dependency order, and each engine
        walks its queue in issue order, so the earliest unfinished op's
        predecessors are always completable.  ``last_completion_order``
        records the order ops finished (itself a linear extension — the
        conformance tests pin it).

    ``async_writeback=True`` is the double-buffered mode mirroring the event
    program on real hardware: a D2H only *dispatches* (the device block stays
    in flight) and materializes when its parity buffer is about to be
    overwritten — i.e. the host blocks on block ``idx``'s compute only after
    block ``idx+1``'s transfers were issued, exactly the paper's overlap.
    (Concurrent mode instead lands each D2H synchronously *on the D2H
    worker* — blocking an engine thread, not the pipeline, which is what a
    real copy engine does.)

    ``record_spans=True`` timestamps every op into ``last_spans`` as
    ``(tag, stream, start_s, end_s)`` — the same span shape the simulator
    emits, so :func:`repro.core.trace.chrome_trace` renders either source.
    In ``"issue_order"`` mode recording synchronizes each op's written
    buffers (JAX dispatch is async), so it serializes the pipeline: use it
    to *inspect* schedules, not to benchmark them.  In ``"concurrent"``
    mode each engine worker stamps its own ops against one shared
    ``perf_counter`` base and only synchronizes the buffers *it* wrote, so
    recording does not serialize the pipeline — spans feed
    ``TraceAnalysis.from_spans`` (wall-clock mode).  Residual skew remains:
    a span's end is when the op's outputs were observed ready on its engine
    thread, which can trail the device-side completion by the worker's
    scheduling latency, and H2D/D2H spans include host slice/copy time the
    simulator models as pure bus time.  Cross-engine ordering of recorded
    spans is therefore reliable only through the event edges, not through
    raw timestamp comparison — which is exactly the tolerance
    ``TraceAnalysis.from_spans`` applies.

    ``last_h2d_bytes``/``last_d2h_bytes`` count the bytes of the transfer
    ops the executor actually performed in the most recent :meth:`run` —
    the ground truth the simulator's modeled byte counts are asserted
    against (a cache-hit step has no H2D op, so skipped transfers are
    counted by neither).  Under fault injection these counters keep their
    meaning (nominal bytes, once per op, always reconciling with
    ``schedule_stats``); the *extra* traffic recovery caused is accounted
    separately in ``last_fault_stats["replayed_h2d_bytes"]``.

    ``faults=``/``policy=`` arm deterministic fault injection
    (DESIGN.md §12): a :class:`~repro.fault.FaultPlan` (or a prepared
    injector, or a ``sched -> plan`` callable) is consulted once per op
    *attempt*; transient transfer errors are retried with the policy's
    exponential backoff, compute faults are recovered by block-granular
    replay from the written buffer's last host-consistent point, and
    ``device_lost``/``oom`` raise immediately for the callers that own
    those recoveries (hybrid rebalance, degrade ladders).  ``faults=None``
    (the default) costs one branch per op.

    When the process :class:`~repro.obs.Observability` is enabled, every
    run publishes its aggregates (bytes, ops, flops, wall seconds,
    block-cache counters, per-stream busy time when recording) as
    ``repro_executor_*`` metrics, and recorded spans are absorbed into the
    active tracer as one lane-group (``trace_group`` names it; the hybrid
    co-scheduler passes the device name).  Disabled observability costs one
    branch per run.
    """

    MODES = ("issue_order", "concurrent")

    def __init__(self,
                 handlers: Optional[Dict[str, HandlerFn]] = None,
                 async_writeback: bool = True,
                 record_spans: bool = False,
                 trace_group: Optional[str] = None,
                 mode: str = "issue_order"):
        if mode not in self.MODES:
            raise ValueError(
                f"unknown executor mode {mode!r}; expected one of "
                f"{self.MODES}")
        self.handlers = dict(handlers) if handlers else {}
        self.async_writeback = async_writeback
        self.record_spans = record_spans
        self.mode = mode
        # lane-group name used when recorded spans are absorbed into an
        # active obs tracer (the hybrid co-scheduler names executors after
        # their device); None derives one from the schedule's kernel meta
        self.trace_group = trace_group
        self.last_spans: List[Tuple[str, int, float, float]] = []
        # issue indices in the order ops completed in the most recent run
        # (serial: identical to issue order; concurrent: a linear extension
        # of the dependency order — the conformance tests pin it)
        self.last_completion_order: List[int] = []
        self.last_h2d_bytes = 0
        self.last_d2h_bytes = 0
        self.last_wall_seconds = 0.0
        # fault-injection accounting for the most recent run (None when the
        # run was fault-free): injected / retries / replayed_ops /
        # replayed_h2d_bytes / backoff_seconds / recovered_{retry,replay}
        self.last_fault_stats: Optional[Dict[str, float]] = None

    def _handler(self, ref: BlockRef) -> HandlerFn:
        fn = self.handlers.get(ref.kernel) or _OP_HANDLERS.get(ref.kernel)
        if fn is None:
            raise KeyError(
                f"no op handler registered for kernel {ref.kernel!r}; "
                f"known: {sorted(set(_OP_HANDLERS) | set(self.handlers))}"
            )
        return fn

    def run(self,
            sched: Schedule,
            operands: Dict[str, Any],
            outputs: Dict[str, np.ndarray],
            ctx: Optional[Dict[str, Any]] = None,
            faults=None,
            policy=None) -> ExecState:
        st = ExecState(bufs={}, operands=operands, outputs=outputs,
                       ctx=ctx or {}, scratch={})
        # compile (or fetch the cached) ExecutablePlan: pre-resolved
        # handlers + engine queues + dependency edges.  A hand-built
        # schedule with a broken event graph can still run serially (the
        # serial loop never consults the edges), so compile failures only
        # propagate when the concurrent runner actually needs the plan.
        try:
            plan: Optional[ExecutablePlan] = compile_executable(sched)
        except ScheduleError:
            if self.mode == "concurrent":
                raise
            plan = None
        resolved = plan.resolved if plan is not None else None

        def handler_for(i: int, ref: BlockRef) -> HandlerFn:
            if self.handlers:
                fn = self.handlers.get(ref.kernel)
                if fn is not None:
                    return fn
            if resolved is not None:
                fn = resolved[i]
                if fn is not None:
                    return fn
            return self._handler(ref)

        # parity-buffer key -> (in-flight device block, destination slice)
        pending: Dict[Tuple[str, Hashable], Tuple[Any, SliceRef]] = {}

        def flush(key) -> None:
            # read-then-delete, NOT pop-then-write: if materializing the
            # block or the host store raises, the entry must stay in flight
            # so a retry re-lands it — popping first made later finalize
            # handlers silently observe stale host state
            blk, ref = pending[key]
            arr = np.asarray(blk)
            dest = st.outputs[ref.operand]
            if ref.transpose:
                arr = arr.T
            rs, rn = ref.rows if ref.rows is not None else (0, dest.shape[0])
            if dest.ndim > 1:
                cs, cn = ref.cols if ref.cols is not None \
                    else (0, dest.shape[1])
                dest[rs:rs + rn, cs:cs + cn] = arr
            else:
                dest[rs:rs + rn] = arr
            del pending[key]

        # ---- fault injection state (armed only when a plan is passed) ----
        fi = faults
        fstats: Optional[Dict[str, float]] = None
        if fi is not None:
            from repro.fault.errors import (ComputeFault, DeviceLostError,
                                            OomError, TransferError)
            from repro.fault.plan import REPLAYABLE_KERNELS
            if callable(fi) and not hasattr(fi, "check"):
                fi = fi(sched)            # a sched -> plan factory
            if hasattr(fi, "injector"):   # a FaultPlan: fresh one-shot state
                fi = fi.injector()
            if policy is None:
                from repro.fault.policy import FaultPolicy
                policy = FaultPolicy()
            fstats = {"injected": 0, "retries": 0, "replayed_ops": 0,
                      "replayed_h2d_bytes": 0, "backoff_seconds": 0.0,
                      "recovered_retry": 0, "recovered_replay": 0}
            # per-buffer recovery state: the value at the last
            # host-consistent point (H2D load / write-back dispatch) and
            # the compute chain applied since — buffer reassignment makes
            # these O(1) reference snapshots, not copies
            clean: Dict[Tuple[str, Hashable], Any] = {}
            chains: Dict[Tuple[str, Hashable], List] = {}

        def flush_retrying(key) -> None:
            # a write-back materialization can itself fail transiently;
            # under a policy it gets the same retry treatment as an
            # injected transfer fault (the fixed flush keeps the entry
            # in flight across attempts)
            if fi is None:
                flush(key)
                return
            attempt = 0
            while True:
                try:
                    flush(key)
                except TransferError:
                    attempt += 1
                    if attempt > policy.max_retries:
                        raise
                    fstats["retries"] += 1
                    delay = policy.backoff(attempt)
                    fstats["backoff_seconds"] += delay
                    policy.sleep(delay)
                    continue
                if attempt:
                    fstats["recovered_retry"] += 1
                return

        def exec_h2d(op, ref) -> None:
            self.last_h2d_bytes += op.bytes
            key = op.buffers_written[0]
            if key in pending:           # schedule's wC wait point: the
                flush_retrying(key)      # previous occupant lands now
            if ref.operand in st.outputs:  # host coherence on re-read
                src_shape = st.outputs[ref.operand].shape
                for k in [k for k, (_, pref) in pending.items()
                          if _spans_overlap(ref, pref, src_shape)]:
                    flush_retrying(k)
            st.bufs[key] = jnp.asarray(_take(st.host(ref.operand), ref))
            if fi is not None:   # fresh load = host-consistent snapshot
                clean[key] = st.bufs[key]
                chains[key] = []

        def exec_compute(i, op, ref) -> None:
            handler_for(i, ref)(st, op, ref)

        def exec_d2h(i, op, ref) -> None:
            self.last_d2h_bytes += op.bytes
            if isinstance(ref, BlockRef):  # finalize handler
                for key in list(pending):  # finalizers read/patch host
                    flush_retrying(key)    # state: land in-flight blocks
                handler_for(i, ref)(st, op, ref)
                return
            key = op.buffers_read[0]
            if key in pending:
                flush_retrying(key)
            pending[key] = (st.bufs[key], ref)
            if fi is not None:
                # write-back boundary: compute replay restores from here,
                # references to the earlier chain are released
                clean[key] = st.bufs[key]
                chains[key] = []
            if not self.async_writeback:
                flush_retrying(key)

        def run_clean(i, op, ref) -> None:
            if op.kind == OpKind.H2D:
                exec_h2d(op, ref)
            elif op.kind == OpKind.COMPUTE:
                exec_compute(i, op, ref)
            elif op.kind == OpKind.D2H:
                exec_d2h(i, op, ref)

        def run_faulted(i, op, ref) -> None:
            attempt = 0              # faulted attempts of this op so far
            while True:
                cls = fi.check(i, op)
                if cls is None:
                    run_clean(i, op, ref)
                    if op.kind == OpKind.COMPUTE:
                        # successful compute: extend the redo chains of the
                        # buffers it wrote, snapshotting its read buffers
                        # so a later replay re-binds the exact inputs
                        reads = {k: st.bufs[k] for k in op.buffers_read
                                 if k in st.bufs}
                        for k in op.buffers_written:
                            if k in chains:
                                chains[k].append((op, ref, reads))
                    if attempt:
                        fstats["recovered_replay"
                               if op.kind == OpKind.COMPUTE
                               else "recovered_retry"] += 1
                    return
                fstats["injected"] += 1
                obs.instant(f"fault:{cls}", op=i, tag=op.tag,
                            stream=op.stream)
                if cls == "device_lost":
                    raise DeviceLostError(
                        f"injected device_lost at op {i} ({op.tag})")
                if cls == "oom":
                    raise OomError(f"injected oom at op {i} ({op.tag})")
                attempt += 1
                if cls == "h2d_error":
                    if op.kind == OpKind.COMPUTE:
                        raise ValueError(
                            f"fault plan injects h2d_error into compute "
                            f"op {i} ({op.tag})")
                    if attempt > policy.max_retries:
                        raise TransferError(
                            f"op {i} ({op.tag}): transfer failed after "
                            f"{policy.max_retries} retries")
                    if op.kind == OpKind.H2D:
                        # the failed attempt still moved the bytes: extra
                        # traffic is recovery's, nominal counters are not
                        fstats["replayed_h2d_bytes"] += op.bytes
                    fstats["retries"] += 1
                    delay = policy.backoff(attempt)
                    fstats["backoff_seconds"] += delay
                    policy.sleep(delay)
                    continue
                # compute_nan: the op runs but its output is corrupt;
                # recover by block-granular replay — restore the written
                # buffer's last host-consistent value and redo the chain
                key = op.buffers_written[0] if op.buffers_written else None
                self._handler(ref)(st, op, ref)
                for k in op.buffers_written:
                    if k in st.bufs:
                        st.bufs[k] = jnp.full_like(st.bufs[k], jnp.nan)
                replayable = (
                    op.kind == OpKind.COMPUTE and key is not None
                    and len(op.buffers_written) == 1 and key in clean
                    and getattr(ref, "kernel", None) in REPLAYABLE_KERNELS)
                if not replayable or attempt > policy.max_retries:
                    raise ComputeFault(
                        f"op {i} ({op.tag}): compute fault "
                        + ("retries exhausted" if replayable
                           else "not replayable"))
                st.bufs[key] = clean[key]
                for cop, cref, creads in chains[key]:
                    saved = {}
                    for rk, rv in creads.items():
                        if rk in cop.buffers_written:
                            continue
                        saved[rk] = st.bufs.get(rk)
                        st.bufs[rk] = rv
                    self._handler(cref)(st, cop, cref)
                    for rk, rv in saved.items():
                        if rv is None:
                            st.bufs.pop(rk, None)
                        else:
                            st.bufs[rk] = rv
                fstats["replayed_ops"] += len(chains[key]) + 1
                # loop: the next attempt re-consults the injector and
                # either faults again (times > 1) or dispatches cleanly

        # stale spans from a prior run must never leak into a new trace,
        # so the reset is unconditional (not gated on record_spans)
        self.last_spans = []
        self.last_completion_order = []
        self.last_h2d_bytes = 0
        self.last_d2h_bytes = 0
        self.last_fault_stats = None
        obs = get_observability()
        tracer = obs.tracer
        # an active tracer forces span recording: a trace is inspection
        # mode by definition, and a silent executor would leave a hole in
        # the timeline
        trace = self.record_spans or tracer is not None
        run_offset = tracer.now() if tracer is not None else 0.0
        t_run0 = time.perf_counter()
        if trace:
            t_base = t_run0

        # fault injection is not ported to the worker-thread runner yet:
        # an armed plan falls back to the serial oracle (same results,
        # same recovery semantics, no overlap)
        concurrent = self.mode == "concurrent" and fi is None

        try:
            if concurrent:
                self._run_concurrent(plan, st, trace, t_run0)
            else:
                for i, op in enumerate(sched.ops):
                    ref = op.payload
                    if trace:
                        t0 = time.perf_counter() - t_base
                    if fi is None:
                        run_clean(i, op, ref)
                    else:
                        run_faulted(i, op, ref)
                    if trace:
                        sync = [st.bufs[k] for k in op.buffers_written
                                if k in st.bufs]
                        if op.kind == OpKind.COMPUTE \
                                and "carry" in st.scratch:
                            sync.append(st.scratch["carry"])
                        jax.block_until_ready(sync)
                        self.last_spans.append(
                            (op.tag, op.stream, t0,
                             time.perf_counter() - t_base))
                    self.last_completion_order.append(i)
                for key in list(pending):
                    flush_retrying(key)
        finally:
            if fi is not None:
                # publish even when an unrecoverable fault propagates:
                # the caller's degrade/rebalance handler still needs the
                # injection record
                self.last_fault_stats = fstats
                obs.record_fault_run(sched.meta.get("kernel", "run"),
                                     fstats)
        self.last_wall_seconds = time.perf_counter() - t_run0
        if obs.metrics.enabled:
            obs.record_executor_run(
                sched, self.last_wall_seconds,
                self.last_h2d_bytes, self.last_d2h_bytes,
                spans=self.last_spans if trace else None)
        if tracer is not None and trace and self.last_spans:
            tracer.add_flat_spans(
                self.trace_group
                or f"executor:{sched.meta.get('kernel', 'run')}",
                self.last_spans, offset=run_offset,
                reuse=sched.reuse or None)
        return st

    def _run_concurrent(self, plan: ExecutablePlan, st: ExecState,
                        trace: bool, t_base: float) -> None:
        """Event-driven run loop: one worker thread per engine.

        Each worker walks its engine's FIFO queue in issue order; before
        dispatching op ``i`` it waits the ``threading.Event`` of every
        cross-engine predecessor in ``plan.preds[i]`` (same-engine edges
        are implied by the queue walk) and sets ``done[i]`` after the op
        completed *on this engine* — H2D after the device put was issued,
        D2H after the block landed in host memory, COMPUTE after the
        handler dispatched.  This mirrors the simulator's event program:
        engines block, the host never does.

        Failure: the first raising worker records its error, sets ``stop``
        and force-sets every ``done`` event so blocked peers wake, observe
        ``stop`` (set strictly before the force-set, so any waiter woken
        by it reads stop=True) and drain without dispatching further ops.
        The lowest-issue-index error is re-raised on the calling thread.
        """
        ops = plan.ops
        done = [threading.Event() for _ in range(plan.n_ops)]
        stop = threading.Event()
        errors: List[Tuple[int, BaseException]] = []
        err_lock = threading.Lock()
        completion: List[int] = []   # list.append is atomic under the GIL
        n_eng = len(plan.queues)
        eng_h2d = [0] * n_eng
        eng_d2h = [0] * n_eng
        eng_spans: List[List[Tuple[str, int, float, float]]] = \
            [[] for _ in range(n_eng)]
        handlers = self.handlers
        resolved = plan.resolved

        def handler_at(i: int, ref: BlockRef) -> HandlerFn:
            if handlers:
                fn = handlers.get(ref.kernel)
                if fn is not None:
                    return fn
            fn = resolved[i]
            return fn if fn is not None else self._handler(ref)

        def land(blk: Any, ref: SliceRef) -> None:
            # synchronous D2H: np.asarray blocks this worker (the "copy
            # engine") until the device value is ready, then stores it —
            # the concurrent analogue of the serial pending-flush
            arr = np.asarray(blk)
            dest = st.outputs[ref.operand]
            if ref.transpose:
                arr = arr.T
            rs, rn = ref.rows if ref.rows is not None else (0, dest.shape[0])
            if dest.ndim > 1:
                cs, cn = ref.cols if ref.cols is not None \
                    else (0, dest.shape[1])
                dest[rs:rs + rn, cs:cs + cn] = arr
            else:
                dest[rs:rs + rn] = arr

        def dispatch(e: int, i: int, op: Op) -> None:
            ref = op.payload
            kind = plan.kinds[i]
            if kind == _xplan.KIND_H2D:
                eng_h2d[e] += op.bytes
                st.bufs[op.buffers_written[0]] = jnp.asarray(
                    _take(st.host(ref.operand), ref))
            elif kind == _xplan.KIND_COMPUTE:
                handler_at(i, ref)(st, op, ref)
            else:  # D2H
                eng_d2h[e] += op.bytes
                if isinstance(ref, BlockRef):   # finalize handler
                    handler_at(i, ref)(st, op, ref)
                else:
                    land(st.bufs[op.buffers_read[0]], ref)

        def worker(e: int) -> None:
            spans = eng_spans[e]
            for i in plan.queues[e]:
                for p in plan.preds[i]:
                    done[p].wait()
                if stop.is_set():
                    return
                op = ops[i]
                if trace:
                    t0 = time.perf_counter() - t_base
                try:
                    dispatch(e, i, op)
                    if trace:
                        # per-engine clock: synchronize only the buffers
                        # THIS op wrote — other engines keep running
                        sync = [st.bufs[k] for k in op.buffers_written
                                if k in st.bufs]
                        if plan.kinds[i] == _xplan.KIND_COMPUTE \
                                and "carry" in st.scratch:
                            sync.append(st.scratch["carry"])
                        jax.block_until_ready(sync)
                except BaseException as exc:
                    with err_lock:
                        errors.append((i, exc))
                    stop.set()
                    for d in done:
                        d.set()
                    return
                if trace:
                    spans.append((op.tag, op.stream, t0,
                                  time.perf_counter() - t_base))
                completion.append(i)
                done[i].set()

        threads = [
            threading.Thread(target=worker, args=(e,), daemon=True,
                             name=f"exec-{plan.engines[e]}")
            for e in range(n_eng) if plan.queues[e]]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        self.last_h2d_bytes += sum(eng_h2d)
        self.last_d2h_bytes += sum(eng_d2h)
        self.last_completion_order = completion
        if trace:
            merged = [sp for spans in eng_spans for sp in spans]
            merged.sort(key=lambda s: (s[2], s[3]))
            self.last_spans = merged
        if errors:
            errors.sort(key=lambda ie: ie[0])
            raise errors[0][1]


@register_op_handler("noop")
def _noop_handler(st: ExecState, op: Op, ref: BlockRef) -> None:
    """Buffer-release marker ("keep" write-back mode): nothing to execute."""


@register_op_handler("dgemm")
def _dgemm_handler(st: ExecState, op: Op, ref: BlockRef) -> None:
    """C_p = alpha * lhs @ rhs + beta * C_p on parity buffers (GEMM + SYRK:
    buffers_read = (lhs, rhs), buffers_written[0] = accumulator)."""
    ckey = op.buffers_written[0]
    st.bufs[ckey] = _block_dgemm(
        st.bufs[op.buffers_read[0]], st.bufs[op.buffers_read[1]],
        st.bufs[ckey],
        jnp.asarray(st.ctx.get("alpha", 1.0), dtype=jnp.float32),
        jnp.asarray(st.ctx.get("beta", 0.0), dtype=jnp.float32),
    )


# ---------------------------------------------------------------------------
# Factorization panel ops (the paper's §VII kernels, DESIGN.md §8): in-core
# panel factor / solve handlers the factor pipeline interleaves with the
# streamed dgemm trailing update.  Panels are resident parity buffers shaped
# (m, pw); the panel width is recovered from the buffer itself.
# ---------------------------------------------------------------------------
def getrf_panel(buf: np.ndarray) -> np.ndarray:
    """Unblocked right-looking LU with partial pivoting on an (m, pw) panel,
    in place.  Returns LAPACK-style local pivot rows ``piv`` (column ``j``
    swapped panel rows ``j`` and ``piv[j]``); L's unit diagonal is implicit,
    multipliers live below it, U on and above."""
    m, pw = buf.shape
    piv = np.arange(pw)
    for j in range(pw):
        p = j + int(np.argmax(np.abs(buf[j:, j])))
        piv[j] = p
        if p != j:
            buf[[j, p], :] = buf[[p, j], :]
        d = buf[j, j]
        if d != 0:
            buf[j + 1:, j] /= d
            if j + 1 < pw:
                buf[j + 1:, j + 1:] -= np.outer(buf[j + 1:, j],
                                                buf[j, j + 1:])
    return piv


def apply_panel_pivots(A: np.ndarray, piv: np.ndarray, k0: int, k1: int,
                       perm: np.ndarray) -> None:
    """Replay a panel's local pivots on the host matrix columns *outside*
    the panel (left of it: already-written L; right of it: the trailing
    columns), accumulating the global row permutation — the one definition
    of the swap-replay invariant, shared by the pipeline's ``lu_writeback``
    handler and the per-panel fallback loop."""
    for j, p in enumerate(piv):
        if p != j:
            r1, r2 = k0 + j, k0 + int(p)
            A[[r1, r2], :k0] = A[[r2, r1], :k0]
            A[[r1, r2], k1:] = A[[r2, r1], k1:]
            perm[[r1, r2]] = perm[[r2, r1]]


@register_op_handler("panel_chol")
def _panel_chol_handler(st: ExecState, op: Op, ref: BlockRef) -> None:
    """POTRF: factor the resident panel's diagonal block in-core (the upper
    triangle comes back zeroed, as np.linalg.cholesky leaves it)."""
    key = op.buffers_written[0]
    buf = np.array(st.bufs[key])
    d = buf.shape[1]
    buf[:d, :d] = np.linalg.cholesky(buf[:d, :d])
    st.bufs[key] = jnp.asarray(buf)


@register_op_handler("panel_trsm")
def _panel_trsm_handler(st: ExecState, op: Op, ref: BlockRef) -> None:
    """Cholesky panel solve: sub-diagonal rows <- rows @ inv(Lkk)^T, in the
    resident panel buffer."""
    key = op.buffers_written[0]
    buf = np.array(st.bufs[key])
    d = buf.shape[1]
    buf[d:, :] = np.linalg.solve(buf[:d, :d], buf[d:, :].T).T
    st.bufs[key] = jnp.asarray(buf)


@register_op_handler("panel_lu")
def _panel_lu_handler(st: ExecState, op: Op, ref: BlockRef) -> None:
    """GETRF: partial-pivot LU of the resident panel; the local pivot rows
    park in scratch for the write-back's row-swap replay."""
    key = op.buffers_written[0]
    buf = np.array(st.bufs[key])
    st.scratch[("piv", ref.index)] = getrf_panel(buf)
    st.bufs[key] = jnp.asarray(buf)


@register_op_handler("lu_trsm")
def _lu_trsm_handler(st: ExecState, op: Op, ref: BlockRef) -> None:
    """LU row-panel solve: U[k, k+1:] <- inv(unit-lower Lkk) @ U[k, k+1:],
    with Lkk read from the resident factored panel."""
    pkey, ukey = op.buffers_read
    pnl = np.asarray(st.bufs[pkey])
    urow = np.asarray(st.bufs[ukey])
    d = pnl.shape[1]
    lkk = np.tril(pnl[:d, :d], -1) + np.eye(d, dtype=pnl.dtype)
    st.bufs[ukey] = jnp.asarray(
        np.linalg.solve(lkk, urow).astype(urow.dtype))


@register_op_handler("lu_writeback")
def _lu_writeback_handler(st: ExecState, op: Op, ref: BlockRef) -> None:
    """LU panel write-back with row-swap replay: land the factored panel and
    apply its pivots to the host columns *outside* the panel (left of it:
    already-written L; right of it: the not-yet-updated trailing columns),
    accumulating the global permutation in scratch."""
    A = st.outputs["A"]
    n = A.shape[0]
    buf = np.asarray(st.bufs[op.buffers_read[0]])
    pw = buf.shape[1]
    k0 = n - buf.shape[0]
    k1 = k0 + pw
    piv = st.scratch.pop(("piv", ref.index))
    perm = st.scratch.setdefault("perm", np.arange(n))
    apply_panel_pivots(A, piv, k0, k1, perm)
    A[k0:, k0:k1] = buf.astype(A.dtype)


@register_runtime("HBM")
class HostOocRuntime(OocRuntime):
    """Host-driven block streaming: builds (or accepts) a pipeline schedule
    and hands it to the shared :class:`ScheduleExecutor` — no private
    interpreter loop.  On real hardware JAX's async dispatch overlaps the
    transfer of block ``idx+1`` with the DGEMM of block ``idx`` exactly as
    the event program orders them; on CPU the schedule executes with
    identical semantics (ordering + results), which is what tests assert.
    """

    def __init__(self, device: Optional[Device] = None,
                 executor: Optional[ScheduleExecutor] = None):
        self.device = device or Device("HBM", 0, 16 * 2**30)
        self.executor = executor or ScheduleExecutor()

    def gemm(self, A, B, C, alpha, beta, part: GemmPartition,
             nstreams: int = 2, nbuf: int = 2,
             schedule: Optional[Schedule] = None,
             faults=None, policy=None):
        sched = schedule or plib.build_gemm_schedule(
            part, nstreams=nstreams, nbuf=nbuf
        )
        out = np.array(C, copy=True)
        self.executor.run(
            sched,
            operands={"A": np.asarray(A), "B": np.asarray(B)},
            outputs={"C": out},
            ctx={"alpha": alpha, "beta": beta},
            faults=faults, policy=policy,
        )
        return out

    def syrk(self, P, C, alpha, beta, part: GemmPartition,
             nstreams: int = 2, nbuf: int = 2,
             schedule: Optional[Schedule] = None,
             faults=None, policy=None):
        """C = alpha * P @ P^T + beta * C via the SYRK pipeline spec (the
        Cholesky trailing update as a first-class schedule)."""
        sched = schedule or plib.build_syrk_schedule(
            part, nstreams=nstreams, nbuf=nbuf
        )
        out = np.array(C, copy=True)
        self.executor.run(
            sched,
            operands={"P": np.asarray(P)},
            outputs={"C": out},
            ctx={"alpha": alpha, "beta": beta},
            faults=faults, policy=policy,
        )
        return out


@register_runtime("VMEM")
class VmemOocRuntime(OocRuntime):
    """HBM->VMEM tier: delegates to the Pallas block-matmul kernel, which IS
    the paper's pipeline compiled into the chip (Mosaic double-buffers the
    A/B/C tile DMAs across grid steps)."""

    def __init__(self, device: Optional[Device] = None,
                 interpret: Optional[bool] = None):
        self.device = device or Device("VMEM", 0, 128 * 2**20)
        # CPU container: interpret mode (kernel body runs in Python).
        self.interpret = (
            interpret if interpret is not None
            else jax.devices()[0].platform != "tpu"
        )

    def gemm(self, A, B, C, alpha, beta, part: GemmPartition,
             block: Optional[Tuple[int, int, int]] = None, **kw):
        from repro.kernels import ops as kops

        bm = min(part.bm, 512)
        bn = min(part.bn, 512)
        bk = min(part.K, 512)
        if block is not None:
            bm, bn, bk = block
        return kops.block_matmul(
            jnp.asarray(A), jnp.asarray(B), jnp.asarray(C),
            alpha=alpha, beta=beta, block=(bm, bn, bk),
            interpret=self.interpret,
        )


@register_runtime("MESH")
class MeshOocRuntime(OocRuntime):
    """Mesh tier: SUMMA ring over ICI.

    The operands are sharded across a 1-D submesh (A by row blocks, B by
    column blocks, C by row blocks); each device streams the remote B blocks
    through a ping-pong buffer with ``ppermute`` while the MXU consumes the
    current block — the paper's 2-stream overlap where the "PCIe link" is ICI
    and the "host memory" is the neighbours' HBM.
    """

    def __init__(self, mesh: Mesh, axis: str = "model",
                 device: Optional[Device] = None):
        self.mesh = mesh
        self.axis = axis
        self.device = device or Device("MESH", 0, 16 * 2**30)

    @classmethod
    def from_device(cls, device: Device, *, mesh: Optional[Mesh] = None,
                    **kw) -> "MeshOocRuntime":
        if mesh is None:
            raise ValueError("MESH runtime needs a jax Mesh")
        return cls(mesh, device=device, **kw)

    def gemm(self, A, B, C, alpha, beta, part=None, overlap: bool = True, **kw):
        mesh, axis = self.mesh, self.axis
        Pn = mesh.shape[axis]
        M, K = A.shape
        _, N = B.shape
        if M % Pn or N % Pn:
            raise ValueError(f"SUMMA needs M,N divisible by mesh axis {Pn}")
        n_blk = N // Pn
        alpha = jnp.float32(alpha)
        beta = jnp.float32(beta)

        def ring_body(a_blk, b_blk, c_blk):
            # a_blk: (M/P, K)  b_blk: (K, N/P)  c_blk: (M/P, N)
            me = jax.lax.axis_index(axis)
            perm = [(i, (i - 1) % Pn) for i in range(Pn)]

            def step(t, carry):
                b_cur, acc = carry
                # issue the permute FIRST so Mosaic/XLA can overlap the ICI
                # transfer of the next block with this block's matmul
                # (ping-pong buffer: b_nxt is a fresh buffer).
                b_nxt = jax.lax.ppermute(b_cur, axis, perm) if overlap else b_cur
                col = ((me + t) % Pn) * n_blk
                prod = jnp.dot(a_blk, b_cur,
                               preferred_element_type=jnp.float32)
                old = jax.lax.dynamic_slice(
                    acc, (0, col), (acc.shape[0], n_blk))
                upd = (alpha * prod + beta * old).astype(acc.dtype)
                acc = jax.lax.dynamic_update_slice(acc, upd, (0, col))
                if not overlap:
                    b_nxt = jax.lax.ppermute(b_cur, axis, perm)
                return b_nxt, acc

            _, acc = jax.lax.fori_loop(0, Pn, step, (b_blk, c_blk))
            return acc

        spec_a = P(axis, None)
        spec_b = P(None, axis)
        spec_c = P(axis, None)
        fn = jax.shard_map(
            ring_body, mesh=mesh,
            in_specs=(spec_a, spec_b, spec_c),
            out_specs=spec_c,
        )
        sA = jax.device_put(A, NamedSharding(mesh, spec_a))
        sB = jax.device_put(B, NamedSharding(mesh, spec_b))
        sC = jax.device_put(C, NamedSharding(mesh, spec_c))
        return jax.jit(fn)(sA, sB, sC)


class RuntimeFactory:
    """``hclRuntimeFactory``: device tuple -> runtime, via the declarative
    registry populated by :func:`register_runtime`.  Extra keyword arguments
    are forwarded to the tier's ``from_device`` hook (e.g. ``devices=[...]``
    for the hybrid composite)."""

    @staticmethod
    def create(device: Device, mesh: Optional[Mesh] = None,
               **kw) -> OocRuntime:
        name = device.name.upper()
        cls = _RUNTIME_REGISTRY.get(name)
        if cls is None and name in _LAZY_RUNTIME_MODULES:
            importlib.import_module(_LAZY_RUNTIME_MODULES[name])
            cls = _RUNTIME_REGISTRY.get(name)
        if cls is None:
            raise ValueError(
                f"unknown device type {device.name!r}; registered tiers: "
                f"{RuntimeFactory.registered()}"
            )
        return cls.from_device(device, mesh=mesh, **kw)

    @staticmethod
    def registered() -> List[str]:
        """Tier names ``create`` accepts (registered + lazily importable)."""
        return sorted(set(_RUNTIME_REGISTRY) | set(_LAZY_RUNTIME_MODULES))
