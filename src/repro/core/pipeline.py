"""Pipeline schedule builders — libhclooc's Fig. 2 program, generated.

The paper hand-writes a ~55-line event/stream program for out-of-core GEMM and
notes (§V) that "this synchronization pattern is common and can be reused for
out-of-core implementations of other data-parallel kernels", proposing a DSL
as future work.  ``BlockPipelineBuilder`` is that DSL: a small builder that
takes *stage* descriptions (transfer in / compute / transfer out, which buffer
class each touches, how often each runs) and emits an event-correct
multi-stream :class:`~repro.core.streams.Schedule`.

Two instantiations ship:

  * :func:`build_gemm_schedule` — the paper's MMOOC pipeline
    ``S(b_j) S(a_i) S(c_ij) DGEMM R(c_ij)`` with round-robin streams and the
    five event sets (rA, rB, rC, eA, wC).
  * :func:`build_attention_schedule` — out-of-core attention over a blocked KV
    cache (beyond paper): same pipeline with an online-softmax carry instead
    of a beta-accumulate, demonstrating the claimed reusability.

Schedules are *backend-neutral*: the simulator times them under a hardware
model; the Host runtime executes them with real JAX ops.
"""

from __future__ import annotations

from typing import List, Optional

from repro.core.partitioner import AttentionPartition, GemmPartition
from repro.core.streams import (
    Device,
    Event,
    Op,
    OpKind,
    Schedule,
    StreamFactory,
)


class BlockPipelineBuilder:
    """Generates the paper's round-robin / parity-buffer schedule shape.

    Semantics (faithful to libhclooc §V):
      * ``nbuf`` on-device buffers per streamed operand class; block ``idx``
        occupies parity ``idx % nbuf``.
      * compute for block ``idx`` runs on stream ``idx % nstreams``; the
        prefetch of block ``idx+1`` runs concurrently on stream
        ``(idx+1) % nstreams`` (the paper's ``idx1``/``idx2`` round robin).
      * before a transfer overwrites a parity buffer, it waits on the event
        proving the previous occupant's last consumer finished — the paper's
        ``hclWaitEvent(eA[idx-1])`` / ``eC[idx-1]`` lines.
      * ``nstreams = 1`` degenerates to the fully serial Phi-style pipeline
        (claim C5): program order supplies every dependency.
    """

    def __init__(self, device: Device, nstreams: int, nbuf: int):
        if nbuf < 1 or nstreams < 1:
            raise ValueError("nbuf and nstreams must be >= 1")
        self.nbuf = nbuf
        self.nstreams = nstreams
        self.sched = Schedule(device, StreamFactory.create(device, nstreams))
        self._events = {}

    def event(self, name: str) -> Event:
        return self._events.setdefault(name, Event(name))

    def compute_stream(self, idx: int) -> int:
        return idx % self.nstreams

    def transfer_stream(self, idx: int) -> int:
        # Transfers overlapping compute of block idx-1 share that block's
        # "other" stream; with one stream everything serializes.
        return idx % self.nstreams

    def issue(self, **kw) -> Op:
        return self.sched.issue(Op(**kw))


def build_gemm_schedule(
    part: GemmPartition,
    nstreams: int = 2,
    nbuf: int = 2,
    write_back: bool = True,
    device: Optional[Device] = None,
) -> Schedule:
    """Emit the MMOOC schedule of libhclooc Fig. 2 for ``part``.

    Stage set per C block (i, j), idx = j*h + i (column-major so each B slice
    transfers once per column):

      S(b_j)   H2D   once per column j           -> records rB[j]
      S(a_i)   H2D   once per block              -> records rA[idx]
      S(c_ij)  H2D   once per block              -> records rC[idx]
      DGEMM    COMP  waits rA,rB,rC              -> records eA[idx]
      R(c_ij)  D2H   same stream as DGEMM        -> records wC[idx]

    Overwrite guards (buffer parity p = idx % nbuf):
      S(a_idx) waits eA[idx-nbuf]        (A buffer free)
      S(c_idx) waits wC[idx-nbuf]        (C buffer free: written back)
      S(b_j)   waits eA of the last min(nbuf,h) blocks of column j-2
               (B ping-pong buffer free once that column fully consumed)
    """
    dev = device or Device("HBM", 0, part.budget)
    b = BlockPipelineBuilder(dev, nstreams, nbuf)
    sched = b.sched
    bpe = part.bytes_per_el
    blocks = list(part.blocks())
    h = part.h

    for idx, (i, j, rs, rn, cs, cn) in enumerate(blocks):
        s_cur = b.compute_stream(idx)
        # --- prefetch stream for this block's inputs: the paper issues block
        # idx+1's transfers during block idx's DGEMM; equivalently every
        # block's inputs are issued on its own parity stream, one block ahead.
        s_xfer = b.transfer_stream(idx)

        if i == 0:  # first block of column j: bring in B slice j
            waits = []
            if j >= 2:  # B ping-pong buffer occupied by column j-2
                col_blocks = [j2 * h + i2 for (i2, j2) in
                              [(x, j - 2) for x in range(h)]]
                for k in col_blocks[-min(nbuf, h):]:
                    waits.append(b.event(f"eA[{k}]"))
            b.issue(
                kind=OpKind.H2D, tag=f"S(b[{j}])", stream=s_xfer,
                waits=tuple(waits), records=b.event(f"rB[{j}]"),
                buffers_written=((("B", j % 2)),),
                bytes=part.K * cn * bpe,
                payload={"operand": "B", "j": j, "cs": cs, "cn": cn},
            )

        waits_a = (b.event(f"eA[{idx - nbuf}]"),) if idx - nbuf >= 0 else ()
        b.issue(
            kind=OpKind.H2D, tag=f"S(a[{idx}])", stream=s_xfer,
            waits=waits_a, records=b.event(f"rA[{idx}]"),
            buffers_written=(("A", idx % nbuf),),
            bytes=rn * part.K * bpe,
            payload={"operand": "A", "i": i, "rs": rs, "rn": rn},
        )
        waits_c = (b.event(f"wC[{idx - nbuf}]"),) if idx - nbuf >= 0 else ()
        b.issue(
            kind=OpKind.H2D, tag=f"S(c[{idx}])", stream=s_xfer,
            waits=waits_c, records=b.event(f"rC[{idx}]"),
            buffers_written=(("C", idx % nbuf),),
            bytes=rn * cn * bpe,
            payload={"operand": "C", "i": i, "j": j,
                     "rs": rs, "rn": rn, "cs": cs, "cn": cn},
        )
        b.issue(
            kind=OpKind.COMPUTE, tag=f"DGEMM[{idx}]", stream=s_cur,
            waits=(b.event(f"rA[{idx}]"), b.event(f"rB[{j}]"),
                   b.event(f"rC[{idx}]")),
            records=b.event(f"eA[{idx}]"),
            buffers_read=(("A", idx % nbuf), ("B", j % 2)),
            buffers_written=(("C", idx % nbuf),),
            flops=2 * rn * cn * part.K + 3 * rn * cn,
            payload={"idx": idx, "i": i, "j": j,
                     "rs": rs, "rn": rn, "cs": cs, "cn": cn},
        )
        if write_back:
            b.issue(
                kind=OpKind.D2H, tag=f"R(c[{idx}])", stream=s_cur,
                waits=(b.event(f"eA[{idx}]"),),
                records=b.event(f"wC[{idx}]"),
                buffers_read=(("C", idx % nbuf),),
                bytes=rn * cn * bpe,
                payload={"operand": "C", "i": i, "j": j,
                         "rs": rs, "rn": rn, "cs": cs, "cn": cn},
            )
        else:  # C stays resident (SUMMA nsteps mode); buffer still recycles
            b.issue(
                kind=OpKind.COMPUTE, tag=f"keep(c[{idx}])", stream=s_cur,
                waits=(b.event(f"eA[{idx}]"),),
                records=b.event(f"wC[{idx}]"),
                buffers_read=(("C", idx % nbuf),),
                flops=0,
                payload={"noop": True},
            )
    return sched


def build_attention_schedule(
    part: AttentionPartition,
    kv_heads: int,
    head_dim: int,
    q_heads: int,
    nstreams: int = 2,
    nbuf: int = 2,
    device: Optional[Device] = None,
) -> Schedule:
    """OOC attention: stream KV blocks, accumulate online-softmax partials.

    Demonstrates the paper's claim that the MMOOC synchronization pattern is
    reusable for other data-parallel kernels: the stage graph is identical —
    only the compute op (ATTN with (m, l, acc) carry) and the absence of a
    per-block write-back (one final merge instead) differ.
    """
    dev = device or Device("HBM", 0, part.budget)
    b = BlockPipelineBuilder(dev, nstreams, nbuf)
    bpe = part.bytes_per_el
    blk_bytes = part.bs * kv_heads * head_dim * bpe

    for idx in range(part.nblocks):
        s_cur = b.compute_stream(idx)
        s_xfer = b.transfer_stream(idx)
        waits_kv = (b.event(f"eKV[{idx - nbuf}]"),) if idx - nbuf >= 0 else ()
        b.issue(
            kind=OpKind.H2D, tag=f"S(k[{idx}])", stream=s_xfer,
            waits=waits_kv, records=b.event(f"rK[{idx}]"),
            buffers_written=(("K", idx % nbuf),), bytes=blk_bytes,
            payload={"operand": "K", "idx": idx},
        )
        b.issue(
            kind=OpKind.H2D, tag=f"S(v[{idx}])", stream=s_xfer,
            waits=waits_kv, records=b.event(f"rV[{idx}]"),
            buffers_written=(("V", idx % nbuf),), bytes=blk_bytes,
            payload={"operand": "V", "idx": idx},
        )
        # carry buffer is a single accumulator: serialized via carry reads.
        prev = (b.event(f"eKV[{idx - 1}]"),) if idx > 0 else ()
        b.issue(
            kind=OpKind.COMPUTE, tag=f"ATTN[{idx}]", stream=s_cur,
            waits=(b.event(f"rK[{idx}]"), b.event(f"rV[{idx}]")) + prev,
            records=b.event(f"eKV[{idx}]"),
            buffers_read=(("K", idx % nbuf), ("V", idx % nbuf), "carry"),
            buffers_written=("carry",),
            flops=2 * q_heads * part.bs * head_dim * 2,  # qk^T and pv
            payload={"idx": idx},
        )
    b.issue(
        kind=OpKind.D2H, tag="R(out)", stream=0,
        waits=(b.event(f"eKV[{part.nblocks - 1}]"),),
        records=b.event("done"),
        buffers_read=("carry",),
        bytes=q_heads * head_dim * bpe,
        payload={"operand": "out"},
    )
    return b.sched


def build_vendor_schedule(
    part: GemmPartition,
    device: Optional[Device] = None,
    tile: int = 512,
) -> Schedule:
    """CUBLAS-XT-style baseline schedule (the paper's C3 comparison point).

    CUBLAS-XT tiles C into fixed square blocks (default ~4k) and, per tile,
    synchronously streams the corresponding A-row and B-column *panels* —
    i.e. B panels are re-sent for every row of tiles (no column reuse) and
    nothing overlaps.  We model exactly that: one stream, per-block
    B re-transfer, DGEMM strictly after its transfers, write-back before the
    next tile starts.
    """
    dev = device or Device("HBM", 0, part.budget)
    b = BlockPipelineBuilder(dev, nstreams=1, nbuf=1)
    bpe = part.bytes_per_el
    # CUBLAS-XT tiles C into fixed square blocks regardless of the memory
    # budget; model that with its own `tile`-sized partition.
    vpart = GemmPartition(
        part.M, part.N, part.K,
        (part.M + tile - 1) // tile, (part.N + tile - 1) // tile,
        min(tile, part.M), min(tile, part.N), bpe, part.budget)
    for idx, (i, j, rs, rn, cs, cn) in enumerate(vpart.blocks()):
        b.issue(kind=OpKind.H2D, tag=f"S(b[{idx}])", stream=0,
                records=b.event(f"rB[{idx}]"),
                buffers_written=(("B", 0),), bytes=part.K * cn * bpe,
                payload={"operand": "B", "j": j, "cs": cs, "cn": cn})
        b.issue(kind=OpKind.H2D, tag=f"S(a[{idx}])", stream=0,
                records=b.event(f"rA[{idx}]"),
                buffers_written=(("A", 0),), bytes=rn * part.K * bpe,
                payload={"operand": "A", "i": i, "rs": rs, "rn": rn})
        b.issue(kind=OpKind.H2D, tag=f"S(c[{idx}])", stream=0,
                records=b.event(f"rC[{idx}]"),
                buffers_written=(("C", 0),), bytes=rn * cn * bpe,
                payload={"operand": "C", "i": i, "j": j,
                         "rs": rs, "rn": rn, "cs": cs, "cn": cn})
        b.issue(kind=OpKind.COMPUTE, tag=f"DGEMM[{idx}]", stream=0,
                waits=(b.event(f"rA[{idx}]"), b.event(f"rB[{idx}]"),
                       b.event(f"rC[{idx}]")),
                records=b.event(f"eA[{idx}]"),
                buffers_read=(("A", 0), ("B", 0)),
                buffers_written=(("C", 0),),
                flops=2 * rn * cn * part.K + 3 * rn * cn,
                payload={"idx": idx, "i": i, "j": j,
                         "rs": rs, "rn": rn, "cs": cs, "cn": cn})
        b.issue(kind=OpKind.D2H, tag=f"R(c[{idx}])", stream=0,
                waits=(b.event(f"eA[{idx}]"),),
                records=b.event(f"wC[{idx}]"),
                buffers_read=(("C", 0),), bytes=rn * cn * bpe,
                payload={"operand": "C", "i": i, "j": j,
                         "rs": rs, "rn": rn, "cs": cs, "cn": cn})
    return b.sched


def schedule_stats(sched: Schedule) -> dict:
    """Summary counters used by benchmarks and EXPERIMENTS.md."""
    return {
        "n_ops": len(sched.ops),
        "n_streams": len(sched.streams),
        "h2d_bytes": sched.total_bytes(OpKind.H2D),
        "d2h_bytes": sched.total_bytes(OpKind.D2H),
        "flops": sched.total_flops(),
        "n_events": sum(1 for o in sched.ops if o.records is not None),
    }
