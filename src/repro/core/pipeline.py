"""PipelineSpec DSL — libhclooc's Fig. 2 program, generated from a spec.

The paper hand-writes a ~55-line event/stream program for out-of-core GEMM and
notes (§V) that "this synchronization pattern is common and can be reused for
out-of-core implementations of other data-parallel kernels", proposing a DSL
as future work.  :class:`PipelineSpec` is that DSL: a declarative kernel
description — which operand classes stream through device buffers, which
blocks each pipeline step consumes, what the compute op is and whether it
carries state between steps, and how results are written back — that
:func:`compile_pipeline` turns into an event-correct multi-stream
:class:`~repro.core.streams.Schedule`.

Three kernels ship as specs (DESIGN.md §4):

  * :func:`gemm_pipeline_spec`      — the paper's MMOOC pipeline
    ``S(b_j) S(a_i) S(c_ij) DGEMM R(c_ij)`` with round-robin streams and the
    five event sets (rA, rB, rC, eA, wC).
  * :func:`attention_pipeline_spec` — out-of-core attention over a blocked KV
    cache (beyond paper): same stage graph with an online-softmax carry
    instead of a beta-accumulate and one final write-back.
  * :func:`syrk_pipeline_spec`      — the blocked-Cholesky trailing update
    ``C <- alpha * P @ P^T + beta * C``: the *same* compute handler as GEMM
    with the panel streamed twice (row slices and transposed column slices),
    proving the reuse claim end-to-end.

Schedules are *backend-neutral*: the simulator times them under a hardware
model; :class:`~repro.core.runtime.ScheduleExecutor` runs them with real JAX
ops.  One schedule object drives simulation, host execution, and stats.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.partitioner import AttentionPartition, GemmPartition
from repro.core.streams import (
    BlockRef,
    Device,
    Event,
    Op,
    OpKind,
    Schedule,
    SliceRef,
    StreamFactory,
)


# ===========================================================================
# The spec
# ===========================================================================
@dataclasses.dataclass(frozen=True)
class StreamedOperand:
    """One operand class streamed through parity device buffers.

    Attributes:
      name: buffer-class name — keys the device buffers and transfer tags
            (``S(a[..])``); may differ from the host array the slices come
            from (``slice_of``'s ``SliceRef.operand``), e.g. SYRK streams the
            same panel as two operand classes.
      nblocks: distinct blocks of this operand over the whole pipeline.
      block_of: step -> block id this step consumes.  Blocks must be consumed
            in non-decreasing contiguous runs (the paper's column-major order)
            so each block transfers exactly once.
      slice_of: block id -> typed host-slice payload for the H2D op.
      bytes_of: block id -> transfer size (drives the simulator's bandwidth
            model).
      nbuf: device buffers for this class (None = the pipeline's ``nbuf``).
            GEMM's B slice is a 2-deep ping-pong regardless of pipeline depth.
      inout: read-modify-write operand (GEMM's C): its transfer must wait for
            the previous occupant's *write-back*, not just its last read.
    """

    name: str
    nblocks: int
    block_of: Callable[[int], int]
    slice_of: Callable[[int], SliceRef]
    bytes_of: Callable[[int], int]
    nbuf: Optional[int] = None
    inout: bool = False


@dataclasses.dataclass(frozen=True)
class ComputeStage:
    """The per-step compute op.

    ``kernel`` keys the executor's handler registry; ``reads`` names the
    operand classes whose parity buffers are passed to the handler *in this
    order* (the positional contract with
    :func:`~repro.core.runtime.register_op_handler` handlers).  ``carry``
    declares a resident accumulator read+written every step, which serializes
    compute across streams (online-softmax state).
    """

    kernel: str
    reads: Tuple[str, ...]
    flops_of: Callable[[int], int]
    carry: bool = False
    tag: Optional[str] = None          # defaults to kernel.upper()
    event: str = "e"                   # compute event name prefix


@dataclasses.dataclass(frozen=True)
class WriteBack:
    """Write-back policy.

    mode:
      * "each"  — D2H the inout ``operand``'s block after every step (MMOOC).
      * "keep"  — no transfer; a zero-flop release op recycles the buffer
                  (SUMMA ``nsteps`` mode: C stays resident).
      * "final" — one D2H at the end dispatching the ``kernel`` finalize
                  handler (attention's normalize-and-emit).
    """

    mode: str
    operand: Optional[str] = None      # inout class ("each"/"keep")
    kernel: Optional[str] = None       # finalize handler key ("final")
    out: Optional[str] = None          # host output name ("final")
    bytes: int = 0                     # final transfer size


@dataclasses.dataclass(frozen=True)
class PipelineSpec:
    """Declarative out-of-core kernel: operands x compute x write-back.

    ``compile_pipeline`` is the only consumer; everything a backend needs at
    execution time rides on the generated ops as typed payloads.
    """

    name: str
    nsteps: int
    operands: Tuple[StreamedOperand, ...]
    compute: ComputeStage
    writeback: WriteBack
    budget: int = 0

    def operand(self, name: str) -> StreamedOperand:
        for x in self.operands:
            if x.name == name:
                return x
        raise KeyError(name)


# ===========================================================================
# Spec -> Schedule compiler
# ===========================================================================
class BlockPipelineBuilder:
    """Low-level emitter for the paper's round-robin / parity-buffer shape.

    Semantics (faithful to libhclooc §V):
      * ``nbuf`` on-device buffers per streamed operand class; block ``idx``
        occupies parity ``idx % nbuf``.
      * compute for block ``idx`` runs on stream ``idx % nstreams``; the
        prefetch of block ``idx+1`` runs concurrently on stream
        ``(idx+1) % nstreams`` (the paper's ``idx1``/``idx2`` round robin).
      * before a transfer overwrites a parity buffer, it waits on the events
        proving the previous occupant's last consumers finished — the paper's
        ``hclWaitEvent(eA[idx-1])`` / ``eC[idx-1]`` lines.
      * ``nstreams = 1`` degenerates to the fully serial Phi-style pipeline
        (claim C5): program order supplies every dependency.
    """

    def __init__(self, device: Device, nstreams: int, nbuf: int):
        if nbuf < 1 or nstreams < 1:
            raise ValueError("nbuf and nstreams must be >= 1")
        self.nbuf = nbuf
        self.nstreams = nstreams
        self.sched = Schedule(device, StreamFactory.create(device, nstreams))
        self._events: Dict[str, Event] = {}

    def event(self, name: str) -> Event:
        return self._events.setdefault(name, Event(name))

    def compute_stream(self, idx: int) -> int:
        return idx % self.nstreams

    def transfer_stream(self, idx: int) -> int:
        # Transfers overlapping compute of block idx-1 share that block's
        # "other" stream; with one stream everything serializes.
        return idx % self.nstreams

    def issue(self, **kw) -> Op:
        return self.sched.issue(Op(**kw))


def compile_pipeline(
    spec: PipelineSpec,
    nstreams: int = 2,
    nbuf: int = 2,
    device: Optional[Device] = None,
) -> Schedule:
    """Compile ``spec`` into an event-correct multi-stream Schedule.

    Event wiring, generalizing the paper's five event sets:

      * transfer of operand X block ``b`` records ``rX[b]`` and waits on the
        release events of block ``b - nbuf_X`` (the parity buffer's previous
        occupant): its write-back event if X is inout, else the compute
        events of its last ``min(max(nbuf, nstreams), consumers)`` consuming
        steps — enough to cover every stream the consumers ran on.
      * compute at step ``s`` waits every operand's ``r`` event (plus the
        previous step's compute event when a carry serializes the stage),
        and records ``e[s]``.
      * write-back per policy: D2H after each step ("each"), a zero-flop
        buffer release ("keep"), or one finalize D2H at the end ("final").
    """
    dev = device or Device("HBM", 0, spec.budget)
    b = BlockPipelineBuilder(dev, nstreams, nbuf)
    ev = spec.compute.event
    ctag = spec.compute.tag or spec.compute.kernel.upper()
    wb = spec.writeback

    # consuming steps per (operand, block): release points for buffer reuse.
    consumers: Dict[Tuple[str, int], List[int]] = {}
    for s in range(spec.nsteps):
        for x in spec.operands:
            consumers.setdefault((x.name, x.block_of(s)), []).append(s)

    def release_waits(x: StreamedOperand, evicted: int) -> Tuple[Event, ...]:
        if evicted < 0 or (x.name, evicted) not in consumers:
            return ()
        steps = consumers[(x.name, evicted)]
        if x.inout:
            return tuple(b.event(f"w{x.name}[{s}]") for s in steps)
        # the last min(max(nbuf, nstreams), len) consumers cover every stream
        # consecutive consuming steps were round-robined onto.
        k = min(max(nbuf, nstreams), len(steps))
        return tuple(b.event(f"{ev}[{s}]") for s in steps[-k:])

    for s in range(spec.nsteps):
        s_cur = b.compute_stream(s)
        s_xfer = b.transfer_stream(s)

        # -- H2D: bring in each operand block the moment the step needs it
        for x in spec.operands:
            blk = x.block_of(s)
            if s > 0 and x.block_of(s - 1) == blk:
                continue  # resident from a previous step (column reuse)
            xn = x.nbuf or nbuf
            b.issue(
                kind=OpKind.H2D, tag=f"S({x.name.lower()}[{blk}])",
                stream=s_xfer,
                waits=release_waits(x, blk - xn),
                records=b.event(f"r{x.name}[{blk}]"),
                buffers_written=((x.name, blk % xn),),
                bytes=x.bytes_of(blk),
                payload=x.slice_of(blk),
            )

        # -- COMPUTE: positional buffers per the stage's `reads` contract
        reads = []
        waits = []
        for name in spec.compute.reads:
            x = spec.operand(name)
            blk = x.block_of(s)
            reads.append((name, blk % (x.nbuf or nbuf)))
            waits.append(b.event(f"r{name}[{blk}]"))
        writes = []
        if wb.operand is not None:
            x = spec.operand(wb.operand)
            blk = x.block_of(s)
            writes.append((wb.operand, blk % (x.nbuf or nbuf)))
            waits.append(b.event(f"r{wb.operand}[{blk}]"))
        if spec.compute.carry:
            reads.append("carry")
            writes.append("carry")
            if s > 0:
                waits.append(b.event(f"{ev}[{s - 1}]"))
        b.issue(
            kind=OpKind.COMPUTE, tag=f"{ctag}[{s}]", stream=s_cur,
            waits=tuple(waits), records=b.event(f"{ev}[{s}]"),
            buffers_read=tuple(reads), buffers_written=tuple(writes),
            flops=spec.compute.flops_of(s),
            payload=BlockRef(kernel=spec.compute.kernel, index=s),
        )

        # -- write-back
        if wb.mode == "each":
            x = spec.operand(wb.operand)
            blk = x.block_of(s)
            b.issue(
                kind=OpKind.D2H, tag=f"R({wb.operand.lower()}[{s}])",
                stream=s_cur,
                waits=(b.event(f"{ev}[{s}]"),),
                records=b.event(f"w{wb.operand}[{s}]"),
                buffers_read=((wb.operand, blk % (x.nbuf or nbuf)),),
                bytes=x.bytes_of(blk),
                payload=x.slice_of(blk),
            )
        elif wb.mode == "keep":  # resident C (SUMMA mode); buffer recycles
            x = spec.operand(wb.operand)
            blk = x.block_of(s)
            b.issue(
                kind=OpKind.COMPUTE, tag=f"keep({wb.operand.lower()}[{s}])",
                stream=s_cur,
                waits=(b.event(f"{ev}[{s}]"),),
                records=b.event(f"w{wb.operand}[{s}]"),
                buffers_read=((wb.operand, blk % (x.nbuf or nbuf)),),
                flops=0,
                payload=BlockRef(kernel="noop", index=s),
            )

    if wb.mode == "final":
        b.issue(
            kind=OpKind.D2H, tag=f"R({wb.out})", stream=0,
            waits=(b.event(f"{ev}[{spec.nsteps - 1}]"),),
            records=b.event("done"),
            buffers_read=("carry",),
            bytes=wb.bytes,
            payload=BlockRef(kernel=wb.kernel, index=spec.nsteps - 1),
        )
    return b.sched


# ===========================================================================
# Kernel specs
# ===========================================================================
def _block_accessors(part: GemmPartition):
    """(rows, cols, flops) accessors over ``part.blocks()`` in issue order —
    the one place that knows the block-tuple layout and the DGEMM flop model
    (multiply-add on the K panel plus the alpha/beta epilogue)."""
    blocks = list(part.blocks())

    def rows(idx):
        return blocks[idx][2], blocks[idx][3]

    def cols(idx):
        return blocks[idx][4], blocks[idx][5]

    def flops(idx):
        rn, cn = rows(idx)[1], cols(idx)[1]
        return 2 * rn * cn * part.K + 3 * rn * cn

    return rows, cols, flops


def gemm_pipeline_spec(part: GemmPartition,
                       write_back: bool = True) -> PipelineSpec:
    """The paper's MMOOC pipeline as a spec.

    Stage set per C block (i, j), idx = j*h + i (column-major so each B slice
    transfers once per column):

      S(b_j)   H2D   once per column j           -> records rB[j]
      S(a_i)   H2D   once per block              -> records rA[idx]
      S(c_ij)  H2D   once per block              -> records rC[idx]
      DGEMM    COMP  waits rA,rB,rC              -> records eA[idx]
      R(c_ij)  D2H   same stream as DGEMM        -> records wC[idx]
    """
    bpe = part.bytes_per_el
    rows, cols, flops = _block_accessors(part)

    a = StreamedOperand(
        name="A", nblocks=part.nblocks, block_of=lambda s: s,
        slice_of=lambda blk: SliceRef("A", blk, rows=rows(blk)),
        bytes_of=lambda blk: rows(blk)[1] * part.K * bpe,
    )
    bb = StreamedOperand(
        name="B", nblocks=part.w, block_of=lambda s: s // part.h,
        slice_of=lambda j: SliceRef("B", j, cols=part.block_cols(j)),
        bytes_of=lambda j: part.K * part.block_cols(j)[1] * bpe,
        nbuf=2,  # ping-pong regardless of pipeline depth (paper Fig. 2)
    )
    c = StreamedOperand(
        name="C", nblocks=part.nblocks, block_of=lambda s: s,
        slice_of=lambda blk: SliceRef("C", blk, rows=rows(blk),
                                      cols=cols(blk)),
        bytes_of=lambda blk: rows(blk)[1] * cols(blk)[1] * bpe,
        inout=True,
    )
    return PipelineSpec(
        name="gemm",
        nsteps=part.nblocks,
        operands=(bb, a, c),  # issue order: S(b) S(a) S(c), as in Fig. 2
        compute=ComputeStage(
            kernel="dgemm", reads=("A", "B"), tag="DGEMM", event="eA",
            flops_of=flops,
        ),
        writeback=WriteBack(mode="each" if write_back else "keep",
                            operand="C"),
        budget=part.budget,
    )


def attention_pipeline_spec(
    part: AttentionPartition,
    kv_heads: int,
    head_dim: int,
    q_heads: int,
) -> PipelineSpec:
    """OOC attention: stream KV blocks, accumulate online-softmax partials.

    Demonstrates the paper's claim that the MMOOC synchronization pattern is
    reusable for other data-parallel kernels: the stage graph is identical —
    only the compute op (ATTN with (m, l, acc) carry) and the absence of a
    per-block write-back (one final merge instead) differ.
    """
    bpe = part.bytes_per_el
    blk_bytes = part.bs * kv_heads * head_dim * bpe

    def kv_rows(blk):
        lo = blk * part.bs
        return lo, min(part.S, (blk + 1) * part.bs) - lo

    def operand(name):
        return StreamedOperand(
            name=name, nblocks=part.nblocks, block_of=lambda s: s,
            slice_of=lambda blk: SliceRef(name, blk, rows=kv_rows(blk)),
            bytes_of=lambda blk: blk_bytes,
        )

    return PipelineSpec(
        name="attention",
        nsteps=part.nblocks,
        operands=(operand("K"), operand("V")),
        compute=ComputeStage(
            kernel="attn", reads=("K", "V"), tag="ATTN", event="eKV",
            carry=True,
            flops_of=lambda s: 2 * q_heads * part.bs * head_dim * 2,
        ),
        writeback=WriteBack(mode="final", kernel="attn_out", out="out",
                            bytes=q_heads * head_dim * bpe),
        budget=part.budget,
    )


def syrk_pipeline_spec(part: GemmPartition,
                       alpha_tag: str = "P",
                       pt_source: Optional[str] = None) -> PipelineSpec:
    """Blocked SYRK ``C <- alpha * P @ P^T + beta * C`` as a spec.

    The Cholesky trailing update, first-class: the same ``dgemm`` handler as
    MMOOC consumes the panel twice — row slices (``Pr``, the A role) and
    transposed row slices (``Pt``, the B role) — with no host-side ``P.T``
    materialization.  ``part`` partitions the symmetric C (M = N = trailing
    dim, K = panel width).

    ``pt_source`` names a *separate* host operand the transposed slices
    stream from (default: the same ``alpha_tag`` array).  The hybrid
    co-scheduler uses this for row-band SYRK: each device's ``Pr`` reads its
    band of the panel while ``Pt`` still spans every row of the full panel,
    so the band operand and the full panel must be distinct host arrays.
    """
    bpe = part.bytes_per_el
    rows, cols, flops = _block_accessors(part)
    pt_src = pt_source or alpha_tag

    pr = StreamedOperand(
        name="Pr", nblocks=part.nblocks, block_of=lambda s: s,
        slice_of=lambda blk: SliceRef(alpha_tag, blk, rows=rows(blk)),
        bytes_of=lambda blk: rows(blk)[1] * part.K * bpe,
    )
    pt = StreamedOperand(
        name="Pt", nblocks=part.w, block_of=lambda s: s // part.h,
        slice_of=lambda j: SliceRef(pt_src, j, rows=part.block_cols(j),
                                    transpose=True),
        bytes_of=lambda j: part.block_cols(j)[1] * part.K * bpe,
        nbuf=2,
    )
    c = StreamedOperand(
        name="C", nblocks=part.nblocks, block_of=lambda s: s,
        slice_of=lambda blk: SliceRef("C", blk, rows=rows(blk),
                                      cols=cols(blk)),
        bytes_of=lambda blk: rows(blk)[1] * cols(blk)[1] * bpe,
        inout=True,
    )
    return PipelineSpec(
        name="syrk",
        nsteps=part.nblocks,
        operands=(pt, pr, c),
        compute=ComputeStage(
            kernel="dgemm", reads=("Pr", "Pt"), tag="SYRK", event="eP",
            flops_of=flops,
        ),
        writeback=WriteBack(mode="each", operand="C"),
        budget=part.budget,
    )


def vendor_pipeline_spec(part: GemmPartition, tile: int = 512) -> PipelineSpec:
    """CUBLAS-XT-style baseline spec (the paper's C3 comparison point).

    CUBLAS-XT tiles C into fixed square blocks (default ~4k) and, per tile,
    synchronously streams the corresponding A-row and B-column *panels* —
    i.e. B panels are re-sent for every row of tiles (no column reuse) and
    nothing overlaps.  The spec models exactly that: per-step B blocks (every
    step re-transfers its panel), single buffers, compiled with one stream.
    """
    bpe = part.bytes_per_el
    vpart = GemmPartition(
        part.M, part.N, part.K,
        (part.M + tile - 1) // tile, (part.N + tile - 1) // tile,
        min(tile, part.M), min(tile, part.N), bpe, part.budget)
    rows, cols, flops = _block_accessors(vpart)

    a = StreamedOperand(
        name="A", nblocks=vpart.nblocks, block_of=lambda s: s,
        slice_of=lambda blk: SliceRef("A", blk, rows=rows(blk)),
        bytes_of=lambda blk: rows(blk)[1] * part.K * bpe,
        nbuf=1,
    )
    bb = StreamedOperand(  # re-sent per C tile: block id == step (no reuse)
        name="B", nblocks=vpart.nblocks, block_of=lambda s: s,
        slice_of=lambda blk: SliceRef("B", blk, cols=cols(blk)),
        bytes_of=lambda blk: part.K * cols(blk)[1] * bpe,
        nbuf=1,
    )
    c = StreamedOperand(
        name="C", nblocks=vpart.nblocks, block_of=lambda s: s,
        slice_of=lambda blk: SliceRef("C", blk, rows=rows(blk),
                                      cols=cols(blk)),
        bytes_of=lambda blk: rows(blk)[1] * cols(blk)[1] * bpe,
        nbuf=1, inout=True,
    )
    return PipelineSpec(
        name="vendor",
        nsteps=vpart.nblocks,
        operands=(bb, a, c),
        compute=ComputeStage(
            kernel="dgemm", reads=("A", "B"), tag="DGEMM", event="eA",
            flops_of=flops,
        ),
        writeback=WriteBack(mode="each", operand="C"),
        budget=part.budget,
    )


# ===========================================================================
# Builders (spec wrappers — the pre-DSL public surface)
# ===========================================================================
def build_gemm_schedule(
    part: GemmPartition,
    nstreams: int = 2,
    nbuf: int = 2,
    write_back: bool = True,
    device: Optional[Device] = None,
) -> Schedule:
    """Emit the MMOOC schedule of libhclooc Fig. 2 for ``part``."""
    return compile_pipeline(gemm_pipeline_spec(part, write_back=write_back),
                            nstreams=nstreams, nbuf=nbuf, device=device)


def build_attention_schedule(
    part: AttentionPartition,
    kv_heads: int,
    head_dim: int,
    q_heads: int,
    nstreams: int = 2,
    nbuf: int = 2,
    device: Optional[Device] = None,
) -> Schedule:
    """OOC attention schedule: KV blocks + online-softmax carry."""
    spec = attention_pipeline_spec(part, kv_heads, head_dim, q_heads)
    return compile_pipeline(spec, nstreams=nstreams, nbuf=nbuf, device=device)


def build_syrk_schedule(
    part: GemmPartition,
    nstreams: int = 2,
    nbuf: int = 2,
    device: Optional[Device] = None,
) -> Schedule:
    """Blocked SYRK schedule (Cholesky trailing update)."""
    return compile_pipeline(syrk_pipeline_spec(part),
                            nstreams=nstreams, nbuf=nbuf, device=device)


def build_vendor_schedule(
    part: GemmPartition,
    device: Optional[Device] = None,
    tile: int = 512,
) -> Schedule:
    """CUBLAS-XT-style baseline: one stream, B re-sent per tile, no overlap."""
    return compile_pipeline(vendor_pipeline_spec(part, tile=tile),
                            nstreams=1, nbuf=1, device=device)


def schedule_stats(sched: Schedule) -> dict:
    """Summary counters used by benchmarks and EXPERIMENTS.md."""
    return {
        "n_ops": len(sched.ops),
        "n_streams": len(sched.streams),
        "h2d_bytes": sched.total_bytes(OpKind.H2D),
        "d2h_bytes": sched.total_bytes(OpKind.D2H),
        "flops": sched.total_flops(),
        "n_events": sum(1 for o in sched.ops if o.records is not None),
    }
