"""PipelineSpec DSL — libhclooc's Fig. 2 program, generated from a spec.

The paper hand-writes a ~55-line event/stream program for out-of-core GEMM and
notes (§V) that "this synchronization pattern is common and can be reused for
out-of-core implementations of other data-parallel kernels", proposing a DSL
as future work.  :class:`PipelineSpec` is that DSL: a declarative kernel
description — which operand classes stream through device buffers, which
blocks each pipeline step consumes, what the compute op is and whether it
carries state between steps, and how results are written back — that
:func:`compile_pipeline` turns into an event-correct multi-stream
:class:`~repro.core.streams.Schedule`.

Three kernels ship as specs (DESIGN.md §4):

  * :func:`gemm_pipeline_spec`      — the paper's MMOOC pipeline
    ``S(b_j) S(a_i) S(c_ij) DGEMM R(c_ij)`` with round-robin streams and the
    five event sets (rA, rB, rC, eA, wC).
  * :func:`attention_pipeline_spec` — out-of-core attention over a blocked KV
    cache (beyond paper): same stage graph with an online-softmax carry
    instead of a beta-accumulate and one final write-back.
  * :func:`syrk_pipeline_spec`      — the blocked-Cholesky trailing update
    ``C <- alpha * P @ P^T + beta * C``: the *same* compute handler as GEMM
    with the panel streamed twice (row slices and transposed column slices),
    proving the reuse claim end-to-end.

Schedules are *backend-neutral*: the simulator times them under a hardware
model; :class:`~repro.core.runtime.ScheduleExecutor` runs them with real JAX
ops.  One schedule object drives simulation, host execution, and stats.
"""

from __future__ import annotations

import dataclasses
import math
from typing import (Callable, Dict, Hashable, Iterable, List, Optional,
                    Tuple)

from repro.core.partitioner import (AttentionPartition, GemmPartition,
                                    traversal_order)
from repro.core.streams import (
    BlockRef,
    Device,
    Event,
    Op,
    OpKind,
    Schedule,
    SliceRef,
    StreamFactory,
)


# ===========================================================================
# The spec
# ===========================================================================
@dataclasses.dataclass(frozen=True)
class StreamedOperand:
    """One operand class streamed through parity device buffers.

    Attributes:
      name: buffer-class name — keys the device buffers and transfer tags
            (``S(a[..])``); may differ from the host array the slices come
            from (``slice_of``'s ``SliceRef.operand``), e.g. SYRK streams the
            same panel as two operand classes.
      nblocks: distinct blocks of this operand over the whole pipeline.
      block_of: step -> block id this step consumes.  Blocks must be consumed
            in non-decreasing contiguous runs (the paper's column-major order)
            so each block transfers exactly once.
      slice_of: block id -> typed host-slice payload for the H2D op.
      bytes_of: block id -> transfer size (drives the simulator's bandwidth
            model).
      nbuf: device buffers for this class (None = the pipeline's ``nbuf``).
            GEMM's B slice is a 2-deep ping-pong regardless of pipeline depth.
      inout: read-modify-write operand (GEMM's C): its transfer must wait for
            the previous occupant's *write-back*, not just its last read.
    """

    name: str
    nblocks: int
    block_of: Callable[[int], int]
    slice_of: Callable[[int], SliceRef]
    bytes_of: Callable[[int], int]
    nbuf: Optional[int] = None
    inout: bool = False


@dataclasses.dataclass(frozen=True)
class ComputeStage:
    """The per-step compute op.

    ``kernel`` keys the executor's handler registry; ``reads`` names the
    operand classes whose parity buffers are passed to the handler *in this
    order* (the positional contract with
    :func:`~repro.core.runtime.register_op_handler` handlers).  ``carry``
    declares a resident accumulator read+written every step, which serializes
    compute across streams (online-softmax state).
    """

    kernel: str
    reads: Tuple[str, ...]
    flops_of: Callable[[int], int]
    carry: bool = False
    tag: Optional[str] = None          # defaults to kernel.upper()
    event: str = "e"                   # compute event name prefix


@dataclasses.dataclass(frozen=True)
class WriteBack:
    """Write-back policy.

    mode:
      * "each"  — D2H the inout ``operand``'s block after every step (MMOOC).
      * "keep"  — no transfer; a zero-flop release op recycles the buffer
                  (SUMMA ``nsteps`` mode: C stays resident).
      * "final" — one D2H at the end dispatching the ``kernel`` finalize
                  handler (attention's normalize-and-emit).
    """

    mode: str
    operand: Optional[str] = None      # inout class ("each"/"keep")
    kernel: Optional[str] = None       # finalize handler key ("final")
    out: Optional[str] = None          # host output name ("final")
    bytes: int = 0                     # final transfer size


@dataclasses.dataclass(frozen=True)
class PipelineSpec:
    """Declarative out-of-core kernel: operands x compute x write-back.

    ``compile_pipeline`` is the only consumer; everything a backend needs at
    execution time rides on the generated ops as typed payloads.
    """

    name: str
    nsteps: int
    operands: Tuple[StreamedOperand, ...]
    compute: ComputeStage
    writeback: WriteBack
    budget: int = 0
    traversal: str = "col"  # step order over the block grid (reporting only)

    def operand(self, name: str) -> StreamedOperand:
        for x in self.operands:
            if x.name == name:
                return x
        raise KeyError(name)


# ===========================================================================
# Spec -> Schedule compiler
# ===========================================================================
EVICT_POLICIES = ("lru", "belady")


class BlockCache:
    """Compile-time model of one operand class's device-resident blocks.

    Generalizes the paper's parity-buffer rule (block ``idx`` lives in buffer
    ``idx % nbuf``, evicting ``idx - nbuf``) to true residency tracking: a
    block stays usable in its slot until capacity forces replacement, so any
    later step that consumes it again skips its H2D entirely — not just the
    immediately following step.

    ``access`` is called once per (step, operand) in schedule order and
    returns hit/miss plus, on an evicting miss, the events proving the
    evicted occupant's last consumer on every stream has finished — the
    residency-aware generalization of ``hclWaitEvent(eA[idx-1])``.

    Policies: "lru" evicts the least-recently-used slot; "belady" evicts the
    slot whose next use lies furthest in the future (MIN).  Schedules are
    static, so the full access sequence — and hence the Belady oracle — is
    known exactly at compile time.
    """

    def __init__(self, name: str, capacity: int, policy: str,
                 accesses: List[Hashable]):
        if policy not in EVICT_POLICIES:
            raise ValueError(f"unknown eviction policy {policy!r}; "
                             f"expected one of {EVICT_POLICIES}")
        if capacity < 1:
            raise ValueError("cache capacity must be >= 1")
        self.name = name
        self.capacity = capacity
        self.policy = policy
        # next_use[t]: position of the next access to the same block after t
        # (inf if never again) — Belady's oracle, from one backward sweep.
        self.next_use: List[float] = [math.inf] * len(accesses)
        nxt: Dict[Hashable, int] = {}
        for t in range(len(accesses) - 1, -1, -1):
            self.next_use[t] = nxt.get(accesses[t], math.inf)
            nxt[accesses[t]] = t
        self.slots: List[Optional[dict]] = [None] * capacity
        self.where: Dict[Hashable, int] = {}
        self.hits = 0
        self.misses = 0
        self.bytes_moved = 0
        self.bytes_saved = 0

    def access(self, t: int, block: Hashable,
               nbytes: int) -> Tuple[int, bool, Tuple[Event, ...]]:
        """Process the access at sequence position ``t``.

        Returns ``(slot, hit, evict_waits)``; ``evict_waits`` is non-empty
        only when the miss replaces a live occupant.
        """
        if block in self.where:
            slot = self.where[block]
            entry = self.slots[slot]
            entry["last"] = t
            entry["next"] = self.next_use[t]
            self.hits += 1
            self.bytes_saved += nbytes
            return slot, True, ()
        self.misses += 1
        self.bytes_moved += nbytes
        waits: Tuple[Event, ...] = ()
        slot = next((i for i, e in enumerate(self.slots) if e is None), None)
        if slot is None:
            slot = self._victim()
            old = self.slots[slot]
            del self.where[old["block"]]
            waits = tuple(old["released"].values())
        self.slots[slot] = {"block": block, "last": t,
                            "next": self.next_use[t], "released": {},
                            "landing": None}
        self.where[block] = slot
        return slot, False, waits

    def _victim(self) -> int:
        if self.policy == "lru":
            return min(range(self.capacity),
                       key=lambda i: self.slots[i]["last"])
        # belady: furthest next use goes first (never-used-again = inf wins
        # immediately); ties break to the lowest slot for determinism
        return max(range(self.capacity),
                   key=lambda i: (self.slots[i]["next"], -i))

    def set_landing(self, block: Hashable, event: Event) -> None:
        """Remember the H2D completion event of ``block``'s current
        residency; later cache hits wait on it instead of a new transfer."""
        self.slots[self.where[block]]["landing"] = event

    def landing_event(self, block: Hashable) -> Event:
        return self.slots[self.where[block]]["landing"]

    def note_release(self, block: Hashable, stream: int,
                     event: Event) -> None:
        """Record the latest consumer event of ``block`` per stream.  An
        eviction waits on exactly these: earlier consumers on the same
        stream are covered by program order."""
        self.slots[self.where[block]]["released"][stream] = event

    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "bytes_moved": self.bytes_moved,
                "bytes_saved": self.bytes_saved}


class BlockPipelineBuilder:
    """Low-level emitter for the paper's round-robin / parity-buffer shape.

    Semantics (faithful to libhclooc §V):
      * ``nbuf`` on-device buffers per streamed operand class; block ``idx``
        occupies parity ``idx % nbuf``.
      * compute for block ``idx`` runs on stream ``idx % nstreams``; the
        prefetch of block ``idx+1`` runs concurrently on stream
        ``(idx+1) % nstreams`` (the paper's ``idx1``/``idx2`` round robin).
      * before a transfer overwrites a parity buffer, it waits on the events
        proving the previous occupant's last consumers finished — the paper's
        ``hclWaitEvent(eA[idx-1])`` / ``eC[idx-1]`` lines.
      * ``nstreams = 1`` degenerates to the fully serial Phi-style pipeline
        (claim C5): program order supplies every dependency.
    """

    def __init__(self, device: Device, nstreams: int, nbuf: int):
        if nbuf < 1 or nstreams < 1:
            raise ValueError("nbuf and nstreams must be >= 1")
        self.nbuf = nbuf
        self.nstreams = nstreams
        self.sched = Schedule(device, StreamFactory.create(device, nstreams))
        self._events: Dict[str, Event] = {}

    def event(self, name: str) -> Event:
        return self._events.setdefault(name, Event(name))

    def compute_stream(self, idx: int) -> int:
        return idx % self.nstreams

    def transfer_stream(self, idx: int) -> int:
        # Transfers overlapping compute of block idx-1 share that block's
        # "other" stream; with one stream everything serializes.
        return idx % self.nstreams

    def issue(self, **kw) -> Op:
        return self.sched.issue(Op(**kw))


def compile_pipeline(
    spec: PipelineSpec,
    nstreams: int = 2,
    nbuf: int = 2,
    device: Optional[Device] = None,
    evict: str = "lru",
) -> Schedule:
    """Compile ``spec`` into an event-correct multi-stream Schedule.

    Event wiring, generalizing the paper's five event sets:

      * each operand class owns a :class:`BlockCache` of its ``nbuf`` device
        buffers.  A step whose block is still resident emits *no* transfer —
        its compute waits on the original landing event; a miss emits an H2D
        recording ``rX[b]`` that waits on the release events of whichever
        block the cache evicts (write-back event for inout operands, last
        per-stream compute events otherwise).
      * compute at step ``s`` waits every operand's landing event (plus the
        previous step's compute event when a carry serializes the stage),
        and records ``e[s]``.
      * write-back per policy: D2H after each step ("each"), a zero-flop
        buffer release ("keep"), or one finalize D2H at the end ("final").

    ``evict`` selects the replacement policy ("lru" or "belady"); the
    per-class hit/miss/bytes counters land on ``Schedule.reuse`` and the
    chosen traversal/policy on ``Schedule.meta``.
    """
    dev = device or Device("HBM", 0, spec.budget)
    b = BlockPipelineBuilder(dev, nstreams, nbuf)
    ev = spec.compute.event
    ctag = spec.compute.tag or spec.compute.kernel.upper()
    wb = spec.writeback

    # one residency cache per operand class, primed with the full (static)
    # access sequence so the Belady oracle is exact
    caches: Dict[str, BlockCache] = {}
    incarnation: Dict[str, Dict[int, int]] = {}
    for x in spec.operands:
        caches[x.name] = BlockCache(
            x.name, x.nbuf or nbuf, evict,
            [x.block_of(s) for s in range(spec.nsteps)])
        incarnation[x.name] = {}

    slot_of: Dict[str, int] = {}
    for s in range(spec.nsteps):
        s_cur = b.compute_stream(s)
        s_xfer = b.transfer_stream(s)

        # -- H2D: bring in each operand block unless it is still resident
        for x in spec.operands:
            blk = x.block_of(s)
            cache = caches[x.name]
            slot, hit, evict_waits = cache.access(s, blk, x.bytes_of(blk))
            slot_of[x.name] = slot
            if hit:
                continue  # resident from an earlier step: no transfer
            # an evicted-then-refetched block needs a fresh event name (and
            # a distinct tag: spans and error messages key on tags)
            inc = incarnation[x.name].get(blk, 0)
            incarnation[x.name][blk] = inc + 1
            suffix = "" if inc == 0 else f"@{inc}"
            landing = b.event(f"r{x.name}[{blk}]{suffix}")
            cache.set_landing(blk, landing)
            b.issue(
                kind=OpKind.H2D,
                tag=f"S({x.name.lower()}[{blk}]){suffix}",
                stream=s_xfer,
                waits=evict_waits,
                records=landing,
                buffers_written=((x.name, slot),),
                bytes=x.bytes_of(blk),
                payload=x.slice_of(blk),
            )

        # -- COMPUTE: positional buffers per the stage's `reads` contract
        reads = []
        waits = []
        for name in spec.compute.reads:
            x = spec.operand(name)
            reads.append((name, slot_of[name]))
            waits.append(caches[name].landing_event(x.block_of(s)))
        writes = []
        if wb.operand is not None:
            x = spec.operand(wb.operand)
            writes.append((wb.operand, slot_of[wb.operand]))
            waits.append(caches[wb.operand].landing_event(x.block_of(s)))
        if spec.compute.carry:
            reads.append("carry")
            writes.append("carry")
            if s > 0:
                waits.append(b.event(f"{ev}[{s - 1}]"))
        b.issue(
            kind=OpKind.COMPUTE, tag=f"{ctag}[{s}]", stream=s_cur,
            waits=tuple(waits), records=b.event(f"{ev}[{s}]"),
            buffers_read=tuple(reads), buffers_written=tuple(writes),
            flops=spec.compute.flops_of(s),
            payload=BlockRef(kernel=spec.compute.kernel, index=s),
        )

        # -- write-back
        if wb.mode == "each":
            x = spec.operand(wb.operand)
            blk = x.block_of(s)
            b.issue(
                kind=OpKind.D2H, tag=f"R({wb.operand.lower()}[{s}])",
                stream=s_cur,
                waits=(b.event(f"{ev}[{s}]"),),
                records=b.event(f"w{wb.operand}[{s}]"),
                buffers_read=((wb.operand, slot_of[wb.operand]),),
                bytes=x.bytes_of(blk),
                payload=x.slice_of(blk),
            )
        elif wb.mode == "keep":  # resident C (SUMMA mode); buffer recycles
            b.issue(
                kind=OpKind.COMPUTE, tag=f"keep({wb.operand.lower()}[{s}])",
                stream=s_cur,
                waits=(b.event(f"{ev}[{s}]"),),
                records=b.event(f"w{wb.operand}[{s}]"),
                buffers_read=((wb.operand, slot_of[wb.operand]),),
                flops=0,
                payload=BlockRef(kernel="noop", index=s),
            )

        # -- release registration: the events an eviction must wait on
        for x in spec.operands:
            if x.name == wb.operand and wb.mode in ("each", "keep"):
                rel = b.event(f"w{wb.operand}[{s}]")
            else:
                rel = b.event(f"{ev}[{s}]")
            caches[x.name].note_release(x.block_of(s), s_cur, rel)

    if wb.mode == "final":
        b.issue(
            kind=OpKind.D2H, tag=f"R({wb.out})", stream=0,
            waits=(b.event(f"{ev}[{spec.nsteps - 1}]"),),
            records=b.event("done"),
            buffers_read=("carry",),
            bytes=wb.bytes,
            payload=BlockRef(kernel=wb.kernel, index=spec.nsteps - 1),
        )
    b.sched.meta = {"traversal": getattr(spec, "traversal", "col"),
                    "evict": evict, "kernel": spec.name}
    b.sched.reuse = {name: c.stats() for name, c in caches.items()}
    return b.sched


# ===========================================================================
# Kernel specs
# ===========================================================================
def _block_accessors(part: GemmPartition):
    """(rows, cols, flops) accessors over ``part.blocks()`` in issue order —
    the one place that knows the block-tuple layout and the DGEMM flop model
    (multiply-add on the K panel plus the alpha/beta epilogue)."""
    blocks = list(part.blocks())

    def rows(idx):
        return blocks[idx][2], blocks[idx][3]

    def cols(idx):
        return blocks[idx][4], blocks[idx][5]

    def flops(idx):
        rn, cn = rows(idx)[1], cols(idx)[1]
        return 2 * rn * cn * part.K + 3 * rn * cn

    return rows, cols, flops


def _gemm_identity_operands(part: GemmPartition, traversal: str,
                            band: Optional[int],
                            a_name: str, a_slice, a_bytes,
                            b_name: str, b_slice, b_bytes):
    """Shared GEMM/SYRK operand construction with *identity* block ids.

    The A role is keyed by block row ``i``, the B role by block column ``j``
    and C by the canonical block id ``j*h + i`` — so a step revisiting a row
    or column presents the *same* block id to the compiler's residency cache
    and its H2D is skipped whenever the block is still resident.  ``order``
    is the (i, j) step sequence produced by
    :func:`~repro.core.partitioner.traversal_order`.
    """
    bpe = part.bytes_per_el
    order = traversal_order(part.h, part.w, traversal, band=band)
    i_of = [ij[0] for ij in order]
    j_of = [ij[1] for ij in order]
    cid_of = [j * part.h + i for i, j in order]

    a = StreamedOperand(
        name=a_name, nblocks=part.h, block_of=lambda s: i_of[s],
        slice_of=a_slice, bytes_of=a_bytes,
    )
    bb = StreamedOperand(
        name=b_name, nblocks=part.w, block_of=lambda s: j_of[s],
        slice_of=b_slice, bytes_of=b_bytes,
        nbuf=2,  # ping-pong regardless of pipeline depth (paper Fig. 2)
    )
    c = StreamedOperand(
        name="C", nblocks=part.nblocks, block_of=lambda s: cid_of[s],
        slice_of=lambda cid: SliceRef(
            "C", cid, rows=part.block_rows(cid % part.h),
            cols=part.block_cols(cid // part.h)),
        bytes_of=lambda cid: part.block_rows(cid % part.h)[1]
        * part.block_cols(cid // part.h)[1] * bpe,
        inout=True,
    )

    def flops(s):
        rn = part.block_rows(i_of[s])[1]
        cn = part.block_cols(j_of[s])[1]
        return 2 * rn * cn * part.K + 3 * rn * cn

    return a, bb, c, flops


def gemm_pipeline_spec(part: GemmPartition,
                       write_back: bool = True,
                       traversal: str = "col",
                       band: Optional[int] = None,
                       reuse: bool = True) -> PipelineSpec:
    """The paper's MMOOC pipeline as a spec.

    Stage set per C block (i, j), idx = j*h + i (column-major so each B slice
    transfers once per column):

      S(b_j)   H2D   once per column j           -> records rB[j]
      S(a_i)   H2D   once per block              -> records rA[idx]
      S(c_ij)  H2D   once per block              -> records rC[idx]
      DGEMM    COMP  waits rA,rB,rC              -> records eA[idx]
      R(c_ij)  D2H   same stream as DGEMM        -> records wC[idx]

    With ``reuse=True`` (the default) the A/B/C operands carry *identity*
    block ids (row, column, canonical C id) so the compiler's residency
    cache can skip re-transfers across non-adjacent steps, and ``traversal``
    reorders the step sequence to shrink reuse distance (``band`` sizes the
    "blocked" traversal's row bands).  ``reuse=False`` reproduces the seed
    compiler's per-step ids — every A/C recurrence re-transfers — and is the
    naive baseline ``benchmarks/bench_reuse.py`` measures against.
    """
    bpe = part.bytes_per_el

    if reuse:
        a, bb, c, flops = _gemm_identity_operands(
            part, traversal, band,
            "A",
            lambda i: SliceRef("A", i, rows=part.block_rows(i)),
            lambda i: part.block_rows(i)[1] * part.K * bpe,
            "B",
            lambda j: SliceRef("B", j, cols=part.block_cols(j)),
            lambda j: part.K * part.block_cols(j)[1] * bpe,
        )
    else:
        if traversal != "col":
            raise ValueError(
                "reuse=False fixes the paper's column-major order "
                "(the naive baseline)")
        rows, cols, flops = _block_accessors(part)
        a = StreamedOperand(
            name="A", nblocks=part.nblocks, block_of=lambda s: s,
            slice_of=lambda blk: SliceRef("A", blk, rows=rows(blk)),
            bytes_of=lambda blk: rows(blk)[1] * part.K * bpe,
        )
        bb = StreamedOperand(
            name="B", nblocks=part.w, block_of=lambda s: s // part.h,
            slice_of=lambda j: SliceRef("B", j, cols=part.block_cols(j)),
            bytes_of=lambda j: part.K * part.block_cols(j)[1] * bpe,
            nbuf=2,
        )
        c = StreamedOperand(
            name="C", nblocks=part.nblocks, block_of=lambda s: s,
            slice_of=lambda blk: SliceRef("C", blk, rows=rows(blk),
                                          cols=cols(blk)),
            bytes_of=lambda blk: rows(blk)[1] * cols(blk)[1] * bpe,
            inout=True,
        )
    return PipelineSpec(
        name="gemm",
        nsteps=part.nblocks,
        operands=(bb, a, c),  # issue order: S(b) S(a) S(c), as in Fig. 2
        compute=ComputeStage(
            kernel="dgemm", reads=("A", "B"), tag="DGEMM", event="eA",
            flops_of=flops,
        ),
        writeback=WriteBack(mode="each" if write_back else "keep",
                            operand="C"),
        budget=part.budget,
        traversal=traversal,
    )


def attention_pipeline_spec(
    part: AttentionPartition,
    kv_heads: int,
    head_dim: int,
    q_heads: int,
) -> PipelineSpec:
    """OOC attention: stream KV blocks, accumulate online-softmax partials.

    Demonstrates the paper's claim that the MMOOC synchronization pattern is
    reusable for other data-parallel kernels: the stage graph is identical —
    only the compute op (ATTN with (m, l, acc) carry) and the absence of a
    per-block write-back (one final merge instead) differ.
    """
    bpe = part.bytes_per_el
    blk_bytes = part.bs * kv_heads * head_dim * bpe

    def kv_rows(blk):
        lo = blk * part.bs
        return lo, min(part.S, (blk + 1) * part.bs) - lo

    def operand(name):
        return StreamedOperand(
            name=name, nblocks=part.nblocks, block_of=lambda s: s,
            slice_of=lambda blk: SliceRef(name, blk, rows=kv_rows(blk)),
            bytes_of=lambda blk: blk_bytes,
        )

    return PipelineSpec(
        name="attention",
        nsteps=part.nblocks,
        operands=(operand("K"), operand("V")),
        compute=ComputeStage(
            kernel="attn", reads=("K", "V"), tag="ATTN", event="eKV",
            carry=True,
            flops_of=lambda s: 2 * q_heads * part.bs * head_dim * 2,
        ),
        writeback=WriteBack(mode="final", kernel="attn_out", out="out",
                            bytes=q_heads * head_dim * bpe),
        budget=part.budget,
    )


def syrk_pipeline_spec(part: GemmPartition,
                       alpha_tag: str = "P",
                       pt_source: Optional[str] = None,
                       traversal: str = "col",
                       band: Optional[int] = None,
                       reuse: bool = True) -> PipelineSpec:
    """Blocked SYRK ``C <- alpha * P @ P^T + beta * C`` as a spec.

    The Cholesky trailing update, first-class: the same ``dgemm`` handler as
    MMOOC consumes the panel twice — row slices (``Pr``, the A role) and
    transposed row slices (``Pt``, the B role) — with no host-side ``P.T``
    materialization.  ``part`` partitions the symmetric C (M = N = trailing
    dim, K = panel width).

    ``pt_source`` names a *separate* host operand the transposed slices
    stream from (default: the same ``alpha_tag`` array).  The hybrid
    co-scheduler uses this for row-band SYRK: each device's ``Pr`` reads its
    band of the panel while ``Pt`` still spans every row of the full panel,
    so the band operand and the full panel must be distinct host arrays.
    """
    bpe = part.bytes_per_el
    pt_src = pt_source or alpha_tag

    if reuse:
        pr, pt, c, flops = _gemm_identity_operands(
            part, traversal, band,
            "Pr",
            lambda i: SliceRef(alpha_tag, i, rows=part.block_rows(i)),
            lambda i: part.block_rows(i)[1] * part.K * bpe,
            "Pt",
            lambda j: SliceRef(pt_src, j, rows=part.block_cols(j),
                               transpose=True),
            lambda j: part.block_cols(j)[1] * part.K * bpe,
        )
    else:
        if traversal != "col":
            raise ValueError(
                "reuse=False fixes the paper's column-major order "
                "(the naive baseline)")
        rows, cols, flops = _block_accessors(part)
        pr = StreamedOperand(
            name="Pr", nblocks=part.nblocks, block_of=lambda s: s,
            slice_of=lambda blk: SliceRef(alpha_tag, blk, rows=rows(blk)),
            bytes_of=lambda blk: rows(blk)[1] * part.K * bpe,
        )
        pt = StreamedOperand(
            name="Pt", nblocks=part.w, block_of=lambda s: s // part.h,
            slice_of=lambda j: SliceRef(pt_src, j, rows=part.block_cols(j),
                                        transpose=True),
            bytes_of=lambda j: part.block_cols(j)[1] * part.K * bpe,
            nbuf=2,
        )
        c = StreamedOperand(
            name="C", nblocks=part.nblocks, block_of=lambda s: s,
            slice_of=lambda blk: SliceRef("C", blk, rows=rows(blk),
                                          cols=cols(blk)),
            bytes_of=lambda blk: rows(blk)[1] * cols(blk)[1] * bpe,
            inout=True,
        )
    return PipelineSpec(
        name="syrk",
        nsteps=part.nblocks,
        operands=(pt, pr, c),
        compute=ComputeStage(
            kernel="dgemm", reads=("Pr", "Pt"), tag="SYRK", event="eP",
            flops_of=flops,
        ),
        writeback=WriteBack(mode="each", operand="C"),
        budget=part.budget,
        traversal=traversal,
    )


def vendor_pipeline_spec(part: GemmPartition, tile: int = 512) -> PipelineSpec:
    """CUBLAS-XT-style baseline spec (the paper's C3 comparison point).

    CUBLAS-XT tiles C into fixed square blocks (default ~4k) and, per tile,
    synchronously streams the corresponding A-row and B-column *panels* —
    i.e. B panels are re-sent for every row of tiles (no column reuse) and
    nothing overlaps.  The spec models exactly that: per-step B blocks (every
    step re-transfers its panel), single buffers, compiled with one stream.
    """
    bpe = part.bytes_per_el
    vpart = GemmPartition(
        part.M, part.N, part.K,
        (part.M + tile - 1) // tile, (part.N + tile - 1) // tile,
        min(tile, part.M), min(tile, part.N), bpe, part.budget)
    rows, cols, flops = _block_accessors(vpart)

    a = StreamedOperand(
        name="A", nblocks=vpart.nblocks, block_of=lambda s: s,
        slice_of=lambda blk: SliceRef("A", blk, rows=rows(blk)),
        bytes_of=lambda blk: rows(blk)[1] * part.K * bpe,
        nbuf=1,
    )
    bb = StreamedOperand(  # re-sent per C tile: block id == step (no reuse)
        name="B", nblocks=vpart.nblocks, block_of=lambda s: s,
        slice_of=lambda blk: SliceRef("B", blk, cols=cols(blk)),
        bytes_of=lambda blk: part.K * cols(blk)[1] * bpe,
        nbuf=1,
    )
    c = StreamedOperand(
        name="C", nblocks=vpart.nblocks, block_of=lambda s: s,
        slice_of=lambda blk: SliceRef("C", blk, rows=rows(blk),
                                      cols=cols(blk)),
        bytes_of=lambda blk: rows(blk)[1] * cols(blk)[1] * bpe,
        nbuf=1, inout=True,
    )
    return PipelineSpec(
        name="vendor",
        nsteps=vpart.nblocks,
        operands=(bb, a, c),
        compute=ComputeStage(
            kernel="dgemm", reads=("A", "B"), tag="DGEMM", event="eA",
            flops_of=flops,
        ),
        writeback=WriteBack(mode="each", operand="C"),
        budget=part.budget,
    )


# ===========================================================================
# Factorization pipeline — the paper's §VII future work as one multi-kernel
# lookahead program (DESIGN.md §8)
# ===========================================================================
@dataclasses.dataclass(frozen=True)
class FactorPipelineSpec:
    """Blocked right-looking factorization (Cholesky or partial-pivot LU) as
    ONE multi-kernel pipeline.

    Unlike :class:`PipelineSpec` (a single homogeneous compute stage), a
    factorization interleaves *panel* ops — in-core POTRF/GETRF on a resident
    panel column, TRSM panel solves — with the streamed SYRK/GEMM trailing
    update of the shrinking sub-matrix.  ``compile_factor_pipeline`` turns
    this spec into one event-correct :class:`~repro.core.streams.Schedule`
    that the ordinary executor/simulator machinery consumes, so the whole
    factorization simulates, traces and executes like any other kernel.

    Attributes:
      kind: "cholesky" or "lu".
      n: matrix order (square, host-resident).
      panel: panel width (last panel may be narrower).
      bm, bn: trailing-update C block dims (shared across panels; per-panel
        grids are ``ceil(m_k/bm) x ceil(m_k/bn)`` over the shrinking
        trailing dim ``m_k``).
      lookahead: 0 factors panel ``k+1`` only after trailing update ``k``
        fully drained (the sequential per-panel loop); >= 1 issues panel
        ``k+1``'s transfer+factor as soon as the trailing blocks covering
        its columns are written back, overlapping the panel critical path
        with the remaining trailing stream.  Depths beyond 1 only add panel
        parity buffers (the data dependencies serialize deeper lookahead).
    """

    kind: str
    n: int
    panel: int
    bm: int
    bn: int
    bytes_per_el: int
    budget: int
    lookahead: int = 1

    @property
    def npanels(self) -> int:
        return max(1, math.ceil(self.n / self.panel))

    @property
    def npbuf(self) -> int:
        """Panel parity buffers: lookahead panels in flight plus the one
        being consumed."""
        return min(max(self.lookahead, 0), self.npanels - 1) + 1

    def panel_range(self, k: int) -> Tuple[int, int]:
        """(k0, k1) column/row extent of panel ``k``."""
        k0 = k * self.panel
        return k0, min(self.n, k0 + self.panel)

    def panel_bytes(self) -> int:
        """Resident bytes of the ``npbuf`` largest panel columns (plus, for
        LU, their U row panels) — the reserve charged against the budget
        before the trailing blocks are planned."""
        pw = min(self.panel, self.n)
        pnl = sum((self.n - i * pw) * pw
                  for i in range(self.npbuf) if i * pw < self.n)
        if self.kind == "lu":
            pnl += sum(pw * max(self.n - (i + 1) * pw, 0)
                       for i in range(self.npbuf))
        return pnl * self.bytes_per_el

    def working_set_bytes(self, nbuf: int = 2) -> int:
        """Worst-case resident bytes: :meth:`panel_bytes` plus the stage-0
        trailing SYRK/GEMM working set under the generalized ``nbuf``-aware
        model."""
        pw = min(self.panel, self.n)
        m0 = self.n - pw
        trail = 0
        if m0 > 0:
            part = GemmPartition(m0, m0, pw,
                                 math.ceil(m0 / self.bm),
                                 math.ceil(m0 / self.bn),
                                 self.bm, self.bn, self.bytes_per_el,
                                 self.budget)
            trail = part.working_set_bytes(nbuf, None)
        return self.panel_bytes() + trail


def factor_pipeline_spec(
    n: int,
    panel: int,
    budget_bytes: int,
    bytes_per_el: int = 4,
    *,
    kind: str = "cholesky",
    lookahead: int = 1,
    nbuf: int = 2,
    bm: Optional[int] = None,
    bn: Optional[int] = None,
) -> FactorPipelineSpec:
    """Plan a factorization pipeline that fits ``budget_bytes``.

    The panel buffers (and LU's U-row buffers) are charged against the
    budget first; the remainder sizes the trailing-update blocks through the
    ordinary partition planner on the *largest* trailing shape
    ``(n-panel) x (n-panel) x panel`` — later panels reuse the same block
    dims over shrinking grids.  Raises ValueError when even the minimum
    aligned configuration cannot fit (callers may degrade ``lookahead`` or
    ``panel`` and retry — :func:`repro.core.ooc_factor.ooc_cholesky` does).
    """
    if kind not in ("cholesky", "lu"):
        raise ValueError(f"unknown factor kind {kind!r}")
    if n <= 0 or panel <= 0:
        raise ValueError(f"bad factor shape n={n}, panel={panel}")
    pw = min(panel, n)
    probe = FactorPipelineSpec(kind, n, pw, bm or 1, bn or 1,
                               bytes_per_el, budget_bytes, lookahead)
    pnl_bytes = probe.working_set_bytes(nbuf) if n <= pw else None
    if n <= pw:  # single panel: no trailing update to plan
        if pnl_bytes > budget_bytes:
            raise ValueError(
                f"{kind} panel {n}x{pw} needs {pnl_bytes}B resident, "
                f"budget is {budget_bytes}B")
        return dataclasses.replace(probe, bm=pw, bn=pw)
    if bm is None or bn is None:
        reserve = probe.panel_bytes()
        remaining = budget_bytes - reserve
        if remaining <= 0:
            raise ValueError(
                f"{kind} lookahead={lookahead} panel buffers alone need "
                f"{reserve}B, budget is {budget_bytes}B")
        from repro.core.partitioner import plan_gemm_partition
        part = plan_gemm_partition(n - pw, n - pw, pw, remaining,
                                   bytes_per_el, nbuf=nbuf)
        bm, bn = part.bm, part.bn
    spec = FactorPipelineSpec(kind, n, pw, bm, bn, bytes_per_el,
                              budget_bytes, lookahead)
    need = spec.working_set_bytes(nbuf)
    if need > budget_bytes:
        raise ValueError(
            f"{kind} pipeline (panel={pw}, lookahead={lookahead}, "
            f"bm={bm}, bn={bn}) needs {need}B resident, budget is "
            f"{budget_bytes}B")
    return spec


def _stage_grid(o: int, m: int, bm: int, bn: int):
    """Trailing-stage block descriptors: (i, j, rows, cols) in global
    coordinates over the ``m x m`` trailing square at origin ``o``, in the
    paper's column-major order."""
    h = math.ceil(m / bm)
    w = math.ceil(m / bn)
    out = []
    for j in range(w):
        cs = o + j * bn
        cn = min(bn, o + m - cs)
        for i in range(h):
            rs = o + i * bm
            rn = min(bm, o + m - rs)
            out.append((i, j, (rs, rn), (cs, cn)))
    return out


def _hits(span: Tuple[int, int], lo: int, hi: int) -> bool:
    return span[0] < hi and lo < span[0] + span[1]


def _stage_split(spec: FactorPipelineSpec, k: int):
    """(prio, rest) trailing blocks of stage ``k`` under the lookahead
    policy — the single source of truth for trailing emission order, shared
    by the compiler's main loop and the residency pre-pass."""
    k0, k1 = spec.panel_range(k)
    if k1 >= spec.n:
        return [], []
    blocks = _stage_grid(k1, spec.n - k1, spec.bm, spec.bn)
    if spec.kind != "lu":
        # Cholesky is symmetric: nothing ever reads the strict upper
        # triangle (panels and multiplier slices are at-or-below the
        # diagonal, np.linalg.cholesky reads only the lower half, and
        # ooc_cholesky tril's the result), so blocks entirely above it are
        # dead work — skipping them halves the trailing flops and traffic.
        # Diagonal-crossing blocks stay whole.
        blocks = [blk for blk in blocks if blk[2][0] + blk[2][1] > blk[3][0]]
    if max(0, spec.lookahead) == 0 or k == spec.npanels - 1:
        return blocks, []
    nk0, nk1 = spec.panel_range(k + 1)
    # prio: the leading block column(s) — what the next panel factor reads.
    # Whole columns only, so each column's once-per-column Ft transfer stays
    # adjacent to all its consumers.  (LU's U row panel additionally needs
    # the first block *row*, but its chain is fenced behind the swap replay
    # — which waits on the whole stage — so prioritizing it buys nothing.)
    prio = [blk for blk in blocks if _hits(blk[3], nk0, nk1)]
    rest = [blk for blk in blocks if not _hits(blk[3], nk0, nk1)]
    return prio, rest


def _trailing_emission_order(spec: FactorPipelineSpec):
    """(stage, block) pairs in the exact order the compiler emits trailing
    blocks: each iteration drains the previous stage's deferred ``rest``
    before issuing stage ``k``'s ``prio``.  Feeds the Fr residency cache its
    full access sequence so the Belady oracle sees the true future."""
    out, rest, rest_stage = [], [], -1
    for k in range(spec.npanels):
        out.extend((rest_stage, blk) for blk in rest)
        prio, rest = _stage_split(spec, k)
        rest_stage = k
        out.extend((k, blk) for blk in prio)
    assert not rest, "internal: trailing blocks left unemitted"
    return out


def compile_factor_pipeline(
    spec: FactorPipelineSpec,
    nstreams: int = 2,
    nbuf: int = 2,
    device: Optional[Device] = None,
    evict: str = "lru",
) -> Schedule:
    """Compile a factorization spec into one event-correct Schedule.

    Program shape per panel ``k`` (all operands slice the single host
    matrix ``A``; trailing updates run the ordinary ``dgemm`` handler with
    ``ctx = {alpha: -1, beta: 1}``):

      Cholesky: ``S(pnl) POTRF TRSM R(pnl)`` then stream the SYRK trailing
      blocks; LU: ``S(pnl) GETRF`` then a ``lu_writeback`` finalize D2H that
      replays the panel's row swaps on the host columns outside the panel,
      then ``S(ur) TRSM R(ur)`` for the U row panel, then the GEMM trailing
      blocks.

    Lookahead wiring: the trailing blocks covering the *next* panel (its
    columns, plus its U row for LU) are emitted and event-ordered first;
    panel ``k+1``'s transfer+factor waits only on those, so it overlaps the
    rest of trailing update ``k`` — in the simulator via the event graph and
    in the executor via issue order (panel front issued before the rest).
    LU's swap replay additionally waits on every stage-``k`` write-back (the
    replay touches the whole trailing region), so its lookahead overlap is
    the panel transfer + GETRF only; Cholesky's whole panel chain overlaps.
    With ``lookahead=0`` the next panel instead waits on every trailing
    write-back: the sequential per-panel loop, as one schedule.

    The left-multiplier slices (``Fr``) live in a :class:`BlockCache` keyed
    by (stage, block row): every block in block row ``i`` of stage ``k``
    reads the *same* panel-row slice, so only the first emitted block of a
    resident row pays its H2D — the rest hit.  ``evict`` selects the cache's
    replacement policy; the pre-computed trailing emission order feeds the
    Belady oracle.
    """
    n, bpe, lu = spec.n, spec.bytes_per_el, spec.kind == "lu"
    npanels, npbuf = spec.npanels, spec.npbuf
    lookahead = max(0, spec.lookahead)
    dev = device or Device("HBM", 0, spec.budget)
    # trailing blocks round-robin the first `nstreams` streams; the panel
    # chain gets a dedicated stream so a factored-early panel never blocks
    # trailing transfers queued behind it in stream order (the classic
    # lookahead layout: panel stream + update streams)
    b = BlockPipelineBuilder(dev, nstreams + 1, nbuf)
    panel_stream = nstreams

    # buffer-parity release ledger: events that must precede reuse of a key
    release: Dict[Tuple[str, int], Tuple[Event, ...]] = {}
    # previous trailing stage's host writes: (rows, cols, wC event)
    stage_writes: List[Tuple[Tuple[int, int], Tuple[int, int], Event]] = []
    gstep = 0  # global trailing step counter (stream round robin)

    # Fr residency: identity (stage, block row) — its slice depends only on
    # the row extent, so same-row blocks across columns share one transfer
    fr_cache = BlockCache(
        "Fr", nbuf, evict,
        [(k, blk[0]) for k, blk in _trailing_emission_order(spec)])
    fr_pos = 0
    fr_inc: Dict[Tuple[int, int], int] = {}

    def waits_for(key, *events: Iterable[Event]) -> Tuple[Event, ...]:
        out: Dict[str, Event] = {}
        for ev in release.pop(key, ()):
            out[ev.name] = ev
        for group in events:
            for ev in group:
                out[ev.name] = ev
        return tuple(out.values())

    def overlapping(rows, cols) -> List[Event]:
        return [ev for wr, wc, ev in stage_writes + new_writes
                if _hits(wr, rows[0], rows[0] + rows[1])
                and _hits(wc, cols[0], cols[0] + cols[1])]

    def emit_block(k: int, pw: int, blk) -> None:
        """One trailing-update block of stage ``k``: stream the multiplier
        slices and the C block, dgemm, write back."""
        nonlocal gstep, fr_pos
        i, j, rows, cols = blk
        k0, k1 = spec.panel_range(k)
        s = gstep % nstreams
        h_k = math.ceil((n - k1) / spec.bm)
        idx = j * h_k + i
        # left multiplier: rows of the factored panel (the A/Pr role) —
        # cached per (stage, block row), so only the row's first emitted
        # block transfers while it stays resident
        fr_id = (k, i)
        lslot, fr_hit, fr_evict = fr_cache.access(fr_pos, fr_id,
                                                  rows[1] * pw * bpe)
        fr_pos += 1
        lkey = ("Fr", lslot)
        if not fr_hit:
            inc = fr_inc.get(fr_id, 0)
            fr_inc[fr_id] = inc + 1
            suffix = "" if inc == 0 else f"@{inc}"
            landing = b.event(f"rFr{k}[r{i}]{suffix}")
            fr_cache.set_landing(fr_id, landing)
            fr_waits: Dict[str, Event] = {e.name: e for e in fr_evict}
            for e in overlapping(rows, (k0, pw)) + [b.event(f"wPNL[{k}]")]:
                fr_waits[e.name] = e
            b.issue(
                kind=OpKind.H2D, tag=f"S(fr{k}[r{i}]){suffix}", stream=s,
                waits=tuple(fr_waits.values()),
                records=landing,
                buffers_written=(lkey,), bytes=rows[1] * pw * bpe,
                payload=SliceRef("A", i, rows=rows, cols=(k0, pw)))
        # right multiplier, once per column: transposed panel rows (SYRK) or
        # the U row panel slice (LU).  Keyed per (stage, column) — with the
        # Cholesky triangular skip a column's first *emitted* block need not
        # be block row 0.
        tkey = ("Ft", j % 2)
        fresh_ft = (k, j) not in ft_loaded
        if fresh_ft:
            ft_loaded.add((k, j))
            if lu:
                ft = SliceRef("A", j, rows=(k0, pw), cols=cols)
                ft_ev = overlapping((k0, pw), cols) + [b.event(f"wUR[{k}]")]
            else:
                ft = SliceRef("A", j, rows=cols, cols=(k0, pw),
                              transpose=True)
                ft_ev = overlapping(cols, (k0, pw)) + [b.event(f"wPNL[{k}]")]
            b.issue(
                kind=OpKind.H2D, tag=f"S(ft{k}[{j}])", stream=s,
                waits=waits_for(tkey, ft_ev),
                records=b.event(f"rFt{k}[{j}]"),
                buffers_written=(tkey,), bytes=pw * cols[1] * bpe,
                payload=ft)
        ckey = ("C", idx % nbuf)
        # LU: the swap replay permuted these rows on host, so the C block
        # must not be read before the panel write-back (Cholesky's panel
        # write region is disjoint from the trailing square).
        c_extra = (b.event(f"wPNL[{k}]"),) if lu else ()
        b.issue(
            kind=OpKind.H2D, tag=f"S(c{k}[{idx}])", stream=s,
            waits=waits_for(ckey, overlapping(rows, cols), c_extra),
            records=b.event(f"rC{k}[{idx}]"),
            buffers_written=(ckey,), bytes=rows[1] * cols[1] * bpe,
            payload=SliceRef("A", idx, rows=rows, cols=cols))
        b.issue(
            kind=OpKind.COMPUTE, tag=f"{'GEMM' if lu else 'SYRK'}{k}[{idx}]",
            stream=s,
            waits=(fr_cache.landing_event(fr_id), b.event(f"rFt{k}[{j}]"),
                   b.event(f"rC{k}[{idx}]")),
            records=b.event(f"eT{k}[{idx}]"),
            buffers_read=(lkey, tkey), buffers_written=(ckey,),
            flops=2 * rows[1] * cols[1] * pw + 2 * rows[1] * cols[1],
            payload=BlockRef(kernel="dgemm", index=idx))
        wc = b.event(f"wC{k}[{idx}]")
        b.issue(
            kind=OpKind.D2H, tag=f"R(c{k}[{idx}])", stream=s,
            waits=(b.event(f"eT{k}[{idx}]"),), records=wc,
            buffers_read=(ckey,), bytes=rows[1] * cols[1] * bpe,
            payload=SliceRef("A", idx, rows=rows, cols=cols))
        # ledger updates: buffer reuse + host-region write
        fr_cache.note_release(fr_id, s, b.event(f"eT{k}[{idx}]"))
        keep = () if fresh_ft else release.get(tkey, ())
        release[tkey] = tuple(keep) + (b.event(f"eT{k}[{idx}]"),)
        release[ckey] = (wc,)
        new_writes.append((rows, cols, wc))
        gstep += 1

    rest: List = []          # deferred trailing blocks of the previous stage
    rest_stage = -1
    ft_loaded: set = set()   # (stage, column) pairs whose Ft slice landed
    new_writes: List[Tuple[Tuple[int, int], Tuple[int, int], Event]] = []

    for k in range(npanels):
        k0, k1 = spec.panel_range(k)
        pw = k1 - k0
        m = n - k0
        key = ("PNL", k % npbuf)
        s = panel_stream
        # ---- panel front: transfer + in-core factor --------------------
        if lookahead == 0:
            # sequential per-panel loop: the panel waits for every trailing
            # write-back of the previous stage (all still in new_writes —
            # stage k-1 emits in full before this panel)
            dep = [ev for _, _, ev in stage_writes + new_writes]
        else:
            dep = overlapping((k0, m), (k0, pw))
        b.issue(
            kind=OpKind.H2D, tag=f"S(pnl[{k}])", stream=s,
            waits=waits_for(key, dep),
            records=b.event(f"rPNL[{k}]"),
            buffers_written=(key,), bytes=m * pw * bpe,
            payload=SliceRef("A", k, rows=(k0, m), cols=(k0, pw)))
        b.issue(
            kind=OpKind.COMPUTE, tag=f"{'GETRF' if lu else 'POTRF'}[{k}]",
            stream=s,
            waits=(b.event(f"rPNL[{k}]"),), records=b.event(f"ePF[{k}]"),
            buffers_read=(key,), buffers_written=(key,),
            flops=(pw * pw * (3 * m - pw) // 3 if lu
                   else pw * pw * pw // 3),
            payload=BlockRef(kernel="panel_lu" if lu else "panel_chol",
                             index=k))
        last = b.event(f"ePF[{k}]")
        if not lu and m > pw:
            b.issue(
                kind=OpKind.COMPUTE, tag=f"TRSM[{k}]", stream=s,
                waits=(last,), records=b.event(f"eTS[{k}]"),
                buffers_read=(key,), buffers_written=(key,),
                flops=(m - pw) * pw * pw,
                payload=BlockRef(kernel="panel_trsm", index=k))
            last = b.event(f"eTS[{k}]")
        if not lu:
            # Cholesky's panel chain is independent of the previous stage's
            # remaining blocks: write it back before draining them so the
            # next trailing stage can start the moment its inputs land.
            b.issue(
                kind=OpKind.D2H, tag=f"R(pnl[{k}])", stream=s,
                waits=(last,), records=b.event(f"wPNL[{k}]"),
                buffers_read=(key,), bytes=m * pw * bpe,
                payload=SliceRef("A", k, rows=(k0, m), cols=(k0, pw)))
            release[key] = (b.event(f"wPNL[{k}]"),)
        # ---- drain the previous stage's deferred trailing blocks -------
        if rest:
            rpw = spec.panel_range(rest_stage)[1] - \
                spec.panel_range(rest_stage)[0]
            for blk in rest:
                emit_block(rest_stage, rpw, blk)
            rest = []
        if lu:
            # ---- panel back: swap replay + U row panel solve -----------
            # the replay permutes rows across the whole trailing region, so
            # it orders after every write-back of the previous stage
            wb_waits = {b.event(f"ePF[{k}]").name: b.event(f"ePF[{k}]")}
            for _, _, ev in stage_writes + new_writes:
                wb_waits[ev.name] = ev
            b.issue(
                kind=OpKind.D2H, tag=f"R(pnl[{k}])", stream=s,
                waits=tuple(wb_waits.values()),
                records=b.event(f"wPNL[{k}]"),
                buffers_read=(key,), bytes=m * pw * bpe,
                payload=BlockRef(kernel="lu_writeback", index=k))
            release[key] = (b.event(f"wPNL[{k}]"),)
            if m > pw:
                ukey = ("UR", k % npbuf)
                b.issue(
                    kind=OpKind.H2D, tag=f"S(ur[{k}])", stream=s,
                    waits=waits_for(ukey, (b.event(f"wPNL[{k}]"),)),
                    records=b.event(f"rUR[{k}]"),
                    buffers_written=(ukey,), bytes=pw * (n - k1) * bpe,
                    payload=SliceRef("A", k, rows=(k0, pw),
                                     cols=(k1, n - k1)))
                b.issue(
                    kind=OpKind.COMPUTE, tag=f"TRSM[{k}]", stream=s,
                    waits=(b.event(f"rUR[{k}]"), b.event(f"ePF[{k}]")),
                    records=b.event(f"eTS[{k}]"),
                    buffers_read=(key, ukey), buffers_written=(ukey,),
                    flops=(n - k1) * pw * pw,
                    payload=BlockRef(kernel="lu_trsm", index=k))
                b.issue(
                    kind=OpKind.D2H, tag=f"R(ur[{k}])", stream=s,
                    waits=(b.event(f"eTS[{k}]"),),
                    records=b.event(f"wUR[{k}]"),
                    buffers_read=(ukey,), bytes=pw * (n - k1) * bpe,
                    payload=SliceRef("A", k, rows=(k0, pw),
                                     cols=(k1, n - k1)))
                release[ukey] = (b.event(f"wUR[{k}]"),)
                release[key] = (b.event(f"wPNL[{k}]"),
                                b.event(f"eTS[{k}]"))
        # stage k-1 is fully emitted: its writes (plus this panel's) become
        # the overlap ledger for stage k's reads
        stage_writes = new_writes
        new_writes = []
        # ---- trailing update of stage k --------------------------------
        prio, rest = _stage_split(spec, k)
        rest_stage = k
        for blk in prio:
            emit_block(k, pw, blk)
    # the last stage's deferred blocks (none: the final panel drains them)
    assert not rest, "internal: trailing blocks left unemitted"
    assert fr_pos == len(fr_cache.next_use), \
        "internal: emission diverged from the residency pre-pass"
    b.sched.meta = {"evict": evict, "kind": spec.kind,
                    "kernel": f"{spec.kind}-factor"}
    b.sched.reuse = {"Fr": fr_cache.stats()}
    return b.sched
def build_gemm_schedule(
    part: GemmPartition,
    nstreams: int = 2,
    nbuf: int = 2,
    write_back: bool = True,
    device: Optional[Device] = None,
    traversal: str = "col",
    evict: str = "lru",
) -> Schedule:
    """Emit the MMOOC schedule of libhclooc Fig. 2 for ``part``."""
    spec = gemm_pipeline_spec(part, write_back=write_back,
                              traversal=traversal, band=nbuf)
    return compile_pipeline(spec, nstreams=nstreams, nbuf=nbuf,
                            device=device, evict=evict)


def build_attention_schedule(
    part: AttentionPartition,
    kv_heads: int,
    head_dim: int,
    q_heads: int,
    nstreams: int = 2,
    nbuf: int = 2,
    device: Optional[Device] = None,
) -> Schedule:
    """OOC attention schedule: KV blocks + online-softmax carry."""
    spec = attention_pipeline_spec(part, kv_heads, head_dim, q_heads)
    return compile_pipeline(spec, nstreams=nstreams, nbuf=nbuf, device=device)


def build_syrk_schedule(
    part: GemmPartition,
    nstreams: int = 2,
    nbuf: int = 2,
    device: Optional[Device] = None,
    traversal: str = "col",
    evict: str = "lru",
) -> Schedule:
    """Blocked SYRK schedule (Cholesky trailing update)."""
    return compile_pipeline(syrk_pipeline_spec(part, traversal=traversal,
                                               band=nbuf),
                            nstreams=nstreams, nbuf=nbuf,
                            device=device, evict=evict)


def build_vendor_schedule(
    part: GemmPartition,
    device: Optional[Device] = None,
    tile: int = 512,
) -> Schedule:
    """CUBLAS-XT-style baseline: one stream, B re-sent per tile, no overlap."""
    return compile_pipeline(vendor_pipeline_spec(part, tile=tile),
                            nstreams=1, nbuf=1, device=device)


def op_catalog(sched: Schedule) -> list:
    """Flat schedule-addressable op listing, one row per op in global
    issue order — the addressing surface fault plans (``repro.fault``)
    and debugging tools key on.  ``op`` is the index a
    :class:`~repro.fault.FaultSpec` targets; ``kernel`` names the compute
    / finalize handler (None for slice transfers) and ``operand`` the
    host array a slice ref touches (None for block refs)."""
    rows = []
    for i, op in enumerate(sched.ops):
        ref = op.payload
        rows.append({
            "op": i,
            "kind": op.kind.name.lower(),
            "stream": op.stream,
            "tag": op.tag,
            "kernel": ref.kernel if isinstance(ref, BlockRef) else None,
            "operand": getattr(ref, "operand", None),
            "bytes": op.bytes,
            "flops": op.flops,
        })
    return rows


def schedule_stats(sched: Schedule) -> dict:
    """Summary counters used by benchmarks and EXPERIMENTS.md."""
    return {
        "n_ops": len(sched.ops),
        "n_streams": len(sched.streams),
        "h2d_bytes": sched.total_bytes(OpKind.H2D),
        "d2h_bytes": sched.total_bytes(OpKind.D2H),
        "flops": sched.total_flops(),
        "n_events": sum(1 for o in sched.ops if o.records is not None),
        "reuse_hits": sum(r["hits"] for r in sched.reuse.values()),
        "h2d_saved_bytes": sum(r["bytes_saved"]
                               for r in sched.reuse.values()),
    }
