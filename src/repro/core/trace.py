"""Chrome-trace export — make pipeline overlap visually inspectable.

The paper argues its claims (C3/C5) from *overlap*: transfers hidden behind
DGEMM, stream width matched to the engine topology.  A timeline is the
honest way to check that, so both span sources the engine produces —
:attr:`~repro.core.simulator.SimResult.op_spans` (engine-model time) and
:class:`~repro.core.runtime.ScheduleExecutor` wall-clock timings — export to
the ``chrome://tracing`` / Perfetto JSON event format through one helper.
Load the file at ``chrome://tracing`` or https://ui.perfetto.dev.

A span is ``(tag, stream, start_s, end_s)``; streams become trace threads so
each stream renders as its own track.  Categories derive from the schedule's
tag grammar (``S(..)`` H2D, ``R(..)`` D2H, anything else compute), which is
also what Perfetto's search/filter keys on.
"""

from __future__ import annotations

import json
from typing import Iterable, Tuple

Span = Tuple[str, int, float, float]


def _category(tag: str) -> str:
    if tag.startswith("S("):
        return "h2d"
    if tag.startswith("R("):
        return "d2h"
    return "compute"


def chrome_trace(spans: Iterable[Span],
                 process_name: str = "ooc-pipeline") -> dict:
    """Spans -> a ``chrome://tracing`` JSON object (complete "X" events,
    microsecond timestamps, one thread per stream)."""
    spans = list(spans)
    events = [{
        "name": "process_name", "ph": "M", "pid": 0, "tid": 0,
        "args": {"name": process_name},
    }]
    for tid in sorted({s[1] for s in spans}):
        events.append({
            "name": "thread_name", "ph": "M", "pid": 0, "tid": tid,
            "args": {"name": f"stream {tid}"},
        })
    for tag, stream, start, end in spans:
        events.append({
            "name": tag,
            "cat": _category(tag),
            "ph": "X",
            "ts": start * 1e6,
            "dur": max(end - start, 0.0) * 1e6,
            "pid": 0,
            "tid": stream,
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, spans: Iterable[Span],
                       process_name: str = "ooc-pipeline") -> None:
    with open(path, "w") as f:
        json.dump(chrome_trace(spans, process_name=process_name), f)
