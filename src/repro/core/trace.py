"""Chrome-trace export — make pipeline overlap visually inspectable.

The paper argues its claims (C3/C5) from *overlap*: transfers hidden behind
DGEMM, stream width matched to the engine topology.  A timeline is the
honest way to check that, so both span sources the engine produces —
:attr:`~repro.core.simulator.SimResult.op_spans` (engine-model time) and
:class:`~repro.core.runtime.ScheduleExecutor` wall-clock timings — export to
the ``chrome://tracing`` / Perfetto JSON event format through one helper.
Load the file at ``chrome://tracing`` or https://ui.perfetto.dev.

A span is ``(tag, stream, start_s, end_s)``; streams become trace threads so
each stream renders as its own track.  Categories derive from the schedule's
tag grammar (``S(..)`` H2D, ``R(..)`` D2H, anything else compute), which is
also what Perfetto's search/filter keys on.

Multi-device runs (the hybrid co-scheduler) have one span list *per device*,
each with its own stream indices starting at 0; merging them onto one pid
would collide the tracks.  :func:`chrome_trace_groups` gives every device
its own trace *process* (``pid`` = device index), so Perfetto renders one
lane-group per device and identical stream ids never collide.
"""

from __future__ import annotations

import json
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

Span = Tuple[str, int, float, float]
Reuse = Dict[str, Dict[str, int]]


def _category(tag: str) -> str:
    if tag.startswith("S("):
        return "h2d"
    if tag.startswith("R("):
        return "d2h"
    return "compute"


def _group_events(spans: Iterable[Span], process_name: str,
                  pid: int, reuse: Optional[Reuse] = None) -> List[dict]:
    """Events for one span source under one trace process."""
    spans = list(spans)
    events = [{
        "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
        "args": {"name": process_name},
    }]
    for tid in sorted({s[1] for s in spans}):
        events.append({
            "name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
            "args": {"name": f"stream {tid}"},
        })
    for tag, stream, start, end in spans:
        events.append({
            "name": tag,
            "cat": _category(tag),
            "ph": "X",
            "ts": start * 1e6,
            "dur": max(end - start, 0.0) * 1e6,
            "pid": pid,
            "tid": stream,
        })
    if reuse:
        # every H2D on the timeline is a cache miss; hits are the transfers
        # that are *not* there — surface them as an instant annotation
        hits = sum(r["hits"] for r in reuse.values())
        misses = sum(r["misses"] for r in reuse.values())
        events.append({
            "name": f"block-cache: {hits} hits / {misses} transfers",
            "cat": "reuse", "ph": "I", "s": "p",
            "ts": 0.0, "pid": pid, "tid": 0,
            "args": {k: dict(v) for k, v in reuse.items()},
        })
    return events


def chrome_trace(spans: Iterable[Span],
                 process_name: str = "ooc-pipeline",
                 pid: int = 0, reuse: Optional[Reuse] = None) -> dict:
    """Spans -> a ``chrome://tracing`` JSON object (complete "X" events,
    microsecond timestamps, one thread per stream).  ``reuse`` (a schedule's
    block-cache counters) adds an instant event annotating how many H2D
    transfers the residency cache elided."""
    return {"traceEvents": _group_events(spans, process_name, pid, reuse),
            "displayTimeUnit": "ms"}


def chrome_trace_groups(
        groups: Sequence[Tuple[str, Iterable[Span]]]) -> dict:
    """``[(device_name, spans), ...]`` -> one trace with a process (lane
    group) per device: ``pid`` is the device's position in ``groups``, so
    spans from concurrently recorded executors — whose stream ids all start
    at 0 — land on separate tracks instead of colliding."""
    events: List[dict] = []
    for pid, (name, spans) in enumerate(groups):
        events.extend(_group_events(spans, name, pid))
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, spans: Iterable[Span],
                       process_name: str = "ooc-pipeline",
                       reuse: Optional[Reuse] = None) -> None:
    with open(path, "w") as f:
        json.dump(chrome_trace(spans, process_name=process_name,
                               reuse=reuse), f)


def write_chrome_trace_groups(
        path: str, groups: Sequence[Tuple[str, Iterable[Span]]]) -> None:
    with open(path, "w") as f:
        json.dump(chrome_trace_groups(groups), f)
