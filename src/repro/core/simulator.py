"""Discrete-event execution model for OOC schedules.

The paper's performance claims hinge on *overlap*: with two copy engines and a
kernel engine (NVIDIA GPUs), the 2-stream pipeline hides PCIe transfers behind
DGEMM; on Xeon Phi (shared engines, per-stream thread split) one stream is
optimal (claim C5); CUBLAS-XT's non-overlapping block schedule loses 2.3–4×
(claim C3).  This container has no PCIe bus or TPU, so we reproduce those
claims the way the schedules themselves predict them: a discrete-event
simulator with an explicit engine model, exercised by the *same* Schedule
objects the real runtimes execute.

Engine semantics follow CUDA stream rules:
  * ops within a stream start in order, each after the previous completes;
  * an op additionally waits for its events and for a free engine of its kind;
  * engines of a pool serve one op at a time at the pool's rate.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Tuple

from repro.core.streams import Op, OpKind, Schedule


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """Engine pools + rates. ``kind_pool`` maps op kind to a pool name."""

    name: str
    pools: Dict[str, int]                 # pool -> engine count
    kind_pool: Dict[OpKind, str]          # op kind -> pool
    h2d_bw: float                         # bytes/s
    d2h_bw: float
    flops: float                          # flop/s aggregate compute rate
    per_op_overhead: float = 2e-6         # s: launch/abstraction cost (C1)
    compute_split: int = 1                # engines sharing `flops` (Phi mode)
    # aggregate efficiency when the core's threads are split across streams
    # (paper §VI measures 549/725 ≈ 0.76 on Phi 3120P with 2 streams)
    split_efficiency: float = 1.0

    def duration(self, op: Op) -> float:
        if op.kind == OpKind.COMPUTE:
            rate = (self.flops * self.split_efficiency
                    / max(1, self.compute_split))
            return self.per_op_overhead + op.flops / rate
        bw = self.h2d_bw if op.kind == OpKind.H2D else self.d2h_bw
        return self.per_op_overhead + op.bytes / bw


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """Expected-cost inflation for ranking plans under a fault rate
    (DESIGN.md §12).  Deterministic — no sampling — so tuner searches
    stay reproducible: each op's duration is replaced by its expectation
    under per-attempt fault probability ``rate``.

    Transfers retry until success: the expected attempt count is the
    geometric ``1/(1-rate)``, each failed attempt costing the transfer
    again plus ``mean_backoff`` sleep.  Computes recover by replay; a
    fault costs ``redo_factor`` op-durations of redone work on average
    (:func:`repro.fault.replay.mean_redo_len` calibrates this per
    schedule; 1.0 is the no-chain floor).
    """

    rate: float
    mean_backoff: float = 0.0
    redo_factor: float = 1.0

    def expected_duration(self, op: Op, dur: float) -> float:
        r = min(max(self.rate, 0.0), 0.99)
        if r == 0.0:
            return dur
        if op.kind == OpKind.COMPUTE:
            return dur * (1.0 + r * self.redo_factor)
        retries = r / (1.0 - r)          # expected failed attempts
        return dur + retries * (dur + self.mean_backoff)


def gpu_like(flops: float = 1.16e12, pcie: float = 11e9) -> HardwareModel:
    """K40c-like: 2 independent copy engines + kernel engine (paper §I)."""
    return HardwareModel(
        name="gpu-like",
        pools={"h2d": 1, "d2h": 1, "exec": 1},
        kind_pool={OpKind.H2D: "h2d", OpKind.D2H: "d2h",
                   OpKind.COMPUTE: "exec"},
        h2d_bw=pcie, d2h_bw=pcie, flops=flops,
    )


def phi_like(flops: float = 0.725e12, pcie: float = 6.5e9,
             nstreams: int = 1) -> HardwareModel:
    """Xeon Phi 3120P-like: one shared transfer engine; offload streams split
    the core's threads, so ``nstreams`` compute engines each run at
    ``flops/nstreams`` (the paper's C5 observation)."""
    return HardwareModel(
        name="phi-like",
        pools={"xfer": 1, "exec": nstreams},
        kind_pool={OpKind.H2D: "xfer", OpKind.D2H: "xfer",
                   OpKind.COMPUTE: "exec"},
        h2d_bw=pcie, d2h_bw=pcie, flops=flops,
        compute_split=nstreams,
        split_efficiency=1.0 if nstreams == 1 else 0.76,
    )


def tpu_v5e_vmem() -> HardwareModel:
    """TPU v5e, VMEM tier: HBM<->VMEM DMA at HBM bandwidth both directions
    (separate in/out DMA queues), MXU at bf16 peak."""
    return HardwareModel(
        name="tpu-v5e-vmem",
        pools={"in": 1, "out": 1, "exec": 1},
        kind_pool={OpKind.H2D: "in", OpKind.D2H: "out",
                   OpKind.COMPUTE: "exec"},
        h2d_bw=819e9, d2h_bw=819e9, flops=197e12,
        per_op_overhead=5e-8,   # DMA descriptors are pipelined, not launched
    )


def tpu_v5e_ici() -> HardwareModel:
    """TPU v5e, mesh tier: blocks stream over ICI (~50 GB/s/link)."""
    return HardwareModel(
        name="tpu-v5e-ici",
        pools={"in": 1, "out": 1, "exec": 1},
        kind_pool={OpKind.H2D: "in", OpKind.D2H: "out",
                   OpKind.COMPUTE: "exec"},
        h2d_bw=50e9, d2h_bw=50e9, flops=197e12,
        per_op_overhead=1e-6,
    )


@dataclasses.dataclass
class SimResult:
    makespan: float
    busy: Dict[str, float]            # pool -> total busy seconds
    op_spans: List[Tuple[str, int, float, float]]  # (tag, stream, start, end)
    flops: int
    h2d_bytes: int
    d2h_bytes: int
    # H2D bytes actually moved, per operand class (from the H2D ops' parity
    # buffer keys) — exact, not modeled: the sum equals ``h2d_bytes``
    h2d_by_operand: Dict[str, int] = dataclasses.field(default_factory=dict)
    # the schedule's block-cache counters (hits/misses/bytes per class)
    reuse: Dict[str, Dict[str, int]] = dataclasses.field(default_factory=dict)

    @property
    def effective_flops(self) -> float:
        return self.flops / self.makespan if self.makespan > 0 else 0.0

    @property
    def hit_rate(self) -> float:
        """Block-cache hit rate across all operand classes (0.0 when the
        schedule carries no residency stats)."""
        hits = sum(r["hits"] for r in self.reuse.values())
        total = hits + sum(r["misses"] for r in self.reuse.values())
        return hits / total if total else 0.0

    def utilization(self, pool: str) -> float:
        return self.busy.get(pool, 0.0) / self.makespan if self.makespan else 0.0

    def to_chrome_trace(self, process_name: str = "ooc-pipeline",
                        pid: int = 0) -> dict:
        """``chrome://tracing`` / Perfetto JSON for ``op_spans`` — one track
        per stream, so transfer/compute overlap is visually inspectable.
        ``pid`` places the spans in a specific lane group when several
        devices' results are merged into one trace."""
        from repro.core.trace import chrome_trace
        return chrome_trace(self.op_spans, process_name=process_name, pid=pid,
                            reuse=self.reuse)


def _h2d_by_operand(sched: Schedule) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for op in sched.ops:
        if op.kind == OpKind.H2D and op.buffers_written:
            key = op.buffers_written[0]
            name = key[0] if isinstance(key, tuple) else str(key)
            out[name] = out.get(name, 0) + op.bytes
    return out


def simulate(sched: Schedule, hw: HardwareModel,
             faults: "FaultModel" = None) -> SimResult:
    """Event-driven simulation of ``sched`` under ``hw``.

    ``faults`` switches on the faulted-makespan mode: every op duration
    becomes its expectation under the :class:`FaultModel`, so the tuner
    can rank candidate plans by expected cost at a given fault rate
    (``search_gemm(..., fault_rate=...)``).  ``faults=None`` is the exact
    fault-free model cross-checked against ``simulate_reference``.

    Deterministic greedy: repeatedly pick, among stream-head ops whose waited
    events are recorded, the op with the earliest feasible start (ties break
    to the lowest stream index).

    The ready queue is a lazy-key heap rather than a per-pick rescan of all
    stream heads, so large tuning sweeps stay fast.  A head enters the heap
    once all its waited events are recorded, keyed by its feasible start *at
    push time*; because every component of a feasible start (stream-free
    time, engine-free times, event times) only grows as ops are placed, a
    popped key is a lower bound — recompute, re-push if stale, place if
    exact.  The placed op's true start is then <= every other queued head's,
    which is exactly the scan's greedy choice (`simulate_reference`, the
    executable spec this is cross-checked against in
    ``benchmarks/bench_simulate.py``).
    """
    streams = sched.streams
    heads = [0] * len(streams)
    stream_free = [0.0] * len(streams)
    engine_free: Dict[str, List[float]] = {
        pool: [0.0] * n for pool, n in hw.pools.items()
    }
    event_time: Dict[str, float] = {}
    busy: Dict[str, float] = {pool: 0.0 for pool in hw.pools}
    spans: List[Tuple[str, int, float, float]] = []
    remaining = sum(len(s.ops) for s in streams)
    makespan = 0.0

    # (feasible-start lower bound, stream) heap + event -> blocked streams.
    ready: List[Tuple[float, int]] = []
    waiting: Dict[str, List[int]] = {}

    def feasible(si: int) -> Tuple[float, int, Op, str]:
        op = streams[si].ops[heads[si]]
        pool = hw.kind_pool[op.kind]
        free = engine_free[pool]
        if len(free) == 1:
            ei = 0
        else:
            ei = min(range(len(free)), key=free.__getitem__)
        start = stream_free[si]
        if free[ei] > start:
            start = free[ei]
        for ev in op.waits:
            t = event_time[ev.name]
            if t > start:
                start = t
        return start, ei, op, pool

    def enqueue(si: int) -> None:
        """Push stream ``si``'s head, or park it on its first missing event."""
        if heads[si] >= len(streams[si].ops):
            return
        op = streams[si].ops[heads[si]]
        for ev in op.waits:
            if ev.name not in event_time:
                waiting.setdefault(ev.name, []).append(si)
                return
        heapq.heappush(ready, (feasible(si)[0], si))

    for si in range(len(streams)):
        enqueue(si)

    while remaining:
        if not ready:
            raise RuntimeError(
                "simulator deadlock: no stream head is runnable "
                "(schedule should have failed validate_schedule)"
            )
        key, si = heapq.heappop(ready)
        start, ei, op, pool = feasible(si)
        if start > key:  # engine/event state moved since push: stale bound
            heapq.heappush(ready, (start, si))
            continue
        dur = hw.duration(op)
        if faults is not None:
            dur = faults.expected_duration(op, dur)
        end = start + dur
        engine_free[pool][ei] = end
        stream_free[si] = end
        busy[pool] += dur
        heads[si] += 1
        remaining -= 1
        makespan = max(makespan, end)
        spans.append((op.tag, si, start, end))
        if op.records is not None:
            event_time[op.records.name] = end
            for blocked in waiting.pop(op.records.name, ()):
                enqueue(blocked)
        enqueue(si)

    return SimResult(
        makespan=makespan,
        busy=busy,
        op_spans=spans,
        flops=sched.total_flops(),
        h2d_bytes=sched.total_bytes(OpKind.H2D),
        d2h_bytes=sched.total_bytes(OpKind.D2H),
        h2d_by_operand=_h2d_by_operand(sched),
        reuse={k: dict(v) for k, v in sched.reuse.items()},
    )


def simulate_reference(sched: Schedule, hw: HardwareModel) -> SimResult:
    """The original O(n_ops x n_streams) head-scan list scheduler.

    Kept as the executable specification of :func:`simulate`'s greedy rule:
    ``benchmarks/bench_simulate.py`` asserts span-for-span agreement, and the
    heap version's docstring argues equivalence against this loop.
    """
    streams = sched.streams
    heads = [0] * len(streams)
    stream_free = [0.0] * len(streams)
    engine_free: Dict[str, List[float]] = {
        pool: [0.0] * n for pool, n in hw.pools.items()
    }
    event_time: Dict[str, float] = {}
    busy: Dict[str, float] = {pool: 0.0 for pool in hw.pools}
    spans: List[Tuple[str, int, float, float]] = []
    remaining = sum(len(s.ops) for s in streams)
    makespan = 0.0

    while remaining:
        best = None  # (start, engine_idx, stream_idx, op)
        for si, st in enumerate(streams):
            if heads[si] >= len(st.ops):
                continue
            op = st.ops[heads[si]]
            if any(ev.name not in event_time for ev in op.waits):
                continue
            pool = hw.kind_pool[op.kind]
            ei = min(range(len(engine_free[pool])),
                     key=lambda k: engine_free[pool][k])
            start = max(
                stream_free[si],
                engine_free[pool][ei],
                max((event_time[ev.name] for ev in op.waits), default=0.0),
            )
            if best is None or start < best[0]:
                best = (start, ei, si, op)
        if best is None:
            raise RuntimeError(
                "simulator deadlock: no stream head is runnable "
                "(schedule should have failed validate_schedule)"
            )
        start, ei, si, op = best
        dur = hw.duration(op)
        end = start + dur
        pool = hw.kind_pool[op.kind]
        engine_free[pool][ei] = end
        stream_free[si] = end
        busy[pool] += dur
        heads[si] += 1
        remaining -= 1
        makespan = max(makespan, end)
        spans.append((op.tag, si, start, end))
        if op.records is not None:
            event_time[op.records.name] = end

    return SimResult(
        makespan=makespan,
        busy=busy,
        op_spans=spans,
        flops=sched.total_flops(),
        h2d_bytes=sched.total_bytes(OpKind.H2D),
        d2h_bytes=sched.total_bytes(OpKind.D2H),
        h2d_by_operand=_h2d_by_operand(sched),
        reuse={k: dict(v) for k, v in sched.reuse.items()},
    )
