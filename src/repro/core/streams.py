"""Unified Device / Stream / Event abstractions — libhclooc's core interface.

The paper unifies CUDA streams+events, Intel offload streams+signals, and
OpenCL command queues behind ``hclStream``/``hclEvent`` data containers plus an
``hclRuntime`` that issues async ops onto streams.  On TPU the analogous
"queues" are the pipeline slots of the double-buffered DMA engine (``vmem``
backend), the async-dispatch queue (``host`` backend), and the ping-pong
``collective_permute`` buffers of a SUMMA ring (``mesh`` backend).

These classes carry *schedule structure* (issue order, dependency edges,
buffer parity).  Execution semantics are supplied by:

  * ``core.simulator`` — a discrete-event hardware model (copy engines ×
    kernel engine) that turns a schedule into a makespan; used to reproduce
    the paper's overlap claims (C3, C5) without a PCIe bus to measure.
  * ``core.runtime`` — real JAX executors where an Event resolves to a data
    dependency (the consuming op takes the produced array as an operand; value
    dependence on an SSA array IS the event graph on TPU).
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, Hashable, List, Optional, Sequence, Tuple, Union


@dataclasses.dataclass(frozen=True)
class Device:
    """The paper's ``{name, id}`` tuple plus ``hclGetMemSize``.

    ``name`` selects the backend/tier: "VMEM", "HBM", "MESH" (TPU tiers) —
    the analogues of the paper's "GPU"/"PHI"/"FPGA".
    """

    name: str
    id: int
    mem_bytes: int

    def mem_size(self) -> int:  # hclGetMemSize
        return self.mem_bytes


class OpKind(enum.Enum):
    H2D = "H2D"          # backing tier -> fast tier (paper: host to device)
    D2H = "D2H"          # fast tier -> backing tier
    COMPUTE = "COMPUTE"  # in-core kernel on resident blocks (paper: DGEMM)


@dataclasses.dataclass(frozen=True)
class SliceRef:
    """Typed transfer payload: which host-side slice an H2D/D2H op moves.

    ``operand`` names a streamed operand class (the key the executor uses to
    look up the host array); ``index`` is the operand's block number; ``rows``
    and ``cols`` are ``(start, size)`` half-open slices (None = full extent);
    ``transpose`` transposes the slice after extraction (SYRK streams the same
    panel as both the row and the transposed column operand).
    """

    operand: str
    index: int
    rows: Optional[Tuple[int, int]] = None
    cols: Optional[Tuple[int, int]] = None
    transpose: bool = False


@dataclasses.dataclass(frozen=True)
class BlockRef:
    """Typed compute/finalize payload: which registered kernel handler runs.

    ``kernel`` is the key into the :class:`~repro.core.runtime.ScheduleExecutor`
    handler registry ("dgemm", "attn", "noop", ...); ``index`` is the pipeline
    step.  Buffer operands are carried by the op's ``buffers_read`` /
    ``buffers_written`` in the spec's declared order, so handlers are
    positional — no raw dict spelunking.
    """

    kernel: str
    index: int


Payload = Union[SliceRef, BlockRef]


@dataclasses.dataclass(frozen=True)
class Event:
    """Named completion marker (``hclEvent``).

    The paper's events are created uninitialised and recorded by the async op
    they are passed to; here an Event is identified by name and recorded by
    exactly one Op.
    """

    name: str


@dataclasses.dataclass(frozen=True)
class Op:
    """One asynchronous command issued to a stream (``hclMemcpyAsync`` /
    ``hclDgemmAsync`` analogue).

    Attributes:
      kind: transfer direction or compute.
      tag: human-readable, e.g. "S(a[3])", "DGEMM[3]", "R(c[3])".
      stream: stream index the op is enqueued on.
      waits: events that must be recorded before this op may *start*
             (``hclWaitEvent`` semantics: blocks the stream, not the host).
      records: event recorded when this op completes (or None).
      buffers_read / buffers_written: abstract buffer ids touched — used by
             the validator to prove the schedule never overwrites live data
             (the paper's stated purpose for its five event sets).
      bytes: payload for transfers (drives the simulator's bandwidth model).
      flops: work for compute ops (drives the simulator's compute model).
    """

    kind: OpKind
    tag: str
    stream: int
    waits: Tuple[Event, ...] = ()
    records: Optional[Event] = None
    buffers_read: Tuple[Hashable, ...] = ()
    buffers_written: Tuple[Hashable, ...] = ()
    bytes: int = 0
    flops: int = 0
    payload: Optional[Payload] = None  # typed SliceRef / BlockRef


@dataclasses.dataclass
class Stream:
    """An ordered queue of Ops bound to a Device (``hclStream``)."""

    device: Device
    index: int
    ops: List[Op] = dataclasses.field(default_factory=list)

    def enqueue(self, op: Op) -> None:
        assert op.stream == self.index, (op.stream, self.index)
        self.ops.append(op)


class StreamFactory:
    """``hclStreamFactory``: create N streams for a device."""

    @staticmethod
    def create(device: Device, n: int) -> List[Stream]:
        if n < 1:
            raise ValueError("need at least one stream")
        return [Stream(device, i) for i in range(n)]


@dataclasses.dataclass
class Schedule:
    """A complete multi-stream program: the object the paper writes by hand in
    Fig. 2 and that our ``pipeline.PipelineSpec`` DSL generates."""

    device: Device
    streams: List[Stream]
    ops: List[Op] = dataclasses.field(default_factory=list)  # global issue order
    # residency stats per operand class (hits/misses/bytes_moved/bytes_saved)
    # filled by the pipeline compiler's block cache; empty for hand-built
    # schedules
    reuse: Dict[str, Dict[str, int]] = dataclasses.field(default_factory=dict)
    # compile-time knobs worth reporting (traversal, eviction policy, ...)
    meta: Dict[str, str] = dataclasses.field(default_factory=dict)

    def issue(self, op: Op) -> Op:
        self.ops.append(op)
        self.streams[op.stream].enqueue(op)
        return op

    # -- introspection used by benchmarks ------------------------------------
    def total_bytes(self, kind: OpKind) -> int:
        return sum(o.bytes for o in self.ops if o.kind == kind)

    def total_flops(self) -> int:
        return sum(o.flops for o in self.ops if o.kind == OpKind.COMPUTE)


class ScheduleError(AssertionError):
    pass


def dependency_edges(sched: Schedule
                     ) -> Tuple[Dict[str, int], List[List[int]]]:
    """Direct happens-before edges of the event program.

    Returns ``(recorder, preds)``: ``recorder`` maps event name -> issue
    index of the op that records it, ``preds[i]`` lists the issue indices
    op ``i`` directly depends on — its stream predecessor plus the recorder
    of every event it waits on.  The transitive closure of these edges IS
    the schedule's happens-before relation; :func:`validate_schedule`
    layers vector clocks on top of them and
    :func:`repro.core.exec_plan.compile_executable` turns them into the
    concurrent executor's ``threading.Event`` program.

    Raises :class:`ScheduleError` on a twice-recorded event or a wait on a
    never-recorded event (both make the edge list meaningless).
    """
    ops = sched.ops
    n = len(ops)
    recorder: Dict[str, int] = {}
    for idx, op in enumerate(ops):
        if op.records is not None:
            if op.records.name in recorder:
                raise ScheduleError(f"event {op.records.name} recorded twice")
            recorder[op.records.name] = idx

    preds: List[List[int]] = [[] for _ in range(n)]
    last_in_stream: Dict[int, int] = {}
    for idx, op in enumerate(ops):
        if op.stream in last_in_stream:
            preds[idx].append(last_in_stream[op.stream])
        last_in_stream[op.stream] = idx
        for ev in op.waits:
            if ev.name not in recorder:
                raise ScheduleError(
                    f"op {op.tag} waits on never-recorded event {ev.name}"
                )
            preds[idx].append(recorder[ev.name])
    return recorder, preds


def validate_schedule(sched: Schedule) -> None:
    """Prove the event graph is correct — the property the paper's five event
    sets exist to enforce (§V: "To make sure data stored in device buffers is
    not overwritten until kernel executions that operate on the data have
    completed").

    Checks, under *any* legal interleaving (streams advance independently;
    an op may start only when all its ``waits`` have been recorded):

      1. No deadlock: every op's waited-on events are recordable without
         cycles (topological order exists).
      2. Write-after-read safety: an op writing buffer b is ordered (via the
         event/stream happens-before relation) after every earlier op reading
         b, and vice versa (read-after-write).

    Raises ScheduleError on violation.
    """
    ops = sched.ops
    n = len(ops)
    # happens-before edges: stream program order + wait->record edges.
    recorder, preds = dependency_edges(sched)

    # topo order / cycle check (1).
    state = [0] * n  # 0 unvisited, 1 on stack, 2 done
    order: List[int] = []

    def visit(u: int) -> None:
        stack = [(u, iter(preds[u]))]
        state[u] = 1
        while stack:
            node, it = stack[-1]
            advanced = False
            for v in it:
                if state[v] == 1:
                    raise ScheduleError("event graph has a cycle (deadlock)")
                if state[v] == 0:
                    state[v] = 1
                    stack.append((v, iter(preds[v])))
                    advanced = True
                    break
            if not advanced:
                state[node] = 2
                order.append(node)
                stack.pop()

    for u in range(n):
        if state[u] == 0:
            visit(u)

    # O(n * nstreams) happens-before oracle: per-stream vector clocks
    # computed along the topo order.  clock[i][s] = number of ops on stream s
    # that happen before (or are) op i; since a stream is totally ordered,
    # hb(a, b) <=> clock[b][stream(a)] > pos_in_stream(a).
    nstreams = len(sched.streams)
    pos = [0] * n  # op's position within its own stream
    seen: Dict[int, int] = {}
    for idx, op in enumerate(ops):
        pos[idx] = seen.get(op.stream, 0)
        seen[op.stream] = pos[idx] + 1
    clock = [[0] * nstreams for _ in range(n)]
    for u in order:  # preds appear before u in topo order
        cu = clock[u]
        for p in preds[u]:
            cp = clock[p]
            for s in range(nstreams):
                if cp[s] > cu[s]:
                    cu[s] = cp[s]
        su = ops[u].stream
        if pos[u] + 1 > cu[su]:
            cu[su] = pos[u] + 1

    def hb(a: int, b: int) -> bool:
        return a != b and clock[b][ops[a].stream] > pos[a]

    # Per-buffer reader/writer frontier sweep (2), linear in total buffer
    # accesses: walking a topological linearization, each buffer tracks its
    # last writer and the readers since that write.  A reader must be ordered
    # after the last writer; a writer after the last writer AND every reader
    # since.  Transitivity of hb makes the frontier sufficient: any older
    # accessor is ordered before the frontier op that displaced it.
    last_writer: Dict[Hashable, int] = {}
    readers: Dict[Hashable, List[int]] = {}

    def check(prev: int, cur: int, buf: Hashable) -> None:
        if not hb(prev, cur):
            raise ScheduleError(
                f"unordered conflicting ops on buffer {buf!r}: "
                f"{ops[prev].tag} (issue {prev}) vs {ops[cur].tag} (issue {cur})"
            )

    for u in order:
        op = ops[u]
        for b in op.buffers_read:
            w = last_writer.get(b)
            if w is None:
                # device parity buffers (tuple keys) must be transferred
                # into before anything consumes them; string-keyed carry
                # state is legitimately read before the first write
                # (attention initializes the carry in-handler at step 0)
                if isinstance(b, tuple):
                    raise ScheduleError(
                        f"op {op.tag} reads buffer {b!r} before any "
                        f"transfer wrote it (use-before-transfer)"
                    )
            else:
                check(w, u, b)
            readers.setdefault(b, []).append(u)
        for b in op.buffers_written:
            w = last_writer.get(b)
            if w is not None:
                check(w, u, b)
            for r in readers.get(b, ()):
                if r != u:
                    check(r, u, b)
            last_writer[b] = u
            readers[b] = []
