"""Block partitioner for out-of-core GEMM — the ``hclMatrixPartitioner`` analogue.

The paper's partitioner splits A (M×K) into ``h`` horizontal slices, B (K×N)
into ``w`` vertical slices, and C (M×N) into ``h×w`` rectangular blocks such
that *the data required for updating any two blocks of C in the same column is
small enough to fit in the accelerator's memory* (libhclooc §III, §V).  Two
C blocks must fit simultaneously because the double-buffered pipeline holds the
block being computed and the block being transferred at the same time.

TPU adaptation: the "accelerator memory" is a *tier budget* (VMEM for the
Pallas backend, a single chip's HBM for host streaming, per-shard HBM for the
mesh backend), and block edges are aligned to the MXU/VREG tiling
(lane=128, sublane=8) so that the in-core GEMM hits the systolic array at full
utilization.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

# TPU tiling constants (fp32/bf16 lane/sublane granularity).
LANE = 128
SUBLANE = 8


@dataclasses.dataclass(frozen=True)
class GemmPartition:
    """A plan for C = alpha * A @ B + beta * C computed in h x w blocks.

    Attributes mirror the paper's notation:
      h: number of horizontal slices of A (and of C's rows)
      w: number of vertical slices of B (and of C's cols)
      bm, bn: block dims of a C block (last row/col blocks may be smaller)
      M, N, K: problem shape
      bytes_per_el: element size (the paper fixes double; we support any dtype)
      budget: memory budget in bytes that the working set must fit
    """

    M: int
    N: int
    K: int
    h: int
    w: int
    bm: int
    bn: int
    bytes_per_el: int
    budget: int

    @property
    def nblocks(self) -> int:
        return self.h * self.w

    def working_set_bytes(self, nbuf: Optional[int] = None,
                          nstreams: Optional[int] = None) -> int:
        """Bytes resident on-device for the pipeline holding this partition.

        With no arguments this is the paper's fixed 2-deep model: one A slice
        (bm x K) plus its double-buffered successor, one B slice (K x bn),
        and TWO C blocks (bm x bn) — the block being computed and the block
        in flight.

        Passing ``nbuf`` (and optionally ``nstreams``) switches to the
        allocation the compiled pipeline actually makes
        (:func:`~repro.core.pipeline.compile_pipeline`): ``nbuf`` parity
        buffers for each of A and C, and a 2-deep B ping-pong regardless of
        pipeline depth (never deeper than the column count ``w``).  The
        executor allocates per parity class, so stream count adds no buffers
        — but a deeper round robin only pays off with buffers to land in, so
        when only ``nstreams`` is given the depth is ``max(2, nstreams)``:
        the pipeline's default double buffering, deepened if more streams
        demand more landing slots.  This is the model the planner must use
        to stop approving partitions an ``nbuf=3`` schedule overflows.
        """
        if nbuf is None and nstreams is None:
            a = 2 * self.bm * self.K      # current + prefetched A slice
            b = self.K * self.bn          # one B slice (reused down a column)
            c = 2 * self.bm * self.bn     # two C blocks (paper's constraint)
            return (a + b + c) * self.bytes_per_el
        depth = nbuf if nbuf is not None else max(2, nstreams)
        if depth < 1:
            raise ValueError(f"buffer depth must be >= 1, got {depth}")
        b_depth = min(2, self.w) if self.w > 0 else 2
        a = depth * self.bm * self.K
        b = b_depth * self.K * self.bn
        c = depth * self.bm * self.bn
        return (a + b + c) * self.bytes_per_el

    def block_rows(self, i: int) -> Tuple[int, int]:
        """(row_start, row_size) of block row i, i in [0, h)."""
        start = i * self.bm
        return start, min(self.bm, self.M - start)

    def block_cols(self, j: int) -> Tuple[int, int]:
        start = j * self.bn
        return start, min(self.bn, self.N - start)

    def blocks(self):
        """Iterate (i, j, rs, rn, cs, cn) in the paper's column-major order.

        The paper's Fig. 2 loop iterates ``for j in range(w): for i in
        range(h)`` so that a B slice b_j is transferred once and reused for all
        h C blocks in its column.
        """
        for j in range(self.w):
            for i in range(self.h):
                rs, rn = self.block_rows(i)
                cs, cn = self.block_cols(j)
                yield i, j, rs, rn, cs, cn


# ---------------------------------------------------------------------------
# Traversal orders — the lever that controls operand reuse distance
# ---------------------------------------------------------------------------
# The paper's Fig. 2 fixes column-major order (B transfers once per column).
# With a residency-tracking compiler (pipeline.BlockCache) the traversal
# decides which recurrences land inside the cache capacity: serpentine keeps
# the A row live across a column boundary, a blocked band of height <= nbuf
# keeps every A row of the band live for the whole sweep, Z-Morton is the
# cache-oblivious compromise when nbuf is unknown.
TRAVERSALS = ("col", "row", "serpentine", "blocked", "zmorton")


def _morton_key(i: int, j: int) -> int:
    key = 0
    for bit in range(max(i.bit_length(), j.bit_length(), 1)):
        key |= ((i >> bit) & 1) << (2 * bit + 1)
        key |= ((j >> bit) & 1) << (2 * bit)
    return key


def traversal_order(h: int, w: int, traversal: str = "col",
                    band: Optional[int] = None) -> List[Tuple[int, int]]:
    """Visit order of the ``h x w`` C-block grid as ``(i, j)`` pairs.

    * ``col``        — the paper's order: ``for j: for i``.
    * ``row``        — ``for i: for j`` (B-heavy; useful when h < w).
    * ``serpentine`` — column-major with alternating row direction, so the
      A row at each column boundary repeats back-to-back.
    * ``blocked``    — row bands of height ``band`` (default 2), columns
      swept serpentine *across bands*: with ``band <= nbuf`` every A row of
      a band stays resident for its whole sweep, and the B ping-pong hits
      at each band boundary.
    * ``zmorton``    — cells sorted by bit-interleaved (i, j): bounded reuse
      distance in both operands without knowing the buffer depth.

    Every order is a permutation of the grid, so the set of computed blocks
    (and the result) is identical; only transfer traffic changes.
    """
    if h < 1 or w < 1:
        raise ValueError(f"bad grid {h}x{w}")
    if traversal == "col":
        return [(i, j) for j in range(w) for i in range(h)]
    if traversal == "row":
        return [(i, j) for i in range(h) for j in range(w)]
    if traversal == "serpentine":
        out: List[Tuple[int, int]] = []
        for j in range(w):
            rng = range(h) if j % 2 == 0 else range(h - 1, -1, -1)
            out.extend((i, j) for i in rng)
        return out
    if traversal == "blocked":
        b = max(1, band or 2)
        out = []
        for nb, b0 in enumerate(range(0, h, b)):
            i_rng = range(b0, min(b0 + b, h))
            j_rng = range(w) if nb % 2 == 0 else range(w - 1, -1, -1)
            for j in j_rng:
                out.extend((i, j) for i in i_rng)
        return out
    if traversal == "zmorton":
        return sorted(((i, j) for i in range(h) for j in range(w)),
                      key=lambda ij: _morton_key(*ij))
    raise ValueError(
        f"unknown traversal {traversal!r}; expected one of {TRAVERSALS}")


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _align_block(b: int, dim: int, align: int) -> int:
    """Round block size up to ``align`` without exceeding the padded dim."""
    b = max(align, _round_up(b, align))
    return min(b, _round_up(dim, align))


def plan_gemm_partition(
    M: int,
    N: int,
    K: int,
    budget_bytes: int,
    bytes_per_el: int = 4,
    align_m: int = SUBLANE,
    align_n: int = LANE,
    nbuf: Optional[int] = None,
    nstreams: Optional[int] = None,
) -> GemmPartition:
    """Choose (h, w) so the pipeline working set fits ``budget_bytes``.

    Strategy (faithful to the paper, §V): keep K un-split (slices of A are
    full-K rows, slices of B are full-K columns) and grow h and w until the
    working set fits.  Prefer fewer, larger blocks (maximize in-core GEMM
    efficiency) and prefer splitting M before N, because a B slice is reused
    ``h`` times per column while an A slice is used once — smaller bn raises
    B-transfer cost linearly, smaller bm only shrinks the compute tile.

    ``nbuf``/``nstreams`` select the generalized working-set model of
    :meth:`GemmPartition.working_set_bytes` so a deeper pipeline (nbuf > 2)
    gets correspondingly smaller blocks instead of overflowing the budget;
    the default (both None) keeps the paper's fixed 2-deep model.

    Raises ValueError if even the minimum aligned block does not fit — the
    paper's implicit requirement that K itself fits (it never splits K; our
    Pallas backend *does* split K, see kernels/block_matmul.py, which is a
    beyond-paper extension).
    """
    if min(M, N, K) <= 0:
        raise ValueError(f"bad GEMM shape {(M, N, K)}")
    if budget_bytes <= 0:
        raise ValueError("budget must be positive")

    def probe(bm: int, bn: int) -> GemmPartition:
        # carries the real (h, w): the generalized model caps the B
        # ping-pong at the column count, so a single-column partition must
        # not be charged for two B slices
        return GemmPartition(M, N, K, math.ceil(M / bm), math.ceil(N / bn),
                             bm, bn, bytes_per_el, budget_bytes)

    def fits(bm: int, bn: int) -> bool:
        return probe(bm, bn).working_set_bytes(nbuf, nstreams) <= budget_bytes

    # Start in-core: one block covering everything.
    bm = _align_block(M, M, align_m)
    bn = _align_block(N, N, align_n)

    # Shrink the larger block dim first (balanced splitting keeps the in-core
    # GEMM tile fat for the MXU); ties prefer splitting M, because a B slice
    # is reused h times per column while an A slice is used once.
    min_bm, min_bn = align_m, align_n
    while not fits(bm, bn):
        shrink_m = (bm >= bn and bm > min_bm) or bn <= min_bn
        if shrink_m and bm > min_bm:
            target = max(min_bm, _round_up(bm // 2, align_m))
            bm = target if target < bm else bm - align_m
        elif bn > min_bn:
            target = max(min_bn, _round_up(bn // 2, align_n))
            bn = target if target < bn else bn - align_n
        else:
            need = probe(bm, bn).working_set_bytes(nbuf, nstreams)
            raise ValueError(
                f"GEMM {(M, N, K)} cannot fit budget {budget_bytes}B: minimum "
                f"aligned working set is {need}B (K is never split by the "
                f"paper's partitioner; use the vmem backend for K-splitting)"
            )

    h = math.ceil(M / bm)
    w = math.ceil(N / bn)
    return GemmPartition(M, N, K, h, w, bm, bn, bytes_per_el, budget_bytes)


@dataclasses.dataclass(frozen=True)
class AttentionPartition:
    """KV-cache block plan for out-of-core attention (beyond-paper).

    The same budget math applied to attention: queries stay resident, the KV
    cache (S × kv_heads × head_dim, ×2 for K and V) is streamed in ``nblocks``
    sequence blocks of ``bs`` positions each.
    """

    S: int
    bs: int
    nblocks: int
    bytes_per_el: int
    budget: int


def plan_attention_partition(
    seq_len: int,
    kv_heads: int,
    head_dim: int,
    budget_bytes: int,
    bytes_per_el: int = 2,
    align_s: int = LANE,
) -> AttentionPartition:
    """Pick a KV block length so 2 in-flight (K,V) blocks fit the budget."""
    if seq_len <= 0:
        raise ValueError("seq_len must be positive")
    per_pos = 2 * kv_heads * head_dim * bytes_per_el  # K and V
    bs = _round_up(seq_len, align_s)
    while bs > align_s and 2 * bs * per_pos > budget_bytes:
        bs = max(align_s, _round_up(bs // 2, align_s))
    if 2 * bs * per_pos > budget_bytes:
        raise ValueError(
            f"attention KV block of {align_s} positions "
            f"({2 * align_s * per_pos}B double-buffered) exceeds budget "
            f"{budget_bytes}B"
        )
    nblocks = math.ceil(seq_len / bs)
    return AttentionPartition(seq_len, bs, nblocks, bytes_per_el, budget_bytes)
