"""Rank the candidate space with ``simulate()`` as the cost oracle.

Every candidate is compiled by the *production* pipeline compiler
(:func:`~repro.core.pipeline.compile_pipeline`) and timed under the
profile's engine model for **that candidate's stream count**
(:meth:`~repro.tune.calibrate.HardwareProfile.model_for`) — the detail that
reproduces claim C5: on a shared-engine Phi-like profile a 2-stream model
splits the compute core at 0.76 efficiency, so 1 stream wins; on a
GPU-like profile 2 streams hide PCIe behind DGEMM, so 2 wins.  The winner
is returned as a :class:`TunedPlan`, a JSON-serializable value object the
plan cache persists.

The search is exhaustive over the (pruned, tens-of-candidates) space and
fully deterministic: candidates are enumerated in a fixed order and ties
break toward fewer streams, shallower buffers, then larger blocks —
identical inputs always produce an identical plan.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.partitioner import (AttentionPartition, GemmPartition,
                                    plan_attention_partition,
                                    plan_gemm_partition)
from repro.core.pipeline import (attention_pipeline_spec,
                                 compile_factor_pipeline, compile_pipeline,
                                 factor_pipeline_spec, gemm_pipeline_spec,
                                 syrk_pipeline_spec)
from repro.core.simulator import FaultModel, simulate
from repro.obs import get_observability
from repro.tune.calibrate import HardwareProfile
from repro.tune.space import attention_search_space, gemm_search_space

Scalar = Union[int, float, bool, str]


@dataclasses.dataclass(frozen=True)
class TunedPlan:
    """The tuner's output: a complete, executable pipeline configuration.

    ``params`` holds the kernel-specific geometry as a sorted tuple of
    pairs (``bm``/``bn``/``h``/``w`` for GEMM and SYRK, ``bs``/``nblocks``
    for attention) so the dataclass stays frozen, hashable and
    JSON-round-trippable; ``makespan``/``baseline_makespan`` are the
    predicted seconds for this plan and for the hardcoded default
    ``(nstreams=2, nbuf=2)`` plan under the same profile.
    """

    kernel: str                      # "gemm" | "syrk" | "attention"
    problem: Tuple[int, ...]
    dtype: str
    tier: str
    budget: int
    nstreams: int
    nbuf: int
    write_back: bool
    params: Tuple[Tuple[str, int], ...]
    makespan: float
    baseline_makespan: float
    model: str
    fingerprint: str
    # block-grid traversal order and residency eviction policy the schedule
    # is compiled with (defaults match the pre-reuse column-major plans)
    traversal: str = "col"
    evict: str = "lru"

    def param(self, name: str) -> int:
        for k, v in self.params:
            if k == name:
                return v
        raise KeyError(name)

    def gemm_partition(self) -> GemmPartition:
        if self.kernel not in ("gemm", "syrk"):
            raise ValueError(f"{self.kernel!r} plan has no GEMM partition")
        M, N, K = self.problem
        return GemmPartition(
            M, N, K, self.param("h"), self.param("w"),
            self.param("bm"), self.param("bn"),
            np.dtype(self.dtype).itemsize, self.budget)

    def attention_partition(self) -> AttentionPartition:
        if self.kernel != "attention":
            raise ValueError(f"{self.kernel!r} plan has no KV partition")
        S = self.problem[0]
        return AttentionPartition(
            S, self.param("bs"), self.param("nblocks"),
            np.dtype(self.dtype).itemsize, self.budget)

    def to_json(self) -> Dict[str, Scalar]:
        d = dataclasses.asdict(self)
        d["problem"] = list(self.problem)
        d["params"] = {k: v for k, v in self.params}
        return d

    @classmethod
    def from_json(cls, d: Dict) -> "TunedPlan":
        d = dict(d)
        d["problem"] = tuple(d["problem"])
        d["params"] = tuple(sorted(d["params"].items()))
        return cls(**d)


def _rank_key(makespan: float, cand_ns: int, cand_nb: int,
              bm: int, bn: int, idx: int):
    # ties: fewer streams, shallower buffers, larger blocks, issue order
    return (makespan, cand_ns, cand_nb, -bm, -bn, idx)


def _observed(label_of):
    """Wrap a ``search_*`` entry point with a ``tune.search`` span plus
    per-search count/latency metrics.  Decorating here (not in AutoTuner)
    covers *every* caller — the tuner, the hybrid balancer's per-device
    searches, direct test calls — with one guard."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*a, **kw):
            obs = get_observability()
            kernel = label_of(*a, **kw)
            t0 = time.perf_counter()
            with obs.span("tune.search", cat="tune", kernel=kernel):
                plan = fn(*a, **kw)
            if obs.metrics.enabled:
                m = obs.metrics
                m.counter("repro_tune_searches_total",
                          "plan searches run").inc(kernel=kernel)
                m.histogram("repro_tune_search_seconds",
                            "wall seconds per plan search").observe(
                                time.perf_counter() - t0, kernel=kernel)
            return plan
        return wrapper
    return deco


def _count_candidates(kernel: str, n: int) -> None:
    m = get_observability().metrics
    if m.enabled:
        m.counter("repro_tune_candidates_total",
                  "pipeline candidates ranked by simulate()").inc(
                      n, kernel=kernel)


@_observed(lambda *a, **kw: kw.get("kernel", "gemm"))
def search_gemm(
    M: int,
    N: int,
    K: int,
    budget_bytes: int,
    profile: HardwareProfile,
    *,
    kernel: str = "gemm",
    dtype: str = "float32",
    tier: str = "HBM",
    fingerprint: str = "",
    nstreams_options: Sequence[int] = (1, 2),
    nbuf_options: Sequence[int] = (1, 2, 3),
    write_back_options: Sequence[bool] = (True,),
    traversal_options: Sequence[str] = ("col", "serpentine", "blocked",
                                        "zmorton"),
    evict_options: Sequence[str] = ("lru", "belady"),
    max_steps: int = 2048,
    fault_rate: float = 0.0,
    fault_model: Optional[FaultModel] = None,
) -> TunedPlan:
    """Exhaustively rank the pruned GEMM/SYRK space under ``profile``.

    ``fault_rate`` (or an explicit ``fault_model``) ranks candidates by
    *expected* makespan under the simulator's faulted mode (DESIGN.md
    §12) — plans with more transfer ops pay proportionally more retry
    tax, so the winner can differ from the fault-free one.

    Element size derives from ``dtype`` (the plan embeds both; deriving
    keeps the searched bytes and the reconstructed partition consistent).
    Traversal and eviction policy are searched jointly with the pipeline
    shape: Belady never *misses* more than LRU on a static schedule, but
    its eviction waits can stall the transfer stream behind far-future
    consumers, so makespan — not bytes — arbitrates, and the winning plan
    records both knobs so entry points replay the ranked schedule byte for
    byte.
    """
    if kernel not in ("gemm", "syrk"):
        raise ValueError(f"search_gemm cannot tune kernel {kernel!r}")
    if kernel == "syrk" and set(write_back_options) != {True}:
        # the SYRK spec has no resident-C mode; ranking a policy the
        # compiled schedule can't express would record a fictional makespan
        raise ValueError("syrk pipelines always write back; "
                         "write_back_options must be (True,)")
    bytes_per_el = np.dtype(dtype).itemsize
    if kernel == "gemm":
        spec_of = gemm_pipeline_spec
    else:
        def spec_of(part, write_back=True, traversal="col", band=None):
            return syrk_pipeline_spec(part, traversal=traversal, band=band)
    space = gemm_search_space(
        M, N, K, budget_bytes, bytes_per_el,
        nstreams_options=nstreams_options, nbuf_options=nbuf_options,
        write_back_options=write_back_options,
        traversal_options=traversal_options, evict_options=evict_options,
        max_steps=max_steps)
    if not space:
        raise ValueError(
            f"no feasible pipeline configuration for GEMM {(M, N, K)} "
            f"within {budget_bytes}B (max_steps={max_steps})")
    _count_candidates(kernel, len(space))
    fm = fault_model if fault_model is not None else (
        FaultModel(fault_rate) if fault_rate > 0 else None)

    best = None
    best_key = None
    for idx, cand in enumerate(space):
        sched = compile_pipeline(
            spec_of(cand.part, write_back=cand.write_back,
                    traversal=cand.traversal, band=cand.nbuf),
            nstreams=cand.nstreams, nbuf=cand.nbuf, evict=cand.evict)
        res = simulate(sched, profile.model_for(cand.nstreams),
                       faults=fm)
        key = _rank_key(res.makespan, cand.nstreams, cand.nbuf,
                        cand.part.bm, cand.part.bn, idx)
        if best_key is None or key < best_key:
            best, best_key = (cand, res), key

    # baseline: the hardcoded default every entry point used before tuning
    try:
        dpart = plan_gemm_partition(M, N, K, budget_bytes, bytes_per_el)
        dres = simulate(compile_pipeline(spec_of(dpart), nstreams=2, nbuf=2),
                        profile.model_for(2), faults=fm)
        baseline = dres.makespan
    except ValueError:
        baseline = float("inf")

    cand, res = best
    return TunedPlan(
        kernel=kernel,
        problem=(M, N, K),
        dtype=dtype,
        tier=tier,
        budget=budget_bytes,
        nstreams=cand.nstreams,
        nbuf=cand.nbuf,
        write_back=cand.write_back,
        params=tuple(sorted({
            "h": cand.part.h, "w": cand.part.w,
            "bm": cand.part.bm, "bn": cand.part.bn,
        }.items())),
        makespan=res.makespan,
        baseline_makespan=baseline,
        model=profile.name,
        fingerprint=fingerprint,
        traversal=cand.traversal,
        evict=cand.evict,
    )


@_observed(lambda kind, *a, **kw: f"{kind}-factor")
def search_factor(
    kind: str,
    n: int,
    panel: int,
    budget_bytes: int,
    profile: HardwareProfile,
    *,
    dtype: str = "float32",
    tier: str = "HBM",
    fingerprint: str = "",
    nstreams_options: Sequence[int] = (1, 2),
    nbuf_options: Sequence[int] = (1, 2, 3),
    lookahead_options: Sequence[int] = (0, 1, 2),
    evict_options: Sequence[str] = ("lru", "belady"),
    max_steps: int = 4096,
    fault_rate: float = 0.0,
    fault_model: Optional[FaultModel] = None,
) -> TunedPlan:
    """Rank whole-factorization pipelines under ``profile``.

    ``fault_rate``/``fault_model`` rank by expected makespan under faults
    exactly as in :func:`search_gemm`.

    A factorization's trailing shapes *shrink* every panel, so instead of
    caching one plan per trailing shape (the pre-pipeline wrapper's
    behavior: a separate search for every ``ooc_syrk`` call), the whole run
    is one search keyed by ``(n, panel)``: each candidate — panel width
    ladder x (nstreams, nbuf, lookahead) — compiles the complete
    multi-panel schedule through the production
    :func:`~repro.core.pipeline.compile_factor_pipeline` and is timed end
    to end by ``simulate()``, shrinking grids included.  The plan's params
    carry the chosen ``panel``/``bm``/``bn``/``lookahead``; the
    factored-row cache's eviction policy is searched alongside (as in
    :func:`search_gemm`, makespan arbitrates between LRU's unstalled
    transfers and Belady's fewer of them) and recorded on the plan.
    """
    if kind not in ("cholesky", "lu"):
        raise ValueError(f"search_factor cannot tune kernel {kind!r}")
    bytes_per_el = np.dtype(dtype).itemsize
    panels = []
    pw = min(panel, n)
    while pw >= 1 and len(panels) < 3:
        panels.append(pw)
        pw //= 2

    fm = fault_model if fault_model is not None else (
        FaultModel(fault_rate) if fault_rate > 0 else None)
    best = None
    best_key = None
    baseline = None       # the hardcoded default, when rankable
    seq_best = None       # best sequential candidate at the requested panel
    idx = 0
    for pw in panels:
        for ns in nstreams_options:
            for nb in nbuf_options:
                for la in lookahead_options:
                    try:
                        spec = factor_pipeline_spec(
                            n, pw, budget_bytes, bytes_per_el,
                            kind=kind, lookahead=la, nbuf=nb)
                    except ValueError:
                        continue
                    for ev in evict_options:
                        sched = compile_factor_pipeline(spec, nstreams=ns,
                                                        nbuf=nb, evict=ev)
                        if len(sched.ops) > max_steps:
                            continue
                        res = simulate(sched, profile.model_for(ns),
                                       faults=fm)
                        # sequential default: the per-panel loop every
                        # entry point ran before lookahead existed
                        if (pw == panels[0] and ns == 2 and nb == 2
                                and la == 0 and ev == "lru"):
                            baseline = res.makespan
                        if pw == panels[0] and la == 0 and ev == "lru" and (
                                seq_best is None or res.makespan < seq_best):
                            seq_best = res.makespan
                        key = (res.makespan, ns, nb, la, -spec.bm,
                               -spec.bn, idx)
                        if best_key is None or key < best_key:
                            best, best_key = (spec, ns, nb, ev, res), key
                        idx += 1
    _count_candidates(f"{kind}-factor", idx)
    if best is None:
        raise ValueError(
            f"no feasible {kind} pipeline for n={n}, panel<={panel} "
            f"within {budget_bytes}B (max_steps={max_steps})")

    spec, ns, nb, ev, res = best
    if baseline is None:
        # the exact (ns=2, nb=2, la=0) default was outside the option sets
        # or infeasible: fall back to the best sequential candidate, then
        # to the winner itself — the field must stay finite and
        # JSON-portable
        baseline = seq_best if seq_best is not None else res.makespan
    return TunedPlan(
        kernel=f"{kind}-factor",
        problem=(n, panel),
        dtype=dtype,
        tier=tier,
        budget=budget_bytes,
        nstreams=ns,
        nbuf=nb,
        write_back=True,
        params=tuple(sorted({
            "panel": spec.panel, "bm": spec.bm, "bn": spec.bn,
            "lookahead": spec.lookahead,
        }.items())),
        makespan=res.makespan,
        baseline_makespan=baseline,
        model=profile.name,
        fingerprint=fingerprint,
        evict=ev,
    )


@_observed(lambda *a, **kw: "attention")
def search_attention(
    seq_len: int,
    kv_heads: int,
    head_dim: int,
    q_heads: int,
    budget_bytes: int,
    profile: HardwareProfile,
    *,
    dtype: str = "float16",
    tier: str = "HBM",
    fingerprint: str = "",
    nstreams_options: Sequence[int] = (1, 2),
    nbuf_options: Sequence[int] = (2, 3),
    max_steps: int = 4096,
) -> TunedPlan:
    """Exhaustively rank KV block length x pipeline shape under ``profile``."""
    bytes_per_el = np.dtype(dtype).itemsize
    space = attention_search_space(
        seq_len, kv_heads, head_dim, budget_bytes, bytes_per_el,
        nstreams_options=nstreams_options, nbuf_options=nbuf_options,
        max_steps=max_steps)
    if not space:
        raise ValueError(
            f"no feasible attention configuration for S={seq_len} "
            f"within {budget_bytes}B")
    _count_candidates("attention", len(space))

    best = None
    best_key = None
    for idx, cand in enumerate(space):
        spec = attention_pipeline_spec(cand.part, kv_heads, head_dim, q_heads)
        res = simulate(compile_pipeline(spec, nstreams=cand.nstreams,
                                        nbuf=cand.nbuf),
                       profile.model_for(cand.nstreams))
        key = _rank_key(res.makespan, cand.nstreams, cand.nbuf,
                        cand.part.bs, 0, idx)
        if best_key is None or key < best_key:
            best, best_key = (cand, res), key

    try:
        dpart = plan_attention_partition(seq_len, kv_heads, head_dim,
                                         budget_bytes, bytes_per_el)
        dspec = attention_pipeline_spec(dpart, kv_heads, head_dim, q_heads)
        baseline = simulate(compile_pipeline(dspec, nstreams=2, nbuf=2),
                            profile.model_for(2)).makespan
    except ValueError:
        baseline = float("inf")

    cand, res = best
    return TunedPlan(
        kernel="attention",
        problem=(seq_len, kv_heads, head_dim, q_heads),
        dtype=dtype,
        tier=tier,
        budget=budget_bytes,
        nstreams=cand.nstreams,
        nbuf=cand.nbuf,
        write_back=False,
        params=tuple(sorted({
            "bs": cand.part.bs, "nblocks": cand.part.nblocks,
        }.items())),
        makespan=res.makespan,
        baseline_makespan=baseline,
        model=profile.name,
        fingerprint=fingerprint,
    )
