"""Plan cache: search once per (problem, dtype, tier, hardware) tuple.

Plans persist as one JSON document ``{"schema": N, "plans": {key: plan}}``
mapping cache keys to :meth:`~repro.tune.search.TunedPlan.to_json`
payloads.  A store whose ``schema`` differs from :data:`SCHEMA_VERSION` is
treated as empty: bumping the version invalidates every cached plan at
once, which matters whenever the *search space* changes shape (v2 added
traversal-order and eviction-policy search — a v1 plan would silently pin
the old column-major-only schedule).  The key format (DESIGN.md §6) is::

    <kernel>:<problem dims 'x'-joined>:<dtype>:<tier>:<budget>:<fingerprint>

e.g. ``gemm:8192x8192x8192:float32:HBM:268435456:0f3a9c...`` — everything
the plan depends on and nothing it doesn't, so a repeat call on the same
machine is a hit while a different shape, dtype, memory tier, budget or
backend re-searches.  Writes are atomic (temp file + ``os.replace``) so a
crashed run never corrupts the store; a corrupt or unreadable store is
treated as empty rather than fatal (the cache is an accelerator, not a
dependency).  ``hits``/``misses`` counters make cache behavior assertable
in tests and visible in benchmarks.
"""

from __future__ import annotations

import json
import os
import tempfile
import threading
from typing import Dict, Optional, Sequence

from repro.obs import get_observability
from repro.tune.search import TunedPlan

_ENV_VAR = "REPRO_TUNE_CACHE"

# bump whenever the planner's search space or TunedPlan semantics change in
# a way that makes previously-cached plans stale (v2: traversal x eviction
# joined the search space)
SCHEMA_VERSION = 2


def default_cache_path() -> str:
    env = os.environ.get(_ENV_VAR)
    if env:
        return env
    base = os.environ.get("XDG_CACHE_HOME",
                          os.path.join(os.path.expanduser("~"), ".cache"))
    return os.path.join(base, "repro-tune", "plans.json")


class PlanCache:
    """JSON-file-backed store of :class:`TunedPlan` keyed by problem+hardware."""

    def __init__(self, path: Optional[str] = None):
        self.path = path or default_cache_path()
        self.hits = 0
        self.misses = 0
        self._mem: Optional[Dict[str, dict]] = None
        # serializes load-modify-store within this instance; across
        # instances (or processes) the atomic os.replace below keeps the
        # store parseable — a racing writer can lose its update, never
        # corrupt the file
        self._lock = threading.Lock()

    @staticmethod
    def key(kernel: str, problem: Sequence[int], dtype: str, tier: str,
            budget: int, fingerprint: str) -> str:
        dims = "x".join(str(int(d)) for d in problem)
        return f"{kernel}:{dims}:{dtype}:{tier}:{int(budget)}:{fingerprint}"

    # -- storage ------------------------------------------------------------
    def _load(self) -> Dict[str, dict]:
        if self._mem is None:
            try:
                with open(self.path) as f:
                    data = json.load(f)
                if (isinstance(data, dict)
                        and data.get("schema") == SCHEMA_VERSION
                        and isinstance(data.get("plans"), dict)):
                    self._mem = data["plans"]
                else:
                    # other schema versions (including the flat v1 layout)
                    # predate the current search space: invalidate wholesale
                    self._mem = {}
            except (OSError, ValueError):
                self._mem = {}
        return self._mem

    def _store(self) -> None:
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d or ".", suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump({"schema": SCHEMA_VERSION, "plans": self._mem},
                          f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    # -- API ----------------------------------------------------------------
    def get(self, key: str) -> Optional[TunedPlan]:
        m = get_observability().metrics
        with self._lock:           # counters update under the lock too, so
            raw = self._load().get(key)   # concurrent gets never lose a tick
            if raw is None:
                self.misses += 1
                m.counter("repro_plancache_misses_total",
                          "plan-cache lookups that re-search").inc()
                return None
            try:
                plan = TunedPlan.from_json(raw)
            except (TypeError, KeyError, ValueError):
                self.misses += 1   # schema drift: treat as miss, overwrite
                m.counter("repro_plancache_misses_total",
                          "plan-cache lookups that re-search").inc()
                m.counter("repro_plancache_schema_drift_total",
                          "cached plans rejected as unparseable").inc()
                return None
            self.hits += 1
            m.counter("repro_plancache_hits_total",
                      "plan-cache lookups served without a search").inc()
            return plan

    def put(self, key: str, plan: TunedPlan) -> None:
        with self._lock:
            self._load()[key] = plan.to_json()
            self._store()
        get_observability().metrics.counter(
            "repro_plancache_puts_total", "plans stored").inc()

    def clear(self) -> None:
        with self._lock:
            self._mem = {}
            try:
                os.unlink(self.path)
            except OSError:
                pass

    def __len__(self) -> int:
        with self._lock:
            return len(self._load())

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._load()
