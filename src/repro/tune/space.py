"""Search-space enumeration, pruned by the generalized working-set model.

A candidate is a complete pipeline configuration — partition geometry plus
pipeline shape ``(nstreams, nbuf, write_back)``.  Feasibility is decided by
:meth:`GemmPartition.working_set_bytes(nbuf, nstreams)
<repro.core.partitioner.GemmPartition.working_set_bytes>`, the nbuf-aware
model, so a deeper pipeline is only offered block shapes its larger buffer
allocation still fits (the planner bug the tuner exists to avoid).

The block-shape ladder mirrors the default planner's geometry (aligned
halvings of each dim, M split before N); per (nstreams, nbuf) the largest
feasible ``bn`` is kept for every ``bm`` — the frontier the paper's
partitioner walks — so the space stays tens of candidates, not thousands,
and every candidate is simulated exactly once by the search.  Candidates
whose step count exceeds ``max_steps`` are dropped (compiling a
million-block schedule to rank it would dwarf the savings), and the
enumeration order is deterministic so the search (and its tie-breaks) are
reproducible.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

from repro.core.partitioner import (LANE, SUBLANE, AttentionPartition,
                                    GemmPartition, plan_attention_partition,
                                    plan_gemm_partition)
from repro.obs import get_observability


def _count_pruned(space: str, pruned: Dict[str, int]) -> None:
    """Publish per-reason pruning totals (one call per enumeration, so the
    disabled cost is a single branch)."""
    m = get_observability().metrics
    if m.enabled:
        for reason, n in pruned.items():
            if n:
                m.counter("repro_tune_candidates_pruned_total",
                          "candidates dropped before simulation").inc(
                              n, space=space, reason=reason)


@dataclasses.dataclass(frozen=True)
class GemmCandidate:
    """One point of the GEMM/SYRK space: partition + pipeline shape.

    ``baseline`` marks the hardcoded pre-tuner default (legacy planner,
    ``nstreams=2, nbuf=2``): it is kept in the space so the search can
    never lose to it, even though the legacy working-set model undercounts
    the B ping-pong by one slice and so may sit slightly above what the
    generalized model admits.

    ``traversal`` is the step order over the block grid (see
    :data:`repro.core.partitioner.TRAVERSALS`): it changes which H2D
    transfers the compiler's residency cache can elide, at identical
    working set — so it joins the search space for free.  ``evict`` is the
    cache's replacement policy: Belady elides at least as many transfers as
    LRU, but its eviction waits can stall the transfer stream on
    not-yet-run consumers, so *makespan* must arbitrate — both policies are
    enumerated and ranked."""

    part: GemmPartition
    nstreams: int
    nbuf: int
    write_back: bool = True
    baseline: bool = False
    traversal: str = "col"
    evict: str = "lru"


@dataclasses.dataclass(frozen=True)
class AttentionCandidate:
    """One point of the attention space: KV block length + pipeline shape.

    ``baseline`` marks the pre-tuner default (``plan_attention_partition``
    with ``nstreams=2, nbuf=2``), kept in the space unconditionally."""

    part: AttentionPartition
    nstreams: int
    nbuf: int
    baseline: bool = False


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _ladder(dim: int, align: int) -> List[int]:
    """Aligned halvings of ``dim`` down to one tile, largest first."""
    out = []
    b = _round_up(dim, align)
    while b >= align:
        if not out or b != out[-1]:
            out.append(b)
        if b == align:
            break
        b = max(align, _round_up(b // 2, align))
    return out


def _partition(M: int, N: int, K: int, bm: int, bn: int,
               bytes_per_el: int, budget: int) -> GemmPartition:
    return GemmPartition(M, N, K, math.ceil(M / bm), math.ceil(N / bn),
                         bm, bn, bytes_per_el, budget)


def gemm_search_space(
    M: int,
    N: int,
    K: int,
    budget_bytes: int,
    bytes_per_el: int = 4,
    nstreams_options: Sequence[int] = (1, 2),
    nbuf_options: Sequence[int] = (1, 2, 3),
    write_back_options: Sequence[bool] = (True,),
    traversal_options: Sequence[str] = ("col", "serpentine", "blocked",
                                        "zmorton"),
    evict_options: Sequence[str] = ("lru", "belady"),
    max_steps: int = 2048,
    align_m: int = SUBLANE,
    align_n: int = LANE,
) -> List[GemmCandidate]:
    """Enumerate feasible GEMM pipeline configurations, deterministically.

    The default planner's choice (legacy 2-deep working set, ``nstreams=2,
    nbuf=2``, column-major) is always included when it exists, so the
    search's best is never worse than the hardcoded default under the same
    cost oracle.  Traversals and eviction policies multiply the space
    without changing feasibility (same blocks, different order /
    different elided transfers), and "col"/"lru" enumerate first so exact
    makespan ties resolve to the paper's order and the default policy.
    """
    if budget_bytes <= 0:
        raise ValueError("budget must be positive")
    seen = set()
    out: List[GemmCandidate] = []
    pruned = {"max_steps": 0, "infeasible": 0}

    def add(part: GemmPartition, ns: int, nb: int, wb: bool,
            baseline: bool = False, traversal: str = "col",
            evict: str = "lru") -> None:
        key = (part.bm, part.bn, ns, nb, wb, traversal, evict)
        if key in seen:
            return
        # the baseline is exempt from max_steps: whatever tune=None would
        # run must stay rankable, or the tuner could fail (empty space) or
        # lose to the very default it exists to beat
        if part.nblocks > max_steps and not baseline:
            pruned["max_steps"] += 1
            return
        seen.add(key)
        out.append(GemmCandidate(part, ns, nb, wb, baseline, traversal,
                                 evict))

    # The hardcoded default, as the baseline the tuned plan must beat.
    try:
        default = plan_gemm_partition(M, N, K, budget_bytes, bytes_per_el,
                                      align_m=align_m, align_n=align_n)
        for wb in write_back_options:
            add(default, 2, 2, wb, baseline=True)
    except ValueError:
        pass

    bms = _ladder(M, align_m)
    bns = _ladder(N, align_n)
    for ns in nstreams_options:
        for nb in nbuf_options:
            for wb in write_back_options:
                for bm in bms:
                    # largest feasible bn for this bm under the nbuf-aware
                    # model — the frontier the planner walks
                    for bn in bns:
                        part = _partition(M, N, K, bm, bn,
                                          bytes_per_el, budget_bytes)
                        if part.working_set_bytes(nb, ns) <= budget_bytes:
                            for trav in traversal_options:
                                for ev in evict_options:
                                    add(part, ns, nb, wb, traversal=trav,
                                        evict=ev)
                            break
                        pruned["infeasible"] += 1
    _count_pruned("gemm", pruned)
    return out


def attention_search_space(
    seq_len: int,
    kv_heads: int,
    head_dim: int,
    budget_bytes: int,
    bytes_per_el: int = 2,
    nstreams_options: Sequence[int] = (1, 2),
    nbuf_options: Sequence[int] = (2, 3),
    max_steps: int = 4096,
    align_s: int = LANE,
) -> List[AttentionCandidate]:
    """Enumerate KV block lengths x pipeline shapes that fit the budget.

    Residency for attention is ``nbuf`` K blocks plus ``nbuf`` V blocks
    (queries and the softmax carry are negligibly small next to the cache),
    so feasibility is ``2 * nbuf * bs * kv_heads * head_dim * bpe <=
    budget``; the default planner's double-buffered choice is always
    included.
    """
    if seq_len <= 0:
        raise ValueError("seq_len must be positive")
    per_pos = 2 * kv_heads * head_dim * bytes_per_el
    seen = set()
    out: List[AttentionCandidate] = []
    pruned = {"max_steps": 0, "infeasible": 0}

    def add(part: AttentionPartition, ns: int, nb: int,
            baseline: bool = False) -> None:
        key = (part.bs, ns, nb)
        if key in seen:
            return
        if part.nblocks > max_steps and not baseline:
            pruned["max_steps"] += 1
            return
        seen.add(key)
        out.append(AttentionCandidate(part, ns, nb, baseline))

    try:
        add(plan_attention_partition(seq_len, kv_heads, head_dim,
                                     budget_bytes, bytes_per_el,
                                     align_s=align_s), 2, 2, baseline=True)
    except ValueError:
        pass

    for ns in nstreams_options:
        for nb in nbuf_options:
            for bs in _ladder(seq_len, align_s):
                if nb * bs * per_pos <= budget_bytes:
                    part = AttentionPartition(
                        seq_len, bs, math.ceil(seq_len / bs),
                        bytes_per_el, budget_bytes)
                    add(part, ns, nb)
                    break
                pruned["infeasible"] += 1
    _count_pruned("attention", pruned)
    return out
