"""Calibration: measure the machine, don't hand-enter it.

The simulator ships canned :class:`~repro.core.simulator.HardwareModel`
constants (``gpu_like``/``phi_like``/``tpu_v5e_*``) transcribed from the
paper and datasheets.  The tuner instead *measures* the current backend with
micro-benchmarks run through the same :class:`~repro.core.runtime.\
ScheduleExecutor` that executes production schedules — timed H2D/D2H slices
at two sizes separate per-op overhead from bandwidth (a two-point linear
fit), timed ``dgemm`` blocks give the sustained in-core compute rate — and
fits a :class:`HardwareProfile`.

A profile is one level above a ``HardwareModel``: it additionally records
the *engine topology* (shared vs. independent transfer engines, whether
streams split the compute core — the paper's Phi §VI observation behind
claim C5), and instantiates a concrete model per candidate stream count via
:meth:`HardwareProfile.model_for`.  That is what lets the search answer
"how many streams?" per hardware instead of hardcoding 2.

``hardware_fingerprint()`` identifies the hardware *identity* (platform,
device kind, device count, library versions) — deliberately excluding the
noisy measured rates — so plan-cache keys are stable across runs on the
same machine and invalidate when the backend changes.
"""

from __future__ import annotations

import dataclasses
import hashlib
import platform as _platform
from typing import Dict, Tuple

import numpy as np

from repro.core.simulator import HardwareModel
from repro.core.streams import (BlockRef, Device, Op, OpKind, Schedule,
                                SliceRef, StreamFactory)


@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    """Measured (or transcribed) rates plus engine topology.

    ``shared_transfer``: one engine serves both directions (Phi's offload
    path) instead of independent H2D/D2H copy engines (CUDA GPUs).
    ``shared_compute``: offload streams split the core's threads, so the
    aggregate compute rate is divided across streams at
    ``split_efficiency`` (the paper measures 549/725 ~= 0.76 on Phi 3120P
    with 2 streams) — the mechanism behind claim C5.
    """

    name: str
    h2d_bw: float                    # bytes/s
    d2h_bw: float
    flops: float                     # sustained in-core flop/s
    per_op_overhead: float = 2e-6    # s (launch/abstraction cost, claim C1)
    shared_transfer: bool = False
    shared_compute: bool = False
    split_efficiency: float = 1.0

    def model_for(self, nstreams: int = 2) -> HardwareModel:
        """Concrete engine model for a candidate stream count."""
        if nstreams < 1:
            raise ValueError("nstreams must be >= 1")
        if self.shared_transfer:
            pools = {"xfer": 1,
                     "exec": nstreams if self.shared_compute else 1}
            kind_pool = {OpKind.H2D: "xfer", OpKind.D2H: "xfer",
                         OpKind.COMPUTE: "exec"}
        else:
            pools = {"h2d": 1, "d2h": 1, "exec": 1}
            kind_pool = {OpKind.H2D: "h2d", OpKind.D2H: "d2h",
                         OpKind.COMPUTE: "exec"}
        split = nstreams if self.shared_compute else 1
        return HardwareModel(
            name=f"{self.name}-s{nstreams}",
            pools=pools,
            kind_pool=kind_pool,
            h2d_bw=self.h2d_bw,
            d2h_bw=self.d2h_bw,
            flops=self.flops,
            per_op_overhead=self.per_op_overhead,
            compute_split=split,
            split_efficiency=1.0 if split == 1 else self.split_efficiency,
        )


# --------------------------------------------------------------------------
# Canned profiles (the paper's hardware, for simulation studies and tests)
# --------------------------------------------------------------------------
def gpu_profile(flops: float = 1.16e12, pcie: float = 11e9) -> HardwareProfile:
    """K40c-like: independent copy engines, dedicated kernel engine."""
    return HardwareProfile(name="gpu-like", h2d_bw=pcie, d2h_bw=pcie,
                           flops=flops)


def phi_profile(flops: float = 0.725e12,
                pcie: float = 6.5e9) -> HardwareProfile:
    """Xeon Phi 3120P-like: shared transfer engine, thread-split compute."""
    return HardwareProfile(name="phi-like", h2d_bw=pcie, d2h_bw=pcie,
                           flops=flops, shared_transfer=True,
                           shared_compute=True, split_efficiency=0.76)


def tpu_v5e_profile() -> HardwareProfile:
    """TPU v5e VMEM tier: separate in/out DMA queues, pipelined descriptors."""
    return HardwareProfile(name="tpu-v5e-vmem", h2d_bw=819e9, d2h_bw=819e9,
                           flops=197e12, per_op_overhead=5e-8)


# --------------------------------------------------------------------------
# Fingerprint
# --------------------------------------------------------------------------
def hardware_fingerprint() -> str:
    """Stable identity of the current backend for plan-cache keys.

    Hashes platform facts, not measurements: the same machine must produce
    the same fingerprint every run, or every run would re-search.
    """
    import jax

    dev = jax.devices()[0]
    parts = (
        _platform.system(),
        _platform.machine(),
        dev.platform,
        getattr(dev, "device_kind", "unknown"),
        str(jax.device_count()),
        jax.__version__,
        np.__version__,
    )
    return hashlib.sha1("|".join(parts).encode()).hexdigest()[:16]


# --------------------------------------------------------------------------
# Micro-benchmarks through the ScheduleExecutor
# --------------------------------------------------------------------------
def _one_op_schedule(ops) -> Schedule:
    dev = Device("HBM", 0, 1 << 30)
    n = max(op.stream for op in ops) + 1
    sched = Schedule(dev, StreamFactory.create(dev, n))
    for op in ops:
        sched.issue(op)
    return sched


def _min_span(spans, tag_prefix: str) -> float:
    ts = [e - s for tag, _, s, e in spans if tag.startswith(tag_prefix)]
    if not ts:
        raise RuntimeError(f"no spans tagged {tag_prefix!r}")
    return min(ts)


def _time_h2d(rows: int, cols: int, repeats: int) -> float:
    """Best-of-``repeats`` seconds to land one (rows x cols) f32 slice on
    device, measured as an executor H2D span."""
    from repro.core.runtime import ScheduleExecutor

    X = np.ones((rows, cols), dtype=np.float32)
    best = np.inf
    for r in range(repeats):
        ex = ScheduleExecutor(record_spans=True)
        sched = _one_op_schedule([Op(
            kind=OpKind.H2D, tag="S(x[0])", stream=0,
            buffers_written=(("X", 0),),
            bytes=X.nbytes, payload=SliceRef("X", 0, rows=(0, rows)),
        )])
        ex.run(sched, operands={"X": X}, outputs={})
        best = min(best, _min_span(ex.last_spans, "S("))
    return best


def _time_d2h(rows: int, cols: int, repeats: int) -> float:
    """Best-of-``repeats`` seconds to bring one slice back to host memory
    (synchronous write-back, so the span covers the materialization)."""
    from repro.core.runtime import ScheduleExecutor

    X = np.ones((rows, cols), dtype=np.float32)
    out = np.zeros_like(X)
    best = np.inf
    for r in range(repeats):
        ex = ScheduleExecutor(record_spans=True, async_writeback=False)
        sched = _one_op_schedule([
            Op(kind=OpKind.H2D, tag="S(x[0])", stream=0,
               buffers_written=(("X", 0),),
               bytes=X.nbytes, payload=SliceRef("X", 0, rows=(0, rows))),
            Op(kind=OpKind.D2H, tag="R(x[0])", stream=0,
               buffers_read=(("X", 0),),
               bytes=X.nbytes, payload=SliceRef("X", 0, rows=(0, rows))),
        ])
        ex.run(sched, operands={"X": X}, outputs={"X": out})
        best = min(best, _min_span(ex.last_spans, "R("))
    return best


def _time_dgemm(n: int, repeats: int) -> float:
    """Best-of-``repeats`` seconds for one n x n x n ``dgemm`` block through
    the registered handler (the same op production schedules dispatch)."""
    from repro.core.runtime import ScheduleExecutor

    A = np.ones((n, n), dtype=np.float32)
    B = np.ones((n, n), dtype=np.float32)
    C = np.zeros((n, n), dtype=np.float32)
    best = np.inf
    for r in range(repeats):
        ex = ScheduleExecutor(record_spans=True)
        sched = _one_op_schedule([
            Op(kind=OpKind.H2D, tag="S(a[0])", stream=0,
               buffers_written=(("A", 0),), bytes=A.nbytes,
               payload=SliceRef("A", 0)),
            Op(kind=OpKind.H2D, tag="S(b[0])", stream=0,
               buffers_written=(("B", 0),), bytes=B.nbytes,
               payload=SliceRef("B", 0)),
            Op(kind=OpKind.H2D, tag="S(c[0])", stream=0,
               buffers_written=(("C", 0),), bytes=C.nbytes,
               payload=SliceRef("C", 0)),
            Op(kind=OpKind.COMPUTE, tag="DGEMM[0]", stream=0,
               buffers_read=(("A", 0), ("B", 0)),
               buffers_written=(("C", 0),),
               flops=2 * n**3 + 3 * n**2,
               payload=BlockRef(kernel="dgemm", index=0)),
        ])
        ex.run(sched, operands={"A": A, "B": B},
               outputs={"C": C.copy()},
               ctx={"alpha": 1.0, "beta": 0.0})
        best = min(best, _min_span(ex.last_spans, "DGEMM"))
    return best


@dataclasses.dataclass(frozen=True)
class CalibrationResult:
    profile: HardwareProfile
    fingerprint: str
    samples: Dict[str, float]        # raw best-of-N measurements


def calibrate(tier: str = "HBM",
              small: Tuple[int, int] = (256, 1024),
              large: Tuple[int, int] = (2048, 1024),
              gemm_n: int = 512,
              repeats: int = 3) -> CalibrationResult:
    """Fit a :class:`HardwareProfile` for the current backend.

    Transfers are timed at two sizes and solved as ``t = overhead +
    bytes/bw`` (two-point fit, best-of-``repeats`` to suppress scheduler
    noise); compute from one timed ``dgemm`` block.  Topology: JAX backends
    enqueue H2D, D2H and compute independently, so every tier maps to
    independent engines (the gpu-like triple); the shared-engine topologies
    remain available as canned profiles for simulation studies.
    """
    small_b = small[0] * small[1] * 4
    large_b = large[0] * large[1] * 4
    if large_b <= small_b:
        raise ValueError("large transfer must exceed small transfer")

    t_h2d_s = _time_h2d(*small, repeats)
    t_h2d_l = _time_h2d(*large, repeats)
    t_d2h_s = _time_d2h(*small, repeats)
    t_d2h_l = _time_d2h(*large, repeats)
    t_gemm = _time_dgemm(gemm_n, repeats)

    def fit(t_s: float, t_l: float) -> Tuple[float, float]:
        dt = max(t_l - t_s, 1e-9)
        bw = (large_b - small_b) / dt
        overhead = max(t_s - small_b / bw, 1e-8)
        return bw, overhead

    h2d_bw, oh_h2d = fit(t_h2d_s, t_h2d_l)
    d2h_bw, oh_d2h = fit(t_d2h_s, t_d2h_l)
    gemm_flops = 2 * gemm_n**3 + 3 * gemm_n**2
    flops = gemm_flops / max(t_gemm, 1e-9)

    profile = HardwareProfile(
        name=f"calibrated-{tier.lower()}",
        h2d_bw=h2d_bw,
        d2h_bw=d2h_bw,
        flops=flops,
        per_op_overhead=float(np.clip((oh_h2d + oh_d2h) / 2, 1e-8, 1e-3)),
    )
    return CalibrationResult(
        profile=profile,
        fingerprint=hardware_fingerprint(),
        samples={
            "h2d_small_s": t_h2d_s, "h2d_large_s": t_h2d_l,
            "d2h_small_s": t_d2h_s, "d2h_large_s": t_d2h_l,
            f"dgemm_{gemm_n}_s": t_gemm,
        },
    )
