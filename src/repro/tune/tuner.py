"""AutoTuner — the closed loop: calibrate -> search -> cache -> execute.

One object owns the three pieces: a :class:`~repro.tune.calibrate.\
HardwareProfile` (measured lazily on first use, or injected for simulation
studies and tests), a :class:`~repro.tune.cache.PlanCache`, and the search
options.  Entry points (``ooc_gemm(tune="auto")`` and friends) ask it for a
plan; repeat calls with the same problem and hardware fingerprint are
served from the cache without re-searching (``last_from_cache`` and the
``searches`` counter make that observable).

A module-level default tuner backs ``tune="auto"`` when the caller doesn't
supply one, so the calibration and cache warm-up cost is paid once per
process, not per call.
"""

from __future__ import annotations

import threading
from typing import Optional, Sequence

import numpy as np

from repro.obs import get_observability
from repro.tune.cache import PlanCache
from repro.tune.calibrate import (CalibrationResult, HardwareProfile,
                                  calibrate, hardware_fingerprint)
from repro.tune.search import (TunedPlan, search_attention, search_factor,
                               search_gemm)


class AutoTuner:
    """Plan factory for out-of-core kernels on the current hardware.

    Args:
      profile: engine model source; None measures the machine on first use.
      cache: plan store; None uses the default on-disk JSON cache.
      fingerprint: cache-key hardware identity; None derives it (from the
        calibration when one runs, else :func:`hardware_fingerprint`).
      tier: memory-tier name baked into cache keys ("HBM", "VMEM", ...).
      nstreams_options / nbuf_options / max_steps: search-space bounds.
    """

    def __init__(
        self,
        profile: Optional[HardwareProfile] = None,
        cache: Optional[PlanCache] = None,
        fingerprint: Optional[str] = None,
        tier: str = "HBM",
        nstreams_options: Sequence[int] = (1, 2),
        nbuf_options: Sequence[int] = (1, 2, 3),
        max_steps: int = 2048,
    ):
        self._profile = profile
        self._fingerprint = fingerprint
        self.cache = cache if cache is not None else PlanCache()
        self.tier = tier
        self.nstreams_options = tuple(nstreams_options)
        self.nbuf_options = tuple(nbuf_options)
        self.max_steps = max_steps
        self.calibration: Optional[CalibrationResult] = None
        self.searches = 0
        self.last_from_cache = False
        self._lock = threading.Lock()

    # -- lazy hardware identity --------------------------------------------
    @property
    def profile(self) -> HardwareProfile:
        with self._lock:
            if self._profile is None:
                self.calibration = calibrate(tier=self.tier)
                self._profile = self.calibration.profile
                if self._fingerprint is None:
                    self._fingerprint = self.calibration.fingerprint
            return self._profile

    @property
    def fingerprint(self) -> str:
        if self._fingerprint is None:
            self.profile  # calibration also fixes the fingerprint
            if self._fingerprint is None:
                self._fingerprint = hardware_fingerprint()
        return self._fingerprint

    # -- plans --------------------------------------------------------------
    def _cached_plan(self, key: str, kernel: str, search) -> TunedPlan:
        """The one cache-or-search decision every plan method funnels
        through: a ``tune.plan`` span brackets the whole decision and a
        ``plancache.get`` span isolates the lookup, so a trace shows
        whether a run planned from cache or paid for a search."""
        obs = get_observability()
        with obs.span("tune.plan", cat="tune", kernel=kernel,
                      tier=self.tier) as sp:
            with obs.span("plancache.get", cat="tune", key=key):
                plan = self.cache.get(key)
            if plan is not None:
                self.last_from_cache = True
                sp.annotate(from_cache=True)
                return plan
            self.last_from_cache = False
            self.searches += 1
            plan = search()
            self.cache.put(key, plan)
            sp.annotate(from_cache=False, makespan=plan.makespan)
            return plan

    def gemm_plan(self, M: int, N: int, K: int, budget_bytes: int,
                  dtype: str = "float32", kernel: str = "gemm") -> TunedPlan:
        dtype = np.dtype(dtype).name   # one spelling per dtype in cache keys
        key = PlanCache.key(kernel, (M, N, K), dtype, self.tier,
                            budget_bytes, self.fingerprint)
        return self._cached_plan(key, kernel, lambda: search_gemm(
            M, N, K, budget_bytes, self.profile,
            kernel=kernel, dtype=dtype, tier=self.tier,
            fingerprint=self.fingerprint,
            nstreams_options=self.nstreams_options,
            nbuf_options=self.nbuf_options,
            max_steps=self.max_steps))

    def syrk_plan(self, n: int, K: int, budget_bytes: int,
                  dtype: str = "float32") -> TunedPlan:
        return self.gemm_plan(n, n, K, budget_bytes, dtype=dtype,
                              kernel="syrk")

    def factor_plan(self, kind: str, n: int, panel: int, budget_bytes: int,
                    dtype: str = "float32") -> TunedPlan:
        """Whole-factorization plan (panel width, trailing block dims,
        streams/buffers, lookahead depth) for ``ooc_cholesky`` / ``ooc_lu``.

        One cache key — ``<kind>-factor:<n>x<panel>:...`` — covers every
        shrinking per-panel trailing shape, because the search simulates the
        complete multi-panel schedule rather than ranking each trailing
        SYRK/GEMM in isolation (the shrinking-dims path: a factorization
        would otherwise fill the cache with one entry per panel)."""
        dtype = np.dtype(dtype).name
        key = PlanCache.key(f"{kind}-factor", (n, panel), dtype, self.tier,
                            budget_bytes, self.fingerprint)
        return self._cached_plan(key, f"{kind}-factor",
                                 lambda: search_factor(
            kind, n, panel, budget_bytes, self.profile,
            dtype=dtype, tier=self.tier, fingerprint=self.fingerprint,
            nstreams_options=self.nstreams_options,
            nbuf_options=self.nbuf_options,
            max_steps=max(self.max_steps, 4096)))

    def attention_plan(self, seq_len: int, kv_heads: int, head_dim: int,
                       q_heads: int, budget_bytes: int,
                       dtype: str = "float16") -> TunedPlan:
        dtype = np.dtype(dtype).name
        key = PlanCache.key("attention", (seq_len, kv_heads, head_dim,
                                          q_heads), dtype, self.tier,
                            budget_bytes, self.fingerprint)
        return self._cached_plan(key, "attention",
                                 lambda: search_attention(
            seq_len, kv_heads, head_dim, q_heads, budget_bytes,
            self.profile,
            dtype=dtype, tier=self.tier,
            fingerprint=self.fingerprint,
            nstreams_options=self.nstreams_options,
            nbuf_options=tuple(nb for nb in self.nbuf_options if nb >= 2)
            or (2,),
            max_steps=max(self.max_steps, 4096)))


_default_tuner: Optional[AutoTuner] = None
_default_lock = threading.Lock()


def get_default_tuner() -> AutoTuner:
    """Process-wide tuner backing ``tune="auto"`` (calibrates lazily once)."""
    global _default_tuner
    with _default_lock:
        if _default_tuner is None:
            _default_tuner = AutoTuner()
        return _default_tuner


def set_default_tuner(tuner: Optional[AutoTuner]) -> None:
    """Swap (or with None, reset) the process-wide default tuner."""
    global _default_tuner
    with _default_lock:
        _default_tuner = tuner
