"""repro.tune — calibration-driven autotuning for the OOC engine.

The paper's numbers hinge on device-specific pipeline parameters (2 streams
on GPUs, 1 on Xeon Phi — claim C5; block shapes sized to each accelerator's
memory), yet hand-entered defaults travel badly.  This subsystem closes the
loop ``calibrate -> search -> cache -> execute``:

  * :mod:`repro.tune.calibrate` — measure bandwidths/flops/overheads through
    the real ScheduleExecutor; :class:`HardwareProfile` + fingerprint.
  * :mod:`repro.tune.space`     — feasible (partition, nstreams, nbuf,
    write-back) candidates, pruned by the nbuf-aware working-set model.
  * :mod:`repro.tune.search`    — rank candidates with ``simulate()`` as the
    cost oracle; returns a :class:`TunedPlan`.
  * :mod:`repro.tune.cache`     — JSON plan store keyed by
    (problem, dtype, tier, budget, hardware fingerprint).
  * :mod:`repro.tune.tuner`     — :class:`AutoTuner` facade wiring it all;
    backs ``ooc_gemm(tune="auto")`` and friends (``hclAutoTuner`` in
    ``core/api.py``).
"""

from repro.tune.cache import PlanCache, default_cache_path
from repro.tune.calibrate import (
    CalibrationResult,
    HardwareProfile,
    calibrate,
    gpu_profile,
    hardware_fingerprint,
    phi_profile,
    tpu_v5e_profile,
)
from repro.tune.search import (TunedPlan, search_attention, search_factor,
                               search_gemm)
from repro.tune.space import (
    AttentionCandidate,
    GemmCandidate,
    attention_search_space,
    gemm_search_space,
)
from repro.tune.tuner import AutoTuner, get_default_tuner, set_default_tuner

__all__ = [
    "AttentionCandidate", "AutoTuner", "CalibrationResult", "GemmCandidate",
    "HardwareProfile", "PlanCache", "TunedPlan", "attention_search_space",
    "calibrate", "default_cache_path", "gemm_search_space",
    "get_default_tuner", "gpu_profile", "hardware_fingerprint",
    "phi_profile", "search_attention", "search_factor", "search_gemm",
    "set_default_tuner", "tpu_v5e_profile",
]
