"""Version-compat shims for the jax baked into the container.

The engine targets current jax APIs but must degrade gracefully on the older
pinned toolchain (no new installs in CI): ``jax.sharding.AxisType`` and the
``axis_types=`` Mesh kwarg landed after 0.4.37, and Pallas renamed
``TPUMemorySpace`` to ``MemorySpace``.  Gate both behind one module so kernel
and launch code stays current-API-shaped.
"""

from __future__ import annotations

import jax

try:
    from jax.sharding import AxisType  # noqa: F401  (jax >= 0.5)

    HAVE_AXIS_TYPE = True
except ImportError:
    AxisType = None
    HAVE_AXIS_TYPE = False


def make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types where the kwarg exists.

    Older jax's Mesh has no tuple ``axis_types``; Auto is its only behavior,
    so dropping the kwarg is semantics-preserving.
    """
    if HAVE_AXIS_TYPE:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def tpu_memory_space():
    """Pallas TPU memory-space enum under either of its names."""
    from jax.experimental.pallas import tpu as pltpu

    ms = getattr(pltpu, "MemorySpace", None)
    return ms if ms is not None else pltpu.TPUMemorySpace


def tpu_compiler_params():
    """Pallas TPU compiler-params dataclass under either of its names."""
    from jax.experimental.pallas import tpu as pltpu

    cp = getattr(pltpu, "CompilerParams", None)
    return cp if cp is not None else pltpu.TPUCompilerParams
