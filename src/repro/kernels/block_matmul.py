"""Pallas TPU kernel: double-buffered out-of-core block GEMM.

This is MMOOC compiled into the chip.  The libhclooc pipeline maps onto the
Mosaic grid pipeline one-to-one (DESIGN.md §2):

  hclMatrixPartitioner      -> grid = (M/bm, N/bn, K/bk) + BlockSpec index maps
  S(a), S(b), S(c) H2D ops  -> automatic double-buffered HBM->VMEM DMAs
                               (Mosaic prefetches block g+1 while g computes —
                               the paper's two-stream round robin)
  DGEMM on resident blocks  -> MXU jnp.dot on VMEM refs, fp32 scratch acc
  R(c) D2H                  -> output block DMA on the last K step
  events rA/rB/rC/eA/wC     -> DMA semaphores emitted by Mosaic

The K axis is innermost and "arbitrary" (sequential) so the fp32 accumulator
lives in VMEM scratch across K steps; M and N are parallel.  Block shapes are
MXU-aligned (multiples of 128 lanes / 8 sublanes).  C = alpha*A@B + beta*C —
full DGEMM semantics, like the paper.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params, tpu_memory_space

_MS = tpu_memory_space()
_CP = tpu_compiler_params()


def _kernel(a_ref, b_ref, c_ref, out_ref, acc_ref, *, alpha, beta, k_steps):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == k_steps - 1)
    def _finalize():
        out_ref[...] = (
            alpha * acc_ref[...] + beta * c_ref[...].astype(jnp.float32)
        ).astype(out_ref.dtype)


def _pad_to(x, m0: int, m1: int):
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@functools.partial(
    jax.jit,
    static_argnames=("alpha", "beta", "block", "interpret"),
)
def block_matmul(
    a: jax.Array,
    b: jax.Array,
    c: jax.Array,
    *,
    alpha: float = 1.0,
    beta: float = 0.0,
    block: Tuple[int, int, int] = (512, 512, 512),
    interpret: bool = False,
) -> jax.Array:
    """C = alpha * a @ b + beta * c via the VMEM-streaming Pallas kernel.

    Shapes: a (M, K), b (K, N), c (M, N).  Any M/N/K — inputs are zero-padded
    up to block multiples (zero K-padding contributes nothing to the sum).

    VMEM working set per grid step (bf16 in, fp32 acc), default 512³ blocks:
    a 0.5 MB + b 0.5 MB + c 0.5 MB + out 0.5 MB + acc 1 MB ≈ 3 MB, ×2 for
    Mosaic's double buffering ≈ 6 MB ≪ 128 MB VMEM — leaves headroom for
    deeper pipelining (the nbuf > 2 regime of DESIGN.md §2).
    """
    M, K = a.shape
    K2, N = b.shape
    assert K == K2 and c.shape == (M, N), (a.shape, b.shape, c.shape)
    bm, bn, bk = block

    ap = _pad_to(a, bm, bk)
    bp = _pad_to(b, bk, bn)
    cp = _pad_to(c, bm, bn)
    Mp, Kp = ap.shape
    Np = bp.shape[1]
    grid = (Mp // bm, Np // bn, Kp // bk)

    out = pl.pallas_call(
        functools.partial(
            _kernel, alpha=alpha, beta=beta, k_steps=grid[2]
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), cp.dtype),
        scratch_shapes=[_MS.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CP(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(ap, bp, cp)
    return out[:M, :N]
