"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def gemm_ref(a, b, c=None, alpha: float = 1.0, beta: float = 0.0):
    """DGEMM contract: alpha * a @ b + beta * c, fp32 accumulation."""
    acc = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    out = alpha * acc
    if c is not None:
        out = out + beta * c.astype(jnp.float32)
    dtype = a.dtype if c is None else c.dtype
    return out.astype(dtype)


def decode_attention_ref(q, k, v, length=None):
    """Single-token GQA attention oracle.

    q: (B, H, d); k, v: (B, S, Hkv, d); length: (B,) valid cache length
    (positions >= length are masked).  Returns (B, H, d).
    """
    B, H, d = q.shape
    S, hkv = k.shape[1], k.shape[2]
    group = H // hkv
    kb = jnp.repeat(k, group, axis=2)  # (B, S, H, d)
    vb = jnp.repeat(v, group, axis=2)
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                   kb.astype(jnp.float32)) / np.sqrt(d)
    if length is not None:
        mask = jnp.arange(S)[None, None, :] < length[:, None, None]
        s = jnp.where(mask, s, -jnp.inf)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhs,bshd->bhd", p, vb.astype(jnp.float32))
    return out.astype(q.dtype)


def causal_attention_ref(q, k, v):
    """Full-sequence causal GQA attention oracle.

    q: (B, S, H, d); k, v: (B, S, Hkv, d).  Returns (B, S, H, d).
    """
    B, S, H, d = q.shape
    hkv = k.shape[2]
    group = H // hkv
    kb = jnp.repeat(k, group, axis=2)
    vb = jnp.repeat(v, group, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kb.astype(jnp.float32)) / np.sqrt(d)
    mask = jnp.tril(jnp.ones((S, S), dtype=bool))
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vb.astype(jnp.float32))
    return out.astype(q.dtype)
