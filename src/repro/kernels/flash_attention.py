"""Pallas TPU kernel: out-of-core decode attention (flash-decoding style).

The KV cache is the out-of-core operand: queries for one new token stay
resident in VMEM while K/V stream through in sequence blocks (Mosaic
double-buffers the DMAs across grid steps — the MMOOC pipeline again), with
an online-softmax carry (m, l, acc) instead of the GEMM beta-accumulate.
This realizes ``core/ooc_attention.py``'s schedule in-silicon and is the
hot kernel behind the ``decode_32k`` / ``long_500k`` serving shapes.

Layout: queries are grouped by KV head (GQA): q (B, Hkv, G, d) where
G = H // Hkv, so each grid step's MXU work is a fat (G, d) x (d, bs) matmul.
Valid cache length is per-batch in SMEM; fully-masked blocks contribute zero.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params, tpu_memory_space

_MS = tpu_memory_space()
_CP = tpu_compiler_params()

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, len_ref, out_ref, m_ref, l_ref, acc_ref,
            *, bs: int, k_steps: int, scale: float):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (G, d)
    k = k_ref[0, :, 0, :].astype(jnp.float32)      # (bs, d)
    v = v_ref[0, :, 0, :].astype(jnp.float32)      # (bs, d)

    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    offs = s * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    mask = offs < len_ref[pl.program_id(0)]        # (1, bs)
    scores = jnp.where(mask, scores, NEG_INF)      # (G, bs)

    m_prev = m_ref[:, 0]                           # (G,)
    m_new = jnp.maximum(m_prev, scores.max(axis=-1))
    p = jnp.where(mask, jnp.exp(scores - m_new[:, None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_ref[:, 0] * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(s == k_steps - 1)
    def _finalize():
        l = jnp.maximum(l_ref[:, 0], 1e-20)
        out_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def flash_decode_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    length: jax.Array,
    *,
    block_s: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Single-token GQA attention against a blocked KV cache.

    q: (B, H, d); k, v: (B, S, Hkv, d); length: (B,) int32 valid positions.
    Returns (B, H, d).  S is padded to a multiple of ``block_s`` (padded
    positions are masked by ``length``).
    """
    B, H, d = q.shape
    S, hkv = k.shape[1], k.shape[2]
    assert H % hkv == 0, (H, hkv)
    G = H // hkv
    qg = q.reshape(B, hkv, G, d)

    pad = (-S) % block_s
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    k_steps = Sp // block_s
    grid = (B, hkv, k_steps)

    out = pl.pallas_call(
        functools.partial(
            _kernel, bs=block_s, k_steps=k_steps, scale=1.0 / (d ** 0.5)
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, d), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, block_s, 1, d), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, block_s, 1, d), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec(memory_space=_MS.SMEM),
        ],
        out_specs=pl.BlockSpec((1, 1, G, d), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, hkv, G, d), q.dtype),
        scratch_shapes=[
            _MS.VMEM((G, 128), jnp.float32),  # m
            _MS.VMEM((G, 128), jnp.float32),  # l
            _MS.VMEM((G, d), jnp.float32),    # acc
        ],
        compiler_params=_CP(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qg, k, v, length.astype(jnp.int32))
    return out.reshape(B, H, d)
