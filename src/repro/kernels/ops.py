"""jit'd public wrappers for the Pallas kernels.

On the CPU container the kernels run in ``interpret=True`` mode (kernel body
executed in Python — the validation target per the brief); on TPU they lower
through Mosaic.  ``auto_interpret()`` picks per-platform so model code can
call these unconditionally.
"""

from __future__ import annotations

import jax

from repro.kernels.block_matmul import block_matmul as _block_matmul
from repro.kernels.flash_attention import (
    flash_decode_attention as _flash_decode_attention,
)


def auto_interpret() -> bool:
    return jax.devices()[0].platform != "tpu"


def block_matmul(a, b, c, *, alpha=1.0, beta=0.0, block=(512, 512, 512),
                 interpret=None):
    return _block_matmul(
        a, b, c, alpha=alpha, beta=beta, block=tuple(block),
        interpret=auto_interpret() if interpret is None else interpret,
    )


def flash_decode_attention(q, k, v, length, *, block_s=512, interpret=None):
    return _flash_decode_attention(
        q, k, v, length, block_s=block_s,
        interpret=auto_interpret() if interpret is None else interpret,
    )
