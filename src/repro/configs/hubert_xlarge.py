"""hubert-xlarge [audio]: encoder-only, wav2vec2-style backbone.

48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 [arXiv:2106.07447; unverified]
Modality frontend (conv feature extractor) is a STUB per assignment:
input_specs() provides precomputed frame embeddings (B, S, d_model).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,            # masked-unit prediction targets
    causal=False,              # bidirectional encoder: no decode shapes
    embedding_input=True,
    rope_theta=1e4,
    source="[arXiv:2106.07447; unverified]",
)
