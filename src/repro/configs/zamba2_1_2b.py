"""zamba2-1.2b [hybrid]: Mamba2 backbone + 2 shared attention blocks.

38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000, ssm_state=64
[arXiv:2411.15242; hf]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,                 # shared-block MLP
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    shared_attn_every=6,       # 6 shared-attention sites over 38 blocks
    num_shared_attn_blocks=2,  # A/B round-robin, weights shared across sites
    source="[arXiv:2411.15242; hf]",
)
