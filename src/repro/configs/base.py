"""Architecture + run configuration.

One ``ArchConfig`` per assigned architecture (exact published dims) lives in
``configs/<id>.py``; the registry resolves ``--arch <id>``.  Input shapes are
the assignment's four LM shapes; ``input_specs`` builds ShapeDtypeStruct
stand-ins (no allocation) for the dry-run.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    # --- SSM (mamba2 / rwkv6) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    # --- hybrid (zamba2): shared attention block applied every k-th layer ---
    shared_attn_every: int = 0
    num_shared_attn_blocks: int = 2
    # --- misc ---
    qkv_bias: bool = False
    causal: bool = True            # False => encoder-only (no decode shapes)
    embedding_input: bool = False  # audio/vlm: stub frontend supplies embeds
    tie_embeddings: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    # --- execution policy (hillclimb knobs) ---
    param_dtype: str = "bfloat16"
    act_dtype: str = "bfloat16"
    remat: bool = True
    block_q: int = 512             # attention q-block
    microbatch: int = 1            # gradient-accumulation steps
    moe_groups: Optional[int] = None
    # scan_layers=True: lax.scan over stacked layers (small HLO, fast
    # compile — production default).  False: fully unrolled python loops
    # (layer/chunk/microbatch), used by the dry-run because XLA's
    # cost_analysis counts a while body ONCE, not × trip count — unrolled
    # HLO is the only way to read true FLOPs/bytes/collectives off the
    # compiled artifact (EXPERIMENTS.md §Dry-run).
    scan_layers: bool = True
    source: str = ""               # provenance note [source; tier]

    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def adtype(self):
        return jnp.dtype(self.act_dtype)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        return self.replace(
            num_layers=2,
            d_model=64,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            num_experts=min(self.num_experts, 8) if self.is_moe else 0,
            num_experts_per_tok=min(self.num_experts_per_tok, 2),
            num_shared_experts=min(self.num_shared_experts, 1),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16,
            shared_attn_every=2 if self.shared_attn_every else 0,
            num_shared_attn_blocks=1 if self.shared_attn_every else 0,
            param_dtype="float32",
            act_dtype="float32",
            block_q=16,
            remat=False,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "zamba2-1.2b",
    "hubert-xlarge",
    "qwen2.5-3b",
    "codeqwen1.5-7b",
    "stablelm-1.6b",
    "llama3.2-3b",
    "rwkv6-1.6b",
    "qwen3-moe-235b-a22b",
    "deepseek-moe-16b",
    "internvl2-26b",
]

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_arch(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def cell_is_supported(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(supported, reason-if-not) for an (arch × shape) cell — DESIGN.md §5."""
    if shape.kind == "decode" and not cfg.causal:
        return False, "encoder-only arch has no autoregressive decode step"
    sub_quadratic = cfg.family in ("ssm", "hybrid")
    if shape.name == "long_500k" and not sub_quadratic:
        return False, "pure full-attention arch; 500k decode needs sub-quadratic backbone"
    return True, ""


def input_specs(cfg: ArchConfig, shape: ShapeConfig,
                max_cache_len: Optional[int] = None) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train:   tokens/labels (B, S) int32 (or frame/patch embeddings for
             stubbed-frontend archs: (B, S, D) act_dtype + labels).
    prefill: tokens (B, S).
    decode:  tokens (B,) + cache structs are produced by the model itself
             (see models.api.make_cache_specs); here we return the step inputs.
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        if cfg.embedding_input:
            return {
                "inputs": jax.ShapeDtypeStruct((B, S, cfg.d_model), cfg.adtype),
                "labels": jax.ShapeDtypeStruct((B, S), i32),
            }
        return {
            "inputs": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
    if shape.kind == "prefill":
        if cfg.embedding_input:
            return {"inputs": jax.ShapeDtypeStruct((B, S, cfg.d_model), cfg.adtype)}
        return {"inputs": jax.ShapeDtypeStruct((B, S), i32)}
    # decode: one new token against a cache of max length S.  Even
    # stubbed-frontend VLMs decode *text* tokens (the frontend only feeds
    # prefill), so decode inputs are always token ids.
    return {"inputs": jax.ShapeDtypeStruct((B,), i32)}
