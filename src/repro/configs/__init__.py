from repro.configs.base import (
    ARCH_IDS,
    ArchConfig,
    SHAPES,
    ShapeConfig,
    cell_is_supported,
    get_arch,
    input_specs,
)

__all__ = ["ARCH_IDS", "ArchConfig", "SHAPES", "ShapeConfig",
           "cell_is_supported", "get_arch", "input_specs"]
