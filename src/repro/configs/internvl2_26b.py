"""internvl2-26b [vlm]: InternLM2-20B language backbone (InternViT stubbed).

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553
[arXiv:2404.16821; hf]
Vision frontend is a STUB per assignment: input_specs() provides precomputed
patch embeddings (B, S, d_model) for train/prefill.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    embedding_input=True,
    rope_theta=1e6,
    source="[arXiv:2404.16821; hf]",
)
