"""deepseek-moe-16b [moe]: fine-grained experts, 2 shared + 64 routed top-6.

28L d_model=2048 16H (kv=16) expert d_ff=1408 vocab=102400
[arXiv:2401.06066; hf]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,                 # per-expert intermediate
    vocab_size=102400,
    num_experts=64,
    num_experts_per_tok=6,
    num_shared_experts=2,
    rope_theta=1e4,
    source="[arXiv:2401.06066; hf]",
)
