"""rwkv6-1.6b [ssm]: Finch — attention-free, data-dependent decay.

24L d_model=2048 d_ff=7168 vocab=65536 [arXiv:2404.05892; unverified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=32,              # time-mix heads (d_model / ssm_head_dim)
    num_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    ssm_state=0,               # 0 => RWKV6 (matrix state), not Mamba2
    ssm_head_dim=64,
    rope_theta=0.0,            # attention-free
    source="[arXiv:2404.05892; unverified]",
)
