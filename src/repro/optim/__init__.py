from repro.optim import adamw, compression
from repro.optim.adamw import AdamWConfig
__all__ = ["AdamWConfig", "adamw", "compression"]
