"""AdamW with global-norm clipping and ZeRO-1-style sharded states.

Optimizer moments (fp32) inherit the parameters' 2-D FSDP×TP sharding — with
params sharded over both the ``data`` and ``model`` axes, the m/v/master
state is fully distributed across all chips (ZeRO-1): 235B-param MoE fits
16 GB/chip only because of this (DESIGN.md §6).

``master`` keeps fp32 copies when params are bf16 (mixed precision).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    # fp32 master copy of bf16 params.  Disabling saves one fp32 param-size
    # buffer per chip (TPU-style stochastic-rounding-free mixed precision);
    # used when the memory roofline term dominates (see EXPERIMENTS.md §Perf).
    use_master: bool = True


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        1.0, cfg.total_steps - cfg.warmup_steps)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params, use_master: bool = True) -> Dict:
    f32_like = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "m": jax.tree.map(f32_like, params),
        "v": jax.tree.map(f32_like, params),
        "count": jnp.zeros((), jnp.int32),
    }
    if use_master:
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32), params)
    return state


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)))


def update(grads, state, params, cfg: AdamWConfig
           ) -> Tuple[Dict, Dict, Dict]:
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    b1, b2 = cfg.b1, cfg.b2
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g,
                     state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g,
                     state["v"], grads)
    c = count.astype(jnp.float32)
    mhat_s = 1.0 / (1 - b1 ** c)
    vhat_s = 1.0 / (1 - b2 ** c)
    lr = schedule(cfg, count)

    def step_one(p32, m_, v_):
        upd = (m_ * mhat_s) / (jnp.sqrt(v_ * vhat_s) + cfg.eps)
        return p32 - lr * (upd + cfg.weight_decay * p32)

    p32 = (state["master"] if "master" in state else
           jax.tree.map(lambda p: p.astype(jnp.float32), params))
    master = jax.tree.map(step_one, p32, m, v)
    new_params = jax.tree.map(
        lambda p32_, p: p32_.astype(p.dtype), master, params)
    new_state = {"m": m, "v": v, "count": count}
    if "master" in state:
        new_state["master"] = master
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics


def state_logical_axes(param_axes, use_master: bool = True) -> Dict:
    """Optimizer-state logical axes mirror the parameters'."""
    axes = {
        "m": param_axes,
        "v": param_axes,
        "count": (),
    }
    if use_master:
        axes["master"] = param_axes
    return axes
