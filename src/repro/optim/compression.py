"""int8 gradient compression with error feedback.

Used for the *cross-pod* gradient reduction (the slow DCN/ICI hop of the
multi-pod mesh): gradients are quantized to int8 with a per-tensor scale
before the ``pod``-axis psum and dequantized after; the quantization residual
is carried to the next step (error feedback), which keeps SGD/Adam unbiased
in the long run (Karimireddy et al., 2019).

Wire cost: 1 byte/element + one f32 scale per tensor, vs 4 (fp32) or 2
(bf16) — a 2–4× reduction of the inter-pod collective term.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8 quantization. Returns (q, scale)."""
    g32 = g.astype(jnp.float32)
    scale = jnp.max(jnp.abs(g32)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress(grads, error) -> Tuple[Dict, Dict]:
    """Error-feedback compression of a gradient pytree.

    Returns (pytree of (q, scale) per leaf, new error pytree).
    """
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(error)
    comp, errs = [], []
    for g, e in zip(flat_g, flat_e):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize(corrected)
        comp.append((q, s))
        errs.append(corrected - dequantize(q, s))
    return (jax.tree_util.tree_unflatten(treedef, comp),
            jax.tree_util.tree_unflatten(treedef, errs))


def init_error(params) -> Dict:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_pod_psum(grads, error, axis_name: str = "pod"):
    """Inside shard_map over the ``pod`` axis: quantize + int16 psum +
    dequantize with error feedback.  int16 accumulation is exact for up to
    256 pods of int8 payloads (|sum| <= 127*256 < 2^15).

    A shared scale (pmax of local scales — one scalar psum) makes the
    decompressed sum exact up to quantization:  sum_i q_i * s = s * psum(q).
    Returns the *mean* gradient across pods.
    """

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        s_local = jnp.max(jnp.abs(corrected)) / 127.0 + 1e-12
        s = jax.lax.pmax(s_local, axis_name)            # shared scale
        q = jnp.clip(jnp.round(corrected / s), -127, 127).astype(jnp.int8)
        qsum = jax.lax.psum(q.astype(jnp.int16), axis_name)
        npods = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        deq = qsum.astype(jnp.float32) * s / npods
        return deq, corrected - q.astype(jnp.float32) * s

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(error)
    deq, errs = [], []
    for g, e in zip(flat_g, flat_e):
        d, r = one(g, e)
        deq.append(d)
        errs.append(r)
    return (jax.tree_util.tree_unflatten(treedef, deq),
            jax.tree_util.tree_unflatten(treedef, errs))
