from repro.training.steps import (
    build_decode_step,
    build_forward_step,
    build_loss_fn,
    build_prefill_step,
    build_train_step,
    cross_entropy,
    init_train_state,
    train_state_logical_axes,
)
__all__ = ["build_decode_step", "build_forward_step", "build_loss_fn",
           "build_prefill_step", "build_train_step", "cross_entropy",
           "init_train_state", "train_state_logical_axes"]
