"""Arch-agnostic train / serve step builders.

``build_train_step`` assembles: microbatched gradient accumulation
(lax.scan), fp32 loss with stable logsumexp over the (vocab-sharded) logits,
global-norm clipping, AdamW with ZeRO-sharded state.  ``build_decode_step`` /
``build_prefill_step`` wrap the model's serving entry points.  All builders
are pure functions of (model, config) so the dry-run can lower them against
ShapeDtypeStructs with explicit in/out shardings.
"""

from __future__ import annotations

import functools
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.optim import adamw


def cross_entropy(logits, labels) -> jax.Array:
    """Mean token CE; fp32 logsumexp.

    One-hot/einsum form, NOT take_along_axis: a gather along the
    vocab-sharded logits axis makes GSPMD all-gather the full logits
    (observed: +100 GiB/device temp on train_4k); the einsum contracts the
    sharded axis locally and psums a scalar instead.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.bfloat16)
    ll = jnp.einsum("...v,...v->...", logits,
                    onehot.astype(jnp.float32))
    return (lse - ll).mean()


def build_loss_fn(model) -> Callable:
    def loss_fn(params, batch):
        logits = model.forward(params, batch["inputs"])
        return cross_entropy(logits, batch["labels"])
    return loss_fn


def init_train_state(model, key, opt_cfg: Optional[adamw.AdamWConfig] = None
                     ) -> Dict:
    params = model.init(key)
    use_master = opt_cfg.use_master if opt_cfg else True
    return {"params": params, "opt": adamw.init(params, use_master)}


def train_state_logical_axes(model, use_master: bool = True) -> Dict:
    pax = model.param_logical_axes()
    return {"params": pax,
            "opt": adamw.state_logical_axes(pax, use_master)}


def build_train_step(
    model,
    opt_cfg: adamw.AdamWConfig,
    microbatch: int = 1,
    unroll: bool = False,
) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics)."""
    loss_fn = build_loss_fn(model)
    grad_fn = jax.value_and_grad(loss_fn)

    def train_step(state, batch):
        params = state["params"]
        if microbatch > 1:
            def reshape(x):
                b = x.shape[0]
                assert b % microbatch == 0, (b, microbatch)
                return x.reshape((microbatch, b // microbatch) + x.shape[1:])
            mb = jax.tree.map(reshape, batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def acc_step(acc, b1):
                l, g = grad_fn(params, b1)
                acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(jnp.float32), acc, g)
                return acc, l

            if unroll:
                grads, ls = zeros, []
                for i in range(microbatch):
                    grads, li = acc_step(
                        grads, jax.tree.map(lambda x: x[i], mb))
                    ls.append(li)
                losses = jnp.stack(ls)
            else:
                grads, losses = jax.lax.scan(acc_step, zeros, mb)
            grads = jax.tree.map(lambda g: g / microbatch, grads)
            loss = losses.mean()
        else:
            loss, grads = grad_fn(params, batch)

        new_params, new_opt, metrics = adamw.update(
            grads, state["opt"], params, opt_cfg)
        metrics = dict(metrics, loss=loss)
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def build_forward_step(model) -> Callable:
    def forward_step(params, batch):
        logits = model.forward(params, batch["inputs"])
        return cross_entropy(logits, batch["labels"])
    return forward_step


def build_prefill_step(model, max_len: Optional[int] = None) -> Callable:
    def prefill_step(params, inputs):
        return model.prefill(params, inputs, max_len=max_len)
    return prefill_step


def build_decode_step(model) -> Callable:
    def decode_step(params, cache, inputs):
        return model.decode(params, cache, inputs)
    return decode_step
