"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (required by the brief): tests see 1 CPU device;
only ``dryrun.py`` forces 512 host devices via XLA_FLAGS before any import.
"""

from __future__ import annotations

from jax.sharding import Mesh

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16×16 = 256 chips/pod; multi-pod: 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_debug_mesh(shape=(2, 4), axes=("data", "model")) -> Mesh:
    """Small mesh for tests (requires xla_force_host_platform_device_count
    set by the test itself)."""
    return make_mesh(shape, axes)
