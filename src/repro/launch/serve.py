"""Batched serving driver: prefill a prompt batch, then decode tokens.

Exercises the full serving path (prefill -> KV/state cache -> decode loop)
on local devices.  Cache donation keeps decode steps allocation-free; the
decode step is the same function the dry-run lowers for ``decode_32k`` /
``long_500k``.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
      --batch 4 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_arch
from repro.models import get_model
from repro.training import steps as tsteps


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--greedy", action="store_true", default=True)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if not cfg.causal:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode serving")

    model = get_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    max_len = args.prompt_len + args.gen

    prompts = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)

    prefill = jax.jit(tsteps.build_prefill_step(model, max_len=max_len))
    decode = jax.jit(tsteps.build_decode_step(model), donate_argnums=(1,))

    t0 = time.time()
    logits, cache = prefill(params, prompts)
    logits = jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits, axis=-1)
    out_tokens = [tok]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits, axis=-1)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = np.stack([np.asarray(t) for t in out_tokens], axis=1)
    tput = args.batch * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"[serve] arch={cfg.name} batch={args.batch} "
          f"prompt={args.prompt_len} gen={args.gen}")
    print(f"  prefill {t_prefill*1e3:.1f} ms   decode {t_decode*1e3:.1f} ms "
          f"({tput:.1f} tok/s)")
    print(f"  sample continuation: {gen[0, :8].tolist()}")
    assert np.isfinite(np.asarray(logits)).all(), "non-finite logits"
    assert int(cache["len"][0]) == args.prompt_len + args.gen - 1
    return {"tokens": gen, "tput": tput}


if __name__ == "__main__":
    main()
