import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This proves (without hardware) that the distribution config is coherent:
``jax.jit(step, in_shardings, out_shardings).lower(...).compile()`` must
succeed on the 16×16 single-pod mesh AND the 2×16×16 multi-pod mesh for every
supported cell, with ``memory_analysis()`` showing the working set fits a
16 GB v5e chip.

The two lines above MUST stay the first statements in this file: jax locks
the device count at first backend init.

Roofline measurement (per cell, single-pod):
  * PROOF compile — production program (scan-over-layers), full depth:
    compile success, memory_analysis, per-op collective inventory.
  * COST compiles — *unrolled* programs (see configs.base.scan_layers: XLA's
    cost_analysis counts a while body once, not × trip count) at reduced
    depths L∈{2,4} (zamba2: {2,6,12} to also solve for its shared-attention
    sites).  Layer stacks are homogeneous, so
        cost(L) = base + L·per_layer   (+ sites(L)·per_site for zamba2)
    is exact; we solve for the coefficients and extrapolate FLOPs / HBM
    bytes / collective wire bytes to the full depth.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
  ... [--microbatch N] [--no-remat] [--block-q N] [--no-master] [--proof-only]
"""

import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (ARCH_IDS, SHAPES, cell_is_supported, get_arch,
                           input_specs)
from repro.distributed import (Roofline, SERVE_RULES, collective_bytes,
                               constrain, make_weight_gather, tree_shardings)
from repro.launch.mesh import make_production_mesh
from repro.models import get_model
from repro.optim import AdamWConfig, adamw
from repro.training import steps as tsteps

HBM_PER_CHIP = 16 * 2**30


def _shard_ec_hook(mesh):
    """Constraint for MoE (G, E, C, D) dispatch activations."""
    def hook(t):
        return constrain(t, ("batch", "experts", None, None), mesh)
    return hook


def _shard_assign_hook(mesh):
    """Constraint pinning MoE (G, E, C, D) buffers to model-replicated at
    the dispatch/combine boundaries (see moe_apply §Perf notes).

    History: constraining the (G, A, D) assignment dim to the model axis
    was REFUTED (572 GiB/device replicate-then-partition); the winning form
    is an explicit replicated<->expert-sharded transition.
    """
    def hook(t):
        return constrain(t, ("batch",) + (None,) * (t.ndim - 1), mesh)
    return hook


def count_params(shapes_tree) -> int:
    return int(sum(np.prod(s.shape) for s in jax.tree.leaves(shapes_tree)))


def active_params(cfg, shapes_tree) -> int:
    """MoE: count routed-expert params at top_k/E utilization."""
    total = count_params(shapes_tree)
    if not cfg.is_moe:
        return total
    flat, _ = jax.tree_util.tree_flatten_with_path(shapes_tree)
    expert = sum(
        int(np.prod(leaf.shape))
        for path, leaf in flat
        if "moe" in jax.tree_util.keystr(path)
        and "shared" not in jax.tree_util.keystr(path)
        and "router" not in jax.tree_util.keystr(path))
    frac = cfg.num_experts_per_tok / cfg.num_experts
    return int(total - expert + expert * frac)


def serialize_memory_analysis(mem) -> Dict:
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes", "peak_memory_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _serve_rules_if_fits(param_sds, mesh, budget=int(1.5 * 2**30)):
    """Serving: TP-only weight sharding when params fit comfortably per chip
    (no per-step FSDP gather); 2-D sharding otherwise.  The budget leaves
    HBM headroom for the KV cache (a 6 GiB threshold pushed internvl2-26b
    decode to 20.3 GiB — re-measured and tightened)."""
    bytes_total = sum(
        int(np.prod(s.shape)) * s.dtype.itemsize
        for s in jax.tree.leaves(param_sds))
    if bytes_total / mesh.shape["model"] <= budget:
        return SERVE_RULES
    return None


def _lower_compile(cfg, shape, mesh, use_master, microbatch,
                   weight_gather=True) -> Dict:
    """Lower + compile one program variant; return raw per-device costs."""
    wg = make_weight_gather(mesh) if weight_gather else None
    if shape.kind != "train":
        # serving with TP-only weights needs no per-step gather; archs that
        # stay 2-D-sharded in serving (params too big) keep the FSDP gather
        probe = jax.eval_shape(
            lambda: get_model(cfg).init(jax.random.PRNGKey(0)))
        if _serve_rules_if_fits(probe, mesh) is not None:
            wg = None
    # the MoE replicate-boundary (§Perf B3) trades HBM for wire: a win for
    # train_4k (grads dominate wire) but a memory regression at prefill
    # token counts (measured 23.8 -> 33.2 GiB) — train-only.
    rep_hook = _shard_assign_hook(mesh) if shape.kind == "train" else None
    model = get_model(cfg, shard_ec=_shard_ec_hook(mesh), weight_gather=wg,
                      shard_assign=rep_hook)
    opt_cfg = AdamWConfig(use_master=use_master)
    t0 = time.time()

    batch_sds = input_specs(cfg, shape)
    pod_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    pod_size = int(np.prod([mesh.shape[a] for a in pod_axes]))

    def bspec(sds):
        lead = (pod_axes if len(pod_axes) > 1 else pod_axes[0]) \
            if sds.shape and sds.shape[0] % pod_size == 0 else None
        return NamedSharding(mesh, P(lead, *([None] * (len(sds.shape) - 1))))

    batch_shardings = jax.tree.map(bspec, batch_sds)

    if shape.kind == "train":
        state_sds = jax.eval_shape(
            lambda: tsteps.init_train_state(
                model, jax.random.PRNGKey(0), opt_cfg))
        axes = tsteps.train_state_logical_axes(model, use_master)
        state_shardings = tree_shardings(axes, state_sds, mesh)
        step_fn = tsteps.build_train_step(model, opt_cfg, microbatch,
                                          unroll=not cfg.scan_layers)
        fn = jax.jit(step_fn,
                     in_shardings=(state_shardings, batch_shardings),
                     out_shardings=(state_shardings, None),
                     donate_argnums=(0,))
        lowered = fn.lower(state_sds, batch_sds)
        param_sds = state_sds["params"]
    elif shape.kind == "prefill":
        param_sds = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
        rules = _serve_rules_if_fits(param_sds, mesh)
        param_shardings = tree_shardings(
            model.param_logical_axes(), param_sds, mesh, rules)
        cache_sds = model.cache_specs(shape.global_batch, shape.seq_len)
        cache_shardings = tree_shardings(
            model.cache_logical_axes(), cache_sds, mesh)
        step_fn = tsteps.build_prefill_step(model, max_len=shape.seq_len)
        fn = jax.jit(step_fn,
                     in_shardings=(param_shardings,
                                   batch_shardings["inputs"]),
                     out_shardings=(None, cache_shardings))
        lowered = fn.lower(param_sds, batch_sds["inputs"])
    else:  # decode
        param_sds = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
        rules = _serve_rules_if_fits(param_sds, mesh)
        param_shardings = tree_shardings(
            model.param_logical_axes(), param_sds, mesh, rules)
        cache_sds = model.cache_specs(shape.global_batch, shape.seq_len)
        cache_shardings = tree_shardings(
            model.cache_logical_axes(), cache_sds, mesh)
        step_fn = tsteps.build_decode_step(model)
        fn = jax.jit(step_fn,
                     in_shardings=(param_shardings, cache_shardings,
                                   batch_shardings["inputs"]),
                     out_shardings=(None, cache_shardings),
                     donate_argnums=(1,))
        lowered = fn.lower(param_sds, cache_sds, batch_sds["inputs"])
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    cost = compiled.cost_analysis() or {}
    mem = serialize_memory_analysis(compiled.memory_analysis())
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    del hlo, compiled, lowered
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "wire": float(coll.wire_bytes),
        "by_kind": dict(coll.by_kind),
        "counts": dict(coll.counts),
        "memory": mem,
        "param_sds": param_sds,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
    }


def _cost_depths(cfg):
    """Depths for the unrolled cost compiles + full-depth reconstructor."""
    if cfg.family == "hybrid":
        e = cfg.shared_attn_every
        depths = (2, e, 2 * e)

        def solve(c2, c6, c12, key):
            m = (c12[key] - 2 * c6[key] + c2[key]) / 2.0
            s = c6[key] - c2[key] - (e - 2) * m
            b = c2[key] - 2 * m
            sites = cfg.num_layers // e
            return b + cfg.num_layers * m + sites * s
        return depths, solve

    depths = (2, 4)

    def solve(c2, c4, key):
        m = (c4[key] - c2[key]) / 2.0
        b = c2[key] - 2 * m
        return b + cfg.num_layers * m
    return depths, solve


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides: Optional[Dict] = None,
             proof_only: bool = False) -> Dict:
    """Proof compile + cost extrapolation for one cell."""
    overrides = overrides or {}
    cfg = get_arch(arch)
    cfg_over = {k: v for k, v in overrides.items()
                if k in cfg.__dataclass_fields__ and v is not None}
    cfg = cfg.replace(**cfg_over)
    shape = SHAPES[shape_name]
    ok, why = cell_is_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "SKIP", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    use_master = overrides.get("use_master", True)
    microbatch = overrides.get("microbatch") or cfg.microbatch
    weight_gather = overrides.get("weight_gather", True)

    # ---- PROOF: production scan program, full depth ----
    proof = _lower_compile(cfg.replace(scan_layers=True), shape, mesh,
                           use_master, microbatch, weight_gather)
    mem = proof["memory"]
    device_bytes = (mem.get("argument_size_in_bytes", 0)
                    - mem.get("alias_size_in_bytes", 0)
                    + mem.get("temp_size_in_bytes", 0)
                    + mem.get("output_size_in_bytes", 0))
    n_params = count_params(proof["param_sds"])
    n_active = active_params(cfg, proof["param_sds"])

    art = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "OK", "chips": chips,
        "n_params": n_params, "n_params_active": n_active,
        "memory_analysis": mem,
        "device_hbm_bytes": int(device_bytes),
        "fits_hbm": bool(device_bytes <= HBM_PER_CHIP),
        "proof_compile_s": proof["compile_s"],
        "proof_lower_s": proof["lower_s"],
        "collective_counts_scan_body": proof["counts"],
        "overrides": {k: v for k, v in overrides.items() if v is not None},
    }
    if proof_only:
        return art

    # ---- COST: unrolled reduced-depth compiles + extrapolation ----
    depths, solve = _cost_depths(cfg)
    cost_cfg = cfg.replace(scan_layers=False)
    if shape.kind != "decode":
        cost_cfg = cost_cfg.replace(
            block_q=max(cfg.block_q, shape.seq_len // 8))
    points = []
    for L in depths:
        points.append(_lower_compile(
            cost_cfg.replace(num_layers=L), shape, mesh,
            use_master, microbatch, weight_gather))

    flops = solve(*points, key="flops")
    hbm_bytes = solve(*points, key="bytes")
    wire = max(0.0, solve(*points, key="wire"))
    tokens = (shape.global_batch * shape.seq_len
              if shape.kind != "decode" else shape.global_batch)
    training = shape.kind == "train"
    rl = Roofline(flops=flops, hbm_bytes=hbm_bytes, wire_bytes=wire,
                  chips=chips,
                  model_flops=(6.0 if training else 2.0) * n_active * tokens)

    by_kind = {}
    for k in set().union(*(p["by_kind"] for p in points)):
        by_kind[k] = int(max(0.0, _solve_kind(points, k, solve)))

    art.update({
        "tokens": tokens,
        "flops_per_device": flops,
        "bytes_per_device": hbm_bytes,
        "wire_bytes_per_device": wire,
        "collectives": by_kind,
        "model_flops": rl.model_flops,
        "roofline": rl.row(),
        "cost_points": [
            {"depth": d, "flops": p["flops"], "bytes": p["bytes"],
             "wire": p["wire"], "compile_s": p["compile_s"]}
            for d, p in zip(depths, points)],
    })
    return art


def _solve_kind(points, kind, solve):
    pts = [dict(p, **{"k": p["by_kind"].get(kind, 0.0)}) for p in points]
    return solve(*pts, key="k")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--proof-only", action="store_true",
                    help="skip the cost extrapolation compiles "
                         "(multi-pod shardability pass)")
    ap.add_argument("--skip-existing", action="store_true",
                    help="skip cells whose artifact JSON already exists")
    # hillclimb overrides
    ap.add_argument("--microbatch", type=int)
    ap.add_argument("--block-q", dest="block_q", type=int)
    ap.add_argument("--moe-groups", dest="moe_groups", type=int)
    ap.add_argument("--no-remat", dest="remat", action="store_false",
                    default=None)
    ap.add_argument("--no-master", dest="use_master", action="store_false",
                    default=True)
    ap.add_argument("--no-weight-gather", dest="weight_gather",
                    action="store_false", default=True,
                    help="disable the FSDP point-of-use weight all-gather "
                         "(the pre-iteration-1 baseline)")
    args = ap.parse_args()

    overrides = {"microbatch": args.microbatch, "block_q": args.block_q,
                 "moe_groups": args.moe_groups, "use_master": args.use_master,
                 "weight_gather": args.weight_gather}
    if args.remat is False:
        overrides["remat"] = False

    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required unless --all")
        cells.append((args.arch, args.shape))

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    for arch, shape in cells:
        for mp in meshes:
            # multi-pod pass = shardability proof only; roofline table is
            # single-pod (per brief)
            proof_only = args.proof_only or mp
            name = f"{arch}__{shape}__{'multi' if mp else 'single'}"
            if args.tag:
                name += f"__{args.tag}"
            if args.skip_existing and os.path.exists(
                    os.path.join(args.out, name + ".json")):
                print(f"[SKIP-EXISTING] {name}", flush=True)
                continue
            t_cell = time.time()
            try:
                art = run_cell(arch, shape, mp, overrides,
                               proof_only=proof_only)
            except Exception as e:  # a failing cell is a bug — record it
                art = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if mp else "16x16",
                       "status": "FAIL", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]}
            art["wall_s"] = round(time.time() - t_cell, 1)
            path = os.path.join(args.out, name + ".json")
            with open(path, "w") as f:
                json.dump(art, f, indent=1)
            status = art["status"]
            extra = ""
            if status == "OK":
                extra = (f" hbm={art['device_hbm_bytes'] / 2**30:.2f}GiB"
                         f" fits={art['fits_hbm']}"
                         f" proof={art['proof_compile_s']}s")
                if "roofline" in art:
                    r = art["roofline"]
                    extra += (f" bottleneck={r['bottleneck']}"
                              f" frac={r['roofline_fraction']:.3f}")
            elif status == "SKIP":
                extra = f" ({art['reason']})"
            else:
                extra = f" ({art['error'][:200]})"
            print(f"[{status}] {name}{extra} ({art['wall_s']}s)", flush=True)


if __name__ == "__main__":
    main()
