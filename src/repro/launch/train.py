"""End-to-end training driver with checkpoint/restart and elastic resume.

Runs a real training loop on whatever devices exist (CPU here; the mesh
collapses to 1×1 for local runs, or the debug mesh under forced host
devices).  Fault-tolerance behaviors exercised:

  * ``--resume auto``: restore the latest valid checkpoint (atomic dirs),
    reshard onto the *current* mesh, seek the data pipeline to the restored
    step (no sample loss / duplication).
  * periodic async checkpointing (``--ckpt-every``).
  * deterministic seekable data (SyntheticSource) so a killed-and-restarted
    run produces bit-identical loss curves (asserted in tests).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt --resume auto
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import make_mesh

from repro.checkpoint import CheckpointManager
from repro.configs import ARCH_IDS, get_arch
from repro.data import Prefetcher, SyntheticSource
from repro.distributed import make_weight_gather, tree_shardings
from repro.models import get_model
from repro.optim import AdamWConfig
from repro.training import steps as tsteps


def make_local_mesh() -> Mesh:
    """Best-effort 2-D mesh over the available devices."""
    n = len(jax.devices())
    model = 1
    for m in (4, 2, 1):
        if n % m == 0 and m <= n:
            model = m
            break
    return make_mesh((n // model, model), ("data", "model"))


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="stablelm-1.6b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--total-steps", type=int, default=0,
                    help="LR-schedule horizon (defaults to --steps); set it "
                         "when an interrupted run will be resumed past "
                         "--steps so the schedule is restart-invariant")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", choices=["auto", "none"], default="none")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--d-model", type=int, default=0,
                    help="override width (e.g. ~100M-param example)")
    ap.add_argument("--layers", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if args.d_model:
        cfg = cfg.replace(d_model=args.d_model,
                          head_dim=args.d_model // cfg.num_heads)
    if args.layers:
        cfg = cfg.replace(num_layers=args.layers)
    cfg = cfg.replace(microbatch=args.microbatch)

    mesh = make_local_mesh()
    model = get_model(cfg, weight_gather=(
        make_weight_gather(mesh) if len(jax.devices()) > 1 else None))
    total = args.total_steps or args.steps
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=total,
                          warmup_steps=max(1, total // 10))

    state_sds = jax.eval_shape(
        lambda: tsteps.init_train_state(model, jax.random.PRNGKey(args.seed),
                                        opt_cfg))
    axes = tsteps.train_state_logical_axes(model, opt_cfg.use_master)
    state_shardings = tree_shardings(axes, state_sds, mesh)

    train_step = jax.jit(
        tsteps.build_train_step(model, opt_cfg, args.microbatch),
        in_shardings=(state_shardings, None),
        out_shardings=(state_shardings, None),
        donate_argnums=(0,))

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    if mgr and args.resume == "auto" and mgr.latest_step() is not None:
        step0 = mgr.latest_step()
        state, cursor = mgr.restore(step0, state_sds, state_shardings)
        start_step = cursor
        print(f"[resume] restored step {step0}, data cursor {cursor}")
    else:
        with mesh:
            state = jax.jit(
                lambda: tsteps.init_train_state(
                    model, jax.random.PRNGKey(args.seed), opt_cfg),
                out_shardings=state_shardings)()

    source = SyntheticSource(cfg.vocab_size, seed=args.seed)
    prefetch = Prefetcher(source, args.batch, args.seq,
                          start_step=start_step)
    n_params = sum(p.size for p in jax.tree.leaves(state["params"]))
    print(f"[train] arch={cfg.name} params={n_params/1e6:.1f}M "
          f"mesh={dict(mesh.shape)} steps={start_step}..{args.steps}")

    losses = []
    t0 = time.time()
    for step in range(start_step, args.steps):
        got_step, batch = next(prefetch)
        assert got_step == step, (got_step, step)
        state, metrics = train_step(state, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            print(f"step {step:5d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} ({dt:.1f}s)", flush=True)
        if mgr and args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, state, data_cursor=step + 1)
    if mgr:
        mgr.save(args.steps, state, data_cursor=args.steps, blocking=True)
        mgr.wait()
    prefetch.close()
    return {"final_loss": losses[-1] if losses else float("nan"),
            "losses": losses}


if __name__ == "__main__":
    main()
