"""Atomic, async, elastic checkpointing.

Fault-tolerance properties (DESIGN.md §6):

  * **Atomic**: state is written to ``<dir>/tmp.<step>`` and renamed to
    ``<dir>/step_<step>`` only after the manifest fsyncs — a crash mid-save
    never corrupts the latest valid checkpoint.
  * **Async**: ``save()`` snapshots device arrays to host and hands the file
    I/O to a background thread; training continues (call ``wait()`` before
    the next save or at exit).
  * **Elastic reshard-on-restore**: checkpoints store *logical* arrays
    (dtype/shape + bytes) with no device layout; ``restore()`` applies
    whatever shardings the *current* mesh prescribes, so a job restarted on
    a different pod count resumes seamlessly.
  * **Multi-host layout**: every leaf file is suffixed with the process
    index; on a real multi-controller pod each host saves/loads only its
    addressable shards (single-process here: process 0 owns everything).

State = {params, opt_state, step, data_cursor} — the data pipeline is
seekable by step, so restore loses no samples.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 process_index: int = 0):
        self.dir = directory
        self.keep = keep
        self.proc = process_index
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, state, data_cursor: int = 0,
             blocking: bool = False) -> None:
        self.wait()
        # snapshot to host synchronously (cheap vs I/O), then write async
        host_state = jax.tree.map(lambda x: np.asarray(x), state)

        def _write():
            tmp = os.path.join(self.dir, f"tmp.{step}.{self.proc}")
            final = os.path.join(self.dir, f"step_{step:09d}")
            os.makedirs(tmp, exist_ok=True)
            flat = _flatten(host_state)
            manifest = {"step": step, "data_cursor": data_cursor,
                        "leaves": {}}
            for name, leaf in flat.items():
                fn = f"{abs(hash(name)) & 0xFFFFFFFF:08x}.{self.proc}.npy"
                np.save(os.path.join(tmp, fn), leaf)
                manifest["leaves"][name] = {
                    "file": fn,
                    "shape": list(np.shape(leaf)),
                    "dtype": str(np.asarray(leaf).dtype),
                }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, d, "manifest.json")):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target, shardings=None):
        """Restore into the structure of ``target`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings``: matching pytree of NamedShardings
        for elastic placement on the current mesh (None -> default device).
        Returns (state, data_cursor)."""
        path = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        leaves_meta = manifest["leaves"]

        flat_t, treedef = jax.tree_util.tree_flatten_with_path(target)
        flat_s = (jax.tree_util.tree_flatten(shardings)[0]
                  if shardings is not None else [None] * len(flat_t))
        out = []
        for (kpath, tgt), shard in zip(flat_t, flat_s):
            name = jax.tree_util.keystr(kpath)
            meta = leaves_meta[name]
            arr = np.load(os.path.join(path, meta["file"]))
            expect = tuple(getattr(tgt, "shape", arr.shape))
            if tuple(arr.shape) != expect:
                raise ValueError(
                    f"checkpoint leaf {name} shape {arr.shape} != {expect}")
            if shard is not None:
                out.append(jax.device_put(arr, shard))
            else:
                out.append(jax.device_put(arr))
        return (jax.tree_util.tree_unflatten(treedef, out),
                manifest["data_cursor"])
