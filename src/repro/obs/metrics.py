"""Process-wide metric registry — labeled counters, gauges, histograms.

The paper's claims are *measured* claims (<=10 % abstraction overhead,
pipeline overlap), so the engine needs one uniform place every layer reports
into instead of the ad-hoc ``last_h2d_bytes`` / ``hits`` attributes that
accumulated per subsystem.  This module is that place: a zero-dependency
:class:`MetricRegistry` of metric *families* keyed by name, each family
holding one sample per label set.

Design constraints (DESIGN.md §10):

  * **Cheap when disabled.**  The registry starts disabled; ``inc``/``set``/
    ``observe`` check one bool and return.  Instrumented hot paths publish
    per *run*, never per op, so the disabled cost is a handful of branches
    per kernel call (guarded <2 % in ``benchmarks/bench_overhead.py``).
  * **Thread-safe.**  One registry lock serializes family creation and
    sample updates — publishes happen at run granularity, so a single lock
    is never contended enough to matter.
  * **Exportable and comparable.**  ``snapshot()`` is a plain-JSON document
    that round-trips through :meth:`MetricRegistry.from_snapshot`;
    ``to_prometheus_text()`` is the Prometheus v0.0.4 text exposition, so
    sidecars diff cleanly across runs and CI artifacts.

Naming scheme: ``repro_<layer>_<name>`` with snake_case names and
``_total`` / ``_bytes`` / ``_seconds`` unit suffixes, e.g.
``repro_executor_h2d_bytes{kernel="gemm"}``.  The fault-injection /
recovery subsystem publishes under ``repro_fault_*`` (DESIGN.md §12):
``repro_fault_injected_total``, ``repro_fault_retries_total``,
``repro_fault_replayed_ops_total``, ``repro_fault_replayed_h2d_bytes``,
``repro_fault_recoveries_total{action=...}`` and the
``repro_fault_backoff_seconds`` histogram.
"""

from __future__ import annotations

import json
import threading
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

LabelKey = Tuple[Tuple[str, str], ...]

# Default histogram buckets: log-ish spacing covering microseconds..minutes,
# which is the span of everything the engine times (op launch to factorization
# wall time).
DEFAULT_BUCKETS = (1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0, 10.0, 60.0, 600.0)

# Retry-backoff sleeps are much shorter than op/run durations: exponential
# schedules starting at ~10ms, a handful of doublings.
BACKOFF_BUCKETS = (1e-3, 5e-3, 1e-2, 5e-2, 1e-1, 0.5, 1.0, 5.0, 30.0)


def _label_key(labels: Dict[str, str]) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _fmt_value(v: float) -> str:
    # integers print as integers so golden exposition tests are stable
    if float(v).is_integer() and abs(v) < 2**63:
        return str(int(v))
    return repr(float(v))


def _escape_label_value(v: str) -> str:
    # Prometheus text format: backslash, double quote and newline must be
    # escaped inside label values (spaces and other bytes pass through)
    return (v.replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _fmt_labels(key: LabelKey, extra: Sequence[Tuple[str, str]] = ()) -> str:
    items = list(key) + list(extra)
    if not items:
        return ""
    body = ",".join(f'{k}="{_escape_label_value(v)}"' for k, v in items)
    return "{" + body + "}"


class Metric:
    """One metric family: a name, a type, and one sample per label set."""

    kind = "untyped"

    def __init__(self, registry: "MetricRegistry", name: str, help: str = ""):
        self._reg = registry
        self.name = name
        self.help = help
        self._samples: Dict[LabelKey, float] = {}

    # -- introspection ------------------------------------------------------
    def value(self, **labels) -> float:
        with self._reg._lock:
            return self._samples.get(_label_key(labels), 0.0)

    def samples(self) -> Dict[LabelKey, float]:
        with self._reg._lock:
            return dict(self._samples)

    # -- exposition ---------------------------------------------------------
    def _expo_lines(self) -> List[str]:
        return [f"{self.name}{_fmt_labels(k)} {_fmt_value(v)}"
                for k, v in sorted(self._samples.items())]

    def _snap(self) -> dict:
        return {
            "name": self.name, "type": self.kind, "help": self.help,
            "samples": [{"labels": dict(k), "value": v}
                        for k, v in sorted(self._samples.items())],
        }

    def _restore(self, samples: Iterable[dict]) -> None:
        for s in samples:
            self._samples[_label_key(s.get("labels", {}))] = float(s["value"])


class Counter(Metric):
    """Monotone accumulator.  ``inc(n, **labels)``."""

    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        if not self._reg.enabled:
            return
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease")
        key = _label_key(labels)
        with self._reg._lock:
            self._samples[key] = self._samples.get(key, 0.0) + amount


class Gauge(Metric):
    """Point-in-time value.  ``set(v, **labels)`` / ``add(v, **labels)``."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        if not self._reg.enabled:
            return
        with self._reg._lock:
            self._samples[_label_key(labels)] = float(value)

    def add(self, amount: float, **labels) -> None:
        if not self._reg.enabled:
            return
        key = _label_key(labels)
        with self._reg._lock:
            self._samples[key] = self._samples.get(key, 0.0) + amount


class Histogram(Metric):
    """Cumulative-bucket histogram.  ``observe(v, **labels)``.

    Stored per label set as ``(bucket counts, sum, count)``; exposition
    follows Prometheus (``_bucket{le=...}`` cumulative, ``+Inf`` = count).
    """

    kind = "histogram"

    def __init__(self, registry: "MetricRegistry", name: str, help: str = "",
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        super().__init__(registry, name, help)
        self.buckets = tuple(sorted(float(b) for b in buckets))
        self._hist: Dict[LabelKey, List[float]] = {}  # [counts..., sum, count]

    def observe(self, value: float, **labels) -> None:
        if not self._reg.enabled:
            return
        key = _label_key(labels)
        with self._reg._lock:
            h = self._hist.get(key)
            if h is None:
                h = self._hist[key] = [0.0] * (len(self.buckets) + 2)
            for i, b in enumerate(self.buckets):
                if value <= b:
                    h[i] += 1
            h[-2] += float(value)
            h[-1] += 1

    def stats(self, **labels) -> Tuple[float, float]:
        """(sum, count) for one label set."""
        with self._reg._lock:
            h = self._hist.get(_label_key(labels))
            return (h[-2], h[-1]) if h else (0.0, 0.0)

    def _expo_lines(self) -> List[str]:
        lines = []
        for key, h in sorted(self._hist.items()):
            cum = 0.0
            for i, b in enumerate(self.buckets):
                cum = h[i]
                lines.append(f"{self.name}_bucket"
                             f"{_fmt_labels(key, (('le', repr(float(b))),))}"
                             f" {_fmt_value(cum)}")
            lines.append(f"{self.name}_bucket"
                         f"{_fmt_labels(key, (('le', '+Inf'),))}"
                         f" {_fmt_value(h[-1])}")
            lines.append(f"{self.name}_sum{_fmt_labels(key)} "
                         f"{_fmt_value(h[-2])}")
            lines.append(f"{self.name}_count{_fmt_labels(key)} "
                         f"{_fmt_value(h[-1])}")
        return lines

    def _snap(self) -> dict:
        return {
            "name": self.name, "type": self.kind, "help": self.help,
            "buckets": list(self.buckets),
            "samples": [
                {"labels": dict(k),
                 "counts": [c for c in h[:-2]],
                 "sum": h[-2], "count": h[-1]}
                for k, h in sorted(self._hist.items())
            ],
        }

    def _restore(self, samples: Iterable[dict]) -> None:
        for s in samples:
            self._hist[_label_key(s.get("labels", {}))] = (
                [float(c) for c in s["counts"]]
                + [float(s["sum"]), float(s["count"])])


class MetricRegistry:
    """Registry of metric families.  ``counter``/``gauge``/``histogram`` are
    get-or-create (idempotent per name); re-declaring a name as a different
    type raises."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._lock = threading.RLock()
        self._metrics: Dict[str, Metric] = {}

    # -- family factories ---------------------------------------------------
    def _family(self, cls, name: str, help: str, **kw) -> Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(self, name, help, **kw)
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as {m.kind}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._family(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._family(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._family(Histogram, name, help, buckets=buckets)

    def get(self, name: str) -> Optional[Metric]:
        with self._lock:
            return self._metrics.get(name)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._metrics)

    def reset(self) -> None:
        """Drop every family (sidecar emission resets between sections)."""
        with self._lock:
            self._metrics.clear()

    # -- exposition ---------------------------------------------------------
    def snapshot(self) -> dict:
        """Plain-JSON document; round-trips via :meth:`from_snapshot`."""
        with self._lock:
            return {"metrics": [self._metrics[n]._snap()
                                for n in sorted(self._metrics)]}

    def to_json(self, indent: Optional[int] = 1) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)

    def to_prometheus_text(self) -> str:
        """Prometheus v0.0.4 text exposition (# HELP / # TYPE + samples)."""
        lines: List[str] = []
        with self._lock:
            for name in sorted(self._metrics):
                m = self._metrics[name]
                if m.help:
                    lines.append(f"# HELP {name} {m.help}")
                lines.append(f"# TYPE {name} {m.kind}")
                lines.extend(m._expo_lines())
        return "\n".join(lines) + "\n"

    @classmethod
    def from_snapshot(cls, snap: dict,
                      enabled: bool = True) -> "MetricRegistry":
        """Rebuild a registry from :meth:`snapshot` output (or its JSON)."""
        if isinstance(snap, str):
            snap = json.loads(snap)
        reg = cls(enabled=enabled)
        for m in snap.get("metrics", ()):
            kind = m.get("type", "counter")
            if kind == "counter":
                fam: Metric = reg.counter(m["name"], m.get("help", ""))
            elif kind == "gauge":
                fam = reg.gauge(m["name"], m.get("help", ""))
            elif kind == "histogram":
                fam = reg.histogram(m["name"], m.get("help", ""),
                                    buckets=m.get("buckets",
                                                  DEFAULT_BUCKETS))
            else:
                raise ValueError(f"unknown metric type {kind!r}")
            fam._restore(m.get("samples", ()))
        return reg
