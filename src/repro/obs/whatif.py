"""What-if sensitivity: which resource buys the next makespan reduction?

The attribution layer (:mod:`repro.obs.analyze`) names the bottleneck; this
module *quantifies the alternatives*: re-run ``simulate()`` under scaled
:class:`~repro.tune.calibrate.HardwareProfile` knobs — transfer bandwidth
×k, compute rate ×k, one stream more/fewer, one pipeline buffer more/fewer
— and report the marginal makespan gain of each.  Bandwidth and flops
scenarios reuse the baseline schedule under a replaced profile; stream and
buffer scenarios recompile through ``compile_fn`` because the pipeline
shape (and, via the partitioner, the block geometry) changes with them.

This is also the explanation layer for tuner choices (claim C5): on the
canned gpu profile at the paper's 8192³ fp64 regime, "+1 stream" from a
1-stream baseline gains roughly a full transfer phase — more than
"bandwidth ×1.25" — which is *why* the tuner picks 2 streams; on the
phi-like profile "+1 stream" has negative gain (the 0.76 thread-split
efficiency), so among the stream/buffer/bandwidth knobs more bandwidth
helps most and the tuner stays at 1 stream.  ``tests/test_analyze.py``
pins both rankings.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

from repro.core.simulator import simulate
from repro.core.streams import Schedule

#: knob families a scenario can belong to
KNOBS = ("baseline", "bandwidth", "flops", "streams", "buffers")

CompileFn = Callable[[int, int], Schedule]     # (nstreams, nbuf) -> Schedule


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One simulated configuration next to the baseline."""

    name: str
    knob: str                 # one of KNOBS
    nstreams: int
    nbuf: int
    makespan: float           # inf when infeasible
    gain_seconds: float       # baseline - makespan (negative = worse)
    speedup: float            # baseline / makespan
    feasible: bool = True
    note: str = ""

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class WhatIfReport:
    """Baseline + scenarios, ranked by marginal makespan gain."""

    baseline: Scenario
    scenarios: List[Scenario]

    def ranked(self, knobs: Optional[Sequence[str]] = None
               ) -> List[Scenario]:
        """Feasible non-baseline scenarios, best gain first (optionally
        restricted to a knob subset, e.g. the purchasable resources)."""
        out = [s for s in self.scenarios
               if s.feasible and s.knob != "baseline"
               and (knobs is None or s.knob in knobs)]
        return sorted(out, key=lambda s: (-s.gain_seconds, s.name))

    def best(self, knobs: Optional[Sequence[str]] = None
             ) -> Optional[Scenario]:
        r = self.ranked(knobs)
        return r[0] if r else None

    def scenario(self, name: str) -> Scenario:
        for s in self.scenarios:
            if s.name == name:
                return s
        raise KeyError(name)

    def to_json(self) -> dict:
        return {
            "baseline": self.baseline.to_json(),
            "scenarios": [s.to_json() for s in self.scenarios],
            "ranked": [s.name for s in self.ranked()],
        }


def whatif(compile_fn: CompileFn, profile, nstreams: int, nbuf: int,
           *, scale: float = 1.25) -> WhatIfReport:
    """Sensitivity table around the ``(nstreams, nbuf)`` baseline.

    ``compile_fn(nstreams, nbuf)`` must return the schedule for that
    configuration (raising ``ValueError`` marks the scenario infeasible —
    e.g. a buffer count the memory budget cannot hold).
    """
    base_sched = compile_fn(nstreams, nbuf)
    base_span = simulate(base_sched, profile.model_for(nstreams)).makespan
    baseline = Scenario(name="baseline", knob="baseline",
                        nstreams=nstreams, nbuf=nbuf, makespan=base_span,
                        gain_seconds=0.0, speedup=1.0)
    scenarios: List[Scenario] = [baseline]

    def add(name: str, knob: str, ns: int, nb: int,
            run: Callable[[], float], note: str = "") -> None:
        try:
            span = run()
        except ValueError as e:
            scenarios.append(Scenario(
                name=name, knob=knob, nstreams=ns, nbuf=nb,
                makespan=float("inf"), gain_seconds=float("-inf"),
                speedup=0.0, feasible=False, note=str(e)))
            return
        scenarios.append(Scenario(
            name=name, knob=knob, nstreams=ns, nbuf=nb, makespan=span,
            gain_seconds=base_span - span,
            speedup=base_span / span if span > 0 else float("inf"),
            note=note))

    bw = dataclasses.replace(profile, h2d_bw=profile.h2d_bw * scale,
                             d2h_bw=profile.d2h_bw * scale)
    add(f"bandwidth x{scale:g}", "bandwidth", nstreams, nbuf,
        lambda: simulate(base_sched, bw.model_for(nstreams)).makespan,
        note="same schedule, scaled transfer rates")
    fl = dataclasses.replace(profile, flops=profile.flops * scale)
    add(f"flops x{scale:g}", "flops", nstreams, nbuf,
        lambda: simulate(base_sched, fl.model_for(nstreams)).makespan,
        note="same schedule, scaled compute rate")

    def reconfig(ns: int, nb: int) -> Callable[[], float]:
        return lambda: simulate(compile_fn(ns, nb),
                                profile.model_for(ns)).makespan

    add("+1 stream", "streams", nstreams + 1, nbuf,
        reconfig(nstreams + 1, nbuf), note="recompiled pipeline")
    if nstreams > 1:
        add("-1 stream", "streams", nstreams - 1, nbuf,
            reconfig(nstreams - 1, nbuf), note="recompiled pipeline")
    add("+1 buffer", "buffers", nstreams, nbuf + 1,
        reconfig(nstreams, nbuf + 1), note="recompiled pipeline")
    if nbuf > 1:
        add("-1 buffer", "buffers", nstreams, nbuf - 1,
            reconfig(nstreams, nbuf - 1), note="recompiled pipeline")

    return WhatIfReport(baseline=baseline, scenarios=scenarios)


def whatif_gemm(M: int, N: int, K: int, budget_bytes: int, profile, *,
                kernel: str = "gemm", dtype: str = "float32",
                nstreams: int = 2, nbuf: int = 2, traversal: str = "col",
                evict: str = "lru", write_back: bool = True,
                scale: float = 1.25) -> WhatIfReport:
    """What-if table for a GEMM/SYRK problem: each stream/buffer scenario
    re-partitions (the working set depends on both) and recompiles through
    the production pipeline compiler."""
    import numpy as np

    from repro.core.partitioner import plan_gemm_partition
    from repro.core.pipeline import (compile_pipeline, gemm_pipeline_spec,
                                     syrk_pipeline_spec)

    bpe = np.dtype(dtype).itemsize

    def compile_fn(ns: int, nb: int) -> Schedule:
        part = plan_gemm_partition(M, N, K, budget_bytes, bpe,
                                   nbuf=nb, nstreams=ns)
        if kernel == "gemm":
            spec = gemm_pipeline_spec(part, write_back=write_back,
                                      traversal=traversal, band=nb)
        elif kernel == "syrk":
            spec = syrk_pipeline_spec(part, traversal=traversal, band=nb)
        else:
            raise ValueError(f"whatif_gemm cannot compile {kernel!r}")
        return compile_pipeline(spec, nstreams=ns, nbuf=nb, evict=evict)

    return whatif(compile_fn, profile, nstreams, nbuf, scale=scale)


def whatif_plan(plan, profile, *, scale: float = 1.25) -> WhatIfReport:
    """What-if table around a :class:`~repro.tune.search.TunedPlan`'s
    configuration, replaying its traversal/eviction choices.

    The baseline replays the plan's *stored* block geometry
    (``plan.gemm_partition()``) — the tuner searches geometry directly and
    can pick blocks the budget-driven partitioner would refuse — while
    changed stream/buffer counts re-partition; when the plan's budget
    cannot hold the changed configuration the scenario simply reports
    infeasible."""
    import numpy as np

    from repro.core.partitioner import plan_gemm_partition
    from repro.core.pipeline import (compile_pipeline, gemm_pipeline_spec,
                                     syrk_pipeline_spec)

    if plan.kernel not in ("gemm", "syrk"):
        raise ValueError(f"whatif_plan cannot recompile {plan.kernel!r}")
    M, N, K = plan.problem
    bpe = np.dtype(plan.dtype).itemsize

    def compile_fn(ns: int, nb: int) -> Schedule:
        if (ns, nb) == (plan.nstreams, plan.nbuf):
            part = plan.gemm_partition()
        else:
            part = plan_gemm_partition(M, N, K, plan.budget, bpe,
                                       nbuf=nb, nstreams=ns)
        if plan.kernel == "gemm":
            spec = gemm_pipeline_spec(part, write_back=plan.write_back,
                                      traversal=plan.traversal, band=nb)
        else:
            spec = syrk_pipeline_spec(part, traversal=plan.traversal,
                                      band=nb)
        return compile_pipeline(spec, nstreams=ns, nbuf=nb,
                                evict=plan.evict)

    return whatif(compile_fn, profile, plan.nstreams, plan.nbuf,
                  scale=scale)
