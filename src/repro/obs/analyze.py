"""Bottleneck attribution over the unified span timeline (DESIGN.md §11).

The observability layer (§10) records *what* happened — spans, byte
counters, drift ratios.  This module explains *why a run took as long as it
did*: :class:`TraceAnalysis` consumes a span timeline (the simulator's
``SimResult.op_spans``, an executor's wall-clock ``last_spans``, or a
Tracer flat group) together with the :class:`~repro.core.streams.Schedule`
that produced it, and computes

  * **per-stream utilization** — busy/idle segmentation of every stream,
    with each idle gap attributed to the event or engine the stream was
    waiting on;
  * **the exact critical path** — the chain of ops that tiles
    ``[0, makespan]`` with no gaps, reconstructed backward through the
    schedule's dependency event graph, each segment classified as
    ``h2d`` / ``d2h`` / ``compute`` / ``merge`` / ``eviction-stall``;
  * **a bottleneck verdict** — transfer-bound, compute-bound or
    dependency-bound, from the critical path's class shares.

Exactness.  ``simulate()`` places every op at ``start = max(stream-free,
engine-free, waited-event times)``: each component is the *end* of some
already-placed op (or 0.0), so every op's start equals a predecessor's end
as an exact float.  The backward walk therefore finds, for every op on the
path, a certificate predecessor — its stream predecessor, a waited event's
recorder, or a same-pool op (engine contention) — whose end *equals* its
start, and the resulting segments tile ``[0, makespan]`` with float-exact
abutment.  ``tests/test_analyze.py`` pins this reconciliation across GEMM,
SYRK, Cholesky-with-lookahead and hybrid gpu+phi runs.

Wall-clock spans (``TraceAnalysis.from_spans`` with ``tolerance > 0``) get
the best-effort version: predecessors match within the tolerance, real host
gaps appear as ``idle-wait`` filler segments, and ``exact`` is False.

Eviction stalls.  An event edge whose *successor* is an H2D op means the
transfer was issued but gated on a buffer release — a block-cache eviction
wait in the GEMM/SYRK pipelines (H2D ops wait on nothing else there), a
write-back-before-restream ordering in the factor pipelines.  The tail of
the blocking op's segment, from the moment the stalled transfer's stream
went idle, is reclassified ``eviction-stall`` so "time spent waiting to
transfer" is attributed separately from "time spent transferring".
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.simulator import HardwareModel, SimResult
from repro.core.streams import Op, OpKind, Schedule

FlatSpan = Tuple[str, int, float, float]      # (tag, stream, start, end)

#: every class a critical-path segment can carry
PATH_CLASSES = ("h2d", "d2h", "compute", "merge", "eviction-stall",
                "idle-wait")

#: bottleneck verdicts, from the critical path's class shares
VERDICTS = ("transfer-bound", "compute-bound", "dependency-bound")


def _op_class(op: Op) -> str:
    if op.kind == OpKind.H2D:
        return "h2d"
    if op.kind == OpKind.D2H:
        return "d2h"
    return "merge" if op.tag.lower().startswith("merge") else "compute"


@dataclasses.dataclass(frozen=True)
class PathSegment:
    """One interval of the critical path (``[start, end)``)."""

    tag: str                 # op tag ("(waiting)" for idle-wait filler)
    stream: int              # issuing stream (-1 for filler)
    start: float
    end: float
    cls: str                 # one of PATH_CLASSES
    detail: str = ""         # event name / stalled transfer / pool

    @property
    def duration(self) -> float:
        return self.end - self.start

    def to_json(self) -> dict:
        return {"tag": self.tag, "stream": self.stream,
                "start": self.start, "end": self.end,
                "class": self.cls, "detail": self.detail,
                "seconds": self.duration}


@dataclasses.dataclass(frozen=True)
class StreamStats:
    """Busy/idle accounting for one stream over ``[0, makespan]``."""

    stream: int
    n_ops: int
    busy_seconds: float
    idle_seconds: float
    utilization: float

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class IdleGap:
    """One idle interval of a stream, attributed to what it waited on."""

    stream: int
    start: float
    end: float
    next_tag: str            # the op that ran when the gap closed ("" = none)
    cause: str               # "event rC[3] <- DGEMM[3]" / "h2d engine busy.."

    @property
    def duration(self) -> float:
        return self.end - self.start

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["seconds"] = self.duration
        return d


class _Placed:
    """One op matched to its span (start/end on the run's timeline)."""

    __slots__ = ("op", "stream", "idx", "start", "end", "pool")

    def __init__(self, op: Op, stream: int, idx: int, start: float,
                 end: float, pool: str):
        self.op = op
        self.stream = stream
        self.idx = idx
        self.start = start
        self.end = end
        self.pool = pool


def _place(sched: Schedule, spans: Sequence[FlatSpan],
           hw: Optional[HardwareModel]
           ) -> Tuple[List[_Placed], List[List[_Placed]]]:
    """Pair every span with its scheduled op.

    Streams execute their ops in issue order, so the spans of one stream —
    sorted by start — zip positionally with that stream's op list; tags are
    cross-checked so a span list from a *different* schedule is rejected
    instead of silently mis-attributed.
    """
    per: Dict[int, List[FlatSpan]] = defaultdict(list)
    for sp in spans:
        per[sp[1]].append(sp)
    unknown = set(per) - set(range(len(sched.streams)))
    if unknown:
        raise ValueError(f"spans reference streams {sorted(unknown)} "
                         f"not in the schedule")
    placed: List[_Placed] = []
    rows: List[List[_Placed]] = []
    for si, st in enumerate(sched.streams):
        got = sorted(per.get(si, ()), key=lambda t: (t[2], t[3]))
        if len(got) != len(st.ops):
            raise ValueError(
                f"stream {si}: {len(got)} spans for {len(st.ops)} scheduled "
                f"ops — spans and schedule do not describe the same run")
        row: List[_Placed] = []
        for idx, (op, (tag, _, s, e)) in enumerate(zip(st.ops, got)):
            if tag != op.tag:
                raise ValueError(
                    f"stream {si} op {idx}: span tag {tag!r} does not match "
                    f"scheduled op {op.tag!r}")
            pool = hw.kind_pool[op.kind] if hw is not None else op.kind.name
            row.append(_Placed(op, si, idx, float(s), float(e), pool))
        rows.append(row)
        placed.extend(row)
    return placed, rows


class TraceAnalysis:
    """Critical path + utilization + verdict for one executed schedule.

    Build via :meth:`from_sim` (exact, the default reconciliation target),
    :meth:`from_spans` (wall-clock spans, best effort), or :meth:`analyze`
    (simulate then attribute, one call).
    """

    def __init__(self, sched: Schedule, spans: Sequence[FlatSpan],
                 makespan: Optional[float] = None,
                 hw: Optional[HardwareModel] = None,
                 tolerance: float = 0.0,
                 source: str = "sim"):
        if not spans:
            raise ValueError("cannot analyze an empty span list")
        self.schedule = sched
        self.hw = hw
        self.source = source
        self.tolerance = float(tolerance)
        self.exact = self.tolerance == 0.0
        placed, rows = _place(sched, spans, hw)
        self._placed = placed
        self._rows = rows
        self.n_ops = len(placed)
        self.origin = 0.0 if self.exact else min(p.start for p in placed)
        end = max(p.end for p in placed)
        self.makespan = float(makespan) if makespan is not None else end
        if self.exact and self.makespan != end:
            raise ValueError(
                f"makespan {self.makespan} != last span end {end}: "
                f"spans do not cover the run")
        # modeled totals, recomputed from the paired ops (reconciled against
        # SimResult / schedule_stats by verify_reconciliation)
        self.h2d_bytes = sum(p.op.bytes for p in placed
                             if p.op.kind == OpKind.H2D)
        self.d2h_bytes = sum(p.op.bytes for p in placed
                             if p.op.kind == OpKind.D2H)
        self.flops = sum(p.op.flops for p in placed
                         if p.op.kind == OpKind.COMPUTE)
        self.busy_by_pool: Dict[str, float] = {}
        for p in placed:
            self.busy_by_pool[p.pool] = (self.busy_by_pool.get(p.pool, 0.0)
                                         + (p.end - p.start))
        self._recorder: Dict[str, _Placed] = {
            p.op.records.name: p for p in placed if p.op.records is not None}
        self._by_end: Dict[float, List[_Placed]] = defaultdict(list)
        for p in placed:
            self._by_end[p.end].append(p)
        self.path = self._critical_path()
        self.class_seconds: Dict[str, float] = {}
        for seg in self.path:
            self.class_seconds[seg.cls] = (self.class_seconds.get(seg.cls,
                                                                  0.0)
                                           + seg.duration)
        span = self.makespan - self.origin
        self.shares: Dict[str, float] = {
            cls: (secs / span if span > 0 else 0.0)
            for cls, secs in self.class_seconds.items()}
        self.verdict = self._verdict()
        self.streams, self.gaps = self._stream_stats()

    # -- constructors --------------------------------------------------------
    @classmethod
    def from_sim(cls, sched: Schedule, res: SimResult,
                 hw: Optional[HardwareModel] = None) -> "TraceAnalysis":
        """Exact attribution of one ``simulate()`` result."""
        return cls(sched, res.op_spans, makespan=res.makespan, hw=hw,
                   tolerance=0.0, source="sim")

    @classmethod
    def from_spans(cls, sched: Schedule, spans: Sequence[FlatSpan],
                   hw: Optional[HardwareModel] = None,
                   tolerance: Optional[float] = None) -> "TraceAnalysis":
        """Best-effort attribution of wall-clock (executor/Tracer) spans.

        Wall times carry host scheduling noise, so predecessors match
        within ``tolerance`` (default: 1 % of the observed makespan) and
        un-certificated waiting shows up as ``idle-wait`` segments."""
        end = max(e for _, _, _, e in spans)
        tol = tolerance if tolerance is not None else max(1e-9, 0.01 * end)
        return cls(sched, spans, makespan=None, hw=hw, tolerance=tol,
                   source="spans")

    @classmethod
    def analyze(cls, sched: Schedule, hw: HardwareModel
                ) -> Tuple["TraceAnalysis", SimResult]:
        """Simulate ``sched`` under ``hw`` and attribute it, in one call."""
        from repro.core.simulator import simulate

        res = simulate(sched, hw)
        return cls.from_sim(sched, res, hw=hw), res

    # -- critical path -------------------------------------------------------
    def _ends_at(self, p: _Placed, t: float) -> bool:
        if self.exact:
            return p.end == t
        return abs(p.end - t) <= self.tolerance

    def _predecessor(self, cur: _Placed
                     ) -> Tuple[Optional[_Placed], str, str]:
        """The certificate predecessor whose end equals ``cur.start``:
        stream predecessor, waited-event recorder, or same-pool op (engine
        contention), in that preference order."""
        t = cur.start
        if cur.idx > 0:
            sp = self._rows[cur.stream][cur.idx - 1]
            if self._ends_at(sp, t):
                return sp, "stream", ""
        for ev in cur.op.waits:
            rec = self._recorder.get(ev.name)
            if rec is not None and self._ends_at(rec, t):
                return rec, "event", ev.name
        for cand in self._by_end.get(t, ()):
            if cand is not cur and cand.pool == cur.pool:
                return cand, "engine", cur.pool
        if not self.exact:
            # wall-clock fallback: the latest dependency ending at or
            # before t (+tol); any remaining gap becomes idle-wait filler
            cands: List[Tuple[str, str, _Placed]] = []
            if cur.idx > 0:
                cands.append(("stream", "",
                              self._rows[cur.stream][cur.idx - 1]))
            for ev in cur.op.waits:
                rec = self._recorder.get(ev.name)
                if rec is not None:
                    cands.append(("event", ev.name, rec))
            cands = [c for c in cands if c[2].end <= t + self.tolerance]
            if cands:
                kind, detail, pred = max(cands, key=lambda c: c[2].end)
                return pred, kind, detail
        return None, "", ""

    def _critical_path(self) -> List[PathSegment]:
        tail = max(self._placed, key=lambda p: (p.end, -p.stream))
        links: List[Tuple[_Placed, _Placed, str, str]] = []
        cur = tail
        while cur.start > self.origin + self.tolerance:
            pred, kind, detail = self._predecessor(cur)
            if pred is None:
                if self.exact:
                    raise RuntimeError(
                        f"no exact predecessor for {cur.op.tag!r} at "
                        f"t={cur.start!r}: these spans are not simulate() "
                        f"output — use from_spans(tolerance=...)")
                break
            links.append((pred, cur, kind, detail))
            cur = pred
        links.reverse()
        chain = [cur] + [succ for _, succ, _, _ in links]

        segs: List[PathSegment] = []
        prev_end = self.origin
        for i, p in enumerate(chain):
            start = max(p.start, prev_end)
            if start > prev_end:
                segs.append(PathSegment("(waiting)", -1, prev_end, start,
                                        "idle-wait", ""))
            if p.end <= start:
                prev_end = max(prev_end, p.end)
                continue
            base = _op_class(p.op)
            detail = ""
            if i > 0:
                _, _, kind, d = links[i - 1]
                detail = {"event": f"after {d}",
                          "engine": f"{d} engine busy",
                          "stream": "in-stream order"}.get(kind, "")
            link = links[i] if i < len(links) else None
            if (link is not None and link[2] == "event"
                    and link[1].op.kind == OpKind.H2D):
                # the next path op is a transfer gated on this op's event:
                # from the moment that transfer's stream went idle, this
                # op's remaining execution is an eviction stall
                succ = link[1]
                ready = (self._rows[succ.stream][succ.idx - 1].end
                         if succ.idx > 0 else self.origin)
                cut = min(max(start, ready), p.end)
                if cut > start:
                    segs.append(PathSegment(p.op.tag, p.stream, start, cut,
                                            base, detail))
                segs.append(PathSegment(
                    p.op.tag, p.stream, cut, p.end, "eviction-stall",
                    f"holding {succ.op.tag} (waits {link[3]})"))
            else:
                segs.append(PathSegment(p.op.tag, p.stream, start, p.end,
                                        base, detail))
            prev_end = p.end
        if self.makespan > prev_end:
            segs.append(PathSegment("(waiting)", -1, prev_end,
                                    self.makespan, "idle-wait", ""))
        return segs

    def _verdict(self) -> str:
        transfer = self.shares.get("h2d", 0.0) + self.shares.get("d2h", 0.0)
        compute = self.shares.get("compute", 0.0)
        if transfer >= 0.5:
            return "transfer-bound"
        if compute >= 0.5:
            return "compute-bound"
        return "dependency-bound"

    # -- streams -------------------------------------------------------------
    def _gap_cause(self, nxt: Optional[_Placed]) -> str:
        if nxt is None:
            return "drained (no further ops this stream)"
        t = nxt.start
        for ev in nxt.op.waits:
            rec = self._recorder.get(ev.name)
            if rec is not None and self._ends_at(rec, t):
                return f"event {ev.name} <- {rec.op.tag}"
        for cand in self._by_end.get(t, ()):
            if cand is not nxt and cand.pool == nxt.pool:
                return f"{nxt.pool} engine busy ({cand.op.tag})"
        return "host/dependency"

    def _stream_stats(self) -> Tuple[List[StreamStats], List[IdleGap]]:
        stats: List[StreamStats] = []
        gaps: List[IdleGap] = []
        span = self.makespan - self.origin
        for si, row in enumerate(self._rows):
            busy = sum(p.end - p.start for p in row)
            stats.append(StreamStats(
                stream=si, n_ops=len(row), busy_seconds=busy,
                idle_seconds=span - busy,
                utilization=busy / span if span > 0 else 0.0))
            prev = self.origin
            for p in row:
                if p.start > prev + self.tolerance:
                    gaps.append(IdleGap(si, prev, p.start, p.op.tag,
                                        self._gap_cause(p)))
                prev = max(prev, p.end)
            if self.makespan > prev + self.tolerance:
                gaps.append(IdleGap(si, prev, self.makespan, "",
                                    self._gap_cause(None)))
        return stats, gaps

    # -- accessors -----------------------------------------------------------
    def stream_utilization(self) -> Dict[int, float]:
        return {s.stream: s.utilization for s in self.streams}

    def pool_utilization(self, pool: str) -> float:
        span = self.makespan - self.origin
        return self.busy_by_pool.get(pool, 0.0) / span if span > 0 else 0.0

    def top_gaps(self, n: int = 5) -> List[IdleGap]:
        return sorted(self.gaps, key=lambda g: -g.duration)[:n]

    def digest(self) -> str:
        """One line: verdict, class shares, per-stream utilization."""
        shares = " ".join(f"{c}={self.shares[c]*100:.0f}%"
                          for c in PATH_CLASSES if c in self.shares)
        utils = " ".join(f"s{s.stream}={s.utilization*100:.0f}%"
                         for s in self.streams)
        return (f"{self.verdict}; critical path: {shares}; "
                f"stream utilization: {utils}")

    # -- reconciliation ------------------------------------------------------
    def verify_reconciliation(self, res: Optional[SimResult] = None,
                              stats: Optional[dict] = None) -> dict:
        """Assert the attribution's accounting is exact (raises otherwise).

        Checks: the critical path tiles ``[0, makespan]`` with float-exact
        abutment and its durations sum to the makespan; per-stream busy
        totals equal the span totals; the attributed H2D/D2H bytes and
        flops equal ``SimResult`` / ``schedule_stats`` totals; per-pool
        busy time matches the simulator's engine accounting.
        """
        assert self.exact, "reconciliation is defined for exact analyses"
        p = self.path
        assert p[0].start == 0.0, f"path starts at {p[0].start}, not 0.0"
        assert p[-1].end == self.makespan, \
            f"path ends at {p[-1].end}, not makespan {self.makespan}"
        for a, b in zip(p, p[1:]):
            assert a.end == b.start, \
                f"path gap: {a.tag} ends {a.end}, {b.tag} starts {b.start}"
        assert not any(seg.cls == "idle-wait" for seg in p), \
            "exact critical path must not contain idle-wait filler"
        total = sum(seg.duration for seg in p)
        assert abs(total - self.makespan) <= 1e-12 * max(self.makespan, 1.0)
        busy_streams = sum(s.busy_seconds for s in self.streams)
        busy_spans = sum(pl.end - pl.start for pl in self._placed)
        assert abs(busy_streams - busy_spans) <= 1e-12 * max(busy_spans, 1.0)
        out = {"critical_path_seconds": total,
               "busy_seconds": busy_spans}
        if res is not None:
            assert self.h2d_bytes == res.h2d_bytes, \
                f"h2d {self.h2d_bytes} != SimResult {res.h2d_bytes}"
            assert self.d2h_bytes == res.d2h_bytes
            assert self.flops == res.flops
            assert self.makespan == res.makespan
            for pool, b in res.busy.items():
                mine = self.busy_by_pool.get(pool, 0.0)
                assert abs(mine - b) <= 1e-9 * max(b, 1.0), \
                    f"pool {pool}: busy {mine} != simulator {b}"
        if stats is not None:
            assert self.h2d_bytes == stats["h2d_bytes"], \
                f"h2d {self.h2d_bytes} != schedule_stats {stats['h2d_bytes']}"
            assert self.d2h_bytes == stats["d2h_bytes"]
            assert self.flops == stats["flops"]
            assert self.n_ops == stats["n_ops"]
        return out

    # -- export --------------------------------------------------------------
    def to_json(self, max_path: int = 0, max_gaps: int = 10) -> dict:
        """Plain-JSON attribution document (``max_path=0`` = full path)."""
        path = self.path if max_path <= 0 else self.path[:max_path]
        return {
            "source": self.source,
            "exact": self.exact,
            "makespan_seconds": self.makespan,
            "verdict": self.verdict,
            "shares": dict(sorted(self.shares.items())),
            "class_seconds": dict(sorted(self.class_seconds.items())),
            "critical_path": [seg.to_json() for seg in path],
            "critical_path_ops": len(self.path),
            "streams": [s.to_json() for s in self.streams],
            "top_gaps": [g.to_json() for g in self.top_gaps(max_gaps)],
            "pool_busy_seconds": dict(sorted(self.busy_by_pool.items())),
            "h2d_bytes": self.h2d_bytes,
            "d2h_bytes": self.d2h_bytes,
            "flops": self.flops,
            "n_ops": self.n_ops,
        }


def analyze_plan(plan, profile) -> Tuple[TraceAnalysis, SimResult]:
    """Attribute a :class:`~repro.tune.search.TunedPlan`: recompile the
    exact schedule the tuner ranked and analyze it under the profile's
    engine model for the plan's stream count."""
    from repro.core.pipeline import (compile_pipeline, gemm_pipeline_spec,
                                     syrk_pipeline_spec)

    if plan.kernel == "gemm":
        spec = gemm_pipeline_spec(plan.gemm_partition(),
                                  write_back=plan.write_back,
                                  traversal=plan.traversal, band=plan.nbuf)
    elif plan.kernel == "syrk":
        spec = syrk_pipeline_spec(plan.gemm_partition(),
                                  traversal=plan.traversal, band=plan.nbuf)
    else:
        raise ValueError(f"analyze_plan cannot recompile {plan.kernel!r}")
    sched = compile_pipeline(spec, nstreams=plan.nstreams, nbuf=plan.nbuf,
                             evict=plan.evict)
    return TraceAnalysis.analyze(sched, profile.model_for(plan.nstreams))
