"""Simulate-vs-actual drift — when can ``tune="auto"`` still be trusted?

Every tuned execution carries a prediction: the plan's ``makespan`` came
from :func:`repro.core.simulator.simulate` under a calibrated
:class:`~repro.tune.calibrate.HardwareProfile`, and the compiled schedule's
byte totals are the modeled transfer traffic.  This module records the
*measured* wall time and executor byte counters next to those predictions,
per ``(kernel, tier, fingerprint)``, and maintains rolling drift ratios:

    time_ratio  = measured_seconds / predicted_makespan
    byte_ratio  = measured_h2d_bytes / predicted_h2d_bytes

Byte ratios must be exactly 1.0 (the executor performs the transfers the
schedule ordered; ``tests/test_obs.py`` asserts it) — any deviation is an
engine bug.  Time ratios are the calibration-staleness signal: a *stable*
ratio (even far from 1.0 — this container's wall clock is not a K40c) means
the profile still ranks candidates faithfully; a ratio that trends away
from its own history means the machine no longer matches the profile and
plans chosen by ``tune="auto"`` can no longer be trusted, so recalibrate.

The monitor is bounded (a deque per key, a capped global record list) and
thread-safe; it is always safe to call — recording into a disabled
:class:`~repro.obs.Observability` is simply skipped by the caller.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

DriftKey = Tuple[str, str, str]          # (kernel, tier, fingerprint)

_MAX_RECORDS = 1024                      # global history cap


def _ratio(measured: float, predicted: float) -> float:
    if predicted <= 0:
        return float("inf") if measured > 0 else 1.0
    return measured / predicted


@dataclasses.dataclass(frozen=True)
class DriftRecord:
    """One executed schedule's prediction next to its measurement."""

    kernel: str
    tier: str
    fingerprint: str
    predicted_makespan: float            # simulate() seconds
    measured_seconds: float              # wall clock around the executor
    predicted_h2d_bytes: int = 0         # schedule-modeled transfer totals
    measured_h2d_bytes: int = 0          # executor byte counters
    predicted_d2h_bytes: int = 0
    measured_d2h_bytes: int = 0

    @property
    def key(self) -> DriftKey:
        return (self.kernel, self.tier, self.fingerprint)

    @property
    def time_ratio(self) -> float:
        return _ratio(self.measured_seconds, self.predicted_makespan)

    @property
    def byte_ratio(self) -> float:
        return _ratio(float(self.measured_h2d_bytes),
                      float(self.predicted_h2d_bytes))

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["time_ratio"] = self.time_ratio
        d["byte_ratio"] = self.byte_ratio
        return d


def key_str(key: DriftKey) -> str:
    return "|".join(key)


class DriftMonitor:
    """Rolling predicted-vs-measured monitor per (kernel, tier, fingerprint).

    ``window`` bounds the per-key rolling ratio; the full record list is
    capped at the oldest end so long services don't grow without bound.
    """

    def __init__(self, window: int = 32):
        self.window = window
        self._lock = threading.Lock()
        self._records: Deque[DriftRecord] = deque(maxlen=_MAX_RECORDS)
        self._ratios: Dict[DriftKey, Deque[float]] = {}
        # the key's first-ever ratio: the staleness baseline.  Kept outside
        # the rolling deque — once the window rolls, ``dq[0]`` is merely the
        # oldest *surviving* ratio and drifts along with the trend it is
        # supposed to detect.
        self._first: Dict[DriftKey, float] = {}

    # -- recording -----------------------------------------------------------
    def record(self, kernel: str, tier: str, fingerprint: str, *,
               predicted_makespan: float, measured_seconds: float,
               predicted_h2d_bytes: int = 0, measured_h2d_bytes: int = 0,
               predicted_d2h_bytes: int = 0,
               measured_d2h_bytes: int = 0) -> DriftRecord:
        rec = DriftRecord(
            kernel=kernel, tier=tier, fingerprint=fingerprint,
            predicted_makespan=float(predicted_makespan),
            measured_seconds=float(measured_seconds),
            predicted_h2d_bytes=int(predicted_h2d_bytes),
            measured_h2d_bytes=int(measured_h2d_bytes),
            predicted_d2h_bytes=int(predicted_d2h_bytes),
            measured_d2h_bytes=int(measured_d2h_bytes))
        with self._lock:
            self._records.append(rec)
            dq = self._ratios.get(rec.key)
            if dq is None:
                dq = self._ratios[rec.key] = deque(maxlen=self.window)
                self._first[rec.key] = rec.time_ratio
            dq.append(rec.time_ratio)
        return rec

    # -- introspection -------------------------------------------------------
    def records(self, kernel: Optional[str] = None) -> List[DriftRecord]:
        with self._lock:
            return [r for r in self._records
                    if kernel is None or r.kernel == kernel]

    def keys(self) -> List[DriftKey]:
        with self._lock:
            return sorted(self._ratios)

    def ratio(self, kernel: str, tier: str, fingerprint: str) -> float:
        """Rolling mean time ratio for one key (1.0 when never recorded)."""
        with self._lock:
            dq = self._ratios.get((kernel, tier, fingerprint))
            return sum(dq) / len(dq) if dq else 1.0

    def stale(self, threshold: float = 1.25) -> List[Tuple[DriftKey, float]]:
        """Keys whose rolling ratio left ``[1/threshold, threshold]`` —
        *relative to the key's own first-ever recorded ratio*, so a constant
        model-vs-wall scale (simulating a GPU on a CPU container) doesn't
        flag, but a trend away from the key's own history does.

        A key with a single observation is never stale: one sample has no
        trend (its ratio IS the baseline), and using the rolling window's
        head as the baseline would degenerate once the window rolls — the
        oldest surviving ratio tracks the drift instead of anchoring it.
        """
        out = []
        with self._lock:
            for key, dq in sorted(self._ratios.items()):
                if len(dq) < 2:
                    continue
                base = self._first.get(key, dq[0])
                cur = sum(dq) / len(dq)
                rel = _ratio(cur, base)
                if rel > threshold or rel < 1.0 / threshold:
                    out.append((key, rel))
        return out

    def snapshot(self) -> dict:
        """JSON document: every record plus per-key rolling summaries."""
        with self._lock:
            rolling = {}
            for key, dq in sorted(self._ratios.items()):
                rolling[key_str(key)] = {
                    "n": len(dq),
                    "mean_time_ratio": sum(dq) / len(dq),
                    "last_time_ratio": dq[-1],
                    "first_time_ratio": self._first.get(key, dq[0]),
                }
            return {
                "records": [r.to_json() for r in self._records],
                "rolling": rolling,
            }

    def reset(self) -> None:
        with self._lock:
            self._records.clear()
            self._ratios.clear()
            self._first.clear()
