"""Hierarchical tracing — one coherent timeline for a whole OOC run.

Before this module the engine had two flat span sources (the simulator's
``op_spans`` and ``ScheduleExecutor.record_spans`` wall-clock tuples) and
one exporter (``core/trace.py``), but no way to see a *run* — tuner search,
plan-cache lookups, per-device executors and the hybrid merge — on a single
timeline.  :class:`Tracer` provides that:

  * **Hierarchical spans** — ``with tracer.span("tune.search", kernel=...)``
    opens a span on the *calling thread's* stack; nested spans record their
    parent id, so the control flow (plan -> search -> simulate, run ->
    merge) reconstructs exactly.  Each OS thread renders as its own track.
  * **Flat span groups** — :meth:`add_flat_spans` absorbs the engine's
    existing ``(tag, stream, start_s, end_s)`` tuples (executor or
    simulator) as one *trace process* per group, shifted onto the tracer's
    clock, so per-device pipelines sit beside the control timeline without
    stream-id collisions (the ``chrome_trace_groups`` convention: pid =
    group index, here offset by 1 because pid 0 is the control process).

Export is Chrome-trace JSON via the same helpers as ``core/trace.py``
(:meth:`to_chrome_trace` / :meth:`write`), so one file opened at
``chrome://tracing`` / ui.perfetto.dev shows the entire run.

A tracer is *active* only while installed on the process
:class:`~repro.obs.Observability`; instrumented code does ``tr =
obs.tracer`` and skips everything when it is None, so tracing costs nothing
when off.
"""

from __future__ import annotations

import dataclasses
import itertools
import json
import threading
import time
from typing import Dict, Iterable, List, Optional, Tuple

FlatSpan = Tuple[str, int, float, float]            # (tag, stream, start, end)
Reuse = Dict[str, Dict[str, int]]


@dataclasses.dataclass(frozen=True)
class TraceSpan:
    """One closed hierarchical span (times relative to the tracer epoch)."""

    name: str
    cat: str
    span_id: int
    parent_id: Optional[int]
    tid: int                 # tracer-local thread index (track)
    start: float
    end: float
    args: Tuple[Tuple[str, str], ...] = ()

    @property
    def duration(self) -> float:
        return self.end - self.start


class _SpanHandle:
    """Context manager yielded by :meth:`Tracer.span`; closes on exit."""

    __slots__ = ("_tracer", "name", "cat", "span_id", "parent_id", "tid",
                 "start", "_args")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 span_id: int, parent_id: Optional[int], tid: int,
                 start: float, args: Dict[str, object]):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.span_id = span_id
        self.parent_id = parent_id
        self.tid = tid
        self.start = start
        self._args = dict(args)

    def annotate(self, **kw) -> None:
        """Attach extra key/values to the span before it closes."""
        self._args.update(kw)

    def __enter__(self) -> "_SpanHandle":
        return self

    def __exit__(self, *exc) -> None:
        self._tracer._close(self)
        return None


class Tracer:
    """Hierarchical tracer with per-thread span stacks.

    ``clock`` defaults to ``time.perf_counter``; all recorded times are
    relative to the tracer's construction (its *epoch*), which is also the
    reference :meth:`add_flat_spans` offsets against.
    """

    def __init__(self, name: str = "ooc-run", clock=time.perf_counter):
        self.name = name
        self._clock = clock
        self.epoch = clock()
        self._lock = threading.Lock()
        self._ids = itertools.count(1)
        self._spans: List[TraceSpan] = []
        self._groups: List[Tuple[str, List[FlatSpan], Optional[Reuse]]] = []
        self._local = threading.local()
        self._tids: Dict[int, int] = {}   # thread ident -> track index

    # -- clock --------------------------------------------------------------
    def now(self) -> float:
        """Seconds since the tracer epoch."""
        return self._clock() - self.epoch

    # -- hierarchical spans --------------------------------------------------
    def _stack(self) -> List[_SpanHandle]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _tid(self) -> int:
        ident = threading.get_ident()
        with self._lock:
            tid = self._tids.get(ident)
            if tid is None:
                tid = self._tids[ident] = len(self._tids)
            return tid

    def span(self, name: str, cat: str = "phase", **args) -> _SpanHandle:
        """Open a span on this thread's stack (use as a context manager)."""
        stack = self._stack()
        parent = stack[-1].span_id if stack else None
        h = _SpanHandle(self, name, cat, next(self._ids), parent,
                        self._tid(), self.now(), args)
        stack.append(h)
        return h

    def instant(self, name: str, cat: str = "instant", **args) -> None:
        """Record a zero-duration marker at the current time — fault
        injections and recovery actions stamp the timeline with these so
        a trace shows *where* in the schedule the fault landed."""
        stack = self._stack()
        parent = stack[-1].span_id if stack else None
        t = self.now()
        h = _SpanHandle(self, name, cat, next(self._ids), parent,
                        self._tid(), t, args)
        self._record(h, t)

    def _close(self, h: _SpanHandle) -> None:
        end = self.now()
        stack = self._stack()
        # tolerate exits out of order (a handle closed twice, or from a
        # different frame): pop back to — and including — this handle
        while stack:
            top = stack.pop()
            if top is h:
                break
        self._record(h, end)

    def _record(self, h: _SpanHandle, end: float) -> None:
        span = TraceSpan(
            name=h.name, cat=h.cat, span_id=h.span_id,
            parent_id=h.parent_id, tid=h.tid, start=h.start, end=end,
            args=tuple(sorted((str(k), str(v))
                              for k, v in h._args.items())))
        with self._lock:
            self._spans.append(span)

    # -- flat span groups ----------------------------------------------------
    def add_flat_spans(self, name: str, spans: Iterable[FlatSpan],
                       offset: float = 0.0,
                       reuse: Optional[Reuse] = None) -> None:
        """Absorb an executor's / simulator's flat span list as one trace
        process.  ``offset`` places the group's zero on the tracer clock
        (e.g. ``tracer.now()`` captured when the run started)."""
        shifted = [(tag, stream, start + offset, end + offset)
                   for tag, stream, start, end in spans]
        with self._lock:
            self._groups.append((name, shifted, reuse))

    # -- introspection -------------------------------------------------------
    def spans(self) -> List[TraceSpan]:
        with self._lock:
            return list(self._spans)

    def groups(self) -> List[Tuple[str, List[FlatSpan]]]:
        with self._lock:
            return [(name, list(sp)) for name, sp, _ in self._groups]

    def summary(self) -> dict:
        """Span/group counts plus total span seconds, per process."""
        with self._lock:
            out = {
                "control_spans": len(self._spans),
                "groups": {
                    name: {"spans": len(sp),
                           "span_seconds": sum(e - s for _, _, s, e in sp)}
                    for name, sp, _ in self._groups
                },
            }
        return out

    # -- export --------------------------------------------------------------
    def to_chrome_trace(self) -> dict:
        """One Chrome-trace document: pid 0 is the control process (the
        hierarchical spans, one track per thread), pids 1..N are the flat
        groups in absorption order — the exact lane-group convention of
        :func:`repro.core.trace.chrome_trace_groups`."""
        # lazy import: repro.obs must stay importable before repro.core
        from repro.core.trace import _group_events

        with self._lock:
            spans = list(self._spans)
            groups = list(self._groups)
        events: List[dict] = [{
            "name": "process_name", "ph": "M", "pid": 0, "tid": 0,
            "args": {"name": self.name},
        }]
        for tid in sorted({s.tid for s in spans}):
            events.append({
                "name": "thread_name", "ph": "M", "pid": 0, "tid": tid,
                "args": {"name": f"thread {tid}"},
            })
        for s in sorted(spans, key=lambda s: s.start):
            args = dict(s.args)
            args["span_id"] = s.span_id
            if s.parent_id is not None:
                args["parent_id"] = s.parent_id
            events.append({
                "name": s.name, "cat": s.cat, "ph": "X",
                "ts": s.start * 1e6,
                "dur": max(s.duration, 0.0) * 1e6,
                "pid": 0, "tid": s.tid, "args": args,
            })
        for i, (name, flat, reuse) in enumerate(groups):
            events.extend(_group_events(flat, name, pid=i + 1, reuse=reuse))
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)
