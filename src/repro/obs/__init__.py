"""repro.obs — the unified observability layer (DESIGN.md §10).

One process-wide :class:`Observability` bundle ties together the three
pillars every other subsystem reports into:

  * :class:`~repro.obs.metrics.MetricRegistry` — labeled counters / gauges /
    histograms with JSON + Prometheus exposition (``repro_<layer>_<name>``).
  * :class:`~repro.obs.spans.Tracer` — hierarchical spans (tuner, plan
    cache, merges) plus absorbed flat executor/simulator span groups, all on
    one Chrome-trace timeline.
  * :class:`~repro.obs.drift.DriftMonitor` — predicted-vs-measured rolling
    drift per (kernel, tier, fingerprint): the calibration-staleness signal.

Everything starts **disabled** and instrumented hot paths guard on
``obs.metrics.enabled`` / ``obs.tracer is None``, publishing only per-run
aggregates — so the disabled cost is a few branches per kernel call
(guarded <2 % in ``benchmarks/bench_overhead.py``).

Usage (also via the :func:`repro.core.api.hclObservability` facade)::

    from repro.obs import get_observability
    obs = get_observability()
    obs.enable(trace=True)
    ooc_gemm(..., tune="auto", devices=[gpu, phi])
    obs.tracer.write("trace.json")          # one coherent timeline
    print(obs.metrics.to_prometheus_text())  # exact byte/flop accounting
    print(obs.drift.snapshot()["rolling"])   # predicted vs measured

This package imports nothing from ``repro.core`` at module load (the core
runtime imports *us*), so it is always safe to import first.
"""

from __future__ import annotations

import threading
from typing import Dict, List, Optional, Tuple

from repro.obs.drift import DriftMonitor, DriftRecord, key_str
from repro.obs.metrics import (Counter, Gauge, Histogram, Metric,
                               MetricRegistry)
from repro.obs.spans import FlatSpan, Tracer, TraceSpan

__all__ = [
    "Counter", "DriftMonitor", "DriftRecord", "FlatSpan", "Gauge",
    "Histogram", "Metric", "MetricRegistry", "Observability", "TraceAnalysis",
    "TraceSpan", "Tracer", "WhatIfReport", "get_observability", "key_str",
    "whatif",
]

# Attribution lives in submodules that import repro.core (the simulator);
# resolve lazily so ``import repro.obs`` stays core-free (the core runtime
# imports us first).
_LAZY = {
    "TraceAnalysis": ("repro.obs.analyze", "TraceAnalysis"),
    "WhatIfReport": ("repro.obs.whatif", "WhatIfReport"),
    "whatif": ("repro.obs.whatif", "whatif"),
}


def __getattr__(name: str):
    target = _LAZY.get(name)
    if target is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(target[0]), target[1])


class _NullSpan:
    """No-tracer stand-in so call sites can unconditionally ``with``."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return None

    def annotate(self, **kw) -> None:
        pass


_NULL_SPAN = _NullSpan()


class Observability:
    """Metrics + tracing + drift, with one enable/disable switch.

    A fresh instance is fully disabled; :func:`get_observability` returns
    the process singleton every instrumented layer reports into.
    """

    def __init__(self):
        self.metrics = MetricRegistry(enabled=False)
        self.drift = DriftMonitor()
        self.tracer: Optional[Tracer] = None
        self._lock = threading.Lock()

    # -- switches ------------------------------------------------------------
    @property
    def enabled(self) -> bool:
        return self.metrics.enabled

    def enable(self, metrics: bool = True, trace: bool = False,
               trace_name: str = "ooc-run") -> "Observability":
        self.metrics.enabled = metrics
        if trace and self.tracer is None:
            self.start_trace(trace_name)
        return self

    def disable(self) -> "Observability":
        self.metrics.enabled = False
        self.tracer = None
        return self

    def reset(self) -> "Observability":
        """Drop all collected state (metrics families, drift, trace)."""
        self.metrics.reset()
        self.drift.reset()
        self.tracer = None
        return self

    # -- tracing -------------------------------------------------------------
    def start_trace(self, name: str = "ooc-run") -> Tracer:
        with self._lock:
            self.tracer = Tracer(name)
            return self.tracer

    def stop_trace(self) -> Optional[Tracer]:
        """Detach and return the active tracer (caller exports it)."""
        with self._lock:
            tr, self.tracer = self.tracer, None
            return tr

    def span(self, name: str, cat: str = "phase", **args):
        """A tracer span when tracing is active, else a free no-op."""
        tr = self.tracer
        return tr.span(name, cat=cat, **args) if tr is not None \
            else _NULL_SPAN

    def instant(self, name: str, cat: str = "fault", **args) -> None:
        """A zero-duration trace marker when tracing is active (fault
        injections / recovery actions), else a free no-op."""
        tr = self.tracer
        if tr is not None:
            tr.instant(name, cat=cat, **args)

    # -- per-run publication helpers ----------------------------------------
    # These keep the instrumented call sites to one guarded call each; all
    # are per-run (never per-op) so cost scales with kernel invocations.
    def record_executor_run(self, sched, wall_seconds: float,
                            h2d_bytes: int, d2h_bytes: int,
                            spans: Optional[List[FlatSpan]] = None) -> None:
        """Publish one :meth:`ScheduleExecutor.run`'s aggregates."""
        if not self.metrics.enabled:
            return
        kernel = sched.meta.get("kernel", "unknown")
        m = self.metrics
        m.counter("repro_executor_runs_total",
                  "schedules executed").inc(kernel=kernel)
        m.counter("repro_executor_h2d_bytes",
                  "bytes moved host->device").inc(h2d_bytes, kernel=kernel)
        m.counter("repro_executor_d2h_bytes",
                  "bytes moved device->host").inc(d2h_bytes, kernel=kernel)
        m.counter("repro_executor_flops_total",
                  "modeled flops of executed compute ops").inc(
                      sched.total_flops(), kernel=kernel)
        kinds: Dict[str, int] = {}
        for op in sched.ops:
            kinds[op.kind.name.lower()] = kinds.get(
                op.kind.name.lower(), 0) + 1
        for kind, n in kinds.items():
            m.counter("repro_executor_ops_total",
                      "ops executed by kind").inc(n, kernel=kernel,
                                                  kind=kind)
        m.histogram("repro_executor_run_seconds",
                    "wall seconds per executed schedule").observe(
                        wall_seconds, kernel=kernel)
        for operand, r in sched.reuse.items():
            m.counter("repro_executor_blockcache_hits_total",
                      "block-cache hits (H2D transfers elided)").inc(
                          r.get("hits", 0), kernel=kernel, operand=operand)
            m.counter("repro_executor_blockcache_misses_total",
                      "block-cache misses (H2D transfers performed)").inc(
                          r.get("misses", 0), kernel=kernel, operand=operand)
            m.counter("repro_executor_blockcache_evictions_total",
                      "block-cache evictions").inc(
                          r.get("evictions", 0), kernel=kernel,
                          operand=operand)
            m.counter("repro_executor_blockcache_saved_bytes",
                      "H2D bytes elided by block reuse").inc(
                          r.get("bytes_saved", 0), kernel=kernel,
                          operand=operand)
        if spans:
            busy: Dict[int, float] = {}
            for _, stream, start, end in spans:
                busy[stream] = busy.get(stream, 0.0) + max(end - start, 0.0)
            for stream, b in sorted(busy.items()):
                m.gauge("repro_executor_stream_busy_seconds",
                        "recorded busy seconds per stream, last run").set(
                            b, kernel=kernel, stream=str(stream))

    def record_fault_run(self, kernel: str, stats: Dict[str, float]) -> None:
        """Publish one fault-injected executor run's recovery accounting
        (DESIGN.md §12) — the ``repro_fault_*`` family.  Called once per
        faulted run, including runs that end in an unrecoverable raise."""
        if not self.metrics.enabled:
            return
        from repro.obs.metrics import BACKOFF_BUCKETS

        m = self.metrics
        m.counter("repro_fault_injected_total",
                  "faults injected into executor runs").inc(
                      stats.get("injected", 0), kernel=kernel)
        m.counter("repro_fault_retries_total",
                  "transfer retry attempts").inc(
                      stats.get("retries", 0), kernel=kernel)
        m.counter("repro_fault_replayed_ops_total",
                  "compute ops re-executed by block-granular replay").inc(
                      stats.get("replayed_ops", 0), kernel=kernel)
        m.counter("repro_fault_replayed_h2d_bytes",
                  "extra H2D traffic caused by recovery (separate from "
                  "the nominal executor byte counters)").inc(
                      stats.get("replayed_h2d_bytes", 0), kernel=kernel)
        for action in ("retry", "replay"):
            n = stats.get(f"recovered_{action}", 0)
            if n:
                m.counter("repro_fault_recoveries_total",
                          "successful recovery actions").inc(
                              n, kernel=kernel, action=action)
        backoff = stats.get("backoff_seconds", 0.0)
        if backoff:
            m.histogram("repro_fault_backoff_seconds",
                        "total backoff slept per faulted run",
                        buckets=BACKOFF_BUCKETS).observe(backoff,
                                                         kernel=kernel)

    def record_fault_recovery(self, kernel: str, action: str,
                              **labels) -> None:
        """Publish one out-of-executor recovery action (``rebalance`` for
        device_lost, ``degrade`` for oom ladders) into the same
        ``repro_fault_recoveries_total`` family the executor uses."""
        if not self.metrics.enabled:
            return
        self.metrics.counter("repro_fault_recoveries_total",
                             "successful recovery actions").inc(
                                 kernel=kernel, action=action, **labels)

    def record_drift(self, kernel: str, tier: str, fingerprint: str,
                     **kw) -> Optional[DriftRecord]:
        """Record a predicted-vs-measured pair (when enabled) and mirror the
        rolling ratio into the metric registry."""
        if not self.metrics.enabled:
            return None
        rec = self.drift.record(kernel, tier, fingerprint, **kw)
        m = self.metrics
        m.counter("repro_drift_records_total",
                  "predicted-vs-measured pairs recorded").inc(
                      kernel=kernel, tier=tier)
        m.gauge("repro_drift_time_ratio",
                "rolling measured/predicted makespan ratio").set(
                    self.drift.ratio(kernel, tier, fingerprint),
                    kernel=kernel, tier=tier)
        m.gauge("repro_drift_byte_ratio",
                "last measured/predicted H2D byte ratio (must be 1.0)").set(
                    rec.byte_ratio, kernel=kernel, tier=tier)
        return rec

    def record_analysis(self, analysis, kernel: str = "unknown") -> None:
        """Publish one :class:`~repro.obs.analyze.TraceAnalysis` as the
        ``repro_analysis_*`` metric family (duck-typed: no analyze import,
        this package must stay core-free at load)."""
        if not self.metrics.enabled:
            return
        m = self.metrics
        m.counter("repro_analysis_runs_total",
                  "trace attributions computed").inc(kernel=kernel)
        m.gauge("repro_analysis_makespan_seconds",
                "analyzed timeline makespan, last run").set(
                    analysis.makespan, kernel=kernel)
        m.gauge("repro_analysis_verdict_info",
                "bottleneck verdict of the last analyzed run (value=1)").set(
                    1, kernel=kernel, verdict=analysis.verdict)
        for st in analysis.streams:
            m.gauge("repro_analysis_stream_utilization",
                    "per-stream busy fraction of the analyzed makespan").set(
                        st.utilization, kernel=kernel, stream=str(st.stream))
        for cls, secs in sorted(analysis.class_seconds.items()):
            m.gauge("repro_analysis_critical_path_seconds",
                    "critical-path seconds per segment class").set(
                        secs, kernel=kernel, **{"class": cls})

    def record_whatif(self, report, kernel: str = "unknown") -> None:
        """Publish a :class:`~repro.obs.whatif.WhatIfReport`'s marginal
        gains as ``repro_analysis_whatif_gain_seconds``."""
        if not self.metrics.enabled:
            return
        m = self.metrics
        for sc in report.scenarios:
            if not sc.feasible or sc.knob == "baseline":
                continue
            m.gauge("repro_analysis_whatif_gain_seconds",
                    "marginal makespan gain per scaled resource").set(
                        sc.gain_seconds, kernel=kernel, scenario=sc.name)

    # -- export --------------------------------------------------------------
    def snapshot(self) -> dict:
        """One JSON document: metrics + drift (+ trace summary if active)."""
        out = {"metrics": self.metrics.snapshot()["metrics"],
               "drift": self.drift.snapshot()}
        if self.tracer is not None:
            out["trace"] = self.tracer.summary()
        return out


_OBS = Observability()


def get_observability() -> Observability:
    """The process-wide bundle every instrumented layer reports into."""
    return _OBS
