"""Logical-axis sharding rules (MaxText-style) and spec resolution.

Every parameter/cache/activation declares *logical* axis names; this module
resolves them to mesh ``PartitionSpec``s under the production mesh.  The
strategy is FSDP×TP (DESIGN.md §6):

  * ``batch``           -> ("pod", "data")  — pure DP across pods
  * weight "width" dims (vocab / heads / ffn / experts / inner) -> "model"
  * weight "depth" dim  (embed) -> "data"   — FSDP: 2-D sharded weights,
    all-gathered per-layer by XLA inside the layer scan
  * ``cache_seq``       -> "model" *fallback* when kv_heads can't use it
    (sequence-parallel decode attention; softmax stats reduce over "model")

A dim is sharded only if (a) its size divides the mesh axis product and
(b) the mesh axis is not already consumed by an earlier (higher-priority)
dim of the same tensor — avoiding silent GSPMD padding and double-sharding.
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (logical name, mesh axes, priority) — lower priority number wins an axis.
DEFAULT_RULES: Dict[str, Tuple[Tuple[str, ...], int]] = {
    "batch": (("pod", "data"), 0),
    "vocab": (("model",), 0),
    "heads": (("model",), 0),
    "kv_heads": (("model",), 0),
    "ffn": (("model",), 0),
    "experts": (("model",), 0),
    "inner": (("model",), 0),
    "inner_heads": (("model",), 0),
    "embed": (("data",), 1),      # FSDP dim; loses "data" ties to batch
    "cache_seq": (("model",), 2),  # fallback consumer of "model"
    "assign": (("model",), 0),     # MoE dispatch assignment dim (sorted)
    "embed_act": ((), 9),
    "layer": ((), 9),
}


def _axes_size(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


def logical_to_spec(
    logical: Sequence[Optional[str]],
    shape: Tuple[int, ...],
    mesh: Mesh,
    rules: Optional[Dict] = None,
) -> P:
    """Resolve one tensor's logical axes to a PartitionSpec."""
    rules = rules or DEFAULT_RULES
    assert len(logical) == len(shape), (logical, shape)
    # priority-ordered assignment
    order = sorted(
        range(len(logical)),
        key=lambda i: rules.get(logical[i], ((), 9))[1] if logical[i] else 9,
    )
    used = set()
    out: list = [None] * len(logical)
    for i in order:
        name = logical[i]
        if name is None or name not in rules:
            continue
        axes, _ = rules[name]
        axes = tuple(a for a in axes if a in mesh.shape)
        if not axes or any(a in used for a in axes):
            continue
        if shape[i] % _axes_size(mesh, axes):
            continue  # not divisible: replicate rather than pad
        out[i] = axes if len(axes) > 1 else axes[0]
        used.update(axes)
    return P(*out)


def tree_specs(axes_tree, shape_tree, mesh: Mesh, rules=None):
    """Map (logical-axes pytree, ShapeDtypeStruct pytree) -> PartitionSpecs."""
    is_axes = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)
    return jax.tree.map(
        lambda ax, sds: logical_to_spec(ax, sds.shape, mesh, rules),
        axes_tree, shape_tree, is_leaf=is_axes)


def tree_shardings(axes_tree, shape_tree, mesh: Mesh, rules=None):
    specs = tree_specs(axes_tree, shape_tree, mesh, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def constrain(x, logical: Sequence[Optional[str]], mesh: Mesh, rules=None):
    """with_sharding_constraint by logical names (activation annotations)."""
    spec = logical_to_spec(logical, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# Serving rules: weights TP-sharded only ("embed" not sharded over data), so
# no per-step FSDP gather is needed.  Used when params fit per-chip at
# TP-only sharding (vLLM-style); huge models (MoE-235B) keep DEFAULT_RULES.
SERVE_RULES: Dict[str, Tuple[Tuple[str, ...], int]] = {
    **DEFAULT_RULES, "embed": ((), 9),
}


def make_weight_gather(mesh: Mesh, rules: Optional[Dict] = None,
                       drop: Tuple[str, ...] = ("data", "pod")):
    """FSDP gather hook: constrain layer weights to their *model-axis-only*
    sharding at the point of use.

    Storage stays 2-D sharded (FSDP×TP: the ZeRO memory win), but inside a
    layer the weights are explicitly all-gathered over the data/pod axes.
    Without this, GSPMD may instead keep weights sharded on the contracting
    dim and all-reduce every matmul's *activations* over ``data`` — observed
    to also unshard the batch axis entirely (EXPERIMENTS.md §Perf iter 1:
    +100 GiB/device and ~30× collective wire on train_4k).

    Returns gather(tree, axes_tree) -> tree.
    """
    base = rules or DEFAULT_RULES
    gr = {k: (tuple(a for a in v[0] if a not in drop), v[1])
          for k, v in base.items()}

    def gather(tree, axes_tree):
        is_axes = lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x)

        def one(ax, w):
            spec = logical_to_spec(ax, w.shape, mesh, gr)
            return jax.lax.with_sharding_constraint(
                w, NamedSharding(mesh, spec))

        return jax.tree.map(one, axes_tree, tree, is_leaf=is_axes)

    return gather


def batch_spec(mesh: Mesh, ndim: int, rules=None) -> P:
    """Spec for an input batch tensor: shard dim 0 on ("pod","data")."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return P(axes if len(axes) > 1 else (axes[0] if axes else None),
             *([None] * (ndim - 1)))
