from repro.distributed.sharding import (
    DEFAULT_RULES,
    SERVE_RULES,
    batch_spec,
    constrain,
    logical_to_spec,
    make_weight_gather,
    tree_shardings,
    tree_specs,
)
from repro.distributed.hlo_analysis import (
    CollectiveStats,
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    Roofline,
    collective_bytes,
    model_flops_estimate,
)

__all__ = [
    "CollectiveStats", "DEFAULT_RULES", "SERVE_RULES", "HBM_BW", "ICI_BW", "PEAK_FLOPS",
    "Roofline", "batch_spec", "collective_bytes", "constrain",
    "logical_to_spec", "make_weight_gather", "model_flops_estimate", "tree_shardings", "tree_specs",
]
