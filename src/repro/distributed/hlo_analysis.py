"""Roofline terms from a compiled dry-run artifact.

``compiled.cost_analysis()`` supplies HLO FLOPs and bytes accessed (per
device, post-SPMD-partitioning).  Collective traffic is NOT in
cost_analysis, so we parse the compiled HLO text and sum wire bytes of every
collective op, weighting by the op's algorithmic transfer factor on a ring:

  all-gather        (n-1)/n * output bytes
  reduce-scatter    (n-1)/n * input bytes
  all-reduce        2 (n-1)/n * bytes        (reduce-scatter + all-gather)
  all-to-all        (n-1)/n * bytes
  collective-permute 1.0 * bytes

Hardware constants (TPU v5e, per brief): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
VMEM_BYTES = 128 * 2**20
HBM_BYTES = 16 * 2**30

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all", "collective-broadcast",
)


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _result_bytes(text: str) -> int:
    """Sum byte sizes of all shapes in an HLO result type string."""
    return sum(_shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(text))


def _group_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\{\{([0-9, ]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    # iota form: replica_groups=[G,n]<=[N] (possibly with dims/transpose)
    m = re.search(r"replica_groups=\[\s*(\d+)\s*,\s*(\d+)\s*\]", line)
    if m:
        return int(m.group(2))
    return default


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes: float = 0.0
    by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    counts: Dict[str, int] = dataclasses.field(default_factory=dict)

    def add(self, kind: str, b: float):
        self.wire_bytes += b
        self.by_kind[kind] = self.by_kind.get(kind, 0.0) + b
        self.counts[kind] = self.counts.get(kind, 0) + 1


def collective_bytes(hlo_text: str, default_group: int = 2) -> CollectiveStats:
    """Sum per-device wire bytes of every collective in an HLO module."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        s = line.strip()
        # match ... = <type> <opname>-start?(...) — skip -done ops (no shape
        # transfer; the -start carries the payload).
        m = re.match(r"^[%\w.\-]+\s*=\s*(.+?)\s+([a-z\-]+)(?:-start)?\(", s)
        if not m:
            continue
        result_type, op = m.group(1), m.group(2)
        if op not in _COLLECTIVES:
            continue
        if "-done" in s.split("(")[0]:
            continue
        n = _group_size(s, default_group)
        if n <= 1:
            continue
        b = _result_bytes(result_type)
        if op == "all-gather":
            wire = b * (n - 1) / n
        elif op == "reduce-scatter":
            # result is the scattered (small) shape; input = n * result
            wire = b * (n - 1)
        elif op == "all-reduce":
            wire = 2 * b * (n - 1) / n
        elif op in ("all-to-all", "ragged-all-to-all"):
            wire = b * (n - 1) / n
        elif op == "collective-broadcast":
            wire = b
        else:  # collective-permute
            wire = b
        stats.add(op, wire)
    return stats


@dataclasses.dataclass
class Roofline:
    """Three-term roofline for one (arch × shape × mesh) cell."""

    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device HLO bytes accessed
    wire_bytes: float            # per-device collective bytes
    chips: int
    model_flops: float = 0.0     # 6·N·D (or 6·N_active·D) global

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.wire_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (chips * HLO flops): remat/redundancy waste."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved if the step runs at the
        dominant-term time: t_compute / t_bound."""
        return self.t_compute / self.t_bound if self.t_bound else 0.0

    def row(self) -> Dict[str, float]:
        return {
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "roofline_fraction": self.roofline_fraction,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def model_flops_estimate(n_params_active: float, tokens: float,
                         training: bool) -> float:
    """6·N·D for training, 2·N·D for inference forward."""
    return (6.0 if training else 2.0) * n_params_active * tokens
