"""repro.hybrid — co-scheduling one OOC kernel across heterogeneous devices.

The paper's title promises *hybrid computing platforms* (its testbeds pair a
GPU with a Xeon Phi in one node), but libhclooc only ever drives one
accelerator per kernel call.  This subsystem is the missing layer:

  * :mod:`repro.hybrid.balance`  — functional-performance-model row split:
    shares sized so predicted per-device makespans equalize, with
    ``simulate()`` under each device's :class:`HardwareProfile` as the cost
    oracle and an iterative rebalance loop to a tolerance.
  * :mod:`repro.hybrid.plan`     — :class:`HybridPlan`: per-device
    ``(GemmPartition, TunedPlan)`` pairs produced by reusing ``tune.search``
    per sub-problem (the tuner IS the balance oracle, so the converged
    predictions are the plans' makespans).
  * :mod:`repro.hybrid.executor` — concurrent execution of the per-device
    schedules through the existing :class:`ScheduleExecutor`, exact merges
    (disjoint C bands; flash-attention partial combine),
    :func:`simulate_hybrid` aggregate prediction, Chrome traces with one
    lane-group per device, and the registered ``"HYBRID"``
    :class:`HybridOocRuntime` composite.

Entry points: ``ooc_gemm(..., devices=[...])`` (also ``ooc_syrk`` /
``ooc_attention``) and the ``hclHybridRuntime`` facade in ``core/api.py``.
"""

from repro.hybrid.balance import (BalanceResult, DeviceSpec, balance_gemm,
                                  balance_units, gemm_cost_fn,
                                  surviving_devices)
from repro.hybrid.executor import (HybridOocRuntime, HybridSimResult,
                                   device_schedule, merge_attention_partials,
                                   run_hybrid_attention, run_hybrid_gemm,
                                   run_hybrid_syrk, simulate_hybrid)
from repro.hybrid.plan import (DevicePlan, HybridPlan, plan_hybrid_attention,
                               plan_hybrid_gemm, plan_hybrid_syrk)

__all__ = [
    "BalanceResult", "DevicePlan", "DeviceSpec", "HybridOocRuntime",
    "HybridPlan", "HybridSimResult", "balance_gemm", "balance_units",
    "device_schedule", "gemm_cost_fn", "merge_attention_partials",
    "plan_hybrid_attention", "plan_hybrid_gemm", "plan_hybrid_syrk",
    "run_hybrid_attention", "run_hybrid_gemm", "run_hybrid_syrk",
    "simulate_hybrid", "surviving_devices",
]
