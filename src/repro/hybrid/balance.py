"""Functional-performance-model load balancing across unequal devices.

The paper's testbeds host a GPU *and* a Xeon Phi in one node, yet libhclooc
drives one accelerator per kernel call.  Co-execution needs a split of the
problem proportional not to peak flops but to each device's *predicted
pipeline makespan* — transfers, overlap, stream topology and per-op
overhead included — which is exactly what ``simulate()`` under
``profile.model_for(nstreams)`` already computes for single-device tuning.

:func:`balance_units` is the generic loop: split ``total`` work units (C
row bands for GEMM/SYRK, KV positions for attention) across devices so the
predicted per-device makespans equalize.  Each iteration re-allocates
shares proportionally to the measured rates ``share / cost(share)`` — the
functional performance model's fixed point — until the predicted finish
times agree within ``tolerance`` (relative spread).  Devices whose share
rounds below one alignment unit are dropped to zero (their fixed pipeline
overhead is not worth a sliver of work), which is how a dominated profile
degenerates to the single-device partition.

:func:`balance_gemm` instantiates the loop with a direct simulate() cost
oracle (default planner partition, best feasible stream count).  The
planner (``hybrid/plan.py``) instead injects a ``tune.search``-backed
oracle so the converged predictions ARE the per-device ``TunedPlan``
makespans.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.partitioner import SUBLANE, plan_gemm_partition
from repro.core.pipeline import build_gemm_schedule
from repro.core.simulator import simulate
from repro.tune.calibrate import HardwareProfile

# (device_index, units) -> predicted seconds; float("inf") = infeasible.
CostFn = Callable[[int, int], float]


@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    """One member of the hybrid device set: identity + engine model + budget.

    The profile supplies the cost oracle (``model_for``), the budget bounds
    each sub-problem's working set, and ``tier`` keys any tuner plan caches.
    """

    name: str
    profile: HardwareProfile
    budget_bytes: int
    tier: str = "HBM"


@dataclasses.dataclass(frozen=True)
class BalanceResult:
    """Converged (or best-seen) split of ``total`` work units.

    ``shares[i]`` is device i's contiguous span (0 = dropped); ``predicted``
    the per-device makespans the cost oracle reported for those shares.
    """

    total: int
    shares: Tuple[int, ...]
    predicted: Tuple[float, ...]
    iterations: int
    tolerance: float
    converged: bool

    @property
    def spread(self) -> float:
        """Relative disagreement of active devices' predicted finish times
        (inf when any active device found its share infeasible)."""
        ts = [t for s, t in zip(self.shares, self.predicted) if s > 0]
        if not all(np.isfinite(t) for t in ts):
            return float("inf")
        if len(ts) <= 1:
            return 0.0
        return (max(ts) - min(ts)) / max(ts)

    @property
    def active(self) -> Tuple[int, ...]:
        return tuple(i for i, s in enumerate(self.shares) if s > 0)


def surviving_devices(devices: Sequence[DeviceSpec],
                      lost: Sequence[str]) -> List[DeviceSpec]:
    """The device set minus the members named in ``lost`` — the input to
    re-balancing a failed band after a ``device_lost`` fault (DESIGN.md
    §12).  Unknown names are authoring errors and raise, as does losing
    every device (nothing left to rebalance onto)."""
    names = [d.name for d in devices]
    unknown = [n for n in lost if n not in names]
    if unknown:
        raise ValueError(f"lost devices {unknown} not in device set {names}")
    survivors = [d for d in devices if d.name not in set(lost)]
    if not survivors:
        raise ValueError("all devices lost: no survivors to rebalance onto")
    return survivors


def _allocate(total: int, weights: Sequence[float], align: int) -> List[int]:
    """Split ``total`` into contiguous aligned spans proportional to
    ``weights``.  Zero-weight devices (dropped or infeasible) get exactly
    zero — including the rounding/unaligned tail, which must land on a
    device that can actually run it.  Spans always sum to ``total``;
    slivers below one alignment unit fold into the heaviest device (a
    sliver is not worth a device's fixed pipeline overhead)."""
    active = [i for i, w in enumerate(weights) if w > 0]
    if not active:
        raise ValueError("no device has positive weight")
    wsum = sum(weights[i] for i in active)
    shares = [0] * len(weights)
    prev = 0
    acc = 0.0
    for j, i in enumerate(active):
        acc += weights[i]
        if j == len(active) - 1:
            edge = total          # tail (incl. unaligned remainder)
        else:
            edge = min(total, max(
                prev, int(round(acc / wsum * total / align)) * align))
        shares[i] = edge - prev
        prev = edge
    big = max(active, key=lambda i: weights[i])
    for i in active:
        if i != big and 0 < shares[i] < align:
            shares[big] += shares[i]
            shares[i] = 0
    return shares


def balance_units(
    total: int,
    ndev: int,
    cost: CostFn,
    *,
    tolerance: float = 0.05,
    max_iters: int = 16,
    align: int = SUBLANE,
) -> BalanceResult:
    """Equalize predicted makespans of an aligned contiguous split.

    Starts from an even split, then iterates the functional-performance-model
    update (share proportional to measured rate ``share / cost``) until the
    active devices' predictions agree within ``tolerance``.  Infeasible
    shares (``cost`` returns inf — e.g. the sub-problem's K panel overflows
    that device's budget) zero the device's weight, excluding it from later
    rounds.  Returns the best split seen if ``max_iters`` passes without
    convergence (alignment can induce a +-1-block limit cycle).
    """
    if total <= 0:
        raise ValueError("total work must be positive")
    if ndev < 1:
        raise ValueError("need at least one device")
    weights = [1.0] * ndev
    best: Optional[BalanceResult] = None
    for it in range(1, max_iters + 1):
        shares = _allocate(total, weights, align)
        predicted = [cost(i, s) if s > 0 else 0.0 for i, s in
                     enumerate(shares)]
        if all(s == 0 or not np.isfinite(t)
               for s, t in zip(shares, predicted)):
            raise ValueError(
                "no feasible split: every device rejected its share "
                "(budgets too small for the problem's K panel?)")
        res = BalanceResult(total, tuple(shares), tuple(predicted), it,
                            tolerance, converged=False)
        if best is None or res.spread < best.spread:
            best = res
        if res.spread <= tolerance:
            return dataclasses.replace(res, converged=True)
        # functional performance model: rate = units per predicted second
        weights = [s / t if s > 0 and np.isfinite(t) and t > 0 else 0.0
                   for s, t in zip(shares, predicted)]
    return best


def gemm_cost_fn(
    N: int,
    K: int,
    devices: Sequence[DeviceSpec],
    *,
    bytes_per_el: int = 4,
    nstreams_options: Sequence[int] = (1, 2),
    nbuf: int = 2,
) -> CostFn:
    """Direct simulate() oracle: predicted makespan of the default-planner
    pipeline for a ``rows x N x K`` sub-GEMM on device ``i``, taking the
    best feasible stream count (the C5 question answered per device)."""
    memo = {}

    def cost(i: int, rows: int) -> float:
        key = (i, rows)
        if key not in memo:
            dev = devices[i]
            try:
                part = plan_gemm_partition(rows, N, K, dev.budget_bytes,
                                           bytes_per_el)
                memo[key] = min(
                    simulate(build_gemm_schedule(part, nstreams=ns,
                                                 nbuf=nbuf),
                             dev.profile.model_for(ns)).makespan
                    for ns in nstreams_options)
            except ValueError:
                memo[key] = float("inf")
        return memo[key]

    return cost


def balance_gemm(
    M: int,
    N: int,
    K: int,
    devices: Sequence[DeviceSpec],
    *,
    bytes_per_el: int = 4,
    tolerance: float = 0.05,
    max_iters: int = 16,
    nstreams_options: Sequence[int] = (1, 2),
) -> BalanceResult:
    """Profile-proportional row split of C for one GEMM across ``devices``.

    Each device's share is a contiguous band of C rows (A rows split with
    them; B streams whole to every active device), sized so the predicted
    per-device pipeline makespans equalize within ``tolerance``.
    """
    return balance_units(
        M, len(devices),
        gemm_cost_fn(N, K, devices, bytes_per_el=bytes_per_el,
                     nstreams_options=nstreams_options),
        tolerance=tolerance, max_iters=max_iters, align=SUBLANE)
