"""Co-execution: run per-device schedules concurrently, merge the results.

Each active device of a :class:`~repro.hybrid.plan.HybridPlan` gets its own
compiled schedule (the *same* ``compile_pipeline`` output the tuner ranked)
and its own :class:`~repro.core.runtime.ScheduleExecutor`, driven from a
thread pool.  Merging is kernel-specific but always exact:

  * GEMM — devices own disjoint C row bands; each executor writes its band
    of the output array in place, so the merge is free.
  * SYRK — same row-band split; the transposed panel streams from the full
    host matrix (``syrk_pipeline_spec(pt_source=...)``) while each band's
    row slices stream from its own span.
  * attention — each device folds its KV chunk into an un-normalized
    online-softmax partial ``(m, l, acc)`` (the ``attn_partial`` finalize
    handler below); partials combine with the standard flash-attention
    merge, which is algebraically exact.

:func:`simulate_hybrid` predicts the co-executed makespan by simulating
every device's schedule under its own engine model — devices share nothing,
so the aggregate makespan is the slowest device's — and exports one
Chrome-trace lane-group per device (pid = device index).
"""

from __future__ import annotations

import dataclasses
import os
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.pipeline import (attention_pipeline_spec, compile_pipeline,
                                 gemm_pipeline_spec, syrk_pipeline_spec)
from repro.core.runtime import (ExecState, OocRuntime, ScheduleExecutor,
                                register_op_handler, register_runtime)
from repro.core.simulator import SimResult, simulate
from repro.core.streams import (BlockRef, Device, Op, OpKind, Schedule,
                                validate_schedule)
from repro.core.trace import Span, chrome_trace_groups
from repro.fault.errors import DeviceLostError
from repro.obs import get_observability
from repro.hybrid.balance import DeviceSpec, surviving_devices
from repro.hybrid.plan import (DevicePlan, HybridPlan, _as_device_specs,
                               plan_hybrid_attention, plan_hybrid_gemm,
                               plan_hybrid_syrk)

# Host-operand name the SYRK transposed panel streams from in hybrid mode
# (each band's row slices stream from the band operand "P" instead).
_SYRK_FULL_PANEL = "Pfull"

SpanGroups = List[Tuple[str, List[Span]]]


@register_op_handler("attn_partial")
def _attn_partial_handler(st: ExecState, op: Op, ref: BlockRef) -> None:
    """Finalize one device's KV chunk as an *un-normalized* partial: land
    the raw online-softmax carry (m, l, acc) in host buffers for the
    cross-device merge (contrast ``attn_out``, which normalizes)."""
    m, l, acc = st.scratch["carry"]
    st.outputs["m"][...] = np.asarray(m)
    st.outputs["l"][...] = np.asarray(l)
    st.outputs["acc"][...] = np.asarray(acc)


def merge_attention_partials(
        partials: Sequence[Tuple[np.ndarray, np.ndarray, np.ndarray]]
) -> np.ndarray:
    """Exact flash-attention combine of per-chunk (m, l, acc) partials."""
    m_star = np.max(np.stack([m for m, _, _ in partials]), axis=0)
    l_star = np.zeros_like(partials[0][1])
    acc_star = np.zeros_like(partials[0][2])
    for m, l, acc in partials:
        scale = np.exp(m - m_star)
        l_star += l * scale
        acc_star += acc * scale[:, None]
    return acc_star / l_star[:, None]


def device_schedule(hplan: HybridPlan, dp: DevicePlan) -> Schedule:
    """Compile one device's sub-schedule — the identical spec/shape the
    tuner's search simulated, so executed and predicted pipelines agree."""
    plan = dp.plan
    if hplan.kernel == "gemm":
        if not plan.write_back:
            raise ValueError("hybrid GEMM requires write-back sub-plans")
        spec = gemm_pipeline_spec(plan.gemm_partition(),
                                  traversal=plan.traversal, band=plan.nbuf)
    elif hplan.kernel == "syrk":
        spec = syrk_pipeline_spec(plan.gemm_partition(),
                                  pt_source=_SYRK_FULL_PANEL,
                                  traversal=plan.traversal, band=plan.nbuf)
    elif hplan.kernel == "attention":
        _, kv_heads, head_dim, q_heads = plan.problem
        spec = attention_pipeline_spec(plan.attention_partition(),
                                       kv_heads, head_dim, q_heads)
        spec = dataclasses.replace(
            spec,
            writeback=dataclasses.replace(spec.writeback,
                                          kernel="attn_partial",
                                          out="partial"))
        return compile_pipeline(spec, nstreams=plan.nstreams, nbuf=plan.nbuf)
    else:
        raise ValueError(f"unknown hybrid kernel {hplan.kernel!r}")
    # gemm/syrk: replay the traversal + eviction policy the search ranked,
    # so each device's executed pipeline elides the same H2D transfers the
    # balancer's simulated makespans assumed
    return compile_pipeline(spec, nstreams=plan.nstreams, nbuf=plan.nbuf,
                            evict=plan.evict)


# One process-wide pool for device jobs, created on first multi-device run
# (constructing a fresh ThreadPoolExecutor per call cost thread spawns on
# every hybrid kernel invocation — tuner sweeps make thousands).  Jobs never
# submit nested jobs (the rebalance path re-enters run_hybrid_gemm from the
# *calling* thread after the pool drained), so a fixed-size pool cannot
# deadlock; excess jobs beyond the pool width simply queue.
_POOL_LOCK = threading.Lock()
_POOL: Optional[ThreadPoolExecutor] = None


def _shared_pool() -> ThreadPoolExecutor:
    global _POOL
    with _POOL_LOCK:
        if _POOL is None:
            _POOL = ThreadPoolExecutor(
                max_workers=max(4, os.cpu_count() or 1),
                thread_name_prefix="hybrid-device")
        return _POOL


def _run_concurrent(jobs) -> list:
    """Run one job per device on the shared pool (inline when there is only
    one: no pool overhead for the degenerate single-device plan)."""
    if len(jobs) == 1:
        return [jobs[0]()]
    pool = _shared_pool()
    return [f.result() for f in [pool.submit(j) for j in jobs]]


def _execute(hplan: HybridPlan, make_io, ctx: Dict,
             record_spans: bool,
             validate: bool,
             fault_plans: Optional[Dict] = None,
             fault_policy=None
             ) -> Tuple[SpanGroups, Dict[str, float], List[str]]:
    """Shared driver: per device, build (operands, outputs) via ``make_io``
    and run the compiled sub-schedule on a private executor.

    Returns ``(span_groups, stats, lost)``; ``stats`` aggregates the
    measured executor byte counters and the schedules' modeled byte totals
    (equal by construction — the conformance tests pin it) plus per-device
    wall seconds.  When an obs tracer is active, spans are force-recorded so
    each device's pipeline lands in the trace as its own lane-group (the
    executor absorbs them under ``trace_group=device name``), and per-device
    lag is published as ``repro_hybrid_*`` metrics.

    ``fault_plans`` maps device name -> FaultPlan (or schedule -> FaultPlan
    callable); each device's executor injects and recovers independently
    (DESIGN.md §12).  A ``device_lost`` fault kills only that device's job:
    its name lands in ``lost`` with zeroed counters, and the caller
    re-balances the band onto the survivors.  Other fault classes recover
    in-executor (retry / replay) and never surface here.
    """
    obs = get_observability()
    record = record_spans or obs.tracer is not None

    def job(dp: DevicePlan):
        sched = device_schedule(hplan, dp)
        if validate:
            validate_schedule(sched)
        # concurrent mode: each device's band genuinely overlaps its own
        # H2D/compute/D2H engines (an armed fault plan falls back to the
        # serial oracle inside run(); span recording is ported)
        ex = ScheduleExecutor(record_spans=record,
                              trace_group=dp.device.name,
                              mode="concurrent")
        operands, outputs = make_io(dp)
        faults = (fault_plans or {}).get(dp.device.name)
        t0 = time.perf_counter()
        try:
            ex.run(sched, operands=operands, outputs=outputs, ctx=ctx,
                   faults=faults, policy=fault_policy)
        except DeviceLostError:
            obs.instant("fault:device_lost_band", kernel=hplan.kernel,
                        device=dp.device.name)
            return {
                "name": dp.device.name, "lost": True, "spans": [],
                "wall": time.perf_counter() - t0,
                "h2d": 0, "d2h": 0, "sched_h2d": 0, "sched_d2h": 0,
            }
        return {
            "name": dp.device.name,
            "lost": False,
            "spans": list(ex.last_spans),
            "wall": time.perf_counter() - t0,
            "h2d": ex.last_h2d_bytes,
            "d2h": ex.last_d2h_bytes,
            "sched_h2d": sched.total_bytes(OpKind.H2D),
            "sched_d2h": sched.total_bytes(OpKind.D2H),
        }

    results = _run_concurrent([
        (lambda dp=dp: job(dp)) for dp in hplan.device_plans])
    lost = [r["name"] for r in results if r["lost"]]
    walls = [r["wall"] for r in results]
    stats = {
        "h2d_bytes": sum(r["h2d"] for r in results),
        "d2h_bytes": sum(r["d2h"] for r in results),
        "sched_h2d_bytes": sum(r["sched_h2d"] for r in results),
        "sched_d2h_bytes": sum(r["sched_d2h"] for r in results),
        "lag_seconds": max(walls) - min(walls),
        "wall_seconds": max(walls),
    }
    if obs.metrics.enabled:
        m = obs.metrics
        m.counter("repro_hybrid_runs_total",
                  "hybrid co-executions").inc(kernel=hplan.kernel)
        for r in results:
            m.gauge("repro_hybrid_device_wall_seconds",
                    "per-device wall seconds, last hybrid run").set(
                        r["wall"], kernel=hplan.kernel, device=r["name"])
        m.gauge("repro_hybrid_lag_seconds",
                "slowest-minus-fastest device wall, last hybrid run").set(
                    stats["lag_seconds"], kernel=hplan.kernel)
    groups = [(r["name"], r["spans"]) for r in results if not r["lost"]]
    return groups, stats, lost


def _record_hybrid_drift(obs, hplan: HybridPlan, wall_seconds: float,
                         stats: Dict[str, float]) -> None:
    """One drift record per hybrid run: the balancer's aggregate makespan
    prediction vs measured wall, and modeled vs measured byte totals (equal
    by construction).  Tier is ``HYBRID``; the device set stands in for the
    hardware fingerprint."""
    obs.record_drift(
        hplan.kernel, "HYBRID", "+".join(hplan.device_names()),
        predicted_makespan=hplan.predicted_makespan,
        measured_seconds=wall_seconds,
        predicted_h2d_bytes=int(stats["sched_h2d_bytes"]),
        measured_h2d_bytes=int(stats["h2d_bytes"]),
        predicted_d2h_bytes=int(stats["sched_d2h_bytes"]),
        measured_d2h_bytes=int(stats["d2h_bytes"]))


def _rebalance_lost_bands(kernel: str, hplan: HybridPlan,
                          lost: List[str], out: np.ndarray, C: np.ndarray,
                          alpha: float, beta: float, band_operands,
                          groups: SpanGroups, *, record_spans: bool,
                          validate: bool) -> None:
    """Recompute every lost device's C row band on the survivors.

    Recovery is exact, not approximate: the band restarts from the
    ORIGINAL ``C[lo:hi]`` (the dead executor may have partially written
    ``out``'s band, but ``out`` is a copy so ``C`` is pristine), and the
    re-balanced sub-GEMM never splits K, so every C block is still one
    full-depth dot — bitwise identical to the fault-free run regardless of
    how the survivors' bands differ from the lost device's.  SYRK bands
    recover through the same path with ``B = P^T`` (identical operand bits
    into the identical dgemm kernel).  The recursive run is fault-free by
    construction: the ``device_lost`` occurrence was consumed by the dead
    job.  Survivors' spans gain a ``(rebalance <dead>)`` lane-group suffix.
    """
    obs = get_observability()
    survivors = surviving_devices(
        [dp.device for dp in hplan.device_plans], lost)
    for dp in hplan.device_plans:
        if dp.device.name not in lost:
            continue
        lo, hi = dp.start, dp.start + dp.length
        a_band, b_full = band_operands(lo, hi)
        sub = plan_hybrid_gemm(
            dp.length, b_full.shape[1], a_band.shape[1], survivors,
            dtype=np.dtype(a_band.dtype).name)
        band, g2 = run_hybrid_gemm(
            a_band, b_full, np.asarray(C)[lo:hi], alpha, beta, sub,
            record_spans=record_spans, validate=validate)
        out[lo:hi] = band
        groups.extend((f"{name} (rebalance {dp.device.name})", spans)
                      for name, spans in g2)
        obs.record_fault_recovery(kernel, "rebalance",
                                  device=dp.device.name)


def run_hybrid_gemm(A, B, C, alpha: float, beta: float, hplan: HybridPlan,
                    *, record_spans: bool = False,
                    validate: bool = False,
                    fault_plans: Optional[Dict] = None,
                    fault_policy=None) -> Tuple[np.ndarray, SpanGroups]:
    """Co-execute ``alpha * A @ B + beta * C`` per the plan's row bands.

    Each device streams its band of A and C plus the whole B; bands are
    disjoint views of one output array, so the merge is the writes
    themselves.  Returns ``(C_out, [(device_name, spans), ...])``.

    ``fault_plans`` (device name -> FaultPlan) injects per-device faults:
    transfer/compute faults recover inside that device's executor; a
    ``device_lost`` fault drops the device and its band is re-balanced
    across the survivors and recomputed exactly (DESIGN.md §12).  The
    simulate-vs-actual drift record is skipped when a device was lost —
    the plan's predicted makespan no longer describes what ran.
    """
    A = np.asarray(A)
    B = np.asarray(B)
    M, K = A.shape
    _, N = B.shape
    if tuple(hplan.problem) != (M, N, K):
        raise ValueError(
            f"plan is for {hplan.problem}, operands are {(M, N, K)}")
    if C is None:
        C = np.zeros((M, N), dtype=A.dtype)
        beta = 0.0
    out = np.array(C, copy=True)

    def make_io(dp: DevicePlan):
        lo, hi = dp.start, dp.start + dp.length
        return ({"A": A[lo:hi], "B": B}, {"C": out[lo:hi]})

    obs = get_observability()
    t0 = time.perf_counter()
    groups, stats, lost = _execute(
        hplan, make_io, {"alpha": alpha, "beta": beta}, record_spans,
        validate, fault_plans=fault_plans, fault_policy=fault_policy)
    if lost:
        _rebalance_lost_bands("gemm", hplan, lost, out, C, alpha, beta,
                              lambda lo, hi: (A[lo:hi], B), groups,
                              record_spans=record_spans, validate=validate)
    with obs.span("merge", cat="merge", kernel="gemm",
                  mode="in-place-bands"):
        pass  # disjoint C row bands: the merge is the writes themselves
    if not lost:
        _record_hybrid_drift(obs, hplan, time.perf_counter() - t0, stats)
    return out, groups


def run_hybrid_syrk(P, C, alpha: float, beta: float, hplan: HybridPlan,
                    *, record_spans: bool = False,
                    validate: bool = False,
                    fault_plans: Optional[Dict] = None,
                    fault_policy=None) -> Tuple[np.ndarray, SpanGroups]:
    """Co-execute ``alpha * P @ P^T + beta * C`` per the plan's row bands.

    ``fault_plans``/``fault_policy`` behave as in :func:`run_hybrid_gemm`;
    a lost device's band re-balances as the equivalent GEMM with
    ``B = P^T`` (same operand bits, same dgemm kernel — bitwise).
    """
    P = np.asarray(P)
    n, K = P.shape
    if tuple(hplan.problem) != (n, n, K):
        raise ValueError(
            f"plan is for {hplan.problem}, panel is {(n, n, K)}")
    if C is None:
        C = np.zeros((n, n), dtype=P.dtype)
        beta = 0.0
    out = np.array(C, copy=True)

    def make_io(dp: DevicePlan):
        lo, hi = dp.start, dp.start + dp.length
        return ({"P": P[lo:hi], _SYRK_FULL_PANEL: P}, {"C": out[lo:hi]})

    obs = get_observability()
    t0 = time.perf_counter()
    groups, stats, lost = _execute(
        hplan, make_io, {"alpha": alpha, "beta": beta}, record_spans,
        validate, fault_plans=fault_plans, fault_policy=fault_policy)
    if lost:
        Pt = np.ascontiguousarray(P.T)
        _rebalance_lost_bands("syrk", hplan, lost, out, C, alpha, beta,
                              lambda lo, hi: (P[lo:hi], Pt), groups,
                              record_spans=record_spans, validate=validate)
    with obs.span("merge", cat="merge", kernel="syrk",
                  mode="in-place-bands"):
        pass  # disjoint C row bands: the merge is the writes themselves
    if not lost:
        _record_hybrid_drift(obs, hplan, time.perf_counter() - t0, stats)
    return out, groups


def run_hybrid_attention(q, k_cache, v_cache, hplan: HybridPlan,
                         *, record_spans: bool = False,
                         validate: bool = False
                         ) -> Tuple[np.ndarray, SpanGroups]:
    """Co-execute decode attention: each device folds its KV chunk into a
    partial, merged exactly on the host.  Returns the f32 (H, d) output."""
    import jax.numpy as jnp

    k_cache = np.asarray(k_cache)
    v_cache = np.asarray(v_cache)
    S, hkv, d = k_cache.shape
    H = q.shape[0]
    if tuple(hplan.problem) != (S, hkv, d, H):
        raise ValueError(
            f"plan is for {hplan.problem}, operands are {(S, hkv, d, H)}")
    q = jnp.asarray(q)
    parts: Dict[str, Tuple[np.ndarray, np.ndarray, np.ndarray]] = {}

    def make_io(dp: DevicePlan):
        lo, hi = dp.start, dp.start + dp.length
        partial = (np.zeros((H,), np.float32), np.zeros((H,), np.float32),
                   np.zeros((H, d), np.float32))
        parts[dp.device.name] = partial
        return ({"K": k_cache[lo:hi], "V": v_cache[lo:hi]},
                {"m": partial[0], "l": partial[1], "acc": partial[2]})

    obs = get_observability()
    t0 = time.perf_counter()
    groups, stats, _ = _execute(hplan, make_io, {"q": q}, record_spans,
                                validate)
    with obs.span("merge", cat="merge", kernel="attention",
                  mode="flash-partials",
                  n_partials=len(hplan.device_plans)):
        t_m = time.perf_counter()
        out = merge_attention_partials(
            [parts[dp.device.name] for dp in hplan.device_plans])
        merge_s = time.perf_counter() - t_m
    if obs.metrics.enabled:
        obs.metrics.gauge(
            "repro_hybrid_merge_seconds",
            "host-side partial-merge seconds, last hybrid run").set(
                merge_s, kernel="attention")
    _record_hybrid_drift(obs, hplan, time.perf_counter() - t0, stats)
    return out, groups


# ===========================================================================
# Prediction
# ===========================================================================
@dataclasses.dataclass
class HybridSimResult:
    """Aggregate engine-model prediction for a co-executed plan."""

    makespan: float                                   # slowest device
    per_device: Tuple[Tuple[str, SimResult], ...]     # (name, SimResult)

    @property
    def device_makespans(self) -> Tuple[float, ...]:
        return tuple(r.makespan for _, r in self.per_device)

    def to_chrome_trace(self) -> dict:
        """One lane-group (trace process, pid = device index) per device."""
        return chrome_trace_groups(
            [(name, res.op_spans) for name, res in self.per_device])


def simulate_hybrid(hplan: HybridPlan) -> HybridSimResult:
    """Predict the co-executed makespan: simulate each device's compiled
    sub-schedule under its own ``profile.model_for(nstreams)``.  Devices
    share no engine, so they run truly concurrently and the aggregate
    makespan is the max — the number bench_hybrid holds against the best
    single-device tuned plan."""
    per = []
    for dp in hplan.device_plans:
        sched = device_schedule(hplan, dp)
        res = simulate(sched,
                       dp.device.profile.model_for(dp.plan.nstreams))
        per.append((dp.device.name, res))
    return HybridSimResult(
        makespan=max(r.makespan for _, r in per),
        per_device=tuple(per))


@dataclasses.dataclass
class HybridAnalysis:
    """Per-device bottleneck attribution for a co-executed plan.

    ``imbalance`` is ``(slowest - fastest) / slowest`` over the device
    makespans — the fraction of the critical device's time the other
    devices sit drained; the balancer's ``tolerance`` bounds it by
    construction.  Each device also carries its own
    :class:`~repro.obs.analyze.TraceAnalysis`, so a lagging device's
    verdict (transfer- vs compute-bound) says *why* it lags.
    """

    makespan: float
    critical_device: str
    imbalance: float
    per_device: Tuple[Tuple[str, object], ...]    # (name, TraceAnalysis)

    def device(self, name: str):
        for n, ana in self.per_device:
            if n == name:
                return ana
        raise KeyError(name)

    def to_json(self) -> dict:
        return {
            "makespan_seconds": self.makespan,
            "critical_device": self.critical_device,
            "imbalance": self.imbalance,
            "devices": {name: ana.to_json(max_path=0)
                        for name, ana in self.per_device},
        }


def analyze_hybrid(hplan: HybridPlan,
                   sim: Optional[HybridSimResult] = None) -> HybridAnalysis:
    """Attribute a hybrid plan's predicted co-execution: one exact
    :class:`~repro.obs.analyze.TraceAnalysis` per device (same recompiled
    schedule + engine model as :func:`simulate_hybrid`), plus the
    cross-device imbalance.  Publishes ``repro_analysis_*`` metrics (one
    ``kernel=<kernel>:<device>`` series per device) when obs is enabled.
    """
    from repro.obs.analyze import TraceAnalysis

    sim = sim or simulate_hybrid(hplan)
    obs = get_observability()
    per = []
    for dp, (name, res) in zip(hplan.device_plans, sim.per_device):
        sched = device_schedule(hplan, dp)
        hw = dp.device.profile.model_for(dp.plan.nstreams)
        ana = TraceAnalysis.from_sim(sched, res, hw=hw)
        obs.record_analysis(ana, kernel=f"{hplan.kernel}:{name}")
        per.append((name, ana))
    spans = sim.device_makespans
    imbalance = (max(spans) - min(spans)) / max(spans) if max(spans) else 0.0
    critical = max(sim.per_device, key=lambda nr: nr[1].makespan)[0]
    if obs.metrics.enabled:
        obs.metrics.gauge(
            "repro_analysis_hybrid_imbalance_ratio",
            "(slowest - fastest) / slowest device makespan, last plan").set(
                imbalance, kernel=hplan.kernel)
    return HybridAnalysis(makespan=sim.makespan, critical_device=critical,
                          imbalance=imbalance, per_device=tuple(per))


# ===========================================================================
# The composite runtime (registered tier "HYBRID")
# ===========================================================================
@register_runtime("HYBRID")
class HybridOocRuntime(OocRuntime):
    """``hclRuntime`` composite: one kernel call, a set of devices.

    Construct with the device set (plus optional planning knobs); every
    kernel call balances, tunes and co-executes, caching nothing across
    calls except what ``plan_hybrid_*`` memoizes internally.  ``last_plan``
    and ``last_span_groups`` expose the most recent plan and (when
    ``record_spans=True``) the per-device wall-clock spans for tracing.
    """

    def __init__(self, devices: Sequence[Union[DeviceSpec, Tuple]],
                 device: Optional[Device] = None,
                 tolerance: float = 0.05,
                 max_iters: int = 16,
                 nstreams_options: Sequence[int] = (1, 2),
                 nbuf_options: Sequence[int] = (1, 2, 3),
                 max_steps: int = 2048):
        self.devices = _as_device_specs(devices)
        self.device = device or Device(
            "HYBRID", 0, sum(d.budget_bytes for d in self.devices))
        self.plan_opts = dict(
            tolerance=tolerance, max_iters=max_iters,
            nstreams_options=tuple(nstreams_options),
            nbuf_options=tuple(nbuf_options), max_steps=max_steps)
        self.last_plan: Optional[HybridPlan] = None
        self.last_span_groups: SpanGroups = []

    @classmethod
    def from_device(cls, device: Device, *, mesh=None, devices=None,
                    **kw) -> "HybridOocRuntime":
        if not devices:
            raise ValueError(
                "HYBRID runtime needs devices=[DeviceSpec, ...] "
                "(name, profile, budget_bytes per member)")
        specs = _as_device_specs(devices)
        if device.mem_bytes <= 0:
            # hclDeviceFactory's HYBRID placeholder carries no size of its
            # own: the composite's memory is the member budgets' sum
            device = dataclasses.replace(
                device, mem_bytes=sum(d.budget_bytes for d in specs))
        return cls(specs, device=device, **kw)

    def gemm(self, A, B, C, alpha: float, beta: float, part=None,
             plan: Optional[HybridPlan] = None,
             record_spans: bool = False,
             fault_plans: Optional[Dict] = None,
             fault_policy=None, **kw) -> np.ndarray:
        A = np.asarray(A)
        B = np.asarray(B)
        plan = plan or plan_hybrid_gemm(
            A.shape[0], B.shape[1], A.shape[1], self.devices,
            dtype=np.dtype(A.dtype).name, **self.plan_opts)
        self.last_plan = plan
        out, self.last_span_groups = run_hybrid_gemm(
            A, B, C, alpha, beta, plan, record_spans=record_spans,
            fault_plans=fault_plans, fault_policy=fault_policy)
        return out

    def syrk(self, P, C, alpha: float, beta: float, part=None,
             plan: Optional[HybridPlan] = None,
             record_spans: bool = False,
             fault_plans: Optional[Dict] = None,
             fault_policy=None, **kw) -> np.ndarray:
        P = np.asarray(P)
        plan = plan or plan_hybrid_syrk(
            P.shape[0], P.shape[1], self.devices,
            dtype=np.dtype(P.dtype).name, **self.plan_opts)
        self.last_plan = plan
        out, self.last_span_groups = run_hybrid_syrk(
            P, C, alpha, beta, plan, record_spans=record_spans,
            fault_plans=fault_plans, fault_policy=fault_policy)
        return out

    def attention(self, q, k_cache, v_cache,
                  plan: Optional[HybridPlan] = None,
                  record_spans: bool = False, **kw) -> np.ndarray:
        k_cache = np.asarray(k_cache)
        S, hkv, d = k_cache.shape
        opts = dict(self.plan_opts)
        opts["nbuf_options"] = tuple(
            nb for nb in opts["nbuf_options"] if nb >= 2) or (2,)
        opts["max_steps"] = max(opts["max_steps"], 4096)
        plan = plan or plan_hybrid_attention(
            S, hkv, d, np.asarray(q).shape[0], self.devices,
            dtype=np.dtype(k_cache.dtype).name, **opts)
        self.last_plan = plan
        out, self.last_span_groups = run_hybrid_attention(
            q, k_cache, v_cache, plan, record_spans=record_spans)
        return out
