"""Hybrid planning: balanced split -> per-device tuned sub-plans.

The balancer decides *how much* each device gets; the tuner decides *how*
each device runs its share.  This module closes the loop by using the tuner
itself as the balance loop's cost oracle: each candidate share is planned
with ``tune.search`` (partition geometry, stream count, buffer depth ranked
by ``simulate()`` under that device's profile) and the plan's makespan is
the predicted finish time the balancer equalizes.  The converged
:class:`HybridPlan` therefore carries per-device ``(GemmPartition,
TunedPlan)`` pairs whose recorded makespans already agree within the
balancer tolerance — the property ``benchmarks/bench_hybrid.py`` asserts.

Searches are memoized per (device, share), so re-visited shares across
balance iterations cost nothing, and the winning shares' plans are reused
verbatim in the returned ``HybridPlan``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.partitioner import (LANE, SUBLANE, AttentionPartition,
                                    GemmPartition)
from repro.hybrid.balance import BalanceResult, DeviceSpec, balance_units
from repro.tune.search import TunedPlan, search_attention, search_gemm


@dataclasses.dataclass(frozen=True)
class DevicePlan:
    """One device's slice of the hybrid problem: where it starts, how many
    units it owns, and the tuned pipeline configuration for that
    sub-problem."""

    device: DeviceSpec
    start: int
    length: int
    plan: TunedPlan

    def gemm_partition(self) -> GemmPartition:
        return self.plan.gemm_partition()

    def attention_partition(self) -> AttentionPartition:
        return self.plan.attention_partition()

    @property
    def predicted_makespan(self) -> float:
        return self.plan.makespan


@dataclasses.dataclass(frozen=True)
class HybridPlan:
    """Complete co-scheduling plan: disjoint contiguous spans covering the
    problem, one tuned sub-plan per active device, plus the balance trail.

    ``problem`` is the *full* problem tuple (``(M, N, K)`` for GEMM/SYRK,
    ``(S, kv_heads, head_dim, q_heads)`` for attention); each
    ``DevicePlan.plan.problem`` is the device's sub-problem.
    """

    kernel: str                        # "gemm" | "syrk" | "attention"
    problem: Tuple[int, ...]
    dtype: str
    device_plans: Tuple[DevicePlan, ...]
    balance: BalanceResult

    @property
    def predicted_makespan(self) -> float:
        """Aggregate prediction: devices run concurrently, so the makespan
        is the slowest device's tuned-plan makespan."""
        return max(dp.plan.makespan for dp in self.device_plans)

    @property
    def tolerance(self) -> float:
        return self.balance.tolerance

    def device_names(self) -> Tuple[str, ...]:
        return tuple(dp.device.name for dp in self.device_plans)


def _as_device_specs(
        devices: Sequence[Union[DeviceSpec, Tuple]]) -> Tuple[DeviceSpec, ...]:
    """Accept DeviceSpec objects or bare (name, profile, budget) tuples —
    the entry-point-friendly spelling ``ooc_gemm(devices=[...])`` takes."""
    out = []
    for i, d in enumerate(devices):
        if isinstance(d, DeviceSpec):
            out.append(d)
        else:
            out.append(DeviceSpec(*d))
    if not out:
        raise ValueError("devices must be a non-empty sequence")
    names = [d.name for d in out]
    if len(set(names)) != len(names):
        raise ValueError(f"device names must be unique, got {names}")
    return tuple(out)


def _assemble(kernel: str, problem: Tuple[int, ...], dtype: str,
              devices: Tuple[DeviceSpec, ...], bal: BalanceResult,
              memo: Dict[Tuple[int, int], Optional[TunedPlan]]) -> HybridPlan:
    plans = []
    start = 0
    for i, share in enumerate(bal.shares):
        if share > 0:
            plan = memo[(i, share)]
            if plan is None:
                raise ValueError(
                    f"no feasible {kernel} sub-plan for device "
                    f"{devices[i].name} at share {share} of {bal.total} "
                    f"(budget {devices[i].budget_bytes}B)")
            plans.append(DevicePlan(devices[i], start, share, plan))
        start += share
    return HybridPlan(kernel, problem, dtype, tuple(plans), bal)


def plan_hybrid_gemm(
    M: int,
    N: int,
    K: int,
    devices: Sequence[Union[DeviceSpec, Tuple]],
    *,
    kernel: str = "gemm",
    dtype: str = "float32",
    tolerance: float = 0.05,
    max_iters: int = 16,
    nstreams_options: Sequence[int] = (1, 2),
    nbuf_options: Sequence[int] = (1, 2, 3),
    max_steps: int = 2048,
) -> HybridPlan:
    """Balance a GEMM (or SYRK) row split and tune each device's band.

    Device i computes C rows ``[start_i, start_i + length_i)``: its
    sub-problem is a ``length_i x N x K`` GEMM against the full B (SYRK: the
    full transposed panel), planned by ``tune.search`` under its own profile
    and budget.  The returned plan's per-device predicted makespans agree
    within ``tolerance`` whenever the balancer converged.
    """
    if kernel not in ("gemm", "syrk"):
        raise ValueError(f"plan_hybrid_gemm cannot plan kernel {kernel!r}")
    devs = _as_device_specs(devices)
    dtype = np.dtype(dtype).name
    memo: Dict[Tuple[int, int], Optional[TunedPlan]] = {}

    def cost(i: int, rows: int) -> float:
        key = (i, rows)
        if key not in memo:
            try:
                memo[key] = search_gemm(
                    rows, N, K, devs[i].budget_bytes, devs[i].profile,
                    kernel=kernel, dtype=dtype, tier=devs[i].tier,
                    fingerprint=f"hybrid-{devs[i].name}",
                    nstreams_options=nstreams_options,
                    nbuf_options=nbuf_options, max_steps=max_steps)
            except ValueError:
                memo[key] = None
        plan = memo[key]
        return plan.makespan if plan is not None else float("inf")

    bal = balance_units(M, len(devs), cost, tolerance=tolerance,
                        max_iters=max_iters, align=SUBLANE)
    return _assemble(kernel, (M, N, K), dtype, devs, bal, memo)


def plan_hybrid_syrk(
    n: int,
    K: int,
    devices: Sequence[Union[DeviceSpec, Tuple]],
    *,
    dtype: str = "float32",
    **kw,
) -> HybridPlan:
    """Row-band SYRK across devices: band i computes ``C[rows_i, :] =
    alpha * P[rows_i, :] @ P^T + beta * C[rows_i, :]`` — a rectangular
    sub-SYRK whose ``Pt`` operand spans the full panel."""
    return plan_hybrid_gemm(n, n, K, devices, kernel="syrk", dtype=dtype,
                            **kw)


def plan_hybrid_attention(
    seq_len: int,
    kv_heads: int,
    head_dim: int,
    q_heads: int,
    devices: Sequence[Union[DeviceSpec, Tuple]],
    *,
    dtype: str = "float16",
    tolerance: float = 0.05,
    max_iters: int = 16,
    nstreams_options: Sequence[int] = (1, 2),
    nbuf_options: Sequence[int] = (2, 3),
    max_steps: int = 4096,
) -> HybridPlan:
    """Balance the KV cache across devices: device i streams positions
    ``[start_i, start_i + length_i)`` and produces an un-normalized
    online-softmax partial ``(m, l, acc)``; the executor merges partials
    exactly (the standard flash-attention combine)."""
    devs = _as_device_specs(devices)
    dtype = np.dtype(dtype).name
    memo: Dict[Tuple[int, int], Optional[TunedPlan]] = {}

    def cost(i: int, positions: int) -> float:
        key = (i, positions)
        if key not in memo:
            try:
                memo[key] = search_attention(
                    positions, kv_heads, head_dim, q_heads,
                    devs[i].budget_bytes, devs[i].profile,
                    dtype=dtype, tier=devs[i].tier,
                    fingerprint=f"hybrid-{devs[i].name}",
                    nstreams_options=nstreams_options,
                    nbuf_options=nbuf_options, max_steps=max_steps)
            except ValueError:
                memo[key] = None
        plan = memo[key]
        return plan.makespan if plan is not None else float("inf")

    bal = balance_units(seq_len, len(devs), cost, tolerance=tolerance,
                        max_iters=max_iters, align=LANE)
    return _assemble("attention", (seq_len, kv_heads, head_dim, q_heads),
                     dtype, devs, bal, memo)
