"""Fault taxonomy for the injection + recovery subsystem (DESIGN.md §12).

Four error classes cover the failure modes a long-running out-of-core
kernel meets in practice, each paired with the recovery action that is
actually sound for it:

  * ``TransferError``  ("h2d_error")  — a transient link failure on a
    host<->device transfer.  Recovery: per-op retry with exponential
    backoff; the op is idempotent (it re-reads host truth / re-lands the
    same in-flight block), so retrying is exact.
  * ``ComputeFault``   ("compute_nan") — a compute op produced garbage
    (NaNs from a soft error, a bad reduction, ...).  Recovery:
    block-granular replay from the block's last host-consistent point;
    the static schedule makes the redo-set exactly computable
    (:mod:`repro.fault.replay`).
  * ``DeviceLostError`` ("device_lost") — the device is gone mid-run.
    Not recoverable inside one executor; the hybrid co-scheduler catches
    it, rebalances the lost share over the survivors and resumes.
  * ``OomError``        ("oom")        — the device ran out of memory.
    Not recoverable at the current plan; entry points catch it and walk
    the degradation ladder (halve nbuf, drop lookahead, halve budget)
    before recompiling.
"""

from __future__ import annotations


class FaultError(RuntimeError):
    """Base class for every injected (or real) fault the subsystem models."""


class TransferError(FaultError):
    """Transient host<->device transfer failure — retryable."""


class ComputeFault(FaultError):
    """A compute op produced corrupt output — replayable at block grain."""


class DeviceLostError(FaultError):
    """The device disappeared mid-run — rebalance onto the survivors."""


class OomError(FaultError):
    """Device memory exhausted at the current plan — degrade and replan."""


# error-class string (the FaultSpec vocabulary) -> exception type
ERROR_CLASSES = {
    "h2d_error": TransferError,
    "compute_nan": ComputeFault,
    "device_lost": DeviceLostError,
    "oom": OomError,
}
