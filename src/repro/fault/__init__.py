"""Deterministic fault injection + recovery for OOC schedules (§12).

The subsystem in one picture::

    plan  = FaultPlan.random(seed=7, sched=sched, rate=0.02)
    pol   = FaultPolicy(max_retries=3, backoff_base=0.01)
    ex.run(sched, operands, outputs, faults=plan, policy=pol)
    ex.last_fault_stats   # injected / retries / replayed_ops / bytes

Addressing (:mod:`.plan`), taxonomy (:mod:`.errors`), recovery knobs
(:mod:`.policy`) and offline redo-set analysis (:mod:`.replay`) are
separate modules; the executor hook itself lives in
``repro.core.runtime`` and the oom/device_lost handlers in the entry
points that own the replanning paths.
"""

from repro.fault.errors import (ComputeFault, DeviceLostError, ERROR_CLASSES,
                                FaultError, OomError, TransferError)
from repro.fault.plan import (FaultInjector, FaultPlan, FaultSpec,
                              REPLAYABLE_KERNELS)
from repro.fault.policy import DegradeStep, FaultPolicy
from repro.fault.replay import mean_redo_len, redo_cost, redo_set

__all__ = [
    "ComputeFault", "DegradeStep", "DeviceLostError", "ERROR_CLASSES",
    "FaultError", "FaultInjector", "FaultPlan", "FaultPolicy", "FaultSpec",
    "OomError", "REPLAYABLE_KERNELS", "TransferError",
    "mean_redo_len", "redo_cost", "redo_set",
]
