"""Deterministic, schedule-addressable fault plans (DESIGN.md §12).

A :class:`FaultPlan` is a seeded, replayable list of :class:`FaultSpec`
entries addressed the same way the schedule itself is addressed: by op
index in global issue order (optionally pinned to a stream as a
cross-check).  ``FaultPlan.random(seed, sched, rate)`` draws a Bernoulli
plan over the schedule's *eligible* ops, so the conformance fuzzer can
generate thousands of distinct fault scenarios that are each exactly
reproducible from ``(seed, schedule)``.

Eligibility is deliberately conservative: transfer faults target H2D ops
and slice write-backs (both idempotent), compute faults target only the
replayable single-writer kernels (``REPLAYABLE_KERNELS``).  Finalize
handlers such as ``lu_writeback`` mutate host state irreversibly
(row-swap replay on the host matrix) and are never injected.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.streams import BlockRef, Op, OpKind, Schedule
from repro.fault.errors import ERROR_CLASSES

# Compute kernels whose faults the executor can recover by block-granular
# replay: exactly one written parity buffer, no irreversible host or
# scratch mutation (``panel_lu`` re-parks its pivots on replay, which is
# idempotent because ``lu_writeback`` pops them only at finalize time).
REPLAYABLE_KERNELS = frozenset(
    {"dgemm", "panel_chol", "panel_trsm", "panel_lu", "lu_trsm"})


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One addressed fault: fail op ``op`` with error class ``cls``.

    ``times`` faults that many consecutive *attempts* of the op (times=2
    against a retry policy means: first try faults, first retry faults,
    second retry succeeds).  ``stream``/``device`` are optional pins the
    injector cross-checks against the op actually executing — a mismatch
    is a plan-authoring error and raises, it does not silently no-op.
    """

    op: int
    cls: str
    times: int = 1
    stream: Optional[int] = None
    device: Optional[str] = None

    def __post_init__(self):
        if self.cls not in ERROR_CLASSES:
            raise ValueError(
                f"unknown fault class {self.cls!r}; expected one of "
                f"{sorted(ERROR_CLASSES)}")
        if self.op < 0:
            raise ValueError(f"fault op index must be >= 0, got {self.op}")
        if self.times < 1:
            raise ValueError(f"fault times must be >= 1, got {self.times}")


def _eligible_class(op: Op) -> Optional[str]:
    """The fault class ``FaultPlan.random`` may draw for ``op`` (None if
    the op must never be injected)."""
    if op.kind == OpKind.H2D:
        return "h2d_error"
    if op.kind == OpKind.COMPUTE:
        ref = op.payload
        if (isinstance(ref, BlockRef) and ref.kernel in REPLAYABLE_KERNELS
                and len(op.buffers_written) == 1):
            return "compute_nan"
    return None


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An immutable, seeded set of faults for one schedule execution.

    Pass the plan itself to ``ScheduleExecutor.run(faults=...)`` (each run
    builds a fresh one-shot :class:`FaultInjector` from it), or call
    :meth:`injector` explicitly to keep a handle on the injection log.
    """

    specs: Tuple[FaultSpec, ...] = ()
    seed: Optional[int] = None

    def __post_init__(self):
        object.__setattr__(self, "specs", tuple(self.specs))

    def __len__(self) -> int:
        return len(self.specs)

    def __bool__(self) -> bool:
        return bool(self.specs)

    @classmethod
    def random(cls, seed: int, sched: Schedule, rate: float,
               classes: Sequence[str] = ("h2d_error", "compute_nan"),
               max_faults: Optional[int] = None) -> "FaultPlan":
        """Bernoulli(``rate``) draw over the schedule's eligible ops,
        deterministic in ``seed``: the conformance fuzzer's generator."""
        if not 0.0 <= rate <= 1.0:
            raise ValueError(f"fault rate must be in [0, 1], got {rate}")
        rng = np.random.default_rng(seed)
        allowed = frozenset(classes)
        specs: List[FaultSpec] = []
        for i, op in enumerate(sched.ops):
            c = _eligible_class(op)
            # one rng draw per op regardless of eligibility, so the plan
            # for a given (seed, schedule) never shifts when the allowed
            # class set changes
            hit = rng.random() < rate
            if c is None or c not in allowed or not hit:
                continue
            specs.append(FaultSpec(op=i, cls=c, stream=op.stream))
            if max_faults is not None and len(specs) >= max_faults:
                break
        return cls(tuple(specs), seed=seed)

    def for_device(self, name: str) -> "FaultPlan":
        """Sub-plan of the specs pinned to device ``name`` (plus unpinned
        ones) — how a hybrid-level plan shards over member executors."""
        keep = tuple(s for s in self.specs
                     if s.device is None or s.device == name)
        return FaultPlan(keep, seed=self.seed)

    def injector(self) -> "FaultInjector":
        return FaultInjector(self)


class FaultInjector:
    """Mutable per-run consumption state over a :class:`FaultPlan`.

    ``check(i, op)`` is consulted once per *attempt* of op ``i`` and
    consumes one occurrence: a spec with ``times=k`` faults the op's
    first ``k`` attempts.  Every consumed fault is appended to
    ``injected`` as ``(op_index, cls)`` — the ground truth the fuzzer
    reconciles byte counters against.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._live: Dict[int, List[List]] = {}
        for s in plan.specs:
            self._live.setdefault(s.op, []).append(
                [s.cls, s.times, s.stream])
        self.injected: List[Tuple[int, str]] = []

    def check(self, i: int, op: Op) -> Optional[str]:
        """Fault class to inject for this attempt of op ``i``, or None."""
        queue = self._live.get(i)
        if not queue:
            return None
        cls, remaining, stream = queue[0]
        if stream is not None and stream != op.stream:
            raise ValueError(
                f"fault plan pins op {i} to stream {stream} but the "
                f"schedule runs it on stream {op.stream}")
        if remaining <= 1:
            queue.pop(0)
            if not queue:
                del self._live[i]
        else:
            queue[0][1] = remaining - 1
        self.injected.append((i, cls))
        return cls

    def exhausted(self) -> bool:
        """True once every planned fault has been consumed."""
        return not self._live
