"""Static redo-set derivation from the schedule's event DAG (§12).

The executor recovers a compute fault dynamically (it keeps, per parity
buffer, the value at the last host-consistent point plus the compute
chain applied since).  Because the schedule is static, the same redo-set
is derivable *offline* from the op list alone: walk back from the faulted
op to its written buffer's last host-consistent point — an H2D load into
the buffer, or a slice write-back reading it (the "last completed
write-back") — and collect the computes that wrote the buffer since.

That makes the recovery cost analyzable before running anything:
:func:`redo_cost` prices a fault at any op under an engine model, and the
conformance tests assert the executor's dynamic chains match this static
derivation exactly.
"""

from __future__ import annotations

from typing import List

from repro.core.streams import BlockRef, OpKind, Schedule


def redo_set(sched: Schedule, op_index: int) -> List[int]:
    """Op indices re-executed if ``op_index``'s output block is lost.

    The last entry is ``op_index`` itself; the preceding entries are the
    compute chain (in issue order) that rebuilds the block's value at the
    fault point from its last host-consistent snapshot.  Raises for ops
    that are not single-writer computes — those are not replayable and
    have no redo-set.
    """
    op = sched.ops[op_index]
    if op.kind != OpKind.COMPUTE or len(op.buffers_written) != 1:
        raise ValueError(
            f"op {op_index} ({op.tag}) is not a single-writer compute; "
            f"redo-sets exist only for replayable computes")
    key = op.buffers_written[0]
    start = -1
    for j in range(op_index - 1, -1, -1):
        oj = sched.ops[j]
        if oj.kind == OpKind.H2D and key in oj.buffers_written:
            start = j
            break
        if (oj.kind == OpKind.D2H and key in oj.buffers_read
                and not isinstance(oj.payload, BlockRef)):
            start = j
            break
    redo = [j for j in range(start + 1, op_index)
            if sched.ops[j].kind == OpKind.COMPUTE
            and key in sched.ops[j].buffers_written]
    return redo + [op_index]


def redo_cost(sched: Schedule, hw, op_index: int) -> float:
    """Modeled seconds to replay a compute fault at ``op_index`` under
    engine model ``hw`` (sum of the redo-set's op durations)."""
    return sum(hw.duration(sched.ops[j]) for j in redo_set(sched, op_index))


def mean_redo_len(sched: Schedule) -> float:
    """Average redo-set length over the schedule's replayable computes —
    the ``redo_factor`` a calibrated simulator FaultModel would use."""
    lens = []
    for i, op in enumerate(sched.ops):
        if op.kind == OpKind.COMPUTE and len(op.buffers_written) == 1 \
                and isinstance(op.payload, BlockRef):
            try:
                lens.append(len(redo_set(sched, i)))
            except ValueError:
                continue
    return sum(lens) / len(lens) if lens else 0.0
