"""Recovery policies: retry schedules and degradation ladders (§12).

One :class:`FaultPolicy` object parameterizes every recovery mechanism in
the stack:

  * transient transfer errors — per-op retry with exponential backoff
    (:meth:`backoff` / :meth:`backoff_schedule`; ``sleep`` is injectable
    so tests pin the schedule against a fake clock);
  * compute faults — block-granular replay, bounded by ``max_retries``
    attempts per op just like transfers;
  * oom — the :meth:`degrade_ladder` walked by the entry points
    (``ooc_cholesky`` / ``ooc_lu`` / ``ooc_gemm``): halve nbuf, drop
    lookahead, then halve the memory budget and recompile through the
    existing planning paths.  Every attempted step is recorded in
    ``degrades`` so tests (and users) can see exactly how the run was
    degraded.

:meth:`fault_model` bridges to the simulator's faulted-makespan mode so
the tuner can rank plans by expected cost under this policy's backoff
constants (``simulate(sched, hw, faults=policy.fault_model(rate))``).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, List


@dataclasses.dataclass(frozen=True)
class DegradeStep:
    """One rung of the oom ladder: the knob turned and the resulting
    plan-input triple to recompile with."""

    action: str          # "halve_nbuf" | "drop_lookahead" | "halve_budget"
    nbuf: int
    lookahead: int
    budget_bytes: int


@dataclasses.dataclass
class FaultPolicy:
    """Recovery parameters threaded through executor and entry points."""

    max_retries: int = 3
    backoff_base: float = 0.01
    backoff_factor: float = 2.0
    max_budget_halvings: int = 2
    sleep: Callable[[float], None] = time.sleep
    # attempted degrade steps, appended by the entry points' oom handlers
    degrades: List[DegradeStep] = dataclasses.field(default_factory=list)

    def backoff(self, attempt: int) -> float:
        """Delay before retry ``attempt`` (1-based): base * factor^(a-1)."""
        return self.backoff_base * self.backoff_factor ** (attempt - 1)

    def backoff_schedule(self) -> List[float]:
        """The full pinned delay sequence a fully-retried op sleeps."""
        return [self.backoff(a) for a in range(1, self.max_retries + 1)]

    def degrade_ladder(self, *, nbuf: int, lookahead: int,
                       budget_bytes: int,
                       tuned: bool = False) -> List[DegradeStep]:
        """Successive recompile attempts after an oom, cheapest knob first.

        Untuned: halve nbuf (if > 1), drop lookahead (if > 0), then halve
        the budget up to ``max_budget_halvings`` times.  Tuned: the tuner
        owns nbuf/lookahead, so the ladder is budget halvings only — each
        rung re-searches at the reduced budget, which is what makes the
        degraded run land on exactly the plan the tuner would pick there.
        """
        steps: List[DegradeStep] = []
        nb, la, b = nbuf, lookahead, budget_bytes
        if not tuned:
            if nb > 1:
                nb = max(1, nb // 2)
                steps.append(DegradeStep("halve_nbuf", nb, la, b))
            if la > 0:
                la = 0
                steps.append(DegradeStep("drop_lookahead", nb, la, b))
        for _ in range(self.max_budget_halvings):
            b //= 2
            if b <= 0:
                break
            steps.append(DegradeStep("halve_budget", nb, la, b))
        return steps

    def fault_model(self, rate: float):
        """Simulator :class:`~repro.core.simulator.FaultModel` under this
        policy's backoff constants, for expected-makespan ranking."""
        from repro.core.simulator import FaultModel

        return FaultModel(rate=rate, mean_backoff=self.backoff_base)
