"""Mixture-of-Experts layer: top-k routing, capacity-based grouped dispatch.

Expert weights are the canonical "operands exceed the fast tier" case
(DESIGN.md §4): they are expert-parallel over the ``model`` mesh axis and the
dispatch path is gather/scatter-shaped (bytes, not FLOPs), so compiled FLOPs
track *active* experts only — the 6·N_active·D roofline identity.

Dispatch = the grouped, sort-based scheme: tokens are grouped (per data
shard), assignments sorted by expert id locally, each expert takes its first
``capacity`` tokens (drop-on-overflow), experts run as one batched einsum.
Supports shared (always-on) experts for DeepSeek-MoE.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L

Params = Dict[str, jax.Array]


def moe_init(key, d_model, d_ff, n_experts, n_shared=0,
             dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 5)
    p = {
        "router": L.dense_init(ks[0], (d_model, n_experts), 0, jnp.float32),
        "w_gate": L.dense_init(ks[1], (n_experts, d_model, d_ff), 1, dtype),
        "w_up": L.dense_init(ks[2], (n_experts, d_model, d_ff), 1, dtype),
        "w_down": L.dense_init(ks[3], (n_experts, d_ff, d_model), 1, dtype),
    }
    if n_shared:
        p["shared"] = L.mlp_init(ks[4], d_model, n_shared * d_ff,
                                 gated=True, dtype=dtype)
    return p


def moe_axes(n_shared=0) -> Params:
    a = {
        "router": ("embed", None),
        "w_gate": ("experts", "embed", None),
        "w_up": ("experts", "embed", None),
        "w_down": ("experts", None, "embed"),
    }
    if n_shared:
        a["shared"] = L.mlp_axes(gated=True)
    return a


def _round_up(x, m):
    return int((x + m - 1) // m * m)


def moe_apply(
    p: Params,
    x: jax.Array,                 # (B, S, D)
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    groups: Optional[int] = None,
    shard_ec=None,                # constrain (G, E, C, D) expert activations
    shard_rep=None,               # constrain (G, E, C, D) to model-replicated
):
    """Grouped sort-based dispatch, vmapped per group.

    §Perf notes (qwen3-moe train_4k iterations 1b/2 — both REFUTED):
    a batched (vmap-free) formulation — with or without model-axis
    constraints on the (G, A, D) assignment tensors — made GSPMD
    replicate-then-partition the data-dependent gathers
    (572–608 GiB/device vs 184 baseline).  The vmapped form keeps every
    per-group op group-local under the batch(data) sharding.  Kept win:
    combine weights cast to the value dtype (bf16), halving the combine
    tensors and their backward all-reduces.
    """

    B, S, D = x.shape
    E = p["router"].shape[1]
    G = groups or B
    T = B * S
    assert T % G == 0, (T, G)
    Tg = T // G
    C = _round_up(int(np.ceil(Tg * top_k / E * capacity_factor)), 16)
    C = min(C, Tg * top_k)

    xf = x.reshape(G, Tg, D)
    logits = (xf.astype(jnp.float32) @ p["router"])          # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, top_k)                # (G, Tg, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    def dispatch(xg, eidx_g):
        # xg: (Tg, D); eidx_g: (Tg, k) -> (E, C, D), slot bookkeeping
        fe = eidx_g.reshape(-1)                              # (Tg*k,)
        order = jnp.argsort(fe, stable=True)
        fe_s = fe[order]
        tok_s = order // top_k
        start = jnp.searchsorted(fe_s, jnp.arange(E))        # (E,)
        pos = jnp.arange(Tg * top_k) - start[fe_s]
        valid = pos < C
        slot = jnp.where(valid, fe_s * C + pos, E * C)       # overflow -> sink
        buf = jnp.zeros((E * C + 1, D), x.dtype).at[slot].set(
            xg[tok_s], mode="drop")
        return buf[: E * C].reshape(E, C, D), (order, slot, valid)

    ein, book = jax.vmap(dispatch)(xf, eidx)                 # (G, E, C, D)
    if shard_rep is not None:
        # pin the scatter output to model-replicated: the reshard to
        # expert-sharded is then a local dynamic-slice forward and an
        # all-GATHER backward — without this GSPMD replicates the
        # data-dependent gathers via fp32 all-reduce (5.2 TB/step/device
        # on qwen3-moe train_4k; §Perf iteration 4)
        ein = shard_rep(ein)
    if shard_ec is not None:
        ein = shard_ec(ein)

    up = jnp.einsum("gecd,edf->gecf", ein, p["w_up"])
    gate = jnp.einsum("gecd,edf->gecf", ein, p["w_gate"])
    out = jnp.einsum("gecf,efd->gecd", jax.nn.silu(gate) * up, p["w_down"])
    if shard_ec is not None:
        out = shard_ec(out)
    if shard_rep is not None:
        # one explicit all-gather over the model axis; the combine gathers
        # below are then local (backward: reduce-scatter)
        out = shard_rep(out)

    def combine(out_g, order_slot_valid, gates_g):
        order, slot, valid = order_slot_valid
        flat = out_g.reshape(E * C, D)
        val_s = jnp.take(flat, jnp.minimum(slot, E * C - 1), axis=0)
        val_s = val_s * valid[:, None].astype(val_s.dtype)
        val = jnp.zeros((Tg * top_k, D), val_s.dtype).at[order].set(val_s)
        val = val.reshape(Tg, top_k, D)
        # weight in the value dtype: fp32 gates would upcast (Tg,k,D)
        return (val * gates_g[..., None].astype(val.dtype)).sum(axis=1)

    y = jax.vmap(combine)(out, book, gates)                  # (G, Tg, D)
    y = y.reshape(B, S, D).astype(x.dtype)

    if "shared" in p:
        y = y + L.mlp_apply(p["shared"], x, gated=True)
    return y
