"""Model zoo registry: family -> model class.

Every model implements the same API (init/forward/prefill/decode/
init_cache/cache_specs/param_logical_axes/cache_logical_axes) so the
training/serving steps and the dry-run are arch-agnostic.
"""

from repro.configs.base import ArchConfig
from repro.models.mamba2 import Mamba2Model
from repro.models.rwkv6 import RWKV6Model
from repro.models.transformer import TransformerModel
from repro.models.zamba2 import Zamba2Model

_FAMILIES = {
    "dense": TransformerModel,
    "moe": TransformerModel,
    "audio": TransformerModel,   # encoder backbone; stub frontend
    "vlm": TransformerModel,     # decoder backbone; stub frontend
    "ssm": None,                 # resolved below per ssm kind
    "hybrid": Zamba2Model,
}


def get_model(cfg: ArchConfig, shard_ec=None, weight_gather=None,
              shard_assign=None):
    if cfg.family == "ssm":
        cls = Mamba2Model if cfg.ssm_state else RWKV6Model
    else:
        cls = _FAMILIES[cfg.family]
    return cls(cfg, shard_ec=shard_ec, weight_gather=weight_gather,
               shard_assign=shard_assign)


__all__ = ["get_model", "Mamba2Model", "RWKV6Model", "TransformerModel",
           "Zamba2Model"]
