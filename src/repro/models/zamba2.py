"""Zamba2: Mamba2 backbone with *shared* transformer blocks.

Structure [arXiv:2411.15242]: a stack of Mamba2 blocks; every
``shared_attn_every`` blocks, one of ``num_shared_attn_blocks`` full
transformer blocks (attention + MLP, weights shared across sites, applied
round-robin) runs on the hidden state.  Weight sharing keeps the parameter
count low while giving the SSM backbone periodic global attention.

Faithful simplification (DESIGN.md §5): the shared block consumes the hidden
state directly (upstream Zamba2 concatenates the original embedding and
applies a LoRA per site).

Decode state = per-layer Mamba2 (h, conv) + per-*site* KV caches for the
shared blocks.  The backbone is O(1) in sequence length, so ``long_500k``
runs with only the (sequence-shardable) shared-site KV caches scaling with S.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import mamba2 as MB

Params = Dict[str, jax.Array]


class Zamba2Model:
    def __init__(self, cfg: ArchConfig, shard_ec=None, weight_gather=None,
                 shard_assign=None):
        assert cfg.shared_attn_every > 0
        self.cfg = cfg
        self.weight_gather = weight_gather
        every = cfg.shared_attn_every
        self.n_sites = cfg.num_layers // every
        self.main = cfg.num_layers - cfg.num_layers % every  # scanned in segments
        self.tail = cfg.num_layers - self.main

    # ------------------------------------------------------------------ init
    def _shared_block_init(self, key) -> Params:
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {
            "attn_norm": jnp.ones((cfg.d_model,), cfg.pdtype),
            "mlp_norm": jnp.ones((cfg.d_model,), cfg.pdtype),
            "attn": L.attention_init(k1, cfg.d_model, cfg.num_heads,
                                     cfg.num_kv_heads, cfg.head_dim_,
                                     cfg.qkv_bias, cfg.pdtype),
            "mlp": L.mlp_init(k2, cfg.d_model, cfg.d_ff, True, cfg.pdtype),
        }


    def _top(self, params):
        """Gather non-layer weights (embed / lm_head) over data axes at
        point-of-use — same FSDP rationale as the per-layer hook."""
        if self.weight_gather is None:
            return params
        keys = [k for k in ("embed", "lm_head") if k in params]
        axes = self.param_logical_axes()
        sub = self.weight_gather({k: params[k] for k in keys},
                                 {k: axes[k] for k in keys})
        return {**params, **sub}

    def init(self, key) -> Dict:
        cfg = self.cfg
        keys = jax.random.split(key, cfg.num_layers + 4)

        def one(k):
            return {"norm": jnp.ones((cfg.d_model,), cfg.pdtype),
                    "mamba": MB.mamba_init(k, cfg)}

        layers = jax.vmap(one)(keys[: cfg.num_layers])
        shared = jax.vmap(self._shared_block_init)(
            jax.random.split(keys[-3], cfg.num_shared_attn_blocks))
        return {
            "embed": L.embedding_init(keys[-2], cfg.vocab_size, cfg.d_model,
                                      cfg.pdtype),
            "layers": layers,
            "shared": shared,
            "final_norm": jnp.ones((cfg.d_model,), cfg.pdtype),
            "lm_head": L.dense_init(keys[-1], (cfg.d_model, cfg.vocab_size),
                                    0, cfg.pdtype),
        }

    def layer_axes(self) -> Dict:
        return {"norm": ("embed",), "mamba": MB.mamba_axes(self.cfg)}

    def shared_axes(self) -> Dict:
        cfg = self.cfg
        return {
            "attn_norm": ("embed",), "mlp_norm": ("embed",),
            "attn": L.attention_axes(cfg.qkv_bias),
            "mlp": L.mlp_axes(True),
        }

    def param_logical_axes(self) -> Dict:
        def stack(tree):
            return jax.tree.map(lambda ax: ("layer",) + tuple(ax), tree,
                                is_leaf=lambda x: isinstance(x, tuple))

        return {
            "embed": ("vocab", "embed"),
            "layers": stack(self.layer_axes()),
            "shared": stack(self.shared_axes()),
            "final_norm": ("embed",),
            "lm_head": ("embed", "vocab"),
        }

    # --------------------------------------------------------------- helpers
    def _site_params(self, params, site: int):
        sel = site % self.cfg.num_shared_attn_blocks
        return jax.tree.map(lambda p: p[sel], params["shared"])

    def _mamba_body(self, collect: bool):
        cfg = self.cfg

        def body(carry, lp):
            x = carry
            if self.weight_gather is not None:
                lp = self.weight_gather(lp, self.layer_axes())
            y, h, tail = MB.mamba_apply(
                lp["mamba"], L.rms_norm(x, lp["norm"], cfg.norm_eps), cfg)
            return x + y, ((h, tail) if collect else None)

        if cfg.remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        return body

    def _segments(self, params):
        """Split stacked mamba params into (segments, tail)."""
        cfg = self.cfg
        every = cfg.shared_attn_every
        seg = jax.tree.map(lambda p: p[: self.main].reshape(
            (self.n_sites, every) + p.shape[1:]), params["layers"])
        tail = jax.tree.map(lambda p: p[self.main:], params["layers"])
        return seg, tail

    def _shared_apply(self, sp, x, positions):
        cfg = self.cfg
        if cfg.remat:
            # the shared blocks sit OUTSIDE the segment scans — without
            # their own remat their attention residuals are saved for the
            # backward (measured: ~30 GiB/chip fixed, microbatch-invariant;
            # EXPERIMENTS.md §Perf fit sweep)
            return jax.checkpoint(
                lambda sp_, x_: self._shared_apply_inner(sp_, x_, positions),
                policy=jax.checkpoint_policies.nothing_saveable)(sp, x)
        return self._shared_apply_inner(sp, x, positions)

    def _shared_apply_inner(self, sp, x, positions):
        cfg = self.cfg
        if self.weight_gather is not None:
            sp = self.weight_gather(sp, self.shared_axes())
        h, kv = L.attention_apply(
            sp["attn"], L.rms_norm(x, sp["attn_norm"], cfg.norm_eps),
            n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads,
            head_dim=cfg.head_dim_, positions=positions,
            rope_theta=cfg.rope_theta, causal=True, block_q=cfg.block_q,
            unroll=not cfg.scan_layers)
        x = x + h
        x = x + L.mlp_apply(sp["mlp"],
                            L.rms_norm(x, sp["mlp_norm"], cfg.norm_eps))
        return x, kv

    # --------------------------------------------------------------- forward
    def _run(self, params, x, positions, collect: bool):
        body = self._mamba_body(collect)
        seg, tail = self._segments(params)
        states, kvs = [], []
        def run_stack(x, lp, n):
            if self.cfg.scan_layers:
                return jax.lax.scan(body, x, lp)
            outs = []
            for i in range(n):
                x, st = body(x, jax.tree.map(lambda p_: p_[i], lp))
                outs.append(st)
            if outs and outs[0] is not None:
                st = (jnp.stack([o[0] for o in outs], 0),
                      jnp.stack([o[1] for o in outs], 0))
            else:
                st = None
            return x, st

        every = self.cfg.shared_attn_every
        for s in range(self.n_sites):
            lp = jax.tree.map(lambda p: p[s], seg)
            x, st = run_stack(x, lp, every)
            states.append(st)
            x, kv = self._shared_apply(self._site_params(params, s),
                                       x, positions)
            kvs.append(kv)
        if self.tail:
            x, st = run_stack(x, tail, self.tail)
            states.append(st)
        if not collect:
            return x, None, None
        hs = jnp.concatenate([s[0] for s in states], axis=0)
        tails = jnp.concatenate([s[1] for s in states], axis=0)
        cfg = self.cfg
        if kvs:
            k = jnp.stack([kv[0] for kv in kvs], axis=0)  # (sites,B,S,Hkv,dh)
            v = jnp.stack([kv[1] for kv in kvs], axis=0)
        else:  # degenerate depth (cost compiles at L < shared_attn_every)
            B, S = x.shape[0], x.shape[1]
            k = jnp.zeros((0, B, S, cfg.num_kv_heads, cfg.head_dim_),
                          cfg.adtype)
            v = jnp.zeros_like(k)
        return x, (hs, tails), (k, v)

    def forward(self, params, inputs):
        cfg = self.cfg
        params = self._top(params)
        x = jnp.take(params["embed"], inputs, axis=0).astype(cfg.adtype)
        B, S = inputs.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x, _, _ = self._run(params, x, positions, False)
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x @ params["lm_head"].astype(x.dtype)

    # ----------------------------------------------------------------- cache
    def init_cache(self, batch: int, max_len: int) -> Dict:
        cfg = self.cfg
        di, H, P, N = MB.mamba_dims(cfg)
        conv_dim = di + 2 * N
        return {
            "h": jnp.zeros((cfg.num_layers, batch, H, P, N), jnp.float32),
            "conv": jnp.zeros((cfg.num_layers, batch, cfg.conv_width - 1,
                               conv_dim), cfg.adtype),
            "k": jnp.zeros((self.n_sites, batch, max_len, cfg.num_kv_heads,
                            cfg.head_dim_), cfg.adtype),
            "v": jnp.zeros((self.n_sites, batch, max_len, cfg.num_kv_heads,
                            cfg.head_dim_), cfg.adtype),
            "len": jnp.zeros((batch,), jnp.int32),
        }

    def cache_logical_axes(self) -> Dict:
        kv = ("layer", "batch", "cache_seq", "kv_heads", None)
        return {"h": ("layer", "batch", "inner_heads", None, None),
                "conv": ("layer", "batch", None, "inner"),
                "k": kv, "v": kv, "len": ("batch",)}

    def cache_specs(self, batch: int, max_len: int) -> Dict:
        return jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
            jax.eval_shape(lambda: self.init_cache(batch, max_len)))

    def prefill(self, params, inputs, max_len: Optional[int] = None):
        cfg = self.cfg
        params = self._top(params)
        B, S = inputs.shape
        x = jnp.take(params["embed"], inputs, axis=0).astype(cfg.adtype)
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x, (hs, tails), (k, v) = self._run(params, x, positions, True)
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = x[:, -1] @ params["lm_head"].astype(x.dtype)
        pad = (max_len or S) - S
        if pad > 0:
            zeros = ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))
            k = jnp.pad(k, zeros)
            v = jnp.pad(v, zeros)
        cache = {"h": hs, "conv": tails, "k": k.astype(cfg.adtype),
                 "v": v.astype(cfg.adtype),
                 "len": jnp.full((B,), S, jnp.int32)}
        return logits, cache

    def _decode_stack(self, body, x, lp, hc, cc):
        if self.cfg.scan_layers:
            return jax.lax.scan(body, x, (lp, hc, cc))
        n = jax.tree.leaves(lp)[0].shape[0]
        hs, cs = [], []
        for i in range(n):
            x, (h_i, c_i) = body(
                x, (jax.tree.map(lambda p_: p_[i], lp), hc[i], cc[i]))
            hs.append(h_i)
            cs.append(c_i)
        return x, (jnp.stack(hs, 0), jnp.stack(cs, 0))

    # ---------------------------------------------------------------- decode
    def decode(self, params, cache, inputs):
        cfg = self.cfg
        params = self._top(params)
        x = jnp.take(params["embed"], inputs, axis=0).astype(cfg.adtype)
        length = cache["len"]
        every = cfg.shared_attn_every

        def body(carry, scanned):
            x = carry
            lp, h, tail = scanned
            if self.weight_gather is not None:
                lp = self.weight_gather(lp, self.layer_axes())
            y, h, tail = MB.mamba_decode(
                lp["mamba"], L.rms_norm(x, lp["norm"], cfg.norm_eps),
                h, tail, cfg)
            return x + y, (h, tail)

        seg, tailp = self._segments(params)
        seg_cache = lambda t, s0, n: jax.tree.map(
            lambda c: jax.lax.dynamic_slice_in_dim(c, s0, n, axis=0), t)
        hs_out, tails_out, k_out, v_out = [], [], [], []
        for s in range(self.n_sites):
            lp = jax.tree.map(lambda p: p[s], seg)
            hc = jax.lax.dynamic_slice_in_dim(cache["h"], s * every, every, 0)
            cc = jax.lax.dynamic_slice_in_dim(cache["conv"], s * every,
                                              every, 0)
            x, (h_new, c_new) = self._decode_stack(body, x, lp, hc, cc)
            hs_out.append(h_new)
            tails_out.append(c_new)
            # shared attention site
            sp = self._site_params(params, s)
            if self.weight_gather is not None:
                sp = self.weight_gather(sp, self.shared_axes())
            xn = L.rms_norm(x, sp["attn_norm"], cfg.norm_eps)
            hattn, k_site, v_site = L.attention_decode_apply(
                sp["attn"], xn, cache["k"][s], cache["v"][s], length,
                n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads,
                head_dim=cfg.head_dim_, rope_theta=cfg.rope_theta)
            x = x + hattn
            x = x + L.mlp_apply(sp["mlp"],
                                L.rms_norm(x, sp["mlp_norm"], cfg.norm_eps))
            k_out.append(k_site)
            v_out.append(v_site)
        if self.tail:
            hc = cache["h"][self.main:]
            cc = cache["conv"][self.main:]
            x, (h_new, c_new) = self._decode_stack(body, x, tailp, hc, cc)
            hs_out.append(h_new)
            tails_out.append(c_new)
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = x @ params["lm_head"].astype(x.dtype)
        if k_out:
            k_new = jnp.stack(k_out, axis=0)
            v_new = jnp.stack(v_out, axis=0)
        else:
            k_new, v_new = cache["k"], cache["v"]
        new_cache = {
            "h": jnp.concatenate(hs_out, axis=0),
            "conv": jnp.concatenate(tails_out, axis=0),
            "k": k_new,
            "v": v_new,
            "len": length + 1,
        }
        return logits, new_cache
