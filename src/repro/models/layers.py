"""Shared neural-net layers for the model zoo.

All attention paths are *blocked* (never materialize S×S): training/prefill
attention streams KV blocks through an online-softmax carry (the OOC pipeline
pattern of repro.core applied at the model level), and decode attention scans
the cache in O(S) — which is what makes the ``decode_32k``/``long_500k``
serving shapes lowerable.

Parameters are plain nested dicts; initializers take explicit PRNG keys.
Logical sharding axes for every parameter are declared next to its creation
(see ``*_axes`` functions) and resolved to mesh axes by
``repro.distributed.sharding``.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, jax.Array]


# --------------------------------------------------------------------------
# initializers
# --------------------------------------------------------------------------
def dense_init(key, shape, scale_axis: int = 0, dtype=jnp.float32):
    """Truncated-normal fan-in init."""
    fan_in = shape[scale_axis]
    std = 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------
def rms_norm(x, weight, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------------
# rotary embeddings
# --------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float = 1e4):
    """x: (..., S, H, d); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                       # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    cos = jnp.cos(angles)[..., None, :]                      # (..., S, 1, d/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention — blocked causal (training / prefill)
# --------------------------------------------------------------------------
def blockwise_causal_attention(
    q, k, v, *, block_q: int = 512, causal: bool = True,
    unroll: bool = False,
):
    """GQA attention without an S×S intermediate.

    q: (B, S, H, d); k, v: (B, S, Hkv, d).  Scans q in blocks; each block
    computes masked scores against full K (GSPMD shards the S axis of K/V
    when the cache is sequence-sharded).  Peak intermediate is
    (B, H, block_q, S).  ``unroll`` replaces the lax.map with a python loop
    (dry-run cost mode: while bodies are cost-counted once).
    """
    B, S, H, d = q.shape
    hkv = k.shape[2]
    group = H // hkv
    scale = 1.0 / np.sqrt(d)

    if S % block_q:
        block_q = S  # fallback: one block (small/smoke shapes)
    nq = S // block_q

    kg = jnp.repeat(k, group, axis=2) if group > 1 else k    # (B, S, H, d)
    vg = jnp.repeat(v, group, axis=2) if group > 1 else v
    qb = q.reshape(B, nq, block_q, H, d).transpose(1, 0, 2, 3, 4)

    kv_pos = jnp.arange(S)

    def one_block(qi, q_blk):
        # q_blk: (B, bq, H, d)
        s = jnp.einsum("bqhd,bkhd->bhqk", q_blk.astype(jnp.float32),
                       kg.astype(jnp.float32)) * scale
        if causal:
            q_pos = qi * block_q + jnp.arange(block_q)
            mask = kv_pos[None, :] <= q_pos[:, None]         # (bq, S)
            s = jnp.where(mask[None, None], s, -1e30)
        m = s.max(axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        o = jnp.einsum("bhqk,bkhd->bqhd", p, vg.astype(jnp.float32))
        o = o / p.sum(axis=-1).transpose(0, 2, 1)[..., None]
        return o.astype(q.dtype)

    if unroll:
        out = jnp.stack([one_block(i, qb[i]) for i in range(nq)], axis=0)
    else:
        out = jax.lax.map(lambda args: one_block(*args),
                          (jnp.arange(nq), qb))               # (nq, B, bq, H, d)
    return out.transpose(1, 0, 2, 3, 4).reshape(B, S, H, d)


def decode_attention(q, k_cache, v_cache, length):
    """One-token GQA attention vs a (possibly sequence-sharded) cache.

    q: (B, H, d); caches: (B, Smax, Hkv, d); length: (B,).
    O(S) compute/memory — no S×S term, so ``long_500k`` lowers.

    Implementation notes (§Perf iteration on decode_32k): the cache is
    consumed in its native dtype with fp32 *accumulation*
    (preferred_element_type) — an explicit ``.astype(f32)`` materializes an
    S-sized fp32 temp that GSPMD reshards (observed: involuntary full
    remat + 1 GiB all-gather per layer on the seq-sharded cache); GQA is a
    grouped einsum, never a materialized ``repeat``.
    """
    B, H, d = q.shape
    hkv = k_cache.shape[2]
    group = H // hkv
    scale = 1.0 / np.sqrt(d)
    qg = (q.astype(jnp.float32) * scale).astype(k_cache.dtype)
    qg = qg.reshape(B, hkv, group, d)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                   preferred_element_type=jnp.float32)     # (B,Hkv,G,S)
    mask = jnp.arange(k_cache.shape[1])[None, None, None, :] \
        < length[:, None, None, None]
    s = jnp.where(mask, s, -1e30)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    denom = p.sum(axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    o = o / denom[..., None]
    return o.reshape(B, H, d).astype(q.dtype)


# --------------------------------------------------------------------------
# GQA attention block (params + apply)
# --------------------------------------------------------------------------
def attention_init(key, d_model, n_heads, n_kv, head_dim, qkv_bias,
                   dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d_model, n_heads * head_dim), 0, dtype),
        "wk": dense_init(ks[1], (d_model, n_kv * head_dim), 0, dtype),
        "wv": dense_init(ks[2], (d_model, n_kv * head_dim), 0, dtype),
        "wo": dense_init(ks[3], (n_heads * head_dim, d_model), 0, dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv * head_dim,), dtype)
    return p


def attention_axes(qkv_bias: bool) -> Params:
    a = {
        "wq": ("embed", "heads"),
        "wk": ("embed", "kv_heads"),
        "wv": ("embed", "kv_heads"),
        "wo": ("heads", "embed"),
    }
    if qkv_bias:
        a.update({"bq": ("heads",), "bk": ("kv_heads",),
                  "bv": ("kv_heads",)})
    return a


def attention_apply(
    p: Params, x, *, n_heads, n_kv, head_dim, positions,
    rope_theta, causal=True, block_q=512, unroll=False,
):
    """Full-sequence attention (training / prefill).  Returns (out, (k, v))."""
    B, S, D = x.shape
    q = (x @ p["wq"] + p.get("bq", 0)).reshape(B, S, n_heads, head_dim)
    k = (x @ p["wk"] + p.get("bk", 0)).reshape(B, S, n_kv, head_dim)
    v = (x @ p["wv"] + p.get("bv", 0)).reshape(B, S, n_kv, head_dim)
    if rope_theta:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    o = blockwise_causal_attention(q, k, v, block_q=block_q, causal=causal,
                                   unroll=unroll)
    return o.reshape(B, S, n_heads * head_dim) @ p["wo"], (k, v)


def attention_decode_apply(
    p: Params, x, k_cache, v_cache, length, *,
    n_heads, n_kv, head_dim, rope_theta,
):
    """One-token attention: project, write k/v into the cache at position
    ``length``, attend over ``length+1`` positions (the new token sees
    itself).  Returns (out, k_cache, v_cache)."""
    B, D = x.shape
    q = (x @ p["wq"] + p.get("bq", 0)).reshape(B, n_heads, head_dim)
    k = (x @ p["wk"] + p.get("bk", 0)).reshape(B, n_kv, head_dim)
    v = (x @ p["wv"] + p.get("bv", 0)).reshape(B, n_kv, head_dim)
    if rope_theta:
        pos = length.astype(jnp.float32)                    # (B,)
        q = apply_rope(q[:, None], pos[:, None], rope_theta)[:, 0]
        k = apply_rope(k[:, None], pos[:, None], rope_theta)[:, 0]
    k_cache = cache_update(k_cache, k.astype(k_cache.dtype), length)
    v_cache = cache_update(v_cache, v.astype(v_cache.dtype), length)
    o = decode_attention(q, k_cache, v_cache, length + 1)
    return o.reshape(B, n_heads * head_dim) @ p["wo"], k_cache, v_cache


# --------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# --------------------------------------------------------------------------
def mlp_init(key, d_model, d_ff, gated=True, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(ks[0], (d_model, d_ff), 0, dtype),
        "w_down": dense_init(ks[1], (d_ff, d_model), 0, dtype),
    }
    if gated:
        p["w_gate"] = dense_init(ks[2], (d_model, d_ff), 0, dtype)
    return p


def mlp_axes(gated=True) -> Params:
    a = {"w_up": ("embed", "ffn"), "w_down": ("ffn", "embed")}
    if gated:
        a["w_gate"] = ("embed", "ffn")
    return a


def mlp_apply(p: Params, x, gated=True):
    up = x @ p["w_up"]
    if gated:
        up = jax.nn.silu(x @ p["w_gate"]) * up
    else:
        up = jax.nn.gelu(up)
    return up @ p["w_down"]


# --------------------------------------------------------------------------
# embedding / head
# --------------------------------------------------------------------------
def embedding_init(key, vocab, d_model, dtype=jnp.float32) -> jax.Array:
    return dense_init(key, (vocab, d_model), 1, dtype)


def cache_update(cache, new, length, mode: str = "onehot"):
    """Write ``new`` (B, Hkv, d) into ``cache`` (B, Smax, Hkv, d) at per-row
    position ``length`` (B,).

    mode="onehot" (default): arithmetic select — GSPMD keeps it local on a
    seq-sharded cache and fuses the select into a single pass.
    mode="scatter": batched ``.at[].set`` — hypothesis was O(row) in-place
    traffic, but measured WORSE (decode_32k Tm 0.048 s vs 0.032 s: GSPMD
    masks the scatter per shard and the indexed path defeats fusion) — kept
    as the documented refuted alternative (EXPERIMENTS.md §Perf decode
    iteration 2).
    """
    if mode == "scatter":
        B = cache.shape[0]
        return cache.at[jnp.arange(B), length].set(
            new.astype(cache.dtype), mode="drop")
    S = cache.shape[1]
    onehot = (jnp.arange(S)[None] == length[:, None]).astype(cache.dtype)
    return cache * (1.0 - onehot[..., None, None]) + (
        onehot[..., None, None] * new[:, None]
    )
