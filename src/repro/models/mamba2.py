"""Mamba2 (SSD) blocks — the state-space family (zamba2 backbone, standalone).

The SSD computation is itself a block-streaming pipeline (DESIGN.md §4): the
sequence is partitioned into chunks; each chunk does dense intra-chunk work
(MXU-shaped matmuls) while a small recurrent state (B, H, P, N) carries
between chunks — the paper's partition/stream/accumulate pattern applied to
time instead of matrix tiles.  A naive per-step scan (``ssd_scan_ref``) is
the test oracle.

Decode carries (state h, conv tail) in O(1) memory — the reason the
``long_500k`` shape is runnable for this family.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L

Params = Dict[str, jax.Array]


# --------------------------------------------------------------------------
# SSD core
# --------------------------------------------------------------------------
def ssd_scan_ref(x, dt, a, B_, C_):
    """Naive per-step recurrence (oracle).

    x: (B, S, H, P); dt, a: (B, S, H); B_, C_: (B, S, N).
    h_t = a_t * h_{t-1} + dt_t * x_t ⊗ B_t ;  y_t = C_t · h_t.
    Returns y: (B, S, H, P), h_final: (B, H, P, N).
    """
    Bb, S, H, P = x.shape
    N = B_.shape[-1]
    h0 = jnp.zeros((Bb, H, P, N), jnp.float32)

    def step(h, inp):
        xt, dtt, at, bt, ct = inp
        # xt: (B,H,P) dtt/at: (B,H) bt/ct: (B,N)
        h = at[..., None, None] * h + (
            (dtt[..., None] * xt)[..., None] * bt[:, None, None, :]
        )
        y = jnp.einsum("bhpn,bn->bhp", h, ct)
        return h, y

    xs = (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
          a.transpose(1, 0, 2), B_.transpose(1, 0, 2), C_.transpose(1, 0, 2))
    h, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3), h


def ssd_chunked(x, dt, a, B_, C_, chunk: int = 256,
                h0: Optional[jax.Array] = None, unroll: bool = False):
    """Chunked SSD (Mamba2 algorithm; matrix-form intra-chunk).

    Same contract as ``ssd_scan_ref``.  All decays ≤ 1 by construction so the
    matrix form is numerically safe (log a ≤ 0).
    """
    Bb, S, H, P = x.shape
    N = B_.shape[-1]
    if S % chunk:
        chunk = S
    nc = S // chunk

    def to_chunks(t, extra=()):
        return t.reshape((Bb, nc, chunk) + t.shape[2:]).transpose(
            (1, 0, 2) + tuple(range(3, t.ndim + 1)))

    xs = (to_chunks(x), to_chunks(dt), to_chunks(a),
          to_chunks(B_), to_chunks(C_))
    if h0 is None:
        h0 = jnp.zeros((Bb, H, P, N), jnp.float32)

    def chunk_step(h, inp):
        xc, dtc, ac, bc, cc = inp          # (B,Lc,H,P) (B,Lc,H) (B,Lc,N)
        la = jnp.log(jnp.maximum(ac.astype(jnp.float32), 1e-20))
        ca = jnp.cumsum(la, axis=1)        # (B,Lc,H)
        # intra-chunk: scores[t,s] = (C_t·B_s) exp(ca[t]-ca[s]) dt_s, s<=t
        cb = jnp.einsum("bln,bmn->blm", cc.astype(jnp.float32),
                        bc.astype(jnp.float32))           # (B,Lc,Lc)
        decay = jnp.exp(ca[:, :, None, :] - ca[:, None, :, :])  # (B,t,s,H)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        scores = cb[..., None] * jnp.where(mask[None, ..., None], decay, 0.0)
        xdt = xc.astype(jnp.float32) * dtc[..., None]     # (B,Lc,H,P)
        y = jnp.einsum("blsh,bshp->blhp", scores, xdt)
        # inter-chunk: y += exp(ca[t]) * C_t · h
        y = y + jnp.exp(ca)[..., None] * jnp.einsum(
            "bln,bhpn->blhp", cc.astype(jnp.float32), h)
        # state update: h' = exp(ca[-1]) h + sum_s exp(ca[-1]-ca[s]) dt_s x_s⊗B_s
        tail = jnp.exp(ca[:, -1:, :] - ca)                # (B,Lc,H)
        hc = jnp.einsum("blhp,bln->bhpn", xdt * tail[..., None],
                        bc.astype(jnp.float32))
        h = jnp.exp(ca[:, -1])[..., None, None] * h + hc
        return h, y

    if unroll:
        h, ys_l = h0, []
        for c in range(nc):
            h, yc = chunk_step(h, jax.tree.map(lambda t: t[c], xs))
            ys_l.append(yc)
        ys = jnp.stack(ys_l, axis=0)
    else:
        h, ys = jax.lax.scan(chunk_step, h0, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bb, S, H, P)
    return y.astype(x.dtype), h


# --------------------------------------------------------------------------
# causal depthwise conv (width W) over (B, S, C)
# --------------------------------------------------------------------------
def causal_conv(x, w, tail: Optional[jax.Array] = None):
    """x: (B, S, C); w: (W, C); tail: (B, W-1, C) state for decode/prefill
    continuity.  Returns (y (B,S,C), new_tail (B, W-1, C))."""
    W = w.shape[0]
    pad = tail if tail is not None else jnp.zeros(
        (x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                # (B, S+W-1, C)
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(W))
    return y, xp[:, -(W - 1):]


# --------------------------------------------------------------------------
# Mamba2 block
# --------------------------------------------------------------------------
def mamba_dims(cfg: ArchConfig) -> Tuple[int, int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    P = cfg.ssm_head_dim
    H = d_inner // P
    N = cfg.ssm_state
    return d_inner, H, P, N


def mamba_init(key, cfg: ArchConfig) -> Params:
    d, (di, H, P, N) = cfg.d_model, mamba_dims(cfg)
    conv_dim = di + 2 * N
    ks = jax.random.split(key, 4)
    dt = cfg.pdtype
    return {
        "in_proj": L.dense_init(ks[0], (d, 2 * di + 2 * N + H), 0, dt),
        "conv_w": L.dense_init(ks[1], (cfg.conv_width, conv_dim), 0, dt),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "gate_norm": jnp.ones((di,), dt),
        "out_proj": L.dense_init(ks[2], (di, d), 0, dt),
    }


def mamba_axes(cfg: ArchConfig) -> Params:
    return {
        "in_proj": ("embed", "inner"),
        "conv_w": (None, "inner"),
        "A_log": (None,),
        "D_skip": (None,),
        "dt_bias": (None,),
        "gate_norm": ("inner",),
        "out_proj": ("inner", "embed"),
    }


def _mamba_project(p, x, cfg):
    di, H, P, N = mamba_dims(cfg)
    z, xbc, dt = jnp.split(x @ p["in_proj"], [di, 2 * di + 2 * N], axis=-1)
    return z, xbc, dt


def mamba_apply(p: Params, x, cfg: ArchConfig, chunk: int = 256):
    """Full-sequence Mamba2 block.  x: (B, S, D) -> (y, h_final, conv_tail)."""
    Bb, S, D = x.shape
    di, H, P, N = mamba_dims(cfg)
    z, xbc, dt = _mamba_project(p, x, cfg)
    xbc, tail = causal_conv(xbc, p["conv_w"])
    xbc = jax.nn.silu(xbc)
    xs, B_, C_ = jnp.split(xbc, [di, di + N], axis=-1)
    xs = xs.reshape(Bb, S, H, P)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    a = jnp.exp(-jnp.exp(p["A_log"]) * dt)                        # (B,S,H)
    if not cfg.scan_layers:  # cost mode: bound the unrolled chunk count
        chunk = max(chunk, S // 8 if S >= 8 else S)
    y, h = ssd_chunked(xs, dt, a, B_, C_, chunk=chunk,
                       unroll=not cfg.scan_layers)
    y = y + p["D_skip"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(Bb, S, di).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    return y @ p["out_proj"], h, tail


def mamba_decode(p: Params, x, h, conv_tail, cfg: ArchConfig):
    """One-token step.  x: (B, D); h: (B,H,P,N); conv_tail: (B,W-1,conv)."""
    Bb, D = x.shape
    di, H, P, N = mamba_dims(cfg)
    z, xbc, dt = _mamba_project(p, x[:, None], cfg)
    xbc, conv_tail = causal_conv(xbc, p["conv_w"], conv_tail)
    xbc = jax.nn.silu(xbc[:, 0])                                  # (B, conv)
    z = z[:, 0]
    xs, B_, C_ = jnp.split(xbc, [di, di + N], axis=-1)
    xs = xs.reshape(Bb, H, P)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])
    a = jnp.exp(-jnp.exp(p["A_log"]) * dt)                        # (B,H)
    h = a[..., None, None] * h + (
        (dt[..., None] * xs.astype(jnp.float32))[..., None]
        * B_.astype(jnp.float32)[:, None, None, :])
    y = jnp.einsum("bhpn,bn->bhp", h, C_.astype(jnp.float32))
    y = y + p["D_skip"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(Bb, di).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    return y @ p["out_proj"], h, conv_tail


class Mamba2Model:
    """Pure-SSM decoder (API-compatible with TransformerModel)."""

    def __init__(self, cfg: ArchConfig, shard_ec=None, weight_gather=None,
                 shard_assign=None):
        self.cfg = cfg
        self.weight_gather = weight_gather

    def layer_axes(self) -> Dict:
        return {"norm": ("embed",), "mamba": mamba_axes(self.cfg)}


    def _top(self, params):
        """Gather non-layer weights (embed / lm_head) over data axes at
        point-of-use — same FSDP rationale as the per-layer hook."""
        if self.weight_gather is None:
            return params
        keys = [k for k in ("embed", "lm_head") if k in params]
        axes = self.param_logical_axes()
        sub = self.weight_gather({k: params[k] for k in keys},
                                 {k: axes[k] for k in keys})
        return {**params, **sub}

    def init(self, key) -> Dict:
        cfg = self.cfg
        keys = jax.random.split(key, cfg.num_layers + 2)

        def one(k):
            k1, k2 = jax.random.split(k)
            return {"norm": jnp.ones((cfg.d_model,), cfg.pdtype),
                    "mamba": mamba_init(k1, cfg)}

        layers = jax.vmap(one)(keys[: cfg.num_layers])
        return {
            "embed": L.embedding_init(keys[-2], cfg.vocab_size, cfg.d_model,
                                      cfg.pdtype),
            "layers": layers,
            "final_norm": jnp.ones((cfg.d_model,), cfg.pdtype),
            "lm_head": L.dense_init(keys[-1], (cfg.d_model, cfg.vocab_size),
                                    0, cfg.pdtype),
        }

    def param_logical_axes(self) -> Dict:
        def stack(tree):
            return jax.tree.map(lambda ax: ("layer",) + tuple(ax), tree,
                                is_leaf=lambda x: isinstance(x, tuple))
        return {
            "embed": ("vocab", "embed"),
            "layers": stack(self.layer_axes()),
            "final_norm": ("embed",),
            "lm_head": ("embed", "vocab"),
        }

    def _run(self, params, x, collect_state: bool):
        cfg = self.cfg

        def body(carry, lp):
            x = carry
            if self.weight_gather is not None:
                lp = self.weight_gather(lp, self.layer_axes())
            y, h, tail = mamba_apply(
                lp["mamba"], L.rms_norm(x, lp["norm"], cfg.norm_eps), cfg)
            out = x + y
            return out, ((h, tail) if collect_state else None)

        if cfg.remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        if cfg.scan_layers:
            return jax.lax.scan(body, x, params["layers"])
        outs = []
        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda p_: p_[i], params["layers"])
            x, st = body(x, lp)
            outs.append(st)
        if not collect_state:
            return x, None
        hs = jnp.stack([o[0] for o in outs], axis=0)
        tails = jnp.stack([o[1] for o in outs], axis=0)
        return x, (hs, tails)

    def forward(self, params, inputs):
        cfg = self.cfg
        params = self._top(params)
        x = jnp.take(params["embed"], inputs, axis=0).astype(cfg.adtype)
        x, _ = self._run(params, x, False)
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x @ params["lm_head"].astype(x.dtype)

    # ----------------------------------------------------------------- cache
    def init_cache(self, batch: int, max_len: int) -> Dict:
        cfg = self.cfg
        di, H, P, N = mamba_dims(cfg)
        conv_dim = di + 2 * N
        Lr = cfg.num_layers
        return {
            "h": jnp.zeros((Lr, batch, H, P, N), jnp.float32),
            "conv": jnp.zeros((Lr, batch, cfg.conv_width - 1, conv_dim),
                              cfg.adtype),
            "len": jnp.zeros((batch,), jnp.int32),
        }

    def cache_logical_axes(self) -> Dict:
        return {"h": ("layer", "batch", "inner_heads", None, None),
                "conv": ("layer", "batch", None, "inner"),
                "len": ("batch",)}

    def cache_specs(self, batch: int, max_len: int) -> Dict:
        return jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
            jax.eval_shape(lambda: self.init_cache(batch, max_len)))

    def prefill(self, params, inputs, max_len: Optional[int] = None):
        cfg = self.cfg
        params = self._top(params)
        x = jnp.take(params["embed"], inputs, axis=0).astype(cfg.adtype)
        B, S = inputs.shape
        x, states = self._run(params, x, True)
        hs, tails = states
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = x[:, -1] @ params["lm_head"].astype(x.dtype)
        cache = {"h": hs, "conv": tails,
                 "len": jnp.full((B,), S, jnp.int32)}
        return logits, cache

    def decode(self, params, cache, inputs):
        cfg = self.cfg
        params = self._top(params)
        x = jnp.take(params["embed"], inputs, axis=0).astype(cfg.adtype)

        def body(carry, scanned):
            x = carry
            lp, h, tail = scanned
            if self.weight_gather is not None:
                lp = self.weight_gather(lp, self.layer_axes())
            y, h, tail = mamba_decode(
                lp["mamba"], L.rms_norm(x, lp["norm"], cfg.norm_eps),
                h, tail, cfg)
            return x + y, (h, tail)

        if cfg.scan_layers:
            x, (hs, tails) = jax.lax.scan(
                body, x, (params["layers"], cache["h"], cache["conv"]))
        else:
            hs_l, tails_l = [], []
            for i in range(cfg.num_layers):
                lp = jax.tree.map(lambda p_: p_[i], params["layers"])
                x, (h_i, t_i) = body(x, (lp, cache["h"][i], cache["conv"][i]))
                hs_l.append(h_i)
                tails_l.append(t_i)
            hs = jnp.stack(hs_l, axis=0)
            tails = jnp.stack(tails_l, axis=0)
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = x @ params["lm_head"].astype(x.dtype)
        return logits, {"h": hs, "conv": tails, "len": cache["len"] + 1}
