"""RWKV-6 "Finch": attention-free time-mix with data-dependent decay.

State per layer is a (B, H, P, P) matrix — O(1) in sequence length, so all
decode shapes (incl. ``long_500k``) lower with constant memory.  Training
runs a chunked outer scan (the OOC pattern over time) with a rematerialized
inner recurrence; the per-step scan is the oracle in tests.

Faithful simplifications (DESIGN.md §5): static token-shift mix coefficients
(v6 uses low-rank data-dependent ones), single w projection for the decay.
Head layout: H heads of size P, D = H*P.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L

Params = Dict[str, jax.Array]


# --------------------------------------------------------------------------
# wkv recurrence
# --------------------------------------------------------------------------
def wkv_scan_ref(r, k, v, w, u, m0=None):
    """Oracle: per-step.  r,k,v,w: (B, S, H, P); u: (H, P).

    y_t = r_t · (M_{t-1} + diag(u) k_t ⊗ v_t);  M_t = diag(w_t) M_{t-1} + k_t ⊗ v_t
    Returns y (B, S, H, P), M_final (B, H, P, P).
    """
    B, S, H, P = r.shape
    M = m0 if m0 is not None else jnp.zeros((B, H, P, P), jnp.float32)

    def step(M, inp):
        rt, kt, vt, wt = inp  # (B,H,P)
        cur = (u[None] * kt)[..., None] * vt[..., None, :]   # (B,H,P,P)
        y = jnp.einsum("bhp,bhpq->bhq", rt, M + cur)
        M = wt[..., None] * M + kt[..., None] * vt[..., None, :]
        return M, y

    xs = tuple(t.transpose(1, 0, 2, 3).astype(jnp.float32)
               for t in (r, k, v, w))
    M, ys = jax.lax.scan(step, M, xs)
    return ys.transpose(1, 0, 2, 3), M


def wkv_associative(r, k, v, w, u, m0: Optional[jax.Array] = None):
    """Parallel (associative-scan) WKV — the TPU-parallel training path and
    the dry-run cost path (no while loops, so XLA cost_analysis sees every
    op).  The recurrence M_t = w_t ⊙ M_{t-1} + k_t ⊗ v_t is a linear scan
    with associative composition (w2*w1, w2*a1 + a2).

    Memory trades for parallelism: materializes (B, S, H, P, P) states.
    Validated equal to ``wkv_scan_ref`` in tests.
    """
    B, S, H, P = r.shape
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))
    A = kf[..., None] * vf[..., None, :]          # (B,S,H,P,P)
    W = wf[..., None]                              # (B,S,H,P,1)

    def combine(l, rgt):
        wl, al = l
        wr, ar = rgt
        return wr * wl, wr * al + ar

    Wc, Ac = jax.lax.associative_scan(combine, (W, A), axis=1)
    if m0 is not None:
        M = Ac + Wc * m0[:, None]                 # carry-in
    else:
        M = Ac                                     # (B,S,H,P,P) = M_t
    m_init = (m0 if m0 is not None
              else jnp.zeros((B, H, P, P), jnp.float32))
    M_prev = jnp.concatenate([m_init[:, None], M[:, :-1]], axis=1)
    cur = (u[None, None] * kf)[..., None] * vf[..., None, :]
    y = jnp.einsum("bshp,bshpq->bshq", rf, M_prev + cur)
    return y, M[:, -1]


def wkv_chunked(r, k, v, w, u, chunk: int = 64,
                m0: Optional[jax.Array] = None, remat: bool = True):
    """Outer scan over chunks carrying M; inner per-step recurrence is
    rematerialized so the backward stores only chunk-boundary states."""
    B, S, H, P = r.shape
    if S % chunk:
        chunk = S
    nc = S // chunk

    def to_chunks(t):
        return t.reshape(B, nc, chunk, H, P).transpose(1, 0, 2, 3, 4)

    xs = tuple(to_chunks(t.astype(jnp.float32)) for t in (r, k, v, w))
    M = m0 if m0 is not None else jnp.zeros((B, H, P, P), jnp.float32)

    def chunk_body(M, inp):
        rc, kc, vc, wc = inp  # (B, Lc, H, P)
        yc, Mi = wkv_scan_ref(rc, kc, vc, wc, u, m0=M)
        return Mi, yc

    if remat:
        chunk_body = jax.checkpoint(
            chunk_body, policy=jax.checkpoint_policies.nothing_saveable)
    M, ys = jax.lax.scan(chunk_body, M, xs)
    return ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P), M


# --------------------------------------------------------------------------
# blocks
# --------------------------------------------------------------------------
def _shift(x, last):
    """Token shift: x_{t-1} with ``last`` filling t=0.  x: (B,S,D)."""
    return jnp.concatenate([last[:, None], x[:, :-1]], axis=1)


def timemix_init(key, cfg: ArchConfig) -> Params:
    D = cfg.d_model
    H = D // cfg.ssm_head_dim
    P = cfg.ssm_head_dim
    ks = jax.random.split(key, 6)
    dt = cfg.pdtype
    return {
        "mu": jnp.full((5, D), 0.5, dt),  # r,k,v,g,w shift-mix coefficients
        "w_r": L.dense_init(ks[0], (D, D), 0, dt),
        "w_k": L.dense_init(ks[1], (D, D), 0, dt),
        "w_v": L.dense_init(ks[2], (D, D), 0, dt),
        "w_g": L.dense_init(ks[3], (D, D), 0, dt),
        "w_w": L.dense_init(ks[4], (D, D), 0, dt),
        "w_o": L.dense_init(ks[5], (D, D), 0, dt),
        "u": jnp.zeros((H, P), jnp.float32),
        "ln_x": jnp.ones((D,), dt),
    }


def timemix_axes() -> Params:
    return {"mu": (None, "embed"), "w_r": ("embed", "inner"),
            "w_k": ("embed", "inner"), "w_v": ("embed", "inner"),
            "w_g": ("embed", "inner"), "w_w": ("embed", "inner"),
            "w_o": ("inner", "embed"), "u": ("inner_heads", None),
            "ln_x": ("inner",)}


def _timemix_project(p, x, xprev, H, P):
    mix = lambda i: x + (xprev - x) * p["mu"][i][None, None]
    shp = x.shape[:-1] + (H, P)
    r = (mix(0) @ p["w_r"]).reshape(shp)
    k = (mix(1) @ p["w_k"]).reshape(shp)
    v = (mix(2) @ p["w_v"]).reshape(shp)
    g = jax.nn.silu(mix(3) @ p["w_g"])
    w = jnp.exp(-jnp.exp(
        (mix(4) @ p["w_w"]).astype(jnp.float32).reshape(shp) - 3.0))
    return r, k, v, g, w


def timemix_apply(p: Params, x, cfg: ArchConfig, last,
                  chunk: int = 64, unroll: bool = False):
    """x: (B, S, D); last: (B, D) shift state.  Returns (y, new_last, M)."""
    B, S, D = x.shape
    P = cfg.ssm_head_dim
    H = D // P
    xprev = _shift(x, last)
    r, k, v, g, w = _timemix_project(p, x, xprev, H, P)
    if unroll:
        y, M = wkv_associative(r, k, v, w, p["u"])
    else:
        y, M = wkv_chunked(r, k, v, w, p["u"], chunk=chunk, remat=cfg.remat)
    y = L.rms_norm(y.reshape(B, S, D).astype(x.dtype), p["ln_x"],
                   cfg.norm_eps)
    return (y * g) @ p["w_o"], x[:, -1], M


def timemix_decode(p: Params, x, cfg: ArchConfig, last, M):
    """x: (B, D).  Returns (y, new_last, M_new)."""
    B, D = x.shape
    P = cfg.ssm_head_dim
    H = D // P
    r, k, v, g, w = _timemix_project(p, x[:, None], last[:, None], H, P)
    y, M = wkv_scan_ref(r, k, v, w, p["u"], m0=M)
    y = L.rms_norm(y[:, 0].reshape(B, D).astype(x.dtype), p["ln_x"],
                   cfg.norm_eps)
    return (y * g[:, 0]) @ p["w_o"], x, M


def chanmix_init(key, cfg: ArchConfig) -> Params:
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = cfg.pdtype
    return {
        "mu": jnp.full((2, D), 0.5, dt),
        "w_k": L.dense_init(ks[0], (D, F), 0, dt),
        "w_v": L.dense_init(ks[1], (F, D), 0, dt),
        "w_r": L.dense_init(ks[2], (D, D), 0, dt),
    }


def chanmix_axes() -> Params:
    return {"mu": (None, "embed"), "w_k": ("embed", "ffn"),
            "w_v": ("ffn", "embed"), "w_r": ("embed", "inner")}


def chanmix_apply(p: Params, x, last):
    xprev = _shift(x, last) if x.ndim == 3 else last
    if x.ndim == 2:
        xk = x + (xprev - x) * p["mu"][0][None]
        xr = x + (xprev - x) * p["mu"][1][None]
    else:
        xk = x + (xprev - x) * p["mu"][0][None, None]
        xr = x + (xprev - x) * p["mu"][1][None, None]
    k = jnp.square(jax.nn.relu(xk @ p["w_k"]))
    new_last = x[:, -1] if x.ndim == 3 else x
    return jax.nn.sigmoid(xr @ p["w_r"]) * (k @ p["w_v"]), new_last


class RWKV6Model:
    def __init__(self, cfg: ArchConfig, shard_ec=None, weight_gather=None,
                 shard_assign=None):
        self.cfg = cfg
        self.weight_gather = weight_gather

    def layer_axes(self) -> Dict:
        return {"ln1": ("embed",), "ln2": ("embed",),
                "time": timemix_axes(), "chan": chanmix_axes()}


    def _top(self, params):
        """Gather non-layer weights (embed / lm_head) over data axes at
        point-of-use — same FSDP rationale as the per-layer hook."""
        if self.weight_gather is None:
            return params
        keys = [k for k in ("embed", "lm_head") if k in params]
        axes = self.param_logical_axes()
        sub = self.weight_gather({k: params[k] for k in keys},
                                 {k: axes[k] for k in keys})
        return {**params, **sub}

    def init(self, key) -> Dict:
        cfg = self.cfg
        keys = jax.random.split(key, cfg.num_layers + 2)

        def one(k):
            k1, k2 = jax.random.split(k)
            return {
                "ln1": jnp.ones((cfg.d_model,), cfg.pdtype),
                "ln2": jnp.ones((cfg.d_model,), cfg.pdtype),
                "time": timemix_init(k1, cfg),
                "chan": chanmix_init(k2, cfg),
            }

        layers = jax.vmap(one)(keys[: cfg.num_layers])
        return {
            "embed": L.embedding_init(keys[-2], cfg.vocab_size,
                                      cfg.d_model, cfg.pdtype),
            "layers": layers,
            "final_norm": jnp.ones((cfg.d_model,), cfg.pdtype),
            "lm_head": L.dense_init(keys[-1], (cfg.d_model, cfg.vocab_size),
                                    0, cfg.pdtype),
        }

    def param_logical_axes(self) -> Dict:
        def stack(tree):
            return jax.tree.map(lambda ax: ("layer",) + tuple(ax), tree,
                                is_leaf=lambda x: isinstance(x, tuple))
        return {"embed": ("vocab", "embed"),
                "layers": stack(self.layer_axes()),
                "final_norm": ("embed",), "lm_head": ("embed", "vocab")}

    def _run(self, params, x, collect_state: bool):
        cfg = self.cfg
        B = x.shape[0]
        zeros_last = jnp.zeros((B, cfg.d_model), x.dtype)

        def body(carry, lp):
            x = carry
            if self.weight_gather is not None:
                lp = self.weight_gather(lp, self.layer_axes())
            y, lt, M = timemix_apply(
                lp["time"], L.rms_norm(x, lp["ln1"], cfg.norm_eps),
                cfg, zeros_last, unroll=not cfg.scan_layers)
            x = x + y
            y, lc = chanmix_apply(
                lp["chan"], L.rms_norm(x, lp["ln2"], cfg.norm_eps),
                zeros_last)
            x = x + y
            return x, ((M, lt, lc) if collect_state else None)

        if cfg.remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        if cfg.scan_layers:
            return jax.lax.scan(body, x, params["layers"])
        outs = []
        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda p_: p_[i], params["layers"])
            x, st = body(x, lp)
            outs.append(st)
        if not collect_state:
            return x, None
        M = jnp.stack([o[0] for o in outs], axis=0)
        lt = jnp.stack([o[1] for o in outs], axis=0)
        lc = jnp.stack([o[2] for o in outs], axis=0)
        return x, (M, lt, lc)

    def forward(self, params, inputs):
        cfg = self.cfg
        params = self._top(params)
        x = jnp.take(params["embed"], inputs, axis=0).astype(cfg.adtype)
        x, _ = self._run(params, x, False)
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x @ params["lm_head"].astype(x.dtype)

    def init_cache(self, batch: int, max_len: int) -> Dict:
        cfg = self.cfg
        D = cfg.d_model
        P = cfg.ssm_head_dim
        H = D // P
        Lr = cfg.num_layers
        return {
            "M": jnp.zeros((Lr, batch, H, P, P), jnp.float32),
            "last_t": jnp.zeros((Lr, batch, D), cfg.adtype),
            "last_c": jnp.zeros((Lr, batch, D), cfg.adtype),
            "len": jnp.zeros((batch,), jnp.int32),
        }

    def cache_logical_axes(self) -> Dict:
        return {"M": ("layer", "batch", "inner_heads", None, None),
                "last_t": ("layer", "batch", "embed_act"),
                "last_c": ("layer", "batch", "embed_act"),
                "len": ("batch",)}

    def cache_specs(self, batch: int, max_len: int) -> Dict:
        return jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
            jax.eval_shape(lambda: self.init_cache(batch, max_len)))

    def prefill(self, params, inputs, max_len: Optional[int] = None):
        cfg = self.cfg
        params = self._top(params)
        B, S = inputs.shape
        x = jnp.take(params["embed"], inputs, axis=0).astype(cfg.adtype)
        x, states = self._run(params, x, True)
        M, lt, lc = states
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = x[:, -1] @ params["lm_head"].astype(x.dtype)
        return logits, {"M": M, "last_t": lt, "last_c": lc,
                        "len": jnp.full((B,), S, jnp.int32)}

    def decode(self, params, cache, inputs):
        cfg = self.cfg
        params = self._top(params)
        x = jnp.take(params["embed"], inputs, axis=0).astype(cfg.adtype)

        def body(carry, scanned):
            x = carry
            lp, M, lt, lc = scanned
            if self.weight_gather is not None:
                lp = self.weight_gather(lp, self.layer_axes())
            y, lt, M = timemix_decode(
                lp["time"], L.rms_norm(x, lp["ln1"], cfg.norm_eps),
                cfg, lt, M)
            x = x + y
            y, lc = chanmix_apply(
                lp["chan"], L.rms_norm(x, lp["ln2"], cfg.norm_eps), lc)
            x = x + y
            return x, (M, lt.astype(cfg.adtype), lc.astype(cfg.adtype))

        if cfg.scan_layers:
            x, (M, lt, lc) = jax.lax.scan(
                body, x, (params["layers"], cache["M"],
                          cache["last_t"], cache["last_c"]))
        else:
            Ms, lts, lcs = [], [], []
            for i in range(cfg.num_layers):
                lp = jax.tree.map(lambda p_: p_[i], params["layers"])
                x, (Mi, lti, lci) = body(
                    x, (lp, cache["M"][i], cache["last_t"][i],
                        cache["last_c"][i]))
                Ms.append(Mi)
                lts.append(lti)
                lcs.append(lci)
            M = jnp.stack(Ms, axis=0)
            lt = jnp.stack(lts, axis=0)
            lc = jnp.stack(lcs, axis=0)
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = x @ params["lm_head"].astype(x.dtype)
        return logits, {"M": M, "last_t": lt, "last_c": lc,
                        "len": cache["len"] + 1}
