"""Transformer model: dense decoder, MoE decoder, encoder-only — one class.

Covers eight assigned archs (qwen2.5, codeqwen1.5, stablelm, llama3.2,
internvl2 backbone, hubert encoder, qwen3-moe, deepseek-moe).  Layers are
weight-stacked and driven by ``lax.scan`` so the HLO (and compile time) is
one layer regardless of depth; remat wraps the scanned body.

API (shared by all model families in the zoo):
  init(key) -> params
  forward(params, inputs) -> logits (B, S, V)
  init_cache(batch, max_len) -> cache pytree
  prefill(params, inputs) -> (last_logits, cache)
  decode(params, cache, inputs) -> (logits, cache)
  param_logical_axes() / cache_logical_axes() -> pytrees of logical axis names
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import moe as M


class TransformerModel:
    def __init__(self, cfg: ArchConfig, shard_ec=None, weight_gather=None,
                 shard_assign=None):
        self.cfg = cfg
        self.shard_ec = shard_ec  # MoE (G,E,C,D) activation constraint hook
        self.shard_assign = shard_assign  # MoE (G,A,D) assignment tensors
        # FSDP hook: gathers a layer's weights over the data/pod axes at
        # point-of-use (distributed.make_weight_gather)
        self.weight_gather = weight_gather

    # ------------------------------------------------------------------ init
    def _layer_init(self, key) -> Dict:
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(key, 3)
        p = {
            "attn_norm": jnp.ones((cfg.d_model,), cfg.pdtype),
            "mlp_norm": jnp.ones((cfg.d_model,), cfg.pdtype),
            "attn": L.attention_init(
                k1, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                cfg.head_dim_, cfg.qkv_bias, cfg.pdtype),
        }
        if cfg.is_moe:
            p["moe"] = M.moe_init(
                k2, cfg.d_model, cfg.d_ff, cfg.num_experts,
                cfg.num_shared_experts, cfg.pdtype)
        else:
            p["mlp"] = L.mlp_init(k3, cfg.d_model, cfg.d_ff, True, cfg.pdtype)
        return p


    def _top(self, params):
        """Gather non-layer weights (embed / lm_head) over data axes at
        point-of-use — same FSDP rationale as the per-layer hook."""
        if self.weight_gather is None:
            return params
        keys = [k for k in ("embed", "lm_head") if k in params]
        axes = self.param_logical_axes()
        sub = self.weight_gather({k: params[k] for k in keys},
                                 {k: axes[k] for k in keys})
        return {**params, **sub}

    def init(self, key) -> Dict:
        cfg = self.cfg
        keys = jax.random.split(key, cfg.num_layers + 2)
        layers = jax.vmap(self._layer_init)(keys[: cfg.num_layers])
        params = {
            "layers": layers,
            "final_norm": jnp.ones((cfg.d_model,), cfg.pdtype),
            "lm_head": L.dense_init(keys[-1], (cfg.d_model, cfg.vocab_size),
                                    0, cfg.pdtype),
        }
        # Embedding table exists unless the arch never consumes tokens
        # (encoder with stubbed frontend).  A causal stub-frontend arch
        # (VLM) still decodes text tokens.
        if not cfg.embedding_input or cfg.causal:
            params["embed"] = L.embedding_init(
                keys[-2], cfg.vocab_size, cfg.d_model, cfg.pdtype)
        return params

    def layer_axes(self) -> Dict:
        cfg = self.cfg
        lp = {
            "attn_norm": ("embed",),
            "mlp_norm": ("embed",),
            "attn": L.attention_axes(cfg.qkv_bias),
        }
        if cfg.is_moe:
            lp["moe"] = M.moe_axes(cfg.num_shared_experts)
        else:
            lp["mlp"] = L.mlp_axes(True)
        return lp

    def param_logical_axes(self) -> Dict:
        cfg = self.cfg

        def stack(tree):  # prepend the scanned "layer" axis
            return jax.tree.map(lambda ax: ("layer",) + tuple(ax), tree,
                                is_leaf=lambda x: isinstance(x, tuple))

        axes = {
            "layers": stack(self.layer_axes()),
            "final_norm": ("embed",),
            "lm_head": ("embed", "vocab"),
        }
        if not cfg.embedding_input or cfg.causal:
            axes["embed"] = ("vocab", "embed")
        return axes

    # ----------------------------------------------------------------- layer
    def _layer_apply(self, lp, x, positions, collect_kv: bool):
        cfg = self.cfg
        h, kv = L.attention_apply(
            lp["attn"], L.rms_norm(x, lp["attn_norm"], cfg.norm_eps),
            n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads,
            head_dim=cfg.head_dim_, positions=positions,
            rope_theta=cfg.rope_theta, causal=cfg.causal,
            block_q=cfg.block_q, unroll=not cfg.scan_layers)
        x = x + h
        xn = L.rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
        if cfg.is_moe:
            y = M.moe_apply(lp["moe"], xn, top_k=cfg.num_experts_per_tok,
                            capacity_factor=cfg.capacity_factor,
                            groups=cfg.moe_groups, shard_ec=self.shard_ec,
                            shard_rep=self.shard_assign)
        else:
            y = L.mlp_apply(lp["mlp"], xn, gated=True)
        return x + y, (kv if collect_kv else None)

    def _embed(self, params, inputs):
        cfg = self.cfg
        if cfg.embedding_input:
            return inputs.astype(cfg.adtype)
        return jnp.take(params["embed"], inputs, axis=0).astype(cfg.adtype)

    # --------------------------------------------------------------- forward
    def _run_layers(self, params, x, positions, collect_kv: bool = False):
        cfg = self.cfg

        def body(carry, lp):
            if self.weight_gather is not None:
                lp = self.weight_gather(lp, self.layer_axes())
            out, kv = self._layer_apply(lp, carry, positions, collect_kv)
            return out, kv

        if cfg.remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        if cfg.scan_layers:
            x, kvs = jax.lax.scan(body, x, params["layers"])
            return x, kvs
        # unrolled (dry-run cost mode): identical math, python loop
        outs = []
        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda p: p[i], params["layers"])
            x, kv = body(x, lp)
            outs.append(kv)
        if collect_kv:
            k = jnp.stack([o[0] for o in outs], axis=0)
            v = jnp.stack([o[1] for o in outs], axis=0)
            return x, (k, v)
        return x, None

    def forward(self, params, inputs):
        """Training-shape forward: logits for every position (B, S, V)."""
        cfg = self.cfg
        params = self._top(params)
        x = self._embed(params, inputs)
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x, _ = self._run_layers(params, x, positions)
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x @ params["lm_head"].astype(x.dtype)

    # ----------------------------------------------------------------- cache
    def init_cache(self, batch: int, max_len: int) -> Dict:
        cfg = self.cfg
        shape = (cfg.num_layers, batch, max_len, cfg.num_kv_heads,
                 cfg.head_dim_)
        return {
            "k": jnp.zeros(shape, cfg.adtype),
            "v": jnp.zeros(shape, cfg.adtype),
            "len": jnp.zeros((batch,), jnp.int32),
        }

    def cache_logical_axes(self) -> Dict:
        ax = ("layer", "batch", "cache_seq", "kv_heads", None)
        return {"k": ax, "v": ax, "len": ("batch",)}

    def cache_specs(self, batch: int, max_len: int) -> Dict:
        cfg = self.cfg
        shape = (cfg.num_layers, batch, max_len, cfg.num_kv_heads,
                 cfg.head_dim_)
        return {
            "k": jax.ShapeDtypeStruct(shape, cfg.adtype),
            "v": jax.ShapeDtypeStruct(shape, cfg.adtype),
            "len": jax.ShapeDtypeStruct((batch,), jnp.int32),
        }

    # --------------------------------------------------------------- prefill
    def prefill(self, params, inputs, max_len: Optional[int] = None):
        """Process a full prompt; return (last-token logits, filled cache)."""
        cfg = self.cfg
        params = self._top(params)
        x = self._embed(params, inputs)
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x, kvs = self._run_layers(params, x, positions, collect_kv=True)
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = x[:, -1] @ params["lm_head"].astype(x.dtype)
        k, v = kvs  # each (L, B, S, Hkv, dh)
        pad = (max_len or S) - S
        if pad > 0:
            zeros = ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))
            k = jnp.pad(k, zeros)
            v = jnp.pad(v, zeros)
        cache = {"k": k.astype(cfg.adtype), "v": v.astype(cfg.adtype),
                 "len": jnp.full((B,), S, jnp.int32)}
        return logits, cache

    # ---------------------------------------------------------------- decode
    def decode(self, params, cache, inputs):
        """One decode step.  inputs: (B,) token ids."""
        cfg = self.cfg
        params = self._top(params)
        x = jnp.take(params["embed"], inputs, axis=0).astype(cfg.adtype)
        length = cache["len"]                                # (B,)

        def body(carry, scanned):
            x = carry
            lp, kc, vc = scanned
            if self.weight_gather is not None:
                lp = self.weight_gather(lp, self.layer_axes())
            xn = L.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
            # project new token, write into cache, attend over length+1
            h, kc, vc = L.attention_decode_apply(
                lp["attn"], xn, kc, vc, length,
                n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads,
                head_dim=cfg.head_dim_, rope_theta=cfg.rope_theta)
            x = x + h
            xn = L.rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
            if cfg.is_moe:
                y = M.moe_apply(lp["moe"], xn[:, None, :],
                                top_k=cfg.num_experts_per_tok,
                                capacity_factor=cfg.capacity_factor,
                                groups=1, shard_ec=None)[:, 0]
            else:
                y = L.mlp_apply(lp["mlp"], xn, gated=True)
            return x + y, (kc, vc)

        if cfg.scan_layers:
            x, (k_all, v_all) = jax.lax.scan(
                body, x, (params["layers"], cache["k"], cache["v"]))
        else:
            ks, vs = [], []
            for i in range(cfg.num_layers):
                lp = jax.tree.map(lambda p_: p_[i], params["layers"])
                x, (kc, vc) = body(x, (lp, cache["k"][i], cache["v"][i]))
                ks.append(kc)
                vs.append(vc)
            k_all = jnp.stack(ks, axis=0)
            v_all = jnp.stack(vs, axis=0)
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = x @ params["lm_head"].astype(x.dtype)
        new_cache = {"k": k_all, "v": v_all, "len": length + 1}
        return logits, new_cache
