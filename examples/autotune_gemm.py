"""Autotune quickstart: calibrate -> search -> cache -> execute.

Shows the three ways in: the one-liner (``tune="auto"``), an explicit
AutoTuner with a canned profile (reproducing the paper's C5 stream
selection without the paper's hardware), and the plan cache paying off on
the second call.  Runs on CPU in a few seconds.
"""
import os
import tempfile
import time

import numpy as np

from repro.core import ooc_gemm
from repro.tune import AutoTuner, PlanCache, gpu_profile, phi_profile

rng = np.random.default_rng(0)
M, N, K = 1024, 896, 512
A = rng.standard_normal((M, K)).astype(np.float32)
B = rng.standard_normal((K, N)).astype(np.float32)
C = rng.standard_normal((M, N)).astype(np.float32)
ref = A @ B + C
budget = (A.nbytes + B.nbytes + C.nbytes) // 5    # force out-of-core

# 1. one-liner: calibrate this machine (lazily, once per process), search,
#    cache, execute.  An isolated cache keeps the demo hermetic.
cache = PlanCache(os.path.join(tempfile.mkdtemp(), "plans.json"))
tuner = AutoTuner(cache=cache)
t0 = time.perf_counter()
out = ooc_gemm(A, B, C, 1.0, 1.0, budget_bytes=budget,
               tune="auto", tuner=tuner)
t1 = time.perf_counter()
print(f"tune='auto': max err {np.abs(out - ref).max():.2e} "
      f"({t1 - t0:.2f}s incl. calibration + search)")
print(f"  calibrated: {tuner.profile.h2d_bw/1e9:.2f} GB/s H2D, "
      f"{tuner.profile.flops/1e9:.1f} GFLOP/s, "
      f"fingerprint {tuner.fingerprint}")

# 2. second call: same shape + same hardware fingerprint = plan-cache hit
t0 = time.perf_counter()
ooc_gemm(A, B, C, 1.0, 1.0, budget_bytes=budget, tune="auto", tuner=tuner)
t1 = time.perf_counter()
assert tuner.last_from_cache and tuner.searches == 1
print(f"second call: served from plan cache in {t1 - t0:.2f}s "
      f"({tuner.cache.hits} hit, {tuner.searches} search total)")

# 3. what WOULD the tuner pick on the paper's hardware?  Canned profiles
#    reproduce claim C5: 1 stream on Xeon Phi, 2 on a K40c-like GPU.
shape = (8192, 8192, 8192)
big_budget = 3 * 8192 * 8192 * 8 // 6
for profile in (gpu_profile(), phi_profile()):
    sim_tuner = AutoTuner(profile=profile, cache=cache,
                          fingerprint=f"demo-{profile.name}",
                          nbuf_options=(1, 2), max_steps=128)
    plan = sim_tuner.gemm_plan(*shape, big_budget, dtype="float64")
    print(f"{profile.name}: picked nstreams={plan.nstreams} "
          f"nbuf={plan.nbuf}, {plan.param('h')}x{plan.param('w')} blocks; "
          f"{plan.baseline_makespan / plan.makespan:.2f}x vs default s2b2")
print("autotune quickstart OK")
