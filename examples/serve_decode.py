"""Batched serving example: prefill a prompt batch, decode with KV cache.

Uses the same prefill/decode step functions the multi-pod dry-run lowers for
the ``decode_32k`` / ``long_500k`` cells — here at smoke scale on CPU.

  PYTHONPATH=src python examples/serve_decode.py
  PYTHONPATH=src python examples/serve_decode.py --arch zamba2-1.2b  # SSM+attn
"""
import argparse

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    args = ap.parse_args()
    out = serve_main(["--arch", args.arch, "--smoke",
                      "--batch", "4", "--prompt-len", "32", "--gen", "16"])
    assert out["tokens"].shape == (4, 15)
    print("serve_decode OK")
