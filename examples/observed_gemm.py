"""Observability quickstart: one switch, three outputs (DESIGN.md §10).

Runs the acceptance scenario — a tuned single-device GEMM plus a hybrid
co-execution across the canned gpu+phi profiles — with the process
:class:`repro.obs.Observability` enabled, then shows the three pillars:

  1. **Metrics** — exact byte/flop/op accounting in Prometheus text
     (``repro_executor_h2d_bytes`` equals the schedule's modeled total, to
     the byte).
  2. **Trace** — one Chrome-trace timeline: tuner search and plan-cache
     lookups on the control lane, one executor lane-group per device, the
     merge span closing the run.  Open it at chrome://tracing or
     https://ui.perfetto.dev.
  3. **Drift** — predicted-vs-measured per (kernel, tier, fingerprint):
     byte ratios must be exactly 1.0; time ratios are the
     calibration-staleness trend signal.
  4. **Attribution** (DESIGN.md §11) — the tuned plan's exact critical
     path, bottleneck verdict and what-if sensitivity: which resource
     buys the next makespan reduction, and why the tuner chose what it
     chose.

Runs on CPU in a few seconds.
"""
import os
import tempfile

import numpy as np

from repro.core import ooc_gemm
from repro.core.api import hclObservability
from repro.hybrid import DeviceSpec
from repro.tune import AutoTuner, PlanCache, gpu_profile, phi_profile

# one switch: metrics + trace + drift all report into this singleton
obs = hclObservability(enable=True, trace=True, trace_name="observed-gemm")

rng = np.random.default_rng(0)
M = N = K = 512
A = rng.standard_normal((M, K)).astype(np.float32)
B = rng.standard_normal((K, N)).astype(np.float32)
budget = (A.nbytes + B.nbytes + M * N * 4) // 3   # force out-of-core

# tuned single-device run (canned profile: deterministic, no calibration)
cache = PlanCache(os.path.join(tempfile.mkdtemp(), "plans.json"))
tuner = AutoTuner(profile=gpu_profile(), fingerprint="demo", cache=cache,
                  max_steps=512)
out = ooc_gemm(A, B, budget_bytes=budget, tune="auto", tuner=tuner)

# hybrid co-execution: same kernel, two devices, one shared timeline
devices = [DeviceSpec("gpu0", gpu_profile(), budget),
           DeviceSpec("phi0", phi_profile(), budget)]
out2 = ooc_gemm(A, B, budget_bytes=budget, tune="auto", devices=devices,
                tolerance=0.1)

ref = A @ B
print(f"max err: single {np.abs(out - ref).max():.2e}, "
      f"hybrid {np.abs(out2 - ref).max():.2e}\n")

# 1. metrics: the exact accounting behind the run
print("--- metrics (Prometheus exposition, excerpt) ---")
for line in obs.metrics.to_prometheus_text().splitlines():
    if line.startswith(("repro_executor_h2d_bytes",
                        "repro_executor_runs_total",
                        "repro_tune_searches_total",
                        "repro_plancache_")):
        print(line)

# 2. one coherent Chrome trace: control lane + per-device executor lanes
trace_path = os.path.join(tempfile.mkdtemp(), "observed_gemm_trace.json")
obs.tracer.write(trace_path)
summ = obs.tracer.summary()
print(f"\n--- trace ({trace_path}) ---")
print(f"control spans: {summ['control_spans']}")
for name, g in sorted(summ["groups"].items()):
    print(f"lane {name!r}: {g['spans']} spans, "
          f"{g['span_seconds']*1e3:.2f} ms busy")

# 3. drift: every tuned run recorded its prediction next to the measurement
print("\n--- drift (measured / predicted) ---")
for key, row in sorted(obs.drift.snapshot()["rolling"].items()):
    print(f"{key}: n={row['n']} time_ratio={row['last_time_ratio']:.3g}")
for rec in obs.drift.records():
    assert rec.byte_ratio == 1.0, "executed bytes must match the model"
print("byte ratios: all exactly 1.0 (executed == modeled transfers)")

# 4. attribution: replay the tuned plan's schedule, walk its exact
#    critical path, and ask what the next resource increment would buy
from repro.obs.analyze import analyze_plan
from repro.obs.whatif import whatif_plan

plan = tuner.gemm_plan(M, N, K, budget)          # cache hit
ana, res = analyze_plan(plan, gpu_profile())
ana.verify_reconciliation(res)                    # exact, or AssertionError
print("\n--- attribution (DESIGN.md §11) ---")
print(ana.digest())
for g in ana.top_gaps(3):
    print(f"  idle s{g.stream} {g.duration*1e6:.1f}us before "
          f"{g.next_tag or 'drain'}: {g.cause}")
rep = whatif_plan(plan, gpu_profile())
for sc in rep.ranked():
    print(f"  what-if {sc.name}: {sc.gain_seconds*1e3:+.3f} ms "
          f"({sc.speedup:.3f}x)")

obs.reset()
