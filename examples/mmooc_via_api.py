"""MMOOC written against the unified libhclooc-style API (paper Fig. 2).

This file is the LOC *numerator* for claim C4: compare with the three
backend-specific implementations in benchmarks/direct_impls.py.  The same
code runs on every memory tier by changing the device tuple — the paper's
{"GPU"| "PHI"| "FPGA"} becomes {"HBM"| "VMEM"| "MESH"}.
"""
import sys

import numpy as np

from repro.core.api import (hclDeviceFactory, hclMatrixPartitioner,
                            hclRuntimeFactory)


def mmooc(A, B, C, alpha, beta, device_name="HBM", device_id=0,
          mem_bytes=None, mesh=None):
    d = hclDeviceFactory.create(device_name, device_id, mem_bytes)
    r = hclRuntimeFactory.create(d, mesh)
    part = hclMatrixPartitioner(A.shape[0], B.shape[1], A.shape[1],
                                d.mem_size(), A.dtype.itemsize)
    return r.gemm(A, B, C, alpha, beta, part)


if __name__ == "__main__":
    rng = np.random.default_rng(0)
    M, N, K = 768, 512, 384
    A = rng.standard_normal((M, K)).astype(np.float32)
    B = rng.standard_normal((K, N)).astype(np.float32)
    C = rng.standard_normal((M, N)).astype(np.float32)
    budget = (A.nbytes + B.nbytes + C.nbytes) // 5   # force out-of-core
    for dev in ("HBM", "VMEM"):
        out = mmooc(A, B, C, 1.5, 0.5, dev, mem_bytes=budget)
        err = np.abs(np.asarray(out) - (1.5 * A @ B + 0.5 * C)).max()
        print(f"{dev}: max err {err:.2e}")
        assert err < 1e-2
    print("mmooc_via_api OK")
