"""Resilience quickstart: the four fault classes, each recovered exactly.

DESIGN.md §12 in ~60 lines: a seeded FaultPlan injects transfer errors,
compute corruption, OOM and device loss into one OOC GEMM, and every
recovery path — retry, block replay, degrade ladder, hybrid rebalance —
returns a result **bitwise identical** to the fault-free run.  Runs on
CPU in a few seconds.
"""
import numpy as np

from repro.core import ooc_gemm
from repro.core.api import hclFaultPolicy
from repro.fault import FaultPlan, FaultSpec
from repro.hybrid import DeviceSpec, plan_hybrid_gemm, run_hybrid_gemm
from repro.tune import gpu_profile, phi_profile

rng = np.random.default_rng(0)
M, N, K = 512, 256, 128
A = rng.standard_normal((M, K))
B = rng.standard_normal((K, N))
C = rng.standard_normal((M, N))
budget = (A.nbytes + B.nbytes + C.nbytes) // 5   # genuinely out-of-core

clean = ooc_gemm(A, B, C, 1.0, 0.5, budget_bytes=budget, backend="host")
policy = hclFaultPolicy(backoff_base=1e-4)       # fast demo backoff

# 1. random seeded faults: transfer retries + compute replays.  The same
#    (seed, rate) always injects the same (op, class) set — a failure
#    here would be exactly reproducible.
def run(faults):
    return ooc_gemm(A, B, C, 1.0, 0.5, budget_bytes=budget,
                    backend="host", faults=faults, fault_policy=policy)


cap = {}


def seeded(sched):
    cap["inj"] = FaultPlan.random(7, sched, rate=0.25).injector()
    return cap["inj"]


out = run(seeded)
inj = cap["inj"]
print(f"1. random faults: injected {len(inj.injected)} "
      f"({sorted(set(c for _, c in inj.injected))}), "
      f"bitwise identical: {np.array_equal(out, clean)}")

# 2. a pinned retry storm: op 0 (an H2D) fails twice, the third attempt
#    succeeds; nominal byte counters are untouched by the failed tries.
out = run(FaultPlan(specs=(FaultSpec(op=0, cls="h2d_error", times=2),)))
print(f"2. retry storm:  bitwise identical: {np.array_equal(out, clean)}")

# 3. OOM: the planner's degrade ladder (halve nbuf -> drop lookahead ->
#    halve budget) replans and re-runs fault-free.  Because the
#    partitioner never splits K, the degraded plan is still bitwise.
pol = hclFaultPolicy(backoff_base=1e-4)


def oom_everywhere(sched):
    return FaultPlan(specs=(FaultSpec(op=0, cls="oom", times=99),)).injector()


out = ooc_gemm(A, B, C, 1.0, 0.5, budget_bytes=budget, backend="host",
               faults=oom_everywhere, fault_policy=pol)
print(f"3. oom ladder:   degraded via {[d.action for d in pol.degrades]}, "
      f"bitwise identical: {np.array_equal(out, clean)}")

# 4. device loss mid-hybrid: gpu0 dies on its first op; its C band is
#    replanned across the survivors and recomputed from pristine inputs.
devices = [DeviceSpec("gpu0", gpu_profile(), budget),
           DeviceSpec("phi0", phi_profile(), budget)]
hplan = plan_hybrid_gemm(M, N, K, devices, nbuf_options=(1, 2),
                         max_steps=256)
ref_h, _ = run_hybrid_gemm(A, B, C, 1.0, 0.5, hplan)
lost_plan = FaultPlan(specs=(FaultSpec(op=0, cls="device_lost"),))
out, groups = run_hybrid_gemm(A, B, C, 1.0, 0.5, hplan,
                              fault_plans={"gpu0": lost_plan},
                              fault_policy=hclFaultPolicy())
print(f"4. device lost:  bands {[g for g, _ in groups]}, "
      f"bitwise identical: {np.array_equal(out, ref_h)}")
print("faulty gemm quickstart OK")
