"""Hybrid quickstart: one GEMM co-scheduled across a GPU+Phi profile pair.

The balance -> plan -> co-execute -> merge pipeline (DESIGN.md §7) in ~40
lines: split C's rows so the paper's two canned device profiles predict
equal finish times, tune each band, run both schedules concurrently on this
machine, and compare against the best single device.  Runs on CPU in a few
seconds.
"""
import json

import numpy as np

from repro.core import ooc_gemm
from repro.hybrid import DeviceSpec, plan_hybrid_gemm, simulate_hybrid
from repro.tune import gpu_profile, phi_profile
from repro.tune.search import search_gemm

rng = np.random.default_rng(0)
M, N, K = 1536, 1024, 512
A = rng.standard_normal((M, K)).astype(np.float32)
B = rng.standard_normal((K, N)).astype(np.float32)
C = rng.standard_normal((M, N)).astype(np.float32)
ref = A @ B + C
budget = (M * K + K * N + M * N) * 4 // 4     # per-device tier budget

# 1. the device set: the paper's testbed pair, as calibrated profiles
devices = [DeviceSpec("gpu0", gpu_profile(), budget),
           DeviceSpec("phi0", phi_profile(), budget)]

# 2. balance + tune: shares sized so predicted finish times equalize,
#    each band planned by tune.search under its own profile
hplan = plan_hybrid_gemm(M, N, K, devices, nbuf_options=(1, 2),
                         max_steps=256)
for dp in hplan.device_plans:
    print(f"{dp.device.name}: rows [{dp.start}, {dp.start + dp.length}) "
          f"s{dp.plan.nstreams}b{dp.plan.nbuf} "
          f"-> predicted {dp.plan.makespan * 1e3:.2f} ms")
print(f"balanced in {hplan.balance.iterations} iters, "
      f"finish-time spread {hplan.balance.spread:.3f} "
      f"(tolerance {hplan.tolerance})")

# 3. predicted payoff vs. the best single device (engine model)
sim = simulate_hybrid(hplan)
best_single = min(
    search_gemm(M, N, K, d.budget_bytes, d.profile, fingerprint="demo",
                nbuf_options=(1, 2), max_steps=256).makespan
    for d in devices)
print(f"hybrid {sim.makespan * 1e3:.2f} ms vs best single "
      f"{best_single * 1e3:.2f} ms -> {best_single / sim.makespan:.2f}x")

# 4. co-execute for real: one entry-point call, exact result
out = ooc_gemm(A, B, C, 1.0, 1.0, budget_bytes=budget, devices=devices)
print(f"max err vs oracle: {np.abs(out - ref).max():.2e}")

# 5. one Chrome-trace lane-group per device (pid = device index)
with open("hybrid_trace.json", "w") as f:
    json.dump(sim.to_chrome_trace(), f)
print("wrote hybrid_trace.json — load at chrome://tracing or ui.perfetto.dev")
print("hybrid quickstart OK")
