"""OOC attention demo: the MMOOC pipeline reused for a KV cache.

A decode-step query attends over a cache larger than the (simulated) fast
tier; KV blocks stream through the same double-buffered schedule as the
GEMM, with an online-softmax carry instead of the beta-accumulate.
"""
import numpy as np

from repro.core import (build_attention_schedule, plan_attention_partition,
                        schedule_stats, simulate, tpu_v5e_vmem,
                        validate_schedule)
from repro.core.ooc_attention import ooc_attention
from repro.kernels import ops, ref
import jax.numpy as jnp

rng = np.random.default_rng(0)
H, hkv, d, S = 32, 8, 128, 8192
q = rng.standard_normal((H, d)).astype(np.float32)
k = rng.standard_normal((S, hkv, d)).astype(np.float32)
v = rng.standard_normal((S, hkv, d)).astype(np.float32)
budget = S * hkv * d * 4 // 4     # cache is 4x the fast tier

part = plan_attention_partition(S, hkv, d, budget)
print(f"KV cache split into {part.nblocks} blocks of {part.bs} positions")

sched = build_attention_schedule(part, hkv, d, H)
validate_schedule(sched)
print(f"schedule: {schedule_stats(sched)}")

out = ooc_attention(q, k, v, budget_bytes=budget)
expect = ref.decode_attention_ref(
    jnp.asarray(q)[None], jnp.asarray(k)[None], jnp.asarray(v)[None],
    jnp.asarray([S]))[0]
print(f"engine max err vs oracle: "
      f"{np.abs(np.asarray(out) - np.asarray(expect)).max():.2e}")

# the same computation through the Pallas kernel (interpret mode on CPU)
out_k = ops.flash_decode_attention(
    jnp.asarray(q)[None], jnp.asarray(k)[None], jnp.asarray(v)[None],
    jnp.asarray([S]), block_s=512, interpret=True)[0]
print(f"pallas max err vs oracle: "
      f"{np.abs(np.asarray(out_k) - np.asarray(expect)).max():.2e}")

res = simulate(sched, tpu_v5e_vmem())
print(f"on v5e VMEM tier: {res.makespan*1e6:.1f} us/token, "
      f"DMA util {res.utilization('in'):.2f} (memory-bound, as decode is)")
print("ooc_attention_demo OK")
