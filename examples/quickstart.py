"""Quickstart: out-of-core GEMM through the three TPU memory tiers.

Runs on CPU (vmem backend in interpret mode; mesh backend needs >1 device —
skipped gracefully).  ~30 s.
"""
import numpy as np

from repro.core import (build_gemm_schedule, gpu_like, ooc_gemm,
                        plan_gemm_partition, schedule_stats, simulate,
                        tpu_v5e_vmem, validate_schedule)

rng = np.random.default_rng(0)
M, N, K = 768, 640, 512
A = rng.standard_normal((M, K)).astype(np.float32)
B = rng.standard_normal((K, N)).astype(np.float32)
C = rng.standard_normal((M, N)).astype(np.float32)
ref = 1.5 * A @ B + 0.5 * C
budget = (A.nbytes + B.nbytes + C.nbytes) // 5   # force out-of-core

# 1. plan: how does the hclMatrixPartitioner split this under the budget?
part = plan_gemm_partition(M, N, K, budget, 4)
print(f"partition: {part.h}x{part.w} blocks of {part.bm}x{part.bn} "
      f"(working set {part.working_set_bytes()/1e6:.2f} MB "
      f"<= budget {budget/1e6:.2f} MB)")

# 2. schedule: the paper's Fig.2 event program, generated + validated
sched = build_gemm_schedule(part, nstreams=2, nbuf=2)
validate_schedule(sched)
print(f"schedule: {schedule_stats(sched)}")

# 3. execute on the host-streaming backend
out = ooc_gemm(A, B, C, 1.5, 0.5, budget_bytes=budget, backend="host")
print(f"host backend max err: {np.abs(out - ref).max():.2e}")

# 4. execute through the Pallas VMEM kernel (interpret mode on CPU)
out_v = ooc_gemm(A, B, C, 1.5, 0.5, budget_bytes=budget, backend="vmem")
print(f"vmem backend max err: {np.abs(np.asarray(out_v) - ref).max():.2e}")

# 5. what would this schedule do on real hardware?  (engine model)
for hw in (gpu_like(), tpu_v5e_vmem()):
    res = simulate(sched, hw)
    print(f"{hw.name}: {res.effective_flops/1e9:.1f} GFLOP/s effective, "
          f"exec util {res.utilization('exec'):.2f}")
print("quickstart OK")
