"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Full run (~100M params, 200 steps) takes tens of minutes on this CPU
container; ``--quick`` runs a 12-step sanity version in ~1 minute.  On a real
TPU mesh the same driver shards via the production rules (see
repro/launch/train.py, which this wraps).

  PYTHONPATH=src python examples/train_lm.py --quick
  PYTHONPATH=src python examples/train_lm.py            # the full example
"""
import argparse
import sys

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--steps", type=int, default=0)
    args = ap.parse_args()

    if args.quick:
        argv = ["--arch", "stablelm-1.6b", "--smoke",
                "--steps", str(args.steps or 12),
                "--batch", "2", "--seq", "64", "--log-every", "4"]
    else:
        # ~103M params: stablelm family at d_model=512, 8 layers
        # (embed+head on the 100k vocab dominate, like real small LMs)
        argv = ["--arch", "stablelm-1.6b",
                "--d-model", "512", "--layers", "8",
                "--steps", str(args.steps or 200),
                "--batch", "2", "--seq", "64",
                "--ckpt-dir", "/tmp/repro_train_lm", "--ckpt-every", "50",
                "--resume", "auto", "--log-every", "10"]
    out = train_main(argv)
    print(f"final loss: {out['final_loss']:.4f}")
    assert out["final_loss"] < out["losses"][0], "loss did not improve"
    print("train_lm OK")
