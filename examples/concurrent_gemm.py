"""Concurrent event-driven execution quickstart (DESIGN.md §13).

One OOC GEMM schedule run two ways — the serial issue-order oracle and
``mode="concurrent"`` (one worker thread per H2D/compute/D2H engine,
``threading.Event``s mirroring the schedule's event program).  The demo
shows the three contracts in ~40 lines:

  * results are **bitwise identical** and byte counters equal
    ``schedule_stats`` exactly in both modes;
  * concurrent completion order is a *linear extension* of the
    dependency order, not issue order — engines genuinely overlap;
  * the cached :class:`ExecutablePlan` makes repeat dispatch ~free.

Runs on CPU in a few seconds.
"""
import time

import numpy as np

from repro.core import (
    ScheduleExecutor,
    build_gemm_schedule,
    plan_cache_stats,
    plan_gemm_partition,
    schedule_stats,
)
from repro.core.api import hclCompileExecutable

rng = np.random.default_rng(0)
M, N, K = 2048, 2048, 1024
A = rng.standard_normal((M, K)).astype(np.float32)
B = rng.standard_normal((K, N)).astype(np.float32)
C = rng.standard_normal((M, N)).astype(np.float32)
budget = (A.nbytes + B.nbytes + C.nbytes) // 4   # genuinely out-of-core

part = plan_gemm_partition(M, N, K, budget, 4, nbuf=2, nstreams=2)
sched = build_gemm_schedule(part, nstreams=2, nbuf=2)
stats = schedule_stats(sched)
ctx = {"alpha": 1.0, "beta": 0.5}

# 1. the ExecutablePlan: handlers, engine queues and dependency edges are
#    pre-resolved once and cached on the schedule itself.
t0 = time.perf_counter()
plan = hclCompileExecutable(sched)
t_cold = time.perf_counter() - t0
t0 = time.perf_counter()
assert hclCompileExecutable(sched) is plan       # cache hit
t_warm = time.perf_counter() - t0
print(f"1. plan: {plan.n_ops} ops on {len(plan.queues)} engines, "
      f"compile {t_cold*1e6:.0f}us -> cached {t_warm*1e6:.1f}us "
      f"(stats: {plan_cache_stats()})")

# 2. serial oracle vs concurrent: bitwise outputs, exact byte counters.
outs = {}
for mode in ("issue_order", "concurrent"):
    ex = ScheduleExecutor(mode=mode, record_spans=True)
    out = {"C": np.array(C)}
    t0 = time.perf_counter()
    ex.run(sched, {"A": A, "B": B}, out, ctx)
    dt = time.perf_counter() - t0
    assert ex.last_h2d_bytes == stats["h2d_bytes"]
    assert ex.last_d2h_bytes == stats["d2h_bytes"]
    busy = sum(t1 - t0 for _, _, t0, t1 in ex.last_spans)
    wall = (max(t1 for *_, t1 in ex.last_spans)
            - min(t0 for _, _, t0, _ in ex.last_spans))
    outs[mode] = (out["C"], ex.last_completion_order)
    print(f"2. {mode:<12} {dt*1e3:6.0f}ms  engine overlap "
          f"busy/makespan = {busy/wall:.2f}x")
assert np.array_equal(outs["issue_order"][0], outs["concurrent"][0])
print("   bitwise identical: True")

# 3. concurrent completion reorders across engines but never violates a
#    dependency edge (asserted exhaustively in tests/test_exec_concurrent).
order = outs["concurrent"][1]
moved = sum(1 for pos, i in enumerate(order) if pos != i)
print(f"3. completion order: {moved}/{len(order)} ops completed out of "
      f"issue order — a linear extension of the dependency order")
