"""OOC factorization quickstart: lookahead LU (and Cholesky) pipelines.

Factors a host-resident matrix through ONE compiled schedule that
interleaves panel GETRF/TRSM ops with the streamed GEMM trailing update —
the paper's §VII future work (DESIGN.md §8).  Shows the pivot-permutation
contract, the simulated lookahead win over the sequential per-panel loop,
and the tuned plan.  Runs on CPU in a few seconds.
"""
import os
import tempfile

import numpy as np

from repro.core import (compile_factor_pipeline, factor_pipeline_spec,
                        ooc_cholesky, ooc_lu, simulate)
from repro.tune import AutoTuner, PlanCache, gpu_profile

rng = np.random.default_rng(0)
n = 512
A = rng.standard_normal((n, n)).astype(np.float32)
budget = 4 * A.nbytes

# 1. factor: LU, perm such that A[perm] = (tril(LU,-1) + I) @ triu(LU)
LU, perm = ooc_lu(A, panel=128, budget_bytes=budget, lookahead=1,
                  validate=True)
L = np.tril(LU, -1) + np.eye(n, dtype=np.float32)
U = np.triu(LU)
err = np.abs(A[perm] - L @ U).max() / np.abs(A).max()
print(f"ooc_lu: n={n}, panel=128, reconstruction err {err:.2e}, "
      f"{int((perm != np.arange(n)).sum())} rows pivoted")

# ... and a solve through the factors (row-permute b, then L then U)
b = rng.standard_normal(n).astype(np.float32)
y = np.linalg.solve(L, b[perm])
x = np.linalg.solve(U, y)
print(f"solve via LU vs np.linalg.solve: "
      f"max err {np.abs(x - np.linalg.solve(A, b)).max():.2e}")

# 2. Cholesky rides the same pipeline (POTRF/TRSM panels + SYRK trailing)
S = (A @ A.T + n * np.eye(n)).astype(np.float32)
Lc = ooc_cholesky(S, panel=128, budget_bytes=budget)
print(f"ooc_cholesky: reconstruction err "
      f"{np.abs(Lc @ Lc.T - S).max() / np.abs(S).max():.2e}")

# 3. why lookahead: simulate the same factorization on the paper's
#    K40c-like profile, sequential vs lookahead event graphs
hw = gpu_profile().model_for(2)
big = dict(n=8192, panel=512, bpe=8, budget=256 * 2**20)
ms = {}
for la in (0, 1):
    spec = factor_pipeline_spec(big["n"], big["panel"], big["budget"],
                                big["bpe"], kind="cholesky", lookahead=la)
    ms[la] = simulate(compile_factor_pipeline(spec), hw).makespan
print(f"simulated 8192^2 fp64 Cholesky on gpu-like: sequential "
      f"{ms[0]*1e3:.0f} ms, lookahead {ms[1]*1e3:.0f} ms "
      f"({ms[0]/ms[1]:.2f}x)")

# 4. tune='auto': one cached search covers every shrinking trailing shape
cache = PlanCache(os.path.join(tempfile.mkdtemp(), "plans.json"))
tuner = AutoTuner(profile=gpu_profile(), fingerprint="demo", cache=cache)
LU2, _ = ooc_lu(A, panel=128, budget_bytes=budget, tune="auto",
                tuner=tuner)
plan = tuner.factor_plan("lu", n, 128, budget)
assert tuner.last_from_cache  # the ooc_lu call above warmed the cache
print(f"tuned: panel={plan.param('panel')} lookahead="
      f"{plan.param('lookahead')} s{plan.nstreams}b{plan.nbuf} "
      f"(1 search, then cache hits)")
print("ooc factorization quickstart OK")
