"""Roofline table readout: renders experiments/dryrun/*.json artifacts.

Not a timing benchmark — this is the §Roofline deliverable's presentation
layer, kept in benchmarks/ so ``python -m benchmarks.run`` emits the full
per-cell table alongside the paper-claim benches.
"""

from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def load_cells(pattern="*__single.json"):
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, pattern))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def run():
    rows = []
    cells = load_cells()
    if not cells:
        return [{"name": "roofline", "us_per_call": 0.0,
                 "derived": f"no dry-run artifacts in {DRYRUN_DIR} — run "
                            "`python -m repro.launch.dryrun --all`"}]
    for c in cells:
        name = f"roofline_{c['arch']}__{c['shape']}"
        if c["status"] == "SKIP":
            rows.append({"name": name, "us_per_call": 0.0,
                         "derived": f"SKIP: {c['reason']}"})
            continue
        if c["status"] != "OK" or "roofline" not in c:
            rows.append({"name": name, "us_per_call": 0.0,
                         "derived": f"{c['status']}: "
                                    f"{c.get('error', '')[:120]}"})
            continue
        r = c["roofline"]
        rows.append({
            "name": name,
            "us_per_call": r["t_bound_s"] * 1e6 if "t_bound_s" in r else
            max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"]) * 1e6,
            "derived": (f"Tc={r['t_compute_s']:.3f}s "
                        f"Tm={r['t_memory_s']:.3f}s "
                        f"Tx={r['t_collective_s']:.3f}s "
                        f"bound={r['bottleneck']} "
                        f"frac={r['roofline_fraction']:.3f} "
                        f"useful={r['useful_flops_ratio']:.2f} "
                        f"hbm={c['device_hbm_bytes']/2**30:.1f}GiB "
                        f"fits={c['fits_hbm']}"),
        })
    return rows
