"""Claim C1: abstraction overhead of the unified API vs direct code.

Paper: libhclooc loses <= 10 % (K40c) / 4 % (P100) / 8 % (Phi) against the
hand-optimized accelerator-specific implementations.  Here: wall-clock of
``ooc_gemm`` (spec compilation + schedule build + runtime dispatch + hcl
facade) vs. (a) a pre-built schedule on the same executor (the pure
planning-layer overhead) and (b) the hand-rolled host implementation of
benchmarks/direct_impls.py — which hand-derives its partition and op list
but shares the engine's ScheduleExecutor, so (b) isolates the planning
abstraction, not interpreter duplication.  Same partition and dtype, on CPU.

Also guards the observability layer's disabled cost (DESIGN.md §10): the
``obs_disabled_overhead`` row micro-times the per-run hook sequence every
instrumented kernel call pays when metrics/tracing are OFF (guard branches
in ``record_executor_run`` / ``record_drift`` / ``span``) and asserts it
stays under 2 % of the smallest GEMM's floor time.

The ``exec_plan_cache_hit`` row guards per-run dispatch setup (DESIGN.md
§13): a cached :func:`compile_executable` hit — what every repeated
``run()`` on the same schedule pays — must stay >= 2x faster than a cold
plan compile.

The ``analysis_cost`` row guards the attribution layer (DESIGN.md §11):
one full :class:`~repro.obs.analyze.TraceAnalysis` — span pairing, exact
critical-path walk, stream segmentation — over the paper-regime 8192^3
fp64 GEMM trace must stay under 50 ms, so post-run attribution is always
cheap enough to leave on.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.direct_impls import direct_host_ooc_gemm, direct_vmem_ooc_gemm
from repro.core import ooc_gemm


def _time(fn, *args, reps=3, **kw):
    fn(*args, **kw)  # warmup + jit
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        ts.append(time.perf_counter() - t0)
    return min(ts), out


def _obs_disabled_overhead(sched, t_floor: float) -> dict:
    """Per-run cost of the obs hooks with everything disabled, as a percent
    of the smallest GEMM's floor time.  Micro-timing the hook path directly
    (instead of diffing two noisy wall-clock A/B runs) makes the guard
    stable: the publish sequence is identical on every run, the floor time
    is the benchmark's own measurement."""
    from repro.obs import get_observability

    obs = get_observability()
    was_metrics, was_tracer = obs.metrics.enabled, obs.tracer
    obs.metrics.enabled = False
    obs.tracer = None
    try:
        reps = 2000
        t0 = time.perf_counter()
        for _ in range(reps):
            # the exact per-run sequence an instrumented kernel call pays
            obs.record_executor_run(sched, 0.0, 0, 0)
            obs.record_drift("gemm", "HBM", "bench",
                             predicted_makespan=1.0, measured_seconds=1.0)
            with obs.span("bench"):
                pass
        per_run = (time.perf_counter() - t0) / reps
    finally:
        obs.metrics.enabled = was_metrics
        obs.tracer = was_tracer
    pct = per_run / t_floor * 100.0
    assert pct < 2.0, (
        f"disabled observability hooks cost {pct:.3f}% of the smallest "
        f"GEMM floor ({per_run*1e6:.2f}us vs {t_floor*1e3:.1f}ms)")
    return {
        "name": "obs_disabled_overhead",
        "us_per_call": per_run * 1e6,
        "derived": f"hooks={per_run*1e6:.2f}us/run "
                   f"floor={t_floor*1e3:.1f}ms -> {pct:.4f}% (guard: <2%)",
    }


def _fault_disabled_overhead(sched, t_floor: float) -> dict:
    """Per-run cost of the fault-injection plumbing when ``faults=None``,
    as a percent of the smallest GEMM's floor time.  The disabled path
    adds exactly one arming check at run start plus an ``fi is None``
    branch per op (see ScheduleExecutor.run), so the guard micro-times
    that sequence directly — same rationale as ``_obs_disabled_overhead``:
    the branch stream is identical on every run, no A/B wall-clock noise."""
    reps = 2000
    ops = sched.ops
    t0 = time.perf_counter()
    for _ in range(reps):
        fi = None
        if callable(fi):        # arming: resolve plan/factory (not taken)
            raise AssertionError
        for _op in ops:
            if fi is None:
                pass
    per_run = (time.perf_counter() - t0) / reps
    pct = per_run / t_floor * 100.0
    assert pct < 1.0, (
        f"faults-disabled plumbing costs {pct:.3f}% of the smallest GEMM "
        f"floor ({per_run*1e6:.2f}us vs {t_floor*1e3:.1f}ms; guard: <1%)")
    return {
        "name": "fault_disabled_overhead",
        "us_per_call": per_run * 1e6,
        "derived": f"branches={per_run*1e6:.2f}us/run ops={len(ops)} "
                   f"floor={t_floor*1e3:.1f}ms -> {pct:.4f}% (guard: <1%)",
    }


def _exec_plan_cache_hit(sched) -> dict:
    """Per-run cost of the ExecutablePlan cache hit (DESIGN.md §13) — the
    steady-state dispatch setup every repeated ``run()`` pays.  Guard: the
    cached path must beat a cold compile by >= 2x, or pre-compilation has
    stopped amortizing."""
    from repro.core import compile_executable
    from repro.core.exec_plan import _CACHE_ATTR

    reps = 200
    t_cold = 0.0
    for _ in range(reps):
        if hasattr(sched, _CACHE_ATTR):
            delattr(sched, _CACHE_ATTR)
        t0 = time.perf_counter()
        compile_executable(sched)
        t_cold += time.perf_counter() - t0
    t_cold /= reps
    t0 = time.perf_counter()
    for _ in range(reps):
        compile_executable(sched)
    t_warm = (time.perf_counter() - t0) / reps
    speedup = t_cold / t_warm
    assert speedup >= 2.0, (
        f"plan-cache hit only {speedup:.1f}x faster than cold compile "
        f"(cold={t_cold*1e6:.1f}us warm={t_warm*1e6:.2f}us; guard: >=2x)")
    return {
        "name": "exec_plan_cache_hit",
        "us_per_call": t_warm * 1e6,
        "derived": f"warm={t_warm*1e6:.2f}us cold={t_cold*1e6:.1f}us "
                   f"speedup={speedup:.0f}x ops={len(sched.ops)} "
                   f"(guard: >=2x)",
    }


def _analysis_cost() -> dict:
    """Time one exact attribution of the paper-regime 8192^3 fp64 GEMM
    trace (claim C5's schedule) and guard it under 50 ms."""
    from repro.core.partitioner import plan_gemm_partition
    from repro.core.pipeline import compile_pipeline, gemm_pipeline_spec
    from repro.core.simulator import simulate
    from repro.obs.analyze import TraceAnalysis
    from repro.tune import gpu_profile

    m = 8192
    budget = (3 * m * m * 8) // 6
    part = plan_gemm_partition(m, m, m, budget, 8, nbuf=2, nstreams=2)
    sched = compile_pipeline(gemm_pipeline_spec(part, band=2),
                             nstreams=2, nbuf=2)
    hw = gpu_profile().model_for(2)
    res = simulate(sched, hw)
    t, ana = _time(TraceAnalysis.from_sim, sched, res, hw=hw)
    ana.verify_reconciliation(res)
    assert t < 0.050, (
        f"TraceAnalysis of the 8192^3 GEMM trace took {t*1e3:.1f}ms "
        f"(guard: <50ms, {len(sched.ops)} ops)")
    return {
        "name": "analysis_cost",
        "us_per_call": t * 1e6,
        "derived": f"analyze {len(sched.ops)} ops={t*1e3:.2f}ms "
                   f"verdict={ana.verdict} (guard: <50ms)",
    }


def run(sizes=((512, 512, 384), (1024, 768, 512), (1536, 1024, 512))):
    rng = np.random.default_rng(0)
    rows = []
    guard_row = None
    fault_guard_row = None
    plan_guard_row = None
    for (M, N, K) in sizes:
        A = rng.standard_normal((M, K)).astype(np.float32)
        B = rng.standard_normal((K, N)).astype(np.float32)
        C = rng.standard_normal((M, N)).astype(np.float32)
        budget = (A.nbytes + B.nbytes + C.nbytes) // 5
        ref = 1.5 * A @ B + 0.5 * C

        # (a) abstraction overhead: full API (plan + build + validate +
        # dispatch) vs executing a PRE-BUILT schedule (zero-abstraction
        # floor running the identical block program)
        from repro.core import (HostOocRuntime, build_gemm_schedule,
                                plan_gemm_partition)
        part = plan_gemm_partition(M, N, K, budget, 4)
        sched = build_gemm_schedule(part)
        rt = HostOocRuntime()
        # validate=False: schedule validation is the test-suite's job;
        # per-call overhead = partition planning + schedule build + dispatch
        t_api, out_api = _time(
            ooc_gemm, A, B, C, 1.5, 0.5, budget_bytes=budget,
            backend="host", validate=False)
        t_floor, out_floor = _time(
            rt.gemm, A, B, C, 1.5, 0.5, part, schedule=sched)
        assert np.abs(out_api - ref).max() < 1e-2
        assert np.abs(out_floor - ref).max() < 1e-2
        overhead = (t_api - t_floor) / t_floor * 100.0
        if guard_row is None:   # smallest size = tightest 2% budget
            guard_row = _obs_disabled_overhead(sched, t_floor)
            fault_guard_row = _fault_disabled_overhead(sched, t_floor)
            plan_guard_row = _exec_plan_cache_hit(sched)
        rows.append({
            "name": f"overhead_host_{M}x{N}x{K}",
            "us_per_call": t_api * 1e6,
            "derived": f"api={t_api*1e3:.1f}ms floor={t_floor*1e3:.1f}ms "
                       f"overhead={overhead:+.1f}% (paper: <=10%)",
        })
        # (b) beyond-paper: the API schedule vs a hand-rolled direct loop —
        # the library BEATS naive direct code (its schedule is better)
        t_direct, out_direct = _time(
            direct_host_ooc_gemm, A, B, C, 1.5, 0.5, budget)
        assert np.abs(out_direct - ref).max() < 1e-2
        rows.append({
            "name": f"api_vs_handrolled_{M}x{N}x{K}",
            "us_per_call": t_direct * 1e6,
            "derived": f"hand-rolled={t_direct*1e3:.1f}ms "
                       f"api={t_api*1e3:.1f}ms "
                       f"api_speedup={t_direct/t_api:.2f}x",
        })
    if guard_row is not None:
        rows.append(guard_row)
    if fault_guard_row is not None:
        rows.append(fault_guard_row)
    if plan_guard_row is not None:
        rows.append(plan_guard_row)
    rows.append(_analysis_cost())
    return rows
