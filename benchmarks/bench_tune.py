"""Autotuner: tuned vs. default pipeline makespans per hardware model (C5).

The paper's §VI observation — 2 streams hide PCIe on GPUs, 1 stream is
optimal on Xeon Phi (shared transfer engine, thread-split compute) — is the
acceptance bar for the tuner: given a phi-like profile it must *select*
``nstreams=1``, given a gpu-like profile ``nstreams=2``, and in both cases
the tuned plan's simulated makespan must not exceed the hardcoded
``(nstreams=2, nbuf=2)`` default's.  This bench asserts all of that
(hard-fails on regression), reports the tuned speedups, and demonstrates
the plan cache (second plan request = hit, no re-search).

``--smoke`` shrinks the problem for CI; either way results land in
``benchmarks/bench_tune.json`` (uploaded as a CI artifact).
"""

from __future__ import annotations

import json
import os
import tempfile

from repro.tune import (AutoTuner, PlanCache, gpu_profile, phi_profile,
                        tpu_v5e_profile)

JSON_PATH = os.path.join(os.path.dirname(__file__), "bench_tune.json")

# paper §VI regime: compute-dominated large square DGEMM (full / 6 budget).
# C5 is regime-dependent — on a transfer-bound (small) problem even Phi
# prefers overlap — so the smoke mode keeps the paper's shape and shrinks
# the *option space* instead.
M, N, K, BPE = 8192, 8192, 8192, 8

EXPECT_STREAMS = {"gpu-like": 2, "phi-like": 1}


def run(smoke: bool = False):
    rows = []
    budget = (M * K + K * N + M * N) * BPE // 6
    nbuf_options = (1, 2) if smoke else (1, 2, 3)
    max_steps = 128 if smoke else 2048

    cache_path = os.path.join(tempfile.mkdtemp(prefix="bench_tune_"),
                              "plans.json")
    for profile in (gpu_profile(), phi_profile(), tpu_v5e_profile()):
        tuner = AutoTuner(profile=profile,
                          cache=PlanCache(cache_path),
                          fingerprint=f"bench-{profile.name}",
                          nbuf_options=nbuf_options,
                          max_steps=max_steps)
        plan = tuner.gemm_plan(M, N, K, budget, dtype="float64")
        assert not tuner.last_from_cache and tuner.searches == 1
        speedup = plan.baseline_makespan / plan.makespan
        rows.append({
            "name": f"tune_{profile.name}",
            "us_per_call": plan.makespan * 1e6,
            "derived": (f"picked s{plan.nstreams}b{plan.nbuf} "
                        f"{plan.param('h')}x{plan.param('w')} blocks "
                        f"(bm={plan.param('bm')} bn={plan.param('bn')}); "
                        f"default s2b2 {plan.baseline_makespan*1e6:.0f}us "
                        f"-> {speedup:.2f}x"),
        })
        if plan.makespan > plan.baseline_makespan + 1e-12:
            raise AssertionError(
                f"tuned plan slower than default on {profile.name}: "
                f"{plan.makespan} vs {plan.baseline_makespan}")
        want = EXPECT_STREAMS.get(profile.name)
        if want is not None and plan.nstreams != want:
            raise AssertionError(
                f"C5 regression: tuner picked nstreams={plan.nstreams} "
                f"on {profile.name}, paper says {want}")

        # plan cache: the repeat call must be served without re-searching
        again = tuner.gemm_plan(M, N, K, budget, dtype="float64")
        if not (tuner.last_from_cache and tuner.searches == 1
                and again == plan):
            raise AssertionError(
                f"plan cache miss on repeat call ({profile.name}): "
                f"searches={tuner.searches}")
    rows.append({
        "name": "tune_plan_cache",
        "us_per_call": 0.0,
        "derived": (f"{len(rows)} plans produced and cached at "
                    f"{cache_path}; repeat calls hit, 0 re-searches"),
    })
    return rows


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny search space for CI (seconds, asserts a plan "
                         "is produced and cached)")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    print("name,us_per_call,derived")
    for row in rows:
        derived = str(row["derived"]).replace(",", ";")
        print(f"{row['name']},{row['us_per_call']:.1f},{derived}")
    with open(JSON_PATH, "w") as f:
        json.dump(rows, f, indent=2)
    print(f"wrote {JSON_PATH}")


if __name__ == "__main__":
    main()
