"""Reuse-aware scheduling: block-cache + traversal order vs. naive streaming.

The tentpole claim (ISSUE 6): OOC performance is bounded by host<->device
traffic, and the compiler's device-resident block cache — identity block ids,
LRU/Belady eviction, traversal orders that shrink reuse distance — must cut
H2D bytes *measurably* against the seed schedule (``reuse=False``: every
step re-fetches its A and B slices, the pre-cache compiler's behavior).

Asserted on the paper-regime 8192^3 fp64 GEMM (nbuf=3, canned GPU profile):

  * every traversal x eviction-policy schedule moves *no more* H2D bytes
    than the naive baseline, and the best combination cuts them by >= 25 %
    (the smoke shape asserts a strict reduction, same sweep);
  * ``simulate()`` bytes, ``schedule_stats()`` bytes and the bytes counted
    by a real :class:`~repro.core.runtime.ScheduleExecutor` run agree
    *exactly* on an executed shape — the model is the machine;
  * the executed cached schedule is bitwise-identical to the naive one.

Rows carry ``bytes_moved`` and ``hit_rate`` alongside the usual
``us_per_call`` so the perf trajectory tracks traffic, not just makespan;
``run()`` writes ``benchmarks/bench_reuse.json`` (uploaded as a CI
artifact).
"""

from __future__ import annotations

import json
import os

import numpy as np

from repro.core import (EVICT_POLICIES, TRAVERSALS, GemmPartition,
                        ScheduleExecutor, compile_pipeline,
                        gemm_pipeline_spec, schedule_stats, simulate)
from repro.tune import gpu_profile

JSON_PATH = os.path.join(os.path.dirname(__file__), "bench_reuse.json")

# (M, N, K, bm, bn, bytes_per_el, budget, nbuf): FULL is the acceptance
# shape — 8192^3 fp64, an 8x8 block grid, 512 MiB budget, 3-deep buffers
FULL = (8192, 8192, 8192, 1024, 1024, 8, 512 * 2**20, 3)
SMOKE = (2048, 2048, 2048, 512, 512, 4, 32 * 2**20, 3)

# executed-shape consistency check: small enough to run the real executor
# under every traversal x evict combination in CI seconds
EXEC_SHAPE = (256, 256, 192, 64, 64)


def _partition(M, N, K, bm, bn, bpe, budget) -> GemmPartition:
    return GemmPartition(M, N, K, -(-M // bm), -(-N // bn), bm, bn,
                         bpe, budget)


def _naive_schedule(part: GemmPartition, nbuf: int):
    """The seed compiler's behavior: per-step block ids, column-major,
    no cross-step residency — every step pays its full A+B transfer."""
    return compile_pipeline(gemm_pipeline_spec(part, reuse=False),
                            nstreams=2, nbuf=nbuf)


def run(smoke: bool = False):
    hw = gpu_profile().model_for(2)
    M, N, K, bm, bn, bpe, budget, nbuf = SMOKE if smoke else FULL
    part = _partition(M, N, K, bm, bn, bpe, budget)

    naive = simulate(_naive_schedule(part, nbuf), hw)
    rows = [{
        "name": "reuse_gemm_naive",
        "us_per_call": naive.makespan * 1e6,
        "bytes_moved": naive.h2d_bytes,
        "hit_rate": 0.0,
        "derived": f"{M}x{N}x{K} bm={bm} bn={bn} nbuf={nbuf} baseline",
    }]

    best_bytes, best_name = naive.h2d_bytes, "naive"
    for trav in TRAVERSALS:
        for evict in EVICT_POLICIES:
            spec = gemm_pipeline_spec(part, traversal=trav, band=nbuf)
            res = simulate(compile_pipeline(spec, nstreams=2, nbuf=nbuf,
                                            evict=evict), hw)
            if res.h2d_bytes > naive.h2d_bytes:
                raise AssertionError(
                    f"{trav}/{evict} moved MORE H2D bytes than naive: "
                    f"{res.h2d_bytes} vs {naive.h2d_bytes}")
            name = f"reuse_gemm_{trav}_{evict}"
            if res.h2d_bytes < best_bytes:
                best_bytes, best_name = res.h2d_bytes, name
            rows.append({
                "name": name,
                "us_per_call": res.makespan * 1e6,
                "bytes_moved": res.h2d_bytes,
                "hit_rate": res.hit_rate,
                "derived": (f"h2d {res.h2d_bytes / 2**20:.0f}MiB "
                            f"({1 - res.h2d_bytes / naive.h2d_bytes:.0%} "
                            f"saved) hit-rate {res.hit_rate:.2f}"),
            })

    reduction = 1.0 - best_bytes / naive.h2d_bytes
    if best_bytes >= naive.h2d_bytes:
        raise AssertionError(
            "no cached traversal reduced H2D bytes vs the naive schedule")
    if not smoke and reduction < 0.25:
        raise AssertionError(
            f"best traversal ({best_name}) cut H2D by only {reduction:.0%}; "
            f"the acceptance bar is 25%")
    rows.append({
        "name": "reuse_gemm_best",
        "us_per_call": 0.0,
        "bytes_moved": best_bytes,
        "hit_rate": 0.0,
        "derived": f"{best_name}: {reduction:.0%} H2D reduction vs naive",
    })

    rows.append(_executed_consistency_row())
    with open(JSON_PATH, "w") as f:
        json.dump(rows, f, indent=2)
    return rows


def _executed_consistency_row():
    """Execute a small GEMM under every traversal x evict combination and
    require (a) executor-counted H2D bytes == simulate() == schedule_stats()
    and (b) bitwise-identical output vs the naive schedule."""
    M, N, K, bm, bn = EXEC_SHAPE
    part = _partition(M, N, K, bm, bn, 4, 1 << 22)
    rng = np.random.default_rng(0)
    A = rng.standard_normal((M, K)).astype(np.float32)
    B = rng.standard_normal((K, N)).astype(np.float32)
    hw = gpu_profile().model_for(2)

    ref = np.zeros((M, N), np.float32)
    ScheduleExecutor().run(_naive_schedule(part, 3), operands={"A": A, "B": B},
                           outputs={"C": ref}, ctx={"alpha": 1.0, "beta": 0.0})

    checked = 0
    for trav in TRAVERSALS:
        for evict in EVICT_POLICIES:
            sched = compile_pipeline(
                gemm_pipeline_spec(part, traversal=trav, band=3),
                nstreams=2, nbuf=3, evict=evict)
            out = np.zeros((M, N), np.float32)
            ex = ScheduleExecutor()
            ex.run(sched, operands={"A": A, "B": B}, outputs={"C": out},
                   ctx={"alpha": 1.0, "beta": 0.0})
            sim, stats = simulate(sched, hw), schedule_stats(sched)
            if not (ex.last_h2d_bytes == sim.h2d_bytes
                    == stats["h2d_bytes"]):
                raise AssertionError(
                    f"{trav}/{evict}: executor moved {ex.last_h2d_bytes}B "
                    f"but simulate() says {sim.h2d_bytes}B and "
                    f"schedule_stats() says {stats['h2d_bytes']}B")
            if not np.array_equal(out, ref):
                raise AssertionError(
                    f"{trav}/{evict}: cached schedule result differs from "
                    f"the naive schedule (must be bitwise-identical)")
            checked += 1
    return {
        "name": "reuse_gemm_exec_consistency",
        "us_per_call": 0.0,
        "bytes_moved": 0,
        "hit_rate": 0.0,
        "derived": (f"{checked} traversal x evict combos: executor == "
                    f"simulate == stats bytes; outputs bitwise-identical"),
    }


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes for CI (seconds; same asserts minus "
                         "the 25% full-shape bar)")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    print("name,us_per_call,derived")
    for row in rows:
        derived = str(row["derived"]).replace(",", ";")
        print(f"{row['name']},{row['us_per_call']:.1f},{derived}")
    print(f"wrote {JSON_PATH}")


if __name__ == "__main__":
    main()
