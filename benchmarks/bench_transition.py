"""Claim C2 (paper Fig. 5): 0 % performance loss at the in-core ->
out-of-core transition.

Two measurements:
  * engine-model GFLOP/s across an N sweep crossing the memory budget, on
    the K40c-like model the paper measured (the green-line plot of Fig. 5);
  * real wall-clock on CPU for a smaller sweep (absolute numbers are CPU
    throughput; the *shape* across the boundary is the claim).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (build_gemm_schedule, gpu_like, is_in_core, ooc_gemm,
                        plan_gemm_partition, simulate)


def run():
    rows = []
    # ---- engine model sweep (paper's axes: GFLOPs vs N) ----
    K = 4096
    budget = 3 * (6144 * 6144) * 8          # fits N<=6144, OOC above
    hw = gpu_like()
    last_in, first_out = None, None
    for N in (2048, 4096, 6144, 8192, 12288, 16384):
        if is_in_core(N, N, K, budget, 8):
            # single resident DGEMM + one round of transfers
            t = (2 * N * N * K) / hw.flops + (N * K + K * N + 2 * N * N) * 8 / hw.h2d_bw
            mode = "in-core"
            last_in = 2 * N * N * K / t
            gf = last_in
        else:
            part = plan_gemm_partition(N, N, K, budget, 8)
            res = simulate(build_gemm_schedule(part, 2, 2), hw)
            gf = res.effective_flops
            if first_out is None:
                first_out = gf
            mode = f"OOC h={part.h} w={part.w}"
        rows.append({"name": f"transition_model_N{N}",
                     "us_per_call": 0.0,
                     "derived": f"{gf/1e9:.1f} GFLOP/s ({mode})"})
    delta = (first_out - last_in) / last_in * 100.0
    rows.append({"name": "transition_loss",
                 "us_per_call": 0.0,
                 "derived": f"throughput change at in->out boundary: "
                            f"{delta:+.1f}% (no drop; paper: 0% loss — "
                            f"the pipeline hides transfers that the "
                            f"in-core path pays serially)"})

    # ---- real wall-clock sweep on CPU ----
    rng = np.random.default_rng(0)
    Kc = 256
    budget_c = 3 * (512 * 512) * 4
    for N in (256, 512, 768, 1024):
        A = rng.standard_normal((N, Kc)).astype(np.float32)
        B = rng.standard_normal((Kc, N)).astype(np.float32)
        C = np.zeros((N, N), np.float32)
        ooc_gemm(A, B, C, 1.0, 0.0, budget_bytes=budget_c, backend="host")
        t0 = time.perf_counter()
        for _ in range(3):
            ooc_gemm(A, B, C, 1.0, 0.0, budget_bytes=budget_c,
                     backend="host")
        dt = (time.perf_counter() - t0) / 3
        mode = "in-core" if is_in_core(N, N, Kc, budget_c, 4) else "OOC"
        rows.append({"name": f"transition_cpu_N{N}",
                     "us_per_call": dt * 1e6,
                     "derived": f"{2*N*N*Kc/dt/1e9:.2f} GFLOP/s ({mode})"})
    return rows
