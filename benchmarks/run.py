"""Benchmark harness — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV:
  bench_overhead    — claim C1  (<=10 % abstraction overhead; paper §VI)
  bench_transition  — claim C2  (0 % loss at the in/out-of-core boundary;
                                 Fig. 5 green line)
  bench_pipeline    — claims C3+C5 (vs CUBLAS-XT-style vendor schedule;
                                 stream-width vs hardware; Fig. 5a/5b/5c)
  bench_loc         — claim C4  (75 % LOC reduction)
  bench_roofline    — §Roofline table from the dry-run artifacts
  bench_validate    — validate_schedule scaling guard (linear-ish)
  bench_simulate    — simulate() ready-queue guard + reference equivalence
  bench_tune        — autotuner: tuned vs default makespans (C5 selection)
  bench_hybrid      — hybrid co-scheduling: balanced split vs best single
                      device (beyond paper; DESIGN.md §7)
  bench_reuse       — block cache + traversal order: H2D bytes-moved and
                      hit-rate vs the naive schedule (DESIGN.md §9); rows
                      land in benchmarks/bench_reuse.json so the perf
                      trajectory tracks traffic, not just makespan
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (bench_hybrid, bench_loc, bench_overhead,
                            bench_pipeline, bench_reuse, bench_roofline,
                            bench_simulate, bench_transition, bench_tune,
                            bench_validate)

    print("name,us_per_call,derived")
    failures = 0
    for mod in (bench_overhead, bench_transition, bench_pipeline,
                bench_loc, bench_roofline, bench_validate, bench_simulate,
                bench_tune, bench_hybrid, bench_reuse):
        try:
            for row in mod.run():
                derived = str(row["derived"]).replace(",", ";")
                print(f"{row['name']},{row['us_per_call']:.1f},{derived}")
        except Exception as e:
            failures += 1
            print(f"{mod.__name__},0.0,ERROR {type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} benchmark module(s) failed")


if __name__ == "__main__":
    main()
