"""Benchmark harness — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV:
  bench_overhead    — claim C1  (<=10 % abstraction overhead; paper §VI)
                      + the observability guard (disabled obs hooks <2 %)
  bench_transition  — claim C2  (0 % loss at the in/out-of-core boundary;
                                 Fig. 5 green line)
  bench_pipeline    — claims C3+C5 (vs CUBLAS-XT-style vendor schedule;
                                 stream-width vs hardware; Fig. 5a/5b/5c)
  bench_loc         — claim C4  (75 % LOC reduction)
  bench_roofline    — §Roofline table from the dry-run artifacts
  bench_validate    — validate_schedule scaling guard (linear-ish)
  bench_simulate    — simulate() ready-queue guard + reference equivalence
  bench_tune        — autotuner: tuned vs default makespans (C5 selection)
  bench_hybrid      — hybrid co-scheduling: balanced split vs best single
                      device (beyond paper; DESIGN.md §7)
  bench_reuse       — block cache + traversal order: H2D bytes-moved and
                      hit-rate vs the naive schedule (DESIGN.md §9); rows
                      land in benchmarks/bench_reuse.json so the perf
                      trajectory tracks traffic, not just makespan
  bench_fault       — resilience cost: simulated recovery overhead guard
                      (<10 % at a 1 % fault rate) plus an executed pinned
                      fault corpus recovering bitwise (DESIGN.md §12)
  bench_exec        — concurrent executor guards: engine-overlap ratio
                      (busy/makespan > 1.0 in mode="concurrent") and the
                      ExecutablePlan cache's dispatch-cost reduction
                      (DESIGN.md §13)

Each module additionally runs with the process metric registry enabled
(DESIGN.md §10) and, when it recorded anything, leaves a
``benchmarks/<module>.metrics.json`` sidecar next to the ``bench_*.json``
score files — the exact byte/op accounting behind each number, uploaded as
a CI artifact and renderable via ``scripts/run_report.py --input``.

Caveat: timed sections therefore run with metrics ON, which is fine — the
publish path is per-run and bench_overhead's ``obs_disabled_overhead`` row
separately guards the disabled cost.
"""

from __future__ import annotations

import json
import os
import sys
import traceback


def _write_sidecar(obs, mod_name: str) -> None:
    """Snapshot the registry into ``benchmarks/<module>.metrics.json``
    (skipped when the module recorded nothing)."""
    snap = obs.snapshot()
    if not snap["metrics"] and not snap["drift"]["records"]:
        return
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        f"{mod_name}.metrics.json")
    with open(path, "w") as f:
        json.dump(snap, f, indent=1, sort_keys=True)


def main() -> None:
    from benchmarks import (bench_exec, bench_fault, bench_hybrid,
                            bench_loc, bench_overhead, bench_pipeline,
                            bench_reuse, bench_roofline, bench_simulate,
                            bench_transition, bench_tune, bench_validate)
    from repro.obs import get_observability

    obs = get_observability()
    print("name,us_per_call,derived")
    failures = 0
    for mod in (bench_overhead, bench_transition, bench_pipeline,
                bench_loc, bench_roofline, bench_validate, bench_simulate,
                bench_tune, bench_hybrid, bench_reuse, bench_fault,
                bench_exec):
        mod_name = mod.__name__.rsplit(".", 1)[-1]
        obs.reset()
        obs.enable(metrics=True)
        try:
            for row in mod.run():
                derived = str(row["derived"]).replace(",", ";")
                print(f"{row['name']},{row['us_per_call']:.1f},{derived}")
            _write_sidecar(obs, mod_name)
        except Exception as e:
            failures += 1
            print(f"{mod.__name__},0.0,ERROR {type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
        finally:
            obs.reset()
    if failures:
        raise SystemExit(f"{failures} benchmark module(s) failed")


if __name__ == "__main__":
    main()
