"""Claims C3 + C5 (paper Fig. 5a/5c + §VI Phi discussion).

C3: overlapped 2-stream pipeline vs. CUBLAS-XT-style vendor schedule
    (non-overlapping, fixed small tile, B re-sent per tile) — >= 2.3x on
    K40c-like, ~4x on P100-like engine models.
C5: pipeline width is hardware-dependent — buffer-depth sweep under
    GPU-like vs Phi-like (shared transfer engine, thread-split 0.76x) vs
    TPU-v5e tiers.
"""

from __future__ import annotations

from repro.core import (build_gemm_schedule, build_vendor_schedule, gpu_like,
                        phi_like, plan_gemm_partition, simulate, tpu_v5e_ici,
                        tpu_v5e_vmem, HardwareModel)


def p100_like():
    return gpu_like(flops=3.9e12, pcie=12.5e9)


def run(smoke: bool = False):
    rows = []
    # ---- C3: lib vs vendor across N (Fig. 5a K40c / 5c P100) ----
    K = 8192
    sizes = (16384,) if smoke else (16384, 32768, 46080)
    for label, hw, peak in (("k40c", gpu_like(), 1.16e12),
                            ("p100", p100_like(), 3.9e12)):
        for N in sizes:
            budget = 3 * (8192 * 8192) * 8
            part = plan_gemm_partition(N, N, K, budget, 8)
            lib = simulate(build_gemm_schedule(part, 2, 2), hw)
            ven = simulate(build_vendor_schedule(part, tile=512), hw)
            rows.append({
                "name": f"c3_{label}_N{N}",
                "us_per_call": lib.makespan * 1e6,
                "derived": (f"lib={lib.effective_flops/1e12:.2f}TF "
                            f"({lib.effective_flops/peak*100:.0f}%pk) "
                            f"vendor={ven.effective_flops/1e12:.2f}TF "
                            f"speedup={ven.makespan/lib.makespan:.2f}x "
                            f"(paper: >=2.3x K40c, ~4x P100)"),
            })

    # ---- C5: buffer/stream sweep per hardware ----
    part = plan_gemm_partition(16384, 16384, 8192, 3 * 8192 * 8192 * 8, 8)
    for mk, name in ((lambda ns: gpu_like(), "gpu"),
                     (lambda ns: phi_like(nstreams=ns), "phi"),
                     (lambda ns: tpu_v5e_vmem(), "tpu_vmem")):
        for ns, nbuf in ((1, 1), (1, 2), (2, 2), (2, 4)):
            hw = mk(ns)
            res = simulate(build_gemm_schedule(part, ns, nbuf), hw)
            rows.append({
                "name": f"c5_{name}_s{ns}b{nbuf}",
                "us_per_call": res.makespan * 1e6,
                "derived": (f"{res.effective_flops/1e12:.2f} TFLOP/s "
                            f"exec_util={res.utilization('exec'):.2f}"),
            })

    # ---- TPU tiers: where does the paper's pipeline land on v5e ----
    part_v = plan_gemm_partition(8192, 8192, 8192, 64 * 2**20, 2)
    res = simulate(build_gemm_schedule(part_v, 2, 2), tpu_v5e_vmem())
    rows.append({
        "name": "tpu_vmem_tier",
        "us_per_call": res.makespan * 1e6,
        "derived": (f"{res.effective_flops/1e12:.1f} TF "
                    f"({res.effective_flops/197e12*100:.0f}% of v5e peak), "
                    f"DMA hidden: in_util={res.utilization('in'):.2f}"),
    })
    res = simulate(build_gemm_schedule(part_v, 2, 2), tpu_v5e_ici())
    rows.append({
        "name": "tpu_ici_tier",
        "us_per_call": res.makespan * 1e6,
        "derived": (f"{res.effective_flops/1e12:.1f} TF — ICI-streamed "
                    f"blocks (SUMMA tier); in_util="
                    f"{res.utilization('in'):.2f} "
                    f"exec_util={res.utilization('exec'):.2f}"),
    })

    # ---- executed overlap: the C3 claim on the real host executor ----
    # Everything above is the engine model; this row runs the same 2-stream
    # schedule shape through ScheduleExecutor in both modes (DESIGN.md §13)
    # so the overlap the simulator promises is also demonstrated in wall
    # clock.  bench_exec.py owns the hard guard; here it is reporting.
    import time

    import numpy as np

    from repro.core import ScheduleExecutor

    m, n, k = 1024, 1024, 768
    rng = np.random.default_rng(0)
    A = rng.standard_normal((m, k)).astype(np.float32)
    B = rng.standard_normal((k, n)).astype(np.float32)
    part_x = plan_gemm_partition(m, n, k, (m * k + k * n + m * n) * 4 // 4,
                                 4, nbuf=2, nstreams=2)
    sched_x = build_gemm_schedule(part_x, nstreams=2, nbuf=2)
    walls = {}
    for mode in ("issue_order", "concurrent"):
        ex = ScheduleExecutor(mode=mode)
        best = float("inf")
        for rep in range(3):   # rep 0 warms the jit cache
            C = np.zeros((m, n), dtype=np.float32)
            t0 = time.perf_counter()
            ex.run(sched_x, {"A": A, "B": B}, {"C": C},
                   {"alpha": 1.0, "beta": 0.0})
            if rep:
                best = min(best, time.perf_counter() - t0)
        walls[mode] = best
    rows.append({
        "name": f"c3_executed_{m}x{n}x{k}",
        "us_per_call": walls["concurrent"] * 1e6,
        "derived": (f"concurrent={walls['concurrent']*1e3:.0f}ms "
                    f"serial={walls['issue_order']*1e3:.0f}ms "
                    f"wall_speedup="
                    f"{walls['issue_order']/walls['concurrent']:.2f}x "
                    f"(host threads; guard lives in bench_exec)"),
    })
    return rows


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced problem set for CI sanity (CPU, seconds)")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    print("name,us_per_call,derived")
    for row in rows:
        derived = str(row["derived"]).replace(",", ";")
        print(f"{row['name']},{row['us_per_call']:.1f},{derived}")
    # smoke sanity: the C3 overlap claim must hold in the engine model
    c3 = [r for r in rows if r["name"].startswith("c3_")]
    assert c3, "no C3 rows produced"


if __name__ == "__main__":
    main()
