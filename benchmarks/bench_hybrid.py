"""Hybrid co-scheduling: balanced split vs. best single device (engine model).

The hybrid subsystem's reason to exist: under the paper's own canned
profiles (K40c-like GPU + Xeon-Phi-like, the HCLServer testbed pair) a
profile-proportionally split GEMM must finish *strictly* earlier than the
best single-device tuned plan — otherwise co-execution is noise.  This
bench asserts, for an 8192^3 double-precision GEMM:

  * ``simulate_hybrid()`` of the balanced ``HybridPlan`` has strictly lower
    makespan than the best single-device ``tune.search`` plan;
  * the per-device predicted finish times agree within the balancer
    tolerance (the functional-performance-model fixed point was reached);
  * each device keeps its C5 stream selection inside the hybrid plan
    (gpu-like 2 streams, phi-like 1).

``--smoke`` shrinks the search space for CI; either way results land in
``benchmarks/bench_hybrid.json`` (uploaded as a CI artifact alongside the
tuner's).
"""

from __future__ import annotations

import json
import os

from repro.hybrid import DeviceSpec, plan_hybrid_gemm, simulate_hybrid
from repro.tune import gpu_profile, phi_profile
from repro.tune.search import search_gemm

JSON_PATH = os.path.join(os.path.dirname(__file__), "bench_hybrid.json")

# paper §VI regime: compute-dominated large square DGEMM (full / 6 budget),
# the same shape bench_tune.py ranks per device — here split across both.
M, N, K, BPE = 8192, 8192, 8192, 8
TOLERANCE = 0.05

EXPECT_STREAMS = {"gpu-like": 2, "phi-like": 1}


def run(smoke: bool = False):
    rows = []
    budget = (M * K + K * N + M * N) * BPE // 6
    opts = dict(nbuf_options=(1, 2) if smoke else (1, 2, 3),
                max_steps=128 if smoke else 2048)
    devices = [DeviceSpec("gpu0", gpu_profile(), budget),
               DeviceSpec("phi0", phi_profile(), budget)]

    singles = {}
    for dev in devices:
        plan = search_gemm(M, N, K, dev.budget_bytes, dev.profile,
                           dtype="float64", fingerprint=f"bench-{dev.name}",
                           **opts)
        singles[dev.name] = plan.makespan
        rows.append({
            "name": f"hybrid_single_{dev.name}",
            "us_per_call": plan.makespan * 1e6,
            "derived": (f"{dev.profile.name} alone: s{plan.nstreams}"
                        f"b{plan.nbuf}, {plan.param('h')}x{plan.param('w')}"
                        f" blocks"),
        })
    best_single = min(singles.values())
    best_name = min(singles, key=singles.get)

    hplan = plan_hybrid_gemm(M, N, K, devices, dtype="float64",
                             tolerance=TOLERANCE, **opts)
    sim = simulate_hybrid(hplan)
    bal = hplan.balance
    shares = {dp.device.name: dp.length for dp in hplan.device_plans}
    rows.append({
        "name": "hybrid_balanced",
        "us_per_call": sim.makespan * 1e6,
        "derived": (f"split {shares} in {bal.iterations} iters "
                    f"(spread {bal.spread:.3f}); "
                    f"{best_single / sim.makespan:.2f}x vs best single "
                    f"({best_name})"),
    })

    if not (sim.makespan < best_single):
        raise AssertionError(
            f"hybrid makespan {sim.makespan}s does not beat best single "
            f"device {best_name} at {best_single}s")
    if bal.spread > TOLERANCE:
        raise AssertionError(
            f"per-device predicted finish times disagree beyond tolerance: "
            f"spread {bal.spread} > {TOLERANCE}")
    for dp in hplan.device_plans:
        want = EXPECT_STREAMS.get(dp.device.profile.name)
        if want is not None and dp.plan.nstreams != want:
            raise AssertionError(
                f"C5 regression inside hybrid plan: {dp.device.name} "
                f"picked nstreams={dp.plan.nstreams}, paper says {want}")
    # simulate_hybrid re-derives exactly what the balance loop predicted
    for dp, got in zip(hplan.device_plans, sim.device_makespans):
        if abs(got - dp.plan.makespan) > 1e-12:
            raise AssertionError(
                f"simulate_hybrid disagrees with tuned plan on "
                f"{dp.device.name}: {got} vs {dp.plan.makespan}")
    return rows


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny search space for CI (seconds; same asserts)")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    print("name,us_per_call,derived")
    for row in rows:
        derived = str(row["derived"]).replace(",", ";")
        print(f"{row['name']},{row['us_per_call']:.1f},{derived}")
    with open(JSON_PATH, "w") as f:
        json.dump(rows, f, indent=2)
    print(f"wrote {JSON_PATH}")


if __name__ == "__main__":
    main()
