"""Guard: the heap-based ``simulate()`` stays fast and agrees with its spec.

The autotuner ranks every candidate configuration with ``simulate()`` as the
cost oracle, so large tuning sweeps put the simulator on the hot path.  The
lazy-key heap ready queue must (a) produce span-for-span identical results to
``simulate_reference`` (the original per-pick head scan, kept as the
executable specification of the greedy rule) and (b) simulate a 64x64-block
GEMM schedule (~16k ops) well under ``BUDGET_S`` regardless of stream count.
Hard-fails on either regression.

Writes ``benchmarks/bench_simulate.json`` (CI uploads it as an artifact).
"""

from __future__ import annotations

import json
import os
import time

from repro.core import (build_gemm_schedule, gpu_like, phi_like, simulate,
                        simulate_reference, tpu_v5e_vmem)
from repro.core.partitioner import GemmPartition

BUDGET_S = 5.0
JSON_PATH = os.path.join(os.path.dirname(__file__), "bench_simulate.json")


def _grid(h: int, w: int) -> GemmPartition:
    return GemmPartition(M=h * 128, N=w * 128, K=256, h=h, w=w,
                         bm=128, bn=128, bytes_per_el=4, budget=64 * 2**20)


def run():
    rows = []

    # (a) equivalence: heap == scan, span for span, across hw topologies.
    part = _grid(8, 8)
    for hw in (gpu_like(), phi_like(nstreams=1), phi_like(nstreams=2),
               tpu_v5e_vmem()):
        for ns, nb in ((1, 1), (2, 2), (2, 3), (4, 4)):
            sched = build_gemm_schedule(part, ns, nb)
            a = simulate(sched, hw)
            b = simulate_reference(sched, hw)
            if (abs(a.makespan - b.makespan) > 1e-12
                    or a.busy != b.busy
                    or sorted(a.op_spans) != sorted(b.op_spans)):
                raise AssertionError(
                    f"simulate() diverged from simulate_reference on "
                    f"{hw.name} ns={ns} nbuf={nb}: "
                    f"{a.makespan} vs {b.makespan}"
                )
    rows.append({
        "name": "simulate_equivalence",
        "us_per_call": 0.0,
        "derived": "heap == scan (span-for-span) on 16 schedule x hw combos",
    })

    # (b) scaling: the ISSUE's 64x64-block grid, increasing stream counts.
    part = _grid(64, 64)
    for ns in (2, 4, 8):
        sched = build_gemm_schedule(part, ns, max(ns, 2))
        t0 = time.perf_counter()
        res = simulate(sched, gpu_like())
        dt = time.perf_counter() - t0
        t0 = time.perf_counter()
        simulate_reference(sched, gpu_like())
        dt_ref = time.perf_counter() - t0
        n = len(sched.ops)
        rows.append({
            "name": f"simulate_64x64_s{ns}",
            "us_per_call": dt * 1e6,
            "derived": (f"{n} ops in {dt*1e3:.0f}ms "
                        f"({n/max(dt,1e-12)/1e3:.0f}k ops/s; "
                        f"scan {dt_ref*1e3:.0f}ms) "
                        f"makespan={res.makespan*1e3:.1f}ms"),
        })
        if dt > BUDGET_S:
            raise AssertionError(
                f"simulate took {dt:.1f}s on a 64x64 grid with {ns} streams "
                f"({n} ops) — budget is {BUDGET_S}s; the ready-queue "
                f"regressed"
            )
    return rows


if __name__ == "__main__":
    rows = run()
    for row in rows:
        print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
    with open(JSON_PATH, "w") as f:
        json.dump(rows, f, indent=2)
    print(f"wrote {JSON_PATH}")
