"""Guard: ``validate_schedule`` stays linear-ish on large block grids.

The validator proves the full happens-before relation (the paper's five event
sets do their job under ANY legal interleaving).  A naive transitive-
reachability check is O(n^2) in ops and melts on production-scale grids; the
frontier/vector-clock implementation in ``core/streams.py`` must validate a
64x64-block GEMM schedule (~20k ops) in seconds.  This bench both reports the
rate and hard-fails if validation regresses past ``BUDGET_S``.
"""

from __future__ import annotations

import time

from repro.core import build_gemm_schedule, validate_schedule
from repro.core.partitioner import GemmPartition

# 64x64-block grid, the ISSUE's sizing: far beyond anything tests touch.
BUDGET_S = 10.0


def run():
    rows = []
    for h, w in ((16, 16), (32, 32), (64, 64)):
        part = GemmPartition(M=h * 128, N=w * 128, K=256, h=h, w=w,
                             bm=128, bn=128, bytes_per_el=4,
                             budget=64 * 2**20)
        sched = build_gemm_schedule(part, nstreams=2, nbuf=2)
        t0 = time.perf_counter()
        validate_schedule(sched)
        dt = time.perf_counter() - t0
        n = len(sched.ops)
        rows.append({
            "name": f"validate_{h}x{w}",
            "us_per_call": dt * 1e6,
            "derived": f"{n} ops in {dt*1e3:.1f}ms "
                       f"({n/max(dt,1e-12)/1e3:.0f}k ops/s)",
        })
        if h == 64 and dt > BUDGET_S:
            raise AssertionError(
                f"validate_schedule took {dt:.1f}s on a {h}x{w} grid "
                f"({n} ops) — budget is {BUDGET_S}s; the O(n^2) check is back"
            )
    return rows


if __name__ == "__main__":
    for row in run():
        print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
