"""Guards for the concurrent event-driven executor (DESIGN.md §13).

Two hard-fail rows:

  * ``exec_overlap_ratio`` — run one GEMM schedule in ``mode="concurrent"``
    with span recording and compute busy/makespan (total engine-busy time
    over wall-clock).  With per-engine worker threads the H2D engine copies
    block *i+1* while the compute engines contract block *i*, so the ratio
    must exceed 1.0; the serial issue-order ratio (~1.0 by construction) is
    reported alongside for contrast.  This is the host-side analogue of the
    paper's Fig. 6 overlap claim.
  * ``exec_dispatch_cost`` — per-run dispatch setup must be cheap: a cached
    :func:`compile_executable` hit (the steady-state path every repeated
    ``run()`` takes) must be at least ``DISPATCH_SPEEDUP_MIN`` times faster
    than a cold compile, or the plan cache has stopped paying for itself.

``--smoke`` shrinks the problem for CI (same guards, smaller wall time).
Writes ``benchmarks/bench_exec.json`` (committed: ``scripts/check_drift.py``
uses it as the drift baseline; CI re-uploads the fresh copy as an artifact).
"""

from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

from repro.core import (
    ScheduleExecutor,
    build_gemm_schedule,
    compile_executable,
    plan_gemm_partition,
)
from repro.core.exec_plan import _CACHE_ATTR, reset_plan_cache_stats

JSON_PATH = os.path.join(os.path.dirname(__file__), "bench_exec.json")
DISPATCH_SPEEDUP_MIN = 2.0


def _spans_ratio(spans) -> tuple[float, float]:
    """(busy, makespan) from recorded wall-clock spans."""
    starts = [t0 for _, _, t0, _ in spans]
    ends = [t1 for _, _, _, t1 in spans]
    busy = sum(t1 - t0 for _, _, t0, t1 in spans)
    return busy, max(ends) - min(starts)


def _overlap_row(M: int, N: int, K: int, nstreams: int) -> dict:
    rng = np.random.default_rng(0)
    A = rng.standard_normal((M, K)).astype(np.float32)
    B = rng.standard_normal((K, N)).astype(np.float32)
    C = np.zeros((M, N), dtype=np.float32)
    budget = (A.nbytes + B.nbytes + C.nbytes) // 4
    part = plan_gemm_partition(M, N, K, budget, 4, nbuf=2,
                               nstreams=nstreams)
    sched = build_gemm_schedule(part, nstreams=nstreams, nbuf=2)
    ctx = {"alpha": 1.0, "beta": 0.0}

    ratios, makespans = {}, {}
    for mode in ("issue_order", "concurrent"):
        ex = ScheduleExecutor(mode=mode, record_spans=True)
        best, best_mk = 0.0, float("inf")
        # warmup once (jit), then keep the best of 3 measured runs: overlap
        # is capped by the schedule, so max (not min) is the stable statistic
        for rep in range(4):
            ex.run(sched, {"A": A, "B": B}, {"C": np.array(C)}, ctx)
            if rep == 0:
                continue
            busy, makespan = _spans_ratio(ex.last_spans)
            best = max(best, busy / makespan)
            best_mk = min(best_mk, makespan)
        ratios[mode], makespans[mode] = best, best_mk

    assert ratios["concurrent"] > 1.0, (
        f"concurrent executor shows no overlap: busy/makespan = "
        f"{ratios['concurrent']:.3f} on {M}x{N}x{K} s{nstreams} "
        f"(serial = {ratios['issue_order']:.3f}); engine threads are "
        f"serializing")
    return {
        "name": f"exec_overlap_ratio_{M}x{N}x{K}_s{nstreams}",
        "us_per_call": makespans["concurrent"] * 1e6,
        "derived": f"overlap concurrent={ratios['concurrent']:.2f}x "
                   f"serial={ratios['issue_order']:.2f}x "
                   f"makespan={makespans['concurrent']*1e3:.0f}ms "
                   f"({len(sched.ops)} ops; guard: concurrent > 1.0)",
    }


def _dispatch_row(M: int, N: int, K: int) -> dict:
    part = plan_gemm_partition(M, N, K, (M * K + K * N + M * N) * 4 // 4, 4)
    sched = build_gemm_schedule(part, nstreams=2, nbuf=2)
    reps = 50

    t_cold = 0.0
    for _ in range(reps):
        if hasattr(sched, _CACHE_ATTR):
            delattr(sched, _CACHE_ATTR)
        t0 = time.perf_counter()
        compile_executable(sched)
        t_cold += time.perf_counter() - t0
    t_cold /= reps

    reset_plan_cache_stats()
    t0 = time.perf_counter()
    for _ in range(reps):
        compile_executable(sched)
    t_warm = (time.perf_counter() - t0) / reps
    from repro.core import plan_cache_stats
    assert plan_cache_stats()["hits"] >= reps

    speedup = t_cold / t_warm
    assert speedup >= DISPATCH_SPEEDUP_MIN, (
        f"plan cache speedup {speedup:.1f}x < {DISPATCH_SPEEDUP_MIN}x "
        f"(cold={t_cold*1e6:.1f}us warm={t_warm*1e6:.2f}us, "
        f"{len(sched.ops)} ops); per-run dispatch setup regressed")
    return {
        "name": f"exec_dispatch_cost_{M}x{N}x{K}",
        "us_per_call": t_warm * 1e6,
        "derived": f"cold={t_cold*1e6:.1f}us warm={t_warm*1e6:.2f}us "
                   f"speedup={speedup:.0f}x ({len(sched.ops)} ops; "
                   f"guard: >={DISPATCH_SPEEDUP_MIN:.0f}x)",
    }


def run(smoke: bool = False):
    if smoke:
        overlap_shape, dispatch_shape = (1024, 1024, 768), (512, 512, 384)
    else:
        overlap_shape, dispatch_shape = (2048, 2048, 1024), (1536, 1024, 512)
    return [
        _overlap_row(*overlap_shape, nstreams=2),
        _dispatch_row(*dispatch_shape),
    ]


if __name__ == "__main__":
    rows = run(smoke="--smoke" in sys.argv)
    for row in rows:
        print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
    with open(JSON_PATH, "w") as f:
        json.dump(rows, f, indent=2)
    print(f"wrote {JSON_PATH}")
