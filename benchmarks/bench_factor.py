"""Factorization pipelines: lookahead vs. the sequential per-panel loop.

The factor pipeline's reason to exist (ISSUE 4): under the paper's canned
GPU profile, the lookahead schedule — panel ``k+1`` transferring and
factoring while trailing update ``k`` still streams — must finish
*strictly* earlier on simulated makespan than the sequential per-panel loop
(``lookahead=0``: each panel waits for the previous trailing update to
drain, which is exactly what the pre-pipeline wrapper executed).  Both
schedules move identical bytes and flops; only the event graph differs, so
any win is pure overlap.

Asserted per kind (cholesky, lu):

  * ``simulate(lookahead=1)`` < ``simulate(lookahead=0)`` (strict);
  * identical H2D/D2H bytes and flops across the two schedules;
  * the autotuner's ``search_factor`` pick is never slower than either.

``--smoke`` shrinks the problem for CI; results land in
``benchmarks/bench_factor.json`` (uploaded as a CI artifact).
"""

from __future__ import annotations

import json
import os

from repro.core import (compile_factor_pipeline, factor_pipeline_spec,
                        schedule_stats, simulate)
from repro.tune import gpu_profile, search_factor

JSON_PATH = os.path.join(os.path.dirname(__file__), "bench_factor.json")

# paper §VI regime: compute-dominated fp64 factorizations on the K40c-like
# profile; the smoke shape keeps several trailing block columns per stage so
# lookahead has a stream to hide behind
FULL = {"cholesky": (8192, 512, 256 * 2**20, 8),
        "lu": (8192, 1024, 512 * 2**20, 8)}
SMOKE = {"cholesky": (4096, 256, 64 * 2**20, 4),
         "lu": (4096, 256, 64 * 2**20, 4)}


def run(smoke: bool = False):
    profile = gpu_profile()
    hw2 = profile.model_for(2)
    shapes = SMOKE if smoke else FULL
    rows = []
    for kind, (n, panel, budget, bpe) in shapes.items():
        ms = {}
        stats = {}
        for la in (0, 1):
            spec = factor_pipeline_spec(n, panel, budget, bpe, kind=kind,
                                        lookahead=la)
            sched = compile_factor_pipeline(spec, nstreams=2, nbuf=2)
            ms[la] = simulate(sched, hw2).makespan
            stats[la] = schedule_stats(sched)
            rows.append({
                "name": f"factor_{kind}_la{la}",
                "us_per_call": ms[la] * 1e6,
                "derived": (f"n={n} panel={spec.panel} bm={spec.bm} "
                            f"bn={spec.bn} ops={stats[la]['n_ops']}"),
            })
        if not (ms[1] < ms[0]):
            raise AssertionError(
                f"{kind}: lookahead makespan {ms[1]}s does not beat the "
                f"sequential per-panel loop at {ms[0]}s")
        for key in ("h2d_bytes", "d2h_bytes", "flops"):
            if stats[0][key] != stats[1][key]:
                raise AssertionError(
                    f"{kind}: lookahead changed {key}: "
                    f"{stats[0][key]} vs {stats[1][key]} — it may only "
                    f"reorder, never re-transfer")
        plan = search_factor(kind, n, panel, budget, profile,
                             dtype="float64" if bpe == 8 else "float32",
                             fingerprint="bench",
                             max_steps=1024 if smoke else 4096)
        rows.append({
            "name": f"factor_{kind}_tuned",
            "us_per_call": plan.makespan * 1e6,
            "derived": (f"s{plan.nstreams}b{plan.nbuf} "
                        f"panel={plan.param('panel')} "
                        f"lookahead={plan.param('lookahead')}; "
                        f"{ms[0] / plan.makespan:.2f}x vs sequential"),
        })
        if plan.makespan > min(ms.values()) + 1e-12:
            raise AssertionError(
                f"{kind}: tuned plan ({plan.makespan}s) lost to a default "
                f"config ({min(ms.values())}s) under the same oracle")
    return rows


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes for CI (seconds; same asserts)")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    print("name,us_per_call,derived")
    for row in rows:
        derived = str(row["derived"]).replace(",", ";")
        print(f"{row['name']},{row['us_per_call']:.1f},{derived}")
    with open(JSON_PATH, "w") as f:
        json.dump(rows, f, indent=2)
    print(f"wrote {JSON_PATH}")


if __name__ == "__main__":
    main()
