"""Claim C4: 75 % LOC reduction for OOC kernels written against the API.

Counts non-blank, non-comment, non-docstring lines of:
  numerator   — examples/mmooc_via_api.py ``mmooc()`` (unified API), and the
                paper-Fig.2-equivalent driver in repro.core.oocgemm.
  denominator — the three hand-written backend implementations in
                benchmarks/direct_impls.py (host / vmem / mesh tiers); the
                host one hand-writes partitioning + the op list but executes
                on the shared ScheduleExecutor, so the count measures the
                planning/sync code the API saves, not interpreter LOC.
"""

from __future__ import annotations

import ast
import inspect
import os


def _code_lines_of(obj) -> int:
    src = inspect.getsource(obj)
    tree = ast.parse(src)
    doc_lines = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef, ast.Module)):
            if (node.body and isinstance(node.body[0], ast.Expr)
                    and isinstance(node.body[0].value, ast.Constant)
                    and isinstance(node.body[0].value.value, str)):
                d = node.body[0]
                doc_lines.update(range(d.lineno, d.end_lineno + 1))
    n = 0
    for i, line in enumerate(src.splitlines(), start=1):
        t = line.strip()
        if t and not t.startswith("#") and i not in doc_lines:
            n += 1
    return n


def run():
    from benchmarks import direct_impls
    from examples.mmooc_via_api import mmooc

    api_loc = _code_lines_of(mmooc)
    direct = {
        "host": _code_lines_of(direct_impls.direct_host_ooc_gemm),
        "vmem": _code_lines_of(direct_impls.direct_vmem_ooc_gemm),
        "mesh": _code_lines_of(direct_impls.direct_mesh_ooc_gemm),
    }
    total_direct = sum(direct.values())
    # the paper compares one API implementation vs per-device rewrites
    reduction = (1 - api_loc * 3 / (3 * total_direct / 1)) * 100
    reduction = (1 - (api_loc) / (total_direct / 1)) * 100
    rows = [{
        "name": "loc_api_mmooc",
        "us_per_call": 0.0,
        "derived": f"{api_loc} lines (runs on all 3 tiers)",
    }]
    for k, v in direct.items():
        rows.append({"name": f"loc_direct_{k}", "us_per_call": 0.0,
                     "derived": f"{v} lines (single tier)"})
    rows.append({
        "name": "loc_reduction",
        "us_per_call": 0.0,
        "derived": (f"{api_loc} vs {total_direct} lines = "
                    f"{reduction:.0f}% reduction (paper: 75%)"),
    })
    return rows
