"""Hand-written backend-specific OOC DGEMM implementations (no unified API).

These are the LOC denominator for claim C4 (75 % code reduction) and the
"direct" side of the abstraction-overhead benchmark (C1): each re-implements
the out-of-core pipeline for ONE memory tier, managing its own partitioning,
buffers and ordering — exactly the duplication the paper's unified interface
eliminates (its comparison points were ZZGemmOOC / XeonPhiOOC / an OpenCL
port; ours are the three TPU tiers).

What "direct" means per tier: the host path hand-derives its partition and
op ordering (no partitioner, no PipelineSpec, no event sets) but executes on
the engine's shared ScheduleExecutor — the repo keeps exactly one schedule
interpreter, so C1/C4 measure the *planning/abstraction* layers, not a
duplicated interpreter; the vmem and mesh paths are fully standalone.

All three compute C = alpha*A@B + beta*C and are cross-checked against the
oracle in the benchmark harness.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np


# ===========================================================================
# 1. host-tier direct implementation (HBM streaming, manual double buffer)
# ===========================================================================
def direct_host_ooc_gemm(A, B, C, alpha, beta, budget_bytes):
    """Hand-rolled host-driven block streaming: inline partitioning and a
    hand-built serial op list — no partitioner, no PipelineSpec, no event
    sets.  Execution dispatches through the shared ScheduleExecutor (the one
    schedule interpreter in the engine); what stays "direct" here is
    everything the library would otherwise derive."""
    from repro.core.runtime import ScheduleExecutor
    from repro.core.streams import (BlockRef, Device, Op, OpKind, Schedule,
                                    SliceRef, StreamFactory)

    A = np.asarray(A)
    B = np.asarray(B)
    out = np.array(C, copy=True)
    M, K = A.shape
    _, N = B.shape
    bpe = A.dtype.itemsize

    # inline partitioning: shrink block dims until 2 A-slices + B-slice +
    # 2 C-blocks fit the budget, keeping alignment by hand
    bm, bn = M, N
    def ws(bm, bn):
        return (2 * bm * K + K * bn + 2 * bm * bn) * bpe
    while ws(bm, bn) > budget_bytes:
        if bm >= bn and bm > 8:
            bm = max(8, (bm // 2 + 7) // 8 * 8)
        elif bn > 128:
            bn = max(128, (bn // 2 + 127) // 128 * 128)
        elif bm > 8:
            bm = max(8, (bm // 2 + 7) // 8 * 8)
        else:
            raise ValueError("cannot fit budget")
    h = math.ceil(M / bm)
    w = math.ceil(N / bn)

    # hand-built single-stream op list: ping-pong parities, B reused per
    # column, no events (issue order is the only dependency structure)
    dev = Device("HBM", 0, budget_bytes)
    sched = Schedule(dev, StreamFactory.create(dev, 1))
    idx = 0
    for j in range(w):
        cs, cn = j * bn, min(bn, N - j * bn)
        sched.issue(Op(kind=OpKind.H2D, tag=f"S(b[{j}])", stream=0,
                       buffers_written=(("B", j % 2),), bytes=K * cn * bpe,
                       payload=SliceRef("B", j, cols=(cs, cn))))
        for i in range(h):
            rs, rn = i * bm, min(bm, M - i * bm)
            p = idx % 2
            sched.issue(Op(kind=OpKind.H2D, tag=f"S(a[{idx}])", stream=0,
                           buffers_written=(("A", p),), bytes=rn * K * bpe,
                           payload=SliceRef("A", idx, rows=(rs, rn))))
            sched.issue(Op(kind=OpKind.H2D, tag=f"S(c[{idx}])", stream=0,
                           buffers_written=(("C", p),), bytes=rn * cn * bpe,
                           payload=SliceRef("C", idx, rows=(rs, rn),
                                            cols=(cs, cn))))
            sched.issue(Op(kind=OpKind.COMPUTE, tag=f"DGEMM[{idx}]", stream=0,
                           buffers_read=(("A", p), ("B", j % 2)),
                           buffers_written=(("C", p),),
                           flops=2 * rn * cn * K,
                           payload=BlockRef("dgemm", idx)))
            sched.issue(Op(kind=OpKind.D2H, tag=f"R(c[{idx}])", stream=0,
                           buffers_read=(("C", p),), bytes=rn * cn * bpe,
                           payload=SliceRef("C", idx, rows=(rs, rn),
                                            cols=(cs, cn))))
            idx += 1
    ScheduleExecutor(async_writeback=True).run(
        sched, operands={"A": A, "B": B}, outputs={"C": out},
        ctx={"alpha": alpha, "beta": beta})
    return out


# ===========================================================================
# 2. vmem-tier direct implementation (hand-written Pallas pipeline)
# ===========================================================================
def direct_vmem_ooc_gemm(A, B, C, alpha, beta, block=(256, 256, 256),
                         interpret=True):
    """Standalone Pallas kernel written from scratch (no kernels/ reuse):
    its own grid, BlockSpecs, scratch and padding logic."""
    import functools
    from jax.experimental import pallas as pl

    from repro.compat import tpu_memory_space
    _ms = tpu_memory_space()

    bm, bn, bk = block
    M, K = A.shape
    _, N = B.shape

    def kernel(a_ref, b_ref, c_ref, o_ref, acc_ref, *, ks):
        k = pl.program_id(2)

        @pl.when(k == 0)
        def _():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                                preferred_element_type=jnp.float32)

        @pl.when(k == ks - 1)
        def _():
            o_ref[...] = (alpha * acc_ref[...]
                          + beta * c_ref[...].astype(jnp.float32)
                          ).astype(o_ref.dtype)

    pad = lambda x, m0, m1: jnp.pad(
        x, ((0, (-x.shape[0]) % m0), (0, (-x.shape[1]) % m1)))
    Ap, Bp, Cp = pad(A, bm, bk), pad(B, bk, bn), pad(C, bm, bn)
    Mp, Kp = Ap.shape
    Np = Bp.shape[1]
    grid = (Mp // bm, Np // bn, Kp // bk)
    out = pl.pallas_call(
        functools.partial(kernel, ks=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), C.dtype),
        scratch_shapes=[_ms.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(Ap, Bp, Cp)
    return out[:M, :N]


# ===========================================================================
# 3. mesh-tier direct implementation (hand-written SUMMA ring)
# ===========================================================================
def direct_mesh_ooc_gemm(A, B, C, alpha, beta, mesh, axis="model"):
    """Standalone shard_map SUMMA with its own ring bookkeeping."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    Pn = mesh.shape[axis]
    M, K = A.shape
    _, N = B.shape
    assert M % Pn == 0 and N % Pn == 0
    nb = N // Pn
    al = jnp.float32(alpha)
    be = jnp.float32(beta)

    def body(a, b, c):
        me = jax.lax.axis_index(axis)
        perm = [(i, (i - 1) % Pn) for i in range(Pn)]

        def step(t, carry):
            b_cur, acc = carry
            b_nxt = jax.lax.ppermute(b_cur, axis, perm)
            col = ((me + t) % Pn) * nb
            prod = jnp.dot(a, b_cur, preferred_element_type=jnp.float32)
            old = jax.lax.dynamic_slice(acc, (0, col), (acc.shape[0], nb))
            acc = jax.lax.dynamic_update_slice(
                acc, (al * prod + be * old).astype(acc.dtype), (0, col))
            return b_nxt, acc

        _, acc = jax.lax.fori_loop(0, Pn, step, (b, c))
        return acc

    fn = jax.shard_map(body, mesh=mesh,
                       in_specs=(P(axis, None), P(None, axis), P(axis, None)),
                       out_specs=P(axis, None))
    sA = jax.device_put(A, NamedSharding(mesh, P(axis, None)))
    sB = jax.device_put(B, NamedSharding(mesh, P(None, axis)))
    sC = jax.device_put(C, NamedSharding(mesh, P(axis, None)))
    return jax.jit(fn)(sA, sB, sC)
