"""Resilience cost: recovery overhead under the engine model + executed.

DESIGN.md §12's acceptance bar: at a 1 % per-op fault rate the expected
recovery overhead stays under 10 % of the fault-free makespan.  The
simulator's :class:`~repro.core.simulator.FaultModel` makes that check
deterministic — expected durations inflate closed-form (compute: redo
fraction scaled by the schedule's mean redo-set length; transfers:
geometric retry cost plus the policy's backoff) — so the guard is a
property of the schedule + policy, not of a noisy wall clock.

The executed rows then run a real pinned fault corpus through the
executor (one transfer retry storm + one compute replay per run) and
assert the recovered output is bitwise identical with exact byte
reconciliation; the wall-clock ratio is reported for context, never
asserted.

``--smoke`` shrinks only the executed row (simulation is instant at any
shape, and the <10 % bar is a paper-regime claim: at toy block sizes the
policy's fixed backoff dwarfs the transfers it shadows).  Rows land in
``benchmarks/bench_fault.json`` (picked up by scripts/check_drift.py).
"""

from __future__ import annotations

import dataclasses
import json
import os
import time

import numpy as np

from repro.core import build_gemm_schedule, plan_gemm_partition
from repro.core.pipeline import (compile_factor_pipeline,
                                 factor_pipeline_spec, schedule_stats)
from repro.core.runtime import HostOocRuntime
from repro.core.simulator import simulate
from repro.core.streams import OpKind
from repro.fault import (FaultPlan, FaultPolicy, FaultSpec, mean_redo_len)
from repro.tune import gpu_profile

JSON_PATH = os.path.join(os.path.dirname(__file__), "bench_fault.json")

RATE = 0.01                      # the acceptance bar's fault rate
OVERHEAD_GUARD_PCT = 10.0

# paper §VI regime fp64 shapes — used for the sim rows in BOTH modes:
# the guard is about blocks large enough that per-retry backoff amortizes
FULL_GEMM = (8192, 8192, 8192, 3 * 8192 * 8192 * 8 // 6, 8)
FULL_CHOL = (8192, 512, 256 * 2**20, 8)


def _sim_overhead_row(name: str, sched, policy: FaultPolicy) -> dict:
    """Expected recovery overhead of ``sched`` at the acceptance rate,
    guarded under 10 %: the deterministic form of the <10 % claim."""
    hw = gpu_profile().model_for(2)
    base = simulate(sched, hw).makespan
    fm = dataclasses.replace(policy.fault_model(RATE),
                             redo_factor=max(1.0, mean_redo_len(sched)))
    faulted = simulate(sched, hw, faults=fm).makespan
    pct = (faulted - base) / base * 100.0
    assert pct < OVERHEAD_GUARD_PCT, (
        f"{name}: expected recovery overhead {pct:.2f}% at {RATE:.0%} "
        f"fault rate exceeds the {OVERHEAD_GUARD_PCT:.0f}% guard "
        f"(base={base:.4f}s faulted={faulted:.4f}s)")
    return {
        "name": name,
        "us_per_call": faulted * 1e6,
        "derived": (f"base={base*1e3:.1f}ms faulted={faulted*1e3:.1f}ms "
                    f"overhead={pct:.2f}% redo_len={fm.redo_factor:.1f} "
                    f"(guard: <{OVERHEAD_GUARD_PCT:.0f}%)"),
    }


def _pinned_corpus(sched) -> FaultPlan:
    """One transfer retry (times=2) + one compute replay, addressed at the
    schedule's first eligible ops — the fixed corpus every run recovers."""
    h2d = next(i for i, op in enumerate(sched.ops)
               if op.kind == OpKind.H2D)
    comp = next(i for i, op in enumerate(sched.ops)
                if op.kind == OpKind.COMPUTE
                and len(op.buffers_written) == 1)
    return FaultPlan(specs=(FaultSpec(op=h2d, cls="h2d_error", times=2),
                            FaultSpec(op=comp, cls="compute_nan")))


def _executed_rows(smoke: bool) -> list:
    rng = np.random.default_rng(0)
    m, n, k = (512, 256, 128) if smoke else (2048, 1024, 512)
    A = rng.standard_normal((m, k)).astype(np.float32)
    B = rng.standard_normal((k, n)).astype(np.float32)
    C = rng.standard_normal((m, n)).astype(np.float32)
    budget = (A.nbytes + B.nbytes + C.nbytes) // 5
    part = plan_gemm_partition(m, n, k, budget, 4)
    sched = build_gemm_schedule(part, nstreams=2, nbuf=2)
    rt = HostOocRuntime()

    t0 = time.perf_counter()
    clean = rt.gemm(A, B, C, 1.0, 0.5, part, schedule=sched)
    t_clean = time.perf_counter() - t0
    stats = schedule_stats(sched)
    assert rt.executor.last_h2d_bytes == stats["h2d_bytes"]

    plan = _pinned_corpus(sched)
    pol = FaultPolicy(backoff_base=0.0, sleep=lambda s: None)
    inj = plan.injector()
    t0 = time.perf_counter()
    out = rt.gemm(A, B, C, 1.0, 0.5, part, schedule=sched,
                  faults=inj, policy=pol)
    t_faulted = time.perf_counter() - t0

    if not np.array_equal(out, clean):
        raise AssertionError("recovered GEMM is not bitwise identical")
    if not inj.exhausted():
        raise AssertionError(f"unconsumed faults: {inj.plan.specs}")
    fs = rt.executor.last_fault_stats
    if rt.executor.last_h2d_bytes != stats["h2d_bytes"]:
        raise AssertionError(
            "nominal H2D counter drifted under fault injection")
    h2d_op = sched.ops[plan.specs[0].op]
    if fs["replayed_h2d_bytes"] != 2 * h2d_op.bytes:
        raise AssertionError(
            f"replayed-bytes accounting wrong: {fs['replayed_h2d_bytes']} "
            f"vs {2 * h2d_op.bytes}")
    ratio = t_faulted / t_clean if t_clean > 0 else float("nan")
    return [{
        "name": "fault_exec_recovered_gemm",
        "us_per_call": t_faulted * 1e6,
        "derived": (f"bitwise ok; retries={fs['retries']} "
                    f"replayed_ops={fs['replayed_ops']} "
                    f"wall x{ratio:.2f} vs clean (informational)"),
    }]


def run(smoke: bool = False):
    rows = []
    pol = FaultPolicy()

    m, n, k, budget, bpe = FULL_GEMM
    part = plan_gemm_partition(m, n, k, budget, bpe)
    sched = build_gemm_schedule(part, nstreams=2, nbuf=2)
    rows.append(_sim_overhead_row("fault_sim_overhead_gemm", sched, pol))

    nn, panel, fbudget, fbpe = FULL_CHOL
    spec = factor_pipeline_spec(nn, panel, fbudget, fbpe, kind="cholesky",
                                lookahead=1)
    fsched = compile_factor_pipeline(spec, nstreams=2, nbuf=2)
    rows.append(
        _sim_overhead_row("fault_sim_overhead_cholesky", fsched, pol))

    rows.extend(_executed_rows(smoke))
    return rows


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes for CI (seconds; same asserts)")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    print("name,us_per_call,derived")
    for row in rows:
        derived = str(row["derived"]).replace(",", ";")
        print(f"{row['name']},{row['us_per_call']:.1f},{derived}")
    with open(JSON_PATH, "w") as f:
        json.dump(rows, f, indent=2)
    print(f"wrote {JSON_PATH}")


if __name__ == "__main__":
    main()
