"""One-command observability report for an OOC run (DESIGN.md §10).

Two modes:

  * **demo** (default) — run the acceptance scenario end to end with the
    process :class:`repro.obs.Observability` fully enabled: a seeded
    ``ooc_gemm(tune="auto", devices=[gpu, phi])`` co-execution plus a tuned
    single-device GEMM, under canned calibrated profiles (no hardware
    measurement, so the run is deterministic and CI-safe).  Emits:

      - a single Chrome trace (``--trace-out``) — tuner search, plan-cache
        lookups and the merge on pid 0, one executor lane-group per device;
      - the metrics + drift snapshot (``--json-out``);
      - a Markdown (default) or JSON report on stdout.

  * ``--input snapshot.json`` — render an existing snapshot (an
    ``obs.snapshot()`` document, e.g. a benchmark metrics sidecar) as the
    same report, without running anything.  ``--input`` may also name a
    *directory*: every ``*.metrics.json`` sidecar in it is merged into one
    report (counters and histograms add, gauges last-wins, drift records
    concatenate).

The demo also runs the attribution layer (DESIGN.md §11): the tuned plan's
critical path, bottleneck verdict and what-if sensitivity table, plus the
hybrid run's per-device imbalance attribution.  ``--check`` additionally
asserts the canned-profile verdicts are stable (the CI analyze smoke step):
a phi-like 1-stream run must be transfer-bound, and the gpu 2-stream GEMM
must keep its exec stream >=80 % busy.

Example:
    PYTHONPATH=src python scripts/run_report.py --m 384 --trace-out t.json
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile


# ---------------------------------------------------------------------------
# Rendering
# ---------------------------------------------------------------------------
def _fmt(v: float) -> str:
    if float(v).is_integer() and abs(v) < 2**63:
        return str(int(v))
    return f"{float(v):.6g}"


def render_markdown(snap: dict, trace_path: str = None) -> str:
    """Snapshot document -> Markdown report (metrics, drift, trace)."""
    lines = ["# OOC run report", ""]

    metrics = snap.get("metrics", [])
    lines += ["## Metrics", ""]
    if metrics:
        lines += ["| metric | type | labels | value |",
                  "|---|---|---|---|"]
        for fam in metrics:
            for s in fam.get("samples", ()):
                labels = " ".join(
                    f"{k}={v}" for k, v in sorted(s["labels"].items()))
                if fam.get("type") == "histogram":
                    value = (f"count={_fmt(s['count'])} "
                             f"sum={_fmt(s['sum'])}s")
                else:
                    value = _fmt(s["value"])
                lines.append(f"| `{fam['name']}` | {fam['type']} "
                             f"| {labels} | {value} |")
    else:
        lines.append("_no metrics recorded_")

    drift = snap.get("drift", {})
    rolling = drift.get("rolling", {})
    lines += ["", "## Drift (measured / predicted)", ""]
    if rolling:
        # last byte ratio per key comes from the raw records
        byte_ratio = {}
        for r in drift.get("records", ()):
            k = "|".join((r["kernel"], r["tier"], r["fingerprint"]))
            byte_ratio[k] = r.get("byte_ratio", 1.0)
        lines += ["| kernel\\|tier\\|fingerprint | n | first | last "
                  "| rolling mean | byte ratio |",
                  "|---|---|---|---|---|---|"]
        for key, row in sorted(rolling.items()):
            lines.append(
                f"| `{key}` | {row['n']} "
                f"| {row['first_time_ratio']:.3g} "
                f"| {row['last_time_ratio']:.3g} "
                f"| {row['mean_time_ratio']:.3g} "
                f"| {_fmt(byte_ratio.get(key, 1.0))} |")
        lines += ["",
                  "Byte ratios must be exactly 1 (executed transfers == "
                  "modeled transfers).  Time ratios are a *trend* signal: "
                  "a stable ratio means the calibrated profile still ranks "
                  "plans faithfully; a drifting one means recalibrate."]
    else:
        lines.append("_no drift records_")

    ana = snap.get("analysis")
    if ana:
        lines += ["", "## Attribution (tuned single-device plan)", "",
                  f"- verdict: **{ana['verdict']}** over a "
                  f"{ana['makespan_seconds']*1e3:.3g} ms predicted makespan",
                  "", "| critical-path class | seconds | share |",
                  "|---|---|---|"]
        for cls, secs in sorted(ana.get("class_seconds", {}).items(),
                                key=lambda kv: -kv[1]):
            share = ana.get("shares", {}).get(cls, 0.0)
            lines.append(f"| {cls} | {secs:.3e} | {share*100:.1f}% |")
        lines += ["", "| stream | ops | busy | utilization |",
                  "|---|---|---|---|"]
        for st in ana.get("streams", ()):
            lines.append(f"| {st['stream']} | {st['n_ops']} "
                         f"| {st['busy_seconds']:.3e}s "
                         f"| {st['utilization']*100:.1f}% |")
        gaps = ana.get("top_gaps", ())
        if gaps:
            lines += ["", "Top idle gaps (stream, seconds, blocked on):"]
            for g in gaps[:5]:
                lines.append(f"- s{g['stream']}: {g['seconds']:.3e}s before "
                             f"`{g['next_tag'] or 'drain'}` — {g['cause']}")

    rep = snap.get("whatif")
    if rep:
        base = rep["baseline"]
        lines += ["", "## What-if sensitivity", "",
                  f"Baseline: {base['nstreams']} stream(s), "
                  f"{base['nbuf']} buffer(s), "
                  f"{base['makespan']*1e3:.3g} ms.",
                  "", "| scenario | makespan | gain | speedup |",
                  "|---|---|---|---|"]
        for s in rep.get("scenarios", ()):
            if s["knob"] == "baseline":
                continue
            if not s.get("feasible", True):
                lines.append(f"| {s['name']} | _infeasible_ | — | — |")
                continue
            lines.append(f"| {s['name']} | {s['makespan']*1e3:.3g} ms "
                         f"| {s['gain_seconds']*1e3:+.3g} ms "
                         f"| {s['speedup']:.3f}x |")
        ranked = rep.get("ranked", ())
        if ranked:
            lines += ["", f"Best marginal resource: **{ranked[0]}**."]

    ha = snap.get("hybrid_analysis")
    if ha:
        lines += ["", "## Hybrid device attribution", "",
                  f"- critical device: **{ha['critical_device']}** "
                  f"({ha['makespan_seconds']*1e3:.3g} ms makespan)",
                  f"- imbalance (slowest-fastest)/slowest: "
                  f"{ha['imbalance']*100:.2f}%"]
        for name, d in sorted(ha.get("devices", {}).items()):
            utils = " ".join(
                f"s{st['stream']}={st['utilization']*100:.0f}%"
                for st in d.get("streams", ()))
            lines.append(f"- `{name}`: {d['verdict']}, "
                         f"{d['makespan_seconds']*1e3:.3g} ms, {utils}")

    trace = snap.get("trace")
    lines += ["", "## Trace", ""]
    if trace:
        lines.append(f"- control spans: {trace.get('control_spans', 0)}")
        for name, g in sorted(trace.get("groups", {}).items()):
            lines.append(f"- lane `{name}`: {g['spans']} spans, "
                         f"{g['span_seconds']*1e3:.2f} ms busy")
    else:
        lines.append("_no trace recorded_")
    if trace_path:
        lines.append(f"- written to `{trace_path}` "
                     f"(open at chrome://tracing or ui.perfetto.dev)")
    merged = snap.get("merged_from")
    if merged:
        lines += ["", "## Sources", ""]
        lines += [f"- `{p}`" for p in merged]
    return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# Demo run
# ---------------------------------------------------------------------------
def demo_run(m: int, seed: int, cache_path: str):
    """The acceptance scenario, deterministic: one tuned single-device GEMM
    plus one hybrid co-executed GEMM under canned gpu/phi profiles."""
    import numpy as np

    from repro.core.oocgemm import ooc_gemm
    from repro.hybrid import DeviceSpec
    from repro.obs import get_observability
    from repro.tune import AutoTuner, PlanCache, gpu_profile, phi_profile

    obs = get_observability()
    obs.reset()
    obs.enable(metrics=True, trace=True, trace_name="run-report")

    rng = np.random.default_rng(seed)
    M = N = K = m
    A = rng.standard_normal((M, K), dtype=np.float32)
    B = rng.standard_normal((K, N), dtype=np.float32)
    budget = (A.nbytes + B.nbytes + M * N * 4) // 3

    tuner = AutoTuner(profile=gpu_profile(), fingerprint="report",
                      cache=PlanCache(cache_path), max_steps=512)
    out1 = ooc_gemm(A, B, budget_bytes=budget, tune="auto", tuner=tuner)

    devices = [DeviceSpec("gpu0", gpu_profile(), budget),
               DeviceSpec("phi0", phi_profile(), budget)]
    out2 = ooc_gemm(A, B, budget_bytes=budget, tune="auto", devices=devices,
                    tolerance=0.1)

    ref = A @ B
    err = max(float(np.abs(out1 - ref).max()),
              float(np.abs(out2 - ref).max()))

    # attribution + what-if over the plan the tuner just chose (cache hit),
    # and per-device attribution of the hybrid split (DESIGN.md §11)
    from repro.hybrid.executor import analyze_hybrid
    from repro.hybrid.plan import plan_hybrid_gemm
    from repro.obs.analyze import analyze_plan
    from repro.obs.whatif import whatif_plan

    plan = tuner.gemm_plan(M, N, K, budget)
    ana, res = analyze_plan(plan, gpu_profile())
    ana.verify_reconciliation(res)        # exact accounting, or blow up here
    obs.record_analysis(ana, kernel="gemm")
    rep = whatif_plan(plan, gpu_profile())
    obs.record_whatif(rep, kernel="gemm")
    hana = analyze_hybrid(plan_hybrid_gemm(M, N, K, devices,
                                           dtype="float32", tolerance=0.1))
    extras = {"analysis": ana.to_json(max_path=0), "whatif": rep.to_json(),
              "hybrid_analysis": hana.to_json()}
    return obs, err, extras


# ---------------------------------------------------------------------------
# Sidecar merging
# ---------------------------------------------------------------------------
def merge_snapshots(paths):
    """Merge several ``obs.snapshot()`` documents into one report document.

    Counters and histograms accumulate across files (histograms must agree
    on buckets), gauges keep the last file's value, drift records
    concatenate (rolling summaries recomputed over the combined history),
    trace groups merge by lane name.
    """
    merged = {"metrics": [], "drift": {"records": [], "rolling": {}},
              "merged_from": [str(p) for p in paths]}
    fams = {}                       # name -> family dict
    trace = None
    for path in paths:
        with open(path) as f:
            snap = json.load(f)
        for fam in snap.get("metrics", ()):
            cur = fams.get(fam["name"])
            if cur is None:
                fams[fam["name"]] = json.loads(json.dumps(fam))  # deep copy
                continue
            if cur.get("type") != fam.get("type"):
                raise SystemExit(
                    f"{path}: metric {fam['name']!r} is {fam.get('type')} "
                    f"here but {cur.get('type')} in an earlier sidecar")
            by_labels = {tuple(sorted(s["labels"].items())): s
                         for s in cur["samples"]}
            for s in fam.get("samples", ()):
                key = tuple(sorted(s["labels"].items()))
                have = by_labels.get(key)
                if have is None:
                    cur["samples"].append(json.loads(json.dumps(s)))
                    by_labels[key] = cur["samples"][-1]
                elif fam["type"] == "counter":
                    have["value"] += s["value"]
                elif fam["type"] == "histogram":
                    if cur.get("buckets") != fam.get("buckets"):
                        raise SystemExit(
                            f"{path}: histogram {fam['name']!r} bucket "
                            f"layout differs from an earlier sidecar")
                    have["counts"] = [a + b for a, b in
                                      zip(have["counts"], s["counts"])]
                    have["sum"] += s["sum"]
                    have["count"] += s["count"]
                else:                     # gauge (and anything point-in-time)
                    have["value"] = s["value"]
        merged["drift"]["records"].extend(
            snap.get("drift", {}).get("records", ()))
        tr = snap.get("trace")
        if tr:
            if trace is None:
                trace = {"control_spans": 0, "groups": {}}
            trace["control_spans"] += tr.get("control_spans", 0)
            for name, g in tr.get("groups", {}).items():
                have = trace["groups"].setdefault(
                    name, {"spans": 0, "span_seconds": 0.0})
                have["spans"] += g.get("spans", 0)
                have["span_seconds"] += g.get("span_seconds", 0.0)
    merged["metrics"] = [fams[n] for n in sorted(fams)]
    if trace is not None:
        merged["trace"] = trace
    by_key = {}
    for r in merged["drift"]["records"]:
        key = "|".join((r["kernel"], r["tier"], r["fingerprint"]))
        by_key.setdefault(key, []).append(r["time_ratio"])
    for key, ratios in sorted(by_key.items()):
        merged["drift"]["rolling"][key] = {
            "n": len(ratios),
            "mean_time_ratio": sum(ratios) / len(ratios),
            "last_time_ratio": ratios[-1],
            "first_time_ratio": ratios[0],
        }
    return merged


# ---------------------------------------------------------------------------
# Canned-verdict checks (the CI analyze smoke step)
# ---------------------------------------------------------------------------
def run_checks() -> int:
    """Assert the attribution verdicts on canned profiles are stable.

    1. A 1-stream, 1-buffer GEMM under the phi-like profile (shared
       transfer+compute engine) must come out **transfer-bound**.
    2. The gpu 2-stream fp64 GEMM at 4096^3 must keep its exec pool >=80 %
       busy — the overlap the canned profile was built to demonstrate.

    Both analyses must reconcile exactly against their simulations.
    """
    from repro.core.partitioner import plan_gemm_partition
    from repro.core.pipeline import compile_pipeline, gemm_pipeline_spec
    from repro.obs.analyze import TraceAnalysis
    from repro.tune import gpu_profile, phi_profile

    def compiled(M, bpe, budget, ns, nb):
        part = plan_gemm_partition(M, M, M, budget, bpe,
                                   nbuf=nb, nstreams=ns)
        spec = gemm_pipeline_spec(part, write_back=True, traversal="col",
                                  band=nb)
        return compile_pipeline(spec, nstreams=ns, nbuf=nb)

    failures = []

    m = 256
    sched = compiled(m, 4, (m * m * 4 * 3) // 2, ns=1, nb=1)
    ana, res = TraceAnalysis.analyze(sched, phi_profile().model_for(1))
    ana.verify_reconciliation(res)
    print(f"check phi/1-stream: {ana.digest()}")
    if ana.verdict != "transfer-bound":
        failures.append(f"phi 1-stream verdict {ana.verdict!r}, "
                        f"expected 'transfer-bound'")

    m = 4096
    sched = compiled(m, 8, (3 * m * m * 8) // 6, ns=2, nb=2)
    ana, res = TraceAnalysis.analyze(sched, gpu_profile().model_for(2))
    ana.verify_reconciliation(res)
    util = ana.pool_utilization("exec")
    print(f"check gpu/2-stream: exec utilization {util:.3f}; {ana.digest()}")
    if util < 0.8:
        failures.append(f"gpu 2-stream exec utilization {util:.3f} < 0.8")

    for f in failures:
        print(f"CHECK FAILED: {f}", file=sys.stderr)
    if not failures:
        print("analyze checks passed")
    return 1 if failures else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--input", default=None,
                    help="render an existing snapshot JSON (or a directory "
                         "of *.metrics.json sidecars, merged) instead of "
                         "running the demo")
    ap.add_argument("--check", action="store_true",
                    help="also assert the canned-profile attribution "
                         "verdicts are stable (CI smoke)")
    ap.add_argument("--m", type=int, default=256,
                    help="demo GEMM order (M=N=K)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--format", choices=("markdown", "json"),
                    default="markdown", help="stdout report format")
    ap.add_argument("--trace-out", default=None,
                    help="write the demo's Chrome trace here")
    ap.add_argument("--json-out", default=None,
                    help="write the snapshot document here")
    args = ap.parse_args(argv)

    trace_path = args.trace_out
    if args.input:
        if os.path.isdir(args.input):
            sidecars = sorted(
                os.path.join(args.input, n) for n in os.listdir(args.input)
                if n.endswith(".metrics.json"))
            if not sidecars:
                raise SystemExit(f"{args.input}: no *.metrics.json sidecars")
            snap = merge_snapshots(sidecars)
        else:
            with open(args.input) as f:
                snap = json.load(f)
            if "metrics" not in snap and "drift" not in snap:
                raise SystemExit(f"{args.input}: not a snapshot document "
                                 f"(no 'metrics'/'drift' keys)")
    else:
        with tempfile.TemporaryDirectory() as tmp:
            obs, err, extras = demo_run(args.m, args.seed,
                                        os.path.join(tmp, "plans.json"))
        snap = obs.snapshot()
        snap["demo"] = {"m": args.m, "seed": args.seed, "max_abs_err": err}
        snap.update(extras)
        if trace_path:
            obs.tracer.write(trace_path)
        obs.reset()

    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(snap, f, indent=1, sort_keys=True)

    if args.format == "json":
        json.dump(snap, sys.stdout, indent=1, sort_keys=True)
        sys.stdout.write("\n")
    else:
        sys.stdout.write(render_markdown(snap, trace_path=trace_path))
    if args.check:
        return run_checks()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
