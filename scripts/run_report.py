"""One-command observability report for an OOC run (DESIGN.md §10).

Two modes:

  * **demo** (default) — run the acceptance scenario end to end with the
    process :class:`repro.obs.Observability` fully enabled: a seeded
    ``ooc_gemm(tune="auto", devices=[gpu, phi])`` co-execution plus a tuned
    single-device GEMM, under canned calibrated profiles (no hardware
    measurement, so the run is deterministic and CI-safe).  Emits:

      - a single Chrome trace (``--trace-out``) — tuner search, plan-cache
        lookups and the merge on pid 0, one executor lane-group per device;
      - the metrics + drift snapshot (``--json-out``);
      - a Markdown (default) or JSON report on stdout.

  * ``--input snapshot.json`` — render an existing snapshot (an
    ``obs.snapshot()`` document, e.g. a benchmark metrics sidecar) as the
    same report, without running anything.

Example:
    PYTHONPATH=src python scripts/run_report.py --m 384 --trace-out t.json
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile


# ---------------------------------------------------------------------------
# Rendering
# ---------------------------------------------------------------------------
def _fmt(v: float) -> str:
    if float(v).is_integer() and abs(v) < 2**63:
        return str(int(v))
    return f"{float(v):.6g}"


def render_markdown(snap: dict, trace_path: str = None) -> str:
    """Snapshot document -> Markdown report (metrics, drift, trace)."""
    lines = ["# OOC run report", ""]

    metrics = snap.get("metrics", [])
    lines += ["## Metrics", ""]
    if metrics:
        lines += ["| metric | type | labels | value |",
                  "|---|---|---|---|"]
        for fam in metrics:
            for s in fam.get("samples", ()):
                labels = " ".join(
                    f"{k}={v}" for k, v in sorted(s["labels"].items()))
                if fam.get("type") == "histogram":
                    value = (f"count={_fmt(s['count'])} "
                             f"sum={_fmt(s['sum'])}s")
                else:
                    value = _fmt(s["value"])
                lines.append(f"| `{fam['name']}` | {fam['type']} "
                             f"| {labels} | {value} |")
    else:
        lines.append("_no metrics recorded_")

    drift = snap.get("drift", {})
    rolling = drift.get("rolling", {})
    lines += ["", "## Drift (measured / predicted)", ""]
    if rolling:
        # last byte ratio per key comes from the raw records
        byte_ratio = {}
        for r in drift.get("records", ()):
            k = "|".join((r["kernel"], r["tier"], r["fingerprint"]))
            byte_ratio[k] = r.get("byte_ratio", 1.0)
        lines += ["| kernel\\|tier\\|fingerprint | n | first | last "
                  "| rolling mean | byte ratio |",
                  "|---|---|---|---|---|---|"]
        for key, row in sorted(rolling.items()):
            lines.append(
                f"| `{key}` | {row['n']} "
                f"| {row['first_time_ratio']:.3g} "
                f"| {row['last_time_ratio']:.3g} "
                f"| {row['mean_time_ratio']:.3g} "
                f"| {_fmt(byte_ratio.get(key, 1.0))} |")
        lines += ["",
                  "Byte ratios must be exactly 1 (executed transfers == "
                  "modeled transfers).  Time ratios are a *trend* signal: "
                  "a stable ratio means the calibrated profile still ranks "
                  "plans faithfully; a drifting one means recalibrate."]
    else:
        lines.append("_no drift records_")

    trace = snap.get("trace")
    lines += ["", "## Trace", ""]
    if trace:
        lines.append(f"- control spans: {trace.get('control_spans', 0)}")
        for name, g in sorted(trace.get("groups", {}).items()):
            lines.append(f"- lane `{name}`: {g['spans']} spans, "
                         f"{g['span_seconds']*1e3:.2f} ms busy")
    else:
        lines.append("_no trace recorded_")
    if trace_path:
        lines.append(f"- written to `{trace_path}` "
                     f"(open at chrome://tracing or ui.perfetto.dev)")
    return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# Demo run
# ---------------------------------------------------------------------------
def demo_run(m: int, seed: int, cache_path: str):
    """The acceptance scenario, deterministic: one tuned single-device GEMM
    plus one hybrid co-executed GEMM under canned gpu/phi profiles."""
    import numpy as np

    from repro.core.oocgemm import ooc_gemm
    from repro.hybrid import DeviceSpec
    from repro.obs import get_observability
    from repro.tune import AutoTuner, PlanCache, gpu_profile, phi_profile

    obs = get_observability()
    obs.reset()
    obs.enable(metrics=True, trace=True, trace_name="run-report")

    rng = np.random.default_rng(seed)
    M = N = K = m
    A = rng.standard_normal((M, K), dtype=np.float32)
    B = rng.standard_normal((K, N), dtype=np.float32)
    budget = (A.nbytes + B.nbytes + M * N * 4) // 3

    tuner = AutoTuner(profile=gpu_profile(), fingerprint="report",
                      cache=PlanCache(cache_path), max_steps=512)
    out1 = ooc_gemm(A, B, budget_bytes=budget, tune="auto", tuner=tuner)

    devices = [DeviceSpec("gpu0", gpu_profile(), budget),
               DeviceSpec("phi0", phi_profile(), budget)]
    out2 = ooc_gemm(A, B, budget_bytes=budget, tune="auto", devices=devices,
                    tolerance=0.1)

    ref = A @ B
    err = max(float(np.abs(out1 - ref).max()),
              float(np.abs(out2 - ref).max()))
    return obs, err


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--input", default=None,
                    help="render an existing snapshot JSON instead of "
                         "running the demo")
    ap.add_argument("--m", type=int, default=256,
                    help="demo GEMM order (M=N=K)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--format", choices=("markdown", "json"),
                    default="markdown", help="stdout report format")
    ap.add_argument("--trace-out", default=None,
                    help="write the demo's Chrome trace here")
    ap.add_argument("--json-out", default=None,
                    help="write the snapshot document here")
    args = ap.parse_args(argv)

    trace_path = args.trace_out
    if args.input:
        with open(args.input) as f:
            snap = json.load(f)
        if "metrics" not in snap and "drift" not in snap:
            raise SystemExit(f"{args.input}: not a snapshot document "
                             f"(no 'metrics'/'drift' keys)")
    else:
        with tempfile.TemporaryDirectory() as tmp:
            obs, err = demo_run(args.m, args.seed,
                                os.path.join(tmp, "plans.json"))
        snap = obs.snapshot()
        snap["demo"] = {"m": args.m, "seed": args.seed, "max_abs_err": err}
        if trace_path:
            obs.tracer.write(trace_path)
        obs.reset()

    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(snap, f, indent=1, sort_keys=True)

    if args.format == "json":
        json.dump(snap, sys.stdout, indent=1, sort_keys=True)
        sys.stdout.write("\n")
    else:
        sys.stdout.write(render_markdown(snap, trace_path=trace_path))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
