"""Flaky-test detector: rerun a pytest selection, flag intermittent fails.

A test that fails in *some* repetitions but not all is flaky — usually
hidden cross-test state, timing sensitivity, or accidental dependence on
iteration order.  This script runs the selection ``--reps`` times, varying
``PYTHONHASHSEED`` per repetition (so dict/set iteration order actually
changes between runs), parses each run's ``FAILED`` lines, and reports
tests whose failure is not reproducible across every repetition.

Exit status:
  * tests failing in **every** rep are deterministic failures — the normal
    test gate's job, reported here but never a flake;
  * tests failing in **some but not all** reps are flakes: reported, and
    the script exits 1 only under ``--strict`` (CI runs report-only so a
    new flake is visible in the log without blocking unrelated work).

Example:
    python scripts/check_flaky.py tests/test_fault_fuzz.py
    python scripts/check_flaky.py --reps 5 --strict tests/
"""

from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FAILED_RE = re.compile(r"^(?:FAILED|ERROR) (\S+)", re.MULTILINE)


def run_once(selection, hashseed: str, extra_args):
    """One pytest run of ``selection``; returns (set of failed ids, rc)."""
    env = dict(os.environ)
    env["PYTHONHASHSEED"] = hashseed
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in [os.path.join(REPO, "src"),
                    env.get("PYTHONPATH", "")] if p)
    cmd = [sys.executable, "-m", "pytest", "-q", "-rf", "-p", "no:cacheprovider",
           *extra_args, *selection]
    proc = subprocess.run(cmd, cwd=REPO, capture_output=True, text=True,
                          env=env)
    failed = set(FAILED_RE.findall(proc.stdout))
    return failed, proc.returncode, proc.stdout


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("selection", nargs="*", default=["tests"],
                    help="pytest files/dirs/node-ids (default: tests)")
    ap.add_argument("--reps", type=int, default=3,
                    help="repetitions (default 3)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 when flaky tests are found")
    ap.add_argument("--pytest-args", default="",
                    help="extra args forwarded to pytest (one string)")
    args = ap.parse_args(argv)
    if args.reps < 2:
        ap.error("--reps must be >= 2: flakiness needs disagreement")
    extra = args.pytest_args.split() if args.pytest_args else []

    per_rep = []
    for rep in range(args.reps):
        hashseed = str(1000 + rep)
        failed, rc, out = run_once(args.selection, hashseed, extra)
        if rc not in (0, 1):  # collection error, usage error, crash
            print(f"rep {rep + 1}/{args.reps}: pytest exited {rc} "
                  f"(not a test failure) — aborting")
            print(out[-2000:])
            return rc
        per_rep.append(failed)
        print(f"rep {rep + 1}/{args.reps} (PYTHONHASHSEED={hashseed}): "
              f"{len(failed)} failed")

    all_failed = set.union(*per_rep)
    always = set.intersection(*per_rep)
    flaky = all_failed - always

    for tid in sorted(always):
        print(f"DETERMINISTIC FAIL: {tid} (failed in all {args.reps} reps)")
    for tid in sorted(flaky):
        n = sum(tid in f for f in per_rep)
        print(f"FLAKY: {tid} (failed in {n}/{args.reps} reps)")

    if not all_failed:
        print(f"ok: no failures across {args.reps} reps")
    elif not flaky:
        print("no flakes: every failure is deterministic "
              "(the regular test gate covers those)")
    if flaky and args.strict:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
