"""Render EXPERIMENTS.md tables from experiments/dryrun/*.json."""

import glob
import json
import os
import sys

DIR = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def load(mesh):
    out = {}
    for p in sorted(glob.glob(os.path.join(DIR, f"*__{mesh}.json"))):
        c = json.load(open(p))
        out[(c["arch"], c["shape"])] = c
    return out


def dryrun_table():
    single = load("single")
    multi = load("multi")
    print("| arch | shape | 16x16: status / GiB-per-chip / fits | "
          "2x16x16: status / GiB / fits | proof compile (s) |")
    print("|---|---|---|---|---|")
    for (a, s), c in single.items():
        m = multi.get((a, s), {})

        def cell(c):
            if not c:
                return "—"
            if c["status"] == "SKIP":
                return "SKIP"
            if c["status"] != "OK":
                return "FAIL"
            return (f"OK / {fmt_bytes(c['device_hbm_bytes'])} / "
                    f"{'Y' if c['fits_hbm'] else 'N'}")
        pc = c.get("proof_compile_s", "—")
        mc = m.get("proof_compile_s", "—")
        print(f"| {a} | {s} | {cell(c)} | {cell(m)} | {pc} / {mc} |")


def roofline_table():
    single = load("single")
    print("| arch | shape | Tc (s) | Tm (s) | Tx (s) | bound | frac | "
          "useful | MODEL_FLOPS | HLO_FLOPS(tot) |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for (a, s), c in single.items():
        if c["status"] == "SKIP":
            print(f"| {a} | {s} | — | — | — | SKIP: {c['reason'][:40]} "
                  f"| | | | |")
            continue
        if "roofline" not in c:
            print(f"| {a} | {s} | — | — | — | {c['status']} | | | | |")
            continue
        r = c["roofline"]
        print(f"| {a} | {s} | {r['t_compute_s']:.4f} | {r['t_memory_s']:.4f}"
              f" | {r['t_collective_s']:.4f} | {r['bottleneck']} "
              f"| {r['roofline_fraction']:.3f} | {r['useful_flops_ratio']:.2f}"
              f" | {c['model_flops']:.2e} "
              f"| {c['flops_per_device']*c['chips']:.2e} |")


def collectives_table():
    single = load("single")
    print("| arch | shape | all-reduce GiB | all-gather GiB | "
          "reduce-scatter GiB | a2a GiB | permute GiB |")
    print("|---|---|---|---|---|---|---|")
    for (a, s), c in single.items():
        if c.get("status") != "OK" or "collectives" not in c:
            continue
        k = c["collectives"]
        g = lambda n: f"{k.get(n, 0)/2**30:.2f}"
        print(f"| {a} | {s} | {g('all-reduce')} | {g('all-gather')} | "
              f"{g('reduce-scatter')} | {g('all-to-all')} | "
              f"{g('collective-permute')} |")


if __name__ == "__main__":
    which = sys.argv[2] if len(sys.argv) > 2 else "all"
    if which in ("all", "dryrun"):
        print("### Dry-run matrix\n")
        dryrun_table()
        print()
    if which in ("all", "roofline"):
        print("### Roofline (single-pod 16x16, per-cell)\n")
        roofline_table()
        print()
    if which in ("all", "collectives"):
        print("### Collective wire bytes per device (single-pod)\n")
        collectives_table()
