"""Performance-drift check: working-tree bench JSONs vs committed baselines.

The CI benchmark smokes rewrite ``benchmarks/bench_*.json`` in place; the
committed copies (produced by the same ``--smoke`` shapes) are the
baselines.  For every tracked ``bench_*.json`` this script matches rows by
``name`` and compares:

  * ``us_per_call`` — warns when ``new / old`` exceeds ``--threshold``
    (default 1.25x, DESIGN.md §10).  Timing on shared CI runners is noisy,
    so this is a *trend* tripwire, not a gate: the step is warn-only and
    exits 0 unless ``--strict``.
  * ``bytes_moved`` (where present) — the engine-model traffic is
    deterministic, so any difference is a real behavior change and always
    counts as drift, at any ratio.

Rows present on only one side (renamed/added benchmarks) are reported as
informational, never as drift.

Example:
    python scripts/check_drift.py                 # warn-only (CI default)
    python scripts/check_drift.py --strict        # exit 1 on drift
    python scripts/check_drift.py --baseline-ref origin/main
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _committed(ref: str, relpath: str):
    """Row list of ``relpath`` at ``ref``, or None if not tracked there."""
    proc = subprocess.run(
        ["git", "show", f"{ref}:{relpath}"],
        cwd=REPO, capture_output=True, text=True)
    if proc.returncode != 0:
        return None
    return json.loads(proc.stdout)


def _by_name(rows):
    return {r["name"]: r for r in rows if "name" in r}


def check_file(relpath: str, ref: str, threshold: float):
    """Compare one bench JSON; returns (drift_lines, info_lines)."""
    with open(os.path.join(REPO, relpath)) as f:
        new = _by_name(json.load(f))
    old_rows = _committed(ref, relpath)
    if old_rows is None:
        return [], [f"{relpath}: no baseline at {ref} (new file) — skipped"]
    old = _by_name(old_rows)

    drift, info = [], []
    for name in sorted(set(new) & set(old)):
        n, o = new[name], old[name]
        t_new, t_old = n.get("us_per_call", 0.0), o.get("us_per_call", 0.0)
        if t_old > 0 and t_new / t_old > threshold:
            drift.append(
                f"{relpath}:{name}: {t_new/t_old:.2f}x slower "
                f"({t_old:.1f}us -> {t_new:.1f}us, threshold "
                f"{threshold:.2f}x)")
        if "bytes_moved" in o and n.get("bytes_moved") != o["bytes_moved"]:
            drift.append(
                f"{relpath}:{name}: modeled bytes_moved changed "
                f"{o['bytes_moved']} -> {n.get('bytes_moved')} "
                f"(deterministic — real behavior change)")
    for name in sorted(set(new) - set(old)):
        info.append(f"{relpath}:{name}: new row (no baseline)")
    for name in sorted(set(old) - set(new)):
        info.append(f"{relpath}:{name}: baseline row missing from new run")
    return drift, info


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--baseline-ref", default="HEAD",
                    help="git ref holding the baseline JSONs")
    ap.add_argument("--threshold", type=float, default=1.25,
                    help="warn when new/old us_per_call exceeds this")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on drift (default: warn only)")
    ap.add_argument("paths", nargs="*",
                    help="bench JSONs to check (default: "
                         "benchmarks/bench_*.json)")
    args = ap.parse_args(argv)

    paths = args.paths or sorted(
        os.path.relpath(p, REPO)
        for p in glob.glob(os.path.join(REPO, "benchmarks", "bench_*.json"))
        if not p.endswith(".metrics.json"))  # sidecars aren't score files
    if not paths:
        print("check_drift: no bench JSONs found — nothing to check")
        return 0

    all_drift, all_info = [], []
    for rel in paths:
        drift, info = check_file(rel, args.baseline_ref, args.threshold)
        all_drift += drift
        all_info += info

    for line in all_info:
        print(f"  note: {line}")
    if all_drift:
        for line in all_drift:
            print(f"DRIFT: {line}", file=sys.stderr)
        print(f"check_drift: {len(all_drift)} drift warning(s) vs "
              f"{args.baseline_ref}"
              + ("" if args.strict else " (warn-only; pass --strict to "
                                       "fail)"),
              file=sys.stderr)
        return 1 if args.strict else 0
    print(f"check_drift: {len(paths)} file(s) within {args.threshold:.2f}x "
          f"of {args.baseline_ref} baselines")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
