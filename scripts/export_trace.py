"""Export an OOC pipeline timeline as chrome://tracing JSON.

Three span sources, one trace format (``repro.core.trace``):

  * ``--mode sim``  — engine-model spans from ``simulate()`` under a named
    hardware model: what the schedule *predicts* (the C3/C5 overlap story).
  * ``--mode exec`` — wall-clock spans from ``ScheduleExecutor`` running the
    schedule on random data with ``record_spans=True``: what this machine
    *does* (note: recording synchronizes per op, so overlap collapses — use
    it to inspect op ordering and real per-op costs, not speedups).
  * ``--mode hybrid`` — engine-model spans of a GEMM co-scheduled across
    the canned gpu+phi profile pair: one trace *process* (lane-group, pid =
    device index) per device, so the balanced concurrent timelines sit side
    by side without stream-id collisions.
  * ``--mode factor`` — engine-model spans of a whole factorization
    schedule (``--kind cholesky|lu``): panel ops, lookahead overlap and the
    streamed trailing update on one timeline.

GEMM and factor traces carry the schedule's block-cache counters as an
instant "reuse" annotation (hits = transfers *not* on the timeline);
``--traversal``/``--evict`` pick the step order and eviction policy so the
elided-transfer effect is visible by diffing two exports.

Open the output at chrome://tracing or https://ui.perfetto.dev.

Example:
    PYTHONPATH=src python scripts/export_trace.py --mode sim \
        --M 2048 --N 2048 --K 1024 --budget-mb 16 --hw gpu -o trace.json
"""

from __future__ import annotations

import argparse
import json
import sys

import numpy as np

from repro.core import (EVICT_POLICIES, TRAVERSALS, HostOocRuntime, OpKind,
                        ScheduleExecutor, build_gemm_schedule, chrome_trace,
                        compile_factor_pipeline, factor_pipeline_spec,
                        gpu_like, phi_like, plan_gemm_partition, simulate,
                        tpu_v5e_ici, tpu_v5e_vmem)
from repro.obs.analyze import TraceAnalysis

HW = {
    "gpu": lambda ns: gpu_like(),
    "phi": lambda ns: phi_like(nstreams=ns),
    "tpu_vmem": lambda ns: tpu_v5e_vmem(),
    "tpu_ici": lambda ns: tpu_v5e_ici(),
}

# informational output; rebound to stderr when the trace itself goes to
# stdout (--out -) so the JSON stays parseable
log = print


def _summarize(doc: dict) -> str:
    """Per-pid digest of a Chrome-trace doc: lane name, span count, busy
    milliseconds per category, and utilization (busy / (wall span × lanes))
    — plus the modeled byte totals and attribution digest when the
    exporting mode attached them (``otherData``)."""
    lanes: dict = {}
    for e in doc.get("traceEvents", ()):
        pid = e.get("pid", 0)
        lane = lanes.setdefault(pid, {"name": f"pid {pid}", "spans": 0,
                                      "busy_ms": {}, "tids": set(),
                                      "t0": None, "t1": None})
        if e.get("ph") == "M" and e.get("name") == "process_name":
            lane["name"] = e["args"]["name"]
        elif e.get("ph") == "X":
            lane["spans"] += 1
            cat = e.get("cat", "span")
            lane["busy_ms"][cat] = (lane["busy_ms"].get(cat, 0.0)
                                    + e.get("dur", 0.0) / 1e3)
            lane["tids"].add(e.get("tid", 0))
            ts, dur = e.get("ts", 0.0), e.get("dur", 0.0)
            lane["t0"] = ts if lane["t0"] is None else min(lane["t0"], ts)
            lane["t1"] = (ts + dur if lane["t1"] is None
                          else max(lane["t1"], ts + dur))
    lines = []
    for pid in sorted(lanes):
        lane = lanes[pid]
        cats = " ".join(f"{c}={ms:.2f}ms"
                        for c, ms in sorted(lane["busy_ms"].items()))
        util = ""
        if lane["t1"] is not None and lane["t1"] > lane["t0"]:
            wall_ms = (lane["t1"] - lane["t0"]) / 1e3
            frac = (sum(lane["busy_ms"].values())
                    / (wall_ms * max(len(lane["tids"]), 1)))
            util = f"  util={frac*100:.0f}%"
        lines.append(f"  pid {pid} [{lane['name']}]: {lane['spans']} spans"
                     + (f"  {cats}" if cats else "") + util)
    for k, v in sorted(doc.get("otherData", {}).items()):
        lines.append(f"  {k}: {v}")
    return "\n".join(lines)


def _emit(doc: dict, args) -> None:
    """Write the trace doc (``--out -`` = stdout) and, with ``--summary``,
    print the per-pid digest."""
    if args.summary:
        log("summary:")
        log(_summarize(doc))
    if args.out == "-":
        json.dump(doc, sys.stdout)
        sys.stdout.write("\n")
    else:
        with open(args.out, "w") as f:
            json.dump(doc, f)
        log(f"wrote {args.out} — load at chrome://tracing or "
            f"ui.perfetto.dev")


def _hybrid_mode(args) -> None:
    from repro.hybrid import (DeviceSpec, device_schedule, plan_hybrid_gemm,
                              simulate_hybrid)
    from repro.tune import gpu_profile, phi_profile

    budget = int(args.budget_mb * 2**20)
    devices = [DeviceSpec("gpu0", gpu_profile(), budget),
               DeviceSpec("phi0", phi_profile(), budget)]
    hplan = plan_hybrid_gemm(args.M, args.N, args.K, devices,
                             nbuf_options=(1, 2), max_steps=512)
    sim = simulate_hybrid(hplan)
    for dp, span in zip(hplan.device_plans, sim.device_makespans):
        log(f"  {dp.device.name}: rows [{dp.start}, "
            f"{dp.start + dp.length}) s{dp.plan.nstreams}b{dp.plan.nbuf} "
            f"-> {span*1e3:.2f} ms")
    doc = sim.to_chrome_trace()
    scheds = [device_schedule(hplan, dp) for dp in hplan.device_plans]
    doc["otherData"] = {
        "h2d_bytes": sum(s.total_bytes(OpKind.H2D) for s in scheds),
        "d2h_bytes": sum(s.total_bytes(OpKind.D2H) for s in scheds),
        "analysis": {
            dp.device.name: TraceAnalysis.from_sim(
                sched, res,
                hw=dp.device.profile.model_for(dp.plan.nstreams)).digest()
            for dp, sched, (_, res) in zip(hplan.device_plans, scheds,
                                           sim.per_device)
        },
    }
    log(f"hybrid gemm {args.M}x{args.N}x{args.K}: aggregate makespan "
        f"{sim.makespan*1e3:.2f} ms across {len(hplan.device_plans)} "
        f"devices (one lane-group each)")
    _emit(doc, args)


def _factor_mode(args) -> None:
    budget = int(args.budget_mb * 2**20)
    spec = factor_pipeline_spec(args.n, args.panel, budget, 4,
                                kind=args.kind, lookahead=args.lookahead,
                                nbuf=args.nbuf)
    sched = compile_factor_pipeline(spec, nstreams=args.nstreams,
                                    nbuf=args.nbuf, evict=args.evict)
    res = simulate(sched, HW[args.hw](args.nstreams))
    name = (f"{args.kind} n={args.n} panel={spec.panel} "
            f"la{spec.lookahead} s{args.nstreams}b{args.nbuf} {args.evict}")
    reuse = sched.reuse.get("Fr", {})
    log(f"{name}: {len(sched.ops)} ops, simulated makespan "
        f"{res.makespan*1e3:.2f} ms on {args.hw}; factored-row cache "
        f"{reuse.get('hits', 0)} hits / {reuse.get('misses', 0)} "
        f"transfers")
    doc = chrome_trace(res.op_spans, process_name=name, reuse=sched.reuse)
    doc["otherData"] = {
        "h2d_bytes": sched.total_bytes(OpKind.H2D),
        "d2h_bytes": sched.total_bytes(OpKind.D2H),
        "analysis": TraceAnalysis.from_sim(
            sched, res, hw=HW[args.hw](args.nstreams)).digest(),
    }
    _emit(doc, args)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mode", choices=("sim", "exec", "hybrid", "factor"),
                    default="sim")
    ap.add_argument("--M", type=int, default=2048)
    ap.add_argument("--N", type=int, default=2048)
    ap.add_argument("--K", type=int, default=1024)
    ap.add_argument("--budget-mb", type=float, default=16.0)
    ap.add_argument("--nstreams", type=int, default=2)
    ap.add_argument("--nbuf", type=int, default=2)
    ap.add_argument("--traversal", choices=TRAVERSALS, default="col",
                    help="block-grid step order (sim/exec modes)")
    ap.add_argument("--evict", choices=EVICT_POLICIES, default="lru",
                    help="block-cache eviction policy (sim/exec/factor)")
    ap.add_argument("--kind", choices=("cholesky", "lu"), default="cholesky",
                    help="factorization kind for --mode factor")
    ap.add_argument("--n", type=int, default=2048,
                    help="matrix order for --mode factor")
    ap.add_argument("--panel", type=int, default=256,
                    help="panel width for --mode factor")
    ap.add_argument("--lookahead", type=int, default=1,
                    help="lookahead depth for --mode factor")
    ap.add_argument("--hw", choices=sorted(HW), default="gpu",
                    help="hardware model for --mode sim")
    ap.add_argument("-o", "--out", default="trace.json",
                    help="output path; '-' writes the JSON to stdout "
                         "(informational output moves to stderr)")
    ap.add_argument("--summary", action="store_true",
                    help="print a per-pid digest (lane, span count, busy "
                         "ms per category, modeled byte totals)")
    args = ap.parse_args()

    global log
    if args.out == "-":
        log = lambda *a, **kw: print(*a, file=sys.stderr, **kw)  # noqa: E731

    if args.mode == "hybrid":
        _hybrid_mode(args)
        return
    if args.mode == "factor":
        _factor_mode(args)
        return

    budget = int(args.budget_mb * 2**20)
    bpe = 4
    part = plan_gemm_partition(args.M, args.N, args.K, budget, bpe,
                               nbuf=args.nbuf, nstreams=args.nstreams)
    sched = build_gemm_schedule(part, nstreams=args.nstreams, nbuf=args.nbuf,
                                traversal=args.traversal, evict=args.evict)
    name = (f"gemm {args.M}x{args.N}x{args.K} h{part.h}xw{part.w} "
            f"s{args.nstreams}b{args.nbuf} {args.traversal}/{args.evict}")

    analysis = None
    if args.mode == "sim":
        hw = HW[args.hw](args.nstreams)
        res = simulate(sched, hw)
        spans = res.op_spans
        analysis = TraceAnalysis.from_sim(sched, res, hw=hw).digest()
        log(f"{name}: {len(sched.ops)} ops, "
            f"simulated makespan {res.makespan*1e3:.2f} ms on {args.hw}")
    else:
        rng = np.random.default_rng(0)
        A = rng.standard_normal((args.M, args.K)).astype(np.float32)
        B = rng.standard_normal((args.K, args.N)).astype(np.float32)
        C = np.zeros((args.M, args.N), dtype=np.float32)
        ex = ScheduleExecutor(record_spans=True)
        HostOocRuntime(executor=ex).gemm(A, B, C, 1.0, 0.0, part,
                                         schedule=sched)
        spans = ex.last_spans
        total = max(e for _, _, _, e in spans)
        analysis = TraceAnalysis.from_spans(sched, spans).digest()
        log(f"{name}: {len(spans)} ops executed in {total*1e3:.1f} ms wall")

    doc = chrome_trace(spans, process_name=name, reuse=sched.reuse)
    doc["otherData"] = {"h2d_bytes": sched.total_bytes(OpKind.H2D),
                        "d2h_bytes": sched.total_bytes(OpKind.D2H),
                        "analysis": analysis}
    _emit(doc, args)


if __name__ == "__main__":
    main()
