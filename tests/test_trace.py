"""Chrome-trace export: simulator spans and real executor timings."""

import json

import numpy as np

from repro.core import (HostOocRuntime, ScheduleExecutor,
                        build_gemm_schedule, chrome_trace, gpu_like,
                        plan_gemm_partition, simulate, write_chrome_trace)


def _sched():
    part = plan_gemm_partition(512, 384, 256, 1_000_000, 4)
    return part, build_gemm_schedule(part, nstreams=2, nbuf=2)


def test_sim_result_to_chrome_trace():
    part, sched = _sched()
    res = simulate(sched, gpu_like())
    trace = res.to_chrome_trace()
    events = trace["traceEvents"]
    xs = [e for e in events if e["ph"] == "X"]
    assert len(xs) == len(sched.ops)
    by_name = {e["name"]: e for e in xs}
    for tag, stream, start, end in res.op_spans:
        e = by_name[tag]
        assert e["tid"] == stream
        assert e["ts"] == start * 1e6
        assert e["dur"] >= 0
    # categories follow the schedule's tag grammar
    assert by_name["DGEMM[0]"]["cat"] == "compute"
    assert all(e["cat"] == "h2d" for e in xs if e["name"].startswith("S("))
    assert all(e["cat"] == "d2h" for e in xs if e["name"].startswith("R("))
    # metadata names one thread per stream
    tids = {e["tid"] for e in events if e["name"] == "thread_name"}
    assert tids == {0, 1}
    json.dumps(trace)  # serializable as-is


def test_executor_records_real_spans(rng):
    part, sched = _sched()
    A = rng.standard_normal((512, 256)).astype(np.float32)
    B = rng.standard_normal((256, 384)).astype(np.float32)
    C = rng.standard_normal((512, 384)).astype(np.float32)
    ex = ScheduleExecutor(record_spans=True)
    out = HostOocRuntime(executor=ex).gemm(A, B, C, 1.0, 1.0, part,
                                           schedule=sched)
    expect = A.astype(np.float64) @ B + C
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)

    spans = ex.last_spans
    assert len(spans) == len(sched.ops)
    assert [t for t, _, _, _ in spans] == [o.tag for o in sched.ops]
    prev_start = 0.0
    for tag, stream, start, end in spans:
        assert end >= start >= prev_start >= 0.0  # serialized dispatch order
        prev_start = start
    # the recorded spans feed the same trace exporter as the simulator
    trace = chrome_trace(spans, process_name="exec")
    assert sum(e["ph"] == "X" for e in trace["traceEvents"]) == len(spans)


def test_write_chrome_trace_file(tmp_path):
    _, sched = _sched()
    res = simulate(sched, gpu_like())
    path = tmp_path / "trace.json"
    write_chrome_trace(str(path), res.op_spans)
    loaded = json.loads(path.read_text())
    assert loaded["displayTimeUnit"] == "ms"
    assert any(e["ph"] == "X" for e in loaded["traceEvents"])


def test_record_spans_off_by_default(rng):
    part, sched = _sched()
    ex = ScheduleExecutor()
    A = rng.standard_normal((512, 256)).astype(np.float32)
    B = rng.standard_normal((256, 384)).astype(np.float32)
    C = np.zeros((512, 384), np.float32)
    HostOocRuntime(executor=ex).gemm(A, B, C, 1.0, 0.0, part, schedule=sched)
    assert ex.last_spans == []
