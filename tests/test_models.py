"""Per-arch smoke tests (reduced configs) + model-internals correctness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.kernels import ref
from repro.models import get_model
from repro.models.layers import blockwise_causal_attention, cache_update
from repro.models.mamba2 import ssd_chunked, ssd_scan_ref
from repro.models.rwkv6 import wkv_associative, wkv_chunked, wkv_scan_ref

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------- per-arch smoke
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    """Reduced same-family config: one forward + one train step on CPU,
    asserting output shapes and finiteness (the assignment's smoke)."""
    from repro.optim import AdamWConfig
    from repro.training import steps as tsteps

    cfg = get_arch(arch).smoke()
    model = get_model(cfg)
    B, S = 2, 32
    if cfg.embedding_input:
        inputs = jax.random.normal(KEY, (B, S, cfg.d_model), jnp.float32)
    else:
        inputs = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    labels = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)

    logits = jax.jit(model.forward)(model.init(KEY), inputs)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())

    state = tsteps.init_train_state(model, KEY, AdamWConfig())
    step = jax.jit(tsteps.build_train_step(model, AdamWConfig(lr=1e-3)))
    state, metrics = step(state, {"inputs": inputs, "labels": labels})
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if get_arch(a).causal])
def test_arch_smoke_decode(arch):
    """Prefill + a few decode steps: shapes, finiteness, cache length."""
    cfg = get_arch(arch).smoke()
    model = get_model(cfg)
    B, S = 2, 16
    if cfg.embedding_input:
        prompt = jax.random.normal(KEY, (B, S, cfg.d_model), jnp.float32)
    else:
        prompt = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    params = model.init(KEY)
    logits, cache = jax.jit(
        lambda p, t: model.prefill(p, t, max_len=S + 4))(params, prompt)
    assert logits.shape == (B, cfg.vocab_size)
    decode = jax.jit(model.decode)
    for i in range(3):
        tok = jnp.argmax(logits, axis=-1)
        logits, cache = decode(params, cache, tok)
        assert bool(jnp.isfinite(logits).all())
    assert int(cache["len"][0]) == S + 3


def test_decode_matches_forward_dense():
    """Teacher-forced decode must reproduce the training forward's logits
    (the KV-cache path is numerically the same function)."""
    cfg = get_arch("llama3.2-3b").smoke()
    model = get_model(cfg)
    params = model.init(KEY)
    B, S = 2, 12
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full = model.forward(params, toks)              # (B, S, V)

    logits_p, cache = model.prefill(params, toks[:, :5], max_len=S)
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(full[:, 4]),
                               rtol=2e-3, atol=2e-3)
    logits = logits_p
    for t in range(5, S):
        logits, cache = model.decode(params, cache, toks[:, t])
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full[:, t]),
            rtol=2e-3, atol=2e-3)


def test_decode_matches_forward_ssm():
    cfg = get_arch("rwkv6-1.6b").smoke()
    model = get_model(cfg)
    params = model.init(KEY)
    B, S = 1, 10
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full = model.forward(params, toks)
    logits, cache = model.prefill(params, toks[:, :4])
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, 3]),
                               rtol=2e-3, atol=2e-3)
    for t in range(4, S):
        logits, cache = model.decode(params, cache, toks[:, t])
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full[:, t]),
                                   rtol=2e-3, atol=2e-3)


def test_decode_matches_forward_mamba():
    cfg = get_arch("zamba2-1.2b").smoke().replace(shared_attn_every=0)
    # pure-mamba variant via family ssm
    cfg = cfg.replace(shared_attn_every=0)
    from repro.models.mamba2 import Mamba2Model
    cfg2 = get_arch("zamba2-1.2b").smoke()
    m = Mamba2Model(cfg2.replace(shared_attn_every=0, family="ssm"))
    params = m.init(KEY)
    B, S = 1, 8
    toks = jax.random.randint(KEY, (B, S), 0, cfg2.vocab_size)
    full = m.forward(params, toks)
    logits, cache = m.prefill(params, toks[:, :3])
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, 2]),
                               rtol=2e-3, atol=2e-3)
    for t in range(3, S):
        logits, cache = m.decode(params, cache, toks[:, t])
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full[:, t]),
                                   rtol=2e-3, atol=2e-3)


# ----------------------------------------------------------- layer invariants
def test_blockwise_attention_equals_reference(rng):
    B, S, H, hkv, d = 2, 64, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((B, S, H, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, hkv, d)), jnp.float32)
    for bq in (16, 32, 64):
        out = blockwise_causal_attention(q, k, v, block_q=bq)
        expect = ref.causal_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=1e-4, atol=1e-4)
    # unrolled == scanned
    out_u = blockwise_causal_attention(q, k, v, block_q=16, unroll=True)
    np.testing.assert_allclose(np.asarray(out_u), np.asarray(expect),
                               rtol=1e-4, atol=1e-4)


def test_cache_update_writes_at_length(rng):
    B, S, hkv, d = 3, 16, 2, 8
    cache = jnp.zeros((B, S, hkv, d), jnp.float32)
    new = jnp.asarray(rng.standard_normal((B, hkv, d)), jnp.float32)
    lengths = jnp.asarray([0, 5, 15], jnp.int32)
    out = cache_update(cache, new, lengths)
    for b, l in enumerate([0, 5, 15]):
        np.testing.assert_allclose(np.asarray(out[b, l]),
                                   np.asarray(new[b]))
        rest = np.delete(np.asarray(out[b]), l, axis=0)
        assert (rest == 0).all()


# --------------------------------------------------------------- SSM oracles
def test_ssd_chunked_matches_scan(rng):
    B, S, H, P, N = 2, 64, 3, 8, 5
    x = jnp.asarray(rng.standard_normal((B, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 1.0, (B, S, H)), jnp.float32)
    a = jnp.asarray(rng.uniform(0.3, 0.99, (B, S, H)), jnp.float32)
    B_ = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    C_ = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    y0, h0 = ssd_scan_ref(x, dt, a, B_, C_)
    for chunk in (8, 16, 64):
        y1, h1 = ssd_chunked(x, dt, a, B_, C_, chunk=chunk)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1, np.float32),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(h0), np.asarray(h1),
                                   rtol=1e-4, atol=1e-4)
    yu, hu = ssd_chunked(x, dt, a, B_, C_, chunk=16, unroll=True)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(yu, np.float32),
                               rtol=1e-4, atol=1e-4)


def test_wkv_variants_match(rng):
    B, S, H, P = 2, 48, 3, 8
    mk = lambda: jnp.asarray(rng.standard_normal((B, S, H, P)), jnp.float32)
    r, k, v = mk(), mk(), mk()
    w = jnp.asarray(rng.uniform(0.5, 0.99, (B, S, H, P)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((H, P)), jnp.float32)
    m0 = jnp.asarray(rng.standard_normal((B, H, P, P)), jnp.float32)
    y0, M0 = wkv_scan_ref(r, k, v, w, u, m0=m0)
    y1, M1 = wkv_associative(r, k, v, w, u, m0=m0)
    y2, M2 = wkv_chunked(r, k, v, w, u, chunk=16, m0=m0)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(M0), np.asarray(M1),
                               rtol=1e-4, atol=1e-4)


def test_moe_capacity_drops_are_bounded(rng):
    """With capacity_factor >= 1 and uniform routing, most tokens route."""
    from repro.models.moe import moe_apply, moe_init
    D, F, E, k = 16, 32, 8, 2
    p = moe_init(KEY, D, F, E)
    x = jnp.asarray(rng.standard_normal((2, 64, D)), jnp.float32)
    y = moe_apply(p, x, top_k=k, capacity_factor=2.0)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    # zero-capacity sanity: with tiny capacity the output shrinks, not NaNs
    y2 = moe_apply(p, x, top_k=k, capacity_factor=0.1)
    assert bool(jnp.isfinite(y2).all())
    assert float(jnp.abs(y2).mean()) <= float(jnp.abs(y).mean()) + 1e-6
