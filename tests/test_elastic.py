"""Elastic rescale: a checkpoint written under one mesh restores onto a
different device count/topology (the fault-tolerance contract for node
loss / cluster resize).  Subprocess-per-mesh because XLA pins the host
device count at first init.
"""

import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os, sys
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={sys.argv[1]}"
import json
import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import make_mesh
from repro.checkpoint import CheckpointManager
from repro.configs import get_arch
from repro.distributed import tree_shardings
from repro.models import get_model
from repro.optim import AdamWConfig
from repro.training import steps as tsteps

ndev, mode, ckpt = int(sys.argv[1]), sys.argv[2], sys.argv[3]
mesh = make_mesh((ndev // 2, 2), ("data", "model"))
cfg = get_arch("stablelm-1.6b").smoke().replace(num_heads=4, num_kv_heads=4)
model = get_model(cfg)
opt = AdamWConfig()
sds = jax.eval_shape(
    lambda: tsteps.init_train_state(model, jax.random.PRNGKey(0), opt))
shardings = tree_shardings(
    tsteps.train_state_logical_axes(model, True), sds, mesh)
mgr = CheckpointManager(ckpt)

if mode == "save":
    with mesh:
        state = jax.jit(lambda: tsteps.init_train_state(
            model, jax.random.PRNGKey(0), opt),
            out_shardings=shardings)()
    # one real step so the state is non-trivial
    step = jax.jit(tsteps.build_train_step(model, opt),
                   in_shardings=(shardings, None),
                   out_shardings=(shardings, None))
    batch = {"inputs": jnp.zeros((8, 16), jnp.int32),
             "labels": jnp.zeros((8, 16), jnp.int32)}
    state, _ = step(state, batch)
    mgr.save(1, state, data_cursor=1, blocking=True)
    ck = float(sum(jnp.sum(jnp.abs(x.astype(jnp.float32)))
                   for x in jax.tree.leaves(state["params"])))
    print(json.dumps({"checksum": ck}))
else:
    state, cursor = mgr.restore(1, sds, shardings)
    assert cursor == 1
    # verify placement matches THIS mesh and values survived
    lead = jax.tree.leaves(state["params"])[0]
    assert len(lead.sharding.mesh.devices.flatten()) == ndev
    ck = float(sum(jnp.sum(jnp.abs(x.astype(jnp.float32)))
                   for x in jax.tree.leaves(state["params"])))
    print(json.dumps({"checksum": ck}))
"""


@pytest.mark.slow
def test_checkpoint_restores_on_different_mesh(tmp_path):
    env = dict(os.environ, PYTHONPATH=os.path.join(
        os.path.dirname(__file__), "..", "src"))
    ck = str(tmp_path / "ck")

    def run(ndev, mode):
        out = subprocess.run(
            [sys.executable, "-c", SCRIPT, str(ndev), mode, ck],
            env=env, capture_output=True, text=True, timeout=600)
        assert out.returncode == 0, out.stderr[-3000:]
        return json.loads(out.stdout.strip().splitlines()[-1])

    saved = run(8, "save")          # 4x2 mesh
    restored = run(4, "restore")    # 2x2 mesh — "half the cluster died"
    assert abs(saved["checksum"] - restored["checksum"]) \
        <= 1e-5 * abs(saved["checksum"])
    grown = run(8, "restore")       # scale back up
    assert abs(saved["checksum"] - grown["checksum"]) \
        <= 1e-5 * abs(saved["checksum"])
