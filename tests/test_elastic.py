"""Elastic rescale: a checkpoint written under one mesh restores onto a
different device count/topology (the fault-tolerance contract for node
loss / cluster resize).  Subprocess-per-mesh because XLA pins the host
device count at first init.

Each subprocess reports a parameter checksum AND the model loss on a
deterministic batch, so rescales are checked for *loss parity* — the
restored model must behave identically, not merely carry the same bytes.
"""

import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os, sys
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={sys.argv[1]}"
import json
import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import make_mesh
from repro.checkpoint import CheckpointManager
from repro.configs import get_arch
from repro.distributed import tree_shardings
from repro.models import get_model
from repro.optim import AdamWConfig
from repro.training import steps as tsteps

ndev, shape, mode, ckpt = (int(sys.argv[1]), sys.argv[2], sys.argv[3],
                           sys.argv[4])
rows, cols = (int(x) for x in shape.split("x"))
assert rows * cols == ndev, (shape, ndev)
mesh = make_mesh((rows, cols), ("data", "model"))
cfg = get_arch("stablelm-1.6b").smoke().replace(num_heads=4, num_kv_heads=4)
model = get_model(cfg)
opt = AdamWConfig()
sds = jax.eval_shape(
    lambda: tsteps.init_train_state(model, jax.random.PRNGKey(0), opt))
shardings = tree_shardings(
    tsteps.train_state_logical_axes(model, True), sds, mesh)
mgr = CheckpointManager(ckpt)

batch = {"inputs": jnp.arange(8 * 16, dtype=jnp.int32).reshape(8, 16) % 64,
         "labels": jnp.ones((8, 16), jnp.int32)}

def eval_loss(state):
    loss_fn = jax.jit(tsteps.build_loss_fn(model))
    return float(loss_fn(state["params"], batch))

if mode == "save":
    with mesh:
        state = jax.jit(lambda: tsteps.init_train_state(
            model, jax.random.PRNGKey(0), opt),
            out_shardings=shardings)()
    # one real step so the state is non-trivial
    step = jax.jit(tsteps.build_train_step(model, opt),
                   in_shardings=(shardings, None),
                   out_shardings=(shardings, None))
    tb = {"inputs": jnp.zeros((8, 16), jnp.int32),
          "labels": jnp.zeros((8, 16), jnp.int32)}
    state, _ = step(state, tb)
    mgr.save(1, state, data_cursor=1, blocking=True)
    ck = float(sum(jnp.sum(jnp.abs(x.astype(jnp.float32)))
                   for x in jax.tree.leaves(state["params"])))
    print(json.dumps({"checksum": ck, "loss": eval_loss(state)}))
else:
    state, cursor = mgr.restore(1, sds, shardings)
    assert cursor == 1
    # verify placement matches THIS mesh and values survived
    lead = jax.tree.leaves(state["params"])[0]
    assert len(lead.sharding.mesh.devices.flatten()) == ndev
    assert lead.sharding.mesh.devices.shape == (rows, cols)
    ck = float(sum(jnp.sum(jnp.abs(x.astype(jnp.float32)))
                   for x in jax.tree.leaves(state["params"])))
    print(json.dumps({"checksum": ck, "loss": eval_loss(state)}))
"""


def _runner(tmp_path):
    env = dict(os.environ, PYTHONPATH=os.path.join(
        os.path.dirname(__file__), "..", "src"))
    ck = str(tmp_path / "ck")

    def run(ndev, shape, mode):
        out = subprocess.run(
            [sys.executable, "-c", SCRIPT, str(ndev), shape, mode, ck],
            env=env, capture_output=True, text=True, timeout=600)
        assert out.returncode == 0, out.stderr[-3000:]
        return json.loads(out.stdout.strip().splitlines()[-1])

    return run


def _assert_parity(saved, restored, what):
    assert abs(saved["checksum"] - restored["checksum"]) \
        <= 1e-5 * abs(saved["checksum"]), what
    assert abs(saved["loss"] - restored["loss"]) \
        <= 1e-4 * max(abs(saved["loss"]), 1e-8), what


@pytest.mark.slow
def test_checkpoint_restores_on_different_mesh(tmp_path):
    run = _runner(tmp_path)
    saved = run(8, "4x2", "save")
    restored = run(4, "2x2", "restore")  # "half the cluster died"
    _assert_parity(saved, restored, "8 -> 4 devices")
    grown = run(8, "4x2", "restore")     # scale back up
    _assert_parity(saved, grown, "4 -> 8 devices")


@pytest.mark.slow
def test_checkpoint_rescale_shrink_and_repartition(tmp_path):
    """The coverage the single test above missed: a shrink that halves a
    4-device mesh (4 -> 2), and a restore onto the SAME device count with a
    changed partition config (2x2 data-parallel-heavy -> 4x1 pure
    data-parallel) — both must preserve the deterministic-batch loss."""
    run = _runner(tmp_path)
    saved = run(4, "2x2", "save")
    shrunk = run(2, "1x2", "restore")    # 4 -> 2 devices
    _assert_parity(saved, shrunk, "4 -> 2 devices")
    repart = run(4, "4x1", "restore")    # same devices, new partitioning
    _assert_parity(saved, repart, "2x2 -> 4x1 repartition")
