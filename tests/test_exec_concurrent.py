"""Concurrent event-driven executor conformance (ISSUE 10, DESIGN.md §13).

Three families:

  * **differential conformance** — ``mode="concurrent"`` must be
    bitwise-identical to the serial oracle on the full kernel corpus
    (GEMM / SYRK / attention / Cholesky / LU x traversal x eviction
    policy), with byte counters exactly equal to ``schedule_stats`` and a
    completion order that is a linear extension of the dependency partial
    order (the ``test_properties.py`` contract, shared with the simulator).
  * **ExecutablePlan cache** — identity-keyed hits, invalidation on op
    mutation and on late handler registration, instance-handler overrides.
  * **concurrency safety** — a seeded stress run (many schedules x
    repeated runs) under a ``faulthandler`` deadlock watchdog, and a
    regression test that metric publishing from engine threads is
    thread-safe.
"""

import dataclasses
import faulthandler
import threading

import numpy as np
import pytest

import repro.core.ooc_attention  # noqa: F401  (registers attn handlers)
from repro.core import (
    ScheduleExecutor,
    build_attention_schedule,
    build_gemm_schedule,
    build_syrk_schedule,
    compile_executable,
    compile_factor_pipeline,
    factor_pipeline_spec,
    plan_attention_partition,
    plan_cache_stats,
    plan_gemm_partition,
    register_op_handler,
    schedule_stats,
    validate_schedule,
)
from repro.core.streams import BlockRef, dependency_edges

# stress/deadlock hard timeout (seconds): generous vs the ~seconds the
# corpus actually needs, tight enough that CI fails fast with a traceback
# dump of every thread instead of hanging to the job timeout
WATCHDOG_S = 300.0


# --------------------------------------------------------------- helpers
def _assert_linear_extension(sched, order):
    """``order`` (issue indices in completion order) covers every op once
    and never completes a dependent before its dependency."""
    n = len(sched.ops)
    assert sorted(order) == list(range(n)), "completion order is not a permutation"
    pos = {op_idx: k for k, op_idx in enumerate(order)}
    _, preds = dependency_edges(sched)
    for succ in range(n):
        for pred in preds[succ]:
            assert pos[pred] < pos[succ], (
                f"{sched.ops[succ].tag} completed before its dependency "
                f"{sched.ops[pred].tag}")


def _run_pair(sched, operands, make_outputs, ctx):
    """Run serial then concurrent; assert bitwise outputs, exact byte
    counters, and completion-order legality.  Returns the serial outputs."""
    validate_schedule(sched)
    stats = schedule_stats(sched)
    results = {}
    for mode in ("issue_order", "concurrent"):
        ex = ScheduleExecutor(mode=mode)
        outs = make_outputs()
        ex.run(sched, operands, outs, ctx)
        assert ex.last_h2d_bytes == stats["h2d_bytes"], mode
        assert ex.last_d2h_bytes == stats["d2h_bytes"], mode
        results[mode] = (outs, ex)
    serial, conc = results["issue_order"], results["concurrent"]
    for key in serial[0]:
        assert np.array_equal(serial[0][key], conc[0][key]), (
            f"concurrent output {key!r} diverged from serial")
    _assert_linear_extension(sched, conc[1].last_completion_order)
    assert serial[1].last_completion_order == list(range(len(sched.ops)))
    return serial[0]


def _gemm_case(rng, M=256, N=256, K=192, frac=3, **build_kw):
    A = rng.standard_normal((M, K)).astype(np.float32)
    B = rng.standard_normal((K, N)).astype(np.float32)
    C = rng.standard_normal((M, N)).astype(np.float32)
    budget = (A.nbytes + B.nbytes + C.nbytes) // frac
    while True:
        try:
            part = plan_gemm_partition(M, N, K, budget, 4,
                                       nbuf=build_kw.get("nbuf"),
                                       nstreams=build_kw.get("nstreams"))
            break
        except ValueError:
            # small random shapes (stress sweep) can undershoot the minimum
            # aligned working set; a bigger budget still yields a valid
            # (possibly shallower) OOC schedule
            budget *= 2
    sched = build_gemm_schedule(part, **build_kw)
    return A, B, C, sched


# ------------------------------------------------- corpus conformance
@pytest.mark.parametrize("traversal", ["col", "row", "serpentine"])
@pytest.mark.parametrize("evict", ["lru", "belady"])
def test_gemm_concurrent_matches_serial(traversal, evict):
    rng = np.random.default_rng(11)
    A, B, C, sched = _gemm_case(rng, nstreams=2, nbuf=2,
                                traversal=traversal, evict=evict)
    out = _run_pair(sched, {"A": A, "B": B},
                    lambda: {"C": np.array(C, copy=True)},
                    {"alpha": 1.5, "beta": 0.5})
    assert np.abs(out["C"] - (1.5 * A @ B + 0.5 * C)).max() < 1e-2


@pytest.mark.parametrize("nstreams,nbuf", [(1, 1), (2, 2), (3, 2)])
def test_gemm_concurrent_stream_depth_sweep(nstreams, nbuf):
    rng = np.random.default_rng(12)
    A, B, C, sched = _gemm_case(rng, nstreams=nstreams, nbuf=nbuf)
    _run_pair(sched, {"A": A, "B": B},
              lambda: {"C": np.array(C, copy=True)},
              {"alpha": 1.0, "beta": 1.0})


@pytest.mark.parametrize("traversal", ["col", "row"])
def test_syrk_concurrent_matches_serial(traversal):
    rng = np.random.default_rng(13)
    n, K = 256, 192
    P = rng.standard_normal((n, K)).astype(np.float32)
    C = rng.standard_normal((n, n)).astype(np.float32)
    part = plan_gemm_partition(n, n, K, (2 * P.nbytes + C.nbytes) // 2, 4,
                               nbuf=2, nstreams=2)
    sched = build_syrk_schedule(part, nstreams=2, nbuf=2,
                                traversal=traversal)
    out = _run_pair(sched, {"P": P},
                    lambda: {"C": np.array(C, copy=True)},
                    {"alpha": 1.0, "beta": 0.5})
    assert np.abs(out["C"] - (P @ P.T + 0.5 * C)).max() < 1e-2


def test_attention_concurrent_matches_serial():
    import jax.numpy as jnp

    rng = np.random.default_rng(14)
    S, hkv, d, H = 512, 2, 64, 8
    kc = rng.standard_normal((S, hkv, d)).astype(np.float32)
    vc = rng.standard_normal((S, hkv, d)).astype(np.float32)
    q = jnp.asarray(rng.standard_normal((H, d)).astype(np.float32))
    part = plan_attention_partition(S, hkv, d, kc.nbytes, bytes_per_el=4)
    sched = build_attention_schedule(part, hkv, d, H, nstreams=2, nbuf=2)
    _run_pair(sched, {"K": kc, "V": vc},
              lambda: {"out": np.zeros((H, d), np.float32)}, {"q": q})


@pytest.mark.parametrize("kind", ["cholesky", "lu"])
def test_factor_concurrent_matches_serial(kind):
    rng = np.random.default_rng(15)
    n = 384
    A = rng.standard_normal((n, n)).astype(np.float64)
    if kind == "cholesky":
        A = A @ A.T + n * np.eye(n)
    spec = factor_pipeline_spec(n, 128, 3 * n * n * 8, 8, kind=kind)
    sched = compile_factor_pipeline(spec, nstreams=2, nbuf=2)
    _run_pair(sched, {}, lambda: {"A": np.array(A, copy=True)},
              {"alpha": -1.0, "beta": 1.0, "panel": spec.panel,
               "n": spec.n})


def test_concurrent_spans_cover_every_op_and_feed_analysis():
    """record_spans in concurrent mode: one span per op, per-stream starts
    monotone (each engine walks its queue in issue order), and the spans
    are consumable by TraceAnalysis's wall-clock mode."""
    from repro.obs.analyze import TraceAnalysis

    rng = np.random.default_rng(16)
    A, B, C, sched = _gemm_case(rng, nstreams=2, nbuf=2)
    ex = ScheduleExecutor(mode="concurrent", record_spans=True)
    out = {"C": np.array(C, copy=True)}
    ex.run(sched, {"A": A, "B": B}, out, {"alpha": 1.0, "beta": 0.0})
    spans = ex.last_spans
    assert len(spans) == len(sched.ops)
    assert sorted(tag for tag, *_ in spans) \
        == sorted(op.tag for op in sched.ops)
    for _, _, t0, t1 in spans:
        assert t1 >= t0 >= 0.0
    ana = TraceAnalysis.from_spans(sched, spans)
    assert ana.n_ops == len(sched.ops)
    assert ana.h2d_bytes == schedule_stats(sched)["h2d_bytes"]


# ------------------------------------------------- ExecutablePlan cache
def test_plan_cache_identity_hit():
    rng = np.random.default_rng(17)
    *_, sched = _gemm_case(rng)
    before = plan_cache_stats()
    p1 = compile_executable(sched)
    p2 = compile_executable(sched)
    after = plan_cache_stats()
    assert p1 is p2
    assert after["misses"] == before["misses"] + 1
    assert after["hits"] >= before["hits"] + 1


def test_plan_cache_invalidated_on_op_mutation():
    rng = np.random.default_rng(18)
    *_, sched = _gemm_case(rng)
    p1 = compile_executable(sched)
    i = next(idx for idx, op in enumerate(sched.ops)
             if isinstance(op.payload, BlockRef))
    sched.ops[i] = dataclasses.replace(sched.ops[i])   # fresh object, same op
    p2 = compile_executable(sched)
    assert p2 is not p1


def test_plan_cache_invalidated_on_handler_registration():
    rng = np.random.default_rng(19)
    *_, sched = _gemm_case(rng)
    p1 = compile_executable(sched)
    register_op_handler("_test_exec_plan_dummy")(lambda st, op, ref: None)
    p2 = compile_executable(sched)
    assert p2 is not p1, "late registration must invalidate cached plans"


def test_unknown_kernel_raises_in_concurrent_mode():
    rng = np.random.default_rng(20)
    A, B, C, sched = _gemm_case(rng)
    i = next(idx for idx, op in enumerate(sched.ops)
             if isinstance(op.payload, BlockRef))
    sched.ops[i] = dataclasses.replace(
        sched.ops[i], payload=BlockRef("definitely_not_registered", 0))
    ex = ScheduleExecutor(mode="concurrent")
    with pytest.raises(KeyError, match="definitely_not_registered"):
        ex.run(sched, {"A": A, "B": B}, {"C": np.array(C, copy=True)},
               {"alpha": 1.0, "beta": 0.0})


def test_instance_handlers_override_plan_resolution():
    rng = np.random.default_rng(21)
    A, B, C, sched = _gemm_case(rng)
    calls = []

    def spy(st, op, ref):
        calls.append(op.tag)
        from repro.core.runtime import _dgemm_handler
        _dgemm_handler(st, op, ref)

    compile_executable(sched)   # pre-resolve against the global registry
    ex = ScheduleExecutor(handlers={"dgemm": spy}, mode="concurrent")
    out = {"C": np.array(C, copy=True)}
    ex.run(sched, {"A": A, "B": B}, out, {"alpha": 1.5, "beta": 0.5})
    assert calls, "instance handler was never consulted"
    assert np.abs(out["C"] - (1.5 * A @ B + 0.5 * C)).max() < 1e-2


def test_faults_fall_back_to_serial_and_recover():
    from repro.core.streams import OpKind
    from repro.fault import FaultPlan, FaultSpec

    rng = np.random.default_rng(22)
    A, B, C, sched = _gemm_case(rng)
    ref = _run_pair(sched, {"A": A, "B": B},
                    lambda: {"C": np.array(C, copy=True)},
                    {"alpha": 1.0, "beta": 1.0})
    h2d = next(i for i, op in enumerate(sched.ops)
               if op.kind == OpKind.H2D)
    plan = FaultPlan(specs=(FaultSpec(op=h2d, cls="h2d_error", times=1),))
    ex = ScheduleExecutor(mode="concurrent")
    out = {"C": np.array(C, copy=True)}
    ex.run(sched, {"A": A, "B": B}, out, {"alpha": 1.0, "beta": 1.0},
           faults=plan)
    assert ex.last_fault_stats["injected"] == 1
    assert ex.last_fault_stats["recovered_retry"] == 1
    assert np.array_equal(out["C"], ref["C"]), \
        "fault fallback must still match the fault-free result"


# ------------------------------------------------- concurrency safety
def test_concurrent_stress_seeded_with_watchdog():
    """Many schedule shapes x repeated runs on one executor: results must
    stay bitwise-stable across reps (no lost updates, no reordering races).
    A faulthandler watchdog turns any deadlock into a traceback dump of
    every thread plus a hard interpreter exit instead of a silent hang."""
    faulthandler.dump_traceback_later(WATCHDOG_S, exit=True)
    try:
        rng = np.random.default_rng(20260808)
        ex = ScheduleExecutor(mode="concurrent")
        for _ in range(6):
            M, N, K = (int(v) * 64 for v in rng.integers(2, 5, size=3))
            nstreams = int(rng.integers(1, 4))
            nbuf = int(rng.integers(1, 4))
            traversal = ["col", "row", "serpentine"][int(rng.integers(3))]
            A, B, C, sched = _gemm_case(
                rng, M=M, N=N, K=K, nstreams=nstreams, nbuf=nbuf,
                traversal=traversal)
            stats = schedule_stats(sched)
            ref = None
            for _rep in range(3):
                out = {"C": np.array(C, copy=True)}
                ex.run(sched, {"A": A, "B": B}, out,
                       {"alpha": 1.0, "beta": 0.5})
                assert ex.last_h2d_bytes == stats["h2d_bytes"]
                assert ex.last_d2h_bytes == stats["d2h_bytes"]
                _assert_linear_extension(sched,
                                         ex.last_completion_order)
                if ref is None:
                    ref = out["C"]
                else:
                    assert np.array_equal(out["C"], ref), (
                        f"run-to-run divergence on {M}x{N}x{K} "
                        f"ns={nstreams} nbuf={nbuf} {traversal}")
    finally:
        faulthandler.cancel_dump_traceback_later()


def test_metric_publishing_from_engine_threads_is_thread_safe():
    """Regression: the one-lock MetricRegistry must survive concurrent
    publishes — both raw increments hammered from worker threads and full
    executor runs racing each other (engine threads publish through
    ``record_executor_run`` at run end and handlers may publish inline)."""
    from repro.obs import get_observability

    obs = get_observability()
    obs.reset()
    obs.enable(metrics=True)
    try:
        reg = obs.metrics
        c = reg.counter("repro_test_engine_total", "stress counter")
        threads = [
            threading.Thread(
                target=lambda: [c.inc(kernel="stress")
                                for _ in range(500)])
            for _ in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert c.value(kernel="stress") == 8 * 500

        # whole executor runs racing: per-run aggregates must still sum
        rng = np.random.default_rng(23)
        A, B, C, sched = _gemm_case(rng, M=128, N=128, K=128, frac=2)
        stats = schedule_stats(sched)
        n_runs = 4

        def one_run():
            ex = ScheduleExecutor(mode="concurrent")
            ex.run(sched, {"A": A, "B": B},
                   {"C": np.array(C, copy=True)},
                   {"alpha": 1.0, "beta": 0.0})

        runners = [threading.Thread(target=one_run)
                   for _ in range(n_runs)]
        for t in runners:
            t.start()
        for t in runners:
            t.join()
        kernel = sched.meta.get("kernel", "run")
        assert reg.get("repro_executor_runs_total").value(
            kernel=kernel) == n_runs
        assert reg.get("repro_executor_h2d_bytes").value(
            kernel=kernel) == n_runs * stats["h2d_bytes"]
    finally:
        obs.reset()
