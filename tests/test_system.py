"""End-to-end system behaviour: the paper's claims as executable assertions."""

import numpy as np
import pytest

from repro.core import (
    build_gemm_schedule,
    build_vendor_schedule,
    gpu_like,
    ooc_gemm,
    phi_like,
    plan_gemm_partition,
    simulate,
    tpu_v5e_vmem,
)


def _part(M=8192, N=8192, K=8192, frac=6):
    full = (M * K + K * N + M * N) * 8
    return plan_gemm_partition(M, N, K, full // frac, 8)


def test_claim_c2_zero_loss_at_ooc_transition():
    """Claim C2: crossing the in-core -> out-of-core boundary loses ~0%
    effective FLOP/s under the overlapped pipeline (simulated on the
    GPU-like engine model the paper measured on)."""
    hw = gpu_like()
    K = 4096

    def gflops(N, budget):
        part = plan_gemm_partition(N, N, K, budget, 8)
        res = simulate(build_gemm_schedule(part, 2, 2), hw)
        return res.effective_flops

    budget = (3 * 4096 * 4096) * 8 * 3  # fits 4k, not 8k
    in_core = gflops(4096, budget)
    out_core = gflops(8192, budget)
    assert out_core >= 0.9 * in_core


def test_claim_c3_beats_vendor_schedule():
    """Claim C3: >= 2.3x over the CUBLAS-XT-style non-overlapping,
    B-resending schedule."""
    part = _part()
    hw = gpu_like()
    t_lib = simulate(build_gemm_schedule(part, 2, 2), hw).makespan
    t_vendor = simulate(build_vendor_schedule(part), hw).makespan
    assert t_vendor / t_lib >= 2.3


def test_claim_c5_overlap_is_hardware_dependent():
    """Claim C5: two streams win on GPU-like engines, one stream wins on
    Phi-like engines."""
    part = _part(8192, 8192, 8192, 6)
    gpu = gpu_like()
    t_gpu_2 = simulate(build_gemm_schedule(part, 2, 2), gpu).makespan
    t_gpu_1 = simulate(build_gemm_schedule(part, 1, 1), gpu).makespan
    assert t_gpu_2 < t_gpu_1
    t_phi_1 = simulate(build_gemm_schedule(part, 1, 2),
                       phi_like(nstreams=1)).makespan
    t_phi_2 = simulate(build_gemm_schedule(part, 2, 2),
                       phi_like(nstreams=2)).makespan
    assert t_phi_1 < t_phi_2
    # magnitude matches the paper: 667 vs 725 GFLOPs ~ 0.92
    assert 0.85 < t_phi_1 / t_phi_2 < 0.99


def test_tpu_vmem_tier_hides_transfers():
    """The TPU adaptation: at 512-blocks the VMEM pipeline is compute-bound
    (DMA fully hidden behind the MXU) — the property the Pallas kernel's
    double buffering provides."""
    part = plan_gemm_partition(4096, 4096, 4096, 6 * 2**20, 2)
    res = simulate(build_gemm_schedule(part, 2, 2), tpu_v5e_vmem())
    assert res.utilization("exec") > 0.85


def test_ooc_equals_incore_numerics(rng):
    """OOC execution is bit-compatible with one-shot DGEMM up to fp32
    accumulation order."""
    M = N = K = 256
    A = rng.standard_normal((M, K)).astype(np.float32)
    B = rng.standard_normal((K, N)).astype(np.float32)
    C = np.zeros((M, N), np.float32)
    big = ooc_gemm(A, B, C, 1.0, 0.0, budget_bytes=1 << 30, backend="host")
    small = ooc_gemm(A, B, C, 1.0, 0.0,
                     budget_bytes=(A.nbytes + B.nbytes + C.nbytes) // 4,
                     backend="host")
    np.testing.assert_allclose(big, small, rtol=1e-4, atol=1e-4)
