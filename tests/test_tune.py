"""Autotuner: C5 stream selection, determinism, plan cache, calibration.

The acceptance bar (ISSUE 2): with a phi-like calibrated model the tuner
selects ``nstreams=1``, with a gpu-like model ``nstreams=2``; the tuned
plan's simulated makespan never exceeds the hardcoded ``(nstreams=2,
nbuf=2)`` default's; and a repeat ``tune="auto"`` call with the same
fingerprint is served from the plan cache without re-searching.
"""

import json

import numpy as np
import pytest

from repro.core import (build_gemm_schedule, gpu_like, ooc_attention,
                        ooc_gemm, phi_like, plan_gemm_partition, simulate,
                        simulate_reference, tpu_v5e_vmem)
from repro.core.ooc_factor import ooc_cholesky
from repro.tune import (AutoTuner, PlanCache, TunedPlan, calibrate,
                        gemm_search_space, gpu_profile, hardware_fingerprint,
                        phi_profile, search_gemm, tpu_v5e_profile)

# paper §VI regime for C5: compute-dominated large square DGEMM
C5_SHAPE = (8192, 8192, 8192)
C5_BUDGET = (3 * 8192 * 8192) * 8 // 6
C5_OPTS = dict(nbuf_options=(1, 2), max_steps=128)  # small space, fast tests


def _tuner(profile, tmp_path, name="fp", **kw):
    opts = {**C5_OPTS, **kw}
    return AutoTuner(profile=profile,
                     cache=PlanCache(str(tmp_path / f"{name}.json")),
                     fingerprint=name, **opts)


# --------------------------------------------------------------- profiles
def test_canned_profiles_match_simulator_models():
    """phi/gpu/tpu profiles must instantiate the simulator's hand-entered
    models engine-for-engine — same pools, rates, split behavior."""
    for ns in (1, 2):
        got = phi_profile().model_for(ns)
        want = phi_like(nstreams=ns)
        assert got.pools == want.pools
        assert got.kind_pool == want.kind_pool
        assert got.compute_split == want.compute_split
        assert got.split_efficiency == want.split_efficiency
        assert (got.h2d_bw, got.d2h_bw, got.flops) == \
            (want.h2d_bw, want.d2h_bw, want.flops)
    assert gpu_profile().model_for(2).pools == gpu_like().pools
    assert gpu_profile().model_for(1).pools == gpu_like().pools
    tpu = tpu_v5e_profile().model_for(2)
    assert tpu.per_op_overhead == tpu_v5e_vmem().per_op_overhead
    assert tpu.pools == {"h2d": 1, "d2h": 1, "exec": 1}


# ------------------------------------------------------------------- space
def test_space_respects_generalized_working_set():
    M, N, K = 2048, 2048, 1024
    budget = (M * K + K * N + M * N) * 4 // 4
    space = gemm_search_space(M, N, K, budget, 4, nbuf_options=(1, 2, 3))
    assert space, "space must not be empty"
    for cand in space:
        # every searched candidate honors the nbuf-aware model; only the
        # marked legacy baseline may exceed it (its 2-deep model
        # undercounts the B ping-pong — the very bug being fixed)
        if not cand.baseline:
            assert cand.part.working_set_bytes(cand.nbuf, cand.nstreams) \
                <= budget
    # the hardcoded default configuration is always a candidate
    default = plan_gemm_partition(M, N, K, budget, 4)
    assert any(c.baseline and c.part.bm == default.bm
               and c.part.bn == default.bn
               and c.nstreams == 2 and c.nbuf == 2 for c in space)


# ---------------------------------------------------------- C5 acceptance
def test_c5_phi_selects_one_stream_gpu_two(tmp_path):
    M, N, K = C5_SHAPE
    phi = _tuner(phi_profile(), tmp_path, "phi")
    gpu = _tuner(gpu_profile(), tmp_path, "gpu")

    p_phi = phi.gemm_plan(M, N, K, C5_BUDGET, dtype="float64")
    p_gpu = gpu.gemm_plan(M, N, K, C5_BUDGET, dtype="float64")

    assert p_phi.nstreams == 1, "Phi-like hardware must run 1 stream (C5)"
    assert p_gpu.nstreams == 2, "GPU-like hardware must run 2 streams (C5)"
    # tuned never loses to the hardcoded default under the same oracle
    assert p_phi.makespan <= p_phi.baseline_makespan + 1e-12
    assert p_gpu.makespan <= p_gpu.baseline_makespan + 1e-12

    # repeat call with the same fingerprint: cache hit, no re-search
    for tuner, plan in ((phi, p_phi), (gpu, p_gpu)):
        searches = tuner.searches
        again = tuner.gemm_plan(M, N, K, C5_BUDGET, dtype="float64")
        assert tuner.last_from_cache
        assert tuner.searches == searches
        assert again == plan


def test_c5_baseline_agrees_with_simulator():
    """The plan's recorded makespans are honest ``simulate()`` numbers."""
    M, N, K = C5_SHAPE
    plan = search_gemm(M, N, K, C5_BUDGET, phi_profile(), dtype="float64",
                       fingerprint="x", **C5_OPTS)
    dpart = plan_gemm_partition(M, N, K, C5_BUDGET, 8)
    want = simulate(build_gemm_schedule(dpart, 2, 2),
                    phi_profile().model_for(2)).makespan
    assert plan.baseline_makespan == pytest.approx(want, rel=1e-12)
    got = simulate(build_gemm_schedule(plan.gemm_partition(),
                                       plan.nstreams, plan.nbuf,
                                       write_back=plan.write_back,
                                       traversal=plan.traversal,
                                       evict=plan.evict),
                   phi_profile().model_for(plan.nstreams)).makespan
    assert plan.makespan == pytest.approx(got, rel=1e-12)


# ------------------------------------------------------------ determinism
def test_search_is_deterministic(tmp_path):
    M, N, K = 4096, 4096, 2048
    budget = (M * K + K * N + M * N) * 4 // 5
    a = search_gemm(M, N, K, budget, gpu_profile(), fingerprint="fp")
    b = search_gemm(M, N, K, budget, gpu_profile(), fingerprint="fp")
    assert a == b
    # and through fresh tuners with separate caches
    t1 = _tuner(gpu_profile(), tmp_path, "d1")
    t2 = _tuner(gpu_profile(), tmp_path, "d2")
    p1 = t1.gemm_plan(M, N, K, budget)
    p2 = t2.gemm_plan(M, N, K, budget)
    assert dataclasses_equal_except_fingerprint(p1, p2)


def dataclasses_equal_except_fingerprint(a: TunedPlan, b: TunedPlan) -> bool:
    import dataclasses
    da, db = dataclasses.asdict(a), dataclasses.asdict(b)
    da.pop("fingerprint"), db.pop("fingerprint")
    return da == db


def test_plan_json_roundtrip(tmp_path):
    plan = search_gemm(1024, 1024, 512, 2_000_000, gpu_profile(),
                       fingerprint="rt")
    again = TunedPlan.from_json(json.loads(json.dumps(plan.to_json())))
    assert again == plan
    part = again.gemm_partition()
    assert (part.bm, part.bn, part.h, part.w) == \
        (plan.param("bm"), plan.param("bn"), plan.param("h"), plan.param("w"))


# -------------------------------------------------------------- plan cache
def test_cache_persists_across_tuner_instances(tmp_path):
    path = tmp_path / "shared.json"
    t1 = AutoTuner(profile=gpu_profile(), cache=PlanCache(str(path)),
                   fingerprint="same", **C5_OPTS)
    p1 = t1.gemm_plan(2048, 2048, 1024, 4_000_000)
    assert t1.searches == 1
    # a new process (modeled by a new tuner) reads the same store
    t2 = AutoTuner(profile=gpu_profile(), cache=PlanCache(str(path)),
                   fingerprint="same", **C5_OPTS)
    p2 = t2.gemm_plan(2048, 2048, 1024, 4_000_000)
    assert t2.searches == 0 and t2.last_from_cache and p2 == p1
    # different fingerprint = different hardware: must re-search
    t3 = AutoTuner(profile=gpu_profile(), cache=PlanCache(str(path)),
                   fingerprint="other", **C5_OPTS)
    t3.gemm_plan(2048, 2048, 1024, 4_000_000)
    assert t3.searches == 1


def test_cache_key_format():
    key = PlanCache.key("gemm", (8192, 8192, 8192), "float32", "HBM",
                        1 << 28, "abcd1234")
    assert key == "gemm:8192x8192x8192:float32:HBM:268435456:abcd1234"


def test_corrupt_cache_is_treated_as_empty(tmp_path):
    path = tmp_path / "broken.json"
    path.write_text("{not json")
    cache = PlanCache(str(path))
    assert cache.get("anything") is None
    assert cache.misses == 1


# ------------------------------------------------- tune="auto" end to end
def test_ooc_gemm_tune_auto_matches_oracle(rng, tmp_path):
    M, N, K = 640, 512, 256
    A = rng.standard_normal((M, K)).astype(np.float32)
    B = rng.standard_normal((K, N)).astype(np.float32)
    C = rng.standard_normal((M, N)).astype(np.float32)
    budget = (A.nbytes + B.nbytes + C.nbytes) // 4
    tuner = _tuner(gpu_profile(), tmp_path, "e2e")
    out = ooc_gemm(A, B, C, 1.5, -0.5, budget_bytes=budget,
                   tune="auto", tuner=tuner)
    expect = 1.5 * (A.astype(np.float64) @ B) - 0.5 * C
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)
    assert tuner.searches == 1
    out2 = ooc_gemm(A, B, C, 1.5, -0.5, budget_bytes=budget,
                    tune="auto", tuner=tuner)
    assert tuner.searches == 1 and tuner.last_from_cache
    np.testing.assert_allclose(out2, expect, rtol=1e-4, atol=1e-4)


def test_ooc_gemm_rejects_unknown_tune_mode(rng):
    A = np.zeros((64, 64), np.float32)
    with pytest.raises(ValueError, match="tune mode"):
        ooc_gemm(A, A, budget_bytes=1 << 20, tune="bogus")


def test_ooc_attention_tune_auto_matches_default(rng, tmp_path):
    S, hkv, d, H = 2048, 4, 64, 8
    q = rng.standard_normal((H, d)).astype(np.float32)
    k = rng.standard_normal((S, hkv, d)).astype(np.float32)
    v = rng.standard_normal((S, hkv, d)).astype(np.float32)
    budget = k.nbytes // 4
    tuner = _tuner(gpu_profile(), tmp_path, "attn")
    tuned = np.asarray(ooc_attention(q, k, v, budget_bytes=budget,
                                     tune="auto", tuner=tuner))
    default = np.asarray(ooc_attention(q, k, v, budget_bytes=budget))
    np.testing.assert_allclose(tuned, default, rtol=2e-3, atol=2e-3)
    assert tuner.searches == 1
    ooc_attention(q, k, v, budget_bytes=budget, tune="auto", tuner=tuner)
    assert tuner.searches == 1 and tuner.last_from_cache


def test_ooc_cholesky_tune_auto(rng, tmp_path):
    n = 320
    Mx = rng.standard_normal((n, n))
    spd = (Mx @ Mx.T + n * np.eye(n)).astype(np.float32)
    tuner = _tuner(gpu_profile(), tmp_path, "chol")
    L = ooc_cholesky(spd, panel=128, budget_bytes=spd.nbytes // 3,
                     tune="auto", tuner=tuner)
    np.testing.assert_allclose(L @ L.T, spd, rtol=2e-3, atol=2e-3)


# ------------------------------------------------------------- calibration
def test_calibrate_measures_this_machine():
    res = calibrate(small=(128, 512), large=(1024, 512), gemm_n=256,
                    repeats=2)
    prof = res.profile
    for rate in (prof.h2d_bw, prof.d2h_bw, prof.flops):
        assert np.isfinite(rate) and rate > 0
    assert 0 < prof.per_op_overhead <= 1e-3
    assert res.fingerprint == hardware_fingerprint()
    # the fitted profile instantiates usable engine models
    for ns in (1, 2):
        model = prof.model_for(ns)
        assert model.pools and model.flops > 0


def test_fingerprint_is_stable():
    assert hardware_fingerprint() == hardware_fingerprint()
    assert len(hardware_fingerprint()) == 16


# ------------------------------------- heap simulator equals its reference
def test_simulate_heap_matches_reference():
    part = plan_gemm_partition(1024, 1024, 512, 2_000_000, 4)
    for ns, nb in ((1, 1), (2, 2), (2, 3), (3, 2)):
        sched = build_gemm_schedule(part, ns, nb)
        for hw in (gpu_like(), phi_like(nstreams=ns), tpu_v5e_vmem()):
            a = simulate(sched, hw)
            b = simulate_reference(sched, hw)
            assert a.makespan == pytest.approx(b.makespan, abs=1e-15)
            assert a.busy == b.busy
            assert sorted(a.op_spans) == sorted(b.op_spans)
