"""Sharding-rule resolution + HLO collective parser + roofline math.

Pure-logic tests (no multi-device requirement); the multi-device dry-run
smoke lives in test_dryrun_smoke.py (subprocess with forced host devices).
"""

import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed import (Roofline, collective_bytes, logical_to_spec,
                               tree_specs)
from repro.distributed.hlo_analysis import _result_bytes


class FakeMesh:
    """Duck-typed mesh with a .shape mapping (enough for spec resolution)."""

    def __init__(self, shape):
        self.shape = shape


M2 = FakeMesh({"data": 16, "model": 16})
M3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_weight_2d_sharding():
    spec = logical_to_spec(("embed", "heads"), (2048, 4096), M2)
    assert spec == P("data", "model")


def test_non_divisible_replicates():
    # kv_heads=2 can't shard over model=16 -> replicated
    spec = logical_to_spec(
        ("layer", "batch", "cache_seq", "kv_heads", None),
        (36, 128, 32768, 2, 128), M2)
    assert spec == P(None, "data", "model", None, None)


def test_kv_heads_win_over_cache_seq_when_divisible():
    spec = logical_to_spec(
        ("layer", "batch", "cache_seq", "kv_heads", None),
        (32, 128, 32768, 32, 128), M2)
    # kv_heads (priority 0) takes "model"; cache_seq falls back to nothing
    assert spec == P(None, "data", None, "model", None)


def test_batch_spans_pod_and_data():
    spec = logical_to_spec(("batch", None), (256, 7), M3)
    assert spec == P(("pod", "data"), None)


def test_batch_1_replicated():
    spec = logical_to_spec(("batch", None, None), (1, 5, 5), M3)
    assert spec == P(None, None, None)


def test_no_double_assignment_of_axis():
    # both want "model": first (priority, then order) wins
    spec = logical_to_spec(("vocab", "ffn"), (160, 160), M2)
    assert spec.count("model") <= 1


# ------------------------------------------------------------- HLO parsing
HLO = """
HloModule test
ENTRY %main {
  %x = bf16[16,1024]{1,0} parameter(0)
  %ar = bf16[16,1024]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = f32[64,1024]{1,0} all-gather(%x), replica_groups=[16,4]<=[64], dimensions={0}
  %rs = f32[4,1024]{1,0} reduce-scatter(%ag), replica_groups={{0,1,2,3}}, dimensions={0}, to_apply=%add
  %cp = bf16[8,8]{1,0} collective-permute(%x), source_target_pairs={{0,1},{1,0}}
  %cpd = bf16[8,8]{1,0} collective-permute-done(%cp)
}
"""


def test_collective_parser():
    st = collective_bytes(HLO)
    b_ar = 16 * 1024 * 2
    assert st.by_kind["all-reduce"] == pytest.approx(2 * b_ar * 3 / 4)
    b_ag = 64 * 1024 * 4
    assert st.by_kind["all-gather"] == pytest.approx(b_ag * 3 / 4)
    b_rs = 4 * 1024 * 4
    assert st.by_kind["reduce-scatter"] == pytest.approx(b_rs * 3)
    assert st.by_kind["collective-permute"] == pytest.approx(8 * 8 * 2)
    assert st.counts["all-reduce"] == 1


def test_result_bytes_tuple():
    assert _result_bytes("(bf16[2,2], f32[4])") == 2 * 2 * 2 + 4 * 4


# ------------------------------------------------------------------ roofline
def test_roofline_terms_and_bottleneck():
    rl = Roofline(flops=197e12, hbm_bytes=819e9 * 2, wire_bytes=50e9 * 0.5,
                  chips=256, model_flops=197e12 * 256 * 0.5)
    assert rl.t_compute == pytest.approx(1.0)
    assert rl.t_memory == pytest.approx(2.0)
    assert rl.t_collective == pytest.approx(0.5)
    assert rl.bottleneck == "memory"
    assert rl.roofline_fraction == pytest.approx(0.5)
    assert rl.useful_flops_ratio == pytest.approx(0.5)
