"""Fault injection + recovery: differential oracles (DESIGN.md §12).

Every recovery mechanism is pinned against an oracle that does not share
its code path:

  * transfer retries — the backoff sequence against a fake clock, and the
    byte-accounting invariant (nominal counters unchanged, failed-attempt
    traffic in ``replayed_h2d_bytes``);
  * compute replay — bitwise equality with the fault-free run, and the
    executor's dynamic chain length against the static
    :func:`repro.fault.replay.redo_set` derivation;
  * device_lost — the hybrid rebalance result against BOTH the fault-free
    hybrid run (bitwise) and the dense reference oracle (allclose);
  * oom — the degrade ladder's landing plan against what the planner /
    tuner produces outright at the reduced knobs;
  * the simulator's faulted-makespan mode — closed-form expectations.

Also the regression test for the executor's flush-exception bug: a
write-back materialization that raises used to drop the in-flight block
(pop-then-write), silently leaving stale host state.
"""

import numpy as np
import pytest

from repro.core.api import hclFaultPolicy
from repro.core.oocgemm import ooc_gemm, ooc_syrk
from repro.core.ooc_factor import ooc_cholesky, ooc_lu
from repro.core.partitioner import plan_gemm_partition
from repro.core.pipeline import build_gemm_schedule, schedule_stats
from repro.core.runtime import HostOocRuntime, ScheduleExecutor
from repro.core.simulator import FaultModel, gpu_like, simulate
from repro.core.streams import OpKind
from repro.fault import (ComputeFault, DeviceLostError, FaultInjector,
                         FaultPlan, FaultPolicy, FaultSpec, OomError,
                         TransferError, mean_redo_len, redo_cost, redo_set)
from repro.hybrid import (DeviceSpec, plan_hybrid_gemm, plan_hybrid_syrk,
                          run_hybrid_gemm, run_hybrid_syrk,
                          surviving_devices)
from repro.kernels import ref
from repro.obs import get_observability
from repro.tune import gpu_profile, phi_profile
from repro.tune.search import search_gemm


@pytest.fixture(autouse=True)
def _clean_obs():
    obs = get_observability()
    obs.reset()
    yield
    obs.reset()


def _gemm_case(m=128, n=48, k=32, budget=60_000, seed=0, nstreams=2, nbuf=2):
    rng = np.random.default_rng(seed)
    A = rng.standard_normal((m, k))
    B = rng.standard_normal((k, n))
    C = rng.standard_normal((m, n))
    part = plan_gemm_partition(m, n, k, budget)
    sched = build_gemm_schedule(part, nstreams=nstreams, nbuf=nbuf)
    return A, B, C, part, sched


def _fake_clock():
    slept = []
    return slept, lambda s: slept.append(s)


# ------------------------------------------------------------- plan basics
def test_fault_spec_validation():
    with pytest.raises(ValueError, match="unknown fault class"):
        FaultSpec(op=0, cls="cosmic_ray")
    with pytest.raises(ValueError, match="op index"):
        FaultSpec(op=-1, cls="h2d_error")
    with pytest.raises(ValueError, match="times"):
        FaultSpec(op=0, cls="h2d_error", times=0)
    with pytest.raises(ValueError, match="rate"):
        FaultPlan.random(0, None, 1.5)


def test_random_plan_is_deterministic_and_class_stable():
    *_, sched = _gemm_case()
    p1 = FaultPlan.random(7, sched, 0.5)
    p2 = FaultPlan.random(7, sched, 0.5)
    assert p1.specs == p2.specs and len(p1) > 0
    # restricting the class set removes specs without shifting the rest:
    # one rng draw per op regardless of eligibility
    h2d_only = FaultPlan.random(7, sched, 0.5, classes=("h2d_error",))
    assert set(h2d_only.specs) == {
        s for s in p1.specs if s.cls == "h2d_error"}
    # specs address eligible ops of the right kind, pinned to their stream
    for s in p1.specs:
        op = sched.ops[s.op]
        assert s.stream == op.stream
        assert (op.kind == OpKind.H2D) == (s.cls == "h2d_error")


def test_injector_consumes_per_attempt_and_checks_stream_pin():
    *_, sched = _gemm_case()
    h2d = next(i for i, op in enumerate(sched.ops) if op.kind == OpKind.H2D)
    plan = FaultPlan(specs=(FaultSpec(op=h2d, cls="h2d_error", times=2),))
    inj = plan.injector()
    op = sched.ops[h2d]
    assert inj.check(h2d, op) == "h2d_error"
    assert not inj.exhausted()
    assert inj.check(h2d, op) == "h2d_error"
    assert inj.check(h2d, op) is None          # times=2: third attempt clean
    assert inj.exhausted()
    assert inj.injected == [(h2d, "h2d_error"), (h2d, "h2d_error")]

    bad = FaultPlan(specs=(FaultSpec(op=h2d, cls="h2d_error",
                                     stream=op.stream + 1),)).injector()
    with pytest.raises(ValueError, match="pins op"):
        bad.check(h2d, op)


def test_for_device_shards_pinned_specs():
    plan = FaultPlan(specs=(FaultSpec(op=0, cls="h2d_error", device="gpu0"),
                            FaultSpec(op=1, cls="h2d_error", device="phi0"),
                            FaultSpec(op=2, cls="h2d_error")))
    gpu = plan.for_device("gpu0")
    assert [s.op for s in gpu.specs] == [0, 2]


# --------------------------------------------------- retry / backoff oracle
def test_backoff_schedule_pinned_against_fake_clock():
    slept, sleep = _fake_clock()
    pol = FaultPolicy(backoff_base=0.5, backoff_factor=2.0, max_retries=3,
                      sleep=sleep)
    assert pol.backoff_schedule() == [0.5, 1.0, 2.0]

    A, B, C, part, sched = _gemm_case()
    h2d = next(i for i, op in enumerate(sched.ops) if op.kind == OpKind.H2D)
    rt = HostOocRuntime()
    clean = rt.gemm(A, B, C, 1.0, 0.5, part, schedule=sched)
    nominal_h2d = rt.executor.last_h2d_bytes

    plan = FaultPlan(specs=(FaultSpec(op=h2d, cls="h2d_error", times=2),))
    out = rt.gemm(A, B, C, 1.0, 0.5, part, schedule=sched,
                  faults=plan, policy=pol)
    assert np.array_equal(out, clean)
    # exactly the policy's first two backoff delays, in order
    assert slept == [0.5, 1.0]
    st = rt.executor.last_fault_stats
    assert st["injected"] == 2 and st["retries"] == 2
    assert st["recovered_retry"] == 1
    assert st["backoff_seconds"] == pytest.approx(1.5)
    # nominal counters unchanged; the two failed attempts' traffic is
    # accounted as recovery's
    assert rt.executor.last_h2d_bytes == nominal_h2d
    assert st["replayed_h2d_bytes"] == 2 * sched.ops[h2d].bytes


def test_transfer_retries_exhaust_and_raise():
    A, B, C, part, sched = _gemm_case()
    h2d = next(i for i, op in enumerate(sched.ops) if op.kind == OpKind.H2D)
    pol = FaultPolicy(max_retries=2, sleep=lambda s: None)
    plan = FaultPlan(specs=(FaultSpec(op=h2d, cls="h2d_error", times=3),))
    rt = HostOocRuntime()
    with pytest.raises(TransferError, match="after 2 retries"):
        rt.gemm(A, B, C, 1.0, 0.5, part, schedule=sched,
                faults=plan, policy=pol)
    # terminal raise still publishes the injection record
    assert rt.executor.last_fault_stats["injected"] == 3


def test_h2d_fault_on_compute_op_is_authoring_error():
    A, B, C, part, sched = _gemm_case()
    ci = next(i for i, op in enumerate(sched.ops)
              if op.kind == OpKind.COMPUTE)
    rt = HostOocRuntime()
    with pytest.raises(ValueError, match="h2d_error into compute"):
        rt.gemm(A, B, C, 1.0, 0.5, part, schedule=sched,
                faults=FaultPlan(specs=(FaultSpec(op=ci, cls="h2d_error"),)),
                policy=FaultPolicy(sleep=lambda s: None))


# ------------------------------------------------------ compute replay oracle
def test_compute_replay_every_op_bitwise_and_matches_static_redo_set():
    A, B, C, part, sched = _gemm_case()
    rt = HostOocRuntime()
    clean = rt.gemm(A, B, C, 1.0, 0.5, part, schedule=sched)
    pol = FaultPolicy(sleep=lambda s: None)
    for ci, op in enumerate(sched.ops):
        if op.kind != OpKind.COMPUTE:
            continue
        plan = FaultPlan(specs=(FaultSpec(op=ci, cls="compute_nan"),))
        out = rt.gemm(A, B, C, 1.0, 0.5, part, schedule=sched,
                      faults=plan, policy=pol)
        assert np.array_equal(out, clean), f"replay at op {ci} diverged"
        st = rt.executor.last_fault_stats
        assert st["recovered_replay"] == 1
        # the dynamic chain the executor replayed == the static derivation
        assert st["replayed_ops"] == len(redo_set(sched, ci))


def test_unrecoverable_compute_fault_raises_compute_fault():
    A, B, C, part, sched = _gemm_case()
    ci = next(i for i, op in enumerate(sched.ops)
              if op.kind == OpKind.COMPUTE)
    pol = FaultPolicy(max_retries=2, sleep=lambda s: None)
    plan = FaultPlan(specs=(FaultSpec(op=ci, cls="compute_nan", times=4),))
    rt = HostOocRuntime()
    with pytest.raises(ComputeFault, match="retries exhausted"):
        rt.gemm(A, B, C, 1.0, 0.5, part, schedule=sched,
                faults=plan, policy=pol)


def test_redo_set_properties():
    *_, sched = _gemm_case()
    computes = [i for i, op in enumerate(sched.ops)
                if op.kind == OpKind.COMPUTE]
    for ci in computes:
        rs = redo_set(sched, ci)
        assert rs[-1] == ci and rs == sorted(rs)
        key = sched.ops[ci].buffers_written[0]
        for j in rs[:-1]:
            assert key in sched.ops[j].buffers_written
    h2d = next(i for i, op in enumerate(sched.ops) if op.kind == OpKind.H2D)
    with pytest.raises(ValueError, match="not a single-writer compute"):
        redo_set(sched, h2d)
    assert mean_redo_len(sched) >= 1.0
    hw = gpu_like()
    assert redo_cost(sched, hw, computes[0]) > 0.0


# ------------------------------------------------------- flush regression
class _FlakyBlock:
    """A device block whose host materialization fails transiently —
    the shape of bug the flush fix guards: the in-flight entry must
    survive a failed write-back attempt."""

    def __init__(self, arr, fails):
        self._arr = np.asarray(arr)
        self.fails = fails

    def __array__(self, dtype=None, copy=None):
        if self.fails > 0:
            self.fails -= 1
            raise TransferError("transient write-back failure")
        return self._arr if dtype is None else self._arr.astype(dtype)


def _flaky_executor(blocks, fails_each=1):
    """An executor whose first ``blocks`` dgemm output blocks each fail
    ``fails_each`` materialization attempts before landing."""
    from repro.core.runtime import _OP_HANDLERS

    real = _OP_HANDLERS["dgemm"]
    left = {"n": blocks}

    def flaky_dgemm(st, op, fref):
        real(st, op, fref)
        if left["n"] > 0:
            left["n"] -= 1
            key = op.buffers_written[0]
            st.bufs[key] = _FlakyBlock(st.bufs[key], fails_each)

    return ScheduleExecutor(handlers={"dgemm": flaky_dgemm})


def test_flush_exception_keeps_block_in_flight_and_retries():
    A, B, C, part, sched = _gemm_case()
    rt = HostOocRuntime()
    clean = rt.gemm(A, B, C, 1.0, 0.5, part, schedule=sched)

    slept, sleep = _fake_clock()
    rt2 = HostOocRuntime(executor=_flaky_executor(blocks=1))
    # an empty plan arms fault mode (retrying flushes) with zero injections
    out = rt2.gemm(A, B, C, 1.0, 0.5, part, schedule=sched,
                   faults=FaultPlan(),
                   policy=FaultPolicy(sleep=sleep))
    # the failed first materialization did NOT drop the block: the retry
    # re-landed it and the output is exact
    assert np.array_equal(out, clean)
    st = rt2.executor.last_fault_stats
    assert st["injected"] == 0 and st["retries"] == 1
    assert st["recovered_retry"] == 1 and len(slept) == 1


def test_flush_exception_without_policy_propagates():
    A, B, C, part, sched = _gemm_case()
    rt = HostOocRuntime(executor=_flaky_executor(blocks=1))
    with pytest.raises(TransferError):
        rt.gemm(A, B, C, 1.0, 0.5, part, schedule=sched)


# --------------------------------------------------------- device_lost oracle
FAST = dict(nbuf_options=(1, 2), max_steps=256)


def _hybrid_devices(budget):
    return [DeviceSpec("gpu0", gpu_profile(), budget),
            DeviceSpec("phi0", phi_profile(), budget)]


def _first_compute_lost(sched):
    for i, op in enumerate(sched.ops):
        if op.kind == OpKind.COMPUTE:
            return FaultPlan(specs=(FaultSpec(op=i, cls="device_lost"),))
    raise AssertionError("schedule has no compute op")


def test_device_lost_gemm_rebalances_bitwise():
    rng = np.random.default_rng(3)
    m, n, k = 512, 256, 128
    budget = (m * k + k * n + m * n) * 4 // 3
    devs = _hybrid_devices(budget)
    A = rng.standard_normal((m, k)).astype(np.float32)
    B = rng.standard_normal((k, n)).astype(np.float32)
    C = rng.standard_normal((m, n)).astype(np.float32)
    hp = plan_hybrid_gemm(m, n, k, devs, **FAST)
    clean, _ = run_hybrid_gemm(A, B, C, 1.2, 0.5, hp)
    pol = FaultPolicy(sleep=lambda s: None)
    for dead in ("gpu0", "phi0"):
        out, groups = run_hybrid_gemm(
            A, B, C, 1.2, 0.5, hp,
            fault_plans={dead: _first_compute_lost},
            fault_policy=pol)
        # bitwise vs the fault-free hybrid run (K is never split, so the
        # rebalanced band's blocks are the same full-depth dots)...
        assert np.array_equal(out, clean)
        # ...and correct vs the dense oracle
        np.testing.assert_allclose(out, ref.gemm_ref(A, B, C, 1.2, 0.5),
                                   rtol=1e-5, atol=1e-5)
        names = [g[0] for g in groups]
        survivor = "phi0" if dead == "gpu0" else "gpu0"
        assert any(f"rebalance {dead}" in nm for nm in names)
        assert dead not in names and survivor in names


def test_device_lost_syrk_recovers_via_gemm_band():
    rng = np.random.default_rng(4)
    m, k = 512, 128
    budget = (m * k + k * m + m * m) * 4 // 3
    devs = _hybrid_devices(budget)
    P = rng.standard_normal((m, k)).astype(np.float32)
    C = rng.standard_normal((m, m)).astype(np.float32)
    C = C + C.T
    hp = plan_hybrid_syrk(m, k, devs, **FAST)
    clean, _ = run_hybrid_syrk(P, C, 1.2, 0.5, hp)
    out, _ = run_hybrid_syrk(P, C, 1.2, 0.5, hp,
                             fault_plans={"gpu0": _first_compute_lost},
                             fault_policy=FaultPolicy(sleep=lambda s: None))
    assert np.array_equal(out, clean)


def test_surviving_devices_validation():
    devs = _hybrid_devices(1 << 20)
    assert [d.name for d in surviving_devices(devs, ["gpu0"])] == ["phi0"]
    with pytest.raises(ValueError, match="not in device set"):
        surviving_devices(devs, ["nope"])
    with pytest.raises(ValueError, match="no survivors"):
        surviving_devices(devs, ["gpu0", "phi0"])


def test_device_lost_outside_hybrid_propagates():
    A, B, C, part, sched = _gemm_case()
    ci = next(i for i, op in enumerate(sched.ops)
              if op.kind == OpKind.COMPUTE)
    rt = HostOocRuntime()
    with pytest.raises(DeviceLostError):
        rt.gemm(A, B, C, 1.0, 0.5, part, schedule=sched,
                faults=FaultPlan(specs=(
                    FaultSpec(op=ci, cls="device_lost"),)))


# -------------------------------------------------------------- oom ladders
def _oom_at_first_compute(sched):
    for i, op in enumerate(sched.ops):
        if op.kind == OpKind.COMPUTE:
            return FaultPlan(specs=(FaultSpec(op=i, cls="oom"),))
    raise AssertionError


def test_oom_untuned_gemm_halves_nbuf_first_and_stays_bitwise():
    rng = np.random.default_rng(5)
    m, n, k = 128, 48, 32
    A = rng.standard_normal((m, k))
    B = rng.standard_normal((k, n))
    C = rng.standard_normal((m, n))
    clean = ooc_gemm(A, B, C, 1.0, 0.5, budget_bytes=60_000)
    pol = FaultPolicy(sleep=lambda s: None)
    out = ooc_gemm(A, B, C, 1.0, 0.5, budget_bytes=60_000,
                   faults=_oom_at_first_compute, fault_policy=pol)
    # first rung: halve nbuf — same partition, so bitwise (K never split)
    assert [d.action for d in pol.degrades] == ["halve_nbuf"]
    assert np.array_equal(out, clean)


def test_oom_tuned_gemm_lands_on_reduced_budget_plan():
    rng = np.random.default_rng(6)
    m, n, k = 256, 64, 32
    A = rng.standard_normal((m, k))
    B = rng.standard_normal((k, n))
    C = rng.standard_normal((m, n))
    budget = 120_000
    clean = ooc_gemm(A, B, C, 1.0, 0.5, budget_bytes=budget, tune="auto")
    pol = FaultPolicy(sleep=lambda s: None)
    out = ooc_gemm(A, B, C, 1.0, 0.5, budget_bytes=budget, tune="auto",
                   faults=_oom_at_first_compute, fault_policy=pol)
    # tuned runs: the tuner owns nbuf/lookahead, so the ladder is budget
    # halvings only, re-searched — the degraded run IS the tuner's plan at
    # the reduced budget
    assert [d.action for d in pol.degrades] == ["halve_budget"]
    assert pol.degrades[0].budget_bytes == budget // 2
    assert np.array_equal(out, clean)
    # the differential: running outright at the reduced budget matches
    direct = ooc_gemm(A, B, C, 1.0, 0.5, budget_bytes=budget // 2,
                      tune="auto")
    assert np.array_equal(out, direct)


def test_oom_degraded_rerun_is_fault_free_and_ladder_exhaustion_raises():
    rng = np.random.default_rng(7)
    m, n, k = 128, 48, 32
    A = rng.standard_normal((m, k))
    B = rng.standard_normal((k, n))
    C = rng.standard_normal((m, n))
    clean = ooc_gemm(A, B, C, 1.0, 0.5, budget_bytes=60_000)

    def oom_many(sched):
        for i, op in enumerate(sched.ops):
            if op.kind == OpKind.COMPUTE:
                return FaultPlan(specs=(
                    FaultSpec(op=i, cls="oom", times=10),))
        raise AssertionError

    # the degraded re-run executes fault-free by design, so even an oom
    # with 9 occurrences left recovers on the first rung
    pol = FaultPolicy(sleep=lambda s: None)
    out = ooc_gemm(A, B, C, 1.0, 0.5, budget_bytes=60_000,
                   faults=oom_many, fault_policy=pol)
    assert [d.action for d in pol.degrades] == ["halve_nbuf"]
    assert np.array_equal(out, clean)

    # tuned ladder at this budget: both halvings (30k, 15k) are below the
    # 53248B aligned working-set floor, so every rung fails to replan and
    # the oom propagates to the caller
    pol2 = FaultPolicy(sleep=lambda s: None, max_budget_halvings=2)
    with pytest.raises(OomError):
        ooc_gemm(A, B, C, 1.0, 0.5, budget_bytes=60_000, tune="auto",
                 faults=oom_many, fault_policy=pol2)
    assert [d.action for d in pol2.degrades] == ["halve_budget",
                                                 "halve_budget"]


def test_oom_cholesky_and_lu_degrade_and_stay_correct():
    rng = np.random.default_rng(8)
    n = 192
    A = rng.standard_normal((n, n))
    spd = A @ A.T + n * np.eye(n)
    budget = 4 * spd.nbytes
    pol = FaultPolicy(sleep=lambda s: None)
    clean_l = ooc_cholesky(spd, panel=64, budget_bytes=budget)
    L = ooc_cholesky(spd, panel=64, budget_bytes=budget,
                     faults=_oom_at_first_compute, fault_policy=pol)
    assert [d.action for d in pol.degrades] == ["halve_nbuf"]
    assert np.array_equal(L, clean_l)

    pol2 = FaultPolicy(sleep=lambda s: None)
    B = rng.standard_normal((n, n)) + n * np.eye(n)
    clean_lu, clean_p = ooc_lu(B, panel=64, budget_bytes=budget)
    LU, perm = ooc_lu(B, panel=64, budget_bytes=budget,
                      faults=_oom_at_first_compute, fault_policy=pol2)
    assert [d.action for d in pol2.degrades] == ["halve_nbuf"]
    assert np.array_equal(LU, clean_lu)
    assert np.array_equal(perm, clean_p)


def test_factor_compute_and_transfer_faults_recover_bitwise():
    rng = np.random.default_rng(9)
    n = 192
    A = rng.standard_normal((n, n))
    spd = A @ A.T + n * np.eye(n)
    budget = 4 * spd.nbytes
    pol = FaultPolicy(sleep=lambda s: None)
    clean = ooc_cholesky(spd, panel=64, budget_bytes=budget)
    got = ooc_cholesky(spd, panel=64, budget_bytes=budget,
                       faults=lambda s: FaultPlan.random(21, s, 0.3),
                       fault_policy=pol)
    assert np.array_equal(got, clean)

    B = rng.standard_normal((n, n)) + n * np.eye(n)
    clean_lu, clean_p = ooc_lu(B, panel=64, budget_bytes=budget)
    LU, perm = ooc_lu(B, panel=64, budget_bytes=budget,
                      faults=lambda s: FaultPlan.random(22, s, 0.3),
                      fault_policy=pol)
    assert np.array_equal(LU, clean_lu)
    assert np.array_equal(perm, clean_p)


def test_faults_rejected_on_non_host_backends():
    rng = np.random.default_rng(10)
    A = rng.standard_normal((64, 32))
    B = rng.standard_normal((32, 48))
    with pytest.raises(ValueError, match="host pipeline backend only"):
        ooc_gemm(A, B, None, 1.0, 0.0, budget_bytes=1 << 20,
                 backend="vmem", faults=FaultPlan())
    spd = A @ A.T + 64 * np.eye(64)
    with pytest.raises(ValueError, match="host pipeline backend only"):
        ooc_cholesky(spd, panel=32, budget_bytes=1 << 20,
                     devices=_hybrid_devices(1 << 20), faults=FaultPlan())


# --------------------------------------------- simulator + tuner fault mode
def test_fault_model_expected_durations_closed_form():
    *_, sched = _gemm_case()
    hw = gpu_like()
    fm = FaultModel(rate=0.1, mean_backoff=0.01, redo_factor=2.0)
    for op in sched.ops:
        dur = hw.duration(op)
        exp = fm.expected_duration(op, dur)
        if op.kind == OpKind.COMPUTE:
            assert exp == pytest.approx(dur * (1 + 0.1 * 2.0))
        else:
            assert exp == pytest.approx(
                dur + (0.1 / 0.9) * (dur + 0.01))
        # rate 0 is the identity
        assert FaultModel(rate=0.0).expected_duration(op, dur) == dur


def test_simulate_faulted_makespan_monotone_in_rate():
    *_, sched = _gemm_case()
    hw = gpu_like()
    base = simulate(sched, hw).makespan
    prev = base
    for rate in (0.01, 0.05, 0.2):
        span = simulate(sched, hw, faults=FaultModel(rate=rate)).makespan
        assert span > prev * (1 - 1e-12)
        prev = span
    assert prev > base


def test_search_ranks_under_fault_model():
    prof = gpu_profile()
    best = search_gemm(512, 256, 128, 1 << 22, prof)
    faulted = search_gemm(512, 256, 128, 1 << 22, prof, fault_rate=0.05)
    assert faulted.makespan >= best.makespan
    # the policy bridge produces the same model the tuner consumes
    pol = FaultPolicy(backoff_base=0.02)
    fm = pol.fault_model(0.05)
    assert fm.rate == 0.05 and fm.mean_backoff == 0.02
    via_model = search_gemm(512, 256, 128, 1 << 22, prof, fault_model=fm)
    assert via_model.makespan >= best.makespan


# ----------------------------------------------------------- obs + facade
def test_fault_metrics_published_and_facade():
    obs = get_observability()
    obs.enable(metrics=True)
    A, B, C, part, sched = _gemm_case()
    h2d = next(i for i, op in enumerate(sched.ops) if op.kind == OpKind.H2D)
    ci = next(i for i, op in enumerate(sched.ops)
              if op.kind == OpKind.COMPUTE)
    pol = hclFaultPolicy(sleep=lambda s: None)
    assert isinstance(pol, FaultPolicy)
    rt = HostOocRuntime()
    rt.gemm(A, B, C, 1.0, 0.5, part, schedule=sched,
            faults=FaultPlan(specs=(FaultSpec(op=h2d, cls="h2d_error"),
                                    FaultSpec(op=ci, cls="compute_nan"))),
            policy=pol)
    text = obs.metrics.to_prometheus_text()
    assert "repro_fault_injected_total" in text
    assert "repro_fault_retries_total" in text
    assert "repro_fault_replayed_ops_total" in text
    assert 'action="retry"' in text and 'action="replay"' in text


def test_executor_counters_reconcile_with_schedule_stats_under_faults():
    A, B, C, part, sched = _gemm_case()
    stats = schedule_stats(sched)
    rt = HostOocRuntime()
    plan = FaultPlan.random(33, sched, 0.4)
    rt.gemm(A, B, C, 1.0, 0.5, part, schedule=sched, faults=plan,
            policy=FaultPolicy(sleep=lambda s: None))
    assert rt.executor.last_h2d_bytes == stats["h2d_bytes"]
    assert rt.executor.last_d2h_bytes == stats["d2h_bytes"]
