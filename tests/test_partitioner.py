"""Property tests for the hclMatrixPartitioner analogue."""

import numpy as np
import pytest
from tests._hypothesis_shim import given, settings, st

from repro.core.partitioner import (
    GemmPartition,
    plan_attention_partition,
    plan_gemm_partition,
)

dims = st.integers(min_value=1, max_value=4096)


@given(M=dims, N=dims, K=dims,
       budget_kb=st.integers(min_value=64, max_value=1 << 16))
@settings(max_examples=200, deadline=None)
def test_partition_fits_budget_and_covers(M, N, K, budget_kb):
    budget = budget_kb * 1024
    try:
        part = plan_gemm_partition(M, N, K, budget, bytes_per_el=4)
    except ValueError:
        # must only refuse when even the minimal aligned working set is over
        minimal = GemmPartition(M, N, K, 0, 0, 8, 128, 4, budget)
        assert minimal.working_set_bytes() > budget
        return
    # invariant 1: the paper's 2-deep working set fits
    assert part.working_set_bytes() <= budget
    # invariant 2: blocks tile C exactly, in column-major order, no overlap
    seen = np.zeros((M, N), dtype=bool)
    last = (-1, -1)
    for i, j, rs, rn, cs, cn in part.blocks():
        assert (j, i) > last, "not column-major"
        last = (j, i)
        assert rn > 0 and cn > 0
        assert not seen[rs:rs + rn, cs:cs + cn].any(), "overlap"
        seen[rs:rs + rn, cs:cs + cn] = True
    assert seen.all(), "C not covered"
    # invariant 3: alignment (except boundary blocks)
    assert part.bm % 8 == 0 and part.bn % 128 == 0


@given(S=st.integers(min_value=1, max_value=1 << 20),
       kv=st.sampled_from([1, 2, 4, 8, 32]),
       d=st.sampled_from([64, 128]),
       budget_mb=st.integers(min_value=1, max_value=128))
@settings(max_examples=100, deadline=None)
def test_attention_partition(S, kv, d, budget_mb):
    budget = budget_mb * 2**20
    per_pos = 2 * kv * d * 2
    try:
        part = plan_attention_partition(S, kv, d, budget, bytes_per_el=2)
    except ValueError:
        assert 2 * 128 * per_pos > budget
        return
    assert 2 * part.bs * per_pos <= budget          # double-buffered fit
    assert part.nblocks * part.bs >= S              # covers the cache
    assert part.bs % 128 == 0


def test_partition_prefers_balanced_blocks():
    part = plan_gemm_partition(4096, 4096, 1024, 32 * 2**20, 4)
    assert max(part.bm, part.bn) <= 8 * max(128, min(part.bm, part.bn))


def test_in_core_single_block():
    part = plan_gemm_partition(256, 256, 256, 1 << 30, 4)
    assert part.nblocks == 1


# ------------------------------------------------------------- edge cases
def test_unaligned_dims_cover_exactly():
    """Boundary blocks shrink to the ragged edge; interior stays aligned."""
    M, N, K = 1000, 999, 130
    part = plan_gemm_partition(M, N, K, 600_000, 4)
    assert part.bm % 8 == 0 and part.bn % 128 == 0
    rows = sum(part.block_rows(i)[1] for i in range(part.h))
    cols = sum(part.block_cols(j)[1] for j in range(part.w))
    assert rows == M and cols == N
    _, last_rn = part.block_rows(part.h - 1)
    _, last_cn = part.block_cols(part.w - 1)
    assert 0 < last_rn <= part.bm and 0 < last_cn <= part.bn


def test_budget_exactly_at_minimum_working_set():
    """The planner accepts a budget equal to the minimum aligned working
    set and rejects one byte less — the refusal boundary is exact."""
    M, N, K, bpe = 64, 512, 256, 4
    minimal = GemmPartition(M, N, K, 0, 0, 8, 128, bpe, 0)
    floor = minimal.working_set_bytes()
    part = plan_gemm_partition(M, N, K, floor, bpe)
    assert (part.bm, part.bn) == (8, 128)
    assert part.working_set_bytes() == floor
    with pytest.raises(ValueError, match="cannot fit"):
        plan_gemm_partition(M, N, K, floor - 1, bpe)


def test_attention_partition_at_align_boundary():
    kv, d, bpe = 4, 64, 2
    per_pos = 2 * kv * d * bpe
    floor = 2 * 128 * per_pos          # double-buffered minimum block pair
    part = plan_attention_partition(128, kv, d, floor, bpe)
    assert part.bs == 128 and part.nblocks == 1
    with pytest.raises(ValueError, match="exceeds budget"):
        plan_attention_partition(128, kv, d, floor - 1, bpe)
    # one position past the alignment boundary rolls to a second block
    part = plan_attention_partition(129, kv, d, floor, bpe)
    assert part.bs == 128 and part.nblocks == 2
    assert part.nblocks * part.bs >= 129


# ---------------------------------------------- generalized working set
def test_working_set_default_is_legacy_two_deep():
    part = GemmPartition(1024, 1024, 512, 8, 8, 128, 128, 4, 1 << 30)
    legacy = (2 * 128 * 512 + 512 * 128 + 2 * 128 * 128) * 4
    assert part.working_set_bytes() == legacy


def test_working_set_scales_with_nbuf():
    part = GemmPartition(1024, 1024, 512, 8, 8, 128, 128, 4, 1 << 30)
    # nbuf A slices + 2-deep B ping-pong + nbuf C blocks
    for nbuf in (1, 2, 3, 4):
        want = (nbuf * 128 * 512 + 2 * 512 * 128 + nbuf * 128 * 128) * 4
        assert part.working_set_bytes(nbuf=nbuf) == want
    assert part.working_set_bytes(nbuf=3) > part.working_set_bytes(nbuf=2)
    # a single-column partition can't ping-pong B deeper than w
    one_col = GemmPartition(1024, 128, 512, 8, 1, 128, 128, 4, 1 << 30)
    assert one_col.working_set_bytes(nbuf=2) == \
        (2 * 128 * 512 + 512 * 128 + 2 * 128 * 128) * 4
    # only nstreams given: canonical nbuf = nstreams pairing
    assert part.working_set_bytes(nstreams=3) == \
        part.working_set_bytes(nbuf=3)
    assert part.working_set_bytes(nstreams=1) == \
        part.working_set_bytes(nbuf=2)
    with pytest.raises(ValueError, match="depth"):
        part.working_set_bytes(nbuf=0)


def test_planner_threads_nbuf_through():
    """A budget the legacy model accepts can overflow a 3-deep pipeline;
    planning with nbuf=3 must shrink blocks until the deeper allocation
    fits (the bug the ISSUE names: the planner approving a partition the
    nbuf=3 schedule overflows)."""
    M, N, K, bpe = 4096, 4096, 2048, 4
    budget = (M * K + K * N + M * N) * bpe // 5
    legacy = plan_gemm_partition(M, N, K, budget, bpe)
    assert legacy.working_set_bytes() <= budget
    assert legacy.working_set_bytes(nbuf=3) > budget  # the overflow
    deep = plan_gemm_partition(M, N, K, budget, bpe, nbuf=3)
    assert deep.working_set_bytes(nbuf=3) <= budget
    assert deep.bm * deep.bn < legacy.bm * legacy.bn


def test_facade_partitioner_accepts_pipeline_shape():
    from repro.core.api import hclMatrixPartitioner
    M, N, K = 4096, 4096, 2048
    budget = (M * K + K * N + M * N) * 4 // 5
    legacy = hclMatrixPartitioner(M, N, K, budget)
    deep = hclMatrixPartitioner(M, N, K, budget, nbuf=3, nstreams=2)
    assert deep.working_set_bytes(nbuf=3, nstreams=2) <= budget
    assert deep.nblocks >= legacy.nblocks
