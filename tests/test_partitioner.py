"""Property tests for the hclMatrixPartitioner analogue."""

import numpy as np
import pytest
from tests._hypothesis_shim import given, settings, st

from repro.core.partitioner import (
    GemmPartition,
    plan_attention_partition,
    plan_gemm_partition,
)

dims = st.integers(min_value=1, max_value=4096)


@given(M=dims, N=dims, K=dims,
       budget_kb=st.integers(min_value=64, max_value=1 << 16))
@settings(max_examples=200, deadline=None)
def test_partition_fits_budget_and_covers(M, N, K, budget_kb):
    budget = budget_kb * 1024
    try:
        part = plan_gemm_partition(M, N, K, budget, bytes_per_el=4)
    except ValueError:
        # must only refuse when even the minimal aligned working set is over
        minimal = GemmPartition(M, N, K, 0, 0, 8, 128, 4, budget)
        assert minimal.working_set_bytes() > budget
        return
    # invariant 1: the paper's 2-deep working set fits
    assert part.working_set_bytes() <= budget
    # invariant 2: blocks tile C exactly, in column-major order, no overlap
    seen = np.zeros((M, N), dtype=bool)
    last = (-1, -1)
    for i, j, rs, rn, cs, cn in part.blocks():
        assert (j, i) > last, "not column-major"
        last = (j, i)
        assert rn > 0 and cn > 0
        assert not seen[rs:rs + rn, cs:cs + cn].any(), "overlap"
        seen[rs:rs + rn, cs:cs + cn] = True
    assert seen.all(), "C not covered"
    # invariant 3: alignment (except boundary blocks)
    assert part.bm % 8 == 0 and part.bn % 128 == 0


@given(S=st.integers(min_value=1, max_value=1 << 20),
       kv=st.sampled_from([1, 2, 4, 8, 32]),
       d=st.sampled_from([64, 128]),
       budget_mb=st.integers(min_value=1, max_value=128))
@settings(max_examples=100, deadline=None)
def test_attention_partition(S, kv, d, budget_mb):
    budget = budget_mb * 2**20
    per_pos = 2 * kv * d * 2
    try:
        part = plan_attention_partition(S, kv, d, budget, bytes_per_el=2)
    except ValueError:
        assert 2 * 128 * per_pos > budget
        return
    assert 2 * part.bs * per_pos <= budget          # double-buffered fit
    assert part.nblocks * part.bs >= S              # covers the cache
    assert part.bs % 128 == 0


def test_partition_prefers_balanced_blocks():
    part = plan_gemm_partition(4096, 4096, 1024, 32 * 2**20, 4)
    assert max(part.bm, part.bn) <= 8 * max(128, min(part.bm, part.bn))


def test_in_core_single_block():
    part = plan_gemm_partition(256, 256, 256, 1 << 30, 4)
    assert part.nblocks == 1
