"""Observability layer (DESIGN.md §10): registry, tracer, drift, conformance.

The acceptance bar (ISSUE 7): the metric registry round-trips through its
JSON snapshot and emits stable Prometheus v0.0.4 text; the tracer nests
spans per thread and absorbs flat executor span groups onto distinct pids
of one Chrome-trace doc; drift ratios follow their definitions and
``stale()`` flags trends, not constant scale; and — the conformance core —
the counters an instrumented run publishes (``repro_executor_h2d_bytes``
etc.) agree *exactly* with the schedule's own modeled totals
(``schedule_stats`` / ``Schedule.total_bytes``) on a seeded GEMM and on a
hybrid co-execution, where byte drift ratios must be exactly 1.0.
"""

import json
import subprocess
import sys
import os

import numpy as np
import pytest

from repro.core import (HostOocRuntime, OpKind, ScheduleExecutor,
                        build_gemm_schedule, ooc_gemm, plan_gemm_partition)
from repro.core.api import hclObservability
from repro.core.pipeline import schedule_stats
from repro.hybrid import DeviceSpec
from repro.obs import (DriftMonitor, MetricRegistry, Observability, Tracer,
                       get_observability)
from repro.tune import gpu_profile, phi_profile

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(autouse=True)
def _clean_obs():
    """Every test sees (and leaves) a disabled, empty singleton."""
    obs = get_observability()
    obs.reset()
    obs.disable()
    yield obs
    obs.reset()
    obs.disable()


# ------------------------------------------------------------------ metrics
def test_counter_labels_and_disabled_guard():
    reg = MetricRegistry(enabled=True)
    c = reg.counter("repro_test_total", "help text")
    c.inc(kernel="gemm")
    c.inc(2, kernel="gemm")
    c.inc(kernel="syrk")
    assert c.value(kernel="gemm") == 3
    assert c.value(kernel="syrk") == 1
    assert c.value(kernel="absent") == 0
    with pytest.raises(ValueError):
        c.inc(-1, kernel="gemm")
    reg.enabled = False
    c.inc(100, kernel="gemm")
    assert c.value(kernel="gemm") == 3  # disabled inc is a no-op


def test_gauge_set_add_and_histogram_stats():
    reg = MetricRegistry(enabled=True)
    g = reg.gauge("repro_test_gauge")
    g.set(2.5, tier="HBM")
    g.add(0.5, tier="HBM")
    assert g.value(tier="HBM") == 3.0
    h = reg.histogram("repro_test_seconds", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 5.0, 50.0):
        h.observe(v)
    s, n = h.stats()
    assert n == 4 and s == pytest.approx(55.55)


def test_redeclaring_name_as_other_type_raises():
    reg = MetricRegistry(enabled=True)
    reg.counter("repro_test_total")
    reg.counter("repro_test_total")  # idempotent get-or-create
    with pytest.raises(TypeError):
        reg.gauge("repro_test_total")


def test_snapshot_round_trips_through_from_snapshot():
    reg = MetricRegistry(enabled=True)
    reg.counter("repro_a_total", "a").inc(3, kernel="gemm")
    reg.gauge("repro_b_ratio", "b").set(1.5, tier="HBM")
    h = reg.histogram("repro_c_seconds", "c", buckets=(0.1, 1.0))
    h.observe(0.05, kernel="lu")
    h.observe(7.0, kernel="lu")
    snap = reg.snapshot()
    clone = MetricRegistry.from_snapshot(snap)
    assert clone.to_prometheus_text() == reg.to_prometheus_text()
    # and the snapshot itself is plain JSON
    assert json.loads(json.dumps(snap)) == snap


def test_prometheus_exposition_golden():
    reg = MetricRegistry(enabled=True)
    reg.counter("repro_runs_total", "runs").inc(2, kernel="gemm")
    h = reg.histogram("repro_run_seconds", "wall", buckets=(0.5, 5.0))
    h.observe(0.25, kernel="gemm")
    h.observe(2.5, kernel="gemm")
    assert reg.to_prometheus_text() == (
        "# HELP repro_run_seconds wall\n"
        "# TYPE repro_run_seconds histogram\n"
        'repro_run_seconds_bucket{kernel="gemm",le="0.5"} 1\n'
        'repro_run_seconds_bucket{kernel="gemm",le="5.0"} 2\n'
        'repro_run_seconds_bucket{kernel="gemm",le="+Inf"} 2\n'
        'repro_run_seconds_sum{kernel="gemm"} 2.75\n'
        'repro_run_seconds_count{kernel="gemm"} 2\n'
        "# HELP repro_runs_total runs\n"
        "# TYPE repro_runs_total counter\n"
        'repro_runs_total{kernel="gemm"} 2\n')


# ------------------------------------------------------------------- tracer
def test_tracer_nests_spans_and_absorbs_flat_groups():
    t = [0.0]
    tr = Tracer("test", clock=lambda: t[0])
    with tr.span("outer", cat="tune"):
        t[0] = 1.0
        with tr.span("inner", cat="tune") as sp:
            sp.annotate(from_cache=False)
            t[0] = 2.0
    spans = tr.spans()
    outer = next(s for s in spans if s.name == "outer")
    inner = next(s for s in spans if s.name == "inner")
    assert inner.parent_id == outer.span_id and outer.parent_id is None
    assert dict(inner.args)["from_cache"] == "False"
    # flat groups land on their own pids, offset applied
    tr.add_flat_spans("gpu0", [("h2d A[0]", 0, 0.0, 0.5)], offset=1.0)
    tr.add_flat_spans("phi0", [("compute C[0]", 1, 0.0, 0.2)], offset=1.0)
    doc = tr.to_chrome_trace()
    pids = sorted({e["pid"] for e in doc["traceEvents"]})
    assert pids == [0, 1, 2]  # control + two device lanes
    names = {e["args"]["name"] for e in doc["traceEvents"]
             if e.get("ph") == "M" and e["name"] == "process_name"}
    assert {"test", "gpu0", "phi0"} <= names
    summ = tr.summary()
    assert summ["control_spans"] == 2
    assert summ["groups"]["gpu0"]["spans"] == 1
    assert summ["groups"]["phi0"]["span_seconds"] == pytest.approx(0.2)


# -------------------------------------------------------------------- drift
def test_drift_ratios_and_snapshot():
    mon = DriftMonitor()
    rec = mon.record("gemm", "HBM", "fp",
                     predicted_makespan=2.0, measured_seconds=1.0,
                     predicted_h2d_bytes=100, measured_h2d_bytes=100)
    assert rec.time_ratio == 0.5 and rec.byte_ratio == 1.0
    assert mon.ratio("gemm", "HBM", "fp") == 0.5
    snap = mon.snapshot()
    assert snap["rolling"]["gemm|HBM|fp"]["n"] == 1
    assert snap["records"][0]["time_ratio"] == 0.5


def test_stale_flags_trend_not_constant_scale():
    mon = DriftMonitor(window=8)
    # constant 50x model-vs-wall scale: ratio stable -> NOT stale
    for _ in range(4):
        mon.record("gemm", "HBM", "fp",
                   predicted_makespan=1.0, measured_seconds=50.0)
    assert mon.stale(threshold=1.25) == []
    # the machine slows 3x relative to its own history -> stale
    for _ in range(8):
        mon.record("lu", "HBM", "fp",
                   predicted_makespan=1.0, measured_seconds=1.0)
        mon.record("lu", "HBM", "fp",
                   predicted_makespan=1.0, measured_seconds=3.0)
    stale = mon.stale(threshold=1.25)
    assert [k for k, _ in stale] == [("lu", "HBM", "fp")]


# ------------------------------------------------- executor conformance core
def _seeded_gemm(m=256, n=256, k=128):
    rng = np.random.default_rng(0)
    A = rng.standard_normal((m, k)).astype(np.float32)
    B = rng.standard_normal((k, n)).astype(np.float32)
    C = np.zeros((m, n), dtype=np.float32)
    budget = (A.nbytes + B.nbytes + C.nbytes) // 3
    return A, B, C, budget


def test_executor_counters_match_schedule_stats_exactly():
    obs = get_observability()
    obs.enable(metrics=True)
    A, B, C, budget = _seeded_gemm()
    part = plan_gemm_partition(A.shape[0], B.shape[1], A.shape[1], budget, 4)
    sched = build_gemm_schedule(part)
    ex = ScheduleExecutor()
    HostOocRuntime(executor=ex).gemm(A, B, C, 1.0, 0.0, part,
                                     schedule=sched)
    stats = schedule_stats(sched)
    m = obs.metrics
    # executor byte counters == schedule-modeled totals, exactly
    assert ex.last_h2d_bytes == stats["h2d_bytes"] \
        == sched.total_bytes(OpKind.H2D)
    assert ex.last_d2h_bytes == stats["d2h_bytes"] \
        == sched.total_bytes(OpKind.D2H)
    assert m.get("repro_executor_h2d_bytes").value(kernel="gemm") \
        == stats["h2d_bytes"]
    assert m.get("repro_executor_d2h_bytes").value(kernel="gemm") \
        == stats["d2h_bytes"]
    assert m.get("repro_executor_flops_total").value(kernel="gemm") \
        == stats["flops"]
    assert m.get("repro_executor_runs_total").value(kernel="gemm") == 1
    _, n_runs = m.get("repro_executor_run_seconds").stats(kernel="gemm")
    assert n_runs == 1


def test_tuned_gemm_records_drift_with_unit_byte_ratio(tmp_path):
    from repro.tune import AutoTuner, PlanCache

    obs = get_observability()
    obs.enable(metrics=True)
    A, B, C, budget = _seeded_gemm()
    tuner = AutoTuner(profile=gpu_profile(), fingerprint="test",
                      cache=PlanCache(str(tmp_path / "plans.json")),
                      max_steps=128, nbuf_options=(1, 2))
    out = ooc_gemm(A, B, budget_bytes=budget, tune="auto", tuner=tuner)
    assert np.abs(out - A @ B).max() < 1e-2
    recs = obs.drift.records("gemm")
    assert len(recs) == 1
    rec = recs[0]
    assert rec.predicted_makespan > 0 and rec.measured_seconds > 0
    assert rec.byte_ratio == 1.0
    assert rec.measured_h2d_bytes == rec.predicted_h2d_bytes > 0
    assert rec.measured_d2h_bytes == rec.predicted_d2h_bytes
    # tuner search instrumented too
    assert obs.metrics.get("repro_tune_searches_total") is not None


def test_hybrid_run_conformance_and_single_trace(tmp_path):
    obs = get_observability()
    obs.enable(metrics=True, trace=True, trace_name="acceptance")
    A, B, C, budget = _seeded_gemm(m=512)
    devices = [DeviceSpec("gpu0", gpu_profile(), budget),
               DeviceSpec("phi0", phi_profile(), budget)]
    out = ooc_gemm(A, B, budget_bytes=budget, tune="auto",
                   devices=devices, tolerance=0.1)
    assert np.abs(out - A @ B).max() < 1e-2
    # hybrid drift: bytes exact, prediction present
    recs = [r for r in obs.drift.records("gemm") if r.tier == "HYBRID"]
    assert len(recs) == 1
    assert recs[0].byte_ratio == 1.0
    assert recs[0].fingerprint == "gpu0+phi0"
    assert recs[0].predicted_makespan > 0
    # one trace doc: control pid + one executor lane-group per device
    doc = obs.tracer.to_chrome_trace()
    lanes = {e["args"]["name"] for e in doc["traceEvents"]
             if e.get("ph") == "M" and e["name"] == "process_name"}
    assert {"acceptance", "gpu0", "phi0"} <= lanes
    cats = {e.get("cat") for e in doc["traceEvents"] if e.get("ph") == "X"}
    assert "tune" in cats and "merge" in cats
    assert obs.metrics.get("repro_hybrid_runs_total").value(
        kernel="gemm") == 1


def test_last_spans_reset_between_runs():
    A, B, C, budget = _seeded_gemm()
    part = plan_gemm_partition(A.shape[0], B.shape[1], A.shape[1], budget, 4)
    sched = build_gemm_schedule(part)
    ex = ScheduleExecutor(record_spans=True)
    rt = HostOocRuntime(executor=ex)
    rt.gemm(A, B, C.copy(), 1.0, 0.0, part, schedule=sched)
    assert ex.last_spans
    ex.record_spans = False
    rt.gemm(A, B, C.copy(), 1.0, 0.0, part, schedule=sched)
    # stale spans from the recorded run must not leak into the second
    assert ex.last_spans == []


def test_disabled_obs_records_nothing():
    obs = get_observability()
    A, B, C, budget = _seeded_gemm()
    part = plan_gemm_partition(A.shape[0], B.shape[1], A.shape[1], budget, 4)
    HostOocRuntime().gemm(A, B, C, 1.0, 0.0, part)
    assert obs.metrics.snapshot()["metrics"] == []
    assert obs.drift.records() == []


# ---------------------------------------------------------- facade + tools
def test_hcl_facade_returns_enabled_singleton():
    obs = hclObservability(enable=True, trace=True, trace_name="facade")
    assert obs is get_observability()
    assert obs.metrics.enabled and obs.tracer is not None
    assert obs.tracer.name == "facade"
    assert hclObservability() is obs  # bare call = accessor, no state change
    assert obs.metrics.enabled


def test_observability_snapshot_shape():
    obs = Observability()
    obs.enable(metrics=True, trace=True)
    obs.metrics.counter("repro_x_total").inc()
    obs.record_drift("gemm", "HBM", "fp",
                     predicted_makespan=1.0, measured_seconds=2.0)
    with obs.span("phase"):
        pass
    snap = obs.snapshot()
    assert {f["name"] for f in snap["metrics"]} >= {
        "repro_x_total", "repro_drift_records_total",
        "repro_drift_time_ratio", "repro_drift_byte_ratio"}
    assert snap["drift"]["rolling"]["gemm|HBM|fp"]["last_time_ratio"] == 2.0
    assert snap["trace"]["control_spans"] == 1
    assert json.loads(json.dumps(snap)) == snap


def test_export_trace_stdout_summary_smoke():
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "scripts", "export_trace.py"),
         "--mode", "sim", "--M", "256", "--N", "256", "--K", "128",
         "--budget-mb", "0.5", "--out", "-", "--summary"],
        capture_output=True, text=True, timeout=120,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")})
    assert proc.returncode == 0, proc.stderr
    doc = json.loads(proc.stdout)  # stdout is pure JSON
    assert doc["traceEvents"]
    assert doc["otherData"]["h2d_bytes"] > 0
    assert "summary:" in proc.stderr and "pid 0" in proc.stderr


def test_run_report_renders_snapshot_markdown():
    sys.path.insert(0, os.path.join(REPO, "scripts"))
    try:
        from run_report import render_markdown
    finally:
        sys.path.pop(0)
    obs = Observability()
    obs.enable(metrics=True)
    obs.metrics.counter("repro_executor_runs_total").inc(kernel="gemm")
    obs.record_drift("gemm", "HBM", "fp", predicted_makespan=1.0,
                     measured_seconds=2.0, predicted_h2d_bytes=10,
                     measured_h2d_bytes=10)
    md = render_markdown(obs.snapshot())
    assert "`repro_executor_runs_total`" in md
    assert "`gemm|HBM|fp`" in md and "| 1 |" in md  # byte ratio column


# ------------------------------------------------- ISSUE 8 satellites
def test_stale_single_observation_never_flagged():
    """One sample has no trend: its ratio IS the baseline."""
    mon = DriftMonitor(window=8)
    # wildly off-scale single observation — still not stale
    mon.record("gemm", "HBM", "fp",
               predicted_makespan=1.0, measured_seconds=500.0)
    assert mon.stale(threshold=1.25) == []
    # a second, matching observation: stable -> still not stale
    mon.record("gemm", "HBM", "fp",
               predicted_makespan=1.0, measured_seconds=500.0)
    assert mon.stale(threshold=1.25) == []


def test_stale_baseline_survives_window_roll():
    """The staleness baseline is the key's FIRST ratio, not the oldest
    surviving deque entry — a slow drift must still be flagged after the
    rolling window has forgotten the early history."""
    mon = DriftMonitor(window=4)
    mon.record("lu", "HBM", "fp",
               predicted_makespan=1.0, measured_seconds=1.0)   # baseline 1.0
    # drift far past the window: the deque now only holds ~2.0 ratios
    for ratio in (1.2, 1.5, 1.8, 2.0, 2.0, 2.0, 2.0):
        mon.record("lu", "HBM", "fp",
                   predicted_makespan=1.0, measured_seconds=ratio)
    assert ("lu", "HBM", "fp") in [k for k, _ in mon.stale(threshold=1.25)]
    snap = mon.snapshot()
    assert snap["rolling"]["lu|HBM|fp"]["first_time_ratio"] == 1.0


def test_prometheus_empty_histogram_family():
    """A histogram family with no observations exposes only HELP/TYPE and
    round-trips through the JSON snapshot."""
    reg = MetricRegistry(enabled=True)
    reg.histogram("repro_test_seconds", "help text")
    text = reg.to_prometheus_text()
    assert "# HELP repro_test_seconds help text" in text
    assert "# TYPE repro_test_seconds histogram" in text
    assert "repro_test_seconds_bucket" not in text
    back = MetricRegistry.from_snapshot(reg.snapshot())
    assert back.to_prometheus_text() == text


def test_prometheus_label_values_escaped():
    """Label values with spaces, quotes, backslashes and newlines must
    survive exposition (Prometheus text format escaping rules)."""
    reg = MetricRegistry(enabled=True)
    reg.counter("repro_test_total").inc(
        tag='S(a[0]) "quoted" back\\slash', note="line1\nline2")
    text = reg.to_prometheus_text()
    assert 'tag="S(a[0]) \\"quoted\\" back\\\\slash"' in text
    assert 'note="line1\\nline2"' in text
    # the raw value is untouched in the JSON snapshot
    snap = reg.snapshot()
    labels = snap["metrics"][0]["samples"][0]["labels"]
    assert labels["tag"] == 'S(a[0]) "quoted" back\\slash'


def test_from_snapshot_unknown_metric_type():
    snap = {"metrics": [{"name": "repro_x", "type": "summary",
                         "samples": []}]}
    with pytest.raises(ValueError, match="unknown metric type 'summary'"):
        MetricRegistry.from_snapshot(snap)


def test_run_report_merges_sidecar_directory(tmp_path):
    """--input <dir>: counters add, gauges last-win, histograms accumulate,
    drift records concatenate across *.metrics.json sidecars."""
    sys.path.insert(0, os.path.join(REPO, "scripts"))
    try:
        from run_report import merge_snapshots, render_markdown
    finally:
        sys.path.pop(0)

    def sidecar(name, runs, gauge, wall):
        obs = Observability()
        obs.enable(metrics=True)
        for _ in range(runs):
            obs.metrics.counter("repro_executor_runs_total",
                                "runs").inc(kernel="gemm")
        obs.metrics.gauge("repro_drift_time_ratio").set(gauge, kernel="gemm")
        obs.metrics.histogram("repro_executor_run_seconds").observe(
            wall, kernel="gemm")
        obs.record_drift("gemm", "HBM", "fp", predicted_makespan=1.0,
                         measured_seconds=wall, predicted_h2d_bytes=8,
                         measured_h2d_bytes=8)
        path = tmp_path / f"{name}.metrics.json"
        path.write_text(json.dumps(obs.snapshot()))
        return path

    a = sidecar("a", runs=2, gauge=1.5, wall=0.25)
    b = sidecar("b", runs=3, gauge=2.5, wall=0.75)
    snap = merge_snapshots([a, b])
    fams = {f["name"]: f for f in snap["metrics"]}
    assert fams["repro_executor_runs_total"]["samples"][0]["value"] == 5
    assert fams["repro_drift_time_ratio"]["samples"][0]["value"] == 2.5
    h = fams["repro_executor_run_seconds"]["samples"][0]
    assert h["count"] == 2 and h["sum"] == pytest.approx(1.0)
    assert len(snap["drift"]["records"]) == 2
    roll = snap["drift"]["rolling"]["gemm|HBM|fp"]
    assert roll["n"] == 2 and roll["first_time_ratio"] == 0.25
    md = render_markdown(snap)
    assert "## Sources" in md and str(a) in md
