"""Optional-hypothesis shim: property tests degrade to deterministic samples.

``hypothesis`` is not baked into the CI container.  When present, this module
re-exports the real ``given`` / ``settings`` / ``strategies``; when absent it
provides a tiny deterministic stand-in that expands each ``sampled_from``
strategy into a pytest parametrization covering every pool value at least
once (a diagonal sweep, not the full cross product), so the property tests
still execute meaningful cases instead of being skipped wholesale.
"""

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _SampledFrom(list):
        """Marker list: the pool of values a strategy draws from."""

    class _Strategies:
        @staticmethod
        def sampled_from(values):
            return _SampledFrom(values)

        @staticmethod
        def integers(min_value, max_value):
            lo, hi = int(min_value), int(max_value)
            mid = (lo + hi) // 2
            pool = sorted({lo, lo + (hi - lo) // 7, mid, hi - 1, hi})
            return _SampledFrom(v for v in pool if lo <= v <= hi)

        @staticmethod
        def floats(min_value, max_value, **kw):
            lo, hi = float(min_value), float(max_value)
            geo = (lo * hi) ** 0.5 if lo > 0 else (lo + hi) / 2
            return _SampledFrom(sorted({lo, geo, (lo + hi) / 2, hi}))

    st = _Strategies()

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    def given(**strategies):
        names = list(strategies)
        pools = [list(strategies[n]) for n in names]
        depth = max(len(p) for p in pools)
        combos = [tuple(p[i % len(p)] for p in pools) for i in range(depth)]

        def deco(fn):
            return pytest.mark.parametrize(",".join(names), combos)(fn)

        return deco
