"""MMOOC end-to-end: every backend must equal the DGEMM oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests._hypothesis_shim import given, settings, st

from repro.core import is_in_core, ooc_gemm, ooc_syrk
from repro.core.api import (hclDeviceFactory, hclGetMemSize,
                            hclMatrixPartitioner, hclRuntimeFactory)
from repro.core.ooc_attention import ooc_attention
from repro.kernels import ref


def _problem(rng, M, N, K, dtype=np.float32):
    A = rng.standard_normal((M, K)).astype(dtype)
    B = rng.standard_normal((K, N)).astype(dtype)
    C = rng.standard_normal((M, N)).astype(dtype)
    return A, B, C


@pytest.mark.parametrize("M,N,K,frac", [
    (256, 256, 128, 4),
    (512, 384, 256, 8),
    (640, 128, 128, 3),
    (128, 128, 64, 1),     # in-core path
])
def test_ooc_gemm_host_matches_oracle(rng, M, N, K, frac):
    A, B, C = _problem(rng, M, N, K)
    budget = (A.nbytes + B.nbytes + C.nbytes) // frac
    out = ooc_gemm(A, B, C, 1.5, 0.25, budget_bytes=budget,
                   backend="host", validate=True)
    expect = 1.5 * (A.astype(np.float64) @ B) + 0.25 * C
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)


@given(nstreams=st.sampled_from([1, 2]), nbuf=st.sampled_from([1, 2, 3]),
       frac=st.sampled_from([2, 5]))
@settings(max_examples=10, deadline=None)
def test_ooc_gemm_any_pipeline_config(nstreams, nbuf, frac):
    """Result is invariant to the pipeline configuration (the overlap is a
    schedule property, never a numerics property)."""
    rng = np.random.default_rng(7)
    A, B, C = _problem(rng, 320, 192, 128)
    budget = (A.nbytes + B.nbytes + C.nbytes) // frac
    out = ooc_gemm(A, B, C, 2.0, -0.5, budget_bytes=budget, backend="host",
                   nstreams=nstreams, nbuf=nbuf, validate=True)
    expect = 2.0 * (A.astype(np.float64) @ B) - 0.5 * C
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)


def test_ooc_gemm_vmem_backend(rng):
    A, B, C = _problem(rng, 256, 256, 256)
    budget = A.nbytes  # force OOC
    out = ooc_gemm(jnp.asarray(A), jnp.asarray(B), jnp.asarray(C),
                   1.0, 1.0, budget_bytes=budget, backend="vmem")
    expect = A.astype(np.float64) @ B + C
    np.testing.assert_allclose(np.asarray(out), expect, rtol=1e-4, atol=1e-4)


def test_in_core_switch():
    assert is_in_core(64, 64, 64, 1 << 20, 4)
    assert not is_in_core(1024, 1024, 1024, 1 << 20, 4)


def test_hcl_facade(rng):
    dev = hclDeviceFactory.create("HBM", 0, mem_bytes=300_000)
    assert hclGetMemSize(dev) == 300_000
    rt = hclRuntimeFactory.create(dev)
    part = hclMatrixPartitioner(512, 256, 128, dev.mem_bytes)
    A, B, C = _problem(rng, 512, 256, 128)
    out = rt.gemm(A, B, C, 1.0, 0.0, part)
    np.testing.assert_allclose(out, A @ B, rtol=1e-4, atol=1e-4)


def test_ooc_attention_matches_oracle(rng):
    H, hkv, d, S = 16, 4, 64, 2048
    q = rng.standard_normal((H, d)).astype(np.float32)
    k = rng.standard_normal((S, hkv, d)).astype(np.float32)
    v = rng.standard_normal((S, hkv, d)).astype(np.float32)
    out = ooc_attention(q, k, v, budget_bytes=S * hkv * d * 4 // 3,
                        validate=True)
    expect = ref.decode_attention_ref(
        jnp.asarray(q)[None], jnp.asarray(k)[None], jnp.asarray(v)[None],
        jnp.asarray([S]))[0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-4)


def test_ooc_attention_narrow_kv_dtype_keeps_f32_accuracy(rng):
    """A reduced-precision KV cache must not quantize the f32 carry on its
    way out (regression: the host output buffer briefly took the KV dtype)."""
    H, hkv, d, S = 16, 4, 64, 1024
    q = rng.standard_normal((H, d)).astype(np.float32)
    k = rng.standard_normal((S, hkv, d)).astype(np.float16)
    v = rng.standard_normal((S, hkv, d)).astype(np.float16)
    out = ooc_attention(q, k, v, budget_bytes=S * hkv * d * 4 // 3)
    expect = ref.decode_attention_ref(
        jnp.asarray(q)[None], jnp.asarray(k).astype(jnp.float32)[None],
        jnp.asarray(v).astype(jnp.float32)[None], jnp.asarray([S]))[0]
    assert out.dtype == q.dtype
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


def test_ooc_cholesky(rng):
    """Paper future-work: blocked Cholesky with the OOC-SYRK trailing
    update (repro.core.ooc_factor)."""
    from repro.core.ooc_factor import ooc_cholesky
    n = 320
    X = rng.standard_normal((n, n)).astype(np.float32)
    A = (X @ X.T + n * np.eye(n)).astype(np.float32)
    L = ooc_cholesky(A, panel=128,
                     budget_bytes=(3 * n * n * 4) // 4, backend="host")
    # fp32 engine (JAX x64 is off): relative reconstruction error
    rel = np.abs(L @ L.T - A).max() / np.abs(A).max()
    assert rel < 1e-5, rel
    assert np.allclose(L, np.tril(L))


def test_ooc_cholesky_matches_numpy_oracle(rng):
    """Element-wise agreement with np.linalg.cholesky, not just L@L^T."""
    from repro.core.ooc_factor import ooc_cholesky
    n = 384
    X = rng.standard_normal((n, n)).astype(np.float32)
    A = (X @ X.T + n * np.eye(n)).astype(np.float32)
    L = ooc_cholesky(A, panel=128,
                     budget_bytes=(3 * n * n * 4) // 5, backend="host")
    expect = np.linalg.cholesky(A.astype(np.float64))
    scale = np.abs(expect).max()
    np.testing.assert_allclose(L / scale, expect / scale,
                               rtol=0, atol=2e-6)


@pytest.mark.parametrize("backend", ["host", "vmem"])
def test_ooc_syrk_matches_oracle(rng, backend):
    """The third DSL kernel: blocked SYRK (the Cholesky trailing update) as
    a first-class PipelineSpec, cross-checked on both single-chip tiers."""
    n, k = 384, 192
    P = rng.standard_normal((n, k)).astype(np.float32)
    C = rng.standard_normal((n, n)).astype(np.float32)
    budget = (2 * P.nbytes + C.nbytes) // 4  # force out-of-core
    out = ooc_syrk(P, C, -2.0, 0.5, budget_bytes=budget,
                   backend=backend, validate=(backend == "host"))
    expect = np.asarray(ref.gemm_ref(
        jnp.asarray(P), jnp.asarray(P).T, jnp.asarray(C),
        alpha=-2.0, beta=0.5))
    np.testing.assert_allclose(np.asarray(out), expect, rtol=1e-4, atol=1e-4)


def test_ooc_syrk_in_core_switch(rng):
    n, k = 128, 64
    P = rng.standard_normal((n, k)).astype(np.float32)
    out = ooc_syrk(P, budget_bytes=1 << 30, backend="host")
    np.testing.assert_allclose(out, P @ P.T, rtol=1e-4, atol=1e-4)
