"""Schedule correctness (the paper's event program) + simulator properties."""

import pytest

from tests._hypothesis_shim import given, settings, st

from repro.core import (
    OpKind,
    build_attention_schedule,
    build_gemm_schedule,
    build_vendor_schedule,
    gpu_like,
    phi_like,
    plan_attention_partition,
    plan_gemm_partition,
    schedule_stats,
    simulate,
    tpu_v5e_vmem,
    validate_schedule,
)
from repro.core.streams import Op, Event, Schedule, ScheduleError, Device, StreamFactory

dims = st.sampled_from([128, 256, 384, 512, 1024])


@given(M=dims, N=dims, K=dims,
       nstreams=st.sampled_from([1, 2]),
       nbuf=st.sampled_from([1, 2, 3]),
       frac=st.sampled_from([3, 4, 8]))
@settings(max_examples=60, deadline=None)
def test_gemm_schedule_event_correct(M, N, K, nstreams, nbuf, frac):
    """For any partition and any stream/buffer count, the generated event
    program is deadlock-free and never overwrites live buffers — under ANY
    legal interleaving (the validator checks the full happens-before
    relation, not one execution)."""
    full = (M * K + K * N + M * N) * 4
    # floor keeps the minimal aligned working set feasible for any K<=1024
    part = plan_gemm_partition(M, N, K, max(full // frac, 700_000), 4)
    sched = build_gemm_schedule(part, nstreams=nstreams, nbuf=nbuf)
    validate_schedule(sched)
    st_ = schedule_stats(sched)
    assert st_["flops"] >= 2 * M * N * K
    # every block of C travels H2D once and D2H once
    assert st_["d2h_bytes"] == M * N * 4


def test_gemm_schedule_transfers_B_once_per_column():
    part = plan_gemm_partition(1024, 1024, 512, 2_000_000, 4)
    sched = build_gemm_schedule(part)
    b_ops = [o for o in sched.ops if o.tag.startswith("S(b")]
    assert len(b_ops) == part.w  # column reuse (vendor baseline re-sends)
    vend = build_vendor_schedule(part, tile=512)
    vb_ops = [o for o in vend.ops if o.tag.startswith("S(b")]
    assert len(vb_ops) == 4  # one B panel per 512-tile of C: no reuse


def test_vendor_B_retransfer_bytes_exceed_lib():
    """Claim C3's mechanism: the vendor schedule re-sends B panels per C
    tile, so its B traffic strictly exceeds the libhclooc schedule's
    once-per-column reuse (and total H2D follows)."""
    part = plan_gemm_partition(2048, 2048, 1024, 8_000_000, 4)
    lib = build_gemm_schedule(part)
    vend = build_vendor_schedule(part, tile=512)

    def b_bytes(sched):
        return sum(o.bytes for o in sched.ops
                   if o.kind == OpKind.H2D and o.tag.startswith("S(b"))

    assert b_bytes(vend) > b_bytes(lib)
    # lib moves each B column exactly once: K*N elements total
    assert b_bytes(lib) == 1024 * 2048 * 4
    # vendor re-sends the panel for every tile row of C
    n_tile_rows = (2048 + 511) // 512
    assert b_bytes(vend) == n_tile_rows * 1024 * 2048 * 4
    st_l = schedule_stats(lib)
    st_v = schedule_stats(vend)
    assert st_v["h2d_bytes"] > st_l["h2d_bytes"]


def test_syrk_schedule_event_correct():
    """Third DSL kernel: the SYRK spec compiles to a valid event program
    with the panel's transposed slices transferred once per column."""
    from repro.core import build_syrk_schedule
    part = plan_gemm_partition(1024, 1024, 256, 3_000_000, 4)
    for ns, nb in ((1, 1), (2, 2), (2, 3)):
        sched = build_syrk_schedule(part, nstreams=ns, nbuf=nb)
        validate_schedule(sched)
    sched = build_syrk_schedule(part)
    pt_ops = [o for o in sched.ops if o.tag.startswith("S(pt")]
    assert len(pt_ops) == part.w  # column reuse, like GEMM's B


def test_attention_schedule_valid():
    part = plan_attention_partition(8192, 8, 128, 4 * 2**20, 2)
    sched = build_attention_schedule(part, 8, 128, 32)
    validate_schedule(sched)


def test_validator_catches_missing_wait():
    dev = Device("HBM", 0, 1 << 20)
    sched = Schedule(dev, StreamFactory.create(dev, 2))
    ev = Event("r0")
    sched.issue(Op(kind=OpKind.H2D, tag="S(a0)", stream=0, records=ev,
                   buffers_written=(("A", 0),), bytes=64))
    # compute on the OTHER stream without waiting for the transfer
    sched.issue(Op(kind=OpKind.COMPUTE, tag="GEMM", stream=1,
                   buffers_read=(("A", 0),), flops=10))
    with pytest.raises(ScheduleError):
        validate_schedule(sched)


def test_validator_catches_deadlock():
    dev = Device("HBM", 0, 1 << 20)
    sched = Schedule(dev, StreamFactory.create(dev, 2))
    e1, e2 = Event("e1"), Event("e2")
    sched.issue(Op(kind=OpKind.COMPUTE, tag="a", stream=0,
                   waits=(e2,), records=e1))
    sched.issue(Op(kind=OpKind.COMPUTE, tag="b", stream=1,
                   waits=(e1,), records=e2))
    with pytest.raises(ScheduleError):
        validate_schedule(sched)


# ---------------------------------------------------------------- simulator
def _mk(M=2048, N=2048, K=1024, frac=4):
    full = (M * K + K * N + M * N) * 8
    return plan_gemm_partition(M, N, K, full // frac, 8)


def test_overlap_beats_serial():
    """Claim C3 mechanics: the 2-stream overlapped pipeline beats the
    non-overlapping vendor-style schedule on GPU-like hardware."""
    part = _mk()
    hw = gpu_like()
    t_lib = simulate(build_gemm_schedule(part, 2, 2), hw).makespan
    t_vendor = simulate(build_vendor_schedule(part), hw).makespan
    assert t_vendor > 1.5 * t_lib


def test_phi_prefers_one_stream():
    """Claim C5: on Phi-like hardware (shared transfer engine, threads split
    across streams — measured 0.76x aggregate) a single stream wins in the
    compute-dominated regime the paper measured (large N=K)."""
    part = _mk(8192, 8192, 8192, 6)
    t1 = simulate(build_gemm_schedule(part, 1, 2), phi_like(nstreams=1)).makespan
    t2 = simulate(build_gemm_schedule(part, 2, 2), phi_like(nstreams=2)).makespan
    assert t1 < t2


def test_gpu_prefers_two_streams():
    part = _mk()
    hw = gpu_like()
    t1 = simulate(build_gemm_schedule(part, 1, 1), hw).makespan
    t2 = simulate(build_gemm_schedule(part, 2, 2), hw).makespan
    assert t2 < t1


def test_simulator_conserves_work():
    part = _mk()
    hw = tpu_v5e_vmem()
    res = simulate(build_gemm_schedule(part, 2, 2), hw)
    sched = build_gemm_schedule(part, 2, 2)
    assert res.flops == sched.total_flops()
    # makespan >= each engine's busy time (no engine overcommitted)
    for pool, busy in res.busy.items():
        cap = hw.pools[pool]
        assert busy <= res.makespan * cap + 1e-9


def test_simulator_respects_events():
    """Every op starts after its waited events record."""
    part = _mk(1024, 1024, 512)
    sched = build_gemm_schedule(part, 2, 2)
    res = simulate(sched, gpu_like())
    end = {}
    start = {}
    for tag, stream, s, e in res.op_spans:
        start[tag] = s
        end[tag] = e
    rec = {o.records.name: o.tag for o in sched.ops if o.records}
    for o in sched.ops:
        for ev in o.waits:
            assert start[o.tag] >= end[rec[ev.name]] - 1e-12
