"""Conformance fuzzing: seeded fault scenarios over every kernel (§12).

The invariant under test is absolute: for every (schedule, FaultPlan)
pair whose faults are recoverable, the recovered run is **bitwise
identical** to the fault-free run, the nominal byte counters still
reconcile exactly with ``schedule_stats`` (failed-attempt traffic is
accounted separately), and every planned fault was actually consumed.

50 seeds x 4 kernels = 200 deterministic cases, each exactly
reproducible from its ``(seed, kernel)`` pair.  A divergence shrinks to
a minimal failing ``(op, cls)`` via :func:`shrink_plan` before the
assertion fires, so a red case names the exact injection that broke
recovery.
"""

import numpy as np
import pytest

from repro.core.ooc_factor import ooc_cholesky, ooc_lu
from repro.core.partitioner import plan_gemm_partition
from repro.core.pipeline import (build_gemm_schedule, build_syrk_schedule,
                                 schedule_stats)
from repro.core.runtime import HostOocRuntime
from repro.core.streams import OpKind
from repro.fault import FaultPlan, FaultPolicy, FaultSpec

N_SEEDS = 50
SEEDS = list(range(N_SEEDS))
RATE = 0.25          # executor-level pipelines (gemm / syrk)
FACTOR_RATE = 0.10   # factor schedules are long; keep replay volume sane

_POL = dict(sleep=lambda s: None)


def _policy():
    return FaultPolicy(**_POL)


# ---------------------------------------------------------------- fixtures
@pytest.fixture(scope="module")
def gemm_case():
    rng = np.random.default_rng(1000)
    m, n, k = 128, 48, 32
    A = rng.standard_normal((m, k))
    B = rng.standard_normal((k, n))
    C = rng.standard_normal((m, n))
    part = plan_gemm_partition(m, n, k, 60_000)
    sched = build_gemm_schedule(part, nstreams=2, nbuf=2)
    rt = HostOocRuntime()
    clean = rt.gemm(A, B, C, 1.0, 0.5, part, schedule=sched)
    return dict(A=A, B=B, C=C, part=part, sched=sched, clean=clean)


@pytest.fixture(scope="module")
def syrk_case():
    rng = np.random.default_rng(2000)
    m, k = 128, 32
    P = rng.standard_normal((m, k))
    C = rng.standard_normal((m, m))
    C = C + C.T
    part = plan_gemm_partition(m, m, k, 100_000)
    sched = build_syrk_schedule(part, nstreams=2, nbuf=2)
    rt = HostOocRuntime()
    clean = rt.syrk(P, C, 1.0, 0.5, part, schedule=sched)
    return dict(P=P, C=C, part=part, sched=sched, clean=clean)


@pytest.fixture(scope="module")
def chol_case():
    rng = np.random.default_rng(3000)
    n = 128
    A = rng.standard_normal((n, n))
    spd = A @ A.T + n * np.eye(n)
    budget = 4 * spd.nbytes
    clean = ooc_cholesky(spd, panel=32, budget_bytes=budget)
    return dict(A=spd, budget=budget, clean=clean)


@pytest.fixture(scope="module")
def lu_case():
    rng = np.random.default_rng(4000)
    n = 128
    A = rng.standard_normal((n, n)) + n * np.eye(n)
    budget = 4 * A.nbytes
    clean_lu, clean_p = ooc_lu(A, panel=32, budget_bytes=budget)
    return dict(A=A, budget=budget, clean_lu=clean_lu, clean_p=clean_p)


# ------------------------------------------------------------ shrink helper
def shrink_plan(plan, fails):
    """Minimal failing sub-plan of ``plan`` under predicate ``fails``.

    Tries every single-spec sub-plan first (the common case: one injection
    breaks recovery); falls back to greedy spec removal when the failure
    needs an interaction.  Returns a plan for which ``fails`` holds with
    no removable spec — for a single-spec result, the exact ``(op, cls)``
    culprit.
    """
    for s in plan.specs:
        single = FaultPlan(specs=(s,), seed=plan.seed)
        if fails(single):
            return single
    cur = plan
    changed = True
    while changed and len(cur.specs) > 1:
        changed = False
        for i in range(len(cur.specs)):
            cand = FaultPlan(specs=cur.specs[:i] + cur.specs[i + 1:],
                             seed=cur.seed)
            if fails(cand):
                cur = cand
                changed = True
                break
    return cur


def test_shrink_finds_single_culprit():
    plan = FaultPlan(specs=tuple(
        FaultSpec(op=i, cls="h2d_error") for i in range(8)))
    got = shrink_plan(plan, lambda p: any(s.op == 5 for s in p.specs))
    assert [(s.op, s.cls) for s in got.specs] == [(5, "h2d_error")]


def test_shrink_preserves_interacting_pair():
    plan = FaultPlan(specs=tuple(
        FaultSpec(op=i, cls="h2d_error") for i in range(6)))

    def fails(p):
        ops = {s.op for s in p.specs}
        return {1, 4} <= ops

    got = shrink_plan(plan, fails)
    assert {s.op for s in got.specs} == {1, 4}


# ------------------------------------------------------- executor pipelines
def _reconcile(executor, sched, injected):
    """The byte-accounting invariant every fuzz case must satisfy."""
    stats = schedule_stats(sched)
    assert executor.last_h2d_bytes == stats["h2d_bytes"]
    assert executor.last_d2h_bytes == stats["d2h_bytes"]
    expect_replayed = sum(
        sched.ops[i].bytes for i, cls in injected
        if cls == "h2d_error" and sched.ops[i].kind == OpKind.H2D)
    fs = executor.last_fault_stats
    assert fs["replayed_h2d_bytes"] == expect_replayed
    assert fs["injected"] == len(injected)


def _run_gemm(case, plan):
    rt = HostOocRuntime()
    inj = plan.injector()
    out = rt.gemm(case["A"], case["B"], case["C"], 1.0, 0.5, case["part"],
                  schedule=case["sched"], faults=inj, policy=_policy())
    return out, rt.executor, inj


@pytest.mark.parametrize("seed", SEEDS)
def test_fuzz_gemm_recovers_bitwise(gemm_case, seed):
    sched = gemm_case["sched"]
    plan = FaultPlan.random(seed, sched, RATE)
    out, ex, inj = _run_gemm(gemm_case, plan)
    assert inj.exhausted()
    _reconcile(ex, sched, inj.injected)
    if not np.array_equal(out, gemm_case["clean"]):
        minimal = shrink_plan(plan, lambda p: not np.array_equal(
            _run_gemm(gemm_case, p)[0], gemm_case["clean"]))
        pytest.fail(
            f"seed {seed}: recovered GEMM diverged; minimal failing "
            f"faults: {[(s.op, s.cls) for s in minimal.specs]}")


def _run_syrk(case, plan):
    rt = HostOocRuntime()
    inj = plan.injector()
    out = rt.syrk(case["P"], case["C"], 1.0, 0.5, case["part"],
                  schedule=case["sched"], faults=inj, policy=_policy())
    return out, rt.executor, inj


@pytest.mark.parametrize("seed", SEEDS)
def test_fuzz_syrk_recovers_bitwise(syrk_case, seed):
    sched = syrk_case["sched"]
    plan = FaultPlan.random(seed, sched, RATE)
    out, ex, inj = _run_syrk(syrk_case, plan)
    assert inj.exhausted()
    _reconcile(ex, sched, inj.injected)
    if not np.array_equal(out, syrk_case["clean"]):
        minimal = shrink_plan(plan, lambda p: not np.array_equal(
            _run_syrk(syrk_case, p)[0], syrk_case["clean"]))
        pytest.fail(
            f"seed {seed}: recovered SYRK diverged; minimal failing "
            f"faults: {[(s.op, s.cls) for s in minimal.specs]}")


# -------------------------------------------------------- factor pipelines
class _Capture:
    """``faults=`` factory that hands the executor a prepared injector and
    keeps it (plus the compiled schedule) for post-run reconciliation."""

    def __init__(self, seed, rate):
        self.seed = seed
        self.rate = rate
        self.inj = None
        self.sched = None

    def __call__(self, sched):
        self.sched = sched
        self.inj = FaultPlan.random(self.seed, sched, self.rate).injector()
        return self.inj


@pytest.mark.parametrize("seed", SEEDS)
def test_fuzz_cholesky_recovers_bitwise(chol_case, seed):
    cap = _Capture(seed, FACTOR_RATE)
    L = ooc_cholesky(chol_case["A"], panel=32,
                     budget_bytes=chol_case["budget"],
                     faults=cap, fault_policy=_policy())
    assert cap.inj is not None and cap.inj.exhausted()
    assert np.array_equal(L, chol_case["clean"]), (
        f"seed {seed}: recovered Cholesky diverged; injected "
        f"{cap.inj.injected}")


@pytest.mark.parametrize("seed", SEEDS)
def test_fuzz_lu_recovers_bitwise(lu_case, seed):
    cap = _Capture(seed, FACTOR_RATE)
    LU, perm = ooc_lu(lu_case["A"], panel=32,
                      budget_bytes=lu_case["budget"],
                      faults=cap, fault_policy=_policy())
    assert cap.inj is not None and cap.inj.exhausted()
    assert np.array_equal(LU, lu_case["clean_lu"]) and \
        np.array_equal(perm, lu_case["clean_p"]), (
        f"seed {seed}: recovered LU diverged; injected {cap.inj.injected}")
