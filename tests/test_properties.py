"""Property-based conformance suite (ISSUE 4).

Two families, driven through the hypothesis shim:

  * every randomly-drawn partition / pipeline-spec / config combination
    compiles to a schedule that passes ``validate_schedule`` (the event
    program is safe under ANY legal interleaving), and
  * simulate-vs-execute conformance: the ``ScheduleExecutor``'s op
    completion order is a *linear extension* of the dependency partial
    order the simulator honors (stream program order + wait -> record
    edges), and the simulator never starts an op before its dependencies
    finish.  This pins the contract that lets one Schedule object drive
    both engines.
"""

import numpy as np
import pytest

from tests._hypothesis_shim import given, settings, st

from repro.core import (
    OpKind,
    ScheduleExecutor,
    attention_pipeline_spec,
    build_attention_schedule,
    build_gemm_schedule,
    build_syrk_schedule,
    build_vendor_schedule,
    compile_factor_pipeline,
    compile_pipeline,
    factor_pipeline_spec,
    gpu_like,
    plan_attention_partition,
    plan_gemm_partition,
    simulate,
    validate_schedule,
)

dims = st.sampled_from([128, 256, 384, 512])


def _dependency_edges(sched):
    """(pred, succ) pairs of the dependency partial order both engines must
    honor: per-stream program order plus wait -> recorder edges."""
    recorder = {}
    for idx, op in enumerate(sched.ops):
        if op.records is not None:
            recorder[op.records.name] = idx
    edges = []
    last_in_stream = {}
    for idx, op in enumerate(sched.ops):
        if op.stream in last_in_stream:
            edges.append((last_in_stream[op.stream], idx))
        last_in_stream[op.stream] = idx
        for ev in op.waits:
            edges.append((recorder[ev.name], idx))
    return edges


def _assert_simulator_honors_deps(sched, hw):
    res = simulate(sched, hw)
    # spans are appended in placement order; map each op to its span by
    # counting per-stream (a stream's ops keep their program order)
    per_stream = {}
    span_of = {}
    for tag, stream, t0, t1 in res.op_spans:
        pos = per_stream.get(stream, 0)
        per_stream[stream] = pos + 1
        span_of[(stream, pos)] = (t0, t1)
    pos_of = {}
    seen = {}
    for idx, op in enumerate(sched.ops):
        pos_of[idx] = (op.stream, seen.get(op.stream, 0))
        seen[op.stream] = seen.get(op.stream, 0) + 1
    for pred, succ in _dependency_edges(sched):
        t_pred_end = span_of[pos_of[pred]][1]
        t_succ_start = span_of[pos_of[succ]][0]
        assert t_succ_start >= t_pred_end - 1e-12, (
            f"simulator started {sched.ops[succ].tag} at {t_succ_start} "
            f"before its dependency {sched.ops[pred].tag} ended at "
            f"{t_pred_end}")
    return res


def _assert_executor_is_linear_extension(sched):
    """The executor completes ops in issue order; that order must extend
    the dependency partial order, or in-order execution would read data
    that is not ready."""
    for pred, succ in _dependency_edges(sched):
        assert pred < succ, (
            f"issue order is not a linear extension: "
            f"{sched.ops[succ].tag} (issue {succ}) depends on "
            f"{sched.ops[pred].tag} (issue {pred})")


# ------------------------------------------------------------ validate
@given(M=dims, N=dims, K=dims,
       nstreams=st.sampled_from([1, 2, 3]),
       nbuf=st.sampled_from([1, 2, 3]),
       frac=st.sampled_from([2, 4, 8]))
@settings(max_examples=40, deadline=None)
def test_random_gemm_specs_validate(M, N, K, nstreams, nbuf, frac):
    full = (M * K + K * N + M * N) * 4
    part = plan_gemm_partition(M, N, K, max(full // frac, 700_000), 4)
    for build in (build_gemm_schedule, build_syrk_schedule):
        sched = build(part, nstreams=nstreams, nbuf=nbuf)
        validate_schedule(sched)
        _assert_executor_is_linear_extension(sched)
    validate_schedule(build_vendor_schedule(part))


@given(S=st.sampled_from([512, 1024, 2048]),
       nstreams=st.sampled_from([1, 2]),
       nbuf=st.sampled_from([2, 3]),
       frac=st.sampled_from([2, 6]))
@settings(max_examples=20, deadline=None)
def test_random_attention_specs_validate(S, nstreams, nbuf, frac):
    kv_heads, head_dim, q_heads = 4, 64, 16
    budget = max(2 * S * kv_heads * head_dim * 2 // frac, 300_000)
    part = plan_attention_partition(S, kv_heads, head_dim, budget, 2)
    sched = build_attention_schedule(part, kv_heads, head_dim, q_heads,
                                     nstreams=nstreams, nbuf=nbuf)
    validate_schedule(sched)
    _assert_executor_is_linear_extension(sched)


@given(n=st.sampled_from([256, 320, 512, 700]),
       panel=st.sampled_from([64, 96, 128, 512]),
       kind=st.sampled_from(["cholesky", "lu"]),
       lookahead=st.sampled_from([0, 1, 2]),
       nstreams=st.sampled_from([1, 2]),
       nbuf=st.sampled_from([1, 2, 3]))
@settings(max_examples=40, deadline=None)
def test_random_factor_specs_validate(n, panel, kind, lookahead, nstreams,
                                      nbuf):
    spec = factor_pipeline_spec(n, panel, 64 * n * n * 4, 4, kind=kind,
                                lookahead=lookahead, nbuf=nbuf,
                                bm=64, bn=128)
    sched = compile_factor_pipeline(spec, nstreams=nstreams, nbuf=nbuf)
    validate_schedule(sched)
    _assert_executor_is_linear_extension(sched)
    _assert_simulator_honors_deps(sched, gpu_like())


# ------------------------------------- simulate-vs-execute conformance
@given(M=dims, N=dims, K=st.sampled_from([128, 256]),
       nstreams=st.sampled_from([1, 2]),
       nbuf=st.sampled_from([1, 2, 3]))
@settings(max_examples=10, deadline=None)
def test_executor_completion_extends_simulator_order(M, N, K, nstreams,
                                                     nbuf):
    """Execute a GEMM schedule with span recording: ops complete in issue
    order, which must be a linear extension of the dependency order the
    simulator schedules by — and the recorded spans cover every op."""
    rng = np.random.default_rng(M + N + K)
    full = (M * K + K * N + M * N) * 4
    part = plan_gemm_partition(M, N, K, max(full // 4, 700_000), 4)
    sched = build_gemm_schedule(part, nstreams=nstreams, nbuf=nbuf)
    validate_schedule(sched)
    _assert_executor_is_linear_extension(sched)
    _assert_simulator_honors_deps(sched, gpu_like())

    A = rng.standard_normal((M, K)).astype(np.float32)
    B = rng.standard_normal((K, N)).astype(np.float32)
    C = np.zeros((M, N), dtype=np.float32)
    ex = ScheduleExecutor(record_spans=True)
    ex.run(sched, operands={"A": A, "B": B}, outputs={"C": C},
           ctx={"alpha": 1.0, "beta": 0.0})
    assert len(ex.last_spans) == len(sched.ops)
    # completion timestamps are monotone in issue order (in-order engine),
    # so span order IS completion order; it matches issue order op-for-op
    for (tag, stream, t0, t1), op in zip(ex.last_spans, sched.ops):
        assert tag == op.tag and stream == op.stream
    ends = [t1 for _, _, _, t1 in ex.last_spans]
    assert all(b >= a - 1e-12 for a, b in zip(ends, ends[1:]))
    np.testing.assert_allclose(C, A.astype(np.float64) @ B,
                               rtol=1e-4, atol=1e-4)


@given(M=dims, N=dims, K=st.sampled_from([128, 256]),
       nstreams=st.sampled_from([1, 2, 3]),
       nbuf=st.sampled_from([1, 2, 3]))
@settings(max_examples=10, deadline=None)
def test_concurrent_completion_is_linear_extension(M, N, K, nstreams, nbuf):
    """mode="concurrent" may complete ops out of issue order, but the
    observed completion order must still be a linear extension of the
    dependency partial order — and the result stays bitwise equal to the
    serial oracle's."""
    rng = np.random.default_rng(M * 3 + N * 5 + K)
    full = (M * K + K * N + M * N) * 4
    part = plan_gemm_partition(M, N, K, max(full // 4, 700_000), 4)
    sched = build_gemm_schedule(part, nstreams=nstreams, nbuf=nbuf)
    validate_schedule(sched)

    A = rng.standard_normal((M, K)).astype(np.float32)
    B = rng.standard_normal((K, N)).astype(np.float32)
    C_ser = np.zeros((M, N), dtype=np.float32)
    ScheduleExecutor().run(sched, {"A": A, "B": B}, {"C": C_ser},
                           {"alpha": 1.0, "beta": 0.0})
    C_conc = np.zeros((M, N), dtype=np.float32)
    ex = ScheduleExecutor(mode="concurrent")
    ex.run(sched, {"A": A, "B": B}, {"C": C_conc},
           {"alpha": 1.0, "beta": 0.0})
    assert np.array_equal(C_ser, C_conc)
    order = ex.last_completion_order
    assert sorted(order) == list(range(len(sched.ops)))
    pos = {op_idx: k for k, op_idx in enumerate(order)}
    for pred, succ in _dependency_edges(sched):
        assert pos[pred] < pos[succ], (
            f"concurrent completion violated dependency "
            f"{sched.ops[pred].tag} -> {sched.ops[succ].tag}")


def test_factor_executor_conformance():
    """The multi-kernel factor schedule (panel ops + trailing stream +
    lookahead reordering) also completes as a linear extension of its
    dependency order, with spans for every op."""
    rng = np.random.default_rng(9)
    n = 320
    X = rng.standard_normal((n, n)).astype(np.float32)
    A = (X @ X.T + n * np.eye(n)).astype(np.float32)
    spec = factor_pipeline_spec(n, 96, 64 * n * n * 4, 4, kind="cholesky",
                                lookahead=1, bm=64, bn=128)
    sched = compile_factor_pipeline(spec, nstreams=2, nbuf=2)
    validate_schedule(sched)
    _assert_executor_is_linear_extension(sched)
    out = np.array(A)
    ex = ScheduleExecutor(record_spans=True)
    ex.run(sched, operands={}, outputs={"A": out},
           ctx={"alpha": -1.0, "beta": 1.0, "panel": 96, "n": n})
    assert len(ex.last_spans) == len(sched.ops)
    expect = np.linalg.cholesky(A.astype(np.float64))
    np.testing.assert_allclose(np.tril(out), expect, rtol=1e-4, atol=1e-4)


# ------------------------------------------------- lookahead properties
@pytest.mark.parametrize("kind", ["cholesky", "lu"])
def test_lookahead_never_slower_than_sequential(kind):
    """Same block geometry, same transfers: the lookahead event graph is a
    relaxation of the sequential one, so its simulated makespan cannot
    regress (small tolerance for greedy list-scheduling noise)."""
    hw = gpu_like()
    spec0 = factor_pipeline_spec(4096, 512, 512 * 2**20, 8, kind=kind,
                                 lookahead=0, bm=512, bn=1024)
    spec1 = factor_pipeline_spec(4096, 512, 512 * 2**20, 8, kind=kind,
                                 lookahead=1, bm=512, bn=1024)
    seq = simulate(compile_factor_pipeline(spec0), hw).makespan
    la = simulate(compile_factor_pipeline(spec1), hw).makespan
    assert la <= seq * 1.02, (la, seq)
