"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU).

Per the brief: sweep shapes/dtypes and assert_allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _tol(dt):
    return dict(rtol=2e-2, atol=2e-2) if dt == jnp.bfloat16 else \
        dict(rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("M,N,K", [
    (128, 128, 128),
    (256, 384, 512),
    (300, 200, 150),      # non-divisible: exercises padding
    (512, 128, 257),
    (64, 64, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_matmul_shapes_dtypes(rng, M, N, K, dtype):
    A = jnp.asarray(rng.standard_normal((M, K)), dtype)
    B = jnp.asarray(rng.standard_normal((K, N)), dtype)
    C = jnp.asarray(rng.standard_normal((M, N)), dtype)
    out = ops.block_matmul(A, B, C, alpha=1.25, beta=0.5,
                           block=(128, 128, 128), interpret=True)
    expect = ref.gemm_ref(A, B, C, 1.25, 0.5)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **_tol(dtype))


@pytest.mark.parametrize("block", [(128, 128, 128), (256, 128, 64),
                                   (64, 256, 128)])
def test_block_matmul_block_shapes(rng, block):
    A = jnp.asarray(rng.standard_normal((256, 320)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((320, 256)), jnp.float32)
    C = jnp.zeros((256, 256), jnp.float32)
    out = ops.block_matmul(A, B, C, alpha=1.0, beta=0.0, block=block,
                           interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(A @ B),
                               rtol=2e-4, atol=2e-4)


def test_block_matmul_beta_zero_ignores_c_nans(rng):
    """beta=0 must not propagate NaNs from uninitialized C (DGEMM contract).

    Note alpha*acc + beta*C with beta=0 still multiplies NaN*0 = NaN, so we
    check with finite C only; the API contract is C must be valid when
    beta != 0.  This test documents numerical behavior at beta=0.
    """
    A = jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)
    C = jnp.zeros((128, 128), jnp.float32)
    out = ops.block_matmul(A, B, C, alpha=2.0, beta=0.0, interpret=True,
                           block=(128, 128, 128))
    np.testing.assert_allclose(np.asarray(out), 2 * np.asarray(A @ B),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("B,H,hkv,d,S,block_s", [
    (1, 8, 2, 64, 512, 128),
    (2, 16, 16, 64, 1000, 256),   # MHA, non-divisible S
    (3, 8, 1, 128, 384, 128),     # MQA
    (2, 4, 4, 80, 300, 128),      # odd head_dim (hubert-like)
])
def test_flash_decode_attention(rng, B, H, hkv, d, S, block_s):
    q = jnp.asarray(rng.standard_normal((B, H, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, hkv, d)), jnp.float32)
    lengths = jnp.asarray(rng.integers(1, S + 1, (B,)), jnp.int32)
    out = ops.flash_decode_attention(q, k, v, lengths, block_s=block_s,
                                     interpret=True)
    expect = ref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-4)


def test_flash_decode_bf16(rng):
    B, H, hkv, d, S = 2, 8, 2, 64, 512
    q = jnp.asarray(rng.standard_normal((B, H, d)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((B, S, hkv, d)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((B, S, hkv, d)), jnp.bfloat16)
    lengths = jnp.full((B,), S, jnp.int32)
    out = ops.flash_decode_attention(q, k, v, lengths, block_s=128,
                                     interpret=True)
    expect = ref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_flash_decode_fully_masked_block(rng):
    """Blocks entirely beyond `length` must contribute exactly nothing."""
    B, H, hkv, d, S = 1, 4, 4, 64, 1024
    q = jnp.asarray(rng.standard_normal((B, H, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, hkv, d)), jnp.float32)
    short = jnp.asarray([100], jnp.int32)
    out = ops.flash_decode_attention(q, k, v, short, block_s=128,
                                     interpret=True)
    # identical to attention over the truncated cache
    expect = ref.decode_attention_ref(q, k[:, :100], v[:, :100],
                                      jnp.asarray([100], jnp.int32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-4)
