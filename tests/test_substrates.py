"""Substrate tests: optimizer, compression, data pipeline, checkpointing."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis_shim import given, settings, st

from repro.checkpoint import CheckpointManager
from repro.data import MemmapSource, Prefetcher, SyntheticSource
from repro.optim import AdamWConfig, adamw, compression

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------------ optimizer
def _toy_params(rng):
    return {"w": jnp.asarray(rng.standard_normal((8, 4)), jnp.float32),
            "b": jnp.asarray(rng.standard_normal((4,)), jnp.float32)}


def test_adamw_decreases_quadratic(rng):
    params = _toy_params(rng)
    target = jax.tree.map(lambda p: jnp.ones_like(p), params)
    cfg = AdamWConfig(lr=5e-2, weight_decay=0.0, warmup_steps=1,
                      total_steps=200)
    state = adamw.init(params)

    def loss(p):
        return sum(jnp.sum((a - b) ** 2)
                   for a, b in zip(jax.tree.leaves(p),
                                   jax.tree.leaves(target)))

    l0 = float(loss(params))
    for _ in range(100):
        grads = jax.grad(loss)(params)
        params, state, _ = adamw.update(grads, state, params, cfg)
    assert float(loss(params)) < 0.2 * l0


def test_adamw_no_master_close_to_master(rng):
    params = _toy_params(rng)
    cfgm = AdamWConfig(lr=1e-2, use_master=True)
    cfgn = AdamWConfig(lr=1e-2, use_master=False)
    sm = adamw.init(params, use_master=True)
    sn = adamw.init(params, use_master=False)
    g = jax.tree.map(lambda p: 0.1 * jnp.ones_like(p), params)
    pm, sm, _ = adamw.update(g, sm, params, cfgm)
    pn, sn, _ = adamw.update(g, sn, params, cfgn)
    for a, b in zip(jax.tree.leaves(pm), jax.tree.leaves(pn)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_grad_clipping_bounds_update(rng):
    params = _toy_params(rng)
    cfg = AdamWConfig(lr=1e-3, clip_norm=1.0)
    state = adamw.init(params)
    big = jax.tree.map(lambda p: 1e6 * jnp.ones_like(p), params)
    _, _, metrics = adamw.update(big, state, params, cfg)
    assert float(metrics["grad_norm"]) > 1e5  # pre-clip norm reported


# ---------------------------------------------------------------- compression
@given(scale=st.floats(min_value=1e-6, max_value=1e4),
       n=st.integers(min_value=1, max_value=500))
@settings(max_examples=50, deadline=None)
def test_quantize_roundtrip_error_bounded(scale, n):
    rng = np.random.default_rng(42)
    g = jnp.asarray(rng.standard_normal(n) * scale, jnp.float32)
    q, s = compression.quantize(g)
    err = np.abs(np.asarray(compression.dequantize(q, s) - g))
    assert err.max() <= float(s) / 2 + 1e-12  # half-ULP of the int8 grid


def test_error_feedback_unbiased_over_time(rng):
    """With EF, the *accumulated* applied gradient converges to the
    accumulated true gradient (residual stays bounded)."""
    g = jnp.asarray(rng.standard_normal((64,)), jnp.float32)
    err = jnp.zeros_like(g)
    total_applied = jnp.zeros_like(g)
    for t in range(50):
        comp, err_tree = compression.ef_compress({"g": g}, {"g": err})
        err = err_tree["g"]
        q, s = comp["g"]
        total_applied = total_applied + compression.dequantize(q, s)
    np.testing.assert_allclose(np.asarray(total_applied / 50), np.asarray(g),
                               rtol=0.05,
                               atol=float(jnp.max(jnp.abs(g))) / 50)


def test_shared_scale_int8_sum_exact(rng):
    """The compressed_pod_psum math: with a shared scale, the int16 sum of
    int8 payloads dequantizes to the exact sum of the quantized values."""
    gs = [jnp.asarray(rng.standard_normal((32,)), jnp.float32)
          for _ in range(4)]
    s = max(float(jnp.max(jnp.abs(g))) for g in gs) / 127.0 + 1e-12
    qs = [jnp.clip(jnp.round(g / s), -127, 127).astype(jnp.int8) for g in gs]
    qsum = sum(q.astype(jnp.int16) for q in qs)
    deq = np.asarray(qsum, np.float32) * s
    direct = sum(np.asarray(q, np.float32) * s for q in qs)
    np.testing.assert_allclose(deq, direct, rtol=1e-5, atol=1e-6)


# ------------------------------------------------------------------ data
def test_synthetic_deterministic_and_seekable():
    src = SyntheticSource(vocab_size=1000, seed=3)
    a = src.batch_at(7, 8, 16)
    b = src.batch_at(7, 8, 16)
    np.testing.assert_array_equal(a["inputs"], b["inputs"])
    c = src.batch_at(8, 8, 16)
    assert not np.array_equal(a["inputs"], c["inputs"])
    # labels are next-token shifted
    full_a = src.batch_at(7, 8, 16)
    np.testing.assert_array_equal(a["labels"][:, :-1], full_a["inputs"][:, 1:])
    assert a["inputs"].max() < 1000


def test_synthetic_host_sharding_partitions_batch():
    src = SyntheticSource(vocab_size=500, seed=0)
    full = src.batch_at(3, 8, 4, host_index=0, host_count=1)
    h0 = src.batch_at(3, 8, 4, host_index=0, host_count=2)
    h1 = src.batch_at(3, 8, 4, host_index=1, host_count=2)
    np.testing.assert_array_equal(
        np.concatenate([h0["inputs"], h1["inputs"]]), full["inputs"])


def test_memmap_source(tmp_path):
    path = str(tmp_path / "tokens.bin")
    toks = np.arange(10000, dtype=np.int32)
    toks.tofile(path)
    src = MemmapSource(path, vocab_size=1 << 30)
    b = src.batch_at(0, 4, 16)
    assert b["inputs"].shape == (4, 16)
    np.testing.assert_array_equal(b["labels"], b["inputs"] + 1)


def test_prefetcher_orders_steps():
    src = SyntheticSource(vocab_size=100, seed=1)
    pf = Prefetcher(src, batch=4, seq=8, start_step=5, depth=2)
    for expect in (5, 6, 7):
        step, batch = next(pf)
        assert step == expect
        ref_b = src.batch_at(step, 4, 8)
        np.testing.assert_array_equal(batch["inputs"], ref_b["inputs"])
    pf.close()


# ------------------------------------------------------------------ checkpoint
def test_checkpoint_roundtrip_and_gc(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"params": {"w": jnp.asarray(rng.standard_normal((4, 4)),
                                         jnp.float32)},
             "step": jnp.asarray(3)}
    for step in (1, 2, 3):
        mgr.save(step, state, data_cursor=step * 10, blocking=True)
    assert mgr.all_steps() == [2, 3]  # keep=2 garbage-collects step 1
    target = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)
    restored, cursor = mgr.restore(3, target)
    assert cursor == 30
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))


def test_checkpoint_atomic_no_partial(tmp_path, rng):
    """tmp dirs never count as checkpoints."""
    mgr = CheckpointManager(str(tmp_path))
    os.makedirs(os.path.join(str(tmp_path), "tmp.99.0"))
    assert mgr.latest_step() is None
    state = {"w": jnp.ones((2,), jnp.float32)}
    mgr.save(5, state, blocking=True)
    assert mgr.latest_step() == 5


def test_checkpoint_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.ones((2, 2), jnp.float32)}, blocking=True)
    bad = {"w": jax.ShapeDtypeStruct((3, 3), jnp.float32)}
    with pytest.raises(ValueError, match="shape"):
        mgr.restore(1, bad)


def test_train_restart_resumes_identically(tmp_path):
    """Kill-and-restart determinism: a run checkpointed at step 10 and
    resumed to 20 produces the same losses as an uninterrupted 20-step
    run (fault-tolerance contract)."""
    from repro.launch.train import main as train_main

    ck1 = str(tmp_path / "a")
    full = train_main(["--arch", "stablelm-1.6b", "--smoke",
                       "--steps", "14", "--batch", "2", "--seq", "32",
                       "--log-every", "100"])
    # interrupted run: first 7 steps, checkpoint, then resume
    part1 = train_main(["--arch", "stablelm-1.6b", "--smoke",
                        "--steps", "7", "--total-steps", "14",
                        "--batch", "2", "--seq", "32",
                        "--ckpt-dir", ck1, "--ckpt-every", "7",
                        "--log-every", "100"])
    part2 = train_main(["--arch", "stablelm-1.6b", "--smoke",
                        "--steps", "14", "--batch", "2", "--seq", "32",
                        "--ckpt-dir", ck1, "--resume", "auto",
                        "--log-every", "100"])
    combined = part1["losses"] + part2["losses"]
    np.testing.assert_allclose(combined, full["losses"], rtol=1e-4)
