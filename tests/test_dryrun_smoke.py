"""Small-mesh dry-run smoke: the production lowering path on 8 fake devices.

Runs in a subprocess because XLA locks the host device count at first init
(the main pytest process must keep seeing 1 CPU device).
"""

import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import make_mesh
from repro.configs import get_arch, SHAPES
from repro.distributed import make_weight_gather, tree_shardings
from repro.models import get_model
from repro.optim import AdamWConfig
from repro.training import steps as tsteps

mesh = make_mesh((2, 4), ("data", "model"))
results = {}
for arch in ["llama3.2-3b", "deepseek-moe-16b", "rwkv6-1.6b", "zamba2-1.2b"]:
    cfg = get_arch(arch).smoke().replace(num_heads=4, num_kv_heads=4)
    model = get_model(cfg, weight_gather=make_weight_gather(mesh))
    opt = AdamWConfig()
    state_sds = jax.eval_shape(
        lambda: tsteps.init_train_state(model, jax.random.PRNGKey(0), opt))
    axes = tsteps.train_state_logical_axes(model, True)
    ss = tree_shardings(axes, state_sds, mesh)
    B, S = 8, 32
    batch = {"inputs": jax.ShapeDtypeStruct((B, S), jnp.int32),
             "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    bs = jax.tree.map(lambda s: NamedSharding(
        mesh, P("data", *([None] * (len(s.shape) - 1)))), batch)
    fn = jax.jit(tsteps.build_train_step(model, opt),
                 in_shardings=(ss, bs), out_shardings=(ss, None),
                 donate_argnums=(0,))
    compiled = fn.lower(state_sds, batch).compile()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # pre-0.5 jax returns [dict]
        cost = cost[0] if cost else {}
    results[arch] = {"flops": float(cost.get("flops", 0)),
                     "compiled": True}

    # decode path on the mesh too (zamba2/rwkv6 carry SSM state)
    cache_sds = model.cache_specs(B, 64)
    cs = tree_shardings(model.cache_logical_axes(), cache_sds, mesh)
    psds = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    ps = tree_shardings(model.param_logical_axes(), psds, mesh)
    dec = jax.jit(tsteps.build_decode_step(model),
                  in_shardings=(ps, cs, NamedSharding(mesh, P("data"))),
                  out_shardings=(None, cs), donate_argnums=(1,))
    dec.lower(psds, cache_sds,
              jax.ShapeDtypeStruct((B,), jnp.int32)).compile()
    results[arch]["decode_compiled"] = True

print(json.dumps(results))
"""


@pytest.mark.slow
def test_dryrun_on_8_fake_devices():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    results = json.loads(out.stdout.strip().splitlines()[-1])
    for arch, r in results.items():
        assert r["compiled"], arch
        assert r["decode_compiled"], arch
        assert r["flops"] > 0, arch
