"""Reuse-aware scheduling: traversal orders, the block cache, and the
eviction event wiring (ISSUE 6).

Three layers of guarantees:

  * *order*: every traversal is a permutation of the block grid, and "col"
    reproduces the paper's column-major sequence exactly;
  * *correctness*: every traversal x eviction-policy schedule validates and
    executes bitwise-identically to the naive (``reuse=False``) schedule —
    for GEMM, SYRK, Cholesky and LU;
  * *accounting*: executor-counted H2D bytes, ``simulate()`` bytes and
    ``schedule_stats()`` bytes agree exactly, and the cache counters on
    ``Schedule.reuse`` reconcile with them.

Plus the satellite regressions: the ``nstreams=1, nbuf=1`` single-consumer
eviction wiring pinned op by op, and ``validate_schedule`` error paths
naming the offending op tag and buffer key.
"""

import numpy as np
import pytest

from repro.core import (EVICT_POLICIES, TRAVERSALS, GemmPartition,
                        ScheduleExecutor, compile_factor_pipeline,
                        compile_pipeline, factor_pipeline_spec,
                        gemm_pipeline_spec, ooc_cholesky, ooc_lu,
                        schedule_stats, simulate, syrk_pipeline_spec,
                        traversal_order, validate_schedule)
from repro.core.simulator import gpu_like
from repro.core.streams import (Device, Event, Op, OpKind, Schedule,
                                ScheduleError, StreamFactory)

COMBOS = [(t, e) for t in TRAVERSALS for e in EVICT_POLICIES]


def _part(M, N, K, bm, bn, bpe=4, budget=1 << 22):
    return GemmPartition(M, N, K, -(-M // bm), -(-N // bn), bm, bn,
                         bpe, budget)


# ===========================================================================
# Traversal orders
# ===========================================================================
@pytest.mark.parametrize("traversal", TRAVERSALS)
@pytest.mark.parametrize("h,w", [(1, 1), (2, 3), (4, 4), (3, 5)])
def test_traversal_is_a_permutation(traversal, h, w):
    order = traversal_order(h, w, traversal, band=2)
    assert len(order) == h * w
    assert set(order) == {(i, j) for i in range(h) for j in range(w)}


def test_col_traversal_matches_paper_order():
    # the seed compiler's column-major sequence: j outer, i inner
    assert traversal_order(3, 2, "col") == [(0, 0), (1, 0), (2, 0),
                                            (0, 1), (1, 1), (2, 1)]


def test_unknown_traversal_names_the_valid_set():
    with pytest.raises(ValueError, match="col"):
        traversal_order(2, 2, "diagonal")


# ===========================================================================
# Every traversal x evict combination validates and is bitwise-identical
# ===========================================================================
@pytest.mark.parametrize("traversal,evict", COMBOS)
@pytest.mark.parametrize("nstreams,nbuf", [(1, 1), (2, 3)])
def test_gemm_schedules_validate(traversal, evict, nstreams, nbuf):
    part = _part(192, 192, 128, 64, 64)
    sched = compile_pipeline(
        gemm_pipeline_spec(part, traversal=traversal, band=nbuf),
        nstreams=nstreams, nbuf=nbuf, evict=evict)
    validate_schedule(sched)
    assert sched.meta["traversal"] == traversal
    assert sched.meta["evict"] == evict
    assert sched.meta["kernel"] == "gemm"   # obs label (DESIGN.md §10)


@pytest.mark.parametrize("traversal,evict", COMBOS)
def test_gemm_bitwise_identical_to_naive(traversal, evict):
    part = _part(192, 192, 128, 64, 64)
    rng = np.random.default_rng(1)
    A = rng.standard_normal((192, 128)).astype(np.float32)
    B = rng.standard_normal((128, 192)).astype(np.float32)
    ctx = {"alpha": 1.0, "beta": 0.0}

    ref = np.zeros((192, 192), np.float32)
    ScheduleExecutor().run(
        compile_pipeline(gemm_pipeline_spec(part, reuse=False),
                         nstreams=2, nbuf=2),
        operands={"A": A, "B": B}, outputs={"C": ref}, ctx=ctx)

    out = np.zeros((192, 192), np.float32)
    ScheduleExecutor().run(
        compile_pipeline(gemm_pipeline_spec(part, traversal=traversal,
                                            band=3),
                         nstreams=2, nbuf=3, evict=evict),
        operands={"A": A, "B": B}, outputs={"C": out}, ctx=ctx)
    assert np.array_equal(out, ref)


@pytest.mark.parametrize("traversal,evict", COMBOS)
def test_syrk_bitwise_identical_to_naive(traversal, evict):
    part = _part(192, 192, 96, 64, 64)
    rng = np.random.default_rng(2)
    P = rng.standard_normal((192, 96)).astype(np.float32)
    ctx = {"alpha": -1.0, "beta": 1.0}
    C0 = rng.standard_normal((192, 192)).astype(np.float32)

    ref = np.array(C0)
    ScheduleExecutor().run(
        compile_pipeline(syrk_pipeline_spec(part, reuse=False),
                         nstreams=2, nbuf=2),
        operands={"P": P}, outputs={"C": ref}, ctx=ctx)

    out = np.array(C0)
    ScheduleExecutor().run(
        compile_pipeline(syrk_pipeline_spec(part, traversal=traversal,
                                            band=3),
                         nstreams=2, nbuf=3, evict=evict),
        operands={"P": P}, outputs={"C": out}, ctx=ctx)
    assert np.array_equal(out, ref)


@pytest.mark.parametrize("evict", EVICT_POLICIES)
def test_cholesky_bitwise_identical_across_evict(evict):
    rng = np.random.default_rng(3)
    X = rng.standard_normal((384, 384)).astype(np.float64)
    A = X @ X.T + 384 * np.eye(384)
    kw = dict(panel=128, budget_bytes=1 << 20, lookahead=1, validate=True)
    ref = ooc_cholesky(A, **kw)                      # default lru
    out = ooc_cholesky(A, evict=evict, **kw)
    assert np.array_equal(out, ref)


@pytest.mark.parametrize("evict", EVICT_POLICIES)
def test_lu_bitwise_identical_across_evict(evict):
    rng = np.random.default_rng(4)
    A = rng.standard_normal((384, 384)).astype(np.float64) \
        + 384 * np.eye(384)
    kw = dict(panel=128, budget_bytes=1 << 20, lookahead=1, validate=True)
    ref_lu, ref_perm = ooc_lu(A, **kw)
    out_lu, out_perm = ooc_lu(A, evict=evict, **kw)
    assert np.array_equal(out_lu, ref_lu)
    assert np.array_equal(out_perm, ref_perm)


# ===========================================================================
# Byte accounting: executor == simulate == stats, counters reconcile
# ===========================================================================
@pytest.mark.parametrize("traversal,evict", COMBOS)
def test_h2d_byte_counters_agree(traversal, evict):
    part = _part(192, 192, 128, 64, 64)
    sched = compile_pipeline(
        gemm_pipeline_spec(part, traversal=traversal, band=3),
        nstreams=2, nbuf=3, evict=evict)
    rng = np.random.default_rng(5)
    A = rng.standard_normal((192, 128)).astype(np.float32)
    B = rng.standard_normal((128, 192)).astype(np.float32)
    out = np.zeros((192, 192), np.float32)
    ex = ScheduleExecutor()
    ex.run(sched, operands={"A": A, "B": B}, outputs={"C": out},
           ctx={"alpha": 1.0, "beta": 0.0})
    res = simulate(sched, gpu_like())
    stats = schedule_stats(sched)
    assert ex.last_h2d_bytes == res.h2d_bytes == stats["h2d_bytes"]
    assert ex.last_d2h_bytes == res.d2h_bytes == stats["d2h_bytes"]
    # per-operand splits and cache counters reconcile with the totals
    assert sum(res.h2d_by_operand.values()) == res.h2d_bytes
    assert sum(r["bytes_moved"] for r in res.reuse.values()) == res.h2d_bytes
    assert 0.0 <= res.hit_rate <= 1.0
    assert stats["reuse_hits"] == sum(r["hits"] for r in res.reuse.values())
    assert stats["h2d_saved_bytes"] == sum(
        r["bytes_saved"] for r in res.reuse.values())


def test_reuse_never_moves_more_bytes_than_naive():
    part = _part(512, 512, 256, 128, 128)
    naive = schedule_stats(compile_pipeline(
        gemm_pipeline_spec(part, reuse=False), nstreams=2, nbuf=3))
    for traversal, evict in COMBOS:
        cached = schedule_stats(compile_pipeline(
            gemm_pipeline_spec(part, traversal=traversal, band=3),
            nstreams=2, nbuf=3, evict=evict))
        assert cached["h2d_bytes"] <= naive["h2d_bytes"]
    # and at least one traversal strictly reduces traffic on a 4x4 grid
    blocked = schedule_stats(compile_pipeline(
        gemm_pipeline_spec(part, traversal="blocked", band=3),
        nstreams=2, nbuf=3))
    assert blocked["h2d_bytes"] < naive["h2d_bytes"]
    assert blocked["reuse_hits"] > 0


def test_factor_fr_cache_hits_and_belady_not_worse():
    moved = {}
    for evict in EVICT_POLICIES:
        spec = factor_pipeline_spec(768, 128, 1 << 20, 4, kind="cholesky",
                                    lookahead=1)
        sched = compile_factor_pipeline(spec, nstreams=2, nbuf=2,
                                        evict=evict)
        validate_schedule(sched)
        assert sched.reuse["Fr"]["hits"] > 0
        moved[evict] = sched.reuse["Fr"]["bytes_moved"]
    # on a static schedule the MIN oracle never misses more than LRU
    assert moved["belady"] <= moved["lru"]


# ===========================================================================
# Satellite 1: nstreams=1, nbuf=1 single-consumer eviction wiring, pinned
# ===========================================================================
def test_release_waits_single_stream_single_buffer():
    part = _part(128, 128, 64, 64, 64)        # 2x2 block grid
    sched = compile_pipeline(gemm_pipeline_spec(part), nstreams=1, nbuf=1)
    validate_schedule(sched)
    ops = {}
    for op in sched.ops:
        ops.setdefault(op.tag, []).append(op)

    def waits(tag, k=0):
        return tuple(ev.name for ev in ops[tag][k].waits)

    # col order: steps (0,0)(1,0)(0,1)(1,1); A ids 0,1,0,1; C ids 0,1,2,3.
    # With one A buffer, fetching A row 1 evicts row 0 — the eviction must
    # wait on row 0's single consumer, DGEMM step 0, and nothing else.
    assert waits("S(a[1])") == ("eA[0]",)
    # C is inout: replacing C block 0 must wait for its *write-back*.
    assert waits("S(c[1])") == ("wC[0]",)
    # B has its 2-deep ping-pong: both columns fit, so neither B transfer
    # carries eviction waits.
    assert waits("S(b[0])") == ()
    assert waits("S(b[1])") == ()
    # A row 0 returns at step 2: a fresh transfer (the cache was forced to
    # evict it) under a distinct incarnation tag/event, waiting on step 1.
    assert ops["S(a[0])"][0].records.name == "rA[0]"
    assert ops["S(a[0])@1"][0].records.name == "rA[0]@1"
    assert waits("S(a[0])@1") == ("eA[1]",)
    # B columns stay resident: exactly one transfer each, 2 cache hits
    assert sched.reuse["B"] == {
        "hits": 2, "misses": 2,
        "bytes_moved": 2 * 64 * 64 * 4, "bytes_saved": 2 * 64 * 64 * 4}


def test_nbuf1_gemm_executes_correctly():
    part = _part(128, 128, 64, 64, 64)
    sched = compile_pipeline(gemm_pipeline_spec(part), nstreams=1, nbuf=1)
    rng = np.random.default_rng(6)
    A = rng.standard_normal((128, 64)).astype(np.float32)
    B = rng.standard_normal((64, 128)).astype(np.float32)
    out = np.zeros((128, 128), np.float32)
    ScheduleExecutor().run(sched, operands={"A": A, "B": B},
                           outputs={"C": out},
                           ctx={"alpha": 1.0, "beta": 0.0})
    np.testing.assert_allclose(out, A @ B, rtol=1e-4, atol=1e-4)


# ===========================================================================
# Satellite 3: validate_schedule error paths name op tag + buffer key
# ===========================================================================
def _two_stream_schedule():
    dev = Device("HBM", 0, 1 << 20)
    return Schedule(dev, StreamFactory.create(dev, 2))


def test_overlap_error_names_both_ops_and_the_buffer():
    sched = _two_stream_schedule()
    sched.issue(Op(kind=OpKind.H2D, tag="S(a[0])", stream=0,
                   records=Event("rA[0]"), buffers_written=(("A", 0),),
                   bytes=4))
    # second transfer overwrites the same device buffer from the other
    # stream with no ordering edge — the classic double-buffering bug
    sched.issue(Op(kind=OpKind.H2D, tag="S(a[1])", stream=1,
                   records=Event("rA[1]"), buffers_written=(("A", 0),),
                   bytes=4))
    with pytest.raises(ScheduleError) as ei:
        validate_schedule(sched)
    msg = str(ei.value)
    assert "S(a[0])" in msg and "S(a[1])" in msg
    assert "('A', 0)" in msg


def test_unordered_read_write_error_names_both_ops_and_the_buffer():
    sched = _two_stream_schedule()
    sched.issue(Op(kind=OpKind.H2D, tag="S(a[0])", stream=0,
                   records=Event("rA[0]"), buffers_written=(("A", 0),),
                   bytes=4))
    sched.issue(Op(kind=OpKind.COMPUTE, tag="DGEMM[0]", stream=0,
                   waits=(Event("rA[0]"),), records=Event("eA[0]"),
                   buffers_read=(("A", 0),), flops=1))
    # refill from stream 1 without waiting on the reader
    sched.issue(Op(kind=OpKind.H2D, tag="S(a[1])", stream=1,
                   waits=(Event("rA[0]"),), records=Event("rA[1]"),
                   buffers_written=(("A", 0),), bytes=4))
    with pytest.raises(ScheduleError) as ei:
        validate_schedule(sched)
    msg = str(ei.value)
    assert "DGEMM[0]" in msg and "S(a[1])" in msg
    assert "('A', 0)" in msg


def test_use_before_transfer_error_names_op_and_buffer():
    sched = _two_stream_schedule()
    sched.issue(Op(kind=OpKind.COMPUTE, tag="DGEMM[0]", stream=0,
                   records=Event("eA[0]"), buffers_read=(("A", 0),),
                   flops=1))
    with pytest.raises(ScheduleError) as ei:
        validate_schedule(sched)
    msg = str(ei.value)
    assert "DGEMM[0]" in msg
    assert "('A', 0)" in msg
    assert "use-before-transfer" in msg


# ===========================================================================
# Tuner integration: traversal/evict searched and recorded
# ===========================================================================
def test_search_records_traversal_and_evict():
    from repro.tune import gpu_profile
    from repro.tune.search import TunedPlan, search_gemm

    plan = search_gemm(256, 256, 256, 1 << 20, gpu_profile(),
                       fingerprint="t", max_steps=256)
    assert plan.traversal in TRAVERSALS
    assert plan.evict in EVICT_POLICIES
    back = TunedPlan.from_json(plan.to_json())
    assert back == plan
