"""Determinism and plan-cache race tests (ISSUE 4).

``tune.search`` must be a pure function of its inputs — identical plans
across repeat runs and after a JSON cache round-trip — and the plan cache
must survive concurrent writers on the same key: the atomic temp-file +
``os.replace`` protocol may lose a racing update but never corrupts the
store or serves a torn plan.
"""

import json
import os
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.tune import (AutoTuner, PlanCache, TunedPlan, gpu_profile,
                        search_factor, search_gemm)
from repro.tune.cache import SCHEMA_VERSION


def test_search_gemm_repeat_runs_identical():
    args = (2048, 2048, 1024, 8_000_000, gpu_profile())
    plans = [search_gemm(*args, fingerprint="det") for _ in range(3)]
    assert plans[0] == plans[1] == plans[2]


def test_search_factor_repeat_runs_identical():
    args = ("cholesky", 2048, 256, 64 * 2**20, gpu_profile())
    a = search_factor(*args, fingerprint="det")
    b = search_factor(*args, fingerprint="det")
    assert a == b
    assert a.kernel == "cholesky-factor"
    assert a.param("lookahead") in (0, 1, 2)


def test_search_factor_baseline_finite_under_restricted_options():
    """baseline_makespan stays finite (and JSON-portable) even when the
    hardcoded (ns=2, nb=2, la=0) default is outside the option sets —
    regression: it once came back float('inf')."""
    for kw in ({"nstreams_options": (1,)}, {"lookahead_options": (1, 2)}):
        plan = search_factor("cholesky", 1024, 128, 32 * 2**20,
                             gpu_profile(), fingerprint="b", **kw)
        assert np.isfinite(plan.baseline_makespan)
        assert plan.makespan <= plan.baseline_makespan + 1e-12
        assert TunedPlan.from_json(json.loads(
            json.dumps(plan.to_json()))) == plan


def test_plan_survives_cache_round_trip(tmp_path):
    """put -> fresh instance -> get returns an equal TunedPlan for both the
    GEMM and the factor plan shapes (inf baselines included)."""
    path = str(tmp_path / "plans.json")
    gemm = search_gemm(1024, 1024, 512, 2_000_000, gpu_profile(),
                       fingerprint="rt")
    factor = search_factor("lu", 1024, 128, 32 * 2**20, gpu_profile(),
                           fingerprint="rt")
    cache = PlanCache(path)
    cache.put("k1", gemm)
    cache.put("k2", factor)
    fresh = PlanCache(path)
    assert fresh.get("k1") == gemm
    assert fresh.get("k2") == factor
    assert fresh.hits == 2 and fresh.misses == 0


def test_tuner_plan_identical_after_cache_round_trip(tmp_path):
    """The full tune="auto" path: a plan served from cache equals the plan
    the search produced."""
    t1 = AutoTuner(profile=gpu_profile(), fingerprint="same",
                   cache=PlanCache(str(tmp_path / "a.json")), max_steps=256)
    p1 = t1.factor_plan("cholesky", 1024, 128, 32 * 2**20)
    t2 = AutoTuner(profile=gpu_profile(), fingerprint="same",
                   cache=PlanCache(str(tmp_path / "a.json")), max_steps=256)
    p2 = t2.factor_plan("cholesky", 1024, 128, 32 * 2**20)
    assert p1 == p2
    assert t2.searches == 0 and t2.last_from_cache


def _any_valid_plan(path, key):
    with open(path) as f:
        data = json.load(f)           # parseable — never torn
    assert data["schema"] == SCHEMA_VERSION
    plans = data["plans"]
    assert key in plans
    plan = TunedPlan.from_json(plans[key])
    assert plan.kernel == "gemm"
    return plan


def test_cache_survives_racing_writers_same_instance(tmp_path):
    """Two threads hammering ONE PlanCache on the same key: every write
    completes, the file stays valid JSON, and the surviving value is one of
    the written plans."""
    path = str(tmp_path / "race.json")
    cache = PlanCache(path)
    plans = [search_gemm(1024, 1024, 512, 2_000_000, gpu_profile(),
                         fingerprint=f"w{i}") for i in range(2)]
    with ThreadPoolExecutor(max_workers=2) as pool:
        futs = [pool.submit(lambda p=p: [cache.put("hot", p)
                                         for _ in range(25)])
                for p in plans]
        for f in futs:
            f.result()                # raises if a writer crashed
    got = _any_valid_plan(path, "hot")
    assert got in plans


def test_cache_survives_racing_writer_instances(tmp_path):
    """Two PlanCache instances (two "processes") racing on the same store
    path: os.replace keeps the file atomic — a racing update may lose, the
    store never corrupts."""
    path = str(tmp_path / "race2.json")
    plans = [search_gemm(1024, 1024, 512, 2_000_000, gpu_profile(),
                         fingerprint=f"i{i}") for i in range(2)]

    def writer(i):
        c = PlanCache(path)
        for _ in range(25):
            c.put("hot", plans[i])
            c._mem = None             # drop the memo: re-read like a fresh
        return True                   # process would

    with ThreadPoolExecutor(max_workers=2) as pool:
        assert all(f.result() for f in
                   [pool.submit(writer, i) for i in range(2)])
    got = _any_valid_plan(path, "hot")
    assert got in plans
    # and a reader through the public API sees a usable plan
    assert PlanCache(path).get("hot") in plans


def test_racing_distinct_keys_do_not_corrupt(tmp_path):
    """Writers on distinct keys through one instance: both keys land (the
    in-instance lock serializes load-modify-store)."""
    path = str(tmp_path / "race3.json")
    cache = PlanCache(path)
    plan = search_gemm(512, 512, 256, 1_000_000, gpu_profile(),
                       fingerprint="x")
    with ThreadPoolExecutor(max_workers=4) as pool:
        futs = [pool.submit(cache.put, f"key{i}", plan) for i in range(8)]
        for f in futs:
            f.result()
    with open(path) as f:
        data = json.load(f)
    assert set(data["plans"]) == {f"key{i}" for i in range(8)}
    assert len(cache) == 8
