"""Bottleneck attribution + what-if modeling (DESIGN.md §11, ISSUE 8).

The acceptance bar:

  * **Exact reconciliation** — on simulate() output, the critical path
    tiles ``[0, makespan]`` with float-equal abutment, its segment
    durations sum to the makespan, and the attributed byte/flop totals
    equal both ``SimResult`` and ``schedule_stats`` accounting — across
    GEMM, SYRK, Cholesky-with-lookahead and a hybrid gpu+phi pair.
  * **Verdicts are explanations** — a 1-stream phi-like run is
    transfer-bound; a compute-heavy gpu run is compute-bound; eviction
    stalls appear on the path exactly when buffers are scarce.
  * **What-if agrees with the tuner** (claim C5) — at the paper's 8192^3
    fp64 regime from a 1-stream baseline, "+1 stream" is the gpu's best
    marginal resource (beats bandwidth x1.25) while on the phi-like
    profile "+1 stream" *loses* time and bandwidth wins among the
    stream/buffer/bandwidth knobs.
"""

import numpy as np
import pytest

from repro.core import HostOocRuntime, ScheduleExecutor
from repro.core.partitioner import plan_gemm_partition
from repro.core.pipeline import (compile_factor_pipeline, compile_pipeline,
                                 factor_pipeline_spec, gemm_pipeline_spec,
                                 schedule_stats, syrk_pipeline_spec)
from repro.core.simulator import simulate
from repro.hybrid import DeviceSpec
from repro.hybrid.executor import analyze_hybrid, simulate_hybrid
from repro.hybrid.plan import plan_hybrid_gemm
from repro.obs import get_observability
from repro.obs.analyze import TraceAnalysis, analyze_plan
from repro.obs.whatif import whatif_gemm
from repro.tune import gpu_profile, phi_profile


@pytest.fixture(autouse=True)
def _clean_obs():
    obs = get_observability()
    obs.reset()
    obs.disable()
    yield obs
    obs.reset()
    obs.disable()


def _gemm_sched(m=1024, bpe=4, ns=2, nb=2, budget=None, kernel="gemm"):
    budget = budget if budget is not None else (3 * m * m * bpe) // 2
    part = plan_gemm_partition(m, m, m, budget, bpe, nbuf=nb, nstreams=ns)
    if kernel == "gemm":
        spec = gemm_pipeline_spec(part, band=nb)
    else:
        spec = syrk_pipeline_spec(part, band=nb)
    return compile_pipeline(spec, nstreams=ns, nbuf=nb)


# ---------------------------------------------------------------- exactness
@pytest.mark.parametrize("profile,ns", [(gpu_profile, 2), (phi_profile, 1)])
def test_reconciliation_exact_gemm(profile, ns):
    sched = _gemm_sched(ns=ns)
    hw = profile().model_for(ns)
    ana, res = TraceAnalysis.analyze(sched, hw)
    out = ana.verify_reconciliation(res, stats=schedule_stats(sched))
    assert out["critical_path_seconds"] == pytest.approx(res.makespan)
    assert ana.exact and ana.source == "sim"
    # the path is in time order and every segment has a known class
    assert all(seg.cls in ("h2d", "d2h", "compute", "merge",
                           "eviction-stall") for seg in ana.path)


def test_reconciliation_exact_syrk():
    sched = _gemm_sched(kernel="syrk")
    ana, res = TraceAnalysis.analyze(sched, gpu_profile().model_for(2))
    ana.verify_reconciliation(res, stats=schedule_stats(sched))


def test_reconciliation_exact_cholesky_lookahead():
    n, panel = 2048, 256
    budget = (3 * panel * n * 4) * 2
    spec = factor_pipeline_spec(n, panel, budget, 4,
                                kind="cholesky", lookahead=1, nbuf=2)
    sched = compile_factor_pipeline(spec, nstreams=2, nbuf=2)
    ana, res = TraceAnalysis.analyze(sched, gpu_profile().model_for(2))
    ana.verify_reconciliation(res, stats=schedule_stats(sched))


def test_reconciliation_exact_hybrid_pair():
    m = 1024
    budget = (3 * m * m * 4) // 2
    devs = [DeviceSpec("gpu0", gpu_profile(), budget),
            DeviceSpec("phi0", phi_profile(), budget)]
    hplan = plan_hybrid_gemm(m, m, m, devs, dtype="float32")
    sim = simulate_hybrid(hplan)
    ha = analyze_hybrid(hplan, sim)
    assert ha.makespan == sim.makespan
    assert ha.critical_device in ("gpu0", "phi0")
    assert 0.0 <= ha.imbalance < 1.0
    for name, ana in ha.per_device:
        res = dict(sim.per_device)[name]
        ana.verify_reconciliation(res)
    # the slowest device's analysis spans the aggregate makespan
    assert ha.device(ha.critical_device).makespan == sim.makespan


# ----------------------------------------------------------------- verdicts
def test_verdict_transfer_bound_phi_one_stream():
    m = 256
    sched = _gemm_sched(m=m, ns=1, nb=1, budget=(m * m * 4 * 3) // 2)
    ana, res = TraceAnalysis.analyze(sched, phi_profile().model_for(1))
    ana.verify_reconciliation(res)
    assert ana.verdict == "transfer-bound"
    assert ana.shares["h2d"] + ana.shares.get("d2h", 0.0) >= 0.5


def test_verdict_compute_bound_gpu_large():
    m = 8192
    sched = _gemm_sched(m=m, bpe=8, ns=2, nb=2, budget=(3 * m * m * 8) // 2)
    ana, res = TraceAnalysis.analyze(sched, gpu_profile().model_for(2))
    ana.verify_reconciliation(res)
    assert ana.verdict == "compute-bound"
    assert ana.shares["compute"] >= 0.5


def test_eviction_stalls_surface_when_buffers_scarce():
    """With nbuf=1, landing buffers recycle immediately: H2D transfers wait
    on eviction events and the blocking tails must be classified."""
    sched = _gemm_sched(ns=2, nb=1)
    ana, res = TraceAnalysis.analyze(sched, gpu_profile().model_for(2))
    ana.verify_reconciliation(res)
    stalls = [seg for seg in ana.path if seg.cls == "eviction-stall"]
    assert stalls, "expected eviction-stall segments at nbuf=1"
    assert all("holding" in seg.detail for seg in stalls)


def test_stream_utilization_and_gaps_account_for_makespan():
    sched = _gemm_sched()
    ana, _ = TraceAnalysis.analyze(sched, gpu_profile().model_for(2))
    for st in ana.streams:
        assert st.busy_seconds + st.idle_seconds == \
            pytest.approx(ana.makespan)
        assert 0.0 < st.utilization <= 1.0
    assert ana.stream_utilization().keys() == {0, 1}
    # every reported gap is attributed to something
    for g in ana.top_gaps(10):
        assert g.duration > 0 and g.cause


# ---------------------------------------------------- wall-clock span input
def test_from_spans_wall_clock_is_tolerant():
    rng = np.random.default_rng(0)
    m = 256
    A = rng.standard_normal((m, m)).astype(np.float32)
    B = rng.standard_normal((m, m)).astype(np.float32)
    C = np.zeros((m, m), dtype=np.float32)
    budget = (3 * m * m * 4) // 2
    part = plan_gemm_partition(m, m, m, budget, 4, nbuf=2, nstreams=2)
    sched = compile_pipeline(gemm_pipeline_spec(part, band=2),
                             nstreams=2, nbuf=2)
    ex = ScheduleExecutor(record_spans=True)
    HostOocRuntime(executor=ex).gemm(A, B, C, 1.0, 0.0, part,
                                     schedule=sched)
    ana = TraceAnalysis.from_spans(sched, ex.last_spans)
    assert not ana.exact and ana.source == "spans"
    # wall-clock paths still tile the timeline (idle-wait fillers allowed)
    assert ana.path[-1].end == ana.makespan
    for a, b in zip(ana.path, ana.path[1:]):
        assert a.end == b.start
    assert ana.verdict in ("transfer-bound", "compute-bound",
                           "dependency-bound")


def test_exact_mode_rejects_wall_spans():
    sched = _gemm_sched(m=256, budget=(3 * 256 * 256 * 4) // 2)
    res = simulate(sched, gpu_profile().model_for(2))
    jittered = [(tag, s, st + 1e-7, en + 2e-7)
                for (tag, s, st, en) in res.op_spans]
    with pytest.raises(RuntimeError, match="no exact predecessor"):
        TraceAnalysis(sched, jittered, tolerance=0.0)


def test_span_schedule_mismatch_raises():
    sched = _gemm_sched(m=256, budget=(3 * 256 * 256 * 4) // 2)
    res = simulate(sched, gpu_profile().model_for(2))
    with pytest.raises(ValueError, match="do not describe the same run"):
        TraceAnalysis(sched, res.op_spans[:-1])
    bad = [(tag + "?", s, st, en) for (tag, s, st, en) in res.op_spans]
    with pytest.raises(ValueError, match="tag"):
        TraceAnalysis(sched, bad)


# ------------------------------------------------------------------ what-if
def _c5_whatif(profile):
    m = 8192
    budget = (3 * m * m * 8) // 6
    return whatif_gemm(m, m, m, budget, profile, dtype="float64",
                       nstreams=1, nbuf=2)


def test_whatif_gpu_second_stream_beats_bandwidth():
    """Claim C5, gpu side: from 1 stream the tuner moves to 2 — and the
    what-if table says why: "+1 stream" gains more than bandwidth x1.25."""
    rep = _c5_whatif(gpu_profile())
    plus = rep.scenario("+1 stream")
    bw = rep.scenario("bandwidth x1.25")
    assert plus.feasible and bw.feasible
    assert plus.gain_seconds > bw.gain_seconds > 0
    assert rep.best(knobs=("bandwidth", "streams", "buffers")).name \
        == "+1 stream"


def test_whatif_phi_bandwidth_wins_streams_lose():
    """Claim C5, phi side: the shared-engine split efficiency makes a
    second stream a *loss*, so among the purchasable stream/buffer/
    bandwidth knobs more bandwidth helps most — the tuner stays at 1."""
    rep = _c5_whatif(phi_profile())
    assert rep.scenario("+1 stream").gain_seconds < 0
    assert rep.best(knobs=("bandwidth", "streams", "buffers")).name \
        == "bandwidth x1.25"
    assert rep.scenario("bandwidth x1.25").gain_seconds > 0


def test_whatif_report_shape_and_ranking():
    m = 512
    rep = whatif_gemm(m, m, m, (3 * m * m * 4) // 2, gpu_profile(),
                      nstreams=2, nbuf=2)
    assert rep.baseline.makespan > 0
    names = {s.name for s in rep.scenarios}
    assert {"baseline", "bandwidth x1.25", "flops x1.25",
            "+1 stream", "-1 stream", "+1 buffer", "-1 buffer"} <= names
    ranked = rep.ranked()
    assert all(a.gain_seconds >= b.gain_seconds
               for a, b in zip(ranked, ranked[1:]))
    doc = rep.to_json()
    assert doc["ranked"][0] == ranked[0].name


def test_whatif_infeasible_scenarios_are_reported_not_raised():
    m = 256
    # tight budget: ±1 buffer / stream re-partitions can overflow it
    rep = whatif_gemm(m, m, m, 290000, gpu_profile(), nstreams=1, nbuf=1)
    assert rep.baseline.makespan > 0
    for s in rep.scenarios:
        if not s.feasible:
            assert s.makespan == float("inf") and s.note


# --------------------------------------------------------------- publication
def test_record_analysis_and_whatif_metrics(_clean_obs):
    obs = _clean_obs
    obs.enable(metrics=True)
    sched = _gemm_sched(m=512, budget=(3 * 512 * 512 * 4) // 2)
    ana, _ = TraceAnalysis.analyze(sched, gpu_profile().model_for(2))
    obs.record_analysis(ana, kernel="gemm")
    m = obs.metrics
    assert m.get("repro_analysis_runs_total").value(kernel="gemm") == 1
    assert m.get("repro_analysis_makespan_seconds").value(
        kernel="gemm") == ana.makespan
    assert m.get("repro_analysis_verdict_info").value(
        kernel="gemm", verdict=ana.verdict) == 1
    assert m.get("repro_analysis_stream_utilization").value(
        kernel="gemm", stream="0") == ana.streams[0].utilization
    assert m.get("repro_analysis_critical_path_seconds") is not None

    rep = whatif_gemm(512, 512, 512, (3 * 512 * 512 * 4) // 2,
                      gpu_profile(), nstreams=2, nbuf=2)
    obs.record_whatif(rep, kernel="gemm")
    g = m.get("repro_analysis_whatif_gain_seconds")
    assert g.value(kernel="gemm", scenario="bandwidth x1.25") == \
        rep.scenario("bandwidth x1.25").gain_seconds


def test_analyze_hybrid_publishes_imbalance(_clean_obs):
    obs = _clean_obs
    obs.enable(metrics=True)
    m = 1024
    budget = (3 * m * m * 4) // 2
    devs = [DeviceSpec("gpu0", gpu_profile(), budget),
            DeviceSpec("phi0", phi_profile(), budget)]
    ha = analyze_hybrid(plan_hybrid_gemm(m, m, m, devs, dtype="float32"))
    g = obs.metrics.get("repro_analysis_hybrid_imbalance_ratio")
    assert g.value(kernel="gemm") == ha.imbalance
    runs = obs.metrics.get("repro_analysis_runs_total")
    assert runs.value(kernel="gemm:gpu0") == 1
    assert runs.value(kernel="gemm:phi0") == 1


# ------------------------------------------------------- plan-level helpers
def test_analyze_plan_replays_tuned_geometry():
    from repro.tune import AutoTuner

    m = 512
    budget = (3 * m * m * 4) // 2
    tuner = AutoTuner(profile=gpu_profile(), fingerprint="t", max_steps=256)
    plan = tuner.gemm_plan(m, m, m, budget)
    ana, res = analyze_plan(plan, gpu_profile())
    ana.verify_reconciliation(res)
    # the analysis attributes the same prediction the tuner ranked
    assert res.makespan == pytest.approx(plan.makespan)


def test_hcl_facade():
    from repro.core.api import hclTraceAnalysis

    sched = _gemm_sched(m=512, budget=(3 * 512 * 512 * 4) // 2)
    ana, res = hclTraceAnalysis(sched, hw=gpu_profile())
    ana.verify_reconciliation(res)
    again = hclTraceAnalysis(sched, res=res)
    assert again.makespan == ana.makespan
    with pytest.raises(ValueError, match="needs"):
        hclTraceAnalysis(sched)


def test_to_json_document_shape():
    sched = _gemm_sched(m=512, budget=(3 * 512 * 512 * 4) // 2)
    ana, _ = TraceAnalysis.analyze(sched, gpu_profile().model_for(2))
    doc = ana.to_json(max_path=0)
    assert doc["exact"] is True
    assert set(doc["shares"]) <= {"h2d", "d2h", "compute", "merge",
                                  "eviction-stall", "idle-wait"}
    assert len(doc["critical_path"]) == doc["critical_path_ops"]
    assert doc["critical_path"][0]["start"] == 0.0
    assert doc["critical_path"][-1]["end"] == doc["makespan_seconds"]
    assert "streams" in doc and "top_gaps" in doc
    assert doc["n_ops"] == len(sched.ops)
