"""Hybrid co-scheduler: balancer properties, exact execution, registry.

The acceptance bar (ISSUE 3): shares always cover the full problem and fit
each device's budget via ``working_set_bytes``; a dominated profile
degenerates to the single-device partition; hybrid GEMM/SYRK results are
bit-for-bit identical to the single-device ``ScheduleExecutor`` pipeline
(and match the ``kernels/ref.py`` oracle to float tolerance — the jnp
oracle fuses its epilogue differently, so bitwise holds against the
pipeline, not the oracle); hybrid attention merges partials exactly; and
the makespan of the balanced plan beats the best single device under the
canned gpu+phi pair.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (Device, RuntimeFactory, chrome_trace_groups,
                        ooc_attention, ooc_gemm, ooc_syrk,
                        register_runtime)
from repro.core.api import hclHybridRuntime, hclRuntimeFactory
from repro.core.runtime import (_RUNTIME_REGISTRY, HostOocRuntime,
                                OocRuntime)
from repro.hybrid import (DeviceSpec, HybridOocRuntime, balance_gemm,
                          balance_units, merge_attention_partials,
                          plan_hybrid_attention, plan_hybrid_gemm,
                          plan_hybrid_syrk, run_hybrid_attention,
                          run_hybrid_gemm, run_hybrid_syrk, simulate_hybrid)
from repro.kernels import ref
from repro.tune import gpu_profile, phi_profile, tpu_v5e_profile

from tests._hypothesis_shim import given, settings, st

FAST = dict(nbuf_options=(1, 2), max_steps=256)


def _devices(budget, flops_ratio=1.0):
    return [DeviceSpec("gpu0", gpu_profile(), budget),
            DeviceSpec("phi0", phi_profile(flops=0.725e12 * flops_ratio),
                       budget)]


# ----------------------------------------------------------- balancer props
@settings(max_examples=20, deadline=None)
@given(m=st.sampled_from([256, 520, 1024, 2048, 4096]),
       ratio=st.floats(min_value=0.05, max_value=1.0))
def test_shares_cover_problem_and_fit_budgets(m, ratio):
    N, K = 512, 256
    budget = (m * K + K * N + m * N) * 4 // 3
    devs = _devices(budget, flops_ratio=ratio)
    hp = plan_hybrid_gemm(m, N, K, devs, **FAST)
    # disjoint contiguous spans covering [0, m)
    assert sum(hp.balance.shares) == m
    cursor = 0
    for dp in hp.device_plans:
        assert dp.start == cursor and dp.length > 0
        cursor += dp.length
    assert cursor == m
    # every active sub-plan's working set fits ITS device budget — under
    # the generalized (nbuf, nstreams) model for searched candidates, or
    # the paper's legacy 2-deep model when the tuner kept the baseline
    # (the one candidate gemm_search_space exempts, by design)
    for dp in hp.device_plans:
        part = dp.gemm_partition()
        assert (part.M, part.N, part.K) == (dp.length, N, K)
        fits = min(part.working_set_bytes(dp.plan.nbuf, dp.plan.nstreams),
                   part.working_set_bytes())
        assert fits <= dp.device.budget_bytes


def test_balance_units_equalizes_linear_costs():
    # two devices with exact 3:1 linear rates -> shares converge to 3:1
    rates = (3.0, 1.0)
    res = balance_units(4096, 2, lambda i, u: u / rates[i], tolerance=0.01)
    assert res.converged and sum(res.shares) == 4096
    assert res.shares[0] == pytest.approx(3072, abs=64)
    assert res.spread <= 0.01


def test_dominant_profile_degenerates_to_single_device():
    M, N, K = 1024, 512, 256
    budget = (M * K + K * N + M * N) * 4 // 3
    # phi at 1e-5 of its flops: a sliver of work would still take longer
    # than the gpu doing everything
    devs = _devices(budget, flops_ratio=1e-5)
    hp = plan_hybrid_gemm(M, N, K, devs, **FAST)
    assert [dp.device.name for dp in hp.device_plans] == ["gpu0"]
    assert hp.device_plans[0].length == M
    assert hp.balance.spread == 0.0
    # the surviving sub-plan IS the single-device tuned plan
    from repro.tune import search_gemm
    solo = search_gemm(M, N, K, budget, gpu_profile(), dtype="float32",
                       fingerprint="hybrid-gpu0", **FAST)
    assert hp.device_plans[0].plan == solo


def test_infeasible_device_is_dropped():
    M, N, K = 1024, 512, 256
    rich = (M * K + K * N + M * N) * 4 // 3
    # second device's budget cannot hold even one aligned K-panel block
    devs = [DeviceSpec("big", gpu_profile(), rich),
            DeviceSpec("tiny", phi_profile(), 1024)]
    hp = plan_hybrid_gemm(M, N, K, devs, **FAST)
    assert [dp.device.name for dp in hp.device_plans] == ["big"]
    with pytest.raises(ValueError, match="no feasible split"):
        plan_hybrid_gemm(M, N, K,
                         [DeviceSpec("tiny", phi_profile(), 1024)], **FAST)


def test_unaligned_total_with_infeasible_device():
    # the rounding/unaligned tail must never land on a zero-weight device:
    # M=4100 leaves a 4-row remainder that belongs to the feasible device
    M, N, K = 4100, 512, 256
    rich = (M * K + K * N + M * N) * 4 // 3
    devs = [DeviceSpec("big", gpu_profile(), rich),
            DeviceSpec("tiny", phi_profile(), 1024)]
    hp = plan_hybrid_gemm(M, N, K, devs, **FAST)
    assert [dp.device.name for dp in hp.device_plans] == ["big"]
    assert hp.device_plans[0].length == M
    # same with the infeasible device listed last (the tail position)
    hp2 = plan_hybrid_gemm(M, N, K, list(reversed(devs)), **FAST)
    assert [dp.device.name for dp in hp2.device_plans] == ["big"]
    assert sum(hp2.balance.shares) == M


def test_balance_gemm_direct_oracle():
    M, N, K = 2048, 512, 256
    budget = (M * K + K * N + M * N) * 4 // 3
    # the direct oracle's makespan is a step function of the row count
    # (default partitions change only at bm thresholds), so equalization
    # is only achievable to the partition granularity — allow 10 %
    res = balance_gemm(M, N, K, _devices(budget), tolerance=0.10)
    assert sum(res.shares) == M and res.spread <= res.tolerance
    # the faster gpu-like profile takes the larger band
    assert res.shares[0] > res.shares[1] > 0


# ------------------------------------------------------- execution exactness
def test_hybrid_gemm_bitwise_vs_single_device_and_oracle(rng):
    M, N, K = 512, 384, 256
    A = rng.standard_normal((M, K)).astype(np.float32)
    B = rng.standard_normal((K, N)).astype(np.float32)
    C = rng.standard_normal((M, N)).astype(np.float32)
    budget = (A.nbytes + B.nbytes + C.nbytes) // 4
    hp = plan_hybrid_gemm(M, N, K, _devices(budget), **FAST)
    assert len(hp.device_plans) == 2, "both profiles must take work"
    out, groups = run_hybrid_gemm(A, B, C, 1.5, -0.5, hp, validate=True)
    single = ooc_gemm(A, B, C, 1.5, -0.5, budget_bytes=budget)
    assert np.array_equal(out, single)  # same pipeline, block for block
    expect = np.asarray(ref.gemm_ref(jnp.asarray(A), jnp.asarray(B),
                                     jnp.asarray(C), 1.5, -0.5))
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)
    assert [g[0] for g in groups] == ["gpu0", "phi0"]


def test_hybrid_syrk_bitwise_vs_single_device_and_oracle(rng):
    n, K = 512, 256
    P = rng.standard_normal((n, K)).astype(np.float32)
    C = rng.standard_normal((n, n)).astype(np.float32)
    budget = (2 * n * K + n * n) * 4 // 3
    hp = plan_hybrid_syrk(n, K, _devices(budget), **FAST)
    assert len(hp.device_plans) == 2
    out, _ = run_hybrid_syrk(P, C, 2.0, 0.5, hp, validate=True)
    single = ooc_syrk(P, C, 2.0, 0.5, budget_bytes=budget)
    assert np.array_equal(out, single)
    expect = np.asarray(ref.gemm_ref(jnp.asarray(P), jnp.asarray(P).T,
                                     jnp.asarray(C), 2.0, 0.5))
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)


def test_hybrid_attention_matches_oracle(rng):
    S, hkv, d, H = 1024, 4, 64, 8
    q = rng.standard_normal((H, d)).astype(np.float32)
    k = rng.standard_normal((S, hkv, d)).astype(np.float32)
    v = rng.standard_normal((S, hkv, d)).astype(np.float32)
    devs = _devices(k.nbytes // 2)
    hp = plan_hybrid_attention(S, hkv, d, H, devs, dtype="float32")
    assert sum(hp.balance.shares) == S and len(hp.device_plans) == 2
    out, _ = run_hybrid_attention(q, k, v, hp, validate=True)
    expect = np.asarray(ref.decode_attention_ref(
        jnp.asarray(q)[None], jnp.asarray(k)[None], jnp.asarray(v)[None],
        jnp.asarray([S]))[0])
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)


def test_merge_attention_partials_is_exact(rng):
    # partials from arbitrary chunkings combine to the same answer
    H, d = 8, 16
    parts = []
    for _ in range(3):
        m = rng.standard_normal(H).astype(np.float32)
        l = rng.uniform(0.5, 2.0, H).astype(np.float32)
        acc = rng.standard_normal((H, d)).astype(np.float32)
        parts.append((m, l, acc))
    merged = merge_attention_partials(parts)
    # fold the same partials in pairwise order: must agree to fp tolerance
    ab = merge_attention_partials(parts[:2])
    m01 = np.maximum(parts[0][0], parts[1][0])
    l01 = (parts[0][1] * np.exp(parts[0][0] - m01)
           + parts[1][1] * np.exp(parts[1][0] - m01))
    acc01 = (parts[0][2] * np.exp(parts[0][0] - m01)[:, None]
             + parts[1][2] * np.exp(parts[1][0] - m01)[:, None])
    seq = merge_attention_partials([(m01, l01, acc01), parts[2]])
    np.testing.assert_allclose(merged, seq, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(ab, acc01 / l01[:, None], rtol=1e-6)


# ------------------------------------------------------- entry points/facade
def test_ooc_gemm_devices_entry_point(rng):
    M, N, K = 384, 256, 192
    A = rng.standard_normal((M, K)).astype(np.float32)
    B = rng.standard_normal((K, N)).astype(np.float32)
    budget = (A.nbytes + B.nbytes + M * N * 4) // 3
    # bare (name, profile, budget) tuples are accepted
    out = ooc_gemm(A, B, budget_bytes=1,
                   devices=[("g", gpu_profile(), budget),
                            ("p", phi_profile(), budget)])
    np.testing.assert_allclose(out, np.asarray(ref.gemm_ref(
        jnp.asarray(A), jnp.asarray(B))), rtol=1e-4, atol=1e-4)


def test_ooc_attention_devices_entry_point(rng):
    S, hkv, d, H = 512, 2, 32, 4
    q = rng.standard_normal((H, d)).astype(np.float32)
    k = rng.standard_normal((S, hkv, d)).astype(np.float32)
    v = rng.standard_normal((S, hkv, d)).astype(np.float32)
    out = np.asarray(ooc_attention(
        q, k, v, budget_bytes=1, devices=_devices(k.nbytes)))
    expect = np.asarray(ref.decode_attention_ref(
        jnp.asarray(q)[None], jnp.asarray(k)[None], jnp.asarray(v)[None],
        jnp.asarray([S]))[0])
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)


def test_hybrid_runtime_facade_and_factory(rng):
    M, N, K = 384, 256, 192
    A = rng.standard_normal((M, K)).astype(np.float32)
    B = rng.standard_normal((K, N)).astype(np.float32)
    C = np.zeros((M, N), np.float32)
    budget = (A.nbytes + B.nbytes + C.nbytes) // 3
    rt = hclHybridRuntime(_devices(budget), **FAST)
    out = rt.gemm(A, B, C, 1.0, 0.0, record_spans=True)
    np.testing.assert_allclose(out, np.asarray(ref.gemm_ref(
        jnp.asarray(A), jnp.asarray(B))), rtol=1e-4, atol=1e-4)
    assert rt.last_plan is not None and rt.last_span_groups
    # the composite resolves through the declarative registry too
    dev = Device("HYBRID", 0, 2 * budget)
    rt2 = hclRuntimeFactory.create(dev, devices=_devices(budget))
    assert isinstance(rt2, HybridOocRuntime)
    # hclDeviceFactory's sizeless HYBRID placeholder reports the member sum
    from repro.core.api import hclDeviceFactory
    rt3 = hclRuntimeFactory.create(hclDeviceFactory.create("HYBRID"),
                                   devices=_devices(budget))
    assert rt3.mem_size() == 2 * budget
    with pytest.raises(ValueError, match="needs devices"):
        RuntimeFactory.create(Device("HYBRID", 0, 0))


# ------------------------------------------------- prediction + lane groups
def test_simulate_hybrid_beats_best_single_device():
    M = N = K = 8192
    budget = (M * K + K * N + M * N) * 8 // 6
    devs = _devices(budget)
    hp = plan_hybrid_gemm(M, N, K, devs, dtype="float64", tolerance=0.05,
                          nbuf_options=(1, 2), max_steps=128)
    sim = simulate_hybrid(hp)
    from repro.tune import search_gemm
    best = min(search_gemm(M, N, K, d.budget_bytes, d.profile,
                           dtype="float64", fingerprint="x",
                           nbuf_options=(1, 2), max_steps=128).makespan
               for d in devs)
    assert sim.makespan < best
    # finish times agree within the balancer tolerance...
    assert hp.balance.spread <= hp.tolerance
    # ...and simulate_hybrid re-derives exactly the tuned predictions
    for dp, got in zip(hp.device_plans, sim.device_makespans):
        assert got == pytest.approx(dp.plan.makespan, rel=1e-12)


def test_trace_lane_group_per_device_no_collisions():
    M, N, K = 1024, 512, 256
    budget = (M * K + K * N + M * N) * 4 // 3
    hp = plan_hybrid_gemm(M, N, K, _devices(budget), **FAST)
    trace = simulate_hybrid(hp).to_chrome_trace()
    events = trace["traceEvents"]
    names = {e["pid"]: e["args"]["name"] for e in events
             if e["name"] == "process_name"}
    assert names == {0: "gpu0", 1: "phi0"}
    # spans from different devices never share a (pid, tid, ts) slot even
    # though both executors number their streams from 0
    xs = [e for e in events if e["ph"] == "X"]
    assert {e["pid"] for e in xs} == {0, 1}
    slots = [(e["pid"], e["tid"], e["ts"]) for e in xs]
    assert len(slots) == len(set(slots))
    # per-pid span sets are exactly the per-device simulations
    per_dev = simulate_hybrid(hp).per_device
    for pid, (_, res) in enumerate(per_dev):
        assert sum(e["pid"] == pid for e in xs) == len(res.op_spans)


def test_chrome_trace_groups_standalone():
    groups = [("devA", [("DGEMM[0]", 0, 0.0, 1.0)]),
              ("devB", [("DGEMM[0]", 0, 0.5, 1.5)])]
    trace = chrome_trace_groups(groups)
    xs = [e for e in trace["traceEvents"] if e["ph"] == "X"]
    assert [(e["pid"], e["tid"]) for e in xs] == [(0, 0), (1, 0)]


# ------------------------------------------------------------ registry unit
def test_register_runtime_plugs_in_new_tier():
    @register_runtime("TESTTIER")
    class TestTierRuntime(HostOocRuntime):
        pass

    try:
        rt = RuntimeFactory.create(Device("TESTTIER", 0, 1 << 20))
        assert isinstance(rt, TestTierRuntime)
        assert "TESTTIER" in RuntimeFactory.registered()
    finally:
        _RUNTIME_REGISTRY.pop("TESTTIER", None)


def test_factory_rejects_unknown_tier():
    with pytest.raises(ValueError, match="registered tiers"):
        RuntimeFactory.create(Device("NOPE", 0, 1))
    for tier in ("HBM", "VMEM", "MESH", "HYBRID"):
        assert tier in RuntimeFactory.registered()
