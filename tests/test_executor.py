"""ScheduleExecutor: one interpreter for every host path, extensible by spec.

Covers the PipelineSpec -> Schedule -> Executor contract end-to-end: typed
payloads, positional handler dispatch, async double-buffered write-back, and
that a brand-new kernel (scaled block copy) rides the DSL with ~20 lines and
no interpreter code.
"""

import numpy as np
import pytest

from repro.core import (
    BlockRef,
    ComputeStage,
    HostOocRuntime,
    OpKind,
    PipelineSpec,
    ScheduleExecutor,
    SliceRef,
    StreamedOperand,
    WriteBack,
    build_gemm_schedule,
    compile_pipeline,
    plan_gemm_partition,
    validate_schedule,
)


def _problem(rng, M, N, K):
    A = rng.standard_normal((M, K)).astype(np.float32)
    B = rng.standard_normal((K, N)).astype(np.float32)
    C = rng.standard_normal((M, N)).astype(np.float32)
    return A, B, C


def test_schedules_carry_typed_payloads():
    part = plan_gemm_partition(512, 384, 256, 1_000_000, 4)
    sched = build_gemm_schedule(part)
    for op in sched.ops:
        if op.kind == OpKind.COMPUTE:
            assert isinstance(op.payload, BlockRef), op.tag
        else:
            assert isinstance(op.payload, SliceRef), op.tag
    # the C block round-trips through the same typed slice
    d2h = [o for o in sched.ops if o.kind == OpKind.D2H]
    assert all(o.payload.operand == "C" for o in d2h)


@pytest.mark.parametrize("async_wb", [False, True])
def test_executor_async_matches_sync(rng, async_wb):
    """The double-buffered write-back mode is a scheduling property, never a
    numerics property."""
    A, B, C = _problem(rng, 320, 256, 128)
    budget = (A.nbytes + B.nbytes + C.nbytes) // 4
    part = plan_gemm_partition(320, 256, 128, budget, 4)
    rt = HostOocRuntime(executor=ScheduleExecutor(async_writeback=async_wb))
    out = rt.gemm(A, B, C, 1.25, -0.5, part)
    expect = 1.25 * (A.astype(np.float64) @ B) - 0.5 * C
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)


def test_direct_host_impl_matches_oracle(rng):
    """The hand-rolled benchmark baseline dispatches through the shared
    executor and still equals the oracle."""
    from benchmarks.direct_impls import direct_host_ooc_gemm
    A, B, C = _problem(rng, 384, 256, 192)
    budget = (A.nbytes + B.nbytes + C.nbytes) // 5
    out = direct_host_ooc_gemm(A, B, C, 1.5, 0.5, budget)
    expect = 1.5 * (A.astype(np.float64) @ B) + 0.5 * C
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)


def test_unknown_kernel_raises(rng):
    import dataclasses
    part = plan_gemm_partition(128, 128, 64, 200_000, 4)
    sched = build_gemm_schedule(part)
    i = next(i for i, o in enumerate(sched.ops) if o.kind == OpKind.COMPUTE)
    sched.ops[i] = dataclasses.replace(
        sched.ops[i], payload=BlockRef("no_such_kernel", 0))
    A = np.zeros((128, 64), np.float32)
    B = np.zeros((64, 128), np.float32)
    out = np.zeros((128, 128), np.float32)
    with pytest.raises(KeyError, match="no_such_kernel"):
        ScheduleExecutor().run(sched, operands={"A": A, "B": B},
                               outputs={"C": out},
                               ctx={"alpha": 1.0, "beta": 0.0})


def test_new_kernel_via_spec(rng):
    """Reuse claim, falsifiable: a scaled block-copy kernel expressed as a
    PipelineSpec + one registered handler, with no interpreter loop."""
    from repro.core.runtime import register_op_handler

    M, N = 256, 192
    X = rng.standard_normal((M, N)).astype(np.float32)
    bm = 64
    h = M // bm

    @register_op_handler("scale_copy")
    def _scale_copy(st, op, ref):
        key = op.buffers_written[0]
        st.bufs[key] = st.bufs[op.buffers_read[0]] * st.ctx["gamma"]

    x = StreamedOperand(
        name="X", nblocks=h, block_of=lambda s: s,
        slice_of=lambda b: SliceRef("X", b, rows=(b * bm, bm)),
        bytes_of=lambda b: bm * N * 4,
    )
    y = StreamedOperand(
        name="Y", nblocks=h, block_of=lambda s: s,
        slice_of=lambda b: SliceRef("Y", b, rows=(b * bm, bm)),
        bytes_of=lambda b: bm * N * 4,
        inout=True,
    )
    spec = PipelineSpec(
        name="scale_copy", nsteps=h, operands=(x, y),
        compute=ComputeStage(kernel="scale_copy", reads=("X",),
                             flops_of=lambda s: bm * N),
        writeback=WriteBack(mode="each", operand="Y"),
        budget=1 << 20,
    )
    sched = compile_pipeline(spec, nstreams=2, nbuf=2)
    validate_schedule(sched)
    out = np.zeros((M, N), np.float32)
    ScheduleExecutor().run(sched, operands={"X": X}, outputs={"Y": out},
                           ctx={"gamma": 3.0})
    np.testing.assert_allclose(out, 3.0 * X, rtol=0, atol=0)
